//! End-to-end serving driver — the headline validation run.
//!
//! Boots the full production stack in one process (PJRT backend from AOT
//! artifacts, dynamic batcher, worker, TCP server), then plays a realistic
//! "AI assistant for chemists" workload from the test split against it
//! over real sockets: a warm-up, a sequential B=1 session comparing
//! standard vs speculative greedy decoding (the paper's Table 2 serving
//! regime), and a concurrent burst exercising the dynamic batcher.
//! Reports latency percentiles, throughput, acceptance rate, and server
//! metrics. Recorded in EXPERIMENTS.md §End-to-end.
//!
//! Usage:
//!     cargo run --release --example serve_assistant [n_requests] [port]
//!     RXNSPEC_BACKEND=rust ... (fallback without artifacts)

use std::net::TcpListener;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::{Duration, Instant};

use rxnspec::bench::{eval_setup, limit};
use rxnspec::coordinator::{run_worker, serve, Client, Metrics, RequestQueue, ServerState};

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx]
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests = args
        .first()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| limit(40));
    let port: u16 = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(0);

    let data = rxnspec::knobs::DATA.raw().unwrap_or_else(|| "data".into());
    let split = rxnspec::chem::read_split(std::path::Path::new(&data).join("fwd_test.tsv").as_path())?;
    eprintln!("loaded fwd test split: {} reactions", split.len());

    // --- boot the serving stack ---------------------------------------
    let state = Arc::new(ServerState {
        queue: RequestQueue::new(32, Duration::from_millis(5)),
        metrics: Arc::new(Metrics::default()),
        cache: Arc::new(rxnspec::cache::ServeCache::default()),
        shutdown: AtomicBool::new(false),
    });
    let listener = TcpListener::bind(("127.0.0.1", port))?;
    let addr = listener.local_addr()?.to_string();
    eprintln!("serving on {addr}");
    let accept_state = Arc::clone(&state);
    std::thread::spawn(move || serve(listener, accept_state));
    // PJRT handles are not Send: the worker thread constructs its own
    // backend (exactly how `rxnspec serve` runs it on the main thread).
    let worker_state = Arc::clone(&state);
    let worker = std::thread::spawn(move || {
        let (vocab, backend, _) = eval_setup("fwd").expect("worker setup");
        run_worker(
            &backend,
            &vocab,
            &worker_state.queue,
            &worker_state.metrics,
            &worker_state.cache,
        );
    });

    let mut client = Client::connect(&addr)?;
    assert!(client.ping()?);

    // --- phase 1: sequential assistant session (B=1) -------------------
    // A chemist pasting one reaction at a time; compare standard greedy
    // with speculative greedy (paper Table 2 regime).
    let queries: Vec<&str> = split.iter().take(n_requests).map(|e| e.src.as_str()).collect();
    for (decoder, label) in [("greedy", "greedy (B=1)"), ("spec:10", "speculative DL=10 (B=1)")] {
        let mut lat: Vec<f64> = Vec::new();
        let mut calls = 0usize;
        let mut acc = 0.0;
        let t0 = Instant::now();
        for q in &queries {
            let p = client.predict(decoder, q)?;
            lat.push(p.latency_ms);
            calls += p.decoder_calls;
            acc += p.acceptance_rate;
        }
        let wall = t0.elapsed().as_secs_f64();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "{label:<26} n={:<4} p50={:.0}ms p95={:.0}ms mean={:.0}ms thpt={:.2} req/s calls/req={:.1} acc={:.0}%",
            queries.len(),
            percentile(&lat, 0.50),
            percentile(&lat, 0.95),
            lat.iter().sum::<f64>() / lat.len() as f64,
            queries.len() as f64 / wall,
            calls as f64 / queries.len() as f64,
            acc * 100.0 / queries.len() as f64,
        );
    }

    // --- phase 2: concurrent burst (dynamic batching) ------------------
    // Fresh queries where available: phase 1 already warmed the result
    // cache for its slice, and a cold burst is what exercises batching.
    let burst = queries.len().min(16);
    let burst_queries: Vec<String> = split
        .iter()
        .skip(n_requests)
        .take(burst)
        .map(|e| e.src.clone())
        .collect();
    let (burst_queries, first_note): (Vec<String>, &str) = if burst_queries.len() == burst {
        (burst_queries, "batched, cold")
    } else {
        // Split too small for fresh queries: phase 1 already warmed these
        // under the same cache tag, so this burst is served from cache
        // and no longer measures batching — say so instead of lying.
        (
            queries[..burst].iter().map(|q| q.to_string()).collect(),
            "cache-warm: split too small for a cold burst",
        )
    };
    for (label, note) in [
        ("concurrent burst spec:10", first_note),
        ("repeat burst spec:10", "served from result cache"),
    ] {
        let t0 = Instant::now();
        let handles: Vec<_> = burst_queries
            .iter()
            .map(|q| {
                let addr = addr.clone();
                let q = q.to_string();
                std::thread::spawn(move || -> anyhow::Result<(f64, usize)> {
                    let mut c = Client::connect(&addr)?;
                    let p = c.predict("spec:10", &q)?;
                    Ok((p.latency_ms, p.decoder_calls))
                })
            })
            .collect();
        let results: Vec<(f64, usize)> = handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect::<anyhow::Result<Vec<_>>>()?;
        let mut lat: Vec<f64> = results.iter().map(|r| r.0).collect();
        let calls: usize = results.iter().map(|r| r.1).sum();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{label:<26} n={:<4} p50={:.0}ms p95={:.0}ms thpt={:.2} req/s calls={calls} ({note})",
            burst_queries.len(),
            percentile(&lat, 0.50),
            percentile(&lat, 0.95),
            burst_queries.len() as f64 / wall,
        );
    }

    // --- server-side metrics -------------------------------------------
    println!("\n--- server STATS ---");
    println!("{}", client.stats()?);

    state.queue.close();
    worker.join().unwrap();
    Ok(())
}
