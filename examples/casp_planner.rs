//! Computer-aided synthesis planning — the paper's motivating application.
//!
//! Runs the full CASP loop the paper's introduction describes: the trained
//! single-step retrosynthesis model (served from AOT artifacts, Python-free)
//! proposes disconnections; the best-first planner expands them until every
//! leaf is purchasable; the forward model optionally round-trip-checks each
//! step. Compares planning cost with standard beam search vs speculative
//! beam search — the end-to-end payoff of the paper's acceleration.
//!
//! Usage:
//!     cargo run --release --example casp_planner [n_targets] [-- --roundtrip]

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use rxnspec::bench::{eval_setup, limit};
use rxnspec::decoding::{greedy, Backend};
use rxnspec::planner::{
    ForwardCheck, Planner, PlannerCache, PlannerConfig, RetroDecoder, RetroModel, Stock,
};
use rxnspec::runtime::AnyBackend;
use rxnspec::vocab::Vocab;

/// Forward model wrapper for round-trip checking.
struct FwdModel<'a> {
    backend: &'a AnyBackend,
    vocab: &'a Vocab,
}

impl<'a> ForwardCheck for FwdModel<'a> {
    fn predict(&self, reactants: &[String]) -> Result<String> {
        let src = self.vocab.encode_wrapped(&reactants.join("."))?;
        if src.len() > self.backend.dims().s_len {
            anyhow::bail!("reactant set too long");
        }
        let out = greedy(self.backend, &src)?;
        Ok(self.vocab.decode(&out.hyps[0].tokens))
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let roundtrip = args.iter().any(|a| a == "--roundtrip");
    let n_targets = args
        .iter()
        .find_map(|a| a.parse::<usize>().ok())
        .unwrap_or_else(|| limit(5));

    let (vocab, retro_backend, split) = eval_setup("retro")?;
    let data = rxnspec::knobs::DATA.raw().unwrap_or_else(|| "data".into());
    let stock = Stock::load(&Path::new(&data).join("stock.txt"))?;
    eprintln!("stock: {} purchasable molecules", stock.len());

    // The forward model is only loaded when round-trip checking is on.
    let fwd_setup = if roundtrip {
        let (fv, fb, _) = eval_setup("fwd")?;
        Some((fv, fb))
    } else {
        None
    };

    let cfg = PlannerConfig {
        n_suggestions: 5,
        max_depth: 3,
        expansion_budget: 12,
        roundtrip_filter: roundtrip,
    };

    println!(
        "planning {} targets (beam 5, depth<=3, budget 12, roundtrip={})\n",
        n_targets, roundtrip
    );

    let mut totals = [(0f64, 0usize, 0usize); 2]; // (wall, solved, calls) per decoder
    for (di, decoder) in [
        RetroDecoder::BeamSearch,
        RetroDecoder::Sbs { draft_len: 10 },
    ]
    .iter()
    .enumerate()
    {
        let label = match decoder {
            RetroDecoder::BeamSearch => "BS    ",
            RetroDecoder::Sbs { .. } => "SBS   ",
        };
        println!("--- decoder: {label} ---");
        // One expansion memo per decoder (shared across targets, never
        // across decoders — entries are raw model output).
        let cache = Arc::new(PlannerCache::new(4096, 4));
        for ex in split.iter().take(n_targets) {
            let model = RetroModel::new(&retro_backend, &vocab, *decoder);
            let t0 = Instant::now();
            let (route, stats) = match &fwd_setup {
                Some((fv, fb)) => {
                    let fwd = FwdModel {
                        backend: fb,
                        vocab: fv,
                    };
                    Planner::with_forward(&model, &stock, &fwd, cfg.clone())
                        .with_cache(Arc::clone(&cache))
                        .plan(&ex.src)?
                }
                None => Planner::new(&model, &stock, cfg.clone())
                    .with_cache(Arc::clone(&cache))
                    .plan(&ex.src)?,
            };
            let wall = t0.elapsed().as_secs_f64();
            totals[di].0 += wall;
            totals[di].2 += model.decoder_calls.get();
            match route {
                Some(r) => {
                    totals[di].1 += 1;
                    println!(
                        "solved {} in {:.1}s ({} expansions, {} cache hits, {} decoder calls)",
                        ex.src,
                        wall,
                        stats.expansions,
                        stats.cache_hits,
                        model.decoder_calls.get()
                    );
                    print!("{}", r.render());
                }
                None => println!(
                    "unsolved {} in {:.1}s ({} expansions, {} cache hits)",
                    ex.src, wall, stats.expansions, stats.cache_hits
                ),
            }
        }
        let cs = cache.stats();
        println!(
            "expansion memo: {} entries, {} hits / {} lookups",
            cs.len,
            cs.hits,
            cs.hits + cs.misses
        );
        println!();
    }
    println!(
        "totals: BS {:.1}s ({} solved, {} calls) | SBS {:.1}s ({} solved, {} calls) | \
         planner speedup {:.2}x",
        totals[0].0,
        totals[0].1,
        totals[0].2,
        totals[1].0,
        totals[1].1,
        totals[1].2,
        totals[0].0 / totals[1].0.max(1e-9)
    );
    Ok(())
}
