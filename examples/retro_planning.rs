//! Single-step retrosynthesis for synthesis planning: propose multiple
//! reactant sets per target molecule with beam search vs speculative beam
//! search (the paper's §3.2 use case: a planning algorithm consumes
//! several candidate disconnections per node).
//!
//! `--trace` reproduces the paper's Figure 3 walk-through: per-iteration
//! candidate counts and the surviving ragged-length beams of one SBS run.
//!
//! Usage:
//!     cargo run --release --example retro_planning [-- --trace] [n_targets]

use std::time::Instant;

use rxnspec::bench::{eval_setup, limit};
use rxnspec::decoding::{beam_search, sbs, sbs_traced, SbsConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let trace = args.iter().any(|a| a == "--trace");
    let n_targets = args
        .iter()
        .find_map(|a| a.parse::<usize>().ok())
        .unwrap_or_else(|| limit(10));

    let (vocab, backend, split) = eval_setup("retro")?;
    let n = 5; // beam width / suggestions per target

    if trace {
        // Figure 3 reproduction: one traced SBS run.
        let ex = &split[0];
        println!("Target product: {}\n", ex.src);
        let src = vocab.encode_wrapped(&ex.src)?;
        let (out, tr) = sbs_traced(&backend, &src, &SbsConfig::new(2, 10))?;
        for (i, it) in tr.iterations.iter().enumerate().take(6) {
            println!(
                "iteration {}: {} decoder rows -> {} candidate sequences, kept {}:",
                i + 1,
                it.rows,
                it.candidates_generated,
                it.kept.len()
            );
            for (tokens, score) in &it.kept {
                println!("    {:>8.3}  {}", score, vocab.decode(tokens));
            }
        }
        println!("\nfinal suggestions:");
        for h in &out.hyps {
            println!("    {:>8.3}  {}", h.score, vocab.decode(&h.tokens));
        }
        return Ok(());
    }

    println!(
        "Proposing {n} reactant sets for {} target molecules (BS vs SBS DL=10)\n",
        n_targets.min(split.len())
    );
    let mut bs_total = 0f64;
    let mut sbs_total = 0f64;
    let mut agreement = 0usize;
    let mut total_hyps = 0usize;
    for ex in split.iter().take(n_targets) {
        let src = vocab.encode_wrapped(&ex.src)?;
        let t0 = Instant::now();
        let b = beam_search(&backend, &src, n)?;
        let bs_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let s = sbs(&backend, &src, &SbsConfig::new(n, 10))?;
        let sbs_s = t0.elapsed().as_secs_f64();
        bs_total += bs_s;
        sbs_total += sbs_s;
        for h in &s.hyps {
            total_hyps += 1;
            if b.hyps.iter().any(|g| g.tokens == h.tokens) {
                agreement += 1;
            }
        }
        println!("target: {}", ex.src);
        println!(
            "  BS : {:5.2}s ({} calls) | SBS: {:5.2}s ({} calls, acc {:.0}%) | speedup {:.2}x",
            bs_s,
            b.stats.decoder_calls,
            sbs_s,
            s.stats.decoder_calls,
            s.stats.acceptance.rate() * 100.0,
            bs_s / sbs_s
        );
        for (i, h) in s.hyps.iter().enumerate().take(3) {
            let mark = if vocab.decode(&h.tokens) == ex.tgt { "✓" } else { " " };
            println!("   {mark}{}. {}", i + 1, vocab.decode(&h.tokens));
        }
    }
    println!(
        "\ntotals: BS {bs_total:.1}s vs SBS {sbs_total:.1}s -> {:.2}x speedup; \
         hypothesis set agreement {:.1}%",
        bs_total / sbs_total,
        agreement as f64 * 100.0 / total_hyps as f64
    );
    Ok(())
}
