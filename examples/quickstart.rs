//! Quickstart: predict the product of the paper's Figure 2 reaction
//! (N-Boc protection of an indole) with standard greedy decoding, then
//! with speculative greedy decoding, and show the draft mechanics.
//!
//! Run after `make artifacts`:
//!     cargo run --release --example quickstart
//! Without compiled artifacts, fall back to the pure-Rust backend:
//!     RXNSPEC_BACKEND=rust cargo run --release --example quickstart

use std::time::Instant;

use rxnspec::bench::eval_setup;
use rxnspec::chem::tokenize;
use rxnspec::decoding::{greedy, spec_greedy};
use rxnspec::draft::{extract_drafts, DraftConfig};

fn main() -> anyhow::Result<()> {
    let (vocab, backend, _) = eval_setup("fwd")?;

    // The paper's Figure 2 reaction: indole ketone + Boc anhydride.
    let reactants = "c1c[nH]c2ccc(C(C)=O)cc12.C(=O)(OC(=O)OC(C)(C)C)OC(C)(C)C";
    println!("Query (reactants): {reactants}\n");

    // Show the drafting mechanics of Figure 2: sliding-window token
    // subsequences of the query.
    let toks = tokenize(reactants)?;
    let ids = vocab.encode(reactants)?;
    let drafts = extract_drafts(&ids, &DraftConfig::new(4));
    println!(
        "Draft construction (DL=4): {} tokens -> {} drafts (N_d cap 25). First five:",
        toks.len(),
        drafts.len()
    );
    for d in drafts.iter().take(5) {
        println!("  {:?}", vocab_decode_tokens(&vocab, d));
    }

    // Standard greedy decoding.
    let src = vocab.encode_wrapped(reactants)?;
    let t0 = Instant::now();
    let g = greedy(&backend, &src)?;
    let greedy_ms = t0.elapsed().as_secs_f64() * 1000.0;
    println!("\nGreedy product:       {}", vocab.decode(&g.hyps[0].tokens));
    println!(
        "  {} decoder calls, {:.1} ms",
        g.stats.decoder_calls, greedy_ms
    );

    // Speculative greedy decoding — same output, fewer calls.
    for dl in [4usize, 10] {
        let t0 = Instant::now();
        let s = spec_greedy(&backend, &src, &DraftConfig::new(dl))?;
        let ms = t0.elapsed().as_secs_f64() * 1000.0;
        println!(
            "Speculative (DL={dl:>2}):  {}",
            vocab.decode(&s.hyps[0].tokens)
        );
        println!(
            "  {} decoder calls, {:.1} ms, acceptance rate {:.0}%  ({}x fewer calls, {:.2}x faster)",
            s.stats.decoder_calls,
            ms,
            s.stats.acceptance.rate() * 100.0,
            g.stats.decoder_calls / s.stats.decoder_calls.max(1),
            greedy_ms / ms
        );
        assert_eq!(
            s.hyps[0].tokens, g.hyps[0].tokens,
            "speculative decoding must be lossless"
        );
    }
    println!("\nOutputs are token-identical: speculative decoding is lossless.");
    Ok(())
}

fn vocab_decode_tokens(vocab: &rxnspec::vocab::Vocab, ids: &[i64]) -> String {
    ids.iter().map(|&i| vocab.tok(i)).collect()
}
