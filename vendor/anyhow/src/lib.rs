//! Minimal offline shim of the `anyhow` API surface used by `rxnspec`.
//!
//! Differences from real `anyhow`, all invisible to this workspace:
//! context is flattened into the message eagerly (`"{context}: {cause}"`)
//! rather than kept as a lazily-rendered source chain, and there is no
//! downcasting or backtrace capture.

use std::fmt;

/// A type-erased error: a rendered message.
///
/// Deliberately does **not** implement [`std::error::Error`], exactly like
/// real `anyhow::Error` — that is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable (the `anyhow!` macro's engine).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line, mirroring `anyhow`'s outermost-first
    /// rendering of `.context(...)` chains.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: format!("{c}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Render the source chain now; nothing in this workspace downcasts.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option` error path.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: fmt::Display + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error {
            msg: format!("{c}: {e}"),
        })
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error {
            msg: format!("{}: {e}", f()),
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::Error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($tt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let n: usize = s.parse()?; // From<ParseIntError> via blanket impl
        ensure!(n < 100, "n too big: {n}");
        Ok(n)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert_eq!(parse("200").unwrap_err().to_string(), "n too big: 200");
    }

    #[test]
    fn context_flattens() {
        let e: Result<()> = Err(anyhow!("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<usize> = None;
        assert_eq!(
            o.with_context(|| "missing").unwrap_err().to_string(),
            "missing"
        );
    }

    #[test]
    fn bail_formats() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(())
        }
        assert!(f(false).is_ok());
        assert_eq!(f(true).unwrap_err().to_string(), "flag was true");
    }
}
