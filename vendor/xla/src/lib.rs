//! Stub of the `xla_extension` PJRT binding surface `runtime/pjrt.rs`
//! compiles against.
//!
//! Every entry point fails with [`Error`] ("PJRT runtime unavailable"),
//! and all handle types are **uninhabited** — if a caller somehow held a
//! `PjRtBuffer` the compiler would accept any method body on it, but no
//! value can ever exist, so the stub is provably inert. Swapping this
//! path dependency for the real bindings restores the production path
//! without touching `rxnspec` source (see vendor/README.md).

use std::fmt;

/// The uninhabited core: fields of this type make a struct impossible to
/// construct, turning its methods into statically-dead code.
enum Void {}

/// Error type matching the shape the real bindings expose (convertible
/// into `anyhow::Error` via `std::error::Error`).
#[derive(Debug)]
pub struct Error {
    what: &'static str,
}

impl Error {
    fn unavailable(what: &'static str) -> Error {
        Error { what }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: PJRT runtime unavailable (offline xla stub; use --backend rust, \
             or point the `xla` path dependency at the real bindings)",
            self.what
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types uploadable into device buffers.
pub trait NativeType {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

pub struct PjRtClient(Void);

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.0 {}
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        match self.0 {}
    }
}

pub struct PjRtBuffer(Void);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

pub struct PjRtLoadedExecutable(Void);

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

pub struct HloModuleProto(Void);

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation(Void);

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto.0 {}
    }
}

pub struct Literal(Void);

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal> {
        match self.0 {}
    }

    /// Destructure a 3-tuple literal — the `deccache` artifact's
    /// `(logp_window, k_cache', v_cache')` return shape.
    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        match self.0 {}
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self.0 {}
    }
}
