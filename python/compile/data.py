"""Corpus loading: tokenizer, vocabulary, and batch assembly.

The tokenizer mirrors `rust/src/chem/tokenizer.rs` exactly (same regex,
Schwaller et al. 2019 atomwise tokenization); `data/golden_tokens.tsv`
written by `gen-data` pins the two implementations together — see
`tests/test_tokenizer_parity.py`.

This module is build-time only: the serving path never imports Python.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from pathlib import Path

import numpy as np

# Special-token ids, fixed by convention across the whole stack
# (rust/src/vocab.rs hard-codes the same values).
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
UNK_ID = 3

SPECIALS = ["<pad>", "<bos>", "<eos>", "<unk>"]

# Schwaller et al. (2019) atomwise tokenization pattern — keep in sync with
# SMILES_TOKEN_PATTERN in rust/src/chem/tokenizer.rs.
SMILES_TOKEN_RE = re.compile(
    r"(\[[^\]]+\]|Br|Cl|N|O|S|P|F|I|B|b|c|n|o|s|p|\(|\)|\.|=|#|-|\+|\\|/|:|~|@|\?|>|\*|\$|%[0-9]{2}|[0-9]|[A-Za-z])"
)


def tokenize(smiles: str) -> list[str]:
    """Atomwise-tokenize a SMILES string; every byte must be consumed."""
    tokens = []
    pos = 0
    for m in SMILES_TOKEN_RE.finditer(smiles):
        if m.start() != pos:
            raise ValueError(f"cannot tokenize {smiles!r} at byte {pos}")
        tokens.append(m.group(0))
        pos = m.end()
    if pos != len(smiles):
        raise ValueError(f"cannot tokenize {smiles!r} at byte {pos}")
    return tokens


class Vocab:
    """Token <-> id mapping loaded from `data/vocab.txt` (written by
    `gen-data`; line number == id; first four lines are the specials)."""

    def __init__(self, tokens: list[str]):
        if tokens[:4] != SPECIALS:
            raise ValueError("not a rxnspec vocab file (bad specials header)")
        self.id_to_tok = tokens
        self.tok_to_id = {t: i for i, t in enumerate(tokens)}

    @classmethod
    def load(cls, path: str | Path) -> "Vocab":
        return cls(Path(path).read_text().splitlines())

    def __len__(self) -> int:
        return len(self.id_to_tok)

    def encode(self, smiles: str) -> list[int]:
        return [self.tok_to_id.get(t, UNK_ID) for t in tokenize(smiles)]

    def decode(self, ids) -> str:
        out = []
        for i in ids:
            i = int(i)
            if i == EOS_ID:
                break
            if i in (PAD_ID, BOS_ID):
                continue
            out.append(self.id_to_tok[i])
        return "".join(out)


@dataclass
class Example:
    src: str
    tgt: str
    template: str


def read_split(path: str | Path) -> list[Example]:
    """Read one TSV split written by `gen-data`."""
    out = []
    for line in Path(path).read_text().splitlines():
        if not line:
            continue
        parts = line.split("\t")
        out.append(Example(parts[0], parts[1], parts[2] if len(parts) > 2 else "unknown"))
    return out


def encode_batch(
    vocab: Vocab,
    examples: list[Example],
    s_len: int,
    t_len: int,
) -> dict[str, np.ndarray]:
    """Assemble one right-padded training batch.

    Returns arrays:
      src       [B, S] int32 — BOS + tokens + EOS, right-padded
      src_pad   [B, S] f32   — 1.0 on real positions
      tgt_in    [B, T] int32 — BOS + tokens, right-padded (decoder input)
      tgt_pos   [B, T] int32 — 0..len-1 (right-padded layout)
      tgt_pad   [B, T] f32
      labels    [B, T] int32 — tokens + EOS, right-padded
      loss_mask [B, T] f32   — 1.0 where labels are real
    """
    b = len(examples)
    src = np.zeros((b, s_len), dtype=np.int32)
    src_pad = np.zeros((b, s_len), dtype=np.float32)
    tgt_in = np.zeros((b, t_len), dtype=np.int32)
    tgt_pos = np.zeros((b, t_len), dtype=np.int32)
    tgt_pad = np.zeros((b, t_len), dtype=np.float32)
    labels = np.zeros((b, t_len), dtype=np.int32)
    loss_mask = np.zeros((b, t_len), dtype=np.float32)

    for i, ex in enumerate(examples):
        s = [BOS_ID] + vocab.encode(ex.src) + [EOS_ID]
        t = vocab.encode(ex.tgt)
        if len(s) > s_len:
            raise ValueError(f"src too long ({len(s)} > {s_len}): {ex.src}")
        if len(t) + 1 > t_len:
            raise ValueError(f"tgt too long ({len(t)+1} > {t_len}): {ex.tgt}")
        src[i, : len(s)] = s
        src_pad[i, : len(s)] = 1.0
        ti = [BOS_ID] + t
        tgt_in[i, : len(ti)] = ti
        tgt_pos[i, : len(ti)] = np.arange(len(ti))
        tgt_pad[i, : len(ti)] = 1.0
        lb = t + [EOS_ID]
        labels[i, : len(lb)] = lb
        loss_mask[i, : len(lb)] = 1.0

    return {
        "src": src,
        "src_pad": src_pad,
        "tgt_in": tgt_in,
        "tgt_pos": tgt_pos,
        "tgt_pad": tgt_pad,
        "labels": labels,
        "loss_mask": loss_mask,
    }
