"""Build-time training of the Molecular Transformer on the synthetic corpus.

Two checkpoints are produced, matching the paper's two experiments:
  * `fwd`   — reaction product prediction (USPTO-MIT-mixed analogue)
  * `retro` — single-step retrosynthesis (USPTO-50K analogue, trained on
              the reactant-order-augmented split)

Optimization is hand-written Adam (no optax in the offline environment)
with the Transformer inverse-sqrt warmup schedule and label smoothing,
mirroring Schwaller et al.'s recipe at toy scale.

Usage: python -m compile.train [--task fwd|retro|both] [--steps N]
       [--batch N] [--data DIR] [--out DIR] [--seed N]
"""

from __future__ import annotations

import argparse
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import weights_io
from .data import EOS_ID, Vocab, encode_batch, read_split
from .model import ModelConfig, decode_logprobs, encode, init_params

LABEL_SMOOTHING = 0.1


def loss_fn(params, cfg: ModelConfig, batch):
    mem = encode(params, cfg, batch["src"], batch["src_pad"])
    logp = decode_logprobs(
        params,
        cfg,
        batch["tgt_in"],
        batch["tgt_pos"],
        batch["tgt_pad"],
        mem,
        batch["src_pad"],
    )
    v = logp.shape[-1]
    onehot = jax.nn.one_hot(batch["labels"], v)
    smooth = onehot * (1.0 - LABEL_SMOOTHING) + LABEL_SMOOTHING / v
    nll = -(smooth * logp).sum(-1)
    mask = batch["loss_mask"]
    loss = (nll * mask).sum() / mask.sum()
    acc = ((logp.argmax(-1) == batch["labels"]) * mask).sum() / mask.sum()
    return loss, acc


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.98, eps=1e-9):
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    bc1 = 1 - b1**step
    bc2 = 1 - b2**step
    params = jax.tree.map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps),
        params,
        m,
        v,
    )
    return params, m, v


def lr_schedule(step, d_model, warmup=400, scale=2.0):
    step = jnp.maximum(step, 1.0)
    return scale * d_model**-0.5 * jnp.minimum(step**-0.5, step * warmup**-1.5)


@partial(jax.jit, static_argnames=("cfg",))
def train_step(params, m, v, step, cfg: ModelConfig, batch):
    (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, batch)
    lr = lr_schedule(step, cfg.d_model)
    params, m, v = adam_update(params, grads, m, v, step, lr)
    return params, m, v, loss, acc


def batches(rng: np.random.Generator, examples, vocab, cfg, batch_size):
    """Infinite shuffled batch stream."""
    idx = np.arange(len(examples))
    while True:
        rng.shuffle(idx)
        for i in range(0, len(idx) - batch_size + 1, batch_size):
            chunk = [examples[j] for j in idx[i : i + batch_size]]
            yield encode_batch(vocab, chunk, cfg.s_len, cfg.t_len)


def evaluate(params, cfg, vocab, examples, batch_size=64, max_batches=8):
    losses, accs = [], []
    for i in range(0, min(len(examples), max_batches * batch_size), batch_size):
        chunk = examples[i : i + batch_size]
        if len(chunk) < batch_size:
            break
        batch = encode_batch(vocab, chunk, cfg.s_len, cfg.t_len)
        loss, acc = jax.jit(loss_fn, static_argnames=("cfg",))(params, cfg, batch)
        losses.append(float(loss))
        accs.append(float(acc))
    return float(np.mean(losses)), float(np.mean(accs))


def train_task(task: str, data_dir: Path, out_dir: Path, steps: int, batch: int, seed: int):
    vocab = Vocab.load(data_dir / "vocab.txt")
    train = read_split(data_dir / f"{task}_train.tsv")
    val = read_split(data_dir / f"{task}_val.tsv")
    cfg = ModelConfig(vocab=len(vocab))
    print(f"[{task}] train={len(train)} val={len(val)} vocab={len(vocab)}")

    params = init_params(jax.random.PRNGKey(seed), cfg)
    zeros = jax.tree.map(jnp.zeros_like, params)
    m, v = zeros, jax.tree.map(jnp.zeros_like, params)

    rng = np.random.default_rng(seed)
    stream = batches(rng, train, vocab, cfg, batch)
    t0 = time.time()
    for step in range(1, steps + 1):
        b = next(stream)
        params, m, v, loss, acc = train_step(
            params, m, v, jnp.asarray(float(step)), cfg, b
        )
        if step % 100 == 0 or step == 1:
            print(
                f"[{task}] step {step:5d} loss {float(loss):.4f} "
                f"tok_acc {float(acc):.4f} ({time.time()-t0:.0f}s)",
                flush=True,
            )
        if step % 1000 == 0 or step == steps:
            vl, va = evaluate(params, cfg, vocab, val)
            print(f"[{task}]   val loss {vl:.4f} tok_acc {va:.4f}", flush=True)

    out_dir.mkdir(parents=True, exist_ok=True)
    weights_io.save(out_dir / f"weights_{task}.bin", params)
    weights_io.save_config(out_dir / f"config_{task}.txt", cfg.to_kv())
    print(f"[{task}] saved weights to {out_dir}/weights_{task}.bin")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="both", choices=["fwd", "retro", "both"])
    ap.add_argument("--steps", type=int, default=3000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--data", default="../data")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tasks = ["fwd", "retro"] if args.task == "both" else [args.task]
    for t in tasks:
        train_task(t, Path(args.data), Path(args.out), args.steps, args.batch, args.seed)


if __name__ == "__main__":
    main()
