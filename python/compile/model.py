"""L2: the Molecular Transformer in JAX.

An encoder-decoder transformer for SMILES-to-SMILES translation
(Schwaller et al., 2019), pre-LN variant, with **explicit position ids** in
the decoder: speculative beam search organizes ragged candidate batches by
left-padding, and "the starting positions for the positional encodings get
shifted accordingly" (paper Appendix B). Passing positions as an input
makes that shift a no-op in the artifact.

The decoder entrypoint returns log-softmaxed distributions (fused into the
AOT artifact) — the Rust coordinator consumes log-probs directly.

Attention is pluggable: `use_pallas=False` uses the pure-jnp reference
(autodiff-friendly; used in training), `use_pallas=True` calls the L1
Pallas kernel (used for the inference artifacts). The two are numerically
equivalent (pytest-checked), so training with the reference and serving
with the kernel is sound.

This file must stay in lock-step with the pure-Rust reference
implementation (`rust/src/model/reference.rs`); artifact↔reference parity
is covered by `rust/tests/test_backend_parity.rs`.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import mha as mha_pallas
from .kernels.ref import mha_ref

NEG_INF = -1e9


@dataclass(frozen=True)
class ModelConfig:
    vocab: int
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 256
    n_enc: int = 2
    n_dec: int = 2
    s_len: int = 96
    t_len: int = 96

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def to_kv(self) -> dict[str, int]:
        return {
            "vocab": self.vocab,
            "d_model": self.d_model,
            "n_heads": self.n_heads,
            "d_ff": self.d_ff,
            "n_enc": self.n_enc,
            "n_dec": self.n_dec,
            "s_len": self.s_len,
            "t_len": self.t_len,
        }


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def _attn_block(key, d_model):
    ks = jax.random.split(key, 4)
    return {
        "wq": _glorot(ks[0], (d_model, d_model)),
        "wk": _glorot(ks[1], (d_model, d_model)),
        "wv": _glorot(ks[2], (d_model, d_model)),
        "wo": _glorot(ks[3], (d_model, d_model)),
        "bq": jnp.zeros((d_model,)),
        "bk": jnp.zeros((d_model,)),
        "bv": jnp.zeros((d_model,)),
        "bo": jnp.zeros((d_model,)),
    }


def _ffn_block(key, d_model, d_ff):
    k1, k2 = jax.random.split(key)
    return {
        "w1": _glorot(k1, (d_model, d_ff)),
        "b1": jnp.zeros((d_ff,)),
        "w2": _glorot(k2, (d_ff, d_model)),
        "b2": jnp.zeros((d_model,)),
    }


def _ln_block(d_model):
    return {"g": jnp.ones((d_model,)), "b": jnp.zeros((d_model,))}


def init_params(key, cfg: ModelConfig) -> dict:
    """Initialize all model parameters (nested dict keyed as serialized)."""
    n_keys = 2 + cfg.n_enc * 2 + cfg.n_dec * 3 + 1
    keys = iter(jax.random.split(key, n_keys))
    params: dict = {
        "tok_emb": jax.random.normal(next(keys), (cfg.vocab, cfg.d_model))
        * (cfg.d_model**-0.5),
        "out_w": _glorot(next(keys), (cfg.d_model, cfg.vocab)),
        "out_b": jnp.zeros((cfg.vocab,)),
        "enc_ln_f": _ln_block(cfg.d_model),
        "dec_ln_f": _ln_block(cfg.d_model),
    }
    for i in range(cfg.n_enc):
        params[f"enc{i}"] = {
            "ln1": _ln_block(cfg.d_model),
            "attn": _attn_block(next(keys), cfg.d_model),
            "ln2": _ln_block(cfg.d_model),
            "ffn": _ffn_block(next(keys), cfg.d_model, cfg.d_ff),
        }
    for i in range(cfg.n_dec):
        params[f"dec{i}"] = {
            "ln1": _ln_block(cfg.d_model),
            "self_attn": _attn_block(next(keys), cfg.d_model),
            "ln2": _ln_block(cfg.d_model),
            "cross_attn": _attn_block(next(keys), cfg.d_model),
            "ln3": _ln_block(cfg.d_model),
            "ffn": _ffn_block(next(keys), cfg.d_model, cfg.d_ff),
        }
    return params


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _layer_norm(p, x, eps: float = 1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def sinusoidal_pe(pos, d_model: int):
    """Sinusoidal positional encoding for explicit position ids.

    pos: [..., L] int32 → [..., L, d_model] f32.
    """
    half = d_model // 2
    i = jnp.arange(half, dtype=jnp.float32)
    freq = jnp.exp(-jnp.log(10000.0) * (2.0 * i / d_model))
    ang = pos[..., None].astype(jnp.float32) * freq  # [..., L, half]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe


def _split_heads(x, n_heads):
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def _attention(p, cfg, x_q, x_kv, mask, use_pallas):
    q = _split_heads(x_q @ p["wq"] + p["bq"], cfg.n_heads)
    k = _split_heads(x_kv @ p["wk"] + p["bk"], cfg.n_heads)
    v = _split_heads(x_kv @ p["wv"] + p["bv"], cfg.n_heads)
    f = mha_pallas if use_pallas else mha_ref
    o = f(q, k, v, mask)
    return _merge_heads(o) @ p["wo"] + p["bo"]


def _ffn(p, x):
    return jnp.maximum(x @ p["w1"] + p["b1"], 0.0) @ p["w2"] + p["b2"]


def encode(params, cfg: ModelConfig, src, src_pad, *, use_pallas: bool = False):
    """Encoder forward: (src [B,S] i32, src_pad [B,S] f32) → [B,S,D] f32.

    Positions in the encoder are implicit 0..S-1 (sources are always
    right-padded; pad positions produce activations that the pad mask
    removes from every subsequent attention).
    """
    b, s = src.shape
    x = params["tok_emb"][src] * jnp.sqrt(float(cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = x + sinusoidal_pe(pos, cfg.d_model)
    # Key-side padding mask: [B, 1, 1, S] additive.
    mask = (1.0 - src_pad)[:, None, None, :] * NEG_INF
    for i in range(cfg.n_enc):
        p = params[f"enc{i}"]
        x = x + _attention(p["attn"], cfg, _layer_norm(p["ln1"], x), _layer_norm(p["ln1"], x), mask, use_pallas)
        x = x + _ffn(p["ffn"], _layer_norm(p["ln2"], x))
    return _layer_norm(params["enc_ln_f"], x)


def decode_logprobs(
    params,
    cfg: ModelConfig,
    tgt,
    tgt_pos,
    tgt_pad,
    mem,
    mem_pad,
    *,
    use_pallas: bool = False,
    out_window: int | None = None,
):
    """Decoder forward returning log-probabilities.

    Args:
      tgt:     [B, T] i32 — token ids, left- or right-padded
      tgt_pos: [B, T] i32 — explicit position ids (left-pad offsets applied
               by the caller; the paper's shifted positional encodings)
      tgt_pad: [B, T] f32 — 1.0 on real positions
      mem:     [B, S, D] f32 — encoder output
      mem_pad: [B, S] f32

    Returns: [B, T, V] f32 log-probs (log-softmax fused here so the AOT
    artifact hands the Rust coordinator ready-to-sum scores).
    """
    b, t = tgt.shape
    x = params["tok_emb"][tgt] * jnp.sqrt(float(cfg.d_model))
    x = x + sinusoidal_pe(tgt_pos, cfg.d_model)

    # Causal mask over absolute columns works for both right- and left-
    # padded layouts (real tokens are contiguous and ordered either way),
    # combined with the key-side pad mask.
    causal = jnp.tril(jnp.ones((t, t), dtype=jnp.float32))
    self_mask = (1.0 - causal)[None, None, :, :] * NEG_INF
    self_mask = self_mask + (1.0 - tgt_pad)[:, None, None, :] * NEG_INF
    self_mask = jnp.maximum(self_mask, NEG_INF)  # avoid -inf accumulation
    cross_mask = (1.0 - mem_pad)[:, None, None, :] * NEG_INF

    for i in range(cfg.n_dec):
        p = params[f"dec{i}"]
        h = _layer_norm(p["ln1"], x)
        x = x + _attention(p["self_attn"], cfg, h, h, self_mask, use_pallas)
        h = _layer_norm(p["ln2"], x)
        x = x + _attention(p["cross_attn"], cfg, h, mem, cross_mask, use_pallas)
        x = x + _ffn(p["ffn"], _layer_norm(p["ln3"], x))
    x = _layer_norm(params["dec_ln_f"], x)
    if out_window is not None:
        # Left-padded rows end at the last column, so the trailing
        # `out_window` columns cover every position a decoding step reads
        # (prefix head + draft verify region). Slicing before the output
        # projection removes most of the [T, V] matmul + log-softmax.
        x = x[:, -out_window:, :]
    logits = x @ params["out_w"] + params["out_b"]
    return jax.nn.log_softmax(logits, axis=-1)
