"""L2: the Molecular Transformer in JAX.

An encoder-decoder transformer for SMILES-to-SMILES translation
(Schwaller et al., 2019), pre-LN variant, with **explicit position ids** in
the decoder: speculative beam search organizes ragged candidate batches by
left-padding, and "the starting positions for the positional encodings get
shifted accordingly" (paper Appendix B). Passing positions as an input
makes that shift a no-op in the artifact.

The decoder entrypoint returns log-softmaxed distributions (fused into the
AOT artifact) — the Rust coordinator consumes log-probs directly.

Attention is pluggable: `use_pallas=False` uses the pure-jnp reference
(autodiff-friendly; used in training), `use_pallas=True` calls the L1
Pallas kernel (used for the inference artifacts). The two are numerically
equivalent (pytest-checked), so training with the reference and serving
with the kernel is sound.

This file must stay in lock-step with the pure-Rust reference
implementation (`rust/src/model/reference.rs`); artifact↔reference parity
is covered by `rust/tests/test_backend_parity.rs`.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import mha as mha_pallas
from .kernels.ref import mha_ref

NEG_INF = -1e9


@dataclass(frozen=True)
class ModelConfig:
    vocab: int
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 256
    n_enc: int = 2
    n_dec: int = 2
    s_len: int = 96
    t_len: int = 96

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def to_kv(self) -> dict[str, int]:
        return {
            "vocab": self.vocab,
            "d_model": self.d_model,
            "n_heads": self.n_heads,
            "d_ff": self.d_ff,
            "n_enc": self.n_enc,
            "n_dec": self.n_dec,
            "s_len": self.s_len,
            "t_len": self.t_len,
        }


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def _attn_block(key, d_model):
    ks = jax.random.split(key, 4)
    return {
        "wq": _glorot(ks[0], (d_model, d_model)),
        "wk": _glorot(ks[1], (d_model, d_model)),
        "wv": _glorot(ks[2], (d_model, d_model)),
        "wo": _glorot(ks[3], (d_model, d_model)),
        "bq": jnp.zeros((d_model,)),
        "bk": jnp.zeros((d_model,)),
        "bv": jnp.zeros((d_model,)),
        "bo": jnp.zeros((d_model,)),
    }


def _ffn_block(key, d_model, d_ff):
    k1, k2 = jax.random.split(key)
    return {
        "w1": _glorot(k1, (d_model, d_ff)),
        "b1": jnp.zeros((d_ff,)),
        "w2": _glorot(k2, (d_ff, d_model)),
        "b2": jnp.zeros((d_model,)),
    }


def _ln_block(d_model):
    return {"g": jnp.ones((d_model,)), "b": jnp.zeros((d_model,))}


def init_params(key, cfg: ModelConfig) -> dict:
    """Initialize all model parameters (nested dict keyed as serialized)."""
    n_keys = 2 + cfg.n_enc * 2 + cfg.n_dec * 3 + 1
    keys = iter(jax.random.split(key, n_keys))
    params: dict = {
        "tok_emb": jax.random.normal(next(keys), (cfg.vocab, cfg.d_model))
        * (cfg.d_model**-0.5),
        "out_w": _glorot(next(keys), (cfg.d_model, cfg.vocab)),
        "out_b": jnp.zeros((cfg.vocab,)),
        "enc_ln_f": _ln_block(cfg.d_model),
        "dec_ln_f": _ln_block(cfg.d_model),
    }
    for i in range(cfg.n_enc):
        params[f"enc{i}"] = {
            "ln1": _ln_block(cfg.d_model),
            "attn": _attn_block(next(keys), cfg.d_model),
            "ln2": _ln_block(cfg.d_model),
            "ffn": _ffn_block(next(keys), cfg.d_model, cfg.d_ff),
        }
    for i in range(cfg.n_dec):
        params[f"dec{i}"] = {
            "ln1": _ln_block(cfg.d_model),
            "self_attn": _attn_block(next(keys), cfg.d_model),
            "ln2": _ln_block(cfg.d_model),
            "cross_attn": _attn_block(next(keys), cfg.d_model),
            "ln3": _ln_block(cfg.d_model),
            "ffn": _ffn_block(next(keys), cfg.d_model, cfg.d_ff),
        }
    return params


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _layer_norm(p, x, eps: float = 1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def sinusoidal_pe(pos, d_model: int):
    """Sinusoidal positional encoding for explicit position ids.

    pos: [..., L] int32 → [..., L, d_model] f32.
    """
    half = d_model // 2
    i = jnp.arange(half, dtype=jnp.float32)
    freq = jnp.exp(-jnp.log(10000.0) * (2.0 * i / d_model))
    ang = pos[..., None].astype(jnp.float32) * freq  # [..., L, half]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe


def _split_heads(x, n_heads):
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def _attention(p, cfg, x_q, x_kv, mask, use_pallas):
    q = _split_heads(x_q @ p["wq"] + p["bq"], cfg.n_heads)
    k = _split_heads(x_kv @ p["wk"] + p["bk"], cfg.n_heads)
    v = _split_heads(x_kv @ p["wv"] + p["bv"], cfg.n_heads)
    f = mha_pallas if use_pallas else mha_ref
    o = f(q, k, v, mask)
    return _merge_heads(o) @ p["wo"] + p["bo"]


def _ffn(p, x):
    return jnp.maximum(x @ p["w1"] + p["b1"], 0.0) @ p["w2"] + p["b2"]


def encode(params, cfg: ModelConfig, src, src_pad, *, use_pallas: bool = False):
    """Encoder forward: (src [B,S] i32, src_pad [B,S] f32) → [B,S,D] f32.

    Positions in the encoder are implicit 0..S-1 (sources are always
    right-padded; pad positions produce activations that the pad mask
    removes from every subsequent attention).
    """
    b, s = src.shape
    x = params["tok_emb"][src] * jnp.sqrt(float(cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = x + sinusoidal_pe(pos, cfg.d_model)
    # Key-side padding mask: [B, 1, 1, S] additive.
    mask = (1.0 - src_pad)[:, None, None, :] * NEG_INF
    for i in range(cfg.n_enc):
        p = params[f"enc{i}"]
        x = x + _attention(p["attn"], cfg, _layer_norm(p["ln1"], x), _layer_norm(p["ln1"], x), mask, use_pallas)
        x = x + _ffn(p["ffn"], _layer_norm(p["ln2"], x))
    return _layer_norm(params["enc_ln_f"], x)


def decode_logprobs(
    params,
    cfg: ModelConfig,
    tgt,
    tgt_pos,
    tgt_pad,
    mem,
    mem_pad,
    *,
    use_pallas: bool = False,
    out_window: int | None = None,
):
    """Decoder forward returning log-probabilities.

    Args:
      tgt:     [B, T] i32 — token ids, left- or right-padded
      tgt_pos: [B, T] i32 — explicit position ids (left-pad offsets applied
               by the caller; the paper's shifted positional encodings)
      tgt_pad: [B, T] f32 — 1.0 on real positions
      mem:     [B, S, D] f32 — encoder output
      mem_pad: [B, S] f32

    Returns: [B, T, V] f32 log-probs (log-softmax fused here so the AOT
    artifact hands the Rust coordinator ready-to-sum scores).
    """
    b, t = tgt.shape
    x = params["tok_emb"][tgt] * jnp.sqrt(float(cfg.d_model))
    x = x + sinusoidal_pe(tgt_pos, cfg.d_model)

    # Causal mask over absolute columns works for both right- and left-
    # padded layouts (real tokens are contiguous and ordered either way),
    # combined with the key-side pad mask.
    causal = jnp.tril(jnp.ones((t, t), dtype=jnp.float32))
    self_mask = (1.0 - causal)[None, None, :, :] * NEG_INF
    self_mask = self_mask + (1.0 - tgt_pad)[:, None, None, :] * NEG_INF
    self_mask = jnp.maximum(self_mask, NEG_INF)  # avoid -inf accumulation
    cross_mask = (1.0 - mem_pad)[:, None, None, :] * NEG_INF

    for i in range(cfg.n_dec):
        p = params[f"dec{i}"]
        h = _layer_norm(p["ln1"], x)
        x = x + _attention(p["self_attn"], cfg, h, h, self_mask, use_pallas)
        h = _layer_norm(p["ln2"], x)
        x = x + _attention(p["cross_attn"], cfg, h, mem, cross_mask, use_pallas)
        x = x + _ffn(p["ffn"], _layer_norm(p["ln3"], x))
    x = _layer_norm(params["dec_ln_f"], x)
    if out_window is not None:
        # Left-padded rows end at the last column, so the trailing
        # `out_window` columns cover every position a decoding step reads
        # (prefix head + draft verify region). Slicing before the output
        # projection removes most of the [T, V] matmul + log-softmax.
        x = x[:, -out_window:, :]
    logits = x @ params["out_w"] + params["out_b"]
    return jax.nn.log_softmax(logits, axis=-1)


def decode_logprobs_cached(
    params,
    cfg: ModelConfig,
    tgt_window,
    tgt_pos,
    tgt_pad,
    mem,
    mem_pad,
    k_cache,
    v_cache,
    cache_len,
    *,
    use_pallas: bool = False,
):
    """Cache-shaped decoder forward: attention over the appended window only.

    The KV-cache formulation: each call appends a small window of tokens
    to a committed prefix whose per-layer self-attention K/V already live
    in `k_cache`/`v_cache`, so the decoder stack runs over `W` positions
    instead of the whole prefix (the ~L/2 → ~1 recompute-per-token win
    the Rust runtime's `deccache` sessions realize).

    Args:
      tgt_window: [B, W] i32 — appended tokens, **right-padded** (real
                  tokens occupy slots 0..m; contrast the left-padded full
                  decoder: right padding keeps the cache write contiguous
                  at `cache_len`)
      tgt_pos:    [B, W] i32 — absolute position ids (`cache_len + slot`
                  on real slots)
      tgt_pad:    [B, W] f32 — 1.0 on real slots
      mem:        [B, S, D] f32 — encoder output, one row per lane
      mem_pad:    [B, S] f32
      k_cache:    [L, B, T, D] f32 — per-decoder-layer self-attention keys
                  of the committed prefix (post-projection, pre-head-split);
                  slots ≥ `cache_len` are ignored and overwritten
      v_cache:    [L, B, T, D] f32 — same for values
      cache_len:  [B] i32 — committed prefix length per lane

    Returns `(logp [B, W, V], k_cache' [L, B, T, D], v_cache')`: successor
    log-probs for the window plus the updated caches (input caches with
    the window's K/V written at slots `cache_len..cache_len+m`; slots
    beyond stay untouched — stale contents there are masked out of every
    attention, so a host-side rewind is just a smaller `cache_len`).
    """
    b, w = tgt_window.shape
    t_cap = k_cache.shape[2]
    x = params["tok_emb"][tgt_window] * jnp.sqrt(float(cfg.d_model))
    x = x + sinusoidal_pe(tgt_pos, cfg.d_model)

    # Cache-slot geometry, shared by the masked attention and the cache
    # write. `jwin[b, t]` is the window slot that cache slot `t` receives
    # this call (negative / ≥ W means "not written").
    t_idx = jnp.arange(t_cap, dtype=jnp.int32)
    cl = cache_len.astype(jnp.int32)[:, None]  # [B, 1]
    jwin = t_idx[None, :] - cl  # [B, T]
    in_window = (jwin >= 0) & (jwin < w)
    jwin_c = jnp.clip(jwin, 0, w - 1)
    # A cache slot is a *real* key iff it is committed prefix, or it is
    # written this call from a real (non-pad) window slot.
    win_real = jnp.take_along_axis(tgt_pad, jwin_c, axis=1) * in_window  # [B, T]
    key_real = jnp.where(t_idx[None, :] < cl, 1.0, win_real)  # [B, T]
    # Causal: query slot i (absolute position cache_len + i) may attend
    # cache slot t iff t ≤ cache_len + i. Combined into one additive mask
    # so NEG_INF never accumulates.
    i_idx = jnp.arange(w, dtype=jnp.int32)
    causal = t_idx[None, None, :] <= cl[:, :, None] + i_idx[None, :, None]  # [B, W, T]
    allowed = jnp.where(causal, key_real[:, None, :], 0.0)
    self_mask = (1.0 - allowed)[:, None, :, :] * NEG_INF  # [B, 1, W, T]
    cross_mask = (1.0 - mem_pad)[:, None, None, :] * NEG_INF

    write = (win_real > 0)[:, :, None]  # [B, T, 1]

    def scatter_window(cache, new):
        # Clamp-free per-lane write of `new[b, jwin[b, t]]` into slot `t`
        # for slots inside the window: gather + select instead of a
        # dynamic-update-slice, so per-lane `cache_len` offsets never
        # clamp or spill past T.
        gathered = jnp.take_along_axis(new, jwin_c[:, :, None], axis=1)  # [B, T, D]
        return jnp.where(write, gathered, cache)

    f = mha_pallas if use_pallas else mha_ref
    k_out = []
    v_out = []
    for i in range(cfg.n_dec):
        p = params[f"dec{i}"]
        sa = p["self_attn"]
        h = _layer_norm(p["ln1"], x)
        q = _split_heads(h @ sa["wq"] + sa["bq"], cfg.n_heads)
        k_upd = scatter_window(k_cache[i], h @ sa["wk"] + sa["bk"])
        v_upd = scatter_window(v_cache[i], h @ sa["wv"] + sa["bv"])
        k_out.append(k_upd)
        v_out.append(v_upd)
        o = f(q, _split_heads(k_upd, cfg.n_heads), _split_heads(v_upd, cfg.n_heads), self_mask)
        x = x + _merge_heads(o) @ sa["wo"] + sa["bo"]
        h = _layer_norm(p["ln2"], x)
        x = x + _attention(p["cross_attn"], cfg, h, mem, cross_mask, use_pallas)
        x = x + _ffn(p["ffn"], _layer_norm(p["ln3"], x))
    x = _layer_norm(params["dec_ln_f"], x)
    logits = x @ params["out_w"] + params["out_b"]
    return jax.nn.log_softmax(logits, axis=-1), jnp.stack(k_out), jnp.stack(v_out)
