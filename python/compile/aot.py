"""AOT lowering: JAX model (with the Pallas kernel) → HLO text artifacts.

Emits, per task (`fwd`, `retro`) and bucket:
    artifacts/enc_{task}_b{B}.hlo.txt       (src, src_pad, *weights) → (mem,)
    artifacts/dec_{task}_b{EB}_t{T}.hlo.txt (tgt, pos, tgt_pad, mem, mem_pad,
                                             *weights) → (logp,)
    artifacts/deccache_{task}_b{EB}_t{W}.hlo.txt
                                            (tgt_window, pos, tgt_pad, mem,
                                             mem_pad, k_cache[L,EB,T,d],
                                             v_cache[L,EB,T,d], cache_len,
                                             *weights)
                                            → (logp_window, k_cache', v_cache')
plus `artifacts/manifest.tsv` (columns `kind\ttask\teb\ttlen\tfile`; `meta`
rows carry `key`/`value` in the eb/tlen columns — see MANIFEST_COLUMNS).

Decoder artifacts come in a (EB, T) grid: EB is the effective batch
(beams × drafts) and T the decoder window. Most of a decode happens at
short prefixes, and without a KV cache the per-call cost is ∝ T — the
window buckets recover that factor (picked per call by the Rust runtime).
The `deccache` grid goes further: T there is the *appended-window* bucket
W, the per-layer K/V of the committed prefix ride as device-resident
buffers, and per-call cost is ∝ W — the ~L/2 → ~1 recompute-per-token
win for every decoder once the Rust `DecoderSession` threads the caches
call to call.

Design choices (see DESIGN.md §5):
  * **HLO text**, not serialized protos — jax ≥ 0.5 emits 64-bit
    instruction ids that xla_extension 0.5.1 rejects; the text parser
    reassigns ids (aot_recipe / xla-example gotcha).
  * **Weights as arguments**, not baked constants — constants would bloat
    each text artifact by tens of MB and slow parsing; instead the Rust
    runtime uploads the RXW1 weights once as device-resident PjRtBuffers
    and passes them to every call. Argument order is the lexicographic
    flat-key order, identical on both sides.
  * `use_pallas=True`: the artifacts contain the L1 kernel's lowering
    (interpret mode → plain HLO, runnable on CPU PJRT).

Usage: python -m compile.aot [--out DIR] [--tasks fwd,retro]
       [--enc-buckets 1,8,32] [--dec-buckets 1,4,8,16,32,64]
       [--dec-t-buckets 24,48,96] [--cache-windows 1,4,8,16]
"""

from __future__ import annotations

import argparse
import hashlib
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import weights_io
from .model import ModelConfig, decode_logprobs, decode_logprobs_cached, encode

# Trailing-columns window of the decfast artifacts. Must be ≥ the largest
# draft length + 1 (verify region) — the Rust runtime only routes calls
# whose read pattern fits. Not assumed on the Rust side: the value is
# written into manifest.tsv as a `meta decfast_window` row and read back
# by rust/src/runtime/pjrt.rs, which rejects mismatched artifacts.
DECFAST_WINDOW = 16

# Appended-window buckets of the cache-shaped decoder grid. The largest
# must cover a full draft verify region (DECFAST_WINDOW); the small ones
# keep the per-token greedy step from paying a 16-wide window.
CACHE_WINDOWS = (1, 4, 8, 16)

# The manifest column contract, shared with the Rust parser
# (rust/src/runtime/pjrt.rs::parse_manifest) and pinned by the golden
# round-trip test (rust/tests/manifest_golden.rs ↔
# python/tests/test_train_smoke.py).
MANIFEST_COLUMNS = "kind\ttask\teb\ttlen\tfile"


def manifest_row(kind: str, task: str, eb: int, tlen: int, fname: str) -> str:
    """One artifact row, in MANIFEST_COLUMNS order."""
    return f"{kind}\t{task}\t{eb}\t{tlen}\t{fname}"


def meta_row(task: str, key: str, value: int | str) -> str:
    """One `meta` row: `key`/`value` ride in the eb/tlen columns, the
    file column is `-` (no artifact). The Rust parser only interprets
    values of keys it knows; unknown keys (and non-numeric values) pass
    through untouched — but every byte still lands in the manifest text
    the runtime hashes into its cache-version identity."""
    return f"meta\t{task}\t{key}\t{value}\t-"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_task(
    task: str, out: Path, enc_buckets, dec_buckets, dec_t_buckets, cache_windows
) -> list[str]:
    params = weights_io.load(out / f"weights_{task}.bin")
    cfg = ModelConfig(**weights_io.load_config(out / f"config_{task}.txt"))
    flat = weights_io.flatten(params)
    names = sorted(flat)
    leaf_specs = [jax.ShapeDtypeStruct(flat[n].shape, jnp.float32) for n in names]

    def rebuild(leaves):
        return weights_io.unflatten(dict(zip(names, leaves)))

    # Clamped like the decfast lowering itself (`x[:, -W:, :]` can never
    # read more than t_len columns) — so a small-window model's manifest
    # always passes the Rust loader's decfast_window ≤ t_len check.
    manifest: list[str] = [
        meta_row(task, "decfast_window", min(DECFAST_WINDOW, cfg.t_len))
    ]

    # Digest of every artifact byte written for this task, emitted as a
    # `meta content_digest` row. The Rust runtime hashes the manifest
    # text into its cache-version identity, so regenerated artifacts
    # (new jax/aot.py, same weights and buckets) still flush stale
    # cross-request cache entries.
    digest = hashlib.sha256()

    def write_artifact(fname: str, text: str) -> None:
        (out / fname).write_text(text)
        digest.update(text.encode())
        print(f"  wrote {fname}")

    def enc_fn(src, src_pad, *leaves):
        p = rebuild(leaves)
        return (encode(p, cfg, src, src_pad, use_pallas=True),)

    for b in enc_buckets:
        lowered = jax.jit(enc_fn, keep_unused=True).lower(
            jax.ShapeDtypeStruct((b, cfg.s_len), jnp.int32),
            jax.ShapeDtypeStruct((b, cfg.s_len), jnp.float32),
            *leaf_specs,
        )
        fname = f"enc_{task}_b{b}.hlo.txt"
        write_artifact(fname, to_hlo_text(lowered))
        manifest.append(manifest_row("enc", task, b, 0, fname))

    def dec_fn(tgt, pos, tgt_pad, mem, mem_pad, *leaves):
        p = rebuild(leaves)
        return (
            decode_logprobs(
                p, cfg, tgt, pos, tgt_pad, mem, mem_pad, use_pallas=True
            ),
        )

    # decfast: the B=1 serving fast path. All rows of one speculative /
    # beam decode step share one encoder memory, so the artifact takes
    # mem[1,S,D] and broadcasts on-device (killing the dominant per-call
    # host→device copy), and emits log-probs only for the trailing
    # DECFAST_WINDOW columns (all a decoding step ever reads, since rows
    # are left-padded).
    def decfast_fn(tgt, pos, tgt_pad, mem1, mem_pad1, *leaves):
        p = rebuild(leaves)
        eb = tgt.shape[0]
        mem = jnp.broadcast_to(mem1, (eb, mem1.shape[1], mem1.shape[2]))
        mem_pad = jnp.broadcast_to(mem_pad1, (eb, mem_pad1.shape[1]))
        return (
            decode_logprobs(
                p, cfg, tgt, pos, tgt_pad, mem, mem_pad,
                use_pallas=True, out_window=DECFAST_WINDOW,
            ),
        )

    # deccache: the KV-cached session path. Per-layer K/V of the committed
    # prefix arrive as arguments (device-resident buffers threaded call to
    # call by the Rust session) and only the appended window is computed;
    # the returned caches carry the window's K/V written at
    # cache_len..cache_len+m so the next call extends them in place.
    def deccache_fn(tgt_w, pos, tgt_pad, mem, mem_pad, k_c, v_c, cache_len, *leaves):
        p = rebuild(leaves)
        return decode_logprobs_cached(
            p, cfg, tgt_w, pos, tgt_pad, mem, mem_pad, k_c, v_c, cache_len,
            use_pallas=True,
        )

    t_buckets = sorted({min(t, cfg.t_len) for t in dec_t_buckets})
    for b in dec_buckets:
        for t in t_buckets:
            lowered = jax.jit(dec_fn, keep_unused=True).lower(
                jax.ShapeDtypeStruct((b, t), jnp.int32),
                jax.ShapeDtypeStruct((b, t), jnp.int32),
                jax.ShapeDtypeStruct((b, t), jnp.float32),
                jax.ShapeDtypeStruct((b, cfg.s_len, cfg.d_model), jnp.float32),
                jax.ShapeDtypeStruct((b, cfg.s_len), jnp.float32),
                *leaf_specs,
            )
            fname = f"dec_{task}_b{b}_t{t}.hlo.txt"
            write_artifact(fname, to_hlo_text(lowered))
            manifest.append(manifest_row("dec", task, b, t, fname))

            lowered = jax.jit(decfast_fn, keep_unused=True).lower(
                jax.ShapeDtypeStruct((b, t), jnp.int32),
                jax.ShapeDtypeStruct((b, t), jnp.int32),
                jax.ShapeDtypeStruct((b, t), jnp.float32),
                jax.ShapeDtypeStruct((1, cfg.s_len, cfg.d_model), jnp.float32),
                jax.ShapeDtypeStruct((1, cfg.s_len), jnp.float32),
                *leaf_specs,
            )
            fname = f"decfast_{task}_b{b}_t{t}.hlo.txt"
            write_artifact(fname, to_hlo_text(lowered))
            manifest.append(manifest_row("decfast", task, b, t, fname))

        for w in sorted({min(w, cfg.t_len) for w in cache_windows}):
            lowered = jax.jit(deccache_fn, keep_unused=True).lower(
                jax.ShapeDtypeStruct((b, w), jnp.int32),
                jax.ShapeDtypeStruct((b, w), jnp.int32),
                jax.ShapeDtypeStruct((b, w), jnp.float32),
                jax.ShapeDtypeStruct((b, cfg.s_len, cfg.d_model), jnp.float32),
                jax.ShapeDtypeStruct((b, cfg.s_len), jnp.float32),
                jax.ShapeDtypeStruct((cfg.n_dec, b, cfg.t_len, cfg.d_model), jnp.float32),
                jax.ShapeDtypeStruct((cfg.n_dec, b, cfg.t_len, cfg.d_model), jnp.float32),
                jax.ShapeDtypeStruct((b,), jnp.int32),
                *leaf_specs,
            )
            fname = f"deccache_{task}_b{b}_t{w}.hlo.txt"
            write_artifact(fname, to_hlo_text(lowered))
            manifest.append(manifest_row("deccache", task, b, w, fname))

    manifest.append(meta_row(task, "content_digest", digest.hexdigest()[:16]))
    return manifest


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface. Defaults are pinned against the usage docstring by
    python/tests/test_train_smoke.py (they drifted apart once)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--tasks", default="fwd,retro")
    ap.add_argument("--enc-buckets", default="1,8,32")
    ap.add_argument("--dec-buckets", default="1,4,8,16,32,64")
    ap.add_argument("--dec-t-buckets", default="24,48,96")
    ap.add_argument(
        "--cache-windows", default=",".join(str(w) for w in CACHE_WINDOWS)
    )
    return ap


def main():
    args = build_parser().parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    manifest: list[str] = []
    for task in args.tasks.split(","):
        print(f"[aot] lowering {task}")
        manifest += lower_task(
            task,
            out,
            [int(x) for x in args.enc_buckets.split(",")],
            [int(x) for x in args.dec_buckets.split(",")],
            [int(x) for x in args.dec_t_buckets.split(",")],
            [int(x) for x in args.cache_windows.split(",")],
        )
    (out / "manifest.tsv").write_text("\n".join(manifest) + "\n")
    print(f"[aot] manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
