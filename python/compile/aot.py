"""AOT lowering: JAX model (with the Pallas kernel) → HLO text artifacts.

Emits, per task (`fwd`, `retro`) and bucket:
    artifacts/enc_{task}_b{B}.hlo.txt       (src, src_pad, *weights) → (mem,)
    artifacts/dec_{task}_b{EB}_t{T}.hlo.txt (tgt, pos, tgt_pad, mem, mem_pad,
                                             *weights) → (logp,)
plus `artifacts/manifest.tsv` (`kind\ttask\teb\ttlen\tfile`).

Decoder artifacts come in a (EB, T) grid: EB is the effective batch
(beams × drafts) and T the decoder window. Most of a decode happens at
short prefixes, and without a KV cache the per-call cost is ∝ T — the
window buckets recover that factor (picked per call by the Rust runtime).

Design choices (see DESIGN.md §5):
  * **HLO text**, not serialized protos — jax ≥ 0.5 emits 64-bit
    instruction ids that xla_extension 0.5.1 rejects; the text parser
    reassigns ids (aot_recipe / xla-example gotcha).
  * **Weights as arguments**, not baked constants — constants would bloat
    each text artifact by tens of MB and slow parsing; instead the Rust
    runtime uploads the RXW1 weights once as device-resident PjRtBuffers
    and passes them to every call. Argument order is the lexicographic
    flat-key order, identical on both sides.
  * `use_pallas=True`: the artifacts contain the L1 kernel's lowering
    (interpret mode → plain HLO, runnable on CPU PJRT).

Usage: python -m compile.aot [--out DIR] [--tasks fwd,retro]
       [--enc-buckets 1,8,32] [--dec-buckets 1,2,4,8,16,32,64]
"""

from __future__ import annotations

import argparse
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import weights_io
from .model import ModelConfig, decode_logprobs, encode

# Trailing-columns window of the decfast artifacts. Must be ≥ the largest
# draft length + 1 (verify region) — the Rust runtime only routes calls
# whose read pattern fits (rust/src/runtime/pjrt.rs).
DECFAST_WINDOW = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_task(task: str, out: Path, enc_buckets, dec_buckets, dec_t_buckets) -> list[str]:
    params = weights_io.load(out / f"weights_{task}.bin")
    cfg = ModelConfig(**weights_io.load_config(out / f"config_{task}.txt"))
    flat = weights_io.flatten(params)
    names = sorted(flat)
    leaf_specs = [jax.ShapeDtypeStruct(flat[n].shape, jnp.float32) for n in names]

    def rebuild(leaves):
        return weights_io.unflatten(dict(zip(names, leaves)))

    manifest: list[str] = []

    def enc_fn(src, src_pad, *leaves):
        p = rebuild(leaves)
        return (encode(p, cfg, src, src_pad, use_pallas=True),)

    for b in enc_buckets:
        lowered = jax.jit(enc_fn, keep_unused=True).lower(
            jax.ShapeDtypeStruct((b, cfg.s_len), jnp.int32),
            jax.ShapeDtypeStruct((b, cfg.s_len), jnp.float32),
            *leaf_specs,
        )
        fname = f"enc_{task}_b{b}.hlo.txt"
        (out / fname).write_text(to_hlo_text(lowered))
        manifest.append(f"enc\t{task}\t{b}\t0\t{fname}")
        print(f"  wrote {fname}")

    def dec_fn(tgt, pos, tgt_pad, mem, mem_pad, *leaves):
        p = rebuild(leaves)
        return (
            decode_logprobs(
                p, cfg, tgt, pos, tgt_pad, mem, mem_pad, use_pallas=True
            ),
        )

    # decfast: the B=1 serving fast path. All rows of one speculative /
    # beam decode step share one encoder memory, so the artifact takes
    # mem[1,S,D] and broadcasts on-device (killing the dominant per-call
    # host→device copy), and emits log-probs only for the trailing
    # DECFAST_WINDOW columns (all a decoding step ever reads, since rows
    # are left-padded).
    def decfast_fn(tgt, pos, tgt_pad, mem1, mem_pad1, *leaves):
        p = rebuild(leaves)
        eb = tgt.shape[0]
        mem = jnp.broadcast_to(mem1, (eb, mem1.shape[1], mem1.shape[2]))
        mem_pad = jnp.broadcast_to(mem_pad1, (eb, mem_pad1.shape[1]))
        return (
            decode_logprobs(
                p, cfg, tgt, pos, tgt_pad, mem, mem_pad,
                use_pallas=True, out_window=DECFAST_WINDOW,
            ),
        )

    t_buckets = sorted({min(t, cfg.t_len) for t in dec_t_buckets})
    for b in dec_buckets:
        for t in t_buckets:
            lowered = jax.jit(dec_fn, keep_unused=True).lower(
                jax.ShapeDtypeStruct((b, t), jnp.int32),
                jax.ShapeDtypeStruct((b, t), jnp.int32),
                jax.ShapeDtypeStruct((b, t), jnp.float32),
                jax.ShapeDtypeStruct((b, cfg.s_len, cfg.d_model), jnp.float32),
                jax.ShapeDtypeStruct((b, cfg.s_len), jnp.float32),
                *leaf_specs,
            )
            fname = f"dec_{task}_b{b}_t{t}.hlo.txt"
            (out / fname).write_text(to_hlo_text(lowered))
            manifest.append(f"dec\t{task}\t{b}\t{t}\t{fname}")
            print(f"  wrote {fname}")

            lowered = jax.jit(decfast_fn, keep_unused=True).lower(
                jax.ShapeDtypeStruct((b, t), jnp.int32),
                jax.ShapeDtypeStruct((b, t), jnp.int32),
                jax.ShapeDtypeStruct((b, t), jnp.float32),
                jax.ShapeDtypeStruct((1, cfg.s_len, cfg.d_model), jnp.float32),
                jax.ShapeDtypeStruct((1, cfg.s_len), jnp.float32),
                *leaf_specs,
            )
            fname = f"decfast_{task}_b{b}_t{t}.hlo.txt"
            (out / fname).write_text(to_hlo_text(lowered))
            manifest.append(f"decfast\t{task}\t{b}\t{t}\t{fname}")
            print(f"  wrote {fname}")

    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--tasks", default="fwd,retro")
    ap.add_argument("--enc-buckets", default="1,8,32")
    ap.add_argument("--dec-buckets", default="1,4,8,16,32,64")
    ap.add_argument("--dec-t-buckets", default="24,48,96")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    manifest: list[str] = []
    for task in args.tasks.split(","):
        print(f"[aot] lowering {task}")
        manifest += lower_task(
            task,
            out,
            [int(x) for x in args.enc_buckets.split(",")],
            [int(x) for x in args.dec_buckets.split(",")],
            [int(x) for x in args.dec_t_buckets.split(",")],
        )
    (out / "manifest.tsv").write_text("\n".join(manifest) + "\n")
    print(f"[aot] manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
