"""RXW1 flat weights format, shared with the Rust reader.

Layout (all integers little-endian):
    magic   4 bytes  b"RXW1"
    count   u32      number of tensors
    per tensor:
        name_len u32, name bytes (utf-8, dotted path e.g. "dec0.ffn.w1")
        ndim     u32, dims u32 × ndim
        dtype    u8   (0 = f32)
        data     f32 LE, prod(dims) elements

Keys are sorted lexicographically so the file is deterministic. The Rust
side is `rust/src/model/weights.rs`.
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"RXW1"


def flatten(params: dict, prefix: str = "") -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    for k, v in params.items():
        name = f"{prefix}.{k}" if prefix else k
        if isinstance(v, dict):
            out.update(flatten(v, name))
        else:
            out[name] = np.asarray(v, dtype=np.float32)
    return out


def unflatten(flat: dict[str, np.ndarray]) -> dict:
    root: dict = {}
    for name, arr in flat.items():
        node = root
        parts = name.split(".")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return root


def save(path: str | Path, params: dict) -> None:
    flat = flatten(params)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(flat)))
        for name in sorted(flat):
            arr = np.ascontiguousarray(flat[name], dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(struct.pack("<B", 0))
            f.write(arr.tobytes())


def load(path: str | Path) -> dict:
    data = Path(path).read_bytes()
    if data[:4] != MAGIC:
        raise ValueError(f"{path}: not an RXW1 weights file")
    off = 4
    (count,) = struct.unpack_from("<I", data, off)
    off += 4
    flat: dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<I", data, off)
        off += 4
        name = data[off : off + nlen].decode("utf-8")
        off += nlen
        (ndim,) = struct.unpack_from("<I", data, off)
        off += 4
        dims = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        (dtype,) = struct.unpack_from("<B", data, off)
        off += 1
        if dtype != 0:
            raise ValueError(f"{name}: unsupported dtype {dtype}")
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(data, dtype="<f4", count=n, offset=off).reshape(dims)
        off += 4 * n
        flat[name] = arr
    return unflatten(flat)


def save_config(path: str | Path, kv: dict[str, int]) -> None:
    Path(path).write_text("".join(f"{k}={v}\n" for k, v in sorted(kv.items())))


def load_config(path: str | Path) -> dict[str, int]:
    out = {}
    for line in Path(path).read_text().splitlines():
        if line:
            k, v = line.split("=")
            out[k] = int(v)
    return out
