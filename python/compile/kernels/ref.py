"""Pure-jnp reference (oracle) for the Pallas attention kernel.

This is the correctness ground truth: `attention.py` (the L1 Pallas kernel)
must match this function under `np.testing.assert_allclose` across the
shape/dtype sweep in `tests/test_kernel.py`. It is also the implementation
used during *training* (autodiff-friendly); the Pallas kernel is swapped in
for the AOT inference artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q, k, v, mask):
    """Multi-head scaled-dot-product attention, additive mask.

    Args:
      q:    [B, H, Tq, Dh]
      k:    [B, H, Tk, Dh]
      v:    [B, H, Tk, Dh]
      mask: additive mask broadcastable to [B, H, Tq, Tk]
            (0 where attention is allowed, large negative where not)

    Returns:
      [B, H, Tq, Dh]
    """
    dh = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(dh).astype(q.dtype)
    scores = scores + mask.astype(scores.dtype)
    # Max-subtracted softmax in f32 for stability regardless of input dtype.
    scores = scores.astype(jnp.float32)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(q.dtype), v)
