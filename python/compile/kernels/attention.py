"""L1: fused multi-head attention as a Pallas kernel.

One grid program per **head** computes attention for the whole batch of
row instances at once: QK^T, additive mask, max-subtracted softmax, and
the value contraction, with every tile resident in VMEM.

Grid choice (§Perf in EXPERIMENTS.md): the first version used one program
per (batch·head) — the classic GPU threadblock mapping. Under interpret
mode (and in XLA CPU generally) grid programs serialize, so per-call cost
scaled with effective batch and wrecked speculative decoding's
parallel-verification premise. One program per head with the batch kept
*inside* the program turns the inner work into large batched `dot_general`s
(MXU-shaped on TPU, single GEMM calls on CPU) — EB=32 calls went from
~330 ms to ~tens of ms. VMEM per program at the largest bucket
(EB=64, T=S=96, Dh=32):
    Q,K,V tiles   3 · 64 · 96 · 32 · 4 B ≈ 2.3 MiB
    score tile        64 · 96 · 96 · 4 B ≈ 2.3 MiB
≈ 5 MiB, comfortably under the ~16 MiB VMEM budget, so the per-head
BlockSpec schedule remains TPU-valid.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that runs (and AOT-
exports) on any backend. Numerics are validated against `ref.mha_ref` by
`tests/test_kernel.py` (hypothesis sweep over shapes/dtypes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mha_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref):
    """One head's program: batched full-tile fused attention.

    Block shapes: q/k/v [1, B, T, Dh] (leading head-block dim), mask
    [B, Tq, Tk] (shared across heads).
    """
    q = q_ref[0]  # [B, Tq, Dh]
    k = k_ref[0]  # [B, Tk, Dh]
    v = v_ref[0]  # [B, Tk, Dh]
    m = mask_ref[...]  # [B, Tq, Tk]

    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    # Batched MXU-shaped contraction, f32 accumulation:
    # scores[b, i, j] = q[b, i, :] · k[b, j, :]
    scores = jax.lax.dot_general(
        q,
        k,
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * scale
    scores = scores + m.astype(jnp.float32)
    # Numerically stable softmax on the VPU.
    mx = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - mx)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    out = jax.lax.dot_general(
        probs.astype(v.dtype),
        v,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.named_call, name="pallas_mha")
def mha(q, k, v, mask):
    """Fused multi-head attention (Pallas, interpret mode).

    Args/returns exactly as `ref.mha_ref`: q [B,H,Tq,Dh], k/v [B,H,Tk,Dh],
    additive mask broadcastable to [B,H,Tq,Tk] → [B,H,Tq,Dh].

    All masks in this model are head-independent, so the kernel carries a
    [B,Tq,Tk] mask tile shared by every head program.
    """
    b, h, tq, dh = q.shape
    tk = k.shape[2]
    # Head-leading layout so the grid maps one program per head.
    qh = q.transpose(1, 0, 2, 3)  # [H, B, Tq, Dh]
    kh = k.transpose(1, 0, 2, 3)
    vh = v.transpose(1, 0, 2, 3)
    mask4 = jnp.broadcast_to(mask.astype(jnp.float32), (b, h, tq, tk))
    mask3 = mask4[:, 0, :, :]  # head-independent by construction

    out = pl.pallas_call(
        _mha_kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, b, tq, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, b, tk, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, b, tk, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((b, tq, tk), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, tq, dh), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, b, tq, dh), q.dtype),
        interpret=True,
    )(qh, kh, vh, mask3)
    return out.transpose(1, 0, 2, 3)
