"""RXW1 weights format: roundtrip and layout pins (the Rust reader parses
this format byte for byte — rust/src/model/weights.rs)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np

from compile import weights_io


def test_flatten_unflatten_roundtrip():
    params = {
        "a": {"b": np.arange(6, dtype=np.float32).reshape(2, 3), "c": np.zeros(4, np.float32)},
        "d": np.ones((1,), np.float32),
    }
    flat = weights_io.flatten(params)
    assert set(flat) == {"a.b", "a.c", "d"}
    back = weights_io.unflatten(flat)
    np.testing.assert_array_equal(back["a"]["b"], params["a"]["b"])


def test_save_load_roundtrip(tmp_path):
    params = {
        "enc0": {"attn": {"wq": np.random.randn(8, 8).astype(np.float32)}},
        "tok_emb": np.random.randn(10, 4).astype(np.float32),
    }
    p = tmp_path / "w.bin"
    weights_io.save(p, params)
    back = weights_io.load(p)
    np.testing.assert_array_equal(back["enc0"]["attn"]["wq"], params["enc0"]["attn"]["wq"])
    np.testing.assert_array_equal(back["tok_emb"], params["tok_emb"])


def test_file_layout_is_pinned(tmp_path):
    # Byte-level pin: magic, count, sorted keys.
    p = tmp_path / "w.bin"
    weights_io.save(p, {"b": np.zeros(1, np.float32), "a": np.ones(2, np.float32)})
    raw = p.read_bytes()
    assert raw[:4] == b"RXW1"
    assert int.from_bytes(raw[4:8], "little") == 2
    # first tensor is "a" (sorted), name_len 1
    assert int.from_bytes(raw[8:12], "little") == 1
    assert raw[12:13] == b"a"


def test_config_roundtrip(tmp_path):
    p = tmp_path / "cfg.txt"
    weights_io.save_config(p, {"d_model": 128, "vocab": 31})
    back = weights_io.load_config(p)
    assert back == {"d_model": 128, "vocab": 31}
