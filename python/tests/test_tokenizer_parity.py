"""Cross-language tokenizer parity: the Python tokenizer must reproduce
the Rust tokenizer's output exactly (golden file written by `gen-data`),
plus local roundtrip/equivalence checks.
"""

import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.data import SPECIALS, Vocab, tokenize

DATA = Path(__file__).resolve().parents[2] / "data"


@pytest.mark.skipif(not (DATA / "golden_tokens.tsv").exists(), reason="run gen-data first")
def test_golden_tokenization_parity():
    lines = (DATA / "golden_tokens.tsv").read_text().splitlines()
    assert len(lines) >= 4
    for line in lines:
        smiles, expected = line.split("\t")
        assert tokenize(smiles) == expected.split(" "), smiles


def test_paper_figure2_example():
    toks = tokenize("c1c[nH]c2ccc(C(C)=O)cc12")
    assert toks == [
        "c", "1", "c", "[nH]", "c", "2", "c", "c", "c", "(", "C", "(", "C",
        ")", "=", "O", ")", "c", "c", "1", "2",
    ]


def test_roundtrip():
    for s in ["BrCCCl", "C%12CC%12", "[Na+].[OH-]", "CC(=O)OC(C)(C)C"]:
        assert "".join(tokenize(s)) == s


def test_rejects_garbage():
    with pytest.raises(ValueError):
        tokenize("C C")
    with pytest.raises(ValueError):
        tokenize("C[nH")


@pytest.mark.skipif(not (DATA / "vocab.txt").exists(), reason="run gen-data first")
def test_vocab_loads_and_encodes():
    v = Vocab.load(DATA / "vocab.txt")
    assert v.id_to_tok[:4] == SPECIALS
    ids = v.encode("c1ccccc1")
    assert all(i >= 4 for i in ids)
    assert v.decode(ids) == "c1ccccc1"


@pytest.mark.skipif(not (DATA / "fwd_test.tsv").exists(), reason="run gen-data first")
def test_whole_test_split_tokenizes_and_roundtrips():
    from compile.data import read_split

    v = Vocab.load(DATA / "vocab.txt")
    for ex in read_split(DATA / "fwd_test.tsv")[:200]:
        for s in (ex.src, ex.tgt):
            ids = v.encode(s)
            assert v.decode(ids) == s
