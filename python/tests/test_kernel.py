"""L1 correctness: the Pallas attention kernel vs the pure-jnp oracle.

This is the CORE kernel correctness signal: hypothesis sweeps shapes and
dtypes and asserts allclose between `kernels.attention.mha` (interpret-mode
Pallas) and `kernels.ref.mha_ref`.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import mha
from compile.kernels.ref import mha_ref

NEG_INF = -1e9


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def make_inputs(seed, b, h, tq, tk, dh, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = rand(ks[0], (b, h, tq, dh), dtype)
    k = rand(ks[1], (b, h, tk, dh), dtype)
    v = rand(ks[2], (b, h, tk, dh), dtype)
    return q, k, v


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    tq=st.integers(1, 24),
    tk=st.integers(1, 24),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_unmasked(b, h, tq, tk, dh, seed):
    q, k, v = make_inputs(seed, b, h, tq, tk, dh, jnp.float32)
    mask = jnp.zeros((b, h, tq, tk), jnp.float32)
    out = mha(q, k, v, mask)
    ref = mha_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol(jnp.float32))


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 2),
    tq=st.integers(2, 16),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_causal_mask(b, tq, seed):
    h, dh = 2, 16
    q, k, v = make_inputs(seed, b, h, tq, tq, dh, jnp.float32)
    causal = jnp.tril(jnp.ones((tq, tq), jnp.float32))
    mask = (1.0 - causal)[None, None] * NEG_INF
    out = mha(q, k, v, jnp.broadcast_to(mask, (b, h, tq, tq)))
    ref = mha_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol(jnp.float32))


@settings(max_examples=15, deadline=None)
@given(
    tk=st.integers(2, 20),
    n_pad=st.integers(1, 10),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_padding_mask(tk, n_pad, seed):
    n_pad = min(n_pad, tk - 1)
    b, h, tq, dh = 1, 2, 5, 16
    q, k, v = make_inputs(seed, b, h, tq, tk, dh, jnp.float32)
    pad = jnp.concatenate([jnp.ones(tk - n_pad), jnp.zeros(n_pad)])
    mask = (1.0 - pad)[None, None, None, :] * NEG_INF
    out = mha(q, k, v, jnp.broadcast_to(mask, (b, h, tq, tk)))
    ref = mha_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    q, k, v = make_inputs(7, 2, 4, 12, 12, 32, dtype)
    mask = jnp.zeros((2, 4, 12, 12), jnp.float32)
    out = mha(q, k, v, mask)
    ref = mha_ref(q, k, v, mask)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_kernel_model_shapes():
    # The exact shapes the model uses: S=T=96, H=4, Dh=32.
    q, k, v = make_inputs(3, 2, 4, 96, 96, 32, jnp.float32)
    mask = jnp.zeros((2, 4, 96, 96), jnp.float32)
    out = mha(q, k, v, mask)
    ref = mha_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol(jnp.float32))


def test_masked_rows_are_uniform_attention():
    # A fully-masked query row degenerates to uniform attention (softmax of
    # equal values) in both implementations — no NaNs.
    b, h, tq, tk, dh = 1, 1, 3, 4, 8
    q, k, v = make_inputs(11, b, h, tq, tk, dh, jnp.float32)
    mask = jnp.full((b, h, tq, tk), NEG_INF)
    out = np.asarray(mha(q, k, v, mask))
    ref = np.asarray(mha_ref(q, k, v, mask))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_kernel_is_jittable_and_stable_under_jit():
    q, k, v = make_inputs(5, 1, 2, 10, 10, 16, jnp.float32)
    mask = jnp.zeros((1, 2, 10, 10), jnp.float32)
    eager = mha(q, k, v, mask)
    jitted = jax.jit(mha)(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted), rtol=1e-6, atol=1e-6)
