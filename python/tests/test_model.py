"""L2 model invariants: shapes, causality, left-pad/position-shift
equivalence (the property the paper's `padLeft` + shifted positional
encodings rely on), and pallas/ref interchangeability at the model level.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.data import BOS_ID, EOS_ID, PAD_ID
from compile.model import (
    ModelConfig,
    decode_logprobs,
    decode_logprobs_cached,
    encode,
    init_params,
)

CFG = ModelConfig(vocab=31, d_model=32, n_heads=2, d_ff=64, n_enc=2, n_dec=2, s_len=16, t_len=16)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def wrap_src(tokens):
    s = [BOS_ID] + tokens + [EOS_ID]
    src = np.zeros((1, CFG.s_len), np.int32)
    pad = np.zeros((1, CFG.s_len), np.float32)
    src[0, : len(s)] = s
    pad[0, : len(s)] = 1.0
    return jnp.asarray(src), jnp.asarray(pad)


def right_pad_row(tokens, t_len):
    tgt = np.zeros((1, t_len), np.int32)
    pos = np.zeros((1, t_len), np.int32)
    pad = np.zeros((1, t_len), np.float32)
    tgt[0, : len(tokens)] = tokens
    pos[0, : len(tokens)] = np.arange(len(tokens))
    pad[0, : len(tokens)] = 1.0
    return jnp.asarray(tgt), jnp.asarray(pos), jnp.asarray(pad)


def left_pad_row(tokens, t_len):
    n = len(tokens)
    off = t_len - n
    tgt = np.zeros((1, t_len), np.int32)
    pos = np.zeros((1, t_len), np.int32)
    pad = np.zeros((1, t_len), np.float32)
    tgt[0, off:] = tokens
    pos[0, off:] = np.arange(n)
    pad[0, off:] = 1.0
    return jnp.asarray(tgt), jnp.asarray(pos), jnp.asarray(pad)


def test_encode_shape_and_finite(params):
    src, pad = wrap_src([5, 6, 7])
    mem = encode(params, CFG, src, pad)
    assert mem.shape == (1, CFG.s_len, CFG.d_model)
    assert np.isfinite(np.asarray(mem)).all()


def test_decode_logprobs_normalized(params):
    src, spad = wrap_src([5, 6, 7])
    mem = encode(params, CFG, src, spad)
    tgt, pos, tpad = right_pad_row([BOS_ID, 5, 6], CFG.t_len)
    lp = decode_logprobs(params, CFG, tgt, pos, tpad, mem, spad)
    assert lp.shape == (1, CFG.t_len, CFG.vocab)
    sums = np.exp(np.asarray(lp)).sum(-1)
    np.testing.assert_allclose(sums[0, :3], 1.0, rtol=1e-4)


def test_causality(params):
    # Changing tokens after position j must not change log-probs at <= j.
    src, spad = wrap_src([5, 6, 7, 8])
    mem = encode(params, CFG, src, spad)
    a = [BOS_ID, 5, 6, 7, 8]
    b = [BOS_ID, 5, 6, 9, 10]  # diverges at position 3
    ta, pa, da = right_pad_row(a, CFG.t_len)
    tb, pb, db = right_pad_row(b, CFG.t_len)
    la = np.asarray(decode_logprobs(params, CFG, ta, pa, da, mem, spad))
    lb = np.asarray(decode_logprobs(params, CFG, tb, pb, db, mem, spad))
    np.testing.assert_allclose(la[0, :3], lb[0, :3], rtol=1e-4, atol=1e-5)
    assert np.abs(la[0, 3] - lb[0, 3]).max() > 1e-4  # content actually matters


def test_left_pad_with_shifted_positions_equals_right_pad(params):
    # The paper's Appendix B property: left-padding with offset positional
    # encodings yields the same distributions on the real positions.
    src, spad = wrap_src([5, 6, 7, 8, 9])
    mem = encode(params, CFG, src, spad)
    tokens = [BOS_ID, 7, 8, 9]
    tr, pr, dr = right_pad_row(tokens, CFG.t_len)
    tl, pl, dl = left_pad_row(tokens, CFG.t_len)
    lr = np.asarray(decode_logprobs(params, CFG, tr, pr, dr, mem, spad))
    ll = np.asarray(decode_logprobs(params, CFG, tl, pl, dl, mem, spad))
    off = CFG.t_len - len(tokens)
    np.testing.assert_allclose(lr[0, : len(tokens)], ll[0, off:], rtol=1e-4, atol=1e-5)


def test_batch_row_independence(params):
    # A row's outputs must not depend on other rows in the batch.
    src, spad = wrap_src([5, 6, 7])
    mem = encode(params, CFG, src, spad)
    t1, p1, d1 = right_pad_row([BOS_ID, 5, 6], CFG.t_len)
    t2, p2, d2 = right_pad_row([BOS_ID, 9, 10, 11], CFG.t_len)
    solo = np.asarray(decode_logprobs(params, CFG, t1, p1, d1, mem, spad))
    mem2 = jnp.concatenate([mem, mem])
    spad2 = jnp.concatenate([spad, spad])
    both = np.asarray(
        decode_logprobs(
            params,
            CFG,
            jnp.concatenate([t1, t2]),
            jnp.concatenate([p1, p2]),
            jnp.concatenate([d1, d2]),
            mem2,
            spad2,
        )
    )
    np.testing.assert_allclose(solo[0, :3], both[0, :3], rtol=1e-4, atol=1e-5)


def test_src_pad_does_not_leak(params):
    # Extending the source with extra PAD columns must not change encoder
    # output on real positions (as seen through the decoder).
    tokens = [5, 6, 7]
    s = [BOS_ID] + tokens + [EOS_ID]
    src_a = np.zeros((1, CFG.s_len), np.int32)
    pad_a = np.zeros((1, CFG.s_len), np.float32)
    src_a[0, : len(s)] = s
    pad_a[0, : len(s)] = 1.0
    src_b = src_a.copy()
    src_b[0, len(s) :] = 9  # garbage behind the pad mask
    tgt, pos, tpad = right_pad_row([BOS_ID, 5], CFG.t_len)
    la = decode_logprobs(
        params, CFG, tgt, pos, tpad, encode(params, CFG, jnp.asarray(src_a), jnp.asarray(pad_a)), jnp.asarray(pad_a)
    )
    lb = decode_logprobs(
        params, CFG, tgt, pos, tpad, encode(params, CFG, jnp.asarray(src_b), jnp.asarray(pad_a)), jnp.asarray(pad_a)
    )
    np.testing.assert_allclose(np.asarray(la)[0, :2], np.asarray(lb)[0, :2], rtol=1e-4, atol=1e-5)


def window_inputs(chunk, start, w):
    """Right-padded deccache window inputs for `chunk` at prefix `start`."""
    tgt = np.zeros((1, w), np.int32)
    pos = np.zeros((1, w), np.int32)
    pad = np.zeros((1, w), np.float32)
    tgt[0, : len(chunk)] = chunk
    pos[0, : len(chunk)] = start + np.arange(len(chunk))
    pad[0, : len(chunk)] = 1.0
    return jnp.asarray(tgt), jnp.asarray(pos), jnp.asarray(pad)


def empty_cache():
    shape = (CFG.n_dec, 1, CFG.t_len, CFG.d_model)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def test_cached_decoder_matches_full(params):
    # Incremental windows through decode_logprobs_cached must reproduce
    # the full-prefix decoder position for position — the contract the
    # Rust deccache sessions rely on.
    src, spad = wrap_src([5, 6, 7, 8])
    mem = encode(params, CFG, src, spad)
    tokens = [BOS_ID, 5, 6, 7, 8, 9, 10, 11, 12]
    tf, pf, df = right_pad_row(tokens, CFG.t_len)
    full = np.asarray(decode_logprobs(params, CFG, tf, pf, df, mem, spad))

    k, v = empty_cache()
    got = np.zeros((len(tokens), CFG.vocab), np.float32)
    start = 0
    w = 4  # fixed window bucket; real lengths vary per call
    for wlen in [1, 3, 2, 3]:
        tgt, pos, pad = window_inputs(tokens[start : start + wlen], start, w)
        lp, k, v = decode_logprobs_cached(
            params, CFG, tgt, pos, pad, mem, spad, k, v,
            jnp.asarray([start], jnp.int32),
        )
        got[start : start + wlen] = np.asarray(lp)[0, :wlen]
        start += wlen
    assert start == len(tokens)
    np.testing.assert_allclose(got, full[0, : len(tokens)], rtol=1e-4, atol=1e-4)


def test_cached_decoder_rewind_overwrites_stale_slots(params):
    # A rewind is just a smaller cache_len: stale K/V beyond it must be
    # masked/overwritten, so re-extending with different tokens matches a
    # fresh full decode of the new sequence (the stale-cache bug class
    # this artifact shape must not reintroduce).
    src, spad = wrap_src([5, 6, 7])
    mem = encode(params, CFG, src, spad)
    committed = [BOS_ID, 5, 6, 7, 8, 9, 10]
    k, v = empty_cache()
    tgt, pos, pad = window_inputs(committed, 0, 8)
    _, k, v = decode_logprobs_cached(
        params, CFG, tgt, pos, pad, mem, spad, k, v, jnp.asarray([0], jnp.int32)
    )
    # Rewind to 3 committed tokens, extend a diverging window.
    keep, fresh = committed[:3], [11, 12, 13]
    tgt, pos, pad = window_inputs(fresh, len(keep), 4)
    lp, k, v = decode_logprobs_cached(
        params, CFG, tgt, pos, pad, mem, spad, k, v,
        jnp.asarray([len(keep)], jnp.int32),
    )
    tf, pf, df = right_pad_row(keep + fresh, CFG.t_len)
    full = np.asarray(decode_logprobs(params, CFG, tf, pf, df, mem, spad))
    np.testing.assert_allclose(
        np.asarray(lp)[0, : len(fresh)],
        full[0, len(keep) : len(keep) + len(fresh)],
        rtol=1e-4,
        atol=1e-4,
    )


def test_cached_decoder_pallas_matches_ref(params):
    src, spad = wrap_src([5, 6, 7])
    mem = encode(params, CFG, src, spad)
    k, v = empty_cache()
    tgt, pos, pad = window_inputs([BOS_ID, 5, 6], 0, 4)
    args = (params, CFG, tgt, pos, pad, mem, spad, k, v, jnp.asarray([0], jnp.int32))
    lr, kr, vr = decode_logprobs_cached(*args, use_pallas=False)
    lp, kp, vp = decode_logprobs_cached(*args, use_pallas=True)
    np.testing.assert_allclose(np.asarray(lr)[0, :3], np.asarray(lp)[0, :3], rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(kr), np.asarray(kp), rtol=2e-4, atol=2e-5)


def test_pallas_and_ref_model_level_equivalence(params):
    src, spad = wrap_src([5, 6, 7, 8])
    mem_ref = encode(params, CFG, src, spad, use_pallas=False)
    mem_pl = encode(params, CFG, src, spad, use_pallas=True)
    np.testing.assert_allclose(np.asarray(mem_ref), np.asarray(mem_pl), rtol=2e-4, atol=2e-5)
    tgt, pos, tpad = right_pad_row([BOS_ID, 5, 6], CFG.t_len)
    lr = decode_logprobs(params, CFG, tgt, pos, tpad, mem_ref, spad, use_pallas=False)
    lp = decode_logprobs(params, CFG, tgt, pos, tpad, mem_pl, spad, use_pallas=True)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lp), rtol=2e-4, atol=2e-4)
