"""Training smoke: a few steps on a tiny model must reduce the loss and
the batch assembler must honour the layout contract. Also pins the AOT
CLI surface: argparse defaults vs the usage docstring (they drifted
apart once) and the manifest column contract shared with the Rust
parser (rust/tests/data/manifest_golden.tsv)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.data import BOS_ID, EOS_ID, Example, Vocab, encode_batch
from compile.model import ModelConfig, init_params
from compile.train import loss_fn, lr_schedule, train_step

VOCAB_TOKENS = ["<pad>", "<bos>", "<eos>", "<unk>", "(", ")", "1", "=", "Br", "C", "N", "O", "c"]


@pytest.fixture(scope="module")
def vocab():
    return Vocab(VOCAB_TOKENS)


def examples():
    return [
        Example("CCO.CC(=O)O", "CC(=O)OCC", "esterification"),
        Example("BrCC.OC", "COCC", "ether"),
        Example("c1ccccc1Br.OC", "c1ccccc1OC", "ether"),
    ] * 4


def test_encode_batch_layout(vocab):
    cfg = ModelConfig(vocab=len(vocab), s_len=32, t_len=32)
    b = encode_batch(vocab, examples()[:2], cfg.s_len, cfg.t_len)
    assert b["src"].shape == (2, 32)
    # BOS at position 0, EOS terminates the real span.
    assert b["src"][0, 0] == BOS_ID
    n_real = int(b["src_pad"][0].sum())
    assert b["src"][0, n_real - 1] == EOS_ID
    # decoder input starts with BOS; labels end with EOS under the mask.
    assert b["tgt_in"][0, 0] == BOS_ID
    n_lbl = int(b["loss_mask"][0].sum())
    assert b["labels"][0, n_lbl - 1] == EOS_ID
    # teacher forcing alignment: labels are tgt_in shifted left by one.
    np.testing.assert_array_equal(b["tgt_in"][0, 1:n_lbl], b["labels"][0, : n_lbl - 1])


def test_loss_decreases_over_steps(vocab):
    cfg = ModelConfig(
        vocab=len(vocab), d_model=32, n_heads=2, d_ff=64, n_enc=1, n_dec=1, s_len=24, t_len=24
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    batch = encode_batch(vocab, examples(), cfg.s_len, cfg.t_len)
    first = None
    loss = None
    for step in range(1, 31):
        params, m, v, loss, _ = train_step(params, m, v, jnp.asarray(float(step)), cfg, batch)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.8, f"loss did not decrease: {first} -> {float(loss)}"


def test_lr_schedule_warmup_then_decay():
    lrs = [float(lr_schedule(jnp.asarray(float(s)), 128)) for s in [1, 200, 400, 1600]]
    assert lrs[0] < lrs[1] < lrs[2]  # warmup
    assert lrs[3] < lrs[2]  # decay
    assert float(lr_schedule(jnp.asarray(0.0), 128)) > 0  # step clamp


def test_aot_usage_docstring_matches_argparse_defaults():
    # The usage block once advertised `--dec-buckets 1,2,4,8,16,32,64`
    # while the argparse default was `1,4,8,16,32,64`. Pin every
    # bucket-flag default to the docstring so they cannot drift again.
    from compile import aot

    defaults = {
        a.option_strings[0]: a.default
        for a in aot.build_parser()._actions
        if a.option_strings
    }
    for flag in ("--enc-buckets", "--dec-buckets", "--dec-t-buckets", "--cache-windows"):
        assert flag in defaults, f"missing {flag}"
        expect = f"[{flag} {defaults[flag]}]"
        assert expect in aot.__doc__, (
            f"usage docstring out of sync with argparse: expected {expect!r}"
        )


def test_manifest_rows_match_rust_golden_file():
    # The manifest column contract (`kind\ttask\teb\ttlen\tfile`, plus
    # `meta` key/value rows) is shared with rust/src/runtime/pjrt.rs.
    # Regenerate the checked-in golden sample from the Python helpers and
    # require an exact match — the Rust side parses the same file in
    # rust/tests/manifest_golden.rs.
    from compile import aot

    golden = (
        Path(__file__).resolve().parents[2]
        / "rust"
        / "tests"
        / "data"
        / "manifest_golden.tsv"
    ).read_text()
    digests = {"fwd": "9c1d3adf00aa43b2", "retro": "5e2b7c90d1f4a688"}
    lines = []
    for task, ebs in (("fwd", (1, 8)), ("retro", (1,))):
        lines.append(aot.meta_row(task, "decfast_window", aot.DECFAST_WINDOW))
        for eb in ebs:
            lines.append(aot.manifest_row("enc", task, eb, 0, f"enc_{task}_b{eb}.hlo.txt"))
        if task == "fwd":
            for eb, t in ((1, 24), (8, 96)):
                lines.append(
                    aot.manifest_row("dec", task, eb, t, f"dec_{task}_b{eb}_t{t}.hlo.txt")
                )
                lines.append(
                    aot.manifest_row(
                        "decfast", task, eb, t, f"decfast_{task}_b{eb}_t{t}.hlo.txt"
                    )
                )
            deccache = ((1, 1), (1, 16), (8, 4), (8, 16))
        else:
            for eb, t in ((1, 48),):
                lines.append(
                    aot.manifest_row("dec", task, eb, t, f"dec_{task}_b{eb}_t{t}.hlo.txt")
                )
                lines.append(
                    aot.manifest_row(
                        "decfast", task, eb, t, f"decfast_{task}_b{eb}_t{t}.hlo.txt"
                    )
                )
            deccache = ((4, 8),)
        for eb, w in deccache:
            lines.append(
                aot.manifest_row(
                    "deccache", task, eb, w, f"deccache_{task}_b{eb}_t{w}.hlo.txt"
                )
            )
        lines.append(aot.meta_row(task, "content_digest", digests[task]))
    regenerated = "\n".join(lines) + "\n"
    assert sorted(regenerated.splitlines()) == sorted(golden.splitlines()), (
        "python manifest helpers no longer reproduce the golden manifest"
    )
    assert aot.MANIFEST_COLUMNS == "kind\ttask\teb\ttlen\tfile"


def test_loss_fn_masks_padding(vocab):
    cfg = ModelConfig(
        vocab=len(vocab), d_model=32, n_heads=2, d_ff=64, n_enc=1, n_dec=1, s_len=24, t_len=24
    )
    params = init_params(jax.random.PRNGKey(1), cfg)
    b1 = encode_batch(vocab, examples()[:1], cfg.s_len, cfg.t_len)
    loss1, _ = loss_fn(params, cfg, b1)
    # Corrupt labels ONLY behind the mask: loss must not change.
    b2 = {k: v.copy() for k, v in b1.items()}
    n_lbl = int(b2["loss_mask"][0].sum())
    b2["labels"][0, n_lbl:] = 9
    loss2, _ = loss_fn(params, cfg, b2)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)
