//! Trace-layer correctness properties (PR 7 acceptance criteria):
//!
//! * span trees are **well-formed** per thread — every child interval
//!   nests inside its parent, siblings never overlap;
//! * tracing is **output-invariant** — decoded tokens and the
//!   DecodeStats token counters are bit-identical with `RXNSPEC_TRACE`
//!   on and off (only the `*_us` phase fields, documented as
//!   trace-populated, may differ);
//! * the Chrome trace-event export is a single line of valid JSON with
//!   the shape Perfetto expects.
//!
//! Tests in this binary toggle the process-wide trace gate, so they
//! serialize on one mutex and filter snapshots where thread identity
//! matters.

use std::sync::{Mutex, MutexGuard};

use rxnspec::bench::json::{self, Val};
use rxnspec::decoding::{greedy_batch, spec_greedy, DecodeOutput};
use rxnspec::draft::DraftConfig;
use rxnspec::testutil::CopyModel;
use rxnspec::trace::{self, Event, Phase, TRACK_BASE};
use rxnspec::vocab::{BOS_ID, EOS_ID};

static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    rxnspec::coordinator::lock_ok(&GATE)
}

fn srcs() -> Vec<Vec<i64>> {
    vec![
        vec![BOS_ID, 10, 11, 12, 13, EOS_ID],
        vec![BOS_ID, 20, 21, 22, 23, 24, 25, EOS_ID],
        vec![BOS_ID, 30, 31, EOS_ID],
    ]
}

fn run_all(m: &CopyModel) -> Vec<DecodeOutput> {
    let seqs = srcs();
    let refs: Vec<&[i64]> = seqs.iter().map(|s| s.as_slice()).collect();
    let mut outs = greedy_batch(m, &refs).unwrap();
    for s in &seqs {
        outs.push(spec_greedy(m, s, &DraftConfig::new(4)).unwrap());
    }
    outs
}

#[test]
fn tracing_never_changes_outputs_or_token_counters() {
    let _g = gate();
    let m = CopyModel::new(96, 96, 40);

    trace::set_enabled(false);
    let off = run_all(&m);

    trace::set_enabled(true);
    trace::clear();
    let on = run_all(&m);
    trace::set_enabled(false);

    assert_eq!(off.len(), on.len());
    for (a, b) in off.iter().zip(&on) {
        assert_eq!(a.hyps.len(), b.hyps.len());
        for (ha, hb) in a.hyps.iter().zip(&b.hyps) {
            assert_eq!(ha.tokens, hb.tokens, "tracing changed decoded tokens");
            assert_eq!(ha.score, hb.score, "tracing changed a score bit");
        }
        assert_eq!(a.stats.decoder_calls, b.stats.decoder_calls);
        assert_eq!(a.stats.encoder_calls, b.stats.encoder_calls);
        assert_eq!(a.stats.decoder_rows, b.stats.decoder_rows);
        assert_eq!(a.stats.tokens_computed, b.stats.tokens_computed);
        assert_eq!(a.stats.tokens_reused, b.stats.tokens_reused);
        assert_eq!(
            a.stats.acceptance.total_tokens,
            b.stats.acceptance.total_tokens
        );
        // The phase fields are the one documented difference: zero when
        // off, trace-populated when on.
        assert_eq!(a.stats.encode_us, 0);
        assert_eq!(a.stats.extend_us, 0);
        assert_eq!(a.stats.verify_us, 0);
    }
}

#[test]
fn span_trees_are_well_formed_per_thread() {
    let _g = gate();
    let m = CopyModel::new(96, 96, 40);
    trace::set_enabled(true);
    trace::clear();
    let _ = run_all(&m);
    let events = trace::snapshot_events();
    trace::set_enabled(false);

    // Real thread spans only; synthetic request tracks are flat
    // intervals recorded outside the span-stack discipline.
    let spans: Vec<&Event> = events.iter().filter(|e| e.tid < TRACK_BASE).collect();
    assert!(!spans.is_empty(), "a traced decode must record spans");
    assert!(
        spans.iter().any(|e| e.phase == Phase::Extend),
        "decode loop must emit extend spans"
    );
    assert!(
        spans.iter().any(|e| e.phase == Phase::Encode),
        "decode prologue must emit an encode span"
    );

    let by_id: std::collections::HashMap<u64, &Event> =
        spans.iter().map(|e| (e.id, *e)).collect();
    for e in &spans {
        assert!(e.t_start_ns <= e.t_end_ns, "span {} ends before it starts", e.id);
        if e.parent == 0 {
            continue;
        }
        // A parent id may be missing only if the ring overwrote it; with
        // the default 65536-event capacity this workload fits entirely.
        let p = by_id
            .get(&e.parent)
            .unwrap_or_else(|| panic!("span {} has orphan parent {}", e.id, e.parent));
        assert_eq!(p.tid, e.tid, "parent/child spans must share a thread");
        assert!(
            p.t_start_ns <= e.t_start_ns && e.t_end_ns <= p.t_end_ns,
            "child span {} [{}, {}] escapes parent {} [{}, {}]",
            e.id,
            e.t_start_ns,
            e.t_end_ns,
            p.id,
            p.t_start_ns,
            p.t_end_ns
        );
    }

    // Siblings (same thread, same parent) never overlap: on one thread
    // two spans with a common parent are strictly sequential.
    let mut groups: std::collections::HashMap<(u64, u64), Vec<&Event>> =
        std::collections::HashMap::new();
    for e in &spans {
        groups.entry((e.tid, e.parent)).or_default().push(e);
    }
    for ((tid, parent), mut sibs) in groups {
        sibs.sort_by_key(|e| (e.t_start_ns, e.id));
        for w in sibs.windows(2) {
            assert!(
                w[0].t_end_ns <= w[1].t_start_ns,
                "sibling spans {} and {} overlap (tid {tid}, parent {parent})",
                w[0].id,
                w[1].id
            );
        }
    }
}

#[test]
fn chrome_export_is_single_line_valid_trace_json() {
    let _g = gate();
    let m = CopyModel::new(96, 96, 40);
    trace::set_enabled(true);
    trace::clear();
    let _ = run_all(&m);
    let out = trace::export_chrome_json();
    trace::set_enabled(false);

    assert!(!out.contains('\n'), "export must stay single-line for the TRACE command");
    let v = json::parse(&out).expect("export parses as JSON");
    let Some(Val::Arr(evs)) = v.get("traceEvents") else {
        panic!("traceEvents missing or not an array");
    };
    assert!(!evs.is_empty(), "a traced run must export events");
    let phase_names: Vec<&str> = rxnspec::trace::ALL_PHASES.iter().map(|p| p.name()).collect();
    for ev in evs {
        match ev.get("ph") {
            Some(Val::Str(s)) => assert_eq!(s, "X", "complete events only"),
            other => panic!("bad ph field: {other:?}"),
        }
        match ev.get("cat") {
            Some(Val::Str(s)) => assert_eq!(s, "rxnspec"),
            other => panic!("bad cat field: {other:?}"),
        }
        match ev.get("name") {
            Some(Val::Str(s)) => assert!(
                phase_names.contains(&s.as_str()) || s.starts_with("exemplar:"),
                "unknown event name {s:?}"
            ),
            other => panic!("bad name field: {other:?}"),
        }
        for key in ["ts", "dur", "pid", "tid"] {
            match ev.get(key) {
                Some(Val::Num(n)) => assert!(n.is_finite() && *n >= 0.0, "bad {key}"),
                other => panic!("bad {key} field: {other:?}"),
            }
        }
    }
}
