//! Golden round-trip of the manifest column contract.
//!
//! `rust/tests/data/manifest_golden.tsv` is a checked-in sample of what
//! `python -m compile.aot` writes; the Python side regenerates it from
//! its row helpers (`python/tests/test_train_smoke.py::
//! test_manifest_rows_match_rust_golden_file`) and this test parses the
//! same bytes with the production Rust parser — so the two sides cannot
//! drift apart silently (the column comment and the emitter did, once).

use rxnspec::runtime::pjrt::{parse_manifest, DECFAST_WINDOW, MANIFEST_COLUMNS};

const GOLDEN: &str = include_str!("data/manifest_golden.tsv");

#[test]
fn golden_manifest_parses_for_both_tasks() {
    let fwd = parse_manifest(GOLDEN, "fwd").unwrap();
    assert_eq!(fwd.decfast_window, Some(16));
    assert_eq!(fwd.enc.keys().copied().collect::<Vec<_>>(), vec![1, 8]);
    // Decoder grids are keyed (tlen, eb) — window first — while the file
    // columns are eb-then-tlen; the parse order is explicit, not
    // positional guesswork.
    assert!(fwd.dec.contains_key(&(24, 1)));
    assert!(fwd.dec.contains_key(&(96, 8)));
    assert_eq!(fwd.decfast[&(24, 1)], "decfast_fwd_b1_t24.hlo.txt");
    assert_eq!(
        fwd.deccache.keys().copied().collect::<Vec<_>>(),
        vec![(1, 1), (4, 8), (16, 1), (16, 8)]
    );
    assert_eq!(fwd.deccache[&(16, 8)], "deccache_fwd_b8_t16.hlo.txt");

    let retro = parse_manifest(GOLDEN, "retro").unwrap();
    assert_eq!(retro.decfast_window, Some(16));
    assert_eq!(retro.enc.keys().copied().collect::<Vec<_>>(), vec![1]);
    assert_eq!(
        retro.deccache.keys().copied().collect::<Vec<_>>(),
        vec![(8, 4)]
    );
    assert_eq!(retro.deccache[&(8, 4)], "deccache_retro_b4_t8.hlo.txt");
}

#[test]
fn golden_manifest_pins_the_column_contract() {
    // The documented contract, the compiled-in legacy default, and the
    // golden file's meta row must all agree.
    assert_eq!(MANIFEST_COLUMNS, "kind\ttask\teb\ttlen\tfile");
    assert_eq!(DECFAST_WINDOW, 16);
    assert!(GOLDEN.lines().any(|l| l == "meta\tfwd\tdecfast_window\t16\t-"));
    // The artifact-content digest is an unknown meta key to this parser
    // (non-numeric value); it must pass through without error because
    // its bytes feed the cache-version hash, not the parse.
    assert!(GOLDEN.lines().any(|l| l.starts_with("meta\tfwd\tcontent_digest\t")));
    // Every non-empty line has exactly the contract's five columns.
    for line in GOLDEN.lines().filter(|l| !l.is_empty()) {
        assert_eq!(line.split('\t').count(), 5, "bad golden line: {line:?}");
    }
}

#[test]
fn manifest_parser_rejects_contract_violations() {
    // Wrong column count, unknown kind, non-numeric buckets: hard errors.
    assert!(parse_manifest("enc\tfwd\t1\t0", "fwd").is_err());
    assert!(parse_manifest("enc\tfwd\t1\t0\ta.hlo.txt\textra", "fwd").is_err());
    assert!(parse_manifest("bogus\tfwd\t1\t0\tx.hlo.txt", "fwd").is_err());
    assert!(parse_manifest("deccache\tfwd\teight\t4\tf.hlo.txt", "fwd").is_err());
    // Other-task rows and blank lines are skipped, not errors.
    let m = parse_manifest("enc\tretro\t1\t0\te.hlo.txt\n\n", "fwd").unwrap();
    assert!(m.enc.is_empty());
}
