//! Integration: the paper's core claims on the *trained* model.
//!
//! * speculative greedy is token-exact vs greedy on real reactions and
//!   uses several-fold fewer decoder calls (Table 2's mechanism),
//! * SBS matches BS hypothesis sets on the trained (low-entropy) model
//!   (Table 4's mechanism) with fewer calls,
//! * the trained model actually solves the task (accuracy floor),
//! * the full TCP serving stack round-trips with the PJRT backend.
//!
//! Requires `make artifacts`; tests no-op politely otherwise.

use rxnspec::decoding::{beam_search, greedy, sbs, spec_greedy, SbsConfig};
use rxnspec::draft::DraftConfig;
use rxnspec::runtime::AnyBackend;
use rxnspec::vocab::Vocab;
use std::path::Path;

fn setup(task: &str) -> Option<(Vocab, AnyBackend, Vec<rxnspec::chem::Example>)> {
    let arts = Path::new("artifacts");
    let data = Path::new("data");
    if !arts.join("manifest.tsv").exists() {
        eprintln!("skipping serving e2e tests: run `make artifacts` first");
        return None;
    }
    let vocab = Vocab::load(&data.join("vocab.txt")).unwrap();
    let backend = AnyBackend::load("pjrt", arts, task).unwrap();
    let split = rxnspec::chem::read_split(&data.join(format!("{task}_test.tsv"))).unwrap();
    Some((vocab, backend, split))
}

#[test]
fn spec_greedy_lossless_and_fewer_calls_on_trained_model() {
    let Some((vocab, backend, split)) = setup("fwd") else {
        return;
    };
    let mut call_ratio = 0f64;
    let n = 8.min(split.len());
    for ex in &split[..n] {
        let src = vocab.encode_wrapped(&ex.src).unwrap();
        let g = greedy(&backend, &src).unwrap();
        let s = spec_greedy(&backend, &src, &DraftConfig::new(10)).unwrap();
        assert_eq!(
            g.hyps[0].tokens, s.hyps[0].tokens,
            "speculative decoding changed the output for {}",
            ex.src
        );
        call_ratio += g.stats.decoder_calls as f64 / s.stats.decoder_calls as f64;
    }
    call_ratio /= n as f64;
    eprintln!("mean greedy/spec call ratio: {call_ratio:.2}x");
    assert!(
        call_ratio > 2.0,
        "expected >2x fewer decoder calls, got {call_ratio:.2}x"
    );
}

#[test]
fn sbs_matches_beam_search_on_trained_model() {
    let Some((vocab, backend, split)) = setup("retro") else {
        return;
    };
    // The paper's Table 4 metric: top-N *accuracy* (is the ground truth
    // among the top N hypotheses), which must be identical between BS and
    // SBS. (Hypothesis sets need not be byte-identical — the corpus
    // contains equal-probability reactant-order permutations whose
    // ordering is tie-noise.)
    let n_beam = 5;
    let n = 8.min(split.len());
    let mut acc = [[0usize; 2]; 2]; // [algo][k ∈ {1, 5}]
    let mut fewer_calls = 0usize;
    for ex in &split[..n] {
        let src = vocab.encode_wrapped(&ex.src).unwrap();
        let b = beam_search(&backend, &src, n_beam).unwrap();
        let s = sbs(&backend, &src, &SbsConfig::new(n_beam, 10)).unwrap();
        for (ai, out) in [&b, &s].iter().enumerate() {
            for (k, slot) in [(1usize, 0usize), (5, 1)] {
                if out.hyps.iter().take(k).any(|h| vocab.decode(&h.tokens) == ex.tgt) {
                    acc[ai][slot] += 1;
                }
            }
        }
        if s.stats.decoder_calls < b.stats.decoder_calls {
            fewer_calls += 1;
        }
    }
    eprintln!(
        "BS top1/top5: {}/{} {}/{} | SBS: {}/{} {}/{}",
        acc[0][0], n, acc[0][1], n, acc[1][0], n, acc[1][1], n
    );
    // Accuracy must match to within one example on this small sample —
    // the paper itself reports a ±0.02pp tail difference at top-25; the
    // larger-sample measurement lives in the table3 bench.
    assert!(
        acc[0][1].abs_diff(acc[1][1]) <= 1,
        "top-5 accuracy diverged: {} vs {}",
        acc[0][1],
        acc[1][1]
    );
    assert!(
        acc[0][0].abs_diff(acc[1][0]) <= 2,
        "top-1 accuracy diverged: {} vs {}",
        acc[0][0],
        acc[1][0]
    );
    assert!(
        fewer_calls * 10 >= n * 7,
        "SBS should use fewer calls on most queries ({fewer_calls}/{n})"
    );
}

#[test]
fn trained_model_solves_the_synthetic_task() {
    let Some((vocab, backend, split)) = setup("fwd") else {
        return;
    };
    let n = 20.min(split.len());
    let mut hits = 0usize;
    for ex in &split[..n] {
        let src = vocab.encode_wrapped(&ex.src).unwrap();
        let g = greedy(&backend, &src).unwrap();
        if vocab.decode(&g.hyps[0].tokens) == ex.tgt {
            hits += 1;
        }
    }
    eprintln!("fwd top-1 exact match: {hits}/{n}");
    assert!(
        hits * 2 >= n,
        "trained model accuracy below 50% ({hits}/{n}) — undertrained artifacts?"
    );
}

#[test]
fn tcp_serving_round_trip_with_pjrt() {
    use rxnspec::coordinator::{
        run_worker, serve, Client, Metrics, RequestQueue, ServerState,
    };
    use std::net::TcpListener;
    use std::sync::Arc;
    use std::time::Duration;

    let Some((_, _, split)) = setup("fwd") else {
        return;
    };
    let state = Arc::new(ServerState::new(
        RequestQueue::new(8, Duration::from_millis(2)),
        Arc::new(Metrics::default()),
        Arc::new(rxnspec::cache::ServeCache::default()),
    ));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let accept_state = Arc::clone(&state);
    let acceptor = std::thread::spawn(move || serve(listener, accept_state));
    let worker_state = Arc::clone(&state);
    let worker = std::thread::spawn(move || {
        // PJRT handles are not Send: construct inside the thread.
        let vocab = Vocab::load(Path::new("data/vocab.txt")).unwrap();
        let backend = AnyBackend::load("pjrt", Path::new("artifacts"), "fwd").unwrap();
        run_worker(
            &backend,
            &vocab,
            &worker_state.queue,
            &worker_state.metrics,
            &worker_state.cache,
        );
    });

    let mut c = Client::connect(&addr).unwrap();
    assert!(c.ping().unwrap());
    let q = &split[0].src;
    let greedy_p = c.predict("greedy", q).unwrap();
    let spec_p = c.predict("spec:10", q).unwrap();
    assert_eq!(greedy_p.hyps[0].0, spec_p.hyps[0].0, "serving losslessness");
    assert!(spec_p.decoder_calls <= greedy_p.decoder_calls);
    let beam_p = c.predict("bs:3", q).unwrap();
    assert_eq!(beam_p.hyps.len(), 3);
    // Repeat traffic is served from the result cache, bit-identically.
    let cached_p = c.predict("greedy", q).unwrap();
    assert_eq!(cached_p.decoder_calls, 0, "repeat must hit the cache");
    assert_eq!(cached_p.hyps, greedy_p.hyps);

    // Graceful drain joins the worker and every connection thread.
    assert_eq!(c.shutdown().unwrap(), "OK draining");
    worker.join().unwrap();
    acceptor.join().unwrap().unwrap();
}
