//! The session-caching contract, held as a *hard* invariant: decoding
//! through the reference backend's KV-cached session (`extend` /
//! `truncate` / `fork`) must be **token-exact and score-exact** against
//! the stateless recompute path, for every decoding algorithm.
//!
//! This is not a tolerance check. By the conditional-consistency
//! contract, a row's distributions depend only on its own prefix, and
//! the cached path runs the same scalar arithmetic in the same order as
//! the stateless one (`attn_core` is shared), so any drift — however
//! small — is a bug in the cache, not numerical noise.
//!
//! The model under test is a tiny seeded-random Molecular-Transformer
//! (real multi-head attention, pre-LN blocks, cross-attention,
//! log-softmax head), built in memory by `testutil::random_rust_backend`.

use rxnspec::decoding::{
    beam_search, greedy, sbs, spec_greedy, Backend, DecoderRow, SbsConfig,
};
use rxnspec::draft::DraftConfig;
use rxnspec::rng::Rng;
use rxnspec::testutil::{random_rust_backend, random_wrapped_src, ForceStateless};
use rxnspec::vocab::BOS_ID;

const VOCAB: usize = 24;
const S_LEN: usize = 32;
const T_LEN: usize = 32;

#[test]
fn prop_cached_greedy_is_bit_identical_to_stateless() {
    let mut rng = Rng::new(0x11);
    for seed in 0..8u64 {
        let backend = random_rust_backend(seed, VOCAB, S_LEN, T_LEN);
        let oracle = ForceStateless(&backend);
        let src = random_wrapped_src(&mut rng, 4, 16, VOCAB);
        let cached = greedy(&backend, &src).unwrap();
        let stateless = greedy(&oracle, &src).unwrap();
        assert_eq!(
            cached.hyps[0].tokens, stateless.hyps[0].tokens,
            "seed {seed}: greedy tokens diverged"
        );
        assert!(
            cached.hyps[0].score == stateless.hyps[0].score,
            "seed {seed}: greedy score diverged: {} vs {}",
            cached.hyps[0].score,
            stateless.hyps[0].score
        );
        // The win the cache exists for: ~1 computed position per emitted
        // token, against the stateless quadratic recompute.
        assert!(cached.stats.tokens_reused > 0, "seed {seed}: no reuse");
        assert!(
            cached.stats.tokens_computed < stateless.stats.tokens_computed,
            "seed {seed}: cache did not reduce computed positions"
        );
        assert_eq!(stateless.stats.tokens_reused, 0);
    }
}

#[test]
fn prop_cached_spec_greedy_is_bit_identical_to_stateless() {
    let mut rng = Rng::new(0x22);
    for seed in 0..8u64 {
        let backend = random_rust_backend(seed + 100, VOCAB, S_LEN, T_LEN);
        let oracle = ForceStateless(&backend);
        let src = random_wrapped_src(&mut rng, 5, 18, VOCAB);
        for dl in [0usize, 3, 7] {
            let cfg = DraftConfig::new(dl);
            let cached = spec_greedy(&backend, &src, &cfg).unwrap();
            let stateless = spec_greedy(&oracle, &src, &cfg).unwrap();
            assert_eq!(
                cached.hyps[0].tokens, stateless.hyps[0].tokens,
                "seed {seed} dl {dl}: spec tokens diverged"
            );
            assert!(
                cached.hyps[0].score == stateless.hyps[0].score,
                "seed {seed} dl {dl}: spec score diverged"
            );
            assert_eq!(
                cached.stats.decoder_calls, stateless.stats.decoder_calls,
                "seed {seed} dl {dl}: call counts diverged"
            );
            // And the session path must still be lossless vs plain greedy.
            let g = greedy(&backend, &src).unwrap();
            assert_eq!(cached.hyps[0].tokens, g.hyps[0].tokens);
        }
    }
}

#[test]
fn prop_cached_beam_search_is_bit_identical_to_stateless() {
    let mut rng = Rng::new(0x33);
    for seed in 0..6u64 {
        let backend = random_rust_backend(seed + 200, VOCAB, S_LEN, T_LEN);
        let oracle = ForceStateless(&backend);
        let src = random_wrapped_src(&mut rng, 5, 16, VOCAB);
        for n in [1usize, 3, 5] {
            let cached = beam_search(&backend, &src, n).unwrap();
            let stateless = beam_search(&oracle, &src, n).unwrap();
            assert_eq!(
                cached.hyps.len(),
                stateless.hyps.len(),
                "seed {seed} n {n}: hyp counts diverged"
            );
            for (a, b) in cached.hyps.iter().zip(&stateless.hyps) {
                assert_eq!(a.tokens, b.tokens, "seed {seed} n {n}: beam diverged");
                assert!(a.score == b.score, "seed {seed} n {n}: score diverged");
            }
        }
    }
}

#[test]
fn prop_cached_sbs_is_bit_identical_to_stateless() {
    let mut rng = Rng::new(0x44);
    for seed in 0..6u64 {
        let backend = random_rust_backend(seed + 300, VOCAB, S_LEN, T_LEN);
        let oracle = ForceStateless(&backend);
        let src = random_wrapped_src(&mut rng, 6, 18, VOCAB);
        for (n, dl) in [(1usize, 4usize), (3, 0), (3, 5), (5, 8)] {
            let cfg = SbsConfig::new(n, dl);
            let cached = sbs(&backend, &src, &cfg).unwrap();
            let stateless = sbs(&oracle, &src, &cfg).unwrap();
            assert_eq!(
                cached.hyps.len(),
                stateless.hyps.len(),
                "seed {seed} n {n} dl {dl}: hyp counts diverged"
            );
            for (a, b) in cached.hyps.iter().zip(&stateless.hyps) {
                assert_eq!(
                    a.tokens, b.tokens,
                    "seed {seed} n {n} dl {dl}: sbs diverged"
                );
                assert!(a.score == b.score, "seed {seed} n {n} dl {dl}: score diverged");
            }
        }
    }
}

/// Drive extend/truncate/fork directly and compare every exposed
/// log-probability bit-for-bit against a fresh stateless decode of the
/// same teacher-forced rows.
#[test]
fn extend_truncate_fork_logprobs_bit_exact() {
    let backend = random_rust_backend(0xD1CE, VOCAB, S_LEN, T_LEN);
    let src: Vec<i64> = vec![BOS_ID, 5, 6, 7, 8, 9, rxnspec::vocab::EOS_ID];
    let memory = backend.encode(&[&src]).unwrap();

    let mut sess = backend.begin(backend.encode(&[&src]).unwrap()).unwrap();
    let a = sess.new_row(0);
    // Commit [BOS, 5, 6] in two uneven extends.
    sess.extend(&[(a, &[BOS_ID])]).unwrap();
    sess.extend(&[(a, &[5, 6])]).unwrap();
    // Fork, roll the fork back one token, extend it differently.
    let b = sess.fork(a);
    sess.truncate(b, 2);
    let lp_b = sess.extend(&[(b, &[9, 10])]).unwrap();
    // Extend the parent after the fork diverged (copy-on-write must have
    // kept its state intact).
    let lp_a = sess.extend(&[(a, &[7])]).unwrap();

    // Stateless oracle rows.
    let rows = vec![
        DecoderRow {
            tokens: vec![BOS_ID, 5, 9, 10],
            mem_row: 0,
        },
        DecoderRow {
            tokens: vec![BOS_ID, 5, 6, 7],
            mem_row: 0,
        },
    ];
    let lp_ref = backend.decode(&rows, &memory).unwrap();

    for v in 0..VOCAB as i64 {
        // Fork row: window covers successors of positions 1..=3.
        for j in [1usize, 2, 3] {
            assert!(
                lp_b.logp(0, j, v) == lp_ref.logp(0, j, v),
                "fork row: j {j} v {v}: {} vs {}",
                lp_b.logp(0, j, v),
                lp_ref.logp(0, j, v)
            );
        }
        // Parent row after divergent fork: successors of positions 2..=3.
        for j in [2usize, 3] {
            assert!(
                lp_a.logp(0, j, v) == lp_ref.logp(1, j, v),
                "parent row: j {j} v {v}: {} vs {}",
                lp_a.logp(0, j, v),
                lp_ref.logp(1, j, v)
            );
        }
    }

    let stats = sess.stats();
    // BOS + [5,6] + [9,10] + [7] = 6 computed positions, never more.
    assert_eq!(stats.tokens_computed, 6);
    assert!(stats.tokens_reused > 0);
}

/// Sessions across multiple memory rows (batch decode + append_memory)
/// keep rows bound to the right query.
#[test]
fn cached_session_append_memory_matches_fresh_session() {
    let backend = random_rust_backend(0xFEED, VOCAB, S_LEN, T_LEN);
    let s1: Vec<i64> = vec![BOS_ID, 4, 5, rxnspec::vocab::EOS_ID];
    let s2: Vec<i64> = vec![BOS_ID, 6, 7, 8, rxnspec::vocab::EOS_ID];

    // One session seeded with s1, s2 appended mid-flight.
    let mut sess = backend.begin(backend.encode(&[&s1]).unwrap()).unwrap();
    let r1 = sess.new_row(0);
    sess.extend(&[(r1, &[BOS_ID])]).unwrap();
    let base = sess.append_memory(&backend.encode(&[&s2]).unwrap());
    let r2 = sess.new_row(base);
    let lp = sess.extend(&[(r2, &[BOS_ID, 9])]).unwrap();

    // Fresh session over s2 alone.
    let mut fresh = backend.begin(backend.encode(&[&s2]).unwrap()).unwrap();
    let fr = fresh.new_row(0);
    let lp_fresh = fresh.extend(&[(fr, &[BOS_ID, 9])]).unwrap();

    for j in 0..2 {
        for v in 0..VOCAB as i64 {
            assert!(
                lp.logp(0, j, v) == lp_fresh.logp(0, j, v),
                "appended-memory row diverged at j {j} v {v}"
            );
        }
    }
}
