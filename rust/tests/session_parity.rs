//! The session-caching contract, held as a *hard* invariant: decoding
//! through the reference backend's KV-cached session (`extend` /
//! `truncate` / `fork`) must be **token-exact and score-exact** against
//! the stateless recompute path, for every decoding algorithm.
//!
//! This is not a tolerance check. By the conditional-consistency
//! contract, a row's distributions depend only on its own prefix, and
//! the cached path runs the same scalar arithmetic in the same order as
//! the stateless one (`attn_core` is shared), so any drift — however
//! small — is a bug in the cache, not numerical noise.
//!
//! The model under test is a tiny seeded-random Molecular-Transformer
//! (real multi-head attention, pre-LN blocks, cross-attention,
//! log-softmax head), built in memory by `testutil::random_rust_backend`.

use rxnspec::decoding::{
    beam_search, greedy, sbs, spec_greedy, Backend, DecoderRow, DecoderSession, SbsConfig,
};
use rxnspec::draft::DraftConfig;
use rxnspec::rng::Rng;
use rxnspec::testutil::{
    random_rust_backend, random_wrapped_src, DeccacheHarness, ForceStateless,
};
use rxnspec::vocab::BOS_ID;

const VOCAB: usize = 24;
const S_LEN: usize = 32;
const T_LEN: usize = 32;

#[test]
fn prop_cached_greedy_is_bit_identical_to_stateless() {
    let mut rng = Rng::new(0x11);
    for seed in 0..8u64 {
        let backend = random_rust_backend(seed, VOCAB, S_LEN, T_LEN);
        let oracle = ForceStateless(&backend);
        let src = random_wrapped_src(&mut rng, 4, 16, VOCAB);
        let cached = greedy(&backend, &src).unwrap();
        let stateless = greedy(&oracle, &src).unwrap();
        assert_eq!(
            cached.hyps[0].tokens, stateless.hyps[0].tokens,
            "seed {seed}: greedy tokens diverged"
        );
        assert!(
            cached.hyps[0].score == stateless.hyps[0].score,
            "seed {seed}: greedy score diverged: {} vs {}",
            cached.hyps[0].score,
            stateless.hyps[0].score
        );
        // The win the cache exists for: ~1 computed position per emitted
        // token, against the stateless quadratic recompute.
        assert!(cached.stats.tokens_reused > 0, "seed {seed}: no reuse");
        assert!(
            cached.stats.tokens_computed < stateless.stats.tokens_computed,
            "seed {seed}: cache did not reduce computed positions"
        );
        assert_eq!(stateless.stats.tokens_reused, 0);
    }
}

#[test]
fn prop_cached_spec_greedy_is_bit_identical_to_stateless() {
    let mut rng = Rng::new(0x22);
    for seed in 0..8u64 {
        let backend = random_rust_backend(seed + 100, VOCAB, S_LEN, T_LEN);
        let oracle = ForceStateless(&backend);
        let src = random_wrapped_src(&mut rng, 5, 18, VOCAB);
        for dl in [0usize, 3, 7] {
            let cfg = DraftConfig::new(dl);
            let cached = spec_greedy(&backend, &src, &cfg).unwrap();
            let stateless = spec_greedy(&oracle, &src, &cfg).unwrap();
            assert_eq!(
                cached.hyps[0].tokens, stateless.hyps[0].tokens,
                "seed {seed} dl {dl}: spec tokens diverged"
            );
            assert!(
                cached.hyps[0].score == stateless.hyps[0].score,
                "seed {seed} dl {dl}: spec score diverged"
            );
            assert_eq!(
                cached.stats.decoder_calls, stateless.stats.decoder_calls,
                "seed {seed} dl {dl}: call counts diverged"
            );
            // And the session path must still be lossless vs plain greedy.
            let g = greedy(&backend, &src).unwrap();
            assert_eq!(cached.hyps[0].tokens, g.hyps[0].tokens);
        }
    }
}

#[test]
fn prop_cached_beam_search_is_bit_identical_to_stateless() {
    let mut rng = Rng::new(0x33);
    for seed in 0..6u64 {
        let backend = random_rust_backend(seed + 200, VOCAB, S_LEN, T_LEN);
        let oracle = ForceStateless(&backend);
        let src = random_wrapped_src(&mut rng, 5, 16, VOCAB);
        for n in [1usize, 3, 5] {
            let cached = beam_search(&backend, &src, n).unwrap();
            let stateless = beam_search(&oracle, &src, n).unwrap();
            assert_eq!(
                cached.hyps.len(),
                stateless.hyps.len(),
                "seed {seed} n {n}: hyp counts diverged"
            );
            for (a, b) in cached.hyps.iter().zip(&stateless.hyps) {
                assert_eq!(a.tokens, b.tokens, "seed {seed} n {n}: beam diverged");
                assert!(a.score == b.score, "seed {seed} n {n}: score diverged");
            }
        }
    }
}

#[test]
fn prop_cached_sbs_is_bit_identical_to_stateless() {
    let mut rng = Rng::new(0x44);
    for seed in 0..6u64 {
        let backend = random_rust_backend(seed + 300, VOCAB, S_LEN, T_LEN);
        let oracle = ForceStateless(&backend);
        let src = random_wrapped_src(&mut rng, 6, 18, VOCAB);
        for (n, dl) in [(1usize, 4usize), (3, 0), (3, 5), (5, 8)] {
            let cfg = SbsConfig::new(n, dl);
            let cached = sbs(&backend, &src, &cfg).unwrap();
            let stateless = sbs(&oracle, &src, &cfg).unwrap();
            assert_eq!(
                cached.hyps.len(),
                stateless.hyps.len(),
                "seed {seed} n {n} dl {dl}: hyp counts diverged"
            );
            for (a, b) in cached.hyps.iter().zip(&stateless.hyps) {
                assert_eq!(
                    a.tokens, b.tokens,
                    "seed {seed} n {n} dl {dl}: sbs diverged"
                );
                assert!(a.score == b.score, "seed {seed} n {n} dl {dl}: score diverged");
            }
        }
    }
}

/// Drive extend/truncate/fork directly and compare every exposed
/// log-probability bit-for-bit against a fresh stateless decode of the
/// same teacher-forced rows.
#[test]
fn extend_truncate_fork_logprobs_bit_exact() {
    let backend = random_rust_backend(0xD1CE, VOCAB, S_LEN, T_LEN);
    let src: Vec<i64> = vec![BOS_ID, 5, 6, 7, 8, 9, rxnspec::vocab::EOS_ID];
    let memory = backend.encode(&[&src]).unwrap();

    let mut sess = backend.begin(backend.encode(&[&src]).unwrap()).unwrap();
    let a = sess.new_row(0);
    // Commit [BOS, 5, 6] in two uneven extends.
    sess.extend(&[(a, &[BOS_ID])]).unwrap();
    sess.extend(&[(a, &[5, 6])]).unwrap();
    // Fork, roll the fork back one token, extend it differently.
    let b = sess.fork(a);
    sess.truncate(b, 2);
    let lp_b = sess.extend(&[(b, &[9, 10])]).unwrap();
    // Extend the parent after the fork diverged (copy-on-write must have
    // kept its state intact).
    let lp_a = sess.extend(&[(a, &[7])]).unwrap();

    // Stateless oracle rows.
    let rows = vec![
        DecoderRow {
            tokens: vec![BOS_ID, 5, 9, 10],
            mem_row: 0,
        },
        DecoderRow {
            tokens: vec![BOS_ID, 5, 6, 7],
            mem_row: 0,
        },
    ];
    let lp_ref = backend.decode(&rows, &memory).unwrap();

    for v in 0..VOCAB as i64 {
        // Fork row: window covers successors of positions 1..=3.
        for j in [1usize, 2, 3] {
            assert!(
                lp_b.logp(0, j, v) == lp_ref.logp(0, j, v),
                "fork row: j {j} v {v}: {} vs {}",
                lp_b.logp(0, j, v),
                lp_ref.logp(0, j, v)
            );
        }
        // Parent row after divergent fork: successors of positions 2..=3.
        for j in [2usize, 3] {
            assert!(
                lp_a.logp(0, j, v) == lp_ref.logp(1, j, v),
                "parent row: j {j} v {v}: {} vs {}",
                lp_a.logp(0, j, v),
                lp_ref.logp(1, j, v)
            );
        }
    }

    let stats = sess.stats();
    // BOS + [5,6] + [9,10] + [7] = 6 computed positions, never more.
    assert_eq!(stats.tokens_computed, 6);
    assert!(stats.tokens_reused > 0);
}

// ---------------------------------------------------------------------------
// PJRT deccache-session parity (`runtime::deccache::CachedPjrtSession`)
//
// The session machinery the PJRT backend uses over `deccache` artifacts,
// driven here by the reference-kernel executor (`RefDeccacheExec`), whose
// per-lane arithmetic is the exact kernel sequence the reference cached
// session runs — so bit-identity against the stateless oracle is a hard
// invariant, not a tolerance. A run against *real* artifacts needs a real
// XLA (see pjrt_real_artifact_session_parity below, #[ignore]d under the
// offline vendor stub).
// ---------------------------------------------------------------------------

#[test]
fn prop_pjrt_cached_session_decoders_bit_identical_to_stateless() {
    let mut rng = Rng::new(0x55);
    for seed in 0..6u64 {
        let backend = random_rust_backend(seed + 400, VOCAB, S_LEN, T_LEN);
        let harness = DeccacheHarness::new(&backend);
        let oracle = ForceStateless(&backend);
        let src = random_wrapped_src(&mut rng, 5, 16, VOCAB);

        let g_c = greedy(&harness, &src).unwrap();
        let g_s = greedy(&oracle, &src).unwrap();
        assert_eq!(g_c.hyps[0].tokens, g_s.hyps[0].tokens, "seed {seed}: greedy");
        assert!(g_c.hyps[0].score == g_s.hyps[0].score, "seed {seed}: greedy score");
        // The win the deccache artifacts exist for.
        assert!(g_c.stats.tokens_reused > 0, "seed {seed}: no reuse");
        assert!(g_c.stats.tokens_computed < g_s.stats.tokens_computed);

        for dl in [0usize, 4, 8] {
            let cfg = DraftConfig::new(dl);
            let s_c = spec_greedy(&harness, &src, &cfg).unwrap();
            let s_s = spec_greedy(&oracle, &src, &cfg).unwrap();
            assert_eq!(
                s_c.hyps[0].tokens, s_s.hyps[0].tokens,
                "seed {seed} dl {dl}: spec tokens"
            );
            assert!(s_c.hyps[0].score == s_s.hyps[0].score, "seed {seed} dl {dl}");
            assert_eq!(s_c.stats.decoder_calls, s_s.stats.decoder_calls);
            assert_eq!(s_c.hyps[0].tokens, g_c.hyps[0].tokens, "losslessness");
        }

        for n in [2usize, 4] {
            let b_c = beam_search(&harness, &src, n).unwrap();
            let b_s = beam_search(&oracle, &src, n).unwrap();
            assert_eq!(b_c.hyps.len(), b_s.hyps.len(), "seed {seed} n {n}");
            for (a, b) in b_c.hyps.iter().zip(&b_s.hyps) {
                assert_eq!(a.tokens, b.tokens, "seed {seed} n {n}: beam");
                assert!(a.score == b.score, "seed {seed} n {n}: beam score");
            }
        }

        let cfg = SbsConfig::new(3, 5);
        let x_c = sbs(&harness, &src, &cfg).unwrap();
        let x_s = sbs(&oracle, &src, &cfg).unwrap();
        assert_eq!(x_c.hyps.len(), x_s.hyps.len(), "seed {seed}: sbs");
        for (a, b) in x_c.hyps.iter().zip(&x_s.hyps) {
            assert_eq!(a.tokens, b.tokens, "seed {seed}: sbs tokens");
            assert!(a.score == b.score, "seed {seed}: sbs score");
        }
    }
}

/// Drive the PJRT session's extend/truncate/fork surface directly —
/// including a rewind past the retained log-prob suffix (heal path) —
/// and compare every exposed log-probability bit-for-bit against a fresh
/// stateless decode.
#[test]
fn pjrt_session_extend_truncate_fork_bit_exact() {
    let backend = random_rust_backend(0xDECC, VOCAB, S_LEN, T_LEN);
    let harness = DeccacheHarness::new(&backend);
    let src: Vec<i64> = vec![BOS_ID, 5, 6, 7, 8, 9, rxnspec::vocab::EOS_ID];
    let memory = backend.encode(&[&src]).unwrap();

    let mut sess = harness.begin_cached(backend.encode(&[&src]).unwrap());
    let a = sess.new_row(0);
    sess.extend(&[(a, &[BOS_ID])]).unwrap();
    sess.extend(&[(a, &[5, 6])]).unwrap();
    let b = sess.fork(a);
    sess.truncate(b, 2);
    let lp_b = sess.extend(&[(b, &[9, 10])]).unwrap();
    let lp_a = sess.extend(&[(a, &[7])]).unwrap();

    let rows = vec![
        DecoderRow {
            tokens: vec![BOS_ID, 5, 9, 10],
            mem_row: 0,
        },
        DecoderRow {
            tokens: vec![BOS_ID, 5, 6, 7],
            mem_row: 0,
        },
    ];
    let lp_ref = backend.decode(&rows, &memory).unwrap();
    for v in 0..VOCAB as i64 {
        for j in [1usize, 2, 3] {
            assert!(
                lp_b.logp(0, j, v) == lp_ref.logp(0, j, v),
                "fork row: j {j} v {v}"
            );
        }
        for j in [2usize, 3] {
            assert!(
                lp_a.logp(0, j, v) == lp_ref.logp(1, j, v),
                "parent row: j {j} v {v}"
            );
        }
    }
    let stats = sess.stats();
    assert_eq!(stats.tokens_computed, 6, "one computed position per token");
    assert!(stats.tokens_reused > 0);
}

/// The steady loop (same rows, same order, same EB bucket every tick)
/// must thread the executor's retained K/V instead of re-uploading.
#[test]
fn pjrt_session_reuses_device_buffers_in_steady_loop() {
    let backend = random_rust_backend(0xB0F5, VOCAB, S_LEN, T_LEN);
    let harness = DeccacheHarness::new(&backend);
    let src: Vec<i64> = vec![BOS_ID, 4, 5, 6, rxnspec::vocab::EOS_ID];

    let mut sess = harness.begin_cached(backend.encode(&[&src]).unwrap());
    let r = sess.new_row(0);
    sess.extend(&[(r, &[BOS_ID])]).unwrap();
    assert_eq!(sess.kv_uploads_skipped(), 0, "first call must upload");
    for tok in [5i64, 6, 7, 8] {
        sess.extend(&[(r, &[tok])]).unwrap();
    }
    assert_eq!(
        sess.kv_uploads_skipped(),
        4,
        "steady single-row loop must skip every upload after the first"
    );
    // A fork entering the batch breaks the signature exactly once.
    let f = sess.fork(r);
    sess.extend(&[(r, &[9]), (f, &[10])]).unwrap();
    assert_eq!(sess.kv_uploads_skipped(), 4, "new lane set must re-upload");
    sess.extend(&[(r, &[11]), (f, &[12])]).unwrap();
    assert_eq!(sess.kv_uploads_skipped(), 5, "then reuse resumes");
    // Truncate is a host-side rewind: it must NOT break reuse.
    sess.truncate(r, 3);
    sess.extend(&[(r, &[13]), (f, &[14])]).unwrap();
    assert_eq!(sess.kv_uploads_skipped(), 6, "truncate keeps device reuse");
}

/// A truncate that rewinds past the bounded log-prob suffix is healed by
/// re-submitting one committed token — bit-identical, because the
/// recompute reads the same cached K/V prefix.
#[test]
fn pjrt_session_deep_rewind_heal_is_bit_exact() {
    let backend = random_rust_backend(0x4EA1, VOCAB, S_LEN, T_LEN);
    let harness = DeccacheHarness::new(&backend);
    let src: Vec<i64> = vec![BOS_ID, 6, 7, 8, rxnspec::vocab::EOS_ID];
    let memory = backend.encode(&[&src]).unwrap();
    let mut sess = harness.begin_cached(backend.encode(&[&src]).unwrap());
    sess.set_lp_retention(1);
    let r = sess.new_row(0);
    sess.extend(&[(r, &[BOS_ID, 5, 6])]).unwrap();
    // Rewind past the 1-position suffix, extend differently.
    sess.truncate(r, 2);
    let lp = sess.extend(&[(r, &[9])]).unwrap();
    let lp_ref = backend
        .decode(
            &[DecoderRow {
                tokens: vec![BOS_ID, 5, 9],
                mem_row: 0,
            }],
            &memory,
        )
        .unwrap();
    for v in 0..VOCAB as i64 {
        for j in [1usize, 2] {
            assert!(
                lp.logp(0, j, v) == lp_ref.logp(0, j, v),
                "healed rewind diverged at j {j} v {v}"
            );
        }
    }
}

/// An extend wider than the largest deccache window bucket (e.g. a deep
/// rewind heal pushing a full verify window one past the grid) is served
/// by sequential segmented passes — bit-identical, never a hard error.
#[test]
fn pjrt_session_oversized_extend_segments_across_calls() {
    let backend = random_rust_backend(0x5E6, VOCAB, S_LEN, T_LEN);
    // Tiny grid: the largest window bucket holds 4 tokens.
    let harness = DeccacheHarness::with_grid(&backend, vec![(1, 1), (4, 1)]);
    let src: Vec<i64> = vec![BOS_ID, 9, 10, rxnspec::vocab::EOS_ID];
    let memory = backend.encode(&[&src]).unwrap();
    let mut sess = harness.begin_cached(backend.encode(&[&src]).unwrap());
    let r = sess.new_row(0);
    let toks: Vec<i64> = vec![BOS_ID, 5, 6, 7, 8, 9, 10];
    let lp = sess.extend(&[(r, &toks)]).unwrap();
    let lp_ref = backend
        .decode(
            &[DecoderRow {
                tokens: toks.clone(),
                mem_row: 0,
            }],
            &memory,
        )
        .unwrap();
    for v in 0..VOCAB as i64 {
        for j in 0..toks.len() {
            assert!(
                lp.logp(0, j, v) == lp_ref.logp(0, j, v),
                "segmented extend diverged at j {j} v {v}"
            );
        }
    }
    assert_eq!(sess.stats().tokens_computed, toks.len());
}

/// Zero-delta extends (a row just re-reading its head position) are
/// served from the retained log-prob suffix without an executor call.
#[test]
fn pjrt_session_zero_delta_served_from_retention() {
    let backend = random_rust_backend(0x0DE1, VOCAB, S_LEN, T_LEN);
    let harness = DeccacheHarness::new(&backend);
    let src: Vec<i64> = vec![BOS_ID, 7, 8, rxnspec::vocab::EOS_ID];
    let memory = backend.encode(&[&src]).unwrap();
    let mut sess = harness.begin_cached(backend.encode(&[&src]).unwrap());
    let r = sess.new_row(0);
    let first = sess.extend(&[(r, &[BOS_ID, 7])]).unwrap();
    let again = sess.extend(&[(r, &[])]).unwrap();
    let lp_ref = backend
        .decode(
            &[DecoderRow {
                tokens: vec![BOS_ID, 7],
                mem_row: 0,
            }],
            &memory,
        )
        .unwrap();
    for v in 0..VOCAB as i64 {
        assert!(first.logp(0, 1, v) == lp_ref.logp(0, 1, v));
        assert!(again.logp(0, 1, v) == lp_ref.logp(0, 1, v));
    }
}

/// Parity of the cached session against **real compiled artifacts**.
/// Requires a real `xla` binding plus `RXNSPEC_ARTIFACTS` pointing at an
/// aot.py output with `deccache` rows — the offline vendor stub can
/// compile nothing, so this is #[ignore]d by default (run with
/// `cargo test -- --ignored` on a machine with xla_extension installed).
#[test]
#[ignore = "needs real xla bindings + compiled deccache artifacts (RXNSPEC_ARTIFACTS)"]
fn pjrt_real_artifact_session_parity() {
    let arts = rxnspec::knobs::ARTIFACTS.raw().unwrap_or_else(|| "artifacts".into());
    let backend = rxnspec::runtime::PjrtBackend::load(std::path::Path::new(&arts), "fwd")
        .expect("load PJRT backend");
    assert!(
        backend.has_cache_artifacts(),
        "artifact set has no deccache rows; regenerate with current aot.py"
    );
    let src: Vec<i64> = vec![BOS_ID, 5, 6, 7, rxnspec::vocab::EOS_ID];
    let cached = greedy(&backend, &src).unwrap();
    let stateless = greedy(&ForceStateless(&backend), &src).unwrap();
    assert_eq!(cached.hyps[0].tokens, stateless.hyps[0].tokens);
    assert!(cached.stats.tokens_reused > 0);
}

// ---------------------------------------------------------------------------
// Paged KV arena parity (`decoding::arena::KvArena` behind both cached
// sessions)
//
// The arena swaps the dense per-row K/V residency for page-pooled tables
// with COW forks and LRU eviction, and the contract is the same hard
// invariant as everything above: **bit-identical** log-probs to the dense
// path, for every page size (including sizes that straddle the SIMD lane
// width) and under eviction pressure. Sessions are built through the
// explicit `begin_cached_with` constructors so paged and dense variants
// run side by side without racing on process-global `RXNSPEC_ARENA`.
// ---------------------------------------------------------------------------

use rxnspec::decoding::{ArenaConfig, LogProbs};

/// Compare every window position of an extend's log-probs bit-for-bit
/// across sessions. `spans[ri]` is that delta row's (len_before,
/// len_after).
fn assert_extends_match(lps: &[LogProbs], spans: &[(usize, usize)], tag: &str) {
    let base = &lps[0];
    for (si, lp) in lps.iter().enumerate().skip(1) {
        for (ri, &(lb, la)) in spans.iter().enumerate() {
            for j in lb.saturating_sub(1)..la {
                for v in 0..VOCAB as i64 {
                    assert!(
                        lp.logp(ri, j, v) == base.logp(ri, j, v),
                        "{tag}: session {si} row {ri} j {j} v {v}: {} vs {}",
                        lp.logp(ri, j, v),
                        base.logp(ri, j, v)
                    );
                }
            }
        }
    }
}

/// Randomized fork/truncate/extend/release schedules through six
/// sessions at once — dense and paged reference, dense and paged PJRT
/// machinery (reference executor), plus one-page-budget "starved"
/// variants of both paged sessions whose cold rows are perpetually
/// evicted and rehydrated — asserting bit-identical logits at every
/// extend and against the stateless oracle at the end.
#[test]
fn prop_paged_sessions_bit_identical_under_random_schedules() {
    let mut rng = Rng::new(0x9A6E);
    for (seed, page) in [(0u64, 1usize), (1, 3), (2, 5), (3, 16)] {
        let backend = random_rust_backend(seed + 500, VOCAB, S_LEN, T_LEN);
        let harness = DeccacheHarness::new(&backend);
        let src = random_wrapped_src(&mut rng, 5, 16, VOCAB);
        let memory = backend.encode(&[&src]).unwrap();
        let paged = ArenaConfig { page_positions: page, budget_bytes: None };
        // A one-byte budget clamps to a single-page pool: every unpinned
        // cold row is evicted by the next allocation, so extends
        // constantly rehydrate — the heal path must stay bit-exact.
        let starved = ArenaConfig { page_positions: page, budget_bytes: Some(1) };

        let mut s0 = backend.begin_cached_with(backend.encode(&[&src]).unwrap(), None);
        let mut s1 = backend.begin_cached_with(backend.encode(&[&src]).unwrap(), Some(paged));
        let mut s2 = backend.begin_cached_with(backend.encode(&[&src]).unwrap(), Some(starved));
        let mut s3 = harness.begin_cached_with(backend.encode(&[&src]).unwrap(), None);
        let mut s4 = harness.begin_cached_with(backend.encode(&[&src]).unwrap(), Some(paged));
        let mut s5 = harness.begin_cached_with(backend.encode(&[&src]).unwrap(), Some(starved));
        // A 2-position retention makes deep truncates exercise the
        // lp-heal alongside the arena's eviction heal.
        s0.set_lp_retention(2);
        s1.set_lp_retention(2);
        s2.set_lp_retention(2);
        s3.set_lp_retention(2);
        s4.set_lp_retention(2);
        s5.set_lp_retention(2);
        let mut sessions: Vec<Box<dyn DecoderSession + '_>> = vec![
            Box::new(s0),
            Box::new(s1),
            Box::new(s2),
            Box::new(s3),
            Box::new(s4),
            Box::new(s5),
        ];

        // Mirror of the logical row state every session must agree on.
        let mut lens: Vec<usize> = Vec::new();
        let mut hist: Vec<Vec<i64>> = Vec::new();
        let mut live: Vec<bool> = Vec::new();
        for _ in 0..2 {
            for s in sessions.iter_mut() {
                assert_eq!(s.new_row(0), lens.len());
            }
            lens.push(0);
            hist.push(Vec::new());
            live.push(true);
        }

        for op in 0..40 {
            let live_rows: Vec<usize> = (0..lens.len()).filter(|&i| live[i]).collect();
            let pick = rng.below(100);
            if pick < 55 {
                // Extend a random non-empty subset of live rows.
                let mut batch: Vec<usize> =
                    live_rows.iter().copied().filter(|_| rng.chance(0.7)).collect();
                if batch.is_empty() {
                    batch.push(*rng.choose(&live_rows));
                }
                let deltas_own: Vec<(usize, Vec<i64>)> = batch
                    .iter()
                    .map(|&r| {
                        let cap = (T_LEN - 1).saturating_sub(lens[r]);
                        let k = if cap == 0 || (lens[r] > 0 && rng.chance(0.1)) {
                            0 // zero-delta: served from retention
                        } else {
                            rng.range(1, 3.min(cap))
                        };
                        let toks =
                            (0..k).map(|_| rng.range(2, VOCAB - 1) as i64).collect();
                        (r, toks)
                    })
                    .collect();
                let spans: Vec<(usize, usize)> = deltas_own
                    .iter()
                    .map(|(r, t)| (lens[*r], lens[*r] + t.len()))
                    .collect();
                let deltas: Vec<(usize, &[i64])> =
                    deltas_own.iter().map(|(r, t)| (*r, &t[..])).collect();
                let lps: Vec<LogProbs> =
                    sessions.iter_mut().map(|s| s.extend(&deltas).unwrap()).collect();
                assert_extends_match(&lps, &spans, &format!("seed {seed} page {page} op {op}"));
                for (r, t) in &deltas_own {
                    lens[*r] += t.len();
                    hist[*r].extend_from_slice(t);
                }
            } else if pick < 70 {
                // Fork: O(pages) in the arena, shared tail COW'd later.
                let r = *rng.choose(&live_rows);
                for s in sessions.iter_mut() {
                    assert_eq!(s.fork(r), lens.len());
                }
                lens.push(lens[r]);
                hist.push(hist[r].clone());
                live.push(true);
            } else if pick < 85 {
                // Truncate (often deep enough to rewind past retention).
                let r = *rng.choose(&live_rows);
                if lens[r] > 0 {
                    let to = rng.range(0, lens[r] - 1);
                    for s in sessions.iter_mut() {
                        s.truncate(r, to);
                    }
                    lens[r] = to;
                    hist[r].truncate(to);
                }
            } else if live_rows.len() > 1 && rng.chance(0.6) {
                let r = *rng.choose(&live_rows);
                for s in sessions.iter_mut() {
                    s.release(r);
                }
                live[r] = false;
            } else {
                for s in sessions.iter_mut() {
                    assert_eq!(s.new_row(0), lens.len());
                }
                lens.push(0);
                hist.push(Vec::new());
                live.push(true);
            }
        }

        // Closing sweep: append one token to every live row and hold the
        // result against the stateless oracle, not just session-vs-session.
        let batch: Vec<usize> =
            (0..lens.len()).filter(|&i| live[i] && lens[i] + 1 < T_LEN).collect();
        let deltas_own: Vec<(usize, Vec<i64>)> = batch.iter().map(|&r| (r, vec![3i64])).collect();
        let spans: Vec<(usize, usize)> =
            deltas_own.iter().map(|(r, t)| (lens[*r], lens[*r] + t.len())).collect();
        let deltas: Vec<(usize, &[i64])> =
            deltas_own.iter().map(|(r, t)| (*r, &t[..])).collect();
        let lps: Vec<LogProbs> = sessions.iter_mut().map(|s| s.extend(&deltas).unwrap()).collect();
        assert_extends_match(&lps, &spans, &format!("seed {seed} page {page} close"));
        let rows_ref: Vec<DecoderRow> = batch
            .iter()
            .map(|&r| {
                let mut tokens = hist[r].clone();
                tokens.push(3);
                DecoderRow { tokens, mem_row: 0 }
            })
            .collect();
        if !rows_ref.is_empty() {
            let lp_ref = backend.decode(&rows_ref, &memory).unwrap();
            for (ri, &(lb, la)) in spans.iter().enumerate() {
                for j in lb.saturating_sub(1)..la {
                    for v in 0..VOCAB as i64 {
                        assert!(
                            lps[0].logp(ri, j, v) == lp_ref.logp(ri, j, v),
                            "seed {seed} page {page}: oracle diverged row {ri} j {j} v {v}"
                        );
                    }
                }
            }
        }
    }
}

/// Fork storms share pages, divergent writes copy only the tail page,
/// and releasing every row drains the arena back to zero resident pages
/// — both cached session implementations.
#[test]
fn paged_arena_releases_all_pages_at_session_end() {
    let backend = random_rust_backend(0xA7E4, VOCAB, S_LEN, T_LEN);
    let harness = DeccacheHarness::new(&backend);
    let src: Vec<i64> = vec![BOS_ID, 5, 6, 7, rxnspec::vocab::EOS_ID];
    let cfg = ArenaConfig { page_positions: 4, budget_bytes: None };

    // Reference session.
    let mut sess = backend.begin_cached_with(backend.encode(&[&src]).unwrap(), Some(cfg));
    let a = sess.new_row(0);
    sess.extend(&[(a, &[BOS_ID, 5, 6, 7, 8, 9])]).unwrap();
    let forks: Vec<usize> = (0..8).map(|_| sess.fork(a)).collect();
    let tok = [2i64];
    let deltas: Vec<(usize, &[i64])> = forks.iter().map(|&f| (f, tok.as_slice())).collect();
    sess.extend(&deltas).unwrap();
    let st = sess.arena_stats().expect("paged session must expose arena stats");
    assert!(st.pages_resident > 0);
    // 6 committed positions on 4-position pages: each divergent fork
    // COW-copies exactly the shared partial tail page.
    assert_eq!(st.fork_pages_copied, 8, "one tail-page copy per divergent fork");
    // Forks shared the full prefix page: resident pages must be far
    // below 9 rows × 2 pages of dense-equivalent residency.
    assert!(st.pages_resident < 9 * 2, "forks did not share pages: {st:?}");
    for f in forks {
        sess.release(f);
    }
    sess.release(a);
    let st = sess.arena_stats().unwrap();
    assert_eq!(st.pages_resident, 0, "leaked pages after releasing all rows: {st:?}");
    assert_eq!(st.live_tables, 0, "leaked tables: {st:?}");
    // The merged SessionStats surface agrees.
    let stats = rxnspec::decoding::DecoderSession::stats(&sess);
    assert_eq!(stats.kv_pages_resident, 0);
    assert_eq!(stats.fork_pages_copied, 8);
    assert!(stats.kv_pages_high_water > 0);

    // PJRT session machinery.
    let mut sess = harness.begin_cached_with(backend.encode(&[&src]).unwrap(), Some(cfg));
    let a = sess.new_row(0);
    sess.extend(&[(a, &[BOS_ID, 5, 6, 7, 8, 9])]).unwrap();
    let b = sess.fork(a);
    sess.extend(&[(a, &[2]), (b, &[3])]).unwrap();
    assert!(sess.arena_stats().unwrap().fork_pages_copied >= 1);
    sess.release(a);
    sess.release(b);
    let st = sess.arena_stats().unwrap();
    assert_eq!(st.pages_resident, 0, "pjrt session leaked pages: {st:?}");
    assert_eq!(st.live_tables, 0);
}

/// Deterministic eviction-rehydration round trip: a one-page budget
/// forces each of two alternating rows to evict the other, and every
/// rehydrated extend must still match a dense session bit-for-bit.
#[test]
fn paged_eviction_rehydrates_bit_exact() {
    let backend = random_rust_backend(0xEF1C, VOCAB, S_LEN, T_LEN);
    let src: Vec<i64> = vec![BOS_ID, 8, 9, rxnspec::vocab::EOS_ID];
    let starved = ArenaConfig { page_positions: 4, budget_bytes: Some(1) };
    let mut paged = backend.begin_cached_with(backend.encode(&[&src]).unwrap(), Some(starved));
    let mut dense = backend.begin_cached_with(backend.encode(&[&src]).unwrap(), None);

    let a_p = paged.new_row(0);
    let b_p = paged.new_row(0);
    let a_d = dense.new_row(0);
    let b_d = dense.new_row(0);
    assert_eq!((a_p, b_p), (a_d, b_d));

    let mut len_a = 0usize;
    let mut len_b = 0usize;
    for step in 0..5 {
        let toks: Vec<i64> = (0..3).map(|i| 2 + ((step * 3 + i) % 19) as i64).collect();
        let lp_p = paged.extend(&[(a_p, &toks)]).unwrap();
        let lp_d = dense.extend(&[(a_d, &toks)]).unwrap();
        assert_extends_match(
            &[lp_d, lp_p],
            &[(len_a, len_a + toks.len())],
            &format!("evict step {step} row a"),
        );
        len_a += toks.len();
        let lp_p = paged.extend(&[(b_p, &toks)]).unwrap();
        let lp_d = dense.extend(&[(b_d, &toks)]).unwrap();
        assert_extends_match(
            &[lp_d, lp_p],
            &[(len_b, len_b + toks.len())],
            &format!("evict step {step} row b"),
        );
        len_b += toks.len();
    }
    let st = paged.arena_stats().unwrap();
    assert!(st.evictions > 0, "one-page budget never evicted: {st:?}");
    assert!(st.rehydrated_pages > 0, "evicted rows never rehydrated: {st:?}");
}

/// Sessions across multiple memory rows (batch decode + append_memory)
/// keep rows bound to the right query.
#[test]
fn cached_session_append_memory_matches_fresh_session() {
    let backend = random_rust_backend(0xFEED, VOCAB, S_LEN, T_LEN);
    let s1: Vec<i64> = vec![BOS_ID, 4, 5, rxnspec::vocab::EOS_ID];
    let s2: Vec<i64> = vec![BOS_ID, 6, 7, 8, rxnspec::vocab::EOS_ID];

    // One session seeded with s1, s2 appended mid-flight.
    let mut sess = backend.begin(backend.encode(&[&s1]).unwrap()).unwrap();
    let r1 = sess.new_row(0);
    sess.extend(&[(r1, &[BOS_ID])]).unwrap();
    let base = sess.append_memory(&backend.encode(&[&s2]).unwrap());
    let r2 = sess.new_row(base);
    let lp = sess.extend(&[(r2, &[BOS_ID, 9])]).unwrap();

    // Fresh session over s2 alone.
    let mut fresh = backend.begin(backend.encode(&[&s2]).unwrap()).unwrap();
    let fr = fresh.new_row(0);
    let lp_fresh = fresh.extend(&[(fr, &[BOS_ID, 9])]).unwrap();

    for j in 0..2 {
        for v in 0..VOCAB as i64 {
            assert!(
                lp.logp(0, j, v) == lp_fresh.logp(0, j, v),
                "appended-memory row diverged at j {j} v {v}"
            );
        }
    }
}
