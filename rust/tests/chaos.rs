//! Chaos property tests: the serving stack under seeded fault injection
//! (ISSUE 8 acceptance, extended to the multi-worker pool in ISSUE 9).
//!
//! The properties, each checked under a deterministic fault plan:
//!
//! 1. **Exactly one reply** — every submitted request gets exactly one
//!    response (`OK`, `ERR`, or an admission-time `BUSY`), faults or not.
//!    Nothing is silently dropped and nothing is double-replied.
//! 2. **Surviving outputs are exact** — any request that comes back `OK`
//!    from a faulted run carries hypotheses bit-identical to a
//!    fault-free oracle run (supervised retries go through the exact
//!    stateless decoders, so containment never changes served content).
//! 3. **Warm boot is exact** — kill (drain + dump), restart (reload),
//!    and repeated requests are served from the restored cache with zero
//!    decoder calls; a version-mismatched dump is rejected cleanly and
//!    the server simply boots cold.
//! 4. **Cross-worker failover is invisible** — wedge one worker of an
//!    N-worker pool (`worker.wedge`) and its in-flight requests are
//!    reclaimed and served by siblings with outputs bit-identical to a
//!    fault-free single-worker oracle; pool drain still produces a
//!    loadable warm-boot dump.
//!
//! The fault plan is process-global, so every test here serializes on
//! one lock and disarms on exit (even on panic, via a drop guard).

use std::sync::{mpsc, Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use rxnspec::cache::{dump_to_path, load_into, ServeCache};
use rxnspec::coordinator::{
    run_pool, run_worker, DecodeMode, Job, JobResult, Metrics, PoolConfig, PushError,
    RequestQueue,
};
use rxnspec::faults::{self, parse_spec, FaultKind, FaultPlan, Trigger};
use rxnspec::testutil::{random_rust_backend, CopyModel};
use rxnspec::vocab::Vocab;

fn chaos_lock() -> MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    rxnspec::coordinator::lock_ok(L.get_or_init(|| Mutex::new(())))
}

/// Disarm the global plan when a test exits, panicking or not.
struct Disarm;
impl Drop for Disarm {
    fn drop(&mut self) {
        faults::disarm();
    }
}

/// Injected panics are this suite's working fluid; keep their backtrace
/// spam out of the test log while leaving real panics visible.
fn quiet_injected_panics() {
    static ONCE: OnceLock<()> = OnceLock::new();
    ONCE.get_or_init(|| {
        let default_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<String>()
                .map(|s| s.contains("injected fault"))
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<&str>()
                        .map(|s| s.contains("injected fault"))
                })
                .unwrap_or(false);
            if !injected {
                default_hook(info);
            }
        }));
    });
}

fn tiny_vocab() -> Vocab {
    Vocab::build(["CCONF", "c1ccccc1Br"]).unwrap()
}

/// A mixed-mode request list with repeats (so batching, continuous
/// admission, and solo paths all engage).
fn workload() -> Vec<(DecodeMode, String)> {
    let queries = ["CCO", "c1ccccc1", "NCCO", "BrCC", "c1ccccc1Br", "FC"];
    let modes = [
        DecodeMode::Greedy,
        DecodeMode::SpecGreedy { dl: 3 },
        DecodeMode::SpecGreedy { dl: 3 },
        DecodeMode::Beam { n: 2 },
        DecodeMode::Sbs { n: 2, dl: 3 },
    ];
    let mut reqs = Vec::new();
    for round in 0..4 {
        for (i, q) in queries.iter().enumerate() {
            reqs.push((modes[(round + i) % modes.len()], q.to_string()));
        }
    }
    reqs
}

/// Push every request, close the queue, run the worker to completion,
/// and assert the exactly-one-reply property while collecting replies.
fn serve_all<B: rxnspec::decoding::Backend>(
    backend: &B,
    vocab: &Vocab,
    reqs: &[(DecodeMode, String)],
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
) -> Vec<JobResult> {
    let queue = RequestQueue::new(4, Duration::from_millis(1));
    let mut rxs = Vec::new();
    for (mode, smiles) in reqs {
        let (tx, rx) = mpsc::channel();
        queue.push(*mode, Job::new(smiles.clone(), tx));
        rxs.push(rx);
    }
    queue.close();
    run_worker(backend, vocab, &queue, metrics, cache);
    rxs.iter()
        .map(|rx| {
            let first = rx.try_recv().expect("every request must get a reply");
            assert!(rx.try_recv().is_err(), "a request must get exactly one reply");
            first
        })
        .collect()
}

/// Chaos-speed pool supervision: a wedge is declared in tens of
/// milliseconds instead of seconds so the failover tests run fast.
fn fast_pool(workers: usize) -> PoolConfig {
    let mut cfg = PoolConfig::with_workers(workers);
    cfg.wedge_timeout = Duration::from_millis(50);
    cfg.poll = Duration::from_millis(5);
    cfg
}

/// Pool-shaped counterpart of [`serve_all`]: N CopyModel workers over one
/// queue and one shared cache, exactly-one-reply asserted per request.
fn serve_all_pool(
    vocab: &Vocab,
    reqs: &[(DecodeMode, String)],
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
    cfg: &PoolConfig,
) -> Vec<JobResult> {
    let queue = RequestQueue::new(4, Duration::from_millis(1));
    let mut rxs = Vec::new();
    for (mode, smiles) in reqs {
        let (tx, rx) = mpsc::channel();
        queue.push(*mode, Job::new(smiles.clone(), tx));
        rxs.push(rx);
    }
    queue.close();
    let n_vocab = vocab.len();
    run_pool(
        |_slot| Ok(CopyModel::new(96, 96, n_vocab)),
        vocab,
        &queue,
        metrics,
        cache,
        cfg,
    );
    rxs.iter()
        .map(|rx| {
            let first = rx.try_recv().expect("every request must get a reply");
            assert!(rx.try_recv().is_err(), "a request must get exactly one reply");
            first
        })
        .collect()
}

/// Properties 1 + 2 on the CopyModel: panics and stalls on the decoder
/// path; survivors must match the fault-free oracle bit for bit.
#[test]
fn chaos_survivors_bit_identical_to_oracle() {
    let _g = chaos_lock();
    let _d = Disarm;
    quiet_injected_panics();
    let vocab = tiny_vocab();
    let backend = CopyModel::new(96, 96, vocab.len());
    let reqs = workload();

    faults::disarm();
    let oracle = serve_all(
        &backend,
        &vocab,
        &reqs,
        &Arc::new(Metrics::default()),
        &ServeCache::disabled(),
    );
    assert!(oracle.iter().all(|r| r.is_ok()), "oracle run must be clean");

    // One deterministic panic early (so containment provably engages)
    // plus low-rate seeded background chaos. The error rate is the
    // harshest knob: an injected `Err` fails every unreplied lane in its
    // batch without retry, by design.
    faults::install(
        FaultPlan::new(0xC4A05)
            .with("decoder.extend", FaultKind::Panic, Trigger::Nth(2))
            .with("decoder.extend", FaultKind::Panic, Trigger::Prob(0.03))
            .with("decoder.extend", FaultKind::Slow(1), Trigger::Prob(0.02))
            .with("decoder.extend", FaultKind::Err, Trigger::Prob(0.01)),
    );
    let metrics = Arc::new(Metrics::default());
    let chaotic = serve_all(&backend, &vocab, &reqs, &metrics, &ServeCache::disabled());
    faults::disarm();

    let mut survived = 0usize;
    for (i, (got, want)) in chaotic.iter().zip(&oracle).enumerate() {
        if let Ok(reply) = got {
            let want = want.as_ref().unwrap();
            assert_eq!(
                reply.hyps, want.hyps,
                "request {i}: a faulted run served different content"
            );
            survived += 1;
        }
    }
    // The plan is seeded, so the chaos is reproducible — and at these
    // rates containment + retry keeps a solid majority of requests
    // alive (injected `Err`s and double-panics legitimately fail).
    assert!(
        survived > reqs.len() / 3,
        "only {survived}/{} survived — containment is not working",
        reqs.len()
    );
    use std::sync::atomic::Ordering;
    assert!(
        rxnspec::faults::injected() > 0,
        "the plan never fired — chaos test is vacuous"
    );
    // Contained panics and retries surface in the resilience counters.
    let panics = metrics.panics_contained.load(Ordering::Relaxed);
    let retried = metrics.requests_retried.load(Ordering::Relaxed);
    assert!(panics > 0, "the Nth(2) panic rule must have been contained");
    assert!(retried > 0, "contained panics must trigger retries");

    // And the stack still serves cleanly after disarm, in-process.
    let clean = serve_all(
        &backend,
        &vocab,
        &reqs[..6],
        &Arc::new(Metrics::default()),
        &ServeCache::disabled(),
    );
    assert!(clean.iter().all(|r| r.is_ok()), "post-chaos serving must be clean");
}

/// Same properties on the real compute path: a pure-Rust random-weight
/// backend with panics injected into the GEMM kernel and the session
/// layer.
#[test]
fn chaos_on_rust_backend_kernel_panics_are_contained() {
    let _g = chaos_lock();
    let _d = Disarm;
    quiet_injected_panics();
    let vocab = tiny_vocab();
    let backend = random_rust_backend(0xFA57, vocab.len(), 48, 24);
    let reqs: Vec<(DecodeMode, String)> = vec![
        (DecodeMode::Greedy, "CCO".to_string()),
        (DecodeMode::SpecGreedy { dl: 2 }, "c1ccccc1".to_string()),
        (DecodeMode::Greedy, "BrCC".to_string()),
        (DecodeMode::SpecGreedy { dl: 2 }, "CCO".to_string()),
    ];

    faults::disarm();
    let oracle = serve_all(
        &backend,
        &vocab,
        &reqs,
        &Arc::new(Metrics::default()),
        &ServeCache::disabled(),
    );
    assert!(oracle.iter().all(|r| r.is_ok()));

    // One kernel panic, deep in the first decode; supervision must
    // quarantine the session and the retries must reproduce the oracle.
    faults::install(FaultPlan::new(9).with("kernel.gemm", FaultKind::Panic, Trigger::Nth(30)));
    let metrics = Arc::new(Metrics::default());
    let chaotic = serve_all(&backend, &vocab, &reqs, &metrics, &ServeCache::disabled());
    faults::disarm();

    for (i, (got, want)) in chaotic.iter().zip(&oracle).enumerate() {
        let got = got.as_ref().unwrap_or_else(|e| {
            panic!("request {i} must survive a single kernel panic, got ERR {e}")
        });
        assert_eq!(got.hyps, want.as_ref().unwrap().hyps, "request {i} content drifted");
    }
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.panics_contained.load(Ordering::Relaxed), 1);
}

/// Deadlines + backpressure under stall faults: expired requests are
/// shed with `ERR deadline_exceeded`, over-capacity admissions answer
/// `BUSY`, and still every request gets exactly one reply.
#[test]
fn chaos_stalls_shed_deadlines_and_signal_busy() {
    let _g = chaos_lock();
    let _d = Disarm;
    quiet_injected_panics();
    let vocab = tiny_vocab();
    let backend = CopyModel::new(96, 96, vocab.len());

    // Every decoder extend stalls 10ms: the first batch reliably outlives
    // the 5ms deadlines of the requests queued behind it.
    faults::install(FaultPlan::new(3).with(
        "decoder.extend",
        FaultKind::Slow(10),
        Trigger::Prob(1.0),
    ));

    let queue: RequestQueue<Job> =
        RequestQueue::with_capacity(2, Duration::from_millis(1), 4);
    let metrics = Arc::new(Metrics::default());
    let mut live_rxs = Vec::new();
    let mut dead_rxs = Vec::new();
    let mut busy = 0usize;
    for i in 0..6 {
        let (tx, rx) = mpsc::channel();
        // First two: no deadline (they fill the first batch). Next two:
        // 5ms deadlines that expire while the stalled batch runs. The
        // rest overflow the capacity-4 queue.
        let deadline = if i < 2 {
            None
        } else {
            Some(Instant::now() + Duration::from_millis(5))
        };
        let job = Job::new("CCO".to_string(), tx);
        match queue.try_push(DecodeMode::Greedy, job, deadline) {
            Ok(()) => {
                if i < 2 {
                    live_rxs.push(rx)
                } else {
                    dead_rxs.push(rx)
                }
            }
            Err(PushError::Full(_)) => busy += 1,
            Err(PushError::Closed(_)) => unreachable!("queue is open"),
        }
    }
    assert_eq!(busy, 2, "capacity 4 must refuse the 5th and 6th request");
    queue.close();
    run_worker(&backend, &vocab, &queue, &metrics, &ServeCache::disabled());
    faults::disarm();

    for rx in &live_rxs {
        let r = rx.try_recv().expect("undeadlined request must be served");
        assert!(r.is_ok(), "stalled-but-admitted request still completes: {r:?}");
        assert!(rx.try_recv().is_err(), "exactly one reply");
    }
    for rx in &dead_rxs {
        let r = rx.try_recv().expect("expired request must still get a reply");
        assert_eq!(r.unwrap_err(), "deadline_exceeded");
        assert!(rx.try_recv().is_err(), "exactly one reply");
    }
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.requests_shed.load(Ordering::Relaxed), 2);
    assert_eq!(
        metrics.requests_total.load(Ordering::Relaxed),
        2,
        "shed requests must never count as served"
    );
}

/// Kill-and-restart warm boot: drain dumps the cache pair, a restart
/// reloads it (repeat requests answered with zero decoder calls,
/// bit-identical), and a version-mismatched dump is rejected cleanly.
#[test]
fn kill_and_restart_warm_boots_from_dump() {
    let _g = chaos_lock();
    let _d = Disarm;
    faults::disarm();
    let vocab = tiny_vocab();
    let backend = CopyModel::new(96, 96, vocab.len());
    let reqs: Vec<(DecodeMode, String)> = vec![
        (DecodeMode::SpecGreedy { dl: 3 }, "c1ccccc1".to_string()),
        (DecodeMode::Greedy, "CCO".to_string()),
        (DecodeMode::Beam { n: 2 }, "BrCC".to_string()),
    ];
    let mut dump = std::env::temp_dir();
    dump.push(format!("rxnspec-chaos-{}-warmboot.dump", std::process::id()));

    // Life 1: serve, then "kill" gracefully (drain happened when
    // run_worker returned inside serve_all) and persist.
    let cache1 = ServeCache::default();
    cache1.bind_artifact_version(0xBEEF);
    let first = serve_all(
        &backend,
        &vocab,
        &reqs,
        &Arc::new(Metrics::default()),
        &cache1,
    );
    assert!(first.iter().all(|r| r.is_ok()));
    dump_to_path(&cache1, &dump).unwrap();

    // Life 2: restart with the same artifact version — warm boot.
    let cache2 = ServeCache::default();
    cache2.bind_artifact_version(0xBEEF);
    let report = load_into(&cache2, &dump, 0xBEEF).unwrap();
    assert_eq!(report.results, reqs.len());
    let metrics2 = Arc::new(Metrics::default());
    let second = serve_all(&backend, &vocab, &reqs, &metrics2, &cache2);
    for (i, (got, want)) in second.iter().zip(&first).enumerate() {
        let (got, want) = (got.as_ref().unwrap(), want.as_ref().unwrap());
        assert_eq!(got.decoder_calls, 0, "request {i} must hit the restored cache");
        assert_eq!(got.hyps, want.hyps, "request {i}: warm reply must be bit-identical");
        assert_eq!(got.acceptance_rate, want.acceptance_rate);
    }
    use std::sync::atomic::Ordering;
    assert_eq!(
        metrics2.cache_warm_hits.load(Ordering::Relaxed),
        reqs.len() as u64,
        "every life-2 hit came from the dump"
    );

    // Life 3: a model redeploy — the dump must be refused, cleanly.
    let cache3 = ServeCache::default();
    cache3.bind_artifact_version(0xD00D);
    let err = load_into(&cache3, &dump, 0xD00D).unwrap_err();
    assert!(err.to_string().contains("version mismatch"), "{err}");
    assert!(cache3.results().is_empty(), "a refused dump must not seed the cache");
    // Cold boot still serves (fresh decodes, same content).
    let third = serve_all(
        &backend,
        &vocab,
        &reqs,
        &Arc::new(Metrics::default()),
        &cache3,
    );
    for (got, want) in third.iter().zip(&first) {
        let got = got.as_ref().unwrap();
        assert!(got.decoder_calls > 0, "cold boot must decode fresh");
        assert_eq!(got.hyps, want.as_ref().unwrap().hyps);
    }
    std::fs::remove_file(&dump).ok();
}

/// The CI chaos leg's env-shaped schedule parses and runs: the same
/// grammar `rxnspec serve` arms from `RXNSPEC_FAULTS`.
#[test]
fn env_style_schedule_parses_and_runs() {
    let _g = chaos_lock();
    let _d = Disarm;
    quiet_injected_panics();
    let plan = parse_spec(
        "7:decoder.extend=panic@0.04,decoder.extend=slow2@0.03,arena.alloc=panic#5,kernel.gemm=err@0.01,worker.tick=slow1@0.05",
    )
    .unwrap();
    assert_eq!(plan.seed, 7);
    assert_eq!(plan.rules.len(), 5);

    let vocab = tiny_vocab();
    let backend = CopyModel::new(96, 96, vocab.len());
    faults::install(plan);
    let replies = serve_all(
        &backend,
        &vocab,
        &workload()[..12],
        &Arc::new(Metrics::default()),
        &ServeCache::disabled(),
    );
    faults::disarm();
    assert_eq!(replies.len(), 12, "exactly one reply each, chaos or not");
}

/// Property 4, the ISSUE 9 acceptance scenario: 4 workers, one wedged on
/// its first batch (`worker.wedge`). The supervisor reclaims its
/// in-flight requests, siblings (or a replacement) serve them, every
/// request gets exactly one reply, and every output is bit-identical to
/// a fault-free single-worker oracle.
#[test]
fn wedged_worker_requests_reclaimed_by_siblings() {
    let _g = chaos_lock();
    let _d = Disarm;
    quiet_injected_panics();
    let vocab = tiny_vocab();
    let reqs = workload();

    faults::disarm();
    let backend = CopyModel::new(96, 96, vocab.len());
    let oracle = serve_all(
        &backend,
        &vocab,
        &reqs,
        &Arc::new(Metrics::default()),
        &ServeCache::disabled(),
    );
    assert!(oracle.iter().all(|r| r.is_ok()), "oracle run must be clean");

    // `worker.wedge` is a behavioural site (the kind is never applied,
    // only the trigger): Nth(1) freezes exactly the first worker to pop
    // a batch, pool-wide, batch registered and heartbeat stopped.
    faults::install(FaultPlan::new(0x3D9E).with(
        "worker.wedge",
        FaultKind::Panic,
        Trigger::Nth(1),
    ));
    let metrics = Arc::new(Metrics::default());
    let chaotic = serve_all_pool(
        &vocab,
        &reqs,
        &metrics,
        &ServeCache::disabled(),
        &fast_pool(4),
    );
    faults::disarm();

    for (i, (got, want)) in chaotic.iter().zip(&oracle).enumerate() {
        let got = got.as_ref().unwrap_or_else(|e| {
            panic!("request {i} must survive the wedge via reclaim, got ERR {e}")
        });
        assert_eq!(
            got.hyps,
            want.as_ref().unwrap().hyps,
            "request {i}: a reclaimed request served different content"
        );
    }
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.workers.load(Ordering::Relaxed), 4);
    assert!(
        metrics.requests_reclaimed.load(Ordering::Relaxed) >= 1,
        "the wedged worker's batch must have been reclaimed"
    );
    assert!(
        metrics.worker_restarts.load(Ordering::Relaxed) >= 1,
        "a replacement worker must have been spawned"
    );
    assert_eq!(
        metrics.requests_failed.load(Ordering::Relaxed),
        0,
        "a single wedge must cost no client an ERR"
    );
}

/// A fault-free pool is output-invisible: N workers racing over the
/// shared queue and cache serve the exact replies one worker serves.
#[test]
fn fault_free_pool_is_bit_identical_to_single_worker() {
    let _g = chaos_lock();
    let _d = Disarm;
    faults::disarm();
    let vocab = tiny_vocab();
    let reqs = workload();

    let backend = CopyModel::new(96, 96, vocab.len());
    let oracle = serve_all(
        &backend,
        &vocab,
        &reqs,
        &Arc::new(Metrics::default()),
        &ServeCache::disabled(),
    );
    let metrics = Arc::new(Metrics::default());
    let pooled = serve_all_pool(
        &vocab,
        &reqs,
        &metrics,
        &ServeCache::disabled(),
        &fast_pool(4),
    );
    for (i, (got, want)) in pooled.iter().zip(&oracle).enumerate() {
        assert_eq!(
            got.as_ref().unwrap().hyps,
            want.as_ref().unwrap().hyps,
            "request {i}: pool output drifted from the single-worker oracle"
        );
    }
    use std::sync::atomic::Ordering;
    assert_eq!(metrics.worker_restarts.load(Ordering::Relaxed), 0);
    assert_eq!(metrics.requests_reclaimed.load(Ordering::Relaxed), 0);
}

/// Pool drain under fault still produces a loadable warm-boot dump: life
/// 1 runs 4 workers with one wedged, drains, dumps the shared cache;
/// life 2 warm-boots a fresh 4-worker pool from it and serves the same
/// workload with zero decoder calls, bit-identically.
#[test]
fn pool_drain_under_wedge_dumps_loadable_warm_boot() {
    let _g = chaos_lock();
    let _d = Disarm;
    quiet_injected_panics();
    let vocab = tiny_vocab();
    let reqs = workload();
    let mut dump = std::env::temp_dir();
    dump.push(format!("rxnspec-chaos-{}-poolboot.dump", std::process::id()));

    // Life 1: wedge one of four workers mid-run; the drain must still
    // complete (reclaim + siblings) and the shared cache must hold every
    // completion.
    faults::install(FaultPlan::new(0xB007).with(
        "worker.wedge",
        FaultKind::Panic,
        Trigger::Nth(1),
    ));
    let cache1 = ServeCache::default();
    cache1.bind_artifact_version(0xBEEF);
    let first = serve_all_pool(
        &vocab,
        &reqs,
        &Arc::new(Metrics::default()),
        &cache1,
        &fast_pool(4),
    );
    faults::disarm();
    assert!(first.iter().all(|r| r.is_ok()), "life 1 must serve everything");
    dump_to_path(&cache1, &dump).unwrap();

    // Life 2: a fresh pool warm-boots from the dump — every repeat is a
    // zero-decode cache hit with a bit-identical reply.
    let cache2 = ServeCache::default();
    cache2.bind_artifact_version(0xBEEF);
    let report = load_into(&cache2, &dump, 0xBEEF).unwrap();
    assert!(report.results > 0, "the pool dump must carry results");
    let metrics2 = Arc::new(Metrics::default());
    let second = serve_all_pool(&vocab, &reqs, &metrics2, &cache2, &fast_pool(4));
    for (i, (got, want)) in second.iter().zip(&first).enumerate() {
        let (got, want) = (got.as_ref().unwrap(), want.as_ref().unwrap());
        assert_eq!(got.decoder_calls, 0, "request {i} must hit the restored cache");
        assert_eq!(got.hyps, want.hyps, "request {i}: warm reply must be bit-identical");
    }
    std::fs::remove_file(&dump).ok();
}
