//! Kernel-layer invariants, held as *hard* (bit-exact) properties:
//!
//! * **Batched extend ≡ sequential extends** — `CachedSession::extend`
//!   over N rows with mixed window lengths packs everything into one
//!   layer pass per layer; results must be bit-identical to N sequential
//!   single-row extends, and to the stateless-recompute oracle.
//! * **Batched encode ≡ per-row encode** — `encode` packs every source
//!   row into one activation matrix per encoder layer; each memory row
//!   must be bit-identical to encoding that row alone.
//! * **SIMD ≡ scalar fallback** — the AVX2 micro-kernels vectorize
//!   across output lanes only, so every dispatch level produces the
//!   same bits on any shape (tail panels, n=1 rows included).
//! * **Threaded ≡ single-threaded** — the row/head partitioner never
//!   changes a bit (fixed per-element reduction order), whether chunks
//!   run on the persistent pool, on scoped spawns, or inline.
//! * **Bounded log-prob retention ≡ unbounded** — a deep truncate past
//!   the retained suffix is healed by recomputing one position
//!   bit-identically; only the computed-token accounting differs.

use rxnspec::decoding::{greedy, Backend, DecoderRow, DecoderSession};
use rxnspec::kernels::attention::attn_panels_with;
use rxnspec::kernels::simd::{avx2_available, simd_level, SimdLevel};
use rxnspec::kernels::{threads, KvPanels, PackedLinear};
use rxnspec::model::Config;
use rxnspec::rng::Rng;
use rxnspec::testutil::{
    random_rust_backend, random_rust_backend_cfg, random_wrapped_src, ForceStateless,
};
use rxnspec::vocab::BOS_ID;

const VOCAB: usize = 24;
const S_LEN: usize = 32;
const T_LEN: usize = 32;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect()
}

/// The level to hold against the scalar fallback: explicitly `Avx2`
/// whenever the CPU supports it — independent of the `RXNSPEC_SIMD`
/// override, so the parity properties can't silently degrade into
/// scalar-vs-scalar under the env knob. (Safe: every dispatch site
/// re-checks `avx2_available` before entering intrinsic code.)
fn parity_level() -> SimdLevel {
    if avx2_available() {
        SimdLevel::Avx2
    } else {
        simd_level()
    }
}

#[test]
fn prop_simd_gemm_bit_identical_to_scalar_fallback() {
    let mut rng = Rng::new(0x51D0);
    let active = parity_level();
    // Deliberate edge shapes (tail panels, n=1 rows, single column) plus
    // randomized draws.
    let mut shapes = vec![
        (1usize, 1usize, 1usize),
        (1, 7, 8),
        (1, 8, 9),
        (2, 3, 19),
        (4, 16, 8),
        (5, 13, 24),
    ];
    for _ in 0..12 {
        shapes.push((rng.range(1, 9), rng.range(1, 40), rng.range(1, 40)));
    }
    for (n, din, dout) in shapes {
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let x = rand_vec(&mut rng, n * din);
        let packed = PackedLinear::pack(&w, din, dout, &b);
        let mut y_scalar = vec![0f32; n * dout];
        packed.apply_into_with(&x, n, &mut y_scalar, 1, SimdLevel::Scalar);
        let mut y_active = vec![0f32; n * dout];
        packed.apply_into_with(&x, n, &mut y_active, 1, active);
        assert_eq!(
            y_scalar,
            y_active,
            "n={n} din={din} dout={dout} level={}",
            active.name()
        );
    }
}

#[test]
fn prop_simd_attention_bit_identical_to_scalar_fallback() {
    let mut rng = Rng::new(0x51D1);
    let active = parity_level();
    for trial in 0..10 {
        let nh = rng.range(1, 4);
        let dh = rng.range(1, 20); // lane tails in the AV loop
        let nk = rng.range(1, 30); // lane tails in the score loop
        let nq = rng.range(1, 5);
        let d = nh * dh;
        let mut kv = KvPanels::new(nh, dh);
        let k = rand_vec(&mut rng, nk * d);
        let v = rand_vec(&mut rng, nk * d);
        kv.append(&k, &v, nk);
        let q = rand_vec(&mut rng, nq * d);
        for mask in [None, Some(nk.saturating_sub(nq))] {
            let mut scalar = vec![0f32; nq * d];
            attn_panels_with(&q, d, 0, nq, &kv, mask, &mut scalar, SimdLevel::Scalar);
            let mut auto = vec![0f32; nq * d];
            attn_panels_with(&q, d, 0, nq, &kv, mask, &mut auto, active);
            assert_eq!(
                scalar, auto,
                "trial {trial}: nh={nh} dh={dh} nk={nk} nq={nq} mask={mask:?}"
            );
        }
    }
}

#[test]
fn prop_pool_scoped_and_serial_partitioners_bit_identical() {
    let mut rng = Rng::new(0xB001);
    for trial in 0..5 {
        let n = rng.range(1, 200);
        let base = rand_vec(&mut rng, n);
        // A per-item chain of non-associative float steps: any
        // partitioner bug (wrong chunk, double visit, missed item)
        // changes bits.
        let f = |x: &mut f32| {
            let mut acc = *x;
            for k in 0..16 {
                acc = acc * 0.93 + (k as f32) * 0.011;
                acc += acc * -0.007;
            }
            *x = acc;
        };
        let mut serial = base.clone();
        threads::for_each_partitioned(&mut serial, 1, f);
        for nthreads in [2usize, 3, 8, 32] {
            let mut pooled = base.clone();
            threads::for_each_partitioned(&mut pooled, nthreads, f);
            assert_eq!(serial, pooled, "trial {trial} pool threads={nthreads}");
            let mut scoped = base.clone();
            threads::for_each_partitioned_scoped(&mut scoped, nthreads, f);
            assert_eq!(serial, scoped, "trial {trial} scoped threads={nthreads}");
        }
    }
}

#[test]
fn prop_batched_encode_matches_per_row_encode() {
    let mut rng = Rng::new(0xE4C0);
    for seed in 0..4u64 {
        let backend = random_rust_backend(seed + 900, VOCAB, S_LEN, T_LEN);
        // Mixed lengths, including a minimal wrapped row.
        let srcs: Vec<Vec<i64>> = (0..4)
            .map(|i| random_wrapped_src(&mut rng, 2 + i, 5 + 4 * i, VOCAB))
            .collect();
        let refs: Vec<&[i64]> = srcs.iter().map(|s| s.as_slice()).collect();
        let mem_b = backend.encode(&refs).unwrap();
        assert_eq!(mem_b.batch, refs.len());
        for (i, r) in refs.iter().enumerate() {
            let mem_i = backend.encode(&[r]).unwrap();
            assert_eq!(mem_b.row(i), mem_i.row(0), "seed {seed} row {i} data");
            assert_eq!(mem_b.pad_row(i), mem_i.pad_row(0), "seed {seed} row {i} pad");
        }
    }
}

#[test]
fn session_tracks_encoder_packing_stats() {
    let backend = random_rust_backend(0x517A, VOCAB, S_LEN, T_LEN);
    let mut rng = Rng::new(0x517B);
    let srcs: Vec<Vec<i64>> = (0..3)
        .map(|_| random_wrapped_src(&mut rng, 3, 8, VOCAB))
        .collect();
    let refs: Vec<&[i64]> = srcs.iter().map(|s| s.as_slice()).collect();
    let mut sess = backend.begin(backend.encode(&refs).unwrap()).unwrap();
    let st = sess.stats();
    assert_eq!(st.encode_calls, 1);
    assert_eq!(st.packed_src_rows, 3);
    // Continuous batching: a newcomer's encode pass is accounted too.
    let extra = backend.encode(&refs[..1]).unwrap();
    sess.append_memory(&extra);
    let st = sess.stats();
    assert_eq!(st.encode_calls, 2);
    assert_eq!(st.packed_src_rows, 4);
}

#[test]
fn prop_batched_extend_matches_sequential_and_stateless() {
    let mut rng = Rng::new(0x77);
    for seed in 0..5u64 {
        let backend = random_rust_backend(seed + 400, VOCAB, S_LEN, T_LEN);
        let srcs: Vec<Vec<i64>> = (0..3)
            .map(|_| random_wrapped_src(&mut rng, 4, 12, VOCAB))
            .collect();
        let refs: Vec<&[i64]> = srcs.iter().map(|s| s.as_slice()).collect();

        // Committed prefixes and final windows of mixed lengths.
        let prefixes: [Vec<i64>; 3] = [
            vec![BOS_ID],
            vec![BOS_ID, 5, 6],
            vec![BOS_ID, 7, 8, 9, 10],
        ];
        let windows: [Vec<i64>; 3] = [vec![4, 5, 6], vec![11], vec![6, 7]];

        // Session A: the final extend is one batched call over all rows.
        let mut sa = backend.begin(backend.encode(&refs).unwrap()).unwrap();
        let rows_a: Vec<usize> = (0..3).map(|i| sa.new_row(i)).collect();
        for (i, &r) in rows_a.iter().enumerate() {
            sa.extend(&[(r, prefixes[i].as_slice())]).unwrap();
        }
        let deltas: Vec<(usize, &[i64])> = rows_a
            .iter()
            .enumerate()
            .map(|(i, &r)| (r, windows[i].as_slice()))
            .collect();
        let lp_a = sa.extend(&deltas).unwrap();

        // Session B: identical state, one row per final extend call.
        let mut sb = backend.begin(backend.encode(&refs).unwrap()).unwrap();
        let rows_b: Vec<usize> = (0..3).map(|i| sb.new_row(i)).collect();
        for (i, &r) in rows_b.iter().enumerate() {
            sb.extend(&[(r, prefixes[i].as_slice())]).unwrap();
        }
        let lp_b: Vec<_> = rows_b
            .iter()
            .enumerate()
            .map(|(i, &r)| sb.extend(&[(r, windows[i].as_slice())]).unwrap())
            .collect();

        // Stateless oracle over the same teacher-forced rows.
        let oracle = ForceStateless(&backend);
        let mut so = oracle.begin(backend.encode(&refs).unwrap()).unwrap();
        let rows_o: Vec<usize> = (0..3).map(|i| so.new_row(i)).collect();
        for (i, &r) in rows_o.iter().enumerate() {
            so.extend(&[(r, prefixes[i].as_slice())]).unwrap();
        }
        let deltas_o: Vec<(usize, &[i64])> = rows_o
            .iter()
            .enumerate()
            .map(|(i, &r)| (r, windows[i].as_slice()))
            .collect();
        let lp_o = so.extend(&deltas_o).unwrap();

        for i in 0..3 {
            let len_before = prefixes[i].len();
            let len_after = len_before + windows[i].len();
            for j in (len_before - 1)..len_after {
                for v in 0..VOCAB as i64 {
                    let a = lp_a.logp(i, j, v);
                    let b = lp_b[i].logp(0, j, v);
                    let o = lp_o.logp(i, j, v);
                    assert!(
                        a == b,
                        "seed {seed} row {i} j {j} v {v}: batched {a} vs sequential {b}"
                    );
                    assert!(
                        a == o,
                        "seed {seed} row {i} j {j} v {v}: batched {a} vs stateless {o}"
                    );
                }
            }
        }

        // Packed-rows accounting: 3 single-row prefix calls + one fused
        // 3-row call.
        let st = sa.stats();
        assert_eq!(st.extend_calls, 4);
        assert_eq!(st.packed_rows, 6);
        assert_eq!(sb.stats().extend_calls, 6);
        assert_eq!(sb.stats().packed_rows, 6);
    }
}

#[test]
fn threaded_backend_is_bit_identical_to_single_thread() {
    // Dims large enough that both the GEMM row partitioner
    // (n·din·dout ≥ 2^16) and the attention head partitioner
    // (nq·nk·d_head·n_heads ≥ 2^14) actually engage.
    let cfg = Config {
        vocab: 32,
        d_model: 64,
        n_heads: 4,
        d_ff: 256,
        n_enc: 1,
        n_dec: 2,
        s_len: 32,
        t_len: 32,
    };
    let b1 = random_rust_backend_cfg(0xAB, cfg);
    let mut b4 = random_rust_backend_cfg(0xAB, cfg);
    b4.set_threads(4);
    assert_eq!(b4.threads(), 4);

    let mut rng = Rng::new(0x99);
    let src = random_wrapped_src(&mut rng, 12, 24, cfg.vocab);

    // Encoder parity, bit for bit.
    let mem1 = b1.encode(&[&src]).unwrap();
    let mem4 = b4.encode(&[&src]).unwrap();
    assert_eq!(mem1.data, mem4.data, "threaded encoder diverged");

    // Full teacher-forced decode parity (16 positions engages the head
    // partitioner: 16·16·16·4 = 2^14).
    let mut tokens = vec![BOS_ID];
    for t in 0..15i64 {
        tokens.push(4 + (t % 20));
    }
    let row = DecoderRow { tokens, mem_row: 0 };
    let lp1 = b1.decode(std::slice::from_ref(&row), &mem1).unwrap();
    let lp4 = b4.decode(std::slice::from_ref(&row), &mem4).unwrap();
    for j in 0..row.tokens.len() {
        for v in 0..cfg.vocab as i64 {
            assert!(
                lp1.logp(0, j, v) == lp4.logp(0, j, v),
                "threaded decode diverged at j {j} v {v}"
            );
        }
    }

    // End-to-end greedy decode parity (sessions + batched extends).
    let g1 = greedy(&b1, &src).unwrap();
    let g4 = greedy(&b4, &src).unwrap();
    assert_eq!(g1.hyps[0].tokens, g4.hyps[0].tokens);
    assert!(g1.hyps[0].score == g4.hyps[0].score);
}

#[test]
fn lp_retention_bound_heals_deep_rewinds_bit_exactly() {
    let backend = random_rust_backend(0x1234, VOCAB, S_LEN, T_LEN);
    let src: Vec<i64> = vec![BOS_ID, 4, 5, 6, rxnspec::vocab::EOS_ID];

    let mut tight = backend.begin_cached(backend.encode(&[&src]).unwrap());
    tight.set_lp_retention(2);
    let mut loose = backend.begin_cached(backend.encode(&[&src]).unwrap());

    let rt = tight.new_row(0);
    let rl = loose.new_row(0);
    let toks: Vec<i64> = vec![BOS_ID, 5, 6, 7, 8, 9];
    let lp_t = tight.extend(&[(rt, toks.as_slice())]).unwrap();
    let lp_l = loose.extend(&[(rl, toks.as_slice())]).unwrap();
    // Retention trims *after* the window is assembled, so the first call
    // still exposes every appended position.
    for j in 0..toks.len() {
        for v in 0..VOCAB as i64 {
            assert!(lp_t.logp(0, j, v) == lp_l.logp(0, j, v), "first window j {j} v {v}");
        }
    }

    // Deep rewind: with retention 2 the suffix starts at position 4, so
    // truncating to 2 rewinds past it; the next extend must re-commit
    // position 1 internally and still serve a bit-exact window.
    tight.truncate(rt, 2);
    loose.truncate(rl, 2);
    let lp_t2 = tight.extend(&[(rt, &[10, 11])]).unwrap();
    let lp_l2 = loose.extend(&[(rl, &[10, 11])]).unwrap();
    for j in [1usize, 2, 3] {
        for v in 0..VOCAB as i64 {
            assert!(
                lp_t2.logp(0, j, v) == lp_l2.logp(0, j, v),
                "post-rewind window j {j} v {v}"
            );
        }
    }

    // Oracle check of the healed row: [BOS, 5] ++ [10, 11].
    let memory = backend.encode(&[&src]).unwrap();
    let lp_ref = backend
        .decode(
            &[DecoderRow {
                tokens: vec![BOS_ID, 5, 10, 11],
                mem_row: 0,
            }],
            &memory,
        )
        .unwrap();
    for j in [1usize, 2, 3] {
        for v in 0..VOCAB as i64 {
            assert!(
                lp_t2.logp(0, j, v) == lp_ref.logp(0, j, v),
                "healed row vs stateless decode j {j} v {v}"
            );
        }
    }

    // Accounting: the tight session recomputed exactly one extra
    // position; the high-water mark saw the unbounded first burst.
    let st = tight.stats();
    let sl = loose.stats();
    assert_eq!(st.tokens_computed, sl.tokens_computed + 1);
    assert_eq!(st.lp_high_water, 6);
    assert_eq!(sl.lp_high_water, 6);
    assert_eq!(st.tokens_reused + 1, sl.tokens_reused);
}

#[test]
fn batched_extend_after_fork_and_truncate_matches_stateless() {
    // Forked COW rows with divergent histories joining one batched
    // extend — the shape beam search / SBS produce every step.
    let backend = random_rust_backend(0xC0C0, VOCAB, S_LEN, T_LEN);
    let src: Vec<i64> = vec![BOS_ID, 5, 6, 7, 8, 9, rxnspec::vocab::EOS_ID];
    let memory = backend.encode(&[&src]).unwrap();

    let mut sess = backend.begin(backend.encode(&[&src]).unwrap()).unwrap();
    let a = sess.new_row(0);
    sess.extend(&[(a, &[BOS_ID, 5, 6])]).unwrap();
    let b = sess.fork(a);
    sess.truncate(b, 2);
    // One batched call extending the parent and the rewound fork.
    let lp = sess.extend(&[(a, &[7, 8]), (b, &[9])]).unwrap();

    let rows = vec![
        DecoderRow {
            tokens: vec![BOS_ID, 5, 6, 7, 8],
            mem_row: 0,
        },
        DecoderRow {
            tokens: vec![BOS_ID, 5, 9],
            mem_row: 0,
        },
    ];
    let lp_ref = backend.decode(&rows, &memory).unwrap();
    for v in 0..VOCAB as i64 {
        for j in [2usize, 3, 4] {
            assert!(
                lp.logp(0, j, v) == lp_ref.logp(0, j, v),
                "parent row j {j} v {v}"
            );
        }
        for j in [1usize, 2] {
            assert!(
                lp.logp(1, j, v) == lp_ref.logp(1, j, v),
                "forked row j {j} v {v}"
            );
        }
    }
}
