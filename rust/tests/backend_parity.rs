//! Integration: the AOT artifact run by PJRT must agree with the
//! independent pure-Rust reference implementation — the reproduction of
//! the paper's Table 1 exercise ("our reimplementation matches the
//! original"). Requires `make artifacts`; tests no-op politely otherwise
//! so `cargo test` stays green on a fresh checkout.

use rxnspec::decoding::{beam_search, greedy, Backend, DecoderRow};
use rxnspec::runtime::AnyBackend;
use rxnspec::vocab::Vocab;
use std::path::Path;

fn setup() -> Option<(Vocab, AnyBackend, AnyBackend, Vec<rxnspec::chem::Example>)> {
    let arts = Path::new("artifacts");
    let data = Path::new("data");
    if !arts.join("manifest.tsv").exists() || !data.join("vocab.txt").exists() {
        eprintln!("skipping backend parity tests: run `make artifacts` first");
        return None;
    }
    let vocab = Vocab::load(&data.join("vocab.txt")).unwrap();
    let pjrt = AnyBackend::load("pjrt", arts, "fwd").unwrap();
    let rust = AnyBackend::load("rust", arts, "fwd").unwrap();
    let split = rxnspec::chem::read_split(&data.join("fwd_test.tsv")).unwrap();
    Some((vocab, pjrt, rust, split))
}

#[test]
fn logprobs_close_between_backends() {
    let Some((vocab, pjrt, rust, split)) = setup() else {
        return;
    };
    let mut max_diff = 0f32;
    for ex in &split[..5] {
        let src = vocab.encode_wrapped(&ex.src).unwrap();
        let mem_p = pjrt.encode(&[&src]).unwrap();
        let mem_r = rust.encode(&[&src]).unwrap();
        // Decode a teacher-forced prefix of the true target.
        let tgt = vocab.encode(&ex.tgt).unwrap();
        let mut row = vec![rxnspec::vocab::BOS_ID];
        row.extend(&tgt[..tgt.len().min(10)]);
        let rows = vec![DecoderRow {
            tokens: row.clone(),
            mem_row: 0,
        }];
        let lp_p = pjrt.decode(&rows, &mem_p).unwrap();
        let lp_r = rust.decode(&rows, &mem_r).unwrap();
        for j in 0..row.len() {
            for v in 0..pjrt.dims().vocab as i64 {
                let d = (lp_p.logp(0, j, v) - lp_r.logp(0, j, v)).abs();
                max_diff = max_diff.max(d);
            }
            assert_eq!(
                lp_p.argmax(0, j),
                lp_r.argmax(0, j),
                "argmax diverged at {j} for {}",
                ex.src
            );
        }
    }
    eprintln!("max |Δlogp| between backends: {max_diff:.2e}");
    assert!(max_diff < 5e-3, "backends diverged: {max_diff}");
}

#[test]
fn greedy_outputs_identical_across_backends() {
    let Some((vocab, pjrt, rust, split)) = setup() else {
        return;
    };
    let mut agree = 0;
    let total = 10.min(split.len());
    for ex in &split[..total] {
        let src = vocab.encode_wrapped(&ex.src).unwrap();
        let a = greedy(&pjrt, &src).unwrap();
        let b = greedy(&rust, &src).unwrap();
        if a.hyps[0].tokens == b.hyps[0].tokens {
            agree += 1;
        }
    }
    // Near-ties can flip argmax between float implementations; demand
    // overwhelming (not bit-perfect) agreement, as the paper's Table 1
    // tolerates ±0.2pp.
    assert!(agree * 10 >= total * 9, "only {agree}/{total} greedy agreement");
}

#[test]
fn beam5_sets_overlap_across_backends() {
    let Some((vocab, pjrt, rust, split)) = setup() else {
        return;
    };
    let mut overlap = 0usize;
    let total = 5.min(split.len());
    for ex in &split[..total] {
        let src = vocab.encode_wrapped(&ex.src).unwrap();
        let a = beam_search(&pjrt, &src, 5).unwrap();
        let b = beam_search(&rust, &src, 5).unwrap();
        let set_b: std::collections::HashSet<_> = b.hyps.iter().map(|h| &h.tokens).collect();
        overlap += a.hyps.iter().filter(|h| set_b.contains(&h.tokens)).count();
    }
    assert!(
        overlap * 100 >= 5 * total * 80,
        "top-5 overlap too low: {overlap}/{}",
        5 * total
    );
}
