//! Property tests for the cache subsystem's exactness guarantees
//! (ISSUE 2 acceptance criterion): with the `ResultCache` and the
//! `DraftStore` enabled, served predictions and decoder outputs are
//! bit-identical to the cold/disabled path.
//!
//! * **Speculative greedy** is token-exact vs plain greedy for *any*
//!   draft store content — warm, foreign, or adversarially poisoned —
//!   because the accept rule compares every draft token against the
//!   model's own argmax (the paper's §2.1 losslessness, extended to the
//!   corpus source).
//! * **SBS** with never-accepted corpus windows is bit-identical to SBS
//!   without the store: candidates are generated only from each beam's
//!   best draft, a never-accepted window loses every best-draft
//!   selection (ties keep the earlier, query-copy row), query windows
//!   keep cap priority, and row truncation cuts from the tail.
//! * **ResultCache** replays stored completions verbatim (covered at the
//!   worker/server layer in `coordinator` unit tests and `serving_e2e`).
//! * **Cross-worker merging** — one `ServeCache` shared by pool workers:
//!   windows mined by one worker draft another's decodes (fewer calls),
//!   still bit-output-neutral.

use rxnspec::cache::DraftStore;
use rxnspec::decoding::{beam_search, greedy, sbs, spec_greedy_corpus, SbsConfig};
use rxnspec::draft::DraftConfig;
use rxnspec::rng::Rng;
use rxnspec::testutil::{random_wrapped_src, CopyModel, HashModel};
use rxnspec::vocab::{BOS_ID, EOS_ID, PAD_ID};

/// Plant adversarial windows: special tokens, repeats, and valid-looking
/// but wrong sequences (all ids within the mock vocab).
fn poison(store: &DraftStore, vocab: i64) {
    store.record_window(&[BOS_ID, BOS_ID, PAD_ID, EOS_ID]);
    store.record_window(&[EOS_ID, 5, 5, 5]);
    store.record_window(&[PAD_ID; 6]);
    store.record_window(&[vocab - 1, vocab - 2, vocab - 3, vocab - 4]);
    store.record_window(&[7; 12]);
}

/// THE tentpole invariant: greedy-speculative decoding with a warm *and*
/// poisoned draft store emits exactly the greedy sequence, for an
/// arbitrary conditional model.
#[test]
fn prop_spec_greedy_with_draft_store_bit_identical() {
    let mut rng = Rng::new(0xCAC4E);
    for case in 0..20u64 {
        let m = HashModel::new(64, 64, 32, case + 1000);
        let store = DraftStore::new(4, 1024);
        // Warm the store with real targets from other queries (foreign
        // but plausible windows) and from the query under test itself.
        for _ in 0..3 {
            let s = random_wrapped_src(&mut rng, 6, 20, 32);
            let g = greedy(&m, &s).unwrap();
            store.record(&g.hyps[0].tokens);
        }
        let src = random_wrapped_src(&mut rng, 4, 20, 32);
        let g = greedy(&m, &src).unwrap();
        store.record(&g.hyps[0].tokens);
        poison(&store, 32);

        for dl in [2usize, 4, 10] {
            let corpus = store.top_k(16);
            let s = spec_greedy_corpus(&m, &src, &DraftConfig::new(dl), &corpus).unwrap();
            assert_eq!(
                s.hyps[0].tokens, g.hyps[0].tokens,
                "case {case} dl {dl}: draft store changed the output"
            );
            assert!(
                (s.hyps[0].score - g.hyps[0].score).abs() < 1e-5,
                "case {case} dl {dl}: score drifted"
            );
            assert!(
                s.stats.decoder_calls <= g.stats.decoder_calls,
                "case {case} dl {dl}: corpus drafts made decoding slower than greedy"
            );
            // Source attribution is a partition of accepted tokens.
            assert_eq!(
                s.stats.accepted_query_tokens + s.stats.accepted_corpus_tokens,
                s.stats.acceptance.accepted_draft_tokens,
                "case {case} dl {dl}: attribution must sum to total acceptance"
            );
        }
    }
}

/// On the copy regime (the chemistry case) a store warmed with the true
/// target yields corpus acceptances — still token-exact, fewer calls.
#[test]
fn warm_store_accepts_corpus_windows_on_copy_regime() {
    let m = CopyModel::new(96, 96, 40);
    let src = vec![BOS_ID, 10, 11, 12, 13, 14, 15, 16, EOS_ID];
    let g = greedy(&m, &src).unwrap();
    let store = DraftStore::new(3, 256);
    store.record(&g.hyps[0].tokens);
    poison(&store, 40);
    // DL longer than the query disables query-copy windows entirely, so
    // every acceptance must come from the corpus source.
    let s = spec_greedy_corpus(&m, &src, &DraftConfig::new(20), &store.top_k(16)).unwrap();
    assert_eq!(s.hyps[0].tokens, g.hyps[0].tokens);
    assert_eq!(s.stats.accepted_query_tokens, 0);
    assert!(
        s.stats.accepted_corpus_tokens > 0,
        "true-target windows must be accepted"
    );
    assert!(
        s.stats.decoder_calls < g.stats.decoder_calls,
        "corpus drafts must cut decoder calls ({} vs {})",
        s.stats.decoder_calls,
        g.stats.decoder_calls
    );
}

/// SBS with never-accepted (poisoned) corpus windows returns the exact
/// hypothesis set of SBS without the store — tokens and scores.
#[test]
fn prop_sbs_with_poisoned_store_bit_identical() {
    let mut rng = Rng::new(0xD00D);
    for case in 0..12u64 {
        let m = HashModel::new(64, 64, 32, case + 2000);
        let src = random_wrapped_src(&mut rng, 5, 18, 32);
        for n in [2usize, 4] {
            let mut base_cfg = SbsConfig::new(n, 5);
            // Leave cap room so the poisoned windows really enter rows.
            base_cfg.draft.max_drafts = 100;
            let base = sbs(&m, &src, &base_cfg).unwrap();

            let mut poisoned_cfg = base_cfg.clone();
            poisoned_cfg.corpus_drafts = vec![
                vec![BOS_ID, 9, 9, 9, 9],
                vec![PAD_ID, 4, 4, 4, 4],
                vec![BOS_ID, BOS_ID, BOS_ID],
                vec![EOS_ID, 6, 6],
            ];
            let p = sbs(&m, &src, &poisoned_cfg).unwrap();

            assert_eq!(
                base.hyps.len(),
                p.hyps.len(),
                "case {case} n {n}: hypothesis count changed"
            );
            for (a, b) in base.hyps.iter().zip(&p.hyps) {
                assert_eq!(a.tokens, b.tokens, "case {case} n {n}: tokens diverged");
                assert!(
                    (a.score - b.score).abs() < 1e-12,
                    "case {case} n {n}: scores diverged"
                );
            }
            assert_eq!(
                p.stats.accepted_corpus_tokens, 0,
                "case {case} n {n}: poisoned windows must never be accepted"
            );
        }
    }
}

/// SBS with a warm store on the copy regime: the top hypothesis stays
/// the beam-search top-1 while corpus drafts cut decoder calls.
#[test]
fn sbs_warm_store_keeps_top1_and_cuts_calls_on_copy_regime() {
    let m = CopyModel::new(96, 96, 40);
    let src = vec![
        BOS_ID, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21, EOS_ID,
    ];
    let bs = beam_search(&m, &src, 3).unwrap();
    let cold = sbs(&m, &src, &SbsConfig::new(3, 8)).unwrap();

    let store = DraftStore::new(8, 256);
    store.record(&bs.hyps[0].tokens);
    poison(&store, 40);
    let mut warm_cfg = SbsConfig::new(3, 8);
    warm_cfg.corpus_drafts = store.top_k(8);
    let warm = sbs(&m, &src, &warm_cfg).unwrap();

    assert_eq!(warm.hyps[0].tokens, bs.hyps[0].tokens, "top-1 must hold");
    assert_eq!(warm.hyps[0].tokens, cold.hyps[0].tokens);
    assert!(
        warm.stats.decoder_calls <= cold.stats.decoder_calls,
        "warm store must not cost extra calls ({} vs {})",
        warm.stats.decoder_calls,
        cold.stats.decoder_calls
    );
}

/// Cross-worker draft-store merging at the serving layer (the pool's
/// shared-cache contract): a window mined by worker A measurably raises
/// `accepted_corpus_tokens` for an identical query served by worker B
/// through the *same* `ServeCache` — and the merged store stays
/// bit-output-neutral.
#[test]
fn cross_worker_draft_merge_accelerates_and_stays_exact() {
    use rxnspec::cache::ServeCache;
    use rxnspec::coordinator::{run_worker, DecodeMode, Job, Metrics, RequestQueue};
    use rxnspec::vocab::Vocab;
    use std::sync::atomic::Ordering;
    use std::sync::{mpsc, Arc};
    use std::time::Duration;

    let vocab = Vocab::build(["CCONF", "c1ccccc1"]).unwrap();
    let shared = ServeCache::default();
    let serve_one = |backend: &CopyModel,
                     mode: DecodeMode,
                     cache: &ServeCache,
                     metrics: &Arc<Metrics>| {
        let queue = RequestQueue::new(4, Duration::from_millis(1));
        let (tx, rx) = mpsc::channel();
        queue.push(mode, Job::new("c1ccccc1".to_string(), tx));
        queue.close();
        run_worker(backend, &vocab, &queue, metrics, cache);
        rx.try_recv().expect("one reply").expect("served")
    };

    // Worker A (its own backend instance) mines the greedy completion
    // into the shared draft store.
    let worker_a = CopyModel::new(96, 96, vocab.len());
    let a = serve_one(
        &worker_a,
        DecodeMode::Greedy,
        &shared,
        &Arc::new(Metrics::default()),
    );
    assert_eq!(a.hyps[0].0, "c1ccccc1");

    // Worker B: a different backend instance, the same ServeCache. A
    // different decode mode keys a different result-cache tag (so this
    // is a real decode, not a replay), and a draft length beyond the
    // query length disables query-copy windows — every accepted draft
    // token must come from A's mined corpus window.
    let worker_b = CopyModel::new(96, 96, vocab.len());
    let metrics_b = Arc::new(Metrics::default());
    let b = serve_one(
        &worker_b,
        DecodeMode::SpecGreedy { dl: 20 },
        &shared,
        &metrics_b,
    );
    assert!(b.decoder_calls > 0, "mode-tag miss: B must decode, not replay");
    assert!(
        metrics_b.draft_accepted_corpus.load(Ordering::Relaxed) > 0,
        "worker A's mined windows must draft worker B's decode"
    );

    // Bit-output-neutrality: the merged store changed B's cost, never
    // its content.
    let worker_c = CopyModel::new(96, 96, vocab.len());
    let cold = serve_one(
        &worker_c,
        DecodeMode::SpecGreedy { dl: 20 },
        &ServeCache::disabled(),
        &Arc::new(Metrics::default()),
    );
    assert_eq!(b.hyps, cold.hyps, "shared store must not change served content");
    assert!(
        b.decoder_calls < cold.decoder_calls,
        "A's corpus windows must cut B's decoder calls ({} vs {})",
        b.decoder_calls,
        cold.decoder_calls
    );
}

/// DL=0 with a warm store still reduces SBS to standard beam search —
/// the store must not resurrect speculation the caller turned off.
#[test]
fn dl0_with_warm_store_still_equals_beam_search() {
    let mut rng = Rng::new(0xB0B0);
    let m = HashModel::new(64, 64, 32, 4242);
    let store = DraftStore::new(4, 256);
    for _ in 0..3 {
        let s = random_wrapped_src(&mut rng, 6, 18, 32);
        let g = greedy(&m, &s).unwrap();
        store.record(&g.hyps[0].tokens);
    }
    let src = random_wrapped_src(&mut rng, 6, 18, 32);
    let bs = beam_search(&m, &src, 4).unwrap();
    let mut cfg = SbsConfig::new(4, 0);
    cfg.corpus_drafts = store.top_k(8);
    let sb = sbs(&m, &src, &cfg).unwrap();
    assert_eq!(bs.hyps.len(), sb.hyps.len());
    for (a, b) in bs.hyps.iter().zip(&sb.hyps) {
        assert_eq!(a.tokens, b.tokens);
        assert!((a.score - b.score).abs() < 1e-9);
    }
}
