//! Tier-1 guard for the static-analysis pass: the live tree must be
//! lint-clean, every registered knob must round-trip through the
//! scanner, and each rule must fire on a seeded fixture violation and
//! stay quiet on the matching negative fixture.
//!
//! This file is itself walked by `lint::run_repo`, so fixtures that
//! would trip the raw-line rules (`env-read`, `knob-literal`) are
//! assembled at runtime from pieces instead of written literally.

use rxnspec::bench::json;
use rxnspec::lint::{self, Finding};

fn rule_names(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

/// The headline acceptance test: `rxnspec-lint` over the checked-out
/// repository reports nothing.
#[test]
fn live_tree_is_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let findings = lint::run_repo(&root).expect("lint walk over the repo");
    assert!(
        findings.is_empty(),
        "rxnspec-lint found {} violation(s) in the live tree:\n{}",
        findings.len(),
        findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}

/// Every declared knob survives extraction by the literal scanner and
/// resolves back to itself in the registry.
#[test]
fn registered_knobs_round_trip_through_the_scanner() {
    for k in rxnspec::knobs::REGISTRY {
        let line = format!("export {}=1", k.name);
        assert_eq!(lint::knob_tokens(&line), vec![(1, k.name.to_string())]);
        let hit = rxnspec::knobs::lookup(k.name).expect("registered knob resolves");
        assert_eq!(hit.name, k.name);
        assert!(
            lint::check_knob_literals("fixture.env", &line).is_empty(),
            "{} must not be flagged",
            k.name
        );
    }
}

#[test]
fn float_contract_fires_only_in_kernel_zones() {
    let bad = "pub fn f(a: f32, b: f32, c: f32) -> f32 {\n    a.mul_add(b, c)\n}\n";
    let hits = lint::scan_rust_source("rust/src/kernels/fixture.rs", bad);
    assert_eq!(rule_names(&hits), ["float-contract"]);
    assert_eq!(hits[0].line, 2);

    // Same token outside the bit-identity zones is legal.
    assert!(lint::scan_rust_source("rust/src/coordinator/fixture.rs", bad).is_empty());
    // Mentions in comments and strings are blanked before matching.
    let doc = "// mul_add is forbidden here\nlet s = \"mul_add\";\n";
    assert!(lint::scan_rust_source("rust/src/decoding/fixture.rs", doc).is_empty());
}

#[test]
fn lock_discipline_flags_raw_lock_outside_batcher() {
    let bad = "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n";
    let hits = lint::scan_rust_source("rust/src/trace/fixture.rs", bad);
    assert_eq!(rule_names(&hits), ["lock-discipline"]);
    assert_eq!(hits[0].line, 2);

    // batcher.rs defines lock_ok and is the one allowed caller.
    assert!(lint::scan_rust_source("rust/src/coordinator/batcher.rs", bad).is_empty());
    // An explicit waiver on the preceding line silences the rule.
    let waived = "// lint:allow(lock-discipline) — fixture.\nlet g = m.lock();\n";
    assert!(lint::scan_rust_source("rust/src/trace/fixture.rs", waived).is_empty());
}

#[test]
fn unsafe_audit_requires_an_adjacent_safety_comment() {
    let bad = "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let hits = lint::scan_rust_source("rust/src/model/fixture.rs", bad);
    assert_eq!(rule_names(&hits), ["unsafe-audit"]);
    assert_eq!(hits[0].line, 2);

    let documented = "// SAFETY: fixture pointer is valid for reads.\n\
                      pub fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
    assert!(lint::scan_rust_source("rust/src/model/fixture.rs", documented).is_empty());

    // The safety comment may sit above an attribute/comment block.
    let through_attrs = "// SAFETY: guarded by runtime detection.\n\
                         #[inline]\n\
                         fn g(p: *const u8) -> u8 { unsafe { *p } }\n";
    assert!(lint::scan_rust_source("rust/src/model/fixture.rs", through_attrs).is_empty());

    // A `# Safety` doc section counts for `pub unsafe fn` items.
    let doc_section = "/// # Safety\n\
                       /// Caller upholds the aliasing rules.\n\
                       pub unsafe fn h() {}\n";
    assert!(lint::scan_rust_source("rust/src/model/fixture.rs", doc_section).is_empty());
}

#[test]
fn env_read_flags_direct_reads_outside_the_registry() {
    // Assembled from pieces so this test file's own raw lines never
    // contain the pattern the rule greps for.
    let read = format!("std::env::{}(\"{}_THREADS\")", "var", "RXNSPEC");
    let bad = format!("fn f() -> Option<String> {{\n    {read}.ok()\n}}\n");
    let hits = lint::scan_rust_source("rust/src/bench_fixture.rs", &bad);
    assert_eq!(rule_names(&hits), ["env-read"]);
    assert_eq!(hits[0].line, 2);

    let os_read = format!("std::env::{}(\"{}_DATA\")", "var_os", "RXNSPEC");
    let bad_os = format!("fn f() {{ let _ = {os_read}; }}\n");
    assert_eq!(
        rule_names(&lint::scan_rust_source("rust/src/bench_fixture.rs", &bad_os)),
        ["env-read"]
    );

    // knobs.rs is where the reads are supposed to live.
    assert!(lint::scan_rust_source("rust/src/knobs.rs", &bad).is_empty());
}

#[test]
fn fault_site_flags_unregistered_fire_literals() {
    let bad = "pub fn f() -> anyhow::Result<()> {\n    crate::faults::fire(\"bogus.site\")\n}\n";
    let hits = lint::scan_rust_source("rust/src/coordinator/fixture.rs", bad);
    assert_eq!(rule_names(&hits), ["fault-site"]);
    assert_eq!(hits[0].line, 2);
    assert!(hits[0].msg.contains("bogus.site"));

    let good = "pub fn f() -> anyhow::Result<()> {\n    crate::faults::fire(\"worker.tick\")\n}\n";
    assert!(lint::scan_rust_source("rust/src/coordinator/fixture.rs", good).is_empty());

    let infallible = "crate::faults::fire_infallible(\"worker.wedge\");\n";
    assert!(lint::scan_rust_source("rust/src/coordinator/fixture.rs", infallible).is_empty());

    // Test code (outside rust/src/) may name arbitrary sites.
    assert!(lint::scan_rust_source("rust/tests/fixture.rs", bad).is_empty());
}

#[test]
fn knob_literal_flags_undeclared_names_and_honours_waivers() {
    let bogus = ["RXNSPEC", "_FIXTURE_ONLY"].concat();
    let bad = format!("let _ = \"{bogus}\";\n");
    let hits = lint::check_knob_literals("rust/src/fixture.rs", &bad);
    assert_eq!(rule_names(&hits), ["knob-literal"]);
    assert!(hits[0].msg.contains(&bogus));

    let waived = format!("// lint:allow(knob-literal) — fixture.\nlet _ = \"{bogus}\";\n");
    assert!(lint::check_knob_literals("rust/src/fixture.rs", &waived).is_empty());

    // Wildcard mentions in prose are not knob names.
    assert!(lint::knob_tokens("every RXNSPEC_* knob is declared once").is_empty());
    // Mid-identifier hits do not count as a token start.
    let glued = format!("NOT{bogus}");
    assert!(lint::knob_tokens(&glued).is_empty());
}

#[test]
fn stripper_preserves_line_numbers_and_blanks_literals() {
    let src = "let s = \"a\\\n b\"; // tail\nlet t = 'x';\n/* multi\nline */ let u = 1;\n";
    let lines = lint::strip_rust(src);
    assert_eq!(lines.len(), src.lines().count());
    assert!(lines[4].contains("let u = 1;"));
    assert!(!lines[1].contains("tail"));

    let raw = "let r = r#\"inner \"quoted\" text\"#; after();\n";
    let stripped = lint::strip_rust(raw);
    assert!(stripped[0].contains("after();"));
    assert!(!stripped[0].contains("inner"));

    // Lifetimes survive stripping; char literals do not.
    let lt = "fn f<'a>(x: &'a str) -> char { 'q' }\n";
    let s = lint::strip_rust(lt);
    assert!(s[0].contains("<'a>"));
    assert!(!s[0].contains("'q'"));
}

#[test]
fn glob_match_star_semantics() {
    assert!(lint::glob_match("simd_level", "simd_level"));
    assert!(lint::glob_match("resil_*", "resil_drain_ms"));
    assert!(lint::glob_match("gemm_*_ns", "gemm_f32_256_ns"));
    assert!(lint::glob_match("*", "anything"));
    assert!(!lint::glob_match("gemm_*_ns", "gemm_f32_gflops"));
    assert!(!lint::glob_match("resil_*", "serve_rps"));
    assert!(!lint::glob_match("simd_level", "simd_level_2"));
}

#[test]
fn bench_schema_flags_undeclared_metric_keys() {
    let doc = json::parse(
        r#"{"meta": {"schema_keys": ["gemm_*", "simd_level"], "schema_row_keys": ["tok_s"]},
            "kernel_micro": {"gemm_f32_ns": 1.0, "simd_level": "avx2", "rogue_metric": 2.0},
            "table2_greedy": {"BS beam5": {"tok_s": 3.0, "rogue_row": 4.0}}}"#,
    )
    .expect("fixture json parses");
    let hits = lint::check_bench_schema(&doc, "fixture.json");
    let msgs: Vec<&str> = hits.iter().map(|f| f.msg.as_str()).collect();
    assert_eq!(hits.len(), 2, "exactly the two rogue keys: {msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("kernel_micro.rogue_metric")));
    assert!(msgs.iter().any(|m| m.contains("table2_greedy.BS beam5.rogue_row")));

    let clean = json::parse(
        r#"{"meta": {"schema_keys": ["gemm_*"], "schema_row_keys": ["tok_s"]},
            "kernel_micro": {"gemm_f32_ns": 1.0}}"#,
    )
    .expect("fixture json parses");
    assert!(lint::check_bench_schema(&clean, "fixture.json").is_empty());

    let no_schema = json::parse(r#"{"meta": {"note": "x"}}"#).expect("fixture json parses");
    let hits = lint::check_bench_schema(&no_schema, "fixture.json");
    assert_eq!(rule_names(&hits), ["bench-schema"]);
}

#[test]
fn finding_display_is_file_line_rule_msg() {
    let f = Finding {
        rule: "env-read",
        file: "rust/src/x.rs".into(),
        line: 7,
        msg: "direct read".into(),
    };
    assert_eq!(f.to_string(), "rust/src/x.rs:7: env-read: direct read");
}
