//! The artifact-backed KV-cached [`DecoderSession`]: per-row host K/V
//! mirrors, bucket routing over the `deccache` artifact grid, and
//! device-buffer input reuse threaded call to call.
//!
//! The `deccache` artifact (lowered by `python/compile/aot.py`) has the
//! signature
//!
//! ```text
//! (tgt_window[EB,W], pos[EB,W], tgt_pad[EB,W], mem[EB,S,D], mem_pad[EB,S],
//!  k_cache[L,EB,T,D], v_cache[L,EB,T,D], cache_len[EB], *weights)
//!     → (logp_window[EB,W,V], k_cache', v_cache')
//! ```
//!
//! where `W` is the appended-window bucket, `T` the full decoder window
//! (`t_len`, the cache capacity) and `L` the decoder layer count. The
//! window is **right-padded** (real tokens at slots `0..m`), positions are
//! explicit, and the returned caches are the inputs with the window's K/V
//! written at slots `cache_len..cache_len+m` — everything else untouched
//! and masked, so a *rewind is purely host-side*: `truncate` just lowers
//! the logical length, stale cache slots beyond it are masked out of
//! every later attention and overwritten by the next `extend`. That
//! host-side-rewind property is what makes `fork`/`truncate` O(1) against
//! a device-resident cache.
//!
//! [`CachedPjrtSession`] drives any [`DeccacheExec`] — the production
//! implementation uploads buffers and runs the PJRT executable
//! (`runtime::pjrt::PjrtDeccacheExec`); the test/bench implementation
//! mirrors the artifact semantics with the reference kernels
//! (`testutil::RefDeccacheExec`), so the session machinery is
//! property-tested bit-exactly against the stateless oracle even though
//! the offline build cannot execute real artifacts.
//!
//! # Segmented passes
//!
//! One `extend` may append more tokens to a row than the largest window
//! bucket holds (e.g. a deep-rewind heal pushing a full draft-verify
//! window one past the grid). The session then advances every pending
//! row by up to the largest bucket per **pass**, running sequential
//! executor calls — later segments read the earlier segments' K/V from
//! the updated caches — instead of hard-erroring on traffic the
//! stateless fallback would serve.
//!
//! # Device-buffer reuse
//!
//! The steady decode loop extends the *same rows in the same order* every
//! tick, so the previous call's output K/V buffers are exactly the next
//! call's inputs. When the executor reports its outputs stayed
//! device-resident and the lane signature `(ordered row ids, EB bucket)`
//! is unchanged, `extend` passes `kv_host: None` and the executor feeds
//! its retained buffers back — skipping the `[L,EB,T,D]` host→device
//! upload, the dominant per-call transfer. Host mirrors stay authoritative
//! (outputs are downloaded each call), so any signature break — fork,
//! release, re-bucketing, chunking — falls back to a fresh upload with no
//! correctness edge.
//!
//! # Accounting
//!
//! Same contract as the reference `CachedSession`: `tokens_computed`
//! counts window positions actually run, `tokens_reused` counts prefix
//! positions served from the cache, so `benches/table2_greedy.rs`'s
//! `recomp_tok` drops from ~L/2 to ~1 once artifacts carry `deccache`
//! rows. Per-row successor log-probs are retained as a bounded suffix
//! (`RXNSPEC_LP_RETAIN`, default 64 positions); a truncate that rewinds
//! past the suffix is healed by re-submitting one committed token — the
//! recompute reads the same cached K/V prefix, so it is exact.

use std::borrow::Cow;
use std::sync::Arc;

use anyhow::Result;

use crate::decoding::session::{
    assemble_window_row, lp_retention_from_env, needed_window, rollback_for_extend_kv,
    trim_lp_suffix,
};
use crate::decoding::{
    ArenaConfig, ArenaStats, DecoderSession, KvArena, LogProbs, Memory, ModelDims, SessionStats,
    TableId,
};
use crate::trace::Phase;
use crate::trace_span;
use crate::vocab::PAD_ID;

/// One cache-shaped decoder invocation, padded to its `(W, EB)` bucket.
/// All matrices are row-major and flattened.
pub struct DeccacheCall<'a> {
    /// Window bucket (columns of `tgt`/`pos`/`tgt_pad`).
    pub w: usize,
    /// Effective-batch bucket (lanes; trailing lanes may be padding).
    pub eb: usize,
    /// Real (non-padding) lanes in this call — executors log this, not
    /// the padded `eb`, so call-log row counts stay comparable with the
    /// stateless `decode` path's.
    pub n_rows: usize,
    /// `[EB, W]` appended tokens, right-padded with `PAD_ID`.
    pub tgt: Vec<i64>,
    /// `[EB, W]` absolute position ids (`cache_len + slot` on real slots).
    pub pos: Vec<i64>,
    /// `[EB, W]` 1.0 on real slots.
    pub tgt_pad: Vec<f32>,
    /// `[EB]` committed prefix length per lane.
    pub cache_len: Vec<i64>,
    /// Host K/V to upload (`[L, EB, T, D]` each), or `None` to reuse the
    /// executor's device-resident output buffers from the previous call
    /// (the caller guarantees the lane layout is unchanged).
    pub kv_host: Option<(Vec<f32>, Vec<f32>)>,
    /// Session memory; `mem_rows[lane]` picks the row each lane attends.
    pub mem: &'a Memory,
    pub mem_rows: &'a [usize],
}

/// A completed `deccache` invocation.
pub struct DeccacheOut {
    /// `[EB, W, V]` successor log-probs (pad slots undefined).
    pub logp: Vec<f32>,
    /// `[L, EB, T, D]` updated key cache (host copy).
    pub k_cache: Vec<f32>,
    /// `[L, EB, T, D]` updated value cache (host copy).
    pub v_cache: Vec<f32>,
    /// Whether the executor retained the output K/V on-device, making the
    /// next call eligible for `kv_host: None` input reuse.
    pub device_resident: bool,
}

/// An executor of `deccache` artifact calls. Implemented by the PJRT
/// runtime (real artifacts) and by the reference-kernel mirror in
/// `testutil` (property tests, benches).
pub trait DeccacheExec {
    fn dims(&self) -> ModelDims;

    /// Decoder layer count `L` of the K/V cache shape.
    fn n_layers(&self) -> usize;

    /// The registered `(window, effective-batch)` buckets, ascending.
    fn grid(&self) -> Vec<(usize, usize)>;

    fn run(&self, call: DeccacheCall<'_>) -> Result<DeccacheOut>;
}

/// Shared state of one session row: committed tokens, per-layer host K/V
/// mirrors (`[L, T, D]` flat, slots `< len` valid) and the retained
/// log-prob suffix. Forks share it through an `Arc` (copy-on-write: the
/// first mutating `extend` after a fork clones exactly once — the same
/// pattern as the reference session's `RowCache`, and what keeps
/// beam/SBS forking cheap against megabyte-sized mirrors).
#[derive(Clone)]
struct PjRowCache {
    /// Token history; the prefix `0..len` is the committed sequence
    /// (`truncate` only lowers the row's `len`, the tail is trimmed
    /// lazily by the next `extend`).
    tokens: Vec<i64>,
    /// `[L, T, D]` flattened self-attention key mirror. Empty in paged
    /// mode — the mirror lives in the session arena's pages instead.
    k: Vec<f32>,
    /// `[L, T, D]` flattened value mirror (empty in paged mode).
    v: Vec<f32>,
    /// Retained suffix of per-position successor log-probs,
    /// `[retained, V]` starting at absolute position `lp_start`.
    lp: Vec<f32>,
    lp_start: usize,
}

struct PjRow {
    mem_row: usize,
    /// Logical committed length (`truncate` is O(1): only this moves).
    len: usize,
    cache: Arc<PjRowCache>,
    /// Paged mode: this row's page table in the session arena. Pages
    /// hold the `[L, T, D]` mirror chunked by position — within a page,
    /// layer `l` slot `s` lives at `(l·P + s)·D`, so gather/scatter move
    /// contiguous `run·D`-float spans per layer per page.
    table: Option<TableId>,
}

/// See module docs.
pub struct CachedPjrtSession<E: DeccacheExec> {
    exec: E,
    memory: Memory,
    rows: Vec<Option<PjRow>>,
    stats: SessionStats,
    lp_retain: usize,
    grid: Vec<(usize, usize)>,
    n_layers: usize,
    dims: ModelDims,
    /// `(ordered row ids, EB bucket)` of the last single-chunk call whose
    /// output K/V the executor still holds on-device.
    last_sig: Option<(Vec<usize>, usize)>,
    kv_uploads_skipped: u64,
    /// Page-pooled host-mirror residency (`RXNSPEC_ARENA`; `None` =
    /// dense per-row mirrors, the fallback and parity oracle).
    arena: Option<KvArena>,
}

impl<E: DeccacheExec> CachedPjrtSession<E> {
    pub fn new(exec: E, memory: Memory) -> CachedPjrtSession<E> {
        CachedPjrtSession::with_arena(exec, memory, ArenaConfig::from_env())
    }

    /// Open a session with an explicit arena mode, bypassing the
    /// `RXNSPEC_ARENA` environment knobs (tests drive paged and dense
    /// sessions side by side this way without touching process env).
    pub fn with_arena(
        exec: E,
        memory: Memory,
        arena: Option<ArenaConfig>,
    ) -> CachedPjrtSession<E> {
        let batch = memory.batch;
        let dims = exec.dims();
        let grid = exec.grid();
        assert!(!grid.is_empty(), "deccache session requires a non-empty artifact grid");
        let n_layers = exec.n_layers();
        let arena = arena.map(|cfg| KvArena::new(&cfg, n_layers * dims.d_model));
        CachedPjrtSession {
            exec,
            memory,
            rows: Vec::new(),
            // Same encoder accounting as every session: the memory came
            // from one encode call over `batch` source rows.
            stats: SessionStats {
                encode_calls: 1,
                packed_src_rows: batch,
                ..SessionStats::default()
            },
            lp_retain: lp_retention_from_env(),
            grid,
            n_layers,
            dims,
            last_sig: None,
            kv_uploads_skipped: 0,
            arena,
        }
    }

    /// How many `[L,EB,T,D]` host→device K/V uploads the device-resident
    /// reuse path elided so far.
    pub fn kv_uploads_skipped(&self) -> u64 {
        self.kv_uploads_skipped
    }

    /// Arena residency counters, `None` on the dense path.
    pub fn arena_stats(&self) -> Option<ArenaStats> {
        self.arena.as_ref().map(|a| a.stats())
    }

    /// Cap the per-row log-prob retention (positions; min 1) — same knob
    /// as the reference session's. Rewinds past the cap are healed by
    /// re-submitting one committed token, exactly.
    pub fn set_lp_retention(&mut self, positions: usize) {
        self.lp_retain = positions.max(1);
    }

    fn row(&self, row: usize) -> &PjRow {
        self.rows[row].as_ref().expect("released session row")
    }

    /// Smallest window bucket ≥ `need` (else the largest available).
    fn window_bucket(&self, need: usize) -> usize {
        self.grid
            .iter()
            .map(|&(w, _)| w)
            .filter(|&w| w >= need)
            .min()
            .unwrap_or_else(|| self.grid.iter().map(|&(w, _)| w).max().unwrap())
    }

    /// Smallest EB bucket ≥ `n` within window `w` (else the largest).
    fn eb_bucket(&self, w: usize, n: usize) -> usize {
        self.grid
            .iter()
            .filter(|&&(ww, _)| ww == w)
            .map(|&(_, b)| b)
            .find(|&b| b >= n)
            .unwrap_or_else(|| {
                self.grid
                    .iter()
                    .filter(|&&(ww, _)| ww == w)
                    .map(|&(_, b)| b)
                    .max()
                    .unwrap()
            })
    }

    /// Largest EB bucket registered for window `w` (which must be a
    /// bucket returned by [`Self::window_bucket`]).
    fn max_eb_for(&self, w: usize) -> usize {
        self.grid.iter().filter(|&&(ww, _)| ww == w).map(|&(_, b)| b).max().unwrap()
    }
}

impl<E: DeccacheExec> DecoderSession for CachedPjrtSession<E> {
    fn dims(&self) -> ModelDims {
        self.dims
    }

    fn memory(&self) -> &Memory {
        &self.memory
    }

    fn append_memory(&mut self, extra: &Memory) -> usize {
        assert_eq!(extra.s_len, self.memory.s_len, "memory s_len mismatch");
        assert_eq!(extra.d_model, self.memory.d_model, "memory width mismatch");
        let base = self.memory.batch;
        self.memory.data.extend_from_slice(&extra.data);
        self.memory.pad.extend_from_slice(&extra.pad);
        self.memory.batch += extra.batch;
        self.stats.encode_calls += 1;
        self.stats.packed_src_rows += extra.batch;
        base
    }

    fn new_row(&mut self, mem_row: usize) -> usize {
        assert!(mem_row < self.memory.batch, "memory row out of range");
        let table = self.arena.as_mut().map(|a| a.new_table());
        let sz = if table.is_some() {
            0 // Mirror lives in arena pages, allocated as the row grows.
        } else {
            self.n_layers * self.dims.t_len * self.dims.d_model
        };
        self.rows.push(Some(PjRow {
            mem_row,
            len: 0,
            cache: Arc::new(PjRowCache {
                tokens: Vec::new(),
                k: vec![0f32; sz],
                v: vec![0f32; sz],
                lp: Vec::new(),
                lp_start: 0,
            }),
            table,
        }));
        self.rows.len() - 1
    }

    fn fork(&mut self, row: usize) -> usize {
        let src = self.row(row);
        let mut copy = PjRow {
            mem_row: src.mem_row,
            len: src.len,
            cache: Arc::clone(&src.cache),
            table: src.table,
        };
        if let Some(t) = copy.table {
            // O(pages) pointer work: clone the page table and bump
            // refcounts; blob bytes are copied only on divergent write.
            copy.table = Some(self.arena.as_mut().expect("table without an arena").fork(t));
        }
        self.rows.push(Some(copy));
        self.rows.len() - 1
    }

    fn truncate(&mut self, row: usize, len: usize) {
        // Host-side rewind: stale cache slots ≥ len stay in both the
        // mirrors and any device-resident buffer — masked by `cache_len`
        // and overwritten by the next extend — so this is O(1) and does
        // NOT invalidate device reuse. Paged mode additionally drops
        // whole pages past the new tail back to the free list (O(pages
        // released); the device-reuse signature is still untouched).
        let r = self.rows[row].as_mut().expect("released session row");
        assert!(len <= r.len, "truncate beyond row length");
        r.len = len;
        if let (Some(arena), Some(t)) = (self.arena.as_mut(), r.table) {
            arena.truncate(t, len);
        }
    }

    fn release(&mut self, row: usize) {
        if let Some(r) = self.rows[row].take() {
            if let (Some(arena), Some(t)) = (self.arena.as_mut(), r.table) {
                arena.release(t);
            }
        }
    }

    fn row_len(&self, row: usize) -> usize {
        self.row(row).len
    }

    fn extend(&mut self, deltas: &[(usize, &[i64])]) -> Result<LogProbs> {
        crate::faults::fire("pjrt.session")?;
        let (t_len, d, v) = (self.dims.t_len, self.dims.d_model, self.dims.vocab);
        self.stats.extend_calls += 1;
        self.stats.packed_rows += deltas.len();

        // Validate everything before mutating anything.
        for &(row, toks) in deltas {
            let r = self.rows[row].as_ref().expect("released session row");
            anyhow::ensure!(
                r.len + toks.len() <= t_len,
                "row length {} exceeds window {t_len}",
                r.len + toks.len()
            );
        }

        // Pin every batch row's page table for the whole extend: one
        // row's page allocation must never evict a sibling whose pages
        // this same pass is about to read or write.
        if let Some(arena) = self.arena.as_mut() {
            for &(row, _) in deltas {
                let r = self.rows[row].as_ref().expect("released session row");
                if let Some(t) = r.table {
                    arena.set_pinned(t, true);
                }
            }
        }

        // Roll token/log-prob mirrors back to the submit point. A deep
        // truncate may have rewound past the retained log-prob suffix;
        // heal by re-submitting the last committed token (exact: the
        // recompute reads the same cached K/V prefix). An evicted paged
        // row deepens the resume point to its surviving residency
        // (possibly zero) — the recompute rehydrates its pages exactly.
        struct Prep<'t> {
            row: usize,
            /// Submit base: `cache_len` of this row's first segment.
            start: usize,
            /// The full job (heal token + delta tokens).
            toks: Cow<'t, [i64]>,
            /// Segmented progress through `toks`.
            done: usize,
            len_before: usize,
            delta_len: usize,
        }
        let mut prep: Vec<Prep<'_>> = Vec::with_capacity(deltas.len());
        for &(row, toks) in deltas {
            let r = self.rows[row].as_mut().expect("released session row");
            let len_before = r.len;
            let kv_valid = match (self.arena.as_ref(), r.table) {
                (Some(a), Some(t)) => a.positions(t),
                _ => len_before,
            };
            // Unshare (one clone if forked) and roll back to the submit
            // point via the shared session-contract helper. The dense
            // K/V mirrors need no rollback: stale slots are masked by
            // `cache_len` and overwritten in place.
            let cache = Arc::make_mut(&mut r.cache);
            let (start, job_toks) = rollback_for_extend_kv(
                &mut cache.tokens,
                &mut cache.lp,
                &mut cache.lp_start,
                len_before,
                kv_valid,
                toks,
                v,
            );
            cache.tokens.extend_from_slice(&job_toks);
            if let (Some(arena), Some(t)) = (self.arena.as_mut(), r.table) {
                if kv_valid < len_before {
                    // Marker span: the actual recompute cost lands in the
                    // extend passes below; payload = positions rebuilt.
                    let _heal = trace_span!(Phase::ArenaHeal, (len_before - start) as u64);
                    arena.note_rehydrated(len_before - start);
                }
                // Roll the page table back and make the whole job range
                // writable up front (COW-unshare the tail page, allocate)
                // — segmented passes then fill the pages progressively.
                arena.truncate(t, start);
                arena.prepare_append(t, start, job_toks.len());
            }
            self.stats.tokens_computed += job_toks.len();
            self.stats.tokens_reused += start;
            prep.push(Prep {
                row,
                start,
                toks: job_toks,
                done: 0,
                len_before,
                delta_len: toks.len(),
            });
        }

        // Segmented executor passes (see module docs): every pass
        // advances each pending row by up to the largest window bucket;
        // rows with no appended tokens are served entirely from their
        // retained log-prob suffix. One window bucket per pass (like
        // `decode`'s one bucket per call), chunked by *that window's*
        // largest EB so a non-rectangular grid can never route a chunk
        // into a batch bucket it doesn't have.
        let max_w = self.grid.iter().map(|&(w, _)| w).max().unwrap();
        loop {
            let lanes: Vec<usize> =
                (0..prep.len()).filter(|&i| prep[i].done < prep[i].toks.len()).collect();
            if lanes.is_empty() {
                break;
            }
            let need_w = lanes
                .iter()
                .map(|&i| (prep[i].toks.len() - prep[i].done).min(max_w))
                .max()
                .unwrap();
            let w = {
                let _rt = trace_span!(Phase::BucketRoute, need_w as u64);
                self.window_bucket(need_w)
            };
            let w_max_eb = self.max_eb_for(w);
            let single_chunk = lanes.len() <= w_max_eb;
            for chunk in lanes.chunks(w_max_eb) {
                let n = chunk.len();
                let eb = self.eb_bucket(w, n);
                anyhow::ensure!(n <= eb, "extend chunk {n} exceeds largest eb bucket {eb}");

                let mut tgt = vec![PAD_ID; eb * w];
                let mut pos = vec![0i64; eb * w];
                let mut pad = vec![0f32; eb * w];
                let mut cache_len = vec![0i64; eb];
                let mut mem_rows = vec![0usize; eb];
                let mut segs = vec![0usize; n];
                for (li, &pi) in chunk.iter().enumerate() {
                    let p = &prep[pi];
                    let base = p.start + p.done;
                    let seg = (p.toks.len() - p.done).min(w);
                    segs[li] = seg;
                    for j in 0..seg {
                        tgt[li * w + j] = p.toks[p.done + j];
                        pos[li * w + j] = (base + j) as i64;
                        pad[li * w + j] = 1.0;
                    }
                    cache_len[li] = base as i64;
                    mem_rows[li] = self.row(p.row).mem_row;
                }

                // Device-buffer input reuse: same ordered rows in the
                // same EB bucket as the previous (single-chunk,
                // device-resident) call means the executor's retained
                // output K/V *are* this call's inputs — skip the
                // [L,EB,T,D] upload. Later segments of one oversized
                // extend qualify too.
                let ids: Vec<usize> = chunk.iter().map(|&pi| prep[pi].row).collect();
                let sig_match = match &self.last_sig {
                    Some((pids, peb)) => *pids == ids && *peb == eb,
                    None => false,
                };
                let reuse = single_chunk && sig_match;
                let kv_host = if reuse {
                    let _ru = trace_span!(Phase::KvReuse, (self.n_layers * eb * t_len * d) as u64);
                    self.kv_uploads_skipped += 1;
                    None
                } else {
                    let _up = trace_span!(
                        Phase::KvUpload,
                        (2 * self.n_layers * eb * t_len * d * 4) as u64
                    );
                    let sz = self.n_layers * eb * t_len * d;
                    let mut k = vec![0f32; sz];
                    let mut vv = vec![0f32; sz];
                    for (li, &pi) in chunk.iter().enumerate() {
                        let p = &prep[pi];
                        let r = self.rows[p.row].as_ref().unwrap();
                        let take_pos = p.start + p.done;
                        match (self.arena.as_ref(), r.table) {
                            (Some(arena), Some(table)) => {
                                // Gather the valid prefix from arena
                                // pages: per layer, each page contributes
                                // one contiguous `run·D`-float span.
                                let pp = arena.page_positions();
                                let pages = arena.table_pages(table);
                                for l in 0..self.n_layers {
                                    let dst = (l * eb + li) * t_len * d;
                                    let lbase = l * pp * d;
                                    let mut pos0 = 0usize;
                                    for &pid in pages {
                                        if pos0 >= take_pos {
                                            break;
                                        }
                                        let run = (take_pos - pos0).min(pp);
                                        k[dst + pos0 * d..dst + (pos0 + run) * d]
                                            .copy_from_slice(
                                                &arena.page_k(pid)[lbase..lbase + run * d],
                                            );
                                        vv[dst + pos0 * d..dst + (pos0 + run) * d]
                                            .copy_from_slice(
                                                &arena.page_v(pid)[lbase..lbase + run * d],
                                            );
                                        pos0 += run;
                                    }
                                }
                            }
                            _ => {
                                let take = take_pos * d;
                                for l in 0..self.n_layers {
                                    let src = l * t_len * d;
                                    let dst = (l * eb + li) * t_len * d;
                                    k[dst..dst + take]
                                        .copy_from_slice(&r.cache.k[src..src + take]);
                                    vv[dst..dst + take]
                                        .copy_from_slice(&r.cache.v[src..src + take]);
                                }
                            }
                        }
                    }
                    Some((k, vv))
                };

                // Pessimistically drop the reuse signature before
                // running: a reuse-path call consumes the executor's
                // retained buffers even when it fails, so a stale
                // signature after an error would wedge every later
                // extend on the same lanes. Restored below on success.
                self.last_sig = None;
                let out = self.exec.run(DeccacheCall {
                    w,
                    eb,
                    n_rows: n,
                    tgt,
                    pos,
                    tgt_pad: pad,
                    cache_len,
                    kv_host,
                    mem: &self.memory,
                    mem_rows: &mem_rows,
                })?;
                anyhow::ensure!(
                    out.logp.len() == eb * w * v
                        && out.k_cache.len() == self.n_layers * eb * t_len * d
                        && out.v_cache.len() == out.k_cache.len(),
                    "deccache executor returned mis-shaped outputs"
                );

                // Scatter the segment's K/V and log-probs back into the
                // row mirrors (only slots base..base+seg changed).
                for (li, &pi) in chunk.iter().enumerate() {
                    let seg = segs[li];
                    let base = prep[pi].start + prep[pi].done;
                    let r = self.rows[prep[pi].row].as_mut().unwrap();
                    let cache = Arc::make_mut(&mut r.cache);
                    match (self.arena.as_mut(), r.table) {
                        (Some(arena), Some(table)) => {
                            // The pages covering base.. were unshared by
                            // `prepare_append`; write per layer in
                            // page-bounded contiguous runs.
                            let pp = arena.page_positions();
                            for l in 0..self.n_layers {
                                let mut pos = base;
                                while pos < base + seg {
                                    let pid = arena.table_pages(table)[pos / pp];
                                    let slot = pos % pp;
                                    let run = (base + seg - pos).min(pp - slot);
                                    let lb = (l * pp + slot) * d;
                                    let src = ((l * eb + li) * t_len + pos) * d;
                                    let (pk, pv) = arena.page_kv_mut(pid);
                                    pk[lb..lb + run * d]
                                        .copy_from_slice(&out.k_cache[src..src + run * d]);
                                    pv[lb..lb + run * d]
                                        .copy_from_slice(&out.v_cache[src..src + run * d]);
                                    pos += run;
                                }
                            }
                        }
                        _ => {
                            for l in 0..self.n_layers {
                                let src = ((l * eb + li) * t_len + base) * d;
                                let dst = (l * t_len + base) * d;
                                cache.k[dst..dst + seg * d]
                                    .copy_from_slice(&out.k_cache[src..src + seg * d]);
                                cache.v[dst..dst + seg * d]
                                    .copy_from_slice(&out.v_cache[src..src + seg * d]);
                            }
                        }
                    }
                    for j in 0..seg {
                        let src = (li * w + j) * v;
                        cache.lp.extend_from_slice(&out.logp[src..src + v]);
                    }
                    prep[pi].done += seg;
                }
                self.last_sig = if single_chunk && out.device_resident {
                    Some((ids, eb))
                } else {
                    None
                };
            }
        }

        // Window sizing and assembly over logical lengths — the same
        // contract as every session: the stored window covers positions
        // [max(len_before-1, 0), len_after-1] of each row.
        let mut lens = Vec::with_capacity(prep.len());
        let mut window = 1usize;
        for p in &prep {
            let len_after = p.len_before + p.delta_len;
            self.rows[p.row].as_mut().unwrap().len = len_after;
            lens.push(len_after);
            window = window.max(needed_window(p.len_before, p.delta_len));
        }
        let mut data = vec![0f32; prep.len() * window * v];
        for (ri, p) in prep.iter().enumerate() {
            let r = self.rows[p.row].as_ref().unwrap();
            assemble_window_row(&mut data, ri, window, v, r.len, &r.cache.lp, r.cache.lp_start);
        }
        for p in &prep {
            let r = self.rows[p.row].as_mut().unwrap();
            let cache = Arc::make_mut(&mut r.cache);
            let retained = trim_lp_suffix(&mut cache.lp, &mut cache.lp_start, v, self.lp_retain);
            self.stats.lp_high_water = self.stats.lp_high_water.max(retained);
            if let (Some(arena), Some(t)) = (self.arena.as_mut(), r.table) {
                arena.set_pinned(t, false);
            }
        }
        Ok(LogProbs::new_windowed(data, lens, t_len, v, window))
    }

    fn stats(&self) -> SessionStats {
        let mut stats = self.stats;
        if let Some(arena) = self.arena.as_ref() {
            let a = arena.stats();
            stats.kv_pages_resident = a.pages_resident;
            stats.kv_pages_high_water = a.pages_high_water;
            stats.kv_page_bytes = a.page_bytes;
            stats.arena_evictions = a.evictions;
            stats.fork_pages_copied = a.fork_pages_copied;
        }
        stats
    }
}
