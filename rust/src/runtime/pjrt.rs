//! PJRT backend: loads `artifacts/*.hlo.txt`, compiles one executable per
//! (entrypoint, batch bucket), and serves `encode`/`decode` by padding the
//! request into the smallest bucket that fits.
//!
//! Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits 64-bit
//! instruction ids that the crate's XLA (xla_extension 0.5.1) rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Weights are **arguments**, not baked constants (aot.py keeps artifact
//! text small): the RXW1 checkpoint is uploaded once into device-resident
//! `PjRtBuffer`s, in lexicographic flat-key order — the exact order aot.py
//! lowered them in — and appended to every call.
//!
//! Decoder rows are right-aligned into the fixed `[EB, T]` window — the
//! paper's `padLeft` — with explicit position ids `col - pad_offset`, so
//! one compiled executable serves every mix of prefix and draft lengths.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::decoding::{
    Backend, DecoderRow, DecoderSession, LogProbs, Memory, ModelDims, StatelessSession,
};
use crate::model::{Config, Weights};
use crate::vocab::PAD_ID;

/// Lazily compiled executable: artifact path + compile-on-first-use slot.
/// Loading a backend registers ~21 artifacts per task; most runs touch a
/// handful of buckets, so eager compilation would waste tens of seconds
/// of startup.
struct LazyExe {
    path: std::path::PathBuf,
    exe: std::cell::OnceCell<xla::PjRtLoadedExecutable>,
}

impl LazyExe {
    fn get(&self, client: &xla::PjRtClient) -> Result<&xla::PjRtLoadedExecutable> {
        if self.exe.get().is_none() {
            let exe = compile(client, &self.path)?;
            let _ = self.exe.set(exe);
        }
        Ok(self.exe.get().unwrap())
    }
}

/// Trailing-columns window of decfast artifacts (matches aot.py's
/// DECFAST_WINDOW). Calls whose consumers might read earlier positions
/// must take the full `dec` path.
pub const DECFAST_WINDOW: usize = 16;

/// Registered artifacts for one task (`fwd` or `retro`).
pub struct ArtifactSet {
    /// batch-bucket → encoder executable
    enc: BTreeMap<usize, LazyExe>,
    /// (window bucket T, effective-batch bucket EB) → decoder executable.
    /// Most decoding happens at short prefixes and the per-call cost is
    /// ∝ T without a KV cache, so the runtime picks the smallest window
    /// that fits the longest row of the call.
    dec: BTreeMap<(usize, usize), LazyExe>,
    /// Same grid, B=1 fast path: shared memory row broadcast on-device,
    /// log-probs emitted only for the trailing `DECFAST_WINDOW` columns.
    decfast: BTreeMap<(usize, usize), LazyExe>,
    /// Cache-shaped decoder executables: take per-layer K/V buffers as
    /// extra arguments and compute only the appended window. aot.py does
    /// not emit these yet (ROADMAP: "artifact-side cache inputs"); the
    /// manifest kind is registered here so sessions switch from the
    /// stateless-recompute fallback the moment artifacts grow them.
    deccache: BTreeMap<(usize, usize), LazyExe>,
}

/// The production backend: PJRT-compiled AOT artifacts.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cfg: Config,
    arts: ArtifactSet,
    /// Device-resident weight buffers (lexicographic flat-key order).
    weight_bufs: Vec<xla::PjRtBuffer>,
    /// Decoder-call instrumentation ((rows, window) per call), readable
    /// by benchmarks and the parallel-device projection.
    calls: std::cell::RefCell<Vec<(usize, usize)>>,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto =
        xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 artifact path")?)
            .with_context(|| format!("parse {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compile {}", path.display()))
}

impl PjrtBackend {
    /// Load every artifact for `task` from `dir` (per the manifest written
    /// by aot.py: `manifest.tsv` lines `kind\ttask\tbucket\tfile`) plus
    /// the task's weights, uploaded to the device once.
    pub fn load(dir: &Path, task: &str) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let cfg = Config::from_file(&dir.join(format!("config_{task}.txt")))?;
        let weights = Weights::load(&dir.join(format!("weights_{task}.bin")))?;

        let mut weight_bufs = Vec::with_capacity(weights.len());
        for name in weights.names() {
            let t = weights.get(name)?;
            let dims = if t.dims.is_empty() { vec![1] } else { t.dims.clone() };
            weight_bufs.push(
                client
                    .buffer_from_host_buffer::<f32>(&t.data, &dims, None)
                    .with_context(|| format!("upload weight {name}"))?,
            );
        }

        let manifest = std::fs::read_to_string(dir.join("manifest.tsv")).with_context(|| {
            format!("read {}/manifest.tsv (run `make artifacts`)", dir.display())
        })?;
        let mut enc = BTreeMap::new();
        let mut dec = BTreeMap::new();
        let mut decfast = BTreeMap::new();
        let mut deccache = BTreeMap::new();
        for line in manifest.lines() {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 5 || f[1] != task {
                continue;
            }
            let eb: usize = f[2].parse()?;
            let tlen: usize = f[3].parse()?;
            let lazy = LazyExe {
                path: dir.join(f[4]),
                exe: std::cell::OnceCell::new(),
            };
            anyhow::ensure!(lazy.path.exists(), "missing artifact {}", lazy.path.display());
            match f[0] {
                "enc" => {
                    enc.insert(eb, lazy);
                }
                "dec" => {
                    dec.insert((tlen, eb), lazy);
                }
                "decfast" => {
                    decfast.insert((tlen, eb), lazy);
                }
                "deccache" => {
                    deccache.insert((tlen, eb), lazy);
                }
                other => bail!("unknown artifact kind {other}"),
            }
        }
        if enc.is_empty() || dec.is_empty() {
            bail!("no artifacts for task {task} in {}", dir.display());
        }
        Ok(PjrtBackend {
            client,
            cfg,
            arts: ArtifactSet {
                enc,
                dec,
                decfast,
                deccache,
            },
            weight_bufs,
            calls: std::cell::RefCell::new(Vec::new()),
        })
    }

    pub fn config(&self) -> Config {
        self.cfg
    }

    /// Smallest bucket ≥ `n`, or the largest available (callers chunk).
    fn bucket(map: &BTreeMap<usize, LazyExe>, n: usize) -> usize {
        for (&b, _) in map.iter() {
            if b >= n {
                return b;
            }
        }
        *map.keys().last().unwrap()
    }

    /// Pick the decoder (T, EB) bucket: smallest window ≥ `max_len`, then
    /// smallest effective batch ≥ `n` within that window.
    fn dec_bucket(&self, max_len: usize, n: usize) -> (usize, usize) {
        let t = self
            .arts
            .dec
            .keys()
            .map(|&(t, _)| t)
            .filter(|&t| t >= max_len)
            .min()
            .unwrap_or_else(|| self.arts.dec.keys().map(|&(t, _)| t).max().unwrap());
        let eb = self
            .arts
            .dec
            .keys()
            .filter(|&&(tt, _)| tt == t)
            .map(|&(_, b)| b)
            .find(|&b| b >= n)
            .unwrap_or_else(|| {
                self.arts
                    .dec
                    .keys()
                    .filter(|&&(tt, _)| tt == t)
                    .map(|&(_, b)| b)
                    .max()
                    .unwrap()
            });
        (t, eb)
    }

    pub fn decoder_buckets(&self) -> Vec<(usize, usize)> {
        self.arts.dec.keys().copied().collect()
    }

    /// Eagerly compile every registered artifact. Benchmarks call this so
    /// lazy first-use compilation never pollutes a timed sample.
    pub fn precompile(&self) -> Result<()> {
        for lazy in self
            .arts
            .enc
            .values()
            .chain(self.arts.dec.values())
            .chain(self.arts.decfast.values())
            .chain(self.arts.deccache.values())
        {
            lazy.get(&self.client)?;
        }
        Ok(())
    }

    /// Whether the manifest registered cache-shaped decoder artifacts
    /// (`deccache` kind). When false — the current aot.py output —
    /// sessions use the stateless-recompute fallback.
    pub fn has_cache_artifacts(&self) -> bool {
        !self.arts.deccache.is_empty()
    }

    /// Largest effective-batch bucket (for chunking).
    fn max_eb(&self) -> usize {
        self.arts.dec.keys().map(|&(_, b)| b).max().unwrap()
    }

    /// (rows, window) of every decoder call so far (bench metric).
    pub fn take_call_log(&self) -> Vec<(usize, usize)> {
        std::mem::take(&mut self.calls.borrow_mut())
    }

    /// Run one executable: upload `inputs`, append the weight buffers,
    /// fetch the single (1-tuple) f32 output.
    fn run(&self, exe: &xla::PjRtLoadedExecutable, inputs: Vec<xla::PjRtBuffer>) -> Result<Vec<f32>> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len() + self.weight_bufs.len());
        args.extend(inputs.iter());
        args.extend(self.weight_bufs.iter());
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    fn encode_chunk(&self, srcs: &[&[i64]]) -> Result<(Vec<f32>, Vec<f32>)> {
        let (s_len, d) = (self.cfg.s_len, self.cfg.d_model);
        let n = srcs.len();
        let bucket = Self::bucket(&self.arts.enc, n);
        anyhow::ensure!(n <= bucket, "encode chunk {n} exceeds largest bucket {bucket}");
        let mut src = vec![PAD_ID as i32; bucket * s_len];
        let mut pad = vec![0f32; bucket * s_len];
        for (b, s) in srcs.iter().enumerate() {
            anyhow::ensure!(s.len() <= s_len, "src length {} exceeds {s_len}", s.len());
            for (i, &t) in s.iter().enumerate() {
                src[b * s_len + i] = t as i32;
                pad[b * s_len + i] = 1.0;
            }
        }
        let inputs = vec![
            self.upload_i32(&src, &[bucket, s_len])?,
            self.upload_f32(&pad, &[bucket, s_len])?,
        ];
        let exe = self.arts.enc[&bucket].get(&self.client)?;
        let mem = self.run(exe, inputs)?;
        let row = s_len * d;
        Ok((mem[..n * row].to_vec(), pad[..n * s_len].to_vec()))
    }
}

impl Backend for PjrtBackend {
    fn dims(&self) -> ModelDims {
        ModelDims {
            s_len: self.cfg.s_len,
            t_len: self.cfg.t_len,
            d_model: self.cfg.d_model,
            vocab: self.cfg.vocab,
        }
    }

    fn encode(&self, srcs: &[&[i64]]) -> Result<Memory> {
        let (s_len, d) = (self.cfg.s_len, self.cfg.d_model);
        let max_bucket = *self.arts.enc.keys().last().unwrap();
        let mut data = Vec::with_capacity(srcs.len() * s_len * d);
        let mut pad = Vec::with_capacity(srcs.len() * s_len);
        for chunk in srcs.chunks(max_bucket) {
            let (m, p) = self.encode_chunk(chunk)?;
            data.extend(m);
            pad.extend(p);
        }
        Ok(Memory {
            data,
            pad,
            batch: srcs.len(),
            s_len,
            d_model: d,
        })
    }

    fn decode(&self, rows: &[DecoderRow], memory: &Memory) -> Result<LogProbs> {
        let (s_len, d, v) = (self.cfg.s_len, self.cfg.d_model, self.cfg.vocab);
        let max_eb = self.max_eb();
        let max_len = rows.iter().map(|r| r.tokens.len()).max().unwrap_or(1);
        // One window bucket for the whole call keeps LogProbs uniform.
        let (t_len, _) = self.dec_bucket(max_len, rows.len().min(max_eb));
        anyhow::ensure!(
            max_len <= t_len,
            "row length {max_len} exceeds largest window {t_len}"
        );

        // B=1 fast path: every row attends to the same (single) memory
        // row, so the artifact broadcasts it on-device and returns only
        // the trailing DECFAST_WINDOW columns — all that greedy/
        // speculative/beam steps ever read (rows are left-padded).
        let fast = !self.arts.decfast.is_empty()
            && memory.batch == 1
            && rows.iter().all(|r| r.mem_row == 0)
            && std::env::var_os("RXNSPEC_NO_DECFAST").is_none();
        let window = if fast { DECFAST_WINDOW.min(t_len) } else { t_len };

        let mem_buf = if fast {
            Some((
                self.upload_f32(memory.row(0), &[1, s_len, d])?,
                self.upload_f32(memory.pad_row(0), &[1, s_len])?,
            ))
        } else {
            None
        };

        let mut out = vec![0f32; rows.len() * window * v];
        let mut lens = Vec::with_capacity(rows.len());
        for (ci, chunk) in rows.chunks(max_eb).enumerate() {
            let n = chunk.len();
            let (_, eb) = self.dec_bucket(max_len, n);
            self.calls.borrow_mut().push((n, t_len));

            let mut tgt = vec![PAD_ID as i32; eb * t_len];
            let mut pos = vec![0i32; eb * t_len];
            let mut tpad = vec![0f32; eb * t_len];
            for (r, row) in chunk.iter().enumerate() {
                let l = row.tokens.len();
                lens.push(l);
                let off = t_len - l; // padLeft: right-align the row
                for (i, &t) in row.tokens.iter().enumerate() {
                    tgt[r * t_len + off + i] = t as i32;
                    pos[r * t_len + off + i] = i as i32;
                    tpad[r * t_len + off + i] = 1.0;
                }
            }
            let mut inputs = vec![
                self.upload_i32(&tgt, &[eb, t_len])?,
                self.upload_i32(&pos, &[eb, t_len])?,
                self.upload_f32(&tpad, &[eb, t_len])?,
            ];
            let lp = if let Some((m, mp)) = &mem_buf {
                let mut args: Vec<&xla::PjRtBuffer> =
                    Vec::with_capacity(5 + self.weight_bufs.len());
                args.extend(inputs.iter());
                args.push(m);
                args.push(mp);
                args.extend(self.weight_bufs.iter());
                let exe = self.arts.decfast[&(t_len, eb)].get(&self.client)?;
                let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
                result.to_tuple1()?.to_vec::<f32>()?
            } else {
                let mut mem = vec![0f32; eb * s_len * d];
                let mut mpad = vec![0f32; eb * s_len];
                for (r, row) in chunk.iter().enumerate() {
                    mem[r * s_len * d..(r + 1) * s_len * d]
                        .copy_from_slice(memory.row(row.mem_row));
                    mpad[r * s_len..(r + 1) * s_len]
                        .copy_from_slice(memory.pad_row(row.mem_row));
                }
                inputs.push(self.upload_f32(&mem, &[eb, s_len, d])?);
                inputs.push(self.upload_f32(&mpad, &[eb, s_len])?);
                let exe = self.arts.dec[&(t_len, eb)].get(&self.client)?;
                self.run(exe, inputs)?
            };
            let row_sz = window * v;
            let base = ci * max_eb;
            out[base * row_sz..(base + n) * row_sz].copy_from_slice(&lp[..n * row_sz]);
        }
        Ok(LogProbs::new_windowed(out, lens, t_len, v, window))
    }

    fn begin(&self, memory: Memory) -> Result<Box<dyn DecoderSession + '_>> {
        // Cache-shaped artifacts would let the session keep device-
        // resident per-layer K/V buffers between `extend` calls and run a
        // `deccache` executable over just the appended window. Until
        // aot.py emits them (`has_cache_artifacts()`), every session
        // falls back to stateless recompute through `decode`, which
        // preserves the decfast B=1 path and bucket selection unchanged.
        Ok(Box::new(StatelessSession::new(self, memory)))
    }
}
