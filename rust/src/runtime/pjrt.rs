//! PJRT backend: loads `artifacts/*.hlo.txt`, compiles one executable per
//! (entrypoint, batch bucket), and serves `encode`/`decode` by padding the
//! request into the smallest bucket that fits.
//!
//! Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits 64-bit
//! instruction ids that the crate's XLA (xla_extension 0.5.1) rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Weights are **arguments**, not baked constants (aot.py keeps artifact
//! text small): the RXW1 checkpoint is uploaded once into device-resident
//! `PjRtBuffer`s, in lexicographic flat-key order — the exact order aot.py
//! lowered them in — and appended to every call.
//!
//! Decoder rows are right-aligned into the fixed `[EB, T]` window — the
//! paper's `padLeft` — with explicit position ids `col - pad_offset`, so
//! one compiled executable serves every mix of prefix and draft lengths.
//!
//! When the manifest registers cache-shaped `deccache` artifacts,
//! [`PjrtBackend::begin`] opens a KV-cached
//! [`CachedPjrtSession`](crate::runtime::deccache::CachedPjrtSession)
//! driven by [`PjrtDeccacheExec`] — attention over the appended window
//! only, device-resident K/V threaded call to call. Without them (or
//! under `RXNSPEC_NO_DECCACHE`) sessions fall back to the
//! stateless-recompute [`StatelessSession`].

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::decoding::{
    Backend, DecoderRow, DecoderSession, LogProbs, Memory, ModelDims, StatelessSession,
};
use crate::model::weights::fnv1a;
use crate::model::{Config, Weights};
use crate::runtime::deccache::{CachedPjrtSession, DeccacheCall, DeccacheExec, DeccacheOut};
use crate::vocab::PAD_ID;

/// Lazily compiled executable: artifact path + compile-on-first-use slot.
/// Loading a backend registers ~21 artifacts per task; most runs touch a
/// handful of buckets, so eager compilation would waste tens of seconds
/// of startup.
struct LazyExe {
    path: std::path::PathBuf,
    exe: std::cell::OnceCell<xla::PjRtLoadedExecutable>,
}

impl LazyExe {
    fn get(&self, client: &xla::PjRtClient) -> Result<&xla::PjRtLoadedExecutable> {
        if self.exe.get().is_none() {
            let exe = compile(client, &self.path)?;
            let _ = self.exe.set(exe);
        }
        Ok(self.exe.get().unwrap())
    }
}

/// Default trailing-columns window of decfast artifacts, used only for
/// manifests that predate the `meta decfast_window` row. Current
/// manifests carry the value explicitly (aot.py writes it; see
/// [`parse_manifest`]) so the two sides cannot silently disagree.
pub const DECFAST_WINDOW: usize = 16;

/// The manifest column contract, shared with the Python emitter
/// (`python/compile/aot.py::MANIFEST_COLUMNS`) and pinned by the golden
/// round-trip test (`rust/tests/manifest_golden.rs` ↔
/// `python/tests/test_train_smoke.py`).
pub const MANIFEST_COLUMNS: &str = "kind\ttask\teb\ttlen\tfile";

/// One task's artifact registry parsed out of `manifest.tsv`.
///
/// Column contract ([`MANIFEST_COLUMNS`]): `kind\ttask\teb\ttlen\tfile`,
/// five tab-separated columns on every line. Parse order is explicit —
/// `kind` is matched **first**, then the remaining columns are
/// interpreted per kind:
///
/// * artifact kinds (`enc`/`dec`/`decfast`/`deccache`) parse `eb` then
///   `tlen` as integers; the decoder grids are keyed `(tlen, eb)` —
///   window first — because routing picks the window bucket before the
///   batch bucket;
/// * `meta` rows reuse the `eb`/`tlen` columns as a `key`/`value` pair
///   (file column `-`); unknown meta keys are ignored for forward
///   compatibility.
#[derive(Debug, Default)]
pub struct ParsedManifest {
    /// batch bucket → file name.
    pub enc: BTreeMap<usize, String>,
    /// (window bucket T, effective-batch bucket EB) → file name.
    pub dec: BTreeMap<(usize, usize), String>,
    /// Same grid, B=1 fast path.
    pub decfast: BTreeMap<(usize, usize), String>,
    /// (appended-window bucket W, EB) → cache-shaped decoder file name.
    pub deccache: BTreeMap<(usize, usize), String>,
    /// `meta decfast_window` value, when present.
    pub decfast_window: Option<usize>,
}

/// Parse one `manifest.tsv` body for `task`. Rows of other tasks are
/// skipped; malformed rows (wrong column count, unknown kind, non-numeric
/// buckets) are hard errors — a manifest is a contract, not a best-effort
/// hint.
pub fn parse_manifest(text: &str, task: &str) -> Result<ParsedManifest> {
    let mut m = ParsedManifest::default();
    for (ln, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        anyhow::ensure!(
            f.len() == 5,
            "manifest line {}: expected 5 tab-separated columns ({:?}), got {}",
            ln + 1,
            MANIFEST_COLUMNS,
            f.len()
        );
        if f[1] != task {
            continue;
        }
        match f[0] {
            "meta" => {
                // Unknown meta keys are a forward-compatible no-op — a
                // future emitter may carry non-numeric values, so only
                // known keys get their value parsed.
                if f[2] == "decfast_window" {
                    let value: usize = f[3].parse().with_context(|| {
                        format!("manifest line {}: meta value {:?}", ln + 1, f[3])
                    })?;
                    m.decfast_window = Some(value);
                }
            }
            kind @ ("enc" | "dec" | "decfast" | "deccache") => {
                let eb: usize = f[2]
                    .parse()
                    .with_context(|| format!("manifest line {}: eb {:?}", ln + 1, f[2]))?;
                let tlen: usize = f[3]
                    .parse()
                    .with_context(|| format!("manifest line {}: tlen {:?}", ln + 1, f[3]))?;
                let fname = f[4].to_string();
                match kind {
                    "enc" => {
                        m.enc.insert(eb, fname);
                    }
                    "dec" => {
                        m.dec.insert((tlen, eb), fname);
                    }
                    "decfast" => {
                        m.decfast.insert((tlen, eb), fname);
                    }
                    _ => {
                        m.deccache.insert((tlen, eb), fname);
                    }
                }
            }
            other => bail!("unknown artifact kind {other:?} at manifest line {}", ln + 1),
        }
    }
    Ok(m)
}

/// Registered artifacts for one task (`fwd` or `retro`).
pub struct ArtifactSet {
    /// batch-bucket → encoder executable
    enc: BTreeMap<usize, LazyExe>,
    /// (window bucket T, effective-batch bucket EB) → decoder executable.
    /// Most decoding happens at short prefixes and the per-call cost is
    /// ∝ T without a KV cache, so the runtime picks the smallest window
    /// that fits the longest row of the call.
    dec: BTreeMap<(usize, usize), LazyExe>,
    /// Same grid, B=1 fast path: shared memory row broadcast on-device,
    /// log-probs emitted only for the trailing `decfast_window` columns.
    decfast: BTreeMap<(usize, usize), LazyExe>,
    /// Cache-shaped decoder executables, keyed (appended-window W, EB):
    /// take per-layer K/V buffers as extra arguments and compute only the
    /// appended window. Emitted by aot.py's `deccache` grid; when present
    /// (`has_cache_artifacts()`) sessions run KV-cached instead of the
    /// stateless-recompute fallback.
    deccache: BTreeMap<(usize, usize), LazyExe>,
}

/// The production backend: PJRT-compiled AOT artifacts.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cfg: Config,
    arts: ArtifactSet,
    /// Device-resident weight buffers (lexicographic flat-key order).
    weight_bufs: Vec<xla::PjRtBuffer>,
    /// Trailing-columns window of the decfast artifacts — read from the
    /// manifest's `meta decfast_window` row (the compiled-in
    /// [`DECFAST_WINDOW`] is only the legacy-manifest default).
    decfast_window: usize,
    /// Artifact/weights identity (manifest ⊕ checkpoint content hash) —
    /// folded into cross-request cache keys so entries cannot survive a
    /// model redeploy (`cache::ServeCache::bind_artifact_version`).
    /// aot.py writes a `meta content_digest` row over every artifact
    /// byte, so hashing the manifest text covers regenerated artifacts
    /// even when weights and bucket rows are unchanged.
    version: u64,
    /// Decoder-call instrumentation ((rows, window) per call), readable
    /// by benchmarks and the parallel-device projection.
    calls: std::cell::RefCell<Vec<(usize, usize)>>,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto =
        xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 artifact path")?)
            .with_context(|| format!("parse {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compile {}", path.display()))
}

impl PjrtBackend {
    /// Load every artifact for `task` from `dir` (per the manifest written
    /// by aot.py — see [`MANIFEST_COLUMNS`] and [`parse_manifest`] for
    /// the column contract) plus the task's weights, uploaded to the
    /// device once.
    pub fn load(dir: &Path, task: &str) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let cfg = Config::from_file(&dir.join(format!("config_{task}.txt")))?;
        let weights = Weights::load(&dir.join(format!("weights_{task}.bin")))?;

        let mut weight_bufs = Vec::with_capacity(weights.len());
        for name in weights.names() {
            let t = weights.get(name)?;
            let dims = if t.dims.is_empty() { vec![1] } else { t.dims.clone() };
            weight_bufs.push(
                client
                    .buffer_from_host_buffer::<f32>(&t.data, &dims, None)
                    .with_context(|| format!("upload weight {name}"))?,
            );
        }

        let manifest = std::fs::read_to_string(dir.join("manifest.tsv")).with_context(|| {
            format!("read {}/manifest.tsv (run `make artifacts`)", dir.display())
        })?;
        let parsed = parse_manifest(&manifest, task)?;
        let version = fnv1a(weights.content_hash(), manifest.as_bytes());

        // The decfast window is a *contract* between aot.py's lowering
        // and this runtime's LogProbs windowing: a wrong value silently
        // misindexes every distribution. New manifests carry it; reject
        // combinations that cannot be served instead of assuming.
        let decfast_window = parsed.decfast_window.unwrap_or(DECFAST_WINDOW);
        anyhow::ensure!(
            decfast_window >= 1 && decfast_window <= cfg.t_len,
            "manifest decfast_window {decfast_window} incompatible with t_len {}",
            cfg.t_len
        );
        if !parsed.deccache.is_empty() {
            anyhow::ensure!(
                parsed.decfast_window.is_some(),
                "manifest registers deccache artifacts but lacks the `meta decfast_window` \
                 row — artifacts and manifest disagree; regenerate with current aot.py"
            );
            for &(w, _) in parsed.deccache.keys() {
                anyhow::ensure!(
                    w >= 1 && w <= cfg.t_len,
                    "deccache window bucket {w} incompatible with t_len {}",
                    cfg.t_len
                );
            }
        }

        fn lazy_entry(dir: &Path, fname: &str) -> Result<LazyExe> {
            let lazy = LazyExe {
                path: dir.join(fname),
                exe: std::cell::OnceCell::new(),
            };
            anyhow::ensure!(lazy.path.exists(), "missing artifact {}", lazy.path.display());
            Ok(lazy)
        }
        fn lazy_grid(
            dir: &Path,
            entries: &BTreeMap<(usize, usize), String>,
        ) -> Result<BTreeMap<(usize, usize), LazyExe>> {
            let mut out = BTreeMap::new();
            for (&key, fname) in entries {
                out.insert(key, lazy_entry(dir, fname)?);
            }
            Ok(out)
        }
        let mut enc = BTreeMap::new();
        for (&eb, fname) in &parsed.enc {
            enc.insert(eb, lazy_entry(dir, fname)?);
        }
        let dec = lazy_grid(dir, &parsed.dec)?;
        let decfast = lazy_grid(dir, &parsed.decfast)?;
        let deccache = lazy_grid(dir, &parsed.deccache)?;
        if enc.is_empty() || dec.is_empty() {
            bail!("no artifacts for task {task} in {}", dir.display());
        }
        Ok(PjrtBackend {
            client,
            cfg,
            arts: ArtifactSet {
                enc,
                dec,
                decfast,
                deccache,
            },
            weight_bufs,
            decfast_window,
            version,
            calls: std::cell::RefCell::new(Vec::new()),
        })
    }

    pub fn config(&self) -> Config {
        self.cfg
    }

    /// Artifact/weights identity for cross-request cache keying.
    pub fn artifact_version(&self) -> u64 {
        self.version
    }

    /// Smallest bucket ≥ `n`, or the largest available (callers chunk).
    fn bucket(map: &BTreeMap<usize, LazyExe>, n: usize) -> usize {
        for (&b, _) in map.iter() {
            if b >= n {
                return b;
            }
        }
        *map.keys().last().unwrap()
    }

    /// Pick the decoder (T, EB) bucket: smallest window ≥ `max_len`, then
    /// smallest effective batch ≥ `n` within that window.
    fn dec_bucket(&self, max_len: usize, n: usize) -> (usize, usize) {
        let t = self
            .arts
            .dec
            .keys()
            .map(|&(t, _)| t)
            .filter(|&t| t >= max_len)
            .min()
            .unwrap_or_else(|| self.arts.dec.keys().map(|&(t, _)| t).max().unwrap());
        let eb = self
            .arts
            .dec
            .keys()
            .filter(|&&(tt, _)| tt == t)
            .map(|&(_, b)| b)
            .find(|&b| b >= n)
            .unwrap_or_else(|| {
                self.arts
                    .dec
                    .keys()
                    .filter(|&&(tt, _)| tt == t)
                    .map(|&(_, b)| b)
                    .max()
                    .unwrap()
            });
        (t, eb)
    }

    pub fn decoder_buckets(&self) -> Vec<(usize, usize)> {
        self.arts.dec.keys().copied().collect()
    }

    /// Eagerly compile every registered artifact. Benchmarks call this so
    /// lazy first-use compilation never pollutes a timed sample.
    pub fn precompile(&self) -> Result<()> {
        for lazy in self
            .arts
            .enc
            .values()
            .chain(self.arts.dec.values())
            .chain(self.arts.decfast.values())
            .chain(self.arts.deccache.values())
        {
            lazy.get(&self.client)?;
        }
        Ok(())
    }

    /// Whether the manifest registered cache-shaped decoder artifacts
    /// (`deccache` kind). When true, [`PjrtBackend::begin`] opens a
    /// KV-cached session; when false, the stateless-recompute fallback.
    pub fn has_cache_artifacts(&self) -> bool {
        !self.arts.deccache.is_empty()
    }

    /// Largest effective-batch bucket (for chunking).
    fn max_eb(&self) -> usize {
        self.arts.dec.keys().map(|&(_, b)| b).max().unwrap()
    }

    /// (rows, window) of every decoder call so far (bench metric).
    pub fn take_call_log(&self) -> Vec<(usize, usize)> {
        std::mem::take(&mut self.calls.borrow_mut())
    }

    /// Run one executable: upload `inputs`, append the weight buffers,
    /// fetch the single (1-tuple) f32 output.
    fn run(&self, exe: &xla::PjRtLoadedExecutable, inputs: Vec<xla::PjRtBuffer>) -> Result<Vec<f32>> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len() + self.weight_bufs.len());
        args.extend(inputs.iter());
        args.extend(self.weight_bufs.iter());
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    fn encode_chunk(&self, srcs: &[&[i64]]) -> Result<(Vec<f32>, Vec<f32>)> {
        let (s_len, d) = (self.cfg.s_len, self.cfg.d_model);
        let n = srcs.len();
        let bucket = Self::bucket(&self.arts.enc, n);
        anyhow::ensure!(n <= bucket, "encode chunk {n} exceeds largest bucket {bucket}");
        let mut src = vec![PAD_ID as i32; bucket * s_len];
        let mut pad = vec![0f32; bucket * s_len];
        for (b, s) in srcs.iter().enumerate() {
            anyhow::ensure!(s.len() <= s_len, "src length {} exceeds {s_len}", s.len());
            for (i, &t) in s.iter().enumerate() {
                src[b * s_len + i] = t as i32;
                pad[b * s_len + i] = 1.0;
            }
        }
        let inputs = vec![
            self.upload_i32(&src, &[bucket, s_len])?,
            self.upload_f32(&pad, &[bucket, s_len])?,
        ];
        let exe = self.arts.enc[&bucket].get(&self.client)?;
        let mem = self.run(exe, inputs)?;
        let row = s_len * d;
        Ok((mem[..n * row].to_vec(), pad[..n * s_len].to_vec()))
    }
}

/// The production [`DeccacheExec`]: uploads the padded call, runs the
/// `(W, EB)` artifact, and **retains the output K/V buffers on-device**
/// so the next steady-loop call can pass `kv_host: None` and skip the
/// `[L,EB,T,D]` host→device transfer (the dominant per-call copy once
/// the window shrinks to ~1 token). Host copies of the updated caches
/// are still downloaded every call — they keep the session's per-row
/// mirrors authoritative across fork/re-bucket/chunk breaks; eliding
/// that download for unbroken runs is a further optimization this
/// executor's surface already permits.
pub struct PjrtDeccacheExec<'a> {
    backend: &'a PjrtBackend,
    /// Retained output K/V device buffers of the last call + their EB.
    dev: std::cell::RefCell<Option<(xla::PjRtBuffer, xla::PjRtBuffer, usize)>>,
}

impl<'a> PjrtDeccacheExec<'a> {
    pub fn new(backend: &'a PjrtBackend) -> PjrtDeccacheExec<'a> {
        PjrtDeccacheExec {
            backend,
            dev: std::cell::RefCell::new(None),
        }
    }
}

impl DeccacheExec for PjrtDeccacheExec<'_> {
    fn dims(&self) -> ModelDims {
        self.backend.dims()
    }

    fn n_layers(&self) -> usize {
        self.backend.cfg.n_dec
    }

    fn grid(&self) -> Vec<(usize, usize)> {
        self.backend.arts.deccache.keys().copied().collect()
    }

    fn run(&self, call: DeccacheCall<'_>) -> Result<DeccacheOut> {
        let b = self.backend;
        let (s_len, d, t_len) = (b.cfg.s_len, b.cfg.d_model, b.cfg.t_len);
        let n_l = b.cfg.n_dec;
        let (w, eb) = (call.w, call.eb);
        // Call-log contract is (real rows, window) — same as `decode` —
        // so the bench projections never count padding lanes.
        b.calls.borrow_mut().push((call.n_rows, w));

        let tgt: Vec<i32> = call.tgt.iter().map(|&t| t as i32).collect();
        let pos: Vec<i32> = call.pos.iter().map(|&p| p as i32).collect();
        let clen: Vec<i32> = call.cache_len.iter().map(|&c| c as i32).collect();
        let mut mem = vec![0f32; eb * s_len * d];
        let mut mpad = vec![0f32; eb * s_len];
        for (r, &mr) in call.mem_rows.iter().enumerate() {
            mem[r * s_len * d..(r + 1) * s_len * d].copy_from_slice(call.mem.row(mr));
            mpad[r * s_len..(r + 1) * s_len].copy_from_slice(call.mem.pad_row(mr));
        }

        let (k_in, v_in) = match call.kv_host {
            Some((k, v)) => (
                b.upload_f32(&k, &[n_l, eb, t_len, d])?,
                b.upload_f32(&v, &[n_l, eb, t_len, d])?,
            ),
            None => {
                let retained = self.dev.borrow_mut().take();
                let (kb, vb, peb) = retained
                    .context("deccache input reuse requested without retained device buffers")?;
                anyhow::ensure!(peb == eb, "deccache reuse across EB buckets ({peb} vs {eb})");
                (kb, vb)
            }
        };

        let tgt_b = b.upload_i32(&tgt, &[eb, w])?;
        let pos_b = b.upload_i32(&pos, &[eb, w])?;
        let pad_b = b.upload_f32(&call.tgt_pad, &[eb, w])?;
        let mem_b = b.upload_f32(&mem, &[eb, s_len, d])?;
        let mpad_b = b.upload_f32(&mpad, &[eb, s_len])?;
        let clen_b = b.upload_i32(&clen, &[eb])?;

        let exe = b.arts.deccache[&(w, eb)].get(&b.client)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(8 + b.weight_bufs.len());
        args.extend([&tgt_b, &pos_b, &pad_b, &mem_b, &mpad_b, &k_in, &v_in, &clen_b]);
        args.extend(b.weight_bufs.iter());
        let mut results = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        anyhow::ensure!(!results.is_empty(), "deccache execution returned no results");
        let outs = results.swap_remove(0);

        // Bindings may untuple the 3-tuple result into three buffers
        // (keepable on-device) or hand back one tuple literal.
        if outs.len() == 3 {
            let mut it = outs.into_iter();
            let logp_b = it.next().unwrap();
            let kb = it.next().unwrap();
            let vb = it.next().unwrap();
            let logp = logp_b.to_literal_sync()?.to_vec::<f32>()?;
            let k_cache = kb.to_literal_sync()?.to_vec::<f32>()?;
            let v_cache = vb.to_literal_sync()?.to_vec::<f32>()?;
            *self.dev.borrow_mut() = Some((kb, vb, eb));
            Ok(DeccacheOut {
                logp,
                k_cache,
                v_cache,
                device_resident: true,
            })
        } else {
            let lit = outs
                .into_iter()
                .next()
                .context("deccache execution returned an empty buffer list")?
                .to_literal_sync()?;
            let (l, k, v) = lit.to_tuple3()?;
            Ok(DeccacheOut {
                logp: l.to_vec::<f32>()?,
                k_cache: k.to_vec::<f32>()?,
                v_cache: v.to_vec::<f32>()?,
                device_resident: false,
            })
        }
    }
}

impl Backend for PjrtBackend {
    fn dims(&self) -> ModelDims {
        ModelDims {
            s_len: self.cfg.s_len,
            t_len: self.cfg.t_len,
            d_model: self.cfg.d_model,
            vocab: self.cfg.vocab,
        }
    }

    fn encode(&self, srcs: &[&[i64]]) -> Result<Memory> {
        let (s_len, d) = (self.cfg.s_len, self.cfg.d_model);
        let max_bucket = *self.arts.enc.keys().last().unwrap();
        let mut data = Vec::with_capacity(srcs.len() * s_len * d);
        let mut pad = Vec::with_capacity(srcs.len() * s_len);
        for chunk in srcs.chunks(max_bucket) {
            let (m, p) = self.encode_chunk(chunk)?;
            data.extend(m);
            pad.extend(p);
        }
        Ok(Memory {
            data,
            pad,
            batch: srcs.len(),
            s_len,
            d_model: d,
        })
    }

    fn decode(&self, rows: &[DecoderRow], memory: &Memory) -> Result<LogProbs> {
        let (s_len, d, v) = (self.cfg.s_len, self.cfg.d_model, self.cfg.vocab);
        let max_eb = self.max_eb();
        let max_len = rows.iter().map(|r| r.tokens.len()).max().unwrap_or(1);
        // One window bucket for the whole call keeps LogProbs uniform.
        let (t_len, _) = self.dec_bucket(max_len, rows.len().min(max_eb));
        anyhow::ensure!(
            max_len <= t_len,
            "row length {max_len} exceeds largest window {t_len}"
        );

        // B=1 fast path: every row attends to the same (single) memory
        // row, so the artifact broadcasts it on-device and returns only
        // the trailing decfast_window columns — all that greedy/
        // speculative/beam steps ever read (rows are left-padded).
        let fast = !self.arts.decfast.is_empty()
            && memory.batch == 1
            && rows.iter().all(|r| r.mem_row == 0)
            && !crate::knobs::NO_DECFAST.is_set();
        let window = if fast {
            self.decfast_window.min(t_len)
        } else {
            t_len
        };

        let mem_buf = if fast {
            Some((
                self.upload_f32(memory.row(0), &[1, s_len, d])?,
                self.upload_f32(memory.pad_row(0), &[1, s_len])?,
            ))
        } else {
            None
        };

        let mut out = vec![0f32; rows.len() * window * v];
        let mut lens = Vec::with_capacity(rows.len());
        for (ci, chunk) in rows.chunks(max_eb).enumerate() {
            let n = chunk.len();
            let (_, eb) = self.dec_bucket(max_len, n);
            self.calls.borrow_mut().push((n, t_len));

            let mut tgt = vec![PAD_ID as i32; eb * t_len];
            let mut pos = vec![0i32; eb * t_len];
            let mut tpad = vec![0f32; eb * t_len];
            for (r, row) in chunk.iter().enumerate() {
                let l = row.tokens.len();
                lens.push(l);
                let off = t_len - l; // padLeft: right-align the row
                for (i, &t) in row.tokens.iter().enumerate() {
                    tgt[r * t_len + off + i] = t as i32;
                    pos[r * t_len + off + i] = i as i32;
                    tpad[r * t_len + off + i] = 1.0;
                }
            }
            let mut inputs = vec![
                self.upload_i32(&tgt, &[eb, t_len])?,
                self.upload_i32(&pos, &[eb, t_len])?,
                self.upload_f32(&tpad, &[eb, t_len])?,
            ];
            let lp = if let Some((m, mp)) = &mem_buf {
                let mut args: Vec<&xla::PjRtBuffer> =
                    Vec::with_capacity(5 + self.weight_bufs.len());
                args.extend(inputs.iter());
                args.push(m);
                args.push(mp);
                args.extend(self.weight_bufs.iter());
                let exe = self.arts.decfast[&(t_len, eb)].get(&self.client)?;
                let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?[0][0].to_literal_sync()?;
                result.to_tuple1()?.to_vec::<f32>()?
            } else {
                let mut mem = vec![0f32; eb * s_len * d];
                let mut mpad = vec![0f32; eb * s_len];
                for (r, row) in chunk.iter().enumerate() {
                    mem[r * s_len * d..(r + 1) * s_len * d]
                        .copy_from_slice(memory.row(row.mem_row));
                    mpad[r * s_len..(r + 1) * s_len]
                        .copy_from_slice(memory.pad_row(row.mem_row));
                }
                inputs.push(self.upload_f32(&mem, &[eb, s_len, d])?);
                inputs.push(self.upload_f32(&mpad, &[eb, s_len])?);
                let exe = self.arts.dec[&(t_len, eb)].get(&self.client)?;
                self.run(exe, inputs)?
            };
            let row_sz = window * v;
            let base = ci * max_eb;
            out[base * row_sz..(base + n) * row_sz].copy_from_slice(&lp[..n * row_sz]);
        }
        Ok(LogProbs::new_windowed(out, lens, t_len, v, window))
    }

    fn begin(&self, memory: Memory) -> Result<Box<dyn DecoderSession + '_>> {
        // Cache-shaped artifacts present: open the KV-cached session —
        // device-resident per-layer K/V threaded call to call, attention
        // over the appended window only. Otherwise (or when the operator
        // forces it with RXNSPEC_NO_DECCACHE) fall back to stateless
        // recompute through `decode`, which preserves the decfast B=1
        // path and bucket selection unchanged.
        if self.has_cache_artifacts() && !crate::knobs::NO_DECCACHE.is_set() {
            return Ok(Box::new(CachedPjrtSession::new(PjrtDeccacheExec::new(self), memory)));
        }
        Ok(Box::new(StatelessSession::new(self, memory)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "meta\tfwd\tdecfast_window\t16\t-\n\
                          enc\tfwd\t1\t0\tenc_fwd_b1.hlo.txt\n\
                          dec\tfwd\t1\t24\tdec_fwd_b1_t24.hlo.txt\n\
                          decfast\tfwd\t1\t24\tdecfast_fwd_b1_t24.hlo.txt\n\
                          deccache\tfwd\t1\t4\tdeccache_fwd_b1_t4.hlo.txt\n";

    #[test]
    fn parse_manifest_routes_kinds_and_meta() {
        let m = parse_manifest(SAMPLE, "fwd").unwrap();
        assert_eq!(m.enc[&1], "enc_fwd_b1.hlo.txt");
        // Decoder grids are keyed (tlen, eb) — window first.
        assert_eq!(m.dec[&(24, 1)], "dec_fwd_b1_t24.hlo.txt");
        assert_eq!(m.decfast[&(24, 1)], "decfast_fwd_b1_t24.hlo.txt");
        assert_eq!(m.deccache[&(4, 1)], "deccache_fwd_b1_t4.hlo.txt");
        assert_eq!(m.decfast_window, Some(16));
    }

    #[test]
    fn parse_manifest_skips_other_tasks() {
        let m = parse_manifest(SAMPLE, "retro").unwrap();
        assert!(m.enc.is_empty() && m.dec.is_empty() && m.deccache.is_empty());
        assert_eq!(m.decfast_window, None);
    }

    #[test]
    fn parse_manifest_rejects_malformed_rows() {
        assert!(parse_manifest("enc\tfwd\t1\t0", "fwd").is_err()); // 4 columns
        assert!(parse_manifest("bogus\tfwd\t1\t0\tx.hlo.txt", "fwd").is_err());
        assert!(parse_manifest("dec\tfwd\tx\t24\tf.hlo.txt", "fwd").is_err());
        assert!(parse_manifest("meta\tfwd\tdecfast_window\tx\t-", "fwd").is_err());
    }

    #[test]
    fn parse_manifest_ignores_unknown_meta_keys() {
        let m = parse_manifest("meta\tfwd\tfuture_knob\t3\t-\n", "fwd").unwrap();
        assert_eq!(m.decfast_window, None);
        // Unknown keys may carry non-numeric values (forward compat).
        let m = parse_manifest("meta\tfwd\tcheckpoint_digest\t3fa9c1\t-\n", "fwd").unwrap();
        assert_eq!(m.decfast_window, None);
    }
}
