//! Runtime: executing AOT-compiled model artifacts via PJRT.
//!
//! `python/compile/aot.py` lowers the JAX model (with the Pallas attention
//! kernel) to HLO **text** once at build time; this module loads those
//! files, compiles them on the PJRT CPU client, and exposes them behind
//! the same [`Backend`](crate::decoding::Backend) trait the decoding
//! algorithms use. Python is never on this path.

pub mod deccache;
pub mod pjrt;

pub use deccache::{CachedPjrtSession, DeccacheCall, DeccacheExec, DeccacheOut};
pub use pjrt::{ArtifactSet, PjrtBackend, PjrtDeccacheExec};

use std::path::Path;

use anyhow::Result;

use crate::decoding::{Backend, DecoderRow, DecoderSession, LogProbs, Memory, ModelDims};
use crate::model::RustBackend;

/// Runtime-selectable backend: the PJRT production path or the pure-Rust
/// reference (the paper's "original MT" role — and the fallback when no
/// artifacts are built).
pub enum AnyBackend {
    Pjrt(PjrtBackend),
    Rust(RustBackend),
}

impl AnyBackend {
    /// Eagerly compile all PJRT artifacts (no-op for the Rust backend);
    /// benches call this so compilation never lands in a timed sample.
    pub fn precompile(&self) -> Result<()> {
        match self {
            AnyBackend::Pjrt(b) => b.precompile(),
            AnyBackend::Rust(_) => Ok(()),
        }
    }

    /// Decoder call log ((rows, window) per call); empty for the Rust
    /// backend.
    pub fn take_call_log(&self) -> Vec<(usize, usize)> {
        match self {
            AnyBackend::Pjrt(b) => b.take_call_log(),
            AnyBackend::Rust(_) => Vec::new(),
        }
    }

    /// Artifact/weights identity for cross-request cache keying — cache
    /// entries are only valid per model version, so the serving setup
    /// binds this into `cache::ServeCache` (flush-on-mismatch).
    pub fn artifact_version(&self) -> u64 {
        match self {
            AnyBackend::Pjrt(b) => b.artifact_version(),
            AnyBackend::Rust(b) => b.artifact_version(),
        }
    }

    /// `kind` ∈ {"pjrt", "rust"}; artifacts + weights live in `dir`.
    pub fn load(kind: &str, dir: &Path, task: &str) -> Result<AnyBackend> {
        match kind {
            "pjrt" => Ok(AnyBackend::Pjrt(PjrtBackend::load(dir, task)?)),
            "rust" => Ok(AnyBackend::Rust(RustBackend::load(
                &dir.join(format!("weights_{task}.bin")),
                &dir.join(format!("config_{task}.txt")),
            )?)),
            other => anyhow::bail!("unknown backend {other:?} (use pjrt|rust)"),
        }
    }
}

impl Backend for AnyBackend {
    fn dims(&self) -> ModelDims {
        match self {
            AnyBackend::Pjrt(b) => b.dims(),
            AnyBackend::Rust(b) => b.dims(),
        }
    }

    fn encode(&self, srcs: &[&[i64]]) -> Result<Memory> {
        match self {
            AnyBackend::Pjrt(b) => b.encode(srcs),
            AnyBackend::Rust(b) => b.encode(srcs),
        }
    }

    fn decode(&self, rows: &[DecoderRow], memory: &Memory) -> Result<LogProbs> {
        match self {
            AnyBackend::Pjrt(b) => b.decode(rows, memory),
            AnyBackend::Rust(b) => b.decode(rows, memory),
        }
    }

    fn begin(&self, memory: Memory) -> Result<Box<dyn DecoderSession + '_>> {
        // Dispatch so the reference backend's KV-cached session is used
        // (the default would wrap AnyBackend itself in a stateless
        // adapter and silently lose the cache).
        match self {
            AnyBackend::Pjrt(b) => b.begin(memory),
            AnyBackend::Rust(b) => b.begin(memory),
        }
    }
}
