//! L3 coordinator: the serving system around the decoding algorithms.
//!
//! * [`batcher`] — FIFO request queue with dynamic batching of compatible
//!   greedy/speculative requests.
//! * [`worker`] — the model thread: drains batches, runs the decoding
//!   algorithms against the backend, replies over channels; consults the
//!   [`cache`](crate::cache) pair before admission and feeds it after
//!   every completion.
//! * [`pool`] — the supervised multi-worker tier: N workers over one
//!   queue and one shared cache, heartbeat supervision, and exactly-once
//!   reclaim of a lost worker's in-flight requests.
//! * [`server`] — TCP line-protocol front end + blocking client.
//! * [`metrics`] — counters and latency histograms (acceptance rate,
//!   tokens/call, queue wait, decode latency).

pub mod batcher;
pub mod metrics;
pub mod pool;
pub mod server;
pub mod worker;

pub use batcher::{lock_ok, DecodeMode, PushError, Request, RequestQueue};
pub use metrics::{Histogram, Metrics};
pub use pool::{default_workers, run_pool, PoolConfig};
pub use server::{serve, Client, Prediction, ServerState};
pub use worker::{
    run_worker, run_worker_supervised, InFlight, Job, JobResult, Reply, ReplySlot, WorkerHealth,
};
