//! Dynamic batcher: groups compatible queued requests into model batches.
//!
//! The serving regime the paper targets is an AI assistant for chemists —
//! requests trickle in one at a time, and speculative decoding makes B=1
//! latency acceptable. Under burst load, batching amortizes the decoder:
//! greedy / speculative-greedy requests with the same configuration are
//! decoded together (`greedy_batch` / `spec_greedy_batch`); beam-search
//! requests run solo (their effective batch is already beams × drafts).
//!
//! Policy: close a batch when (a) `max_batch` compatible requests are
//! waiting, or (b) `max_wait` has elapsed since the oldest arrival, or
//! (c) an incompatible request is at the queue head (FIFO order is never
//! violated across classes).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// How a request wants to be decoded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    Greedy,
    /// Speculative greedy with draft length.
    SpecGreedy { dl: usize },
    /// Standard beam search with width n.
    Beam { n: usize },
    /// Speculative beam search with width n and draft length dl.
    Sbs { n: usize, dl: usize },
}

impl DecodeMode {
    /// Requests of the same class may share a decoder batch.
    pub fn batchable_with(&self, other: &DecodeMode) -> bool {
        self == other && matches!(self, DecodeMode::Greedy | DecodeMode::SpecGreedy { .. })
    }

    /// Stable "decoder kind" discriminant for the result cache: two
    /// requests share a cached prediction only when both the query and
    /// this tag match. Variant in the low byte, parameters above it.
    pub fn cache_tag(&self) -> u64 {
        match self {
            DecodeMode::Greedy => 1,
            DecodeMode::SpecGreedy { dl } => 2 | ((*dl as u64) << 8),
            DecodeMode::Beam { n } => 3 | ((*n as u64) << 8),
            DecodeMode::Sbs { n, dl } => 4 | ((*n as u64) << 8) | ((*dl as u64) << 32),
        }
    }

    /// Parse `greedy`, `spec:<dl>`, `bs:<n>`, `sbs:<n>:<dl>`.
    pub fn parse(s: &str) -> Option<DecodeMode> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["greedy"] => Some(DecodeMode::Greedy),
            ["spec", dl] => Some(DecodeMode::SpecGreedy { dl: dl.parse().ok()? }),
            ["bs", n] => Some(DecodeMode::Beam { n: n.parse().ok()? }),
            ["sbs", n, dl] => Some(DecodeMode::Sbs {
                n: n.parse().ok()?,
                dl: dl.parse().ok()?,
            }),
            _ => None,
        }
    }
}

impl std::fmt::Display for DecodeMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeMode::Greedy => write!(f, "greedy"),
            DecodeMode::SpecGreedy { dl } => write!(f, "spec:{dl}"),
            DecodeMode::Beam { n } => write!(f, "bs:{n}"),
            DecodeMode::Sbs { n, dl } => write!(f, "sbs:{n}:{dl}"),
        }
    }
}

/// A queued unit of work.
pub struct Request<T> {
    pub mode: DecodeMode,
    pub payload: T,
    pub enqueued: Instant,
}

/// Thread-safe FIFO queue with condition-variable wakeup.
pub struct RequestQueue<T> {
    inner: Mutex<QueueInner<T>>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
}

struct QueueInner<T> {
    queue: VecDeque<Request<T>>,
    closed: bool,
}

impl<T> RequestQueue<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        RequestQueue {
            inner: Mutex::new(QueueInner {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch,
            max_wait,
        }
    }

    pub fn push(&self, mode: DecodeMode, payload: T) {
        let mut g = self.inner.lock().unwrap();
        g.queue.push_back(Request {
            mode,
            payload,
            enqueued: Instant::now(),
        });
        self.cv.notify_all();
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking: drain up to `max` requests from the queue head that
    /// are batchable with `mode`, preserving FIFO order. Used by the
    /// worker to admit newcomers into a **live decoding session**
    /// between generation steps (continuous batching): the session stays
    /// alive across batching ticks and fresh compatible requests join it
    /// instead of waiting for the whole previous batch to finish.
    pub fn try_pop_compatible(&self, mode: DecodeMode, max: usize) -> Vec<Request<T>> {
        if max == 0 {
            return Vec::new();
        }
        let mut g = self.inner.lock().unwrap();
        let n = g
            .queue
            .iter()
            .take(max)
            .take_while(|r| r.mode.batchable_with(&mode))
            .count();
        g.queue.drain(..n).collect()
    }

    /// Pop the next batch: the queue-head request plus every immediately
    /// following *compatible* request, up to `max_batch`. Blocks until the
    /// head has waited `max_wait` (or the batch is full, or the next
    /// request is incompatible). Returns `None` when closed and drained.
    pub fn pop_batch(&self) -> Option<Vec<Request<T>>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(head) = g.queue.front() {
                let head_mode = head.mode;
                let deadline = head.enqueued + self.max_wait;
                // How many consecutive compatible requests are queued?
                let compat = g
                    .queue
                    .iter()
                    .take(self.max_batch)
                    .take_while(|r| r.mode.batchable_with(&head_mode))
                    .count()
                    .max(1);
                let solo = !head_mode.batchable_with(&head_mode); // beam/SBS go at once
                // An incompatible request right behind the run means no
                // further compatible arrivals can join (FIFO): ship now.
                let blocked = compat < g.queue.len();
                let full = solo || blocked || compat >= self.max_batch;
                if full || Instant::now() >= deadline {
                    let take = compat.min(self.max_batch);
                    let batch: Vec<Request<T>> = g.queue.drain(..take).collect();
                    return Some(batch);
                }
                let wait = deadline.saturating_duration_since(Instant::now());
                let (g2, _) = self.cv.wait_timeout(g, wait).unwrap();
                g = g2;
            } else if g.closed {
                return None;
            } else {
                g = self.cv.wait(g).unwrap();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for s in ["greedy", "spec:10", "bs:5", "sbs:25:10"] {
            let m = DecodeMode::parse(s).unwrap();
            assert_eq!(m.to_string(), s);
        }
        assert!(DecodeMode::parse("nope").is_none());
        assert!(DecodeMode::parse("sbs:x:1").is_none());
    }

    #[test]
    fn cache_tags_discriminate_decoder_kinds() {
        let modes = [
            DecodeMode::Greedy,
            DecodeMode::SpecGreedy { dl: 4 },
            DecodeMode::SpecGreedy { dl: 10 },
            DecodeMode::Beam { n: 5 },
            DecodeMode::Sbs { n: 5, dl: 4 },
            DecodeMode::Sbs { n: 5, dl: 10 },
            DecodeMode::Sbs { n: 4, dl: 10 },
        ];
        for (i, a) in modes.iter().enumerate() {
            for (j, b) in modes.iter().enumerate() {
                assert_eq!(
                    a.cache_tag() == b.cache_tag(),
                    i == j,
                    "tag collision between {a} and {b}"
                );
            }
        }
    }

    #[test]
    fn batchable_classes() {
        let g = DecodeMode::Greedy;
        let s10 = DecodeMode::SpecGreedy { dl: 10 };
        let s4 = DecodeMode::SpecGreedy { dl: 4 };
        let b5 = DecodeMode::Beam { n: 5 };
        assert!(g.batchable_with(&g));
        assert!(s10.batchable_with(&s10));
        assert!(!s10.batchable_with(&s4));
        assert!(!b5.batchable_with(&b5)); // beams run solo
        assert!(!g.batchable_with(&s10));
    }

    #[test]
    fn pop_batch_groups_compatible_head_run() {
        let q: RequestQueue<usize> = RequestQueue::new(8, Duration::from_millis(1));
        q.push(DecodeMode::Greedy, 1);
        q.push(DecodeMode::Greedy, 2);
        q.push(DecodeMode::Beam { n: 5 }, 3);
        q.push(DecodeMode::Greedy, 4);
        let b1 = q.pop_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![1, 2]);
        let b2 = q.pop_batch().unwrap();
        assert_eq!(b2.len(), 1);
        assert_eq!(b2[0].payload, 3);
        let b3 = q.pop_batch().unwrap();
        assert_eq!(b3[0].payload, 4);
    }

    #[test]
    fn pop_batch_respects_max_batch() {
        let q: RequestQueue<usize> = RequestQueue::new(2, Duration::from_millis(1));
        for i in 0..5 {
            q.push(DecodeMode::Greedy, i);
        }
        assert_eq!(q.pop_batch().unwrap().len(), 2);
        assert_eq!(q.pop_batch().unwrap().len(), 2);
        assert_eq!(q.pop_batch().unwrap().len(), 1);
    }

    #[test]
    fn try_pop_compatible_respects_mode_max_and_fifo() {
        let q: RequestQueue<usize> = RequestQueue::new(8, Duration::from_millis(1));
        q.push(DecodeMode::Greedy, 1);
        q.push(DecodeMode::Greedy, 2);
        q.push(DecodeMode::Greedy, 3);
        q.push(DecodeMode::SpecGreedy { dl: 4 }, 4);
        q.push(DecodeMode::Greedy, 5);

        // Cap respected, FIFO order kept.
        let got = q.try_pop_compatible(DecodeMode::Greedy, 2);
        assert_eq!(got.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![1, 2]);
        // Stops at the first incompatible request even with budget left.
        let got = q.try_pop_compatible(DecodeMode::Greedy, 8);
        assert_eq!(got.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![3]);
        // Head is now spec:4 — greedy admission gets nothing (never
        // reorders across classes), spec admission drains it.
        assert!(q.try_pop_compatible(DecodeMode::Greedy, 8).is_empty());
        assert!(q.try_pop_compatible(DecodeMode::Greedy, 0).is_empty());
        let got = q.try_pop_compatible(DecodeMode::SpecGreedy { dl: 4 }, 8);
        assert_eq!(got.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![4]);
        // Beam requests are never batchable, even with themselves.
        q.push(DecodeMode::Beam { n: 5 }, 6);
        assert_eq!(q.len(), 2);
        assert!(q
            .try_pop_compatible(DecodeMode::Beam { n: 5 }, 8)
            .is_empty());
    }

    #[test]
    fn fifo_never_reorders_across_classes() {
        let q: RequestQueue<usize> = RequestQueue::new(8, Duration::from_millis(1));
        q.push(DecodeMode::Beam { n: 5 }, 1);
        q.push(DecodeMode::Greedy, 2);
        let b1 = q.pop_batch().unwrap();
        assert_eq!(b1[0].payload, 1); // beam first even though greedy waits
    }

    #[test]
    fn close_drains_then_none() {
        let q: RequestQueue<usize> = RequestQueue::new(8, Duration::from_millis(1));
        q.push(DecodeMode::Greedy, 7);
        q.close();
        assert_eq!(q.pop_batch().unwrap()[0].payload, 7);
        assert!(q.pop_batch().is_none());
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        use std::sync::Arc;
        let q: Arc<RequestQueue<usize>> = Arc::new(RequestQueue::new(4, Duration::from_millis(1)));
        let producer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..200 {
                    q.push(
                        if i % 3 == 0 {
                            DecodeMode::Beam { n: 2 }
                        } else {
                            DecodeMode::Greedy
                        },
                        i,
                    );
                }
                q.close();
            })
        };
        let mut seen = Vec::new();
        while let Some(batch) = q.pop_batch() {
            for r in batch {
                seen.push(r.payload);
            }
        }
        producer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, (0..200).collect::<Vec<_>>());
    }
}
