//! Dynamic batcher: groups compatible queued requests into model batches.
//!
//! The serving regime the paper targets is an AI assistant for chemists —
//! requests trickle in one at a time, and speculative decoding makes B=1
//! latency acceptable. Under burst load, batching amortizes the decoder:
//! greedy / speculative-greedy requests with the same configuration are
//! decoded together (`greedy_batch` / `spec_greedy_batch`); beam-search
//! requests run solo (their effective batch is already beams × drafts).
//!
//! Policy: close a batch when (a) `max_batch` compatible requests are
//! waiting, or (b) `max_wait` has elapsed since the oldest arrival, or
//! (c) an incompatible request is at the queue head (FIFO order is never
//! violated across classes).
//!
//! Admission control (the industrial-serving layer): the queue carries a
//! `capacity` bound — [`RequestQueue::try_push`] refuses over-capacity
//! admissions instead of queueing unboundedly (the server replies
//! `BUSY`) — and every request may carry a **deadline**. Expired
//! requests are shed *at pop time* (never handed to the worker): both
//! pop paths take a shed callback so the caller can fail them back to
//! their clients (`ERR deadline_exceeded`) rather than dropping them
//! silently. Shed callbacks run **outside** the queue lock — replying
//! to a shed client is socket I/O, and one slow client must not stall
//! every worker's pop.
//!
//! Every admitted request gets a queue-assigned **id**, the unit of the
//! pool supervisor's exactly-once reclaim accounting:
//! [`RequestQueue::requeue_front`] puts a request reclaimed from a lost
//! worker back at the head (same id, bypassing capacity and close — the
//! request was already admitted once).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Lock a mutex, recovering from poisoning: a worker panic is contained
/// by the supervision layer (`catch_unwind`), so a poisoned queue or
/// cache mutex means "a holder panicked mid-update", not "the data is
/// gone" — every structure locked this way keeps its invariants on
/// per-call boundaries. Refusing to serve would turn one contained
/// panic into a full-server outage.
pub fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// How a request wants to be decoded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    Greedy,
    /// Speculative greedy with draft length.
    SpecGreedy { dl: usize },
    /// Standard beam search with width n.
    Beam { n: usize },
    /// Speculative beam search with width n and draft length dl.
    Sbs { n: usize, dl: usize },
}

impl DecodeMode {
    /// Requests of the same class may share a decoder batch.
    pub fn batchable_with(&self, other: &DecodeMode) -> bool {
        self == other && matches!(self, DecodeMode::Greedy | DecodeMode::SpecGreedy { .. })
    }

    /// Stable "decoder kind" discriminant for the result cache: two
    /// requests share a cached prediction only when both the query and
    /// this tag match. Variant in the low byte, parameters above it.
    pub fn cache_tag(&self) -> u64 {
        match self {
            DecodeMode::Greedy => 1,
            DecodeMode::SpecGreedy { dl } => 2 | ((*dl as u64) << 8),
            DecodeMode::Beam { n } => 3 | ((*n as u64) << 8),
            DecodeMode::Sbs { n, dl } => 4 | ((*n as u64) << 8) | ((*dl as u64) << 32),
        }
    }

    /// Parse `greedy`, `spec:<dl>`, `bs:<n>`, `sbs:<n>:<dl>`.
    pub fn parse(s: &str) -> Option<DecodeMode> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["greedy"] => Some(DecodeMode::Greedy),
            ["spec", dl] => Some(DecodeMode::SpecGreedy { dl: dl.parse().ok()? }),
            ["bs", n] => Some(DecodeMode::Beam { n: n.parse().ok()? }),
            ["sbs", n, dl] => Some(DecodeMode::Sbs {
                n: n.parse().ok()?,
                dl: dl.parse().ok()?,
            }),
            _ => None,
        }
    }
}

impl std::fmt::Display for DecodeMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeMode::Greedy => write!(f, "greedy"),
            DecodeMode::SpecGreedy { dl } => write!(f, "spec:{dl}"),
            DecodeMode::Beam { n } => write!(f, "bs:{n}"),
            DecodeMode::Sbs { n, dl } => write!(f, "sbs:{n}:{dl}"),
        }
    }
}

/// A queued unit of work.
pub struct Request<T> {
    /// Queue-assigned admission id (1-based, unique per queue).
    /// [`RequestQueue::requeue_front`] preserves it, so a request
    /// reclaimed from a lost worker keeps its identity — the pool
    /// supervisor dedups reclaims by this id.
    pub id: u64,
    pub mode: DecodeMode,
    pub payload: T,
    pub enqueued: Instant,
    /// Absolute SLO deadline; an expired request is shed at pop time and
    /// never reaches the worker.
    pub deadline: Option<Instant>,
}

impl<T> Request<T> {
    /// Expired relative to `now`?
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| d <= now)
    }
}

/// Why [`RequestQueue::try_push`] refused an admission. The payload
/// comes back so the caller can reply to its client.
#[derive(Debug)]
pub enum PushError<T> {
    /// At capacity — the server replies `BUSY`.
    Full(T),
    /// Shutting down — admissions stopped.
    Closed(T),
}

/// Thread-safe FIFO queue with condition-variable wakeup, a capacity
/// bound, and deadline shedding.
pub struct RequestQueue<T> {
    inner: Mutex<QueueInner<T>>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Admission bound enforced by [`RequestQueue::try_push`]
    /// (`usize::MAX` = unbounded, the compat default of `new`).
    pub capacity: usize,
    /// Admission id counter (ids are 1-based; 0 never occurs).
    next_id: AtomicU64,
}

struct QueueInner<T> {
    queue: VecDeque<Request<T>>,
    closed: bool,
}

impl<T> QueueInner<T> {
    /// Remove and return every expired request. Runs under the queue
    /// lock; the *callbacks* for the removed requests run after the
    /// caller drops the lock — shedding replies over client sockets,
    /// and a slow socket must not hold the queue hostage.
    fn take_expired(&mut self) -> Vec<Request<T>> {
        let now = Instant::now();
        let mut expired = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].expired(now) {
                if let Some(r) = self.queue.remove(i) {
                    expired.push(r);
                }
            } else {
                i += 1;
            }
        }
        expired
    }
}

impl<T> RequestQueue<T> {
    /// Unbounded-capacity queue (library/tests compat constructor).
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self::with_capacity(max_batch, max_wait, usize::MAX)
    }

    /// Queue with an admission bound: `try_push` beyond `capacity`
    /// pending requests returns [`PushError::Full`].
    pub fn with_capacity(max_batch: usize, max_wait: Duration, capacity: usize) -> Self {
        RequestQueue {
            inner: Mutex::new(QueueInner {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            max_batch,
            max_wait,
            capacity: capacity.max(1),
            next_id: AtomicU64::new(1),
        }
    }

    fn assign_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Unconditional enqueue without a deadline — ignores the capacity
    /// bound (internal/test convenience; the serving front end admits
    /// through [`RequestQueue::try_push`]).
    pub fn push(&self, mode: DecodeMode, payload: T) {
        let id = self.assign_id();
        let mut g = lock_ok(&self.inner);
        g.queue.push_back(Request {
            id,
            mode,
            payload,
            enqueued: Instant::now(),
            deadline: None,
        });
        self.cv.notify_all();
    }

    /// Bounded admission with an optional deadline. Refuses when the
    /// queue is at capacity (`Full`: reply `BUSY`) or closed (`Closed`:
    /// reply shutting-down), handing the payload back either way.
    pub fn try_push(
        &self,
        mode: DecodeMode,
        payload: T,
        deadline: Option<Instant>,
    ) -> Result<(), PushError<T>> {
        let mut g = lock_ok(&self.inner);
        if g.closed {
            return Err(PushError::Closed(payload));
        }
        if g.queue.len() >= self.capacity {
            return Err(PushError::Full(payload));
        }
        let id = self.assign_id();
        g.queue.push_back(Request {
            id,
            mode,
            payload,
            enqueued: Instant::now(),
            deadline,
        });
        self.cv.notify_all();
        Ok(())
    }

    /// Put a reclaimed request back at the queue **head**, keeping its
    /// id and original enqueue time. Bypasses both the capacity bound
    /// and the closed flag: the request was already admitted once, and
    /// reclaim must still work mid-drain (a worker can wedge after the
    /// queue closes — pops keep draining a closed, non-empty queue).
    pub fn requeue_front(&self, req: Request<T>) {
        let mut g = lock_ok(&self.inner);
        g.queue.push_front(req);
        self.cv.notify_all();
    }

    /// Stop admissions; pops drain what is queued, then return `None`.
    pub fn close(&self) {
        lock_ok(&self.inner).closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        lock_ok(&self.inner).closed
    }

    pub fn len(&self) -> usize {
        lock_ok(&self.inner).queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Queue occupancy as a fraction of capacity (0.0 for unbounded
    /// queues) — the pressure signal behind the worker's degradation
    /// ladder.
    pub fn occupancy(&self) -> f64 {
        if self.capacity == usize::MAX {
            return 0.0;
        }
        self.len() as f64 / self.capacity as f64
    }

    /// Non-blocking: drain up to `max` requests from the queue head that
    /// are batchable with `mode`, preserving FIFO order. Used by the
    /// worker to admit newcomers into a **live decoding session**
    /// between generation steps (continuous batching): the session stays
    /// alive across batching ticks and fresh compatible requests join it
    /// instead of waiting for the whole previous batch to finish.
    /// Expired requests anywhere in the queue are shed to `shed` first;
    /// the callbacks run after the queue lock is released (shedding is
    /// reply I/O), so `shed` may even touch the queue.
    pub fn try_pop_compatible_shedding(
        &self,
        mode: DecodeMode,
        max: usize,
        shed: &mut dyn FnMut(Request<T>),
    ) -> Vec<Request<T>> {
        let (expired, batch) = {
            let mut g = lock_ok(&self.inner);
            let expired = g.take_expired();
            let n = if max == 0 {
                0
            } else {
                g.queue
                    .iter()
                    .take(max)
                    .take_while(|r| r.mode.batchable_with(&mode))
                    .count()
            };
            (expired, g.queue.drain(..n).collect::<Vec<_>>())
        };
        for r in expired {
            shed(r);
        }
        batch
    }

    /// [`RequestQueue::try_pop_compatible_shedding`] with expired
    /// requests silently dropped (test/compat convenience).
    pub fn try_pop_compatible(&self, mode: DecodeMode, max: usize) -> Vec<Request<T>> {
        self.try_pop_compatible_shedding(mode, max, &mut |_| {})
    }

    /// Pop the next batch: the queue-head request plus every immediately
    /// following *compatible* request, up to `max_batch`. Blocks until the
    /// head has waited `max_wait` (or the batch is full, or the next
    /// request is incompatible). Returns `None` when closed and drained.
    /// Expired requests are shed to `shed` on every wakeup — they never
    /// appear in a returned batch, and the callbacks run with the queue
    /// lock released so a slow shed reply cannot stall sibling workers.
    pub fn pop_batch_shedding(
        &self,
        shed: &mut dyn FnMut(Request<T>),
    ) -> Option<Vec<Request<T>>> {
        let mut g = lock_ok(&self.inner);
        loop {
            let expired = g.take_expired();
            if !expired.is_empty() {
                drop(g);
                for r in expired {
                    shed(r);
                }
                g = lock_ok(&self.inner);
                continue;
            }
            if let Some(head) = g.queue.front() {
                let head_mode = head.mode;
                let deadline = head.enqueued + self.max_wait;
                // How many consecutive compatible requests are queued?
                let compat = g
                    .queue
                    .iter()
                    .take(self.max_batch)
                    .take_while(|r| r.mode.batchable_with(&head_mode))
                    .count()
                    .max(1);
                let solo = !head_mode.batchable_with(&head_mode); // beam/SBS go at once
                // An incompatible request right behind the run means no
                // further compatible arrivals can join (FIFO): ship now.
                let blocked = compat < g.queue.len();
                // Closed queues drain eagerly: no new arrival can join,
                // so waiting out `max_wait` would only stretch the drain.
                let full = solo || blocked || compat >= self.max_batch || g.closed;
                if full || Instant::now() >= deadline {
                    let take = compat.min(self.max_batch);
                    let batch: Vec<Request<T>> = g.queue.drain(..take).collect();
                    return Some(batch);
                }
                let wait = deadline.saturating_duration_since(Instant::now());
                let (g2, _) = self
                    .cv
                    .wait_timeout(g, wait)
                    .unwrap_or_else(|e| e.into_inner());
                g = g2;
            } else if g.closed {
                return None;
            } else {
                g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
        }
    }

    /// [`RequestQueue::pop_batch_shedding`] with expired requests
    /// silently dropped (test/compat convenience).
    pub fn pop_batch(&self) -> Option<Vec<Request<T>>> {
        self.pop_batch_shedding(&mut |_| {})
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for s in ["greedy", "spec:10", "bs:5", "sbs:25:10"] {
            let m = DecodeMode::parse(s).unwrap();
            assert_eq!(m.to_string(), s);
        }
        assert!(DecodeMode::parse("nope").is_none());
        assert!(DecodeMode::parse("sbs:x:1").is_none());
    }

    #[test]
    fn cache_tags_discriminate_decoder_kinds() {
        let modes = [
            DecodeMode::Greedy,
            DecodeMode::SpecGreedy { dl: 4 },
            DecodeMode::SpecGreedy { dl: 10 },
            DecodeMode::Beam { n: 5 },
            DecodeMode::Sbs { n: 5, dl: 4 },
            DecodeMode::Sbs { n: 5, dl: 10 },
            DecodeMode::Sbs { n: 4, dl: 10 },
        ];
        for (i, a) in modes.iter().enumerate() {
            for (j, b) in modes.iter().enumerate() {
                assert_eq!(
                    a.cache_tag() == b.cache_tag(),
                    i == j,
                    "tag collision between {a} and {b}"
                );
            }
        }
    }

    #[test]
    fn batchable_classes() {
        let g = DecodeMode::Greedy;
        let s10 = DecodeMode::SpecGreedy { dl: 10 };
        let s4 = DecodeMode::SpecGreedy { dl: 4 };
        let b5 = DecodeMode::Beam { n: 5 };
        assert!(g.batchable_with(&g));
        assert!(s10.batchable_with(&s10));
        assert!(!s10.batchable_with(&s4));
        assert!(!b5.batchable_with(&b5)); // beams run solo
        assert!(!g.batchable_with(&s10));
    }

    #[test]
    fn pop_batch_groups_compatible_head_run() {
        let q: RequestQueue<usize> = RequestQueue::new(8, Duration::from_millis(1));
        q.push(DecodeMode::Greedy, 1);
        q.push(DecodeMode::Greedy, 2);
        q.push(DecodeMode::Beam { n: 5 }, 3);
        q.push(DecodeMode::Greedy, 4);
        let b1 = q.pop_batch().unwrap();
        assert_eq!(b1.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![1, 2]);
        let b2 = q.pop_batch().unwrap();
        assert_eq!(b2.len(), 1);
        assert_eq!(b2[0].payload, 3);
        let b3 = q.pop_batch().unwrap();
        assert_eq!(b3[0].payload, 4);
    }

    #[test]
    fn pop_batch_respects_max_batch() {
        let q: RequestQueue<usize> = RequestQueue::new(2, Duration::from_millis(1));
        for i in 0..5 {
            q.push(DecodeMode::Greedy, i);
        }
        assert_eq!(q.pop_batch().unwrap().len(), 2);
        assert_eq!(q.pop_batch().unwrap().len(), 2);
        assert_eq!(q.pop_batch().unwrap().len(), 1);
    }

    #[test]
    fn try_pop_compatible_respects_mode_max_and_fifo() {
        let q: RequestQueue<usize> = RequestQueue::new(8, Duration::from_millis(1));
        q.push(DecodeMode::Greedy, 1);
        q.push(DecodeMode::Greedy, 2);
        q.push(DecodeMode::Greedy, 3);
        q.push(DecodeMode::SpecGreedy { dl: 4 }, 4);
        q.push(DecodeMode::Greedy, 5);

        // Cap respected, FIFO order kept.
        let got = q.try_pop_compatible(DecodeMode::Greedy, 2);
        assert_eq!(got.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![1, 2]);
        // Stops at the first incompatible request even with budget left.
        let got = q.try_pop_compatible(DecodeMode::Greedy, 8);
        assert_eq!(got.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![3]);
        // Head is now spec:4 — greedy admission gets nothing (never
        // reorders across classes), spec admission drains it.
        assert!(q.try_pop_compatible(DecodeMode::Greedy, 8).is_empty());
        assert!(q.try_pop_compatible(DecodeMode::Greedy, 0).is_empty());
        let got = q.try_pop_compatible(DecodeMode::SpecGreedy { dl: 4 }, 8);
        assert_eq!(got.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![4]);
        // Beam requests are never batchable, even with themselves.
        q.push(DecodeMode::Beam { n: 5 }, 6);
        assert_eq!(q.len(), 2);
        assert!(q
            .try_pop_compatible(DecodeMode::Beam { n: 5 }, 8)
            .is_empty());
    }

    #[test]
    fn fifo_never_reorders_across_classes() {
        let q: RequestQueue<usize> = RequestQueue::new(8, Duration::from_millis(1));
        q.push(DecodeMode::Beam { n: 5 }, 1);
        q.push(DecodeMode::Greedy, 2);
        let b1 = q.pop_batch().unwrap();
        assert_eq!(b1[0].payload, 1); // beam first even though greedy waits
    }

    #[test]
    fn close_drains_then_none() {
        let q: RequestQueue<usize> = RequestQueue::new(8, Duration::from_millis(1));
        q.push(DecodeMode::Greedy, 7);
        q.close();
        assert_eq!(q.pop_batch().unwrap()[0].payload, 7);
        assert!(q.pop_batch().is_none());
    }

    #[test]
    fn no_request_lost_under_concurrency() {
        use std::sync::Arc;
        let q: Arc<RequestQueue<usize>> = Arc::new(RequestQueue::new(4, Duration::from_millis(1)));
        let producer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..200 {
                    q.push(
                        if i % 3 == 0 {
                            DecodeMode::Beam { n: 2 }
                        } else {
                            DecodeMode::Greedy
                        },
                        i,
                    );
                }
                q.close();
            })
        };
        let mut seen = Vec::new();
        while let Some(batch) = q.pop_batch() {
            for r in batch {
                seen.push(r.payload);
            }
        }
        producer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, (0..200).collect::<Vec<_>>());
    }

    #[test]
    fn try_push_full_returns_busy_not_silent_drop() {
        let q: RequestQueue<usize> = RequestQueue::with_capacity(8, Duration::from_millis(1), 2);
        assert!(q.try_push(DecodeMode::Greedy, 1, None).is_ok());
        assert!(q.try_push(DecodeMode::Greedy, 2, None).is_ok());
        // The refusal hands the payload back — nothing is lost.
        match q.try_push(DecodeMode::Greedy, 3, None) {
            Err(PushError::Full(p)) => assert_eq!(p, 3),
            _ => panic!("over-capacity admission must return Full"),
        }
        assert_eq!(q.len(), 2);
        // Draining frees capacity again.
        let _ = q.pop_batch().unwrap();
        assert!(q.try_push(DecodeMode::Greedy, 4, None).is_ok());
    }

    #[test]
    fn try_push_after_close_returns_closed() {
        let q: RequestQueue<usize> = RequestQueue::with_capacity(8, Duration::from_millis(1), 8);
        q.close();
        match q.try_push(DecodeMode::Greedy, 1, None) {
            Err(PushError::Closed(p)) => assert_eq!(p, 1),
            _ => panic!("admission after close must return Closed"),
        }
        assert!(q.is_closed());
    }

    #[test]
    fn expired_requests_are_shed_at_pop_never_batched() {
        let q: RequestQueue<usize> = RequestQueue::with_capacity(8, Duration::from_millis(1), 8);
        let past = Instant::now() - Duration::from_millis(5);
        let future = Instant::now() + Duration::from_secs(60);
        q.try_push(DecodeMode::Greedy, 1, Some(past)).unwrap();
        q.try_push(DecodeMode::Greedy, 2, Some(future)).unwrap();
        q.try_push(DecodeMode::Greedy, 3, Some(past)).unwrap();
        let mut shed = Vec::new();
        let batch = q.pop_batch_shedding(&mut |r| shed.push(r.payload)).unwrap();
        assert_eq!(batch.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![2]);
        shed.sort_unstable();
        assert_eq!(shed, vec![1, 3], "expired requests must reach the shed handler");
    }

    #[test]
    fn try_pop_compatible_sheds_expired_first() {
        let q: RequestQueue<usize> = RequestQueue::with_capacity(8, Duration::from_millis(1), 8);
        let past = Instant::now() - Duration::from_millis(5);
        q.try_push(DecodeMode::Greedy, 1, Some(past)).unwrap();
        q.try_push(DecodeMode::Greedy, 2, None).unwrap();
        let mut shed = Vec::new();
        let got = q.try_pop_compatible_shedding(DecodeMode::Greedy, 8, &mut |r| {
            shed.push(r.payload)
        });
        assert_eq!(got.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![2]);
        assert_eq!(shed, vec![1]);
        // max == 0 still sheds (admission with no lane budget must not
        // let expired work sit in the queue).
        q.try_push(DecodeMode::Greedy, 4, Some(past)).unwrap();
        let mut shed2 = Vec::new();
        let got = q.try_pop_compatible_shedding(DecodeMode::Greedy, 0, &mut |r| {
            shed2.push(r.payload)
        });
        assert!(got.is_empty());
        assert_eq!(shed2, vec![4]);
    }

    #[test]
    fn expired_head_does_not_block_live_tail() {
        // An expired head must not stall pop_batch for max_wait, and the
        // batch behind it must come out whole.
        let q: RequestQueue<usize> = RequestQueue::with_capacity(8, Duration::from_secs(3600), 8);
        let past = Instant::now() - Duration::from_millis(5);
        q.try_push(DecodeMode::Beam { n: 2 }, 1, Some(past)).unwrap();
        q.try_push(DecodeMode::Greedy, 2, None).unwrap();
        q.try_push(DecodeMode::Greedy, 3, None).unwrap();
        let mut shed = Vec::new();
        let t0 = Instant::now();
        let batch = q.pop_batch_shedding(&mut |r| shed.push(r.payload)).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(60));
        assert_eq!(shed, vec![1]);
        assert_eq!(
            batch.iter().map(|r| r.payload).collect::<Vec<_>>(),
            vec![2, 3]
        );
    }

    #[test]
    fn occupancy_tracks_capacity() {
        let q: RequestQueue<usize> = RequestQueue::with_capacity(8, Duration::from_millis(1), 4);
        assert_eq!(q.occupancy(), 0.0);
        q.push(DecodeMode::Greedy, 1);
        q.push(DecodeMode::Greedy, 2);
        assert!((q.occupancy() - 0.5).abs() < 1e-12);
        let unbounded: RequestQueue<usize> = RequestQueue::new(8, Duration::from_millis(1));
        unbounded.push(DecodeMode::Greedy, 1);
        assert_eq!(unbounded.occupancy(), 0.0);
    }

    #[test]
    fn admission_ids_are_unique_and_monotonic() {
        let q: RequestQueue<usize> = RequestQueue::with_capacity(8, Duration::from_millis(1), 8);
        q.push(DecodeMode::Greedy, 1);
        q.try_push(DecodeMode::Greedy, 2, None).unwrap();
        q.push(DecodeMode::Greedy, 3);
        let batch = q.pop_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        // A refused admission must not burn an id.
        let full: RequestQueue<usize> = RequestQueue::with_capacity(8, Duration::from_millis(1), 1);
        full.try_push(DecodeMode::Greedy, 1, None).unwrap();
        assert!(full.try_push(DecodeMode::Greedy, 2, None).is_err());
        full.pop_batch().unwrap();
        full.try_push(DecodeMode::Greedy, 3, None).unwrap();
        assert_eq!(full.pop_batch().unwrap()[0].id, 2);
    }

    #[test]
    fn requeue_front_keeps_id_and_works_on_a_closed_full_queue() {
        let q: RequestQueue<usize> = RequestQueue::with_capacity(8, Duration::from_millis(1), 1);
        q.try_push(DecodeMode::Greedy, 1, None).unwrap();
        let reclaimed = q.pop_batch().unwrap().remove(0);
        assert_eq!(reclaimed.id, 1);
        // Fill to capacity and close: a reclaim must still land, at the
        // head, with its original id.
        q.try_push(DecodeMode::Greedy, 2, None).unwrap();
        q.close();
        q.requeue_front(reclaimed);
        assert_eq!(q.len(), 2);
        let batch = q.pop_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(batch[0].id, 1);
        assert!(q.pop_batch().is_none(), "closed queue still drains to None");
    }

    /// The shed callback runs outside the queue lock: it may call back
    /// into the queue (here: push a replacement and read the length)
    /// without deadlocking. Under the old under-the-lock contract this
    /// test would hang on the non-reentrant mutex.
    #[test]
    fn shed_callbacks_run_outside_the_queue_lock() {
        let q: RequestQueue<usize> = RequestQueue::with_capacity(8, Duration::from_millis(1), 8);
        let past = Instant::now() - Duration::from_millis(5);
        q.try_push(DecodeMode::Greedy, 1, Some(past)).unwrap();
        q.try_push(DecodeMode::Greedy, 2, None).unwrap();
        let mut shed = Vec::new();
        let batch = q.pop_batch_shedding(&mut |r| {
            let _ = q.len(); // reentrant query — deadlocks if locked
            q.push(DecodeMode::Greedy, 100 + r.payload);
            shed.push(r.payload);
        });
        assert_eq!(shed, vec![1]);
        // The replacement pushed during shedding is live again by the
        // time the pop resumes, so it comes out with the batch.
        let mut seen: Vec<usize> = batch.unwrap().iter().map(|r| r.payload).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![2, 101]);
        // Same contract on the non-blocking admission path.
        q.try_push(DecodeMode::Greedy, 3, Some(past)).unwrap();
        let mut shed2 = Vec::new();
        let got = q.try_pop_compatible_shedding(DecodeMode::Greedy, 8, &mut |r| {
            let _ = q.len();
            shed2.push(r.payload);
        });
        assert!(got.is_empty());
        assert_eq!(shed2, vec![3]);
    }

    /// Concurrent close vs try_pop_compatible: every pushed request is
    /// either popped by the scavenger or drained after close — none
    /// lost, none duplicated, no deadlock.
    #[test]
    fn concurrent_close_vs_try_pop_compatible() {
        use std::sync::Arc;
        for _round in 0..8 {
            let q: Arc<RequestQueue<usize>> =
                Arc::new(RequestQueue::new(4, Duration::from_millis(1)));
            let n = 100usize;
            let producer = {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..n {
                        q.push(DecodeMode::Greedy, i);
                        if i == n / 2 {
                            std::thread::yield_now();
                        }
                    }
                    q.close();
                })
            };
            let scavenger = {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        let batch = q.try_pop_compatible(DecodeMode::Greedy, 3);
                        let drained = batch.is_empty();
                        got.extend(batch.into_iter().map(|r| r.payload));
                        if drained && q.is_closed() && q.is_empty() {
                            return got;
                        }
                        std::thread::yield_now();
                    }
                })
            };
            let mut seen = scavenger.join().unwrap();
            producer.join().unwrap();
            // try_pop_compatible after close still drains (close stops
            // admissions, not consumption).
            seen.extend(
                q.try_pop_compatible(DecodeMode::Greedy, n)
                    .into_iter()
                    .map(|r| r.payload),
            );
            seen.sort_unstable();
            assert_eq!(seen, (0..n).collect::<Vec<_>>());
        }
    }
}
