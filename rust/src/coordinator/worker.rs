//! The model worker: a single thread owning the backend, draining the
//! request queue batch by batch.
//!
//! One worker is the right shape for this testbed (one PJRT CPU device;
//! XLA already uses the cores a single executable can use). The queue +
//! worker split still gives the serving properties that matter: FIFO
//! fairness, dynamic batching, and backpressure (bounded queue wait shows
//! up in metrics rather than in stalled sockets).
//!
//! Greedy and speculative-greedy batches run as **live decoding
//! sessions** ([`GreedyRun`] / [`SpecGreedyRun`]): the session stays
//! alive across batching ticks, finished lanes reply immediately, and
//! compatible requests that arrive mid-decode are admitted into the
//! running session (`RequestQueue::try_pop_compatible`) instead of
//! waiting behind the whole batch — continuous batching. Beam and SBS
//! requests still run solo (their effective batch is already
//! beams × drafts).
//!
//! Cross-request reuse rides through a [`ServeCache`]: every request is
//! checked against the result cache *before admission* (initial batch
//! members and mid-session newcomers alike — a hit replies instantly and
//! never occupies a lane), every completed prediction is memoized, its
//! accepted target feeds the corpus [`DraftStore`](crate::cache::DraftStore),
//! and the speculative decoders draft from the store's top windows on the
//! next request.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::Result;

use crate::cache::{CachedPrediction, ServeCache};
use crate::coordinator::batcher::{DecodeMode, Request, RequestQueue};
use crate::coordinator::metrics::Metrics;
use crate::decoding::{beam_search, sbs, Backend, GreedyRun, SbsConfig, SpecGreedyRun};
use crate::draft::{Acceptance, DraftConfig};
use crate::trace::{self, Phase};
use crate::trace_span;
use crate::vocab::Vocab;

/// Synthetic trace-track allocator: each traced request gets its own
/// Perfetto row, since request intervals overlap on the worker thread.
static REQ_TRACK: AtomicU64 = AtomicU64::new(0);

/// Record a request's queue residency onto its trace track (ending now)
/// and return the admission timestamp for the later `Request` span.
fn trace_admission(enqueued: Instant, track: u64) -> u64 {
    if !trace::enabled() {
        return 0;
    }
    let now = trace::now_ns();
    let wait_ns = enqueued.elapsed().as_nanos() as u64;
    trace::record_manual(Phase::QueueWait, now.saturating_sub(wait_ns), now, 0, track);
    now
}

/// Close a request's trace track: the whole-request span plus a
/// worst-N exemplar offer.
fn trace_completion(t_admit_ns: u64, track: u64, payload: u64) {
    if !trace::enabled() {
        return;
    }
    let now = trace::now_ns();
    trace::record_manual(Phase::Request, t_admit_ns, now, payload, track);
    trace::note_request(&format!("req-{track}"), t_admit_ns, now);
}

/// One unit of serving work: a query SMILES and a reply channel.
pub struct Job {
    pub smiles: String,
    pub resp: mpsc::Sender<JobResult>,
}

/// What the worker sends back.
pub type JobResult = Result<Reply, String>;

/// A successful decode: (SMILES, cumulative log-prob) pairs, best first.
#[derive(Debug, Clone)]
pub struct Reply {
    pub hyps: Vec<(String, f64)>,
    pub decoder_calls: usize,
    pub acceptance_rate: f64,
}

/// Drain the queue until it is closed. Runs on its own thread.
pub fn run_worker<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
) {
    while let Some(batch) = queue.pop_batch() {
        let now = Instant::now();
        for r in &batch {
            metrics
                .queue_wait
                .record(now.duration_since(r.enqueued));
        }
        // batches / batched_requests count actual decode admissions (in
        // stream_batch / solo_batch), so cache hits — which never occupy
        // a lane — don't distort the mean-batch metric in either
        // direction.
        process_batch(backend, vocab, batch, queue, metrics, cache);
    }
}

/// Consult the result cache for one admitted request. On a hit the reply
/// is sent verbatim (bit-identical to the run that produced the entry,
/// with zero decoder calls) and `true` is returned so the caller skips
/// decoding entirely.
fn try_cache_reply(
    cache: &ServeCache,
    metrics: &Metrics,
    mode: DecodeMode,
    ids: &[i64],
    r: &Request<Job>,
) -> bool {
    if !cache.enabled() {
        return false;
    }
    match cache.results().get(mode.cache_tag(), ids) {
        Some(pred) => {
            metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
            metrics.requests_total.fetch_add(1, Ordering::Relaxed);
            let _ = r.payload.resp.send(Ok(Reply {
                hyps: pred.hyps,
                decoder_calls: 0,
                acceptance_rate: pred.acceptance_rate,
            }));
            true
        }
        None => {
            metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
            false
        }
    }
}

/// Memoize a completed prediction and mine its accepted target into the
/// corpus draft store.
fn record_completion(
    cache: &ServeCache,
    metrics: &Metrics,
    mode: DecodeMode,
    ids: &[i64],
    hyps: &[(String, f64)],
    top_tokens: &[i64],
    acceptance_rate: f64,
) {
    if !cache.enabled() {
        return;
    }
    let evicted = cache.results().insert(
        mode.cache_tag(),
        ids.to_vec(),
        CachedPrediction {
            hyps: hyps.to_vec(),
            acceptance_rate,
        },
    );
    metrics.cache_inserts.fetch_add(1, Ordering::Relaxed);
    metrics.cache_evictions.fetch_add(evicted, Ordering::Relaxed);
    cache.drafts().record(top_tokens);
}

/// Encode one request's SMILES, failing the request over its channel on
/// bad input. Returns the wrapped token ids on success.
fn validate<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    r: &Request<Job>,
    metrics: &Arc<Metrics>,
) -> Option<Vec<i64>> {
    match vocab.encode_wrapped(&r.payload.smiles) {
        Ok(ids) if ids.len() <= backend.dims().s_len => Some(ids),
        Ok(_) => {
            let _ = r.payload.resp.send(Err("query too long".to_string()));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            None
        }
        Err(e) => {
            let _ = r.payload.resp.send(Err(format!("bad SMILES: {e}")));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

fn process_batch<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    batch: Vec<Request<Job>>,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
) {
    let mode = batch[0].mode;
    match mode {
        DecodeMode::Greedy | DecodeMode::SpecGreedy { .. } => {
            stream_batch(backend, vocab, batch, queue, metrics, cache, mode)
        }
        DecodeMode::Beam { .. } | DecodeMode::Sbs { .. } => {
            solo_batch(backend, vocab, batch, metrics, cache, mode)
        }
    }
}

/// Beam / SBS: the batcher hands us one request at a time.
fn solo_batch<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    batch: Vec<Request<Job>>,
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
    mode: DecodeMode,
) {
    for r in &batch {
        let Some(src) = validate(backend, vocab, r, metrics) else {
            continue;
        };
        if try_cache_reply(cache, metrics, mode, &src, r) {
            continue;
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batched_requests.fetch_add(1, Ordering::Relaxed);
        let track = REQ_TRACK.fetch_add(1, Ordering::Relaxed);
        let t_admit_ns = trace_admission(r.enqueued, track);
        let t0 = Instant::now();
        let _tick = trace_span!(Phase::BatchTick, 1);
        let out = match mode {
            DecodeMode::Beam { n } => beam_search(backend, &src, n),
            DecodeMode::Sbs { n, dl } => {
                let mut cfg = SbsConfig::new(n, dl);
                // Empty unless the operator opted in: accepted corpus
                // windows can reorder SBS's candidate frontier, and the
                // serving default keeps outputs bit-identical to the
                // cold path (greedy-spec corpus drafts are always safe).
                cfg.corpus_drafts = cache.corpus_drafts_for_sbs();
                sbs(backend, &src, &cfg)
            }
            _ => unreachable!("solo_batch only handles beam/sbs"),
        };
        match out {
            Ok(out) => {
                metrics
                    .tokens_generated
                    .fetch_add(out.stats.acceptance.total_tokens as u64, Ordering::Relaxed);
                metrics.draft_tokens_accepted.fetch_add(
                    out.stats.acceptance.accepted_draft_tokens as u64,
                    Ordering::Relaxed,
                );
                metrics.draft_accepted_query.fetch_add(
                    out.stats.accepted_query_tokens as u64,
                    Ordering::Relaxed,
                );
                metrics.draft_accepted_corpus.fetch_add(
                    out.stats.accepted_corpus_tokens as u64,
                    Ordering::Relaxed,
                );
                metrics
                    .decoder_calls
                    .fetch_add(out.stats.decoder_calls as u64, Ordering::Relaxed);
                metrics.requests_total.fetch_add(1, Ordering::Relaxed);
                let reply = Reply {
                    hyps: out
                        .hyps
                        .iter()
                        .map(|h| (vocab.decode(&h.tokens), h.score))
                        .collect(),
                    decoder_calls: out.stats.decoder_calls,
                    acceptance_rate: out.stats.acceptance.rate(),
                };
                if let Some(top) = out.hyps.first() {
                    record_completion(
                        cache,
                        metrics,
                        mode,
                        &src,
                        &reply.hyps,
                        &top.tokens,
                        reply.acceptance_rate,
                    );
                }
                let _ = r.payload.resp.send(Ok(reply));
            }
            Err(e) => {
                let _ = r
                    .payload
                    .resp
                    .send(Err(format!("decode failed: {e}")));
                metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        metrics.decode_latency.record(t0.elapsed());
        drop(_tick);
        trace_completion(t_admit_ns, track, 1);
    }
}

/// Either incremental run type behind one dispatch surface.
enum Run<'a> {
    Greedy(GreedyRun<'a>),
    Spec(SpecGreedyRun<'a>),
}

impl<'a> Run<'a> {
    fn admit(&mut self, mem_row: usize, src: &[i64]) -> usize {
        match self {
            Run::Greedy(r) => r.admit(mem_row),
            Run::Spec(r) => r.admit(mem_row, src),
        }
    }

    fn append_memory(&mut self, extra: &crate::decoding::Memory) -> usize {
        match self {
            Run::Greedy(r) => r.session_mut().append_memory(extra),
            Run::Spec(r) => r.session_mut().append_memory(extra),
        }
    }

    fn step(&mut self) -> Result<Vec<usize>> {
        match self {
            Run::Greedy(r) => r.step(),
            Run::Spec(r) => r.step(),
        }
    }

    fn finished(&self) -> bool {
        match self {
            Run::Greedy(r) => r.finished(),
            Run::Spec(r) => r.finished(),
        }
    }

    fn n_live(&self) -> usize {
        match self {
            Run::Greedy(r) => r.n_live(),
            Run::Spec(r) => r.n_live(),
        }
    }

    fn calls(&self) -> usize {
        match self {
            Run::Greedy(r) => r.calls(),
            Run::Spec(r) => r.calls(),
        }
    }

    fn session_stats(&self) -> crate::decoding::SessionStats {
        match self {
            Run::Greedy(r) => r.session_stats(),
            Run::Spec(r) => r.session_stats(),
        }
    }

    fn hyp_and_acceptance(&self, lane: usize) -> (crate::decoding::Hypothesis, Acceptance) {
        match self {
            Run::Greedy(r) => {
                let h = r.hypothesis(lane);
                let acc = Acceptance {
                    accepted_draft_tokens: 0,
                    total_tokens: h.tokens.len(),
                };
                (h, acc)
            }
            Run::Spec(r) => (r.hypothesis(lane), r.lane_acceptance(lane)),
        }
    }

    /// Accepted-token split `(query_copy, corpus)` for one lane.
    fn source_acceptance(&self, lane: usize) -> (usize, usize) {
        match self {
            Run::Greedy(_) => (0, 0),
            Run::Spec(r) => r.lane_source_acceptance(lane),
        }
    }
}

/// Greedy / speculative-greedy: run a live session, replying per lane as
/// it finishes and admitting compatible newcomers between steps.
fn stream_batch<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    batch: Vec<Request<Job>>,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
    mode: DecodeMode,
) {
    let max_lanes = queue.max_batch.max(1);

    // Validate and encode the initial batch; cache hits reply now and
    // never occupy a lane.
    let mut valid: Vec<(Request<Job>, Vec<i64>)> = Vec::new();
    for r in batch {
        let Some(ids) = validate(backend, vocab, &r, metrics) else {
            continue;
        };
        if try_cache_reply(cache, metrics, mode, &ids, &r) {
            continue;
        }
        metrics.batched_requests.fetch_add(1, Ordering::Relaxed);
        valid.push((r, ids));
    }
    if valid.is_empty() {
        return;
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    let refs: Vec<&[i64]> = valid.iter().map(|(_, ids)| ids.as_slice()).collect();
    let fail_all = |valid: &[(Request<Job>, Vec<i64>)], e: String| {
        for (r, _) in valid {
            let _ = r.payload.resp.send(Err(e.clone()));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
        }
    };
    let memory = match backend.encode(&refs) {
        Ok(m) => m,
        Err(e) => return fail_all(&valid, format!("encode failed: {e}")),
    };
    let sess = match backend.begin(memory) {
        Ok(s) => s,
        Err(e) => return fail_all(&valid, format!("session failed: {e}")),
    };
    let mut run = match mode {
        DecodeMode::SpecGreedy { dl } => Run::Spec(SpecGreedyRun::with_corpus(
            sess,
            DraftConfig::new(dl),
            cache.corpus_drafts(),
        )),
        _ => Run::Greedy(GreedyRun::new(sess)),
    };

    // Lane bookkeeping: reply channel, per-request decode timer, the
    // session call count at admission (so the per-request decoder_calls
    // stat covers only this request's lifetime), replied?, and the
    // encoded query (the completion's cache key).
    struct LaneCtx {
        resp: mpsc::Sender<JobResult>,
        t0: Instant,
        calls_at_admit: usize,
        replied: bool,
        ids: Vec<i64>,
        /// Synthetic trace track and admission timestamp — request
        /// intervals overlap on this thread, so each lane records its
        /// whole-request span manually onto its own track.
        track: u64,
        t_admit_ns: u64,
    }
    let mut lanes: Vec<LaneCtx> = Vec::new();
    for (i, (r, ids)) in valid.iter().enumerate() {
        let lane = run.admit(i, ids);
        debug_assert_eq!(lane, lanes.len());
        let track = REQ_TRACK.fetch_add(1, Ordering::Relaxed);
        lanes.push(LaneCtx {
            resp: r.payload.resp.clone(),
            t0: Instant::now(),
            calls_at_admit: run.calls(),
            replied: false,
            ids: ids.clone(),
            track,
            t_admit_ns: trace_admission(r.enqueued, track),
        });
    }
    drop(valid);

    // A session's encoder memory and cross-attention caches grow with
    // every admitted query and are only reclaimed when the session
    // drops, so a live session must not serve unboundedly many
    // requests. After this many admissions the session drains and
    // returns; remaining queued work starts a fresh session via the
    // next `pop_batch` tick.
    let max_session_admissions = max_lanes.saturating_mul(8);

    loop {
        let step_res = {
            let _tick = trace_span!(Phase::BatchTick, run.n_live() as u64);
            run.step()
        };
        let finished = match step_res {
            Ok(f) => f,
            Err(e) => {
                // Finished lanes already replied; fail the rest.
                for l in lanes.iter().filter(|l| !l.replied) {
                    let _ = l.resp.send(Err(format!("decode failed: {e}")));
                    metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
                }
                return;
            }
        };
        for li in finished {
            let (hyp, acc) = run.hyp_and_acceptance(li);
            let (src_q, src_c) = run.source_acceptance(li);
            metrics
                .tokens_generated
                .fetch_add(acc.total_tokens as u64, Ordering::Relaxed);
            metrics
                .draft_tokens_accepted
                .fetch_add(acc.accepted_draft_tokens as u64, Ordering::Relaxed);
            metrics
                .draft_accepted_query
                .fetch_add(src_q as u64, Ordering::Relaxed);
            metrics
                .draft_accepted_corpus
                .fetch_add(src_c as u64, Ordering::Relaxed);
            metrics.requests_total.fetch_add(1, Ordering::Relaxed);
            let reply = Reply {
                hyps: vec![(vocab.decode(&hyp.tokens), hyp.score)],
                decoder_calls: run.calls() - lanes[li].calls_at_admit,
                acceptance_rate: acc.rate(),
            };
            record_completion(
                cache,
                metrics,
                mode,
                &lanes[li].ids,
                &reply.hyps,
                &hyp.tokens,
                reply.acceptance_rate,
            );
            let _ = lanes[li].resp.send(Ok(reply));
            lanes[li].replied = true;
            metrics.decode_latency.record(lanes[li].t0.elapsed());
            trace_completion(
                lanes[li].t_admit_ns,
                lanes[li].track,
                (run.calls() - lanes[li].calls_at_admit) as u64,
            );
        }

        // Continuous batching: admit compatible newcomers into the live
        // session while there is lane budget and the session is young
        // enough that its per-query caches stay bounded.
        let free = max_lanes
            .saturating_sub(run.n_live())
            .min(max_session_admissions.saturating_sub(lanes.len()));
        let newcomers = queue.try_pop_compatible(mode, free);
        if !newcomers.is_empty() {
            let _adm_span = trace_span!(Phase::Admission, newcomers.len() as u64);
            let now = Instant::now();
            let mut adm: Vec<(Request<Job>, Vec<i64>)> = Vec::new();
            for r in newcomers {
                metrics.queue_wait.record(now.duration_since(r.enqueued));
                let Some(ids) = validate(backend, vocab, &r, metrics) else {
                    continue;
                };
                if try_cache_reply(cache, metrics, mode, &ids, &r) {
                    continue;
                }
                metrics.batched_requests.fetch_add(1, Ordering::Relaxed);
                adm.push((r, ids));
            }
            if !adm.is_empty() {
                let refs: Vec<&[i64]> = adm.iter().map(|(_, ids)| ids.as_slice()).collect();
                match backend.encode(&refs) {
                    Ok(extra) => {
                        let base = run.append_memory(&extra);
                        for (k, (r, ids)) in adm.iter().enumerate() {
                            let lane = run.admit(base + k, ids);
                            debug_assert_eq!(lane, lanes.len());
                            let track = REQ_TRACK.fetch_add(1, Ordering::Relaxed);
                            lanes.push(LaneCtx {
                                resp: r.payload.resp.clone(),
                                t0: Instant::now(),
                                calls_at_admit: run.calls(),
                                replied: false,
                                ids: ids.clone(),
                                track,
                                t_admit_ns: trace_admission(r.enqueued, track),
                            });
                        }
                    }
                    Err(e) => fail_all(&adm, format!("encode failed: {e}")),
                }
            }
        }

        if run.finished() {
            metrics
                .decoder_calls
                .fetch_add(run.calls() as u64, Ordering::Relaxed);
            // Kernel-layer + arena accounting: every step() was one
            // fused extend over all live lanes. The field-by-field
            // mapping lives in `Metrics::absorb_session`, not here.
            metrics.absorb_session(&run.session_stats());
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::CopyModel;
    use std::time::Duration;

    fn tiny_vocab() -> Vocab {
        Vocab::build(["CCONF", "c1ccccc1"]).unwrap()
    }

    fn send_job(queue: &RequestQueue<Job>, mode: DecodeMode, smiles: &str) -> mpsc::Receiver<JobResult> {
        let (tx, rx) = mpsc::channel();
        queue.push(
            mode,
            Job {
                smiles: smiles.to_string(),
                resp: tx,
            },
        );
        rx
    }

    #[test]
    fn worker_round_trips_greedy_jobs() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::default();

        let rx1 = send_job(&queue, DecodeMode::Greedy, "CCO");
        let rx2 = send_job(&queue, DecodeMode::SpecGreedy { dl: 2 }, "c1ccccc1");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &cache);

        // CopyModel regenerates the source tokens.
        let r1 = rx1.recv().unwrap().unwrap();
        assert_eq!(r1.hyps[0].0, "CCO");
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r2.hyps[0].0, "c1ccccc1");
        assert!(metrics.requests_total.load(Ordering::Relaxed) == 2);
        // Both completions were memoized and mined for draft windows.
        assert_eq!(metrics.cache_inserts.load(Ordering::Relaxed), 2);
        assert_eq!(cache.results().len(), 2);
    }

    #[test]
    fn worker_reports_bad_smiles() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let rx = send_job(&queue, DecodeMode::Greedy, "C C O");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &ServeCache::default());
        assert!(rx.recv().unwrap().is_err());
        assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn worker_handles_beam_and_sbs_modes() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let rx1 = send_job(&queue, DecodeMode::Beam { n: 3 }, "CCO");
        let rx2 = send_job(&queue, DecodeMode::Sbs { n: 3, dl: 4 }, "CCO");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &ServeCache::default());
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r1.hyps[0].0, "CCO");
        assert_eq!(r2.hyps[0].0, "CCO");
        assert!(!r2.hyps.is_empty());
    }

    /// The session-alive-across-ticks behaviour, deterministically: a
    /// request that arrives *after* the batch was popped is admitted
    /// into the running session by `process_batch` itself.
    #[test]
    fn late_request_joins_live_session() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::default();

        let rx1 = send_job(&queue, DecodeMode::Greedy, "c1ccccc1");
        let batch = queue.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        // Arrives between batching ticks — after pop, before decode ends.
        let rx2 = send_job(&queue, DecodeMode::Greedy, "CCO");
        process_batch(&backend, &vocab, batch, &queue, &metrics, &cache);

        assert_eq!(rx1.recv().unwrap().unwrap().hyps[0].0, "c1ccccc1");
        assert_eq!(
            rx2.recv().unwrap().unwrap().hyps[0].0,
            "CCO",
            "late request must be served by the same live session"
        );
        assert!(queue.is_empty(), "admission must drain the queue");
        assert_eq!(metrics.requests_total.load(Ordering::Relaxed), 2);
    }

    /// Incompatible work is never pulled into a live session.
    #[test]
    fn live_session_skips_incompatible_head() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());

        let rx1 = send_job(&queue, DecodeMode::Greedy, "CCO");
        let batch = queue.pop_batch().unwrap();
        let _rx2 = send_job(&queue, DecodeMode::Beam { n: 2 }, "CCO");
        process_batch(&backend, &vocab, batch, &queue, &metrics, &ServeCache::default());

        assert!(rx1.recv().unwrap().is_ok());
        assert_eq!(queue.len(), 1, "beam request must stay queued");
    }

    /// A repeated request is served from the result cache: zero decoder
    /// calls, reply bit-identical to the decoded one.
    #[test]
    fn repeat_request_hits_cache_with_identical_reply() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::default();

        let rx1 = send_job(&queue, DecodeMode::SpecGreedy { dl: 2 }, "c1ccccc1");
        let b1 = queue.pop_batch().unwrap();
        process_batch(&backend, &vocab, b1, &queue, &metrics, &cache);
        let r1 = rx1.recv().unwrap().unwrap();
        assert!(r1.decoder_calls > 0);

        let rx2 = send_job(&queue, DecodeMode::SpecGreedy { dl: 2 }, "c1ccccc1");
        let b2 = queue.pop_batch().unwrap();
        process_batch(&backend, &vocab, b2, &queue, &metrics, &cache);
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r2.decoder_calls, 0, "hit must skip decoding");
        assert_eq!(r2.hyps, r1.hyps, "cached reply must be bit-identical");
        assert_eq!(r2.acceptance_rate, r1.acceptance_rate);
        assert_eq!(metrics.cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.requests_total.load(Ordering::Relaxed), 2);

        // A different decoder kind over the same query is a miss.
        let rx3 = send_job(&queue, DecodeMode::Greedy, "c1ccccc1");
        let b3 = queue.pop_batch().unwrap();
        process_batch(&backend, &vocab, b3, &queue, &metrics, &cache);
        let r3 = rx3.recv().unwrap().unwrap();
        assert!(r3.decoder_calls > 0);
        assert_eq!(metrics.cache_hits.load(Ordering::Relaxed), 1);
    }

    /// Beam/SBS results are memoized too, and a disabled cache never
    /// hits, inserts, or records.
    #[test]
    fn solo_modes_memoize_and_disabled_cache_is_inert() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::default();

        // "c1ccccc1" decodes to 8 tokens — exactly one default-width
        // (8) draft-store window, so mining is observable.
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let rx1 = send_job(&queue, DecodeMode::Sbs { n: 2, dl: 4 }, "c1ccccc1");
        let rx2 = send_job(&queue, DecodeMode::Sbs { n: 2, dl: 4 }, "c1ccccc1");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &cache);
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r1.hyps, r2.hyps);
        assert_eq!(r2.decoder_calls, 0);
        assert!(!cache.drafts().is_empty(), "accepted target must be mined");

        let off = ServeCache::disabled();
        let metrics2 = Arc::new(Metrics::default());
        let queue2 = RequestQueue::new(8, Duration::from_millis(1));
        let rx3 = send_job(&queue2, DecodeMode::Greedy, "CCO");
        let rx4 = send_job(&queue2, DecodeMode::Greedy, "CCO");
        queue2.close();
        run_worker(&backend, &vocab, &queue2, &metrics2, &off);
        assert!(rx3.recv().unwrap().unwrap().decoder_calls > 0);
        assert!(rx4.recv().unwrap().unwrap().decoder_calls > 0);
        assert_eq!(metrics2.cache_hits.load(Ordering::Relaxed), 0);
        assert_eq!(metrics2.cache_inserts.load(Ordering::Relaxed), 0);
        assert!(off.results().is_empty());
        assert!(off.drafts().is_empty());
    }
}
