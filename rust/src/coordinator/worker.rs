//! The model worker: a single thread owning the backend, draining the
//! request queue batch by batch.
//!
//! One worker is the right shape for this testbed (one PJRT CPU device;
//! XLA already uses the cores a single executable can use). The queue +
//! worker split still gives the serving properties that matter: FIFO
//! fairness, dynamic batching, and backpressure (bounded queue wait shows
//! up in metrics rather than in stalled sockets).

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::batcher::{DecodeMode, Request, RequestQueue};
use crate::coordinator::metrics::Metrics;
use crate::decoding::{
    beam_search, greedy_batch, sbs, spec_greedy_batch, Backend, DecodeOutput, SbsConfig,
};
use crate::draft::DraftConfig;
use crate::vocab::Vocab;

/// One unit of serving work: a query SMILES and a reply channel.
pub struct Job {
    pub smiles: String,
    pub resp: mpsc::Sender<JobResult>,
}

/// What the worker sends back.
pub type JobResult = Result<Reply, String>;

/// A successful decode: (SMILES, cumulative log-prob) pairs, best first.
#[derive(Debug, Clone)]
pub struct Reply {
    pub hyps: Vec<(String, f64)>,
    pub decoder_calls: usize,
    pub acceptance_rate: f64,
}

/// Drain the queue until it is closed. Runs on its own thread.
pub fn run_worker<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
) {
    while let Some(batch) = queue.pop_batch() {
        let now = Instant::now();
        for r in &batch {
            metrics
                .queue_wait
                .record(now.duration_since(r.enqueued));
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics
            .batched_requests
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        process_batch(backend, vocab, batch, metrics);
    }
}

fn process_batch<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    batch: Vec<Request<Job>>,
    metrics: &Arc<Metrics>,
) {
    let mode = batch[0].mode;
    let t0 = Instant::now();

    // Encode queries; invalid SMILES fail fast per request.
    let mut srcs: Vec<Vec<i64>> = Vec::with_capacity(batch.len());
    let mut ok_idx: Vec<usize> = Vec::new();
    for (i, r) in batch.iter().enumerate() {
        match vocab.encode_wrapped(&r.payload.smiles) {
            Ok(ids) if ids.len() <= backend.dims().s_len => {
                srcs.push(ids);
                ok_idx.push(i);
            }
            Ok(_) => {
                let _ = r.payload.resp.send(Err("query too long".to_string()));
                metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                let _ = r.payload.resp.send(Err(format!("bad SMILES: {e}")));
                metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    if srcs.is_empty() {
        return;
    }
    let src_refs: Vec<&[i64]> = srcs.iter().map(|s| s.as_slice()).collect();

    let outputs: Result<Vec<DecodeOutput>> = match mode {
        DecodeMode::Greedy => greedy_batch(backend, &src_refs),
        DecodeMode::SpecGreedy { dl } => {
            spec_greedy_batch(backend, &src_refs, &DraftConfig::new(dl))
        }
        DecodeMode::Beam { n } => {
            // Solo class: the batcher hands us one request at a time.
            beam_search(backend, src_refs[0], n).map(|o| vec![o])
        }
        DecodeMode::Sbs { n, dl } => sbs(backend, src_refs[0], &SbsConfig::new(n, dl)).map(|o| vec![o]),
    };

    match outputs {
        Ok(outs) => {
            for (out, &bi) in outs.iter().zip(&ok_idx) {
                metrics
                    .tokens_generated
                    .fetch_add(out.stats.acceptance.total_tokens as u64, Ordering::Relaxed);
                metrics.draft_tokens_accepted.fetch_add(
                    out.stats.acceptance.accepted_draft_tokens as u64,
                    Ordering::Relaxed,
                );
                metrics
                    .decoder_calls
                    .fetch_add(out.stats.decoder_calls as u64, Ordering::Relaxed);
                metrics.requests_total.fetch_add(1, Ordering::Relaxed);
                let reply = Reply {
                    hyps: out
                        .hyps
                        .iter()
                        .map(|h| (vocab.decode(&h.tokens), h.score))
                        .collect(),
                    decoder_calls: out.stats.decoder_calls,
                    acceptance_rate: out.stats.acceptance.rate(),
                };
                let _ = batch[bi].payload.resp.send(Ok(reply));
            }
        }
        Err(e) => {
            for &bi in &ok_idx {
                let _ = batch[bi]
                    .payload
                    .resp
                    .send(Err(format!("decode failed: {e}")));
                metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    metrics.decode_latency.record(t0.elapsed());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::CopyModel;
    use std::time::Duration;

    fn tiny_vocab() -> Vocab {
        Vocab::build(["CCONF", "c1ccccc1"]).unwrap()
    }

    fn send_job(queue: &RequestQueue<Job>, mode: DecodeMode, smiles: &str) -> mpsc::Receiver<JobResult> {
        let (tx, rx) = mpsc::channel();
        queue.push(
            mode,
            Job {
                smiles: smiles.to_string(),
                resp: tx,
            },
        );
        rx
    }

    #[test]
    fn worker_round_trips_greedy_jobs() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());

        let rx1 = send_job(&queue, DecodeMode::Greedy, "CCO");
        let rx2 = send_job(&queue, DecodeMode::SpecGreedy { dl: 2 }, "c1ccccc1");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics);

        // CopyModel regenerates the source tokens.
        let r1 = rx1.recv().unwrap().unwrap();
        assert_eq!(r1.hyps[0].0, "CCO");
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r2.hyps[0].0, "c1ccccc1");
        assert!(metrics.requests_total.load(Ordering::Relaxed) == 2);
    }

    #[test]
    fn worker_reports_bad_smiles() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let rx = send_job(&queue, DecodeMode::Greedy, "C C O");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics);
        assert!(rx.recv().unwrap().is_err());
        assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn worker_handles_beam_and_sbs_modes() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let rx1 = send_job(&queue, DecodeMode::Beam { n: 3 }, "CCO");
        let rx2 = send_job(&queue, DecodeMode::Sbs { n: 3, dl: 4 }, "CCO");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics);
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r1.hyps[0].0, "CCO");
        assert_eq!(r2.hyps[0].0, "CCO");
        assert!(r2.hyps.len() >= 1);
    }
}
