//! The model worker: one thread owning a backend instance, draining the
//! shared request queue batch by batch.
//!
//! A worker runs either standalone ([`run_worker`], the single-device
//! shape) or as one member of the supervised pool in
//! [`pool`](crate::coordinator::pool): N workers pull from the **same**
//! `RequestQueue` and share one `ServeCache` (one result cache, one
//! draft store — windows mined by any worker speed up its siblings),
//! each with its own backend session pool. Either way the queue + worker
//! split gives the serving properties that matter: FIFO fairness,
//! dynamic batching, and backpressure (bounded queue wait shows up in
//! metrics rather than in stalled sockets).
//!
//! Pool membership adds two contracts, both carried by [`WorkerHealth`]:
//! a heartbeat (ticked every pop and every session step — a *busy*
//! worker with a stale heartbeat is wedged) and an in-flight registry
//! (every owned request, by admission id, so the supervisor can reclaim
//! the unreplied ones from a lost worker). Replies go through
//! [`ReplySlot`], which enforces **exactly one reply per request** even
//! when a reclaimed request is re-served while its original owner limps
//! to completion.
//!
//! Greedy and speculative-greedy batches run as **live decoding
//! sessions** ([`GreedyRun`] / [`SpecGreedyRun`]): the session stays
//! alive across batching ticks, finished lanes reply immediately, and
//! compatible requests that arrive mid-decode are admitted into the
//! running session (`RequestQueue::try_pop_compatible`) instead of
//! waiting behind the whole batch — continuous batching. Beam and SBS
//! requests still run solo (their effective batch is already
//! beams × drafts).
//!
//! Cross-request reuse rides through a [`ServeCache`]: every request is
//! checked against the result cache *before admission* (initial batch
//! members and mid-session newcomers alike — a hit replies instantly and
//! never occupies a lane), every completed prediction is memoized, its
//! accepted target feeds the corpus [`DraftStore`](crate::cache::DraftStore),
//! and the speculative decoders draft from the store's top windows on the
//! next request.
//!
//! # Fault tolerance
//!
//! The worker is **supervised**: every decode runs under `catch_unwind`,
//! so a panicking row — a backend bug, an injected fault, a poisoned
//! artifact — is contained to the batch that hit it. The poisoned
//! session is quarantined (dropped under its own `catch_unwind`), each
//! unreplied lane is retried **once** solo via the stateless-equivalent
//! free decoders (exact by the session-parity invariant; bounded backoff
//! first), and a second panic turns into an `ERR` for that one client.
//! The worker thread itself never dies.
//!
//! Deadlines and pressure: expired requests are shed at pop time with
//! `ERR deadline_exceeded` (they never occupy a lane), and sustained
//! queue pressure walks a degradation ladder — level 1 (≥½ capacity for
//! 3 consecutive ticks) drops corpus drafts, level 2 (≥⅞) drops
//! speculative drafts entirely. Both are output-neutral for the
//! greedy/spec-greedy paths (speculation is lossless for *any* draft
//! set); SBS keeps its configured draft depth because its candidate
//! frontier does depend on it. De-escalation is immediate when pressure
//! drops.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cache::{CachedPrediction, ServeCache};
use crate::coordinator::batcher::{lock_ok, DecodeMode, Request, RequestQueue};
use crate::coordinator::metrics::Metrics;
use crate::decoding::{
    beam_search, greedy, sbs, spec_greedy, Backend, GreedyRun, SbsConfig, SpecGreedyRun,
};
use crate::draft::{Acceptance, DraftConfig};
use crate::faults;
use crate::trace::{self, Phase};
use crate::trace_span;
use crate::vocab::Vocab;

/// Synthetic trace-track allocator: each traced request gets its own
/// Perfetto row, since request intervals overlap on the worker thread.
static REQ_TRACK: AtomicU64 = AtomicU64::new(0);

/// Backoff before the single solo retry of a lane whose session
/// panicked: long enough to ride out an ephemeral glitch, short enough
/// to stay invisible next to a decode.
const RETRY_BACKOFF: Duration = Duration::from_millis(5);

/// Consecutive over-threshold ticks before the degradation ladder
/// escalates a level (de-escalation is immediate).
const DEGRADE_SUSTAIN_TICKS: u32 = 3;

/// Record a request's queue residency onto its trace track (ending now)
/// and return the admission timestamp for the later `Request` span.
fn trace_admission(enqueued: Instant, track: u64) -> u64 {
    if !trace::enabled() {
        return 0;
    }
    let now = trace::now_ns();
    let wait_ns = enqueued.elapsed().as_nanos() as u64;
    trace::record_manual(Phase::QueueWait, now.saturating_sub(wait_ns), now, 0, track);
    now
}

/// Close a request's trace track: the whole-request span plus a
/// worst-N exemplar offer.
fn trace_completion(t_admit_ns: u64, track: u64, payload: u64) {
    if !trace::enabled() {
        return;
    }
    let now = trace::now_ns();
    trace::record_manual(Phase::Request, t_admit_ns, now, payload, track);
    trace::note_request(&format!("req-{track}"), t_admit_ns, now);
}

/// Render a caught panic payload for client-facing `ERR` replies.
fn panic_text(p: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// Exactly-one-reply guard around a job's reply channel. Clones share
/// the flag, so wherever copies of a request travel — a live lane, a
/// solo retry, a supervisor reclaim re-served by a sibling worker — the
/// **first** `send` wins and every later one is a no-op. A request
/// reclaimed from a wedged worker that later limps to completion can
/// therefore never answer its client twice.
#[derive(Debug, Clone)]
pub struct ReplySlot {
    tx: mpsc::Sender<JobResult>,
    replied: Arc<AtomicBool>,
}

impl ReplySlot {
    pub fn new(tx: mpsc::Sender<JobResult>) -> ReplySlot {
        ReplySlot {
            tx,
            replied: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Deliver `result` unless some clone of this slot already replied.
    /// Returns whether this call won the race.
    pub fn send(&self, result: JobResult) -> bool {
        if self
            .replied
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            let _ = self.tx.send(result);
            true
        } else {
            false
        }
    }

    /// Has any clone of this slot replied?
    pub fn is_replied(&self) -> bool {
        self.replied.load(Ordering::Acquire)
    }
}

/// One unit of serving work: a query SMILES and a reply slot.
#[derive(Debug)]
pub struct Job {
    pub smiles: String,
    pub resp: ReplySlot,
}

impl Job {
    /// Wrap a raw reply channel in a fresh exactly-once slot.
    pub fn new(smiles: String, tx: mpsc::Sender<JobResult>) -> Job {
        Job {
            smiles,
            resp: ReplySlot::new(tx),
        }
    }
}

/// What the worker sends back.
pub type JobResult = Result<Reply, String>;

/// A successful decode: (SMILES, cumulative log-prob) pairs, best first.
#[derive(Debug, Clone)]
pub struct Reply {
    pub hyps: Vec<(String, f64)>,
    pub decoder_calls: usize,
    pub acceptance_rate: f64,
}

/// The queue-pressure degradation ladder. Escalates one level after
/// [`DEGRADE_SUSTAIN_TICKS`] consecutive ticks above the level's
/// occupancy threshold; drops instantly when pressure does.
#[derive(Default)]
struct DegradeState {
    level: u8,
    hot_ticks: u32,
}

impl DegradeState {
    fn observe(&mut self, occupancy: f64) -> u8 {
        let want = if occupancy >= 0.875 {
            2
        } else if occupancy >= 0.5 {
            1
        } else {
            0
        };
        if want > self.level {
            self.hot_ticks += 1;
            if self.hot_ticks >= DEGRADE_SUSTAIN_TICKS {
                self.level = want;
                self.hot_ticks = 0;
            }
        } else {
            self.level = want;
            self.hot_ticks = 0;
        }
        self.level
    }
}

/// Snapshot of one in-flight request, sufficient for the pool
/// supervisor to re-enqueue it if its owning worker is lost.
#[derive(Debug)]
pub struct InFlight {
    pub mode: DecodeMode,
    pub smiles: String,
    pub resp: ReplySlot,
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
}

/// Heartbeat + in-flight registry shared between one worker thread and
/// the pool supervisor. The worker ticks it on every pop and every
/// session step and registers each request it owns; the supervisor
/// declares a *busy* worker with a stale heartbeat wedged and reclaims
/// whatever is registered and unreplied. `run_worker` (no supervisor)
/// uses a standalone instance via [`WorkerHealth::solo`].
#[derive(Debug)]
pub struct WorkerHealth {
    /// Stable worker slot index (kept across respawns into the slot).
    pub slot: usize,
    /// Spawn generation within the slot (0 = original worker).
    pub generation: u64,
    /// Panics contained by this worker incarnation. The pool-wide
    /// aggregate stays in [`Metrics::panics_contained`], so the
    /// `resil_*` surface keeps its meaning.
    pub panics: AtomicU64,
    /// Milliseconds since `epoch` of the last liveness tick, stored +1
    /// so 0 means "never ticked".
    last_tick_ms: AtomicU64,
    /// Inside a batch? Idle workers block in `pop_batch` without
    /// ticking; only a busy worker with a stale heartbeat is wedged.
    busy: AtomicBool,
    /// Requests currently owned by this worker, by admission id.
    in_flight: Mutex<HashMap<u64, InFlight>>,
    /// Set pool-wide at drain so parked (wedged) threads exit.
    released: Arc<AtomicBool>,
    epoch: Instant,
}

impl WorkerHealth {
    pub fn new(slot: usize, generation: u64, released: Arc<AtomicBool>) -> WorkerHealth {
        WorkerHealth {
            slot,
            generation,
            panics: AtomicU64::new(0),
            last_tick_ms: AtomicU64::new(0),
            busy: AtomicBool::new(false),
            in_flight: Mutex::new(HashMap::new()),
            released,
            epoch: Instant::now(),
        }
    }

    /// Health for an unsupervised standalone worker: nothing watches the
    /// heartbeat, and a `worker.wedge` fault releases immediately (there
    /// is no supervisor to reclaim and free it).
    pub fn solo() -> WorkerHealth {
        WorkerHealth::new(0, 0, Arc::new(AtomicBool::new(true)))
    }

    /// Record a liveness tick (per pop, per session step).
    pub fn tick(&self) {
        self.last_tick_ms
            .store(self.epoch.elapsed().as_millis() as u64 + 1, Ordering::Release);
    }

    /// Milliseconds since the last tick (`u64::MAX` if never ticked).
    pub fn stale_ms(&self) -> u64 {
        match self.last_tick_ms.load(Ordering::Acquire) {
            0 => u64::MAX,
            t => (self.epoch.elapsed().as_millis() as u64).saturating_sub(t - 1),
        }
    }

    pub fn is_busy(&self) -> bool {
        self.busy.load(Ordering::Acquire)
    }

    /// Register one owned request (popped batch member or mid-session
    /// newcomer). Cleared wholesale by [`WorkerHealth::end_batch`];
    /// replied entries are skipped by reclaim, so lazy cleanup is safe.
    fn register(&self, r: &Request<Job>) {
        lock_ok(&self.in_flight).insert(
            r.id,
            InFlight {
                mode: r.mode,
                smiles: r.payload.smiles.clone(),
                resp: r.payload.resp.clone(),
                enqueued: r.enqueued,
                deadline: r.deadline,
            },
        );
    }

    fn begin_batch(&self, batch: &[Request<Job>]) {
        self.busy.store(true, Ordering::Release);
        for r in batch {
            self.register(r);
        }
        self.tick();
    }

    fn end_batch(&self) {
        lock_ok(&self.in_flight).clear();
        self.busy.store(false, Ordering::Release);
        self.tick();
    }

    /// Count one contained panic against both this worker and the
    /// pool-wide aggregate.
    fn contain_panic(&self, metrics: &Metrics) {
        self.panics.fetch_add(1, Ordering::Relaxed);
        metrics.panics_contained.fetch_add(1, Ordering::Relaxed);
    }

    /// Any registered request still waiting for its reply?
    pub fn has_unreplied(&self) -> bool {
        lock_ok(&self.in_flight)
            .values()
            .any(|inf| !inf.resp.is_replied())
    }

    /// Drain the registry, returning the unreplied entries (the ones the
    /// supervisor must reclaim). Replied entries are dropped.
    pub fn take_unreplied(&self) -> Vec<(u64, InFlight)> {
        lock_ok(&self.in_flight)
            .drain()
            .filter(|(_, inf)| !inf.resp.is_replied())
            .collect()
    }

    /// Park a wedged worker until the pool drains and releases it. The
    /// heartbeat stays frozen the whole time — that is the signal.
    fn park_wedged(&self) {
        while !self.released.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

/// Fail one shed request back to its client. The shedding pop variants
/// call this *after* releasing the queue lock, so the reply — which can
/// block on a slow client socket — never stalls sibling workers' pops.
fn shed_request(r: Request<Job>, metrics: &Metrics) {
    let _ = r.payload.resp.send(Err("deadline_exceeded".to_string()));
    metrics.requests_shed.fetch_add(1, Ordering::Relaxed);
}

/// Drain the queue until it is closed. Runs on its own thread.
/// Standalone compatibility wrapper: one unsupervised worker (no pool,
/// no heartbeat watcher) — the single-device serving shape.
pub fn run_worker<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
) {
    run_worker_supervised(backend, vocab, queue, metrics, cache, &WorkerHealth::solo())
}

/// One (possibly pool-member) worker: drain the queue until it is
/// closed, reporting liveness and in-flight ownership through `health`
/// so a supervisor can reclaim this worker's requests if it wedges.
pub fn run_worker_supervised<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
    health: &WorkerHealth,
) {
    let mut degrade = DegradeState::default();
    loop {
        health.tick();
        let Some(batch) = queue.pop_batch_shedding(&mut |r| shed_request(r, metrics)) else {
            return;
        };
        // Ownership is registered before anything can go wrong: from
        // here until `end_batch`, every request in the batch is either
        // replied to or reclaimable by the supervisor.
        health.begin_batch(&batch);
        // Pool-level fault sites. `worker.tick` models a sick control
        // loop: a panic here is contained like any decode panic (the
        // batch is registered, so nothing can be lost), and a Slow stall
        // starves the heartbeat the supervisor watches. `worker.wedge`
        // freezes this worker outright — batch registered, heartbeat
        // stopped — so the pool must declare it lost, reclaim its
        // requests, and spawn a replacement; the frozen thread parks
        // until the pool drains.
        if catch_unwind(AssertUnwindSafe(|| faults::fire_infallible("worker.tick"))).is_err() {
            health.contain_panic(metrics);
        }
        if faults::fires("worker.wedge") {
            health.park_wedged();
            return;
        }
        // Pressure is sampled per tick *after* the pop: what is still
        // queued behind this batch is the backlog the tick can't serve.
        let level = degrade.observe(queue.occupancy());
        metrics.degrade_level.store(level as u64, Ordering::Relaxed);
        if level > 0 {
            metrics.degraded_ticks.fetch_add(1, Ordering::Relaxed);
        }
        let now = Instant::now();
        for r in &batch {
            metrics.queue_wait.record(now.duration_since(r.enqueued));
        }
        // batches / batched_requests count actual decode admissions (in
        // stream_batch / solo_batch), so cache hits — which never occupy
        // a lane — don't distort the mean-batch metric in either
        // direction.
        process_batch(backend, vocab, batch, queue, metrics, cache, level, health);
        health.end_batch();
    }
}

/// Consult the result cache for one admitted request. On a hit the reply
/// is sent verbatim (bit-identical to the run that produced the entry,
/// with zero decoder calls) and `true` is returned so the caller skips
/// decoding entirely.
fn try_cache_reply(
    cache: &ServeCache,
    metrics: &Metrics,
    mode: DecodeMode,
    ids: &[i64],
    r: &Request<Job>,
) -> bool {
    if !cache.enabled() {
        return false;
    }
    match cache.results().get(mode.cache_tag(), ids) {
        Some(pred) => {
            metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
            metrics.requests_total.fetch_add(1, Ordering::Relaxed);
            // Warm-boot accounting is a gauge mirrored from the cache's
            // own counter (only it knows which entries came from a dump).
            metrics
                .cache_warm_hits
                .store(cache.results().stats().warm_hits, Ordering::Relaxed);
            let _ = r.payload.resp.send(Ok(Reply {
                hyps: pred.hyps,
                decoder_calls: 0,
                acceptance_rate: pred.acceptance_rate,
            }));
            true
        }
        None => {
            metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
            false
        }
    }
}

/// Memoize a completed prediction and mine its accepted target into the
/// corpus draft store.
fn record_completion(
    cache: &ServeCache,
    metrics: &Metrics,
    mode: DecodeMode,
    ids: &[i64],
    hyps: &[(String, f64)],
    top_tokens: &[i64],
    acceptance_rate: f64,
) {
    if !cache.enabled() {
        return;
    }
    let evicted = cache.results().insert(
        mode.cache_tag(),
        ids.to_vec(),
        CachedPrediction {
            hyps: hyps.to_vec(),
            acceptance_rate,
        },
    );
    metrics.cache_inserts.fetch_add(1, Ordering::Relaxed);
    metrics.cache_evictions.fetch_add(evicted, Ordering::Relaxed);
    cache.drafts().record(top_tokens);
}

/// Encode one request's SMILES, failing the request over its channel on
/// bad input. Returns the wrapped token ids on success.
fn validate<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    r: &Request<Job>,
    metrics: &Arc<Metrics>,
) -> Option<Vec<i64>> {
    match vocab.encode_wrapped(&r.payload.smiles) {
        Ok(ids) if ids.len() <= backend.dims().s_len => Some(ids),
        Ok(_) => {
            let _ = r.payload.resp.send(Err("query too long".to_string()));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            None
        }
        Err(e) => {
            let _ = r.payload.resp.send(Err(format!("bad SMILES: {e}")));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn process_batch<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    batch: Vec<Request<Job>>,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
    degrade_level: u8,
    health: &WorkerHealth,
) {
    let mode = batch[0].mode;
    match mode {
        DecodeMode::Greedy | DecodeMode::SpecGreedy { .. } => stream_batch(
            backend,
            vocab,
            batch,
            queue,
            metrics,
            cache,
            mode,
            degrade_level,
            health,
        ),
        DecodeMode::Beam { .. } | DecodeMode::Sbs { .. } => solo_batch(
            backend,
            vocab,
            batch,
            metrics,
            cache,
            mode,
            degrade_level,
            health,
        ),
    }
}

/// Fold one successful `DecodeOutput` into the metrics registry (the
/// shared tail of the solo path and the supervised retry path).
fn absorb_solo_output(metrics: &Metrics, out: &crate::decoding::DecodeOutput) {
    metrics
        .tokens_generated
        .fetch_add(out.stats.acceptance.total_tokens as u64, Ordering::Relaxed);
    metrics.draft_tokens_accepted.fetch_add(
        out.stats.acceptance.accepted_draft_tokens as u64,
        Ordering::Relaxed,
    );
    metrics
        .draft_accepted_query
        .fetch_add(out.stats.accepted_query_tokens as u64, Ordering::Relaxed);
    metrics
        .draft_accepted_corpus
        .fetch_add(out.stats.accepted_corpus_tokens as u64, Ordering::Relaxed);
    metrics
        .decoder_calls
        .fetch_add(out.stats.decoder_calls as u64, Ordering::Relaxed);
    metrics.requests_total.fetch_add(1, Ordering::Relaxed);
}

/// Beam / SBS: the batcher hands us one request at a time. The decode is
/// supervised: a panic is contained, retried once after a backoff, and a
/// second panic becomes an `ERR` for this one client.
#[allow(clippy::too_many_arguments)]
fn solo_batch<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    batch: Vec<Request<Job>>,
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
    mode: DecodeMode,
    degrade_level: u8,
    health: &WorkerHealth,
) {
    for r in &batch {
        health.tick();
        let Some(src) = validate(backend, vocab, r, metrics) else {
            continue;
        };
        if try_cache_reply(cache, metrics, mode, &src, r) {
            continue;
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.batched_requests.fetch_add(1, Ordering::Relaxed);
        let track = REQ_TRACK.fetch_add(1, Ordering::Relaxed);
        let t_admit_ns = trace_admission(r.enqueued, track);
        let t0 = Instant::now();
        let _tick = trace_span!(Phase::BatchTick, 1);
        let attempt = || match mode {
            DecodeMode::Beam { n } => beam_search(backend, &src, n),
            DecodeMode::Sbs { n, dl } => {
                let mut cfg = SbsConfig::new(n, dl);
                // Empty unless the operator opted in: accepted corpus
                // windows can reorder SBS's candidate frontier, and the
                // serving default keeps outputs bit-identical to the
                // cold path (greedy-spec corpus drafts are always safe).
                // Degradation level ≥ 1 drops them for opted-in configs
                // too (those already accepted store-dependent outputs).
                cfg.corpus_drafts = if degrade_level >= 1 {
                    Vec::new()
                } else {
                    cache.corpus_drafts_for_sbs()
                };
                sbs(backend, &src, &cfg)
            }
            _ => unreachable!("solo_batch only handles beam/sbs"),
        };
        let out = match catch_unwind(AssertUnwindSafe(attempt)) {
            Ok(res) => res,
            Err(p) => {
                health.contain_panic(metrics);
                metrics.requests_retried.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(RETRY_BACKOFF);
                match catch_unwind(AssertUnwindSafe(attempt)) {
                    Ok(res) => res,
                    Err(p2) => {
                        health.contain_panic(metrics);
                        let _ = p;
                        let _ = r
                            .payload
                            .resp
                            .send(Err(format!("panic: {}", panic_text(&p2))));
                        metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
                        metrics.decode_latency.record(t0.elapsed());
                        continue;
                    }
                }
            }
        };
        match out {
            Ok(out) => {
                absorb_solo_output(metrics, &out);
                let reply = Reply {
                    hyps: out
                        .hyps
                        .iter()
                        .map(|h| (vocab.decode(&h.tokens), h.score))
                        .collect(),
                    decoder_calls: out.stats.decoder_calls,
                    acceptance_rate: out.stats.acceptance.rate(),
                };
                if let Some(top) = out.hyps.first() {
                    record_completion(
                        cache,
                        metrics,
                        mode,
                        &src,
                        &reply.hyps,
                        &top.tokens,
                        reply.acceptance_rate,
                    );
                }
                let _ = r.payload.resp.send(Ok(reply));
            }
            Err(e) => {
                let _ = r
                    .payload
                    .resp
                    .send(Err(format!("decode failed: {e}")));
                metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        metrics.decode_latency.record(t0.elapsed());
        drop(_tick);
        trace_completion(t_admit_ns, track, 1);
    }
}

/// Either incremental run type behind one dispatch surface.
enum Run<'a> {
    Greedy(GreedyRun<'a>),
    Spec(SpecGreedyRun<'a>),
}

impl<'a> Run<'a> {
    fn admit(&mut self, mem_row: usize, src: &[i64]) -> usize {
        match self {
            Run::Greedy(r) => r.admit(mem_row),
            Run::Spec(r) => r.admit(mem_row, src),
        }
    }

    fn append_memory(&mut self, extra: &crate::decoding::Memory) -> usize {
        match self {
            Run::Greedy(r) => r.session_mut().append_memory(extra),
            Run::Spec(r) => r.session_mut().append_memory(extra),
        }
    }

    fn step(&mut self) -> Result<Vec<usize>> {
        match self {
            Run::Greedy(r) => r.step(),
            Run::Spec(r) => r.step(),
        }
    }

    fn finished(&self) -> bool {
        match self {
            Run::Greedy(r) => r.finished(),
            Run::Spec(r) => r.finished(),
        }
    }

    fn n_live(&self) -> usize {
        match self {
            Run::Greedy(r) => r.n_live(),
            Run::Spec(r) => r.n_live(),
        }
    }

    fn calls(&self) -> usize {
        match self {
            Run::Greedy(r) => r.calls(),
            Run::Spec(r) => r.calls(),
        }
    }

    fn session_stats(&self) -> crate::decoding::SessionStats {
        match self {
            Run::Greedy(r) => r.session_stats(),
            Run::Spec(r) => r.session_stats(),
        }
    }

    fn hyp_and_acceptance(&self, lane: usize) -> (crate::decoding::Hypothesis, Acceptance) {
        match self {
            Run::Greedy(r) => {
                let h = r.hypothesis(lane);
                let acc = Acceptance {
                    accepted_draft_tokens: 0,
                    total_tokens: h.tokens.len(),
                };
                (h, acc)
            }
            Run::Spec(r) => (r.hypothesis(lane), r.lane_acceptance(lane)),
        }
    }

    /// Accepted-token split `(query_copy, corpus)` for one lane.
    fn source_acceptance(&self, lane: usize) -> (usize, usize) {
        match self {
            Run::Greedy(_) => (0, 0),
            Run::Spec(r) => r.lane_source_acceptance(lane),
        }
    }
}

/// Lane bookkeeping: reply slot, per-request decode timer, the
/// session call count at admission (so the per-request decoder_calls
/// stat covers only this request's lifetime), and the encoded query
/// (the completion's cache key). "Replied?" lives in the [`ReplySlot`]
/// itself — shared with any reclaim clone, so a lane whose request was
/// re-served elsewhere reads as replied here too.
#[derive(Debug)]
struct LaneCtx {
    resp: ReplySlot,
    t0: Instant,
    calls_at_admit: usize,
    ids: Vec<i64>,
    /// Synthetic trace track and admission timestamp — request
    /// intervals overlap on this thread, so each lane records its
    /// whole-request span manually onto its own track.
    track: u64,
    t_admit_ns: u64,
}

/// Open a lane's bookkeeping for one admitted request.
fn fresh_lane(r: &Request<Job>, ids: &[i64], calls_at_admit: usize) -> LaneCtx {
    let track = REQ_TRACK.fetch_add(1, Ordering::Relaxed);
    LaneCtx {
        resp: r.payload.resp.clone(),
        t0: Instant::now(),
        calls_at_admit,
        ids: ids.to_vec(),
        track,
        t_admit_ns: trace_admission(r.enqueued, track),
    }
}

/// Retry one quarantined lane solo through the stateless-equivalent free
/// decoders — exact by the session-parity and speculation-losslessness
/// invariants, so a successful retry is bit-identical to what the
/// panicked session would have produced. Single attempt: a second panic
/// becomes this client's `ERR`.
#[allow(clippy::too_many_arguments)]
fn retry_lane_solo<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    metrics: &Metrics,
    cache: &ServeCache,
    mode: DecodeMode,
    lane: &LaneCtx,
    degrade_level: u8,
    health: &WorkerHealth,
) {
    metrics.requests_retried.fetch_add(1, Ordering::Relaxed);
    std::thread::sleep(RETRY_BACKOFF);
    // No corpus drafts on the retry (they are output-neutral here, and
    // the simplest recovery path is the most predictable one); level 2
    // degradation drops speculation the same way the live session would.
    let attempt = || match mode {
        DecodeMode::Greedy => greedy(backend, &lane.ids),
        DecodeMode::SpecGreedy { dl } => {
            let dl = if degrade_level >= 2 { 0 } else { dl };
            spec_greedy(backend, &lane.ids, &DraftConfig::new(dl))
        }
        _ => unreachable!("stream lanes are greedy/spec-greedy"),
    };
    match catch_unwind(AssertUnwindSafe(attempt)) {
        Ok(Ok(out)) => {
            absorb_solo_output(metrics, &out);
            let hyp = &out.hyps[0];
            let reply = Reply {
                hyps: vec![(vocab.decode(&hyp.tokens), hyp.score)],
                decoder_calls: out.stats.decoder_calls,
                acceptance_rate: out.stats.acceptance.rate(),
            };
            record_completion(
                cache,
                metrics,
                mode,
                &lane.ids,
                &reply.hyps,
                &hyp.tokens,
                reply.acceptance_rate,
            );
            let _ = lane.resp.send(Ok(reply));
        }
        Ok(Err(e)) => {
            let _ = lane.resp.send(Err(format!("decode failed: {e}")));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
        }
        Err(p) => {
            health.contain_panic(metrics);
            let _ = lane.resp.send(Err(format!("panic: {}", panic_text(&p))));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
        }
    }
    metrics.decode_latency.record(lane.t0.elapsed());
    trace_completion(lane.t_admit_ns, lane.track, 0);
}

/// Greedy / speculative-greedy: run a live session, replying per lane as
/// it finishes and admitting compatible newcomers between steps.
#[allow(clippy::too_many_arguments)]
fn stream_batch<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    batch: Vec<Request<Job>>,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
    mode: DecodeMode,
    degrade_level: u8,
    health: &WorkerHealth,
) {
    let max_lanes = queue.max_batch.max(1);

    // Validate and encode the initial batch; cache hits reply now and
    // never occupy a lane.
    let mut valid: Vec<(Request<Job>, Vec<i64>)> = Vec::new();
    for r in batch {
        let Some(ids) = validate(backend, vocab, &r, metrics) else {
            continue;
        };
        if try_cache_reply(cache, metrics, mode, &ids, &r) {
            continue;
        }
        metrics.batched_requests.fetch_add(1, Ordering::Relaxed);
        valid.push((r, ids));
    }
    if valid.is_empty() {
        return;
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    let refs: Vec<&[i64]> = valid.iter().map(|(_, ids)| ids.as_slice()).collect();
    let fail_all = |valid: &[(Request<Job>, Vec<i64>)], e: String| {
        for (r, _) in valid {
            let _ = r.payload.resp.send(Err(e.clone()));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
        }
    };
    // Session setup touches the backend too — encoder kernels, session
    // begin, per-lane arena rows in `admit` — so it is supervised like
    // the step loop: a setup panic means no usable session exists, and
    // every validated request is retried solo instead.
    let mut run_slot: Option<Run> = None;
    let setup = catch_unwind(AssertUnwindSafe(|| -> Result<()> {
        let memory = backend
            .encode(&refs)
            .map_err(|e| anyhow::anyhow!("encode failed: {e}"))?;
        let sess = backend
            .begin(memory)
            .map_err(|e| anyhow::anyhow!("session failed: {e}"))?;
        let mut run = match mode {
            DecodeMode::SpecGreedy { dl } => {
                // Degradation ladder, both output-neutral for
                // speculation: level 1 drops the corpus draft source,
                // level 2 drops speculative drafts entirely (dl = 0 is
                // the lossless sentinel draft).
                let dl = if degrade_level >= 2 { 0 } else { dl };
                let corpus = if degrade_level >= 1 {
                    Vec::new()
                } else {
                    cache.corpus_drafts()
                };
                Run::Spec(SpecGreedyRun::with_corpus(sess, DraftConfig::new(dl), corpus))
            }
            _ => Run::Greedy(GreedyRun::new(sess)),
        };
        for (i, (_, ids)) in valid.iter().enumerate() {
            run.admit(i, ids);
        }
        run_slot = Some(run);
        Ok(())
    }));
    let mut run = match setup {
        Ok(Ok(())) => run_slot.expect("setup stored the run"),
        Ok(Err(e)) => return fail_all(&valid, e.to_string()),
        Err(_p) => {
            health.contain_panic(metrics);
            for (r, ids) in &valid {
                let lane = fresh_lane(r, ids, 0);
                retry_lane_solo(
                    backend,
                    vocab,
                    metrics,
                    cache,
                    mode,
                    &lane,
                    degrade_level,
                    health,
                );
            }
            return;
        }
    };
    let mut lanes: Vec<LaneCtx> = valid
        .iter()
        .map(|(r, ids)| fresh_lane(r, ids, run.calls()))
        .collect();
    drop(valid);

    // A session's encoder memory and cross-attention caches grow with
    // every admitted query and are only reclaimed when the session
    // drops, so a live session must not serve unboundedly many
    // requests. After this many admissions the session drains and
    // returns; remaining queued work starts a fresh session via the
    // next `pop_batch` tick.
    let max_session_admissions = max_lanes.saturating_mul(8);

    loop {
        health.tick();
        let step_res = match catch_unwind(AssertUnwindSafe(|| {
            let _tick = trace_span!(Phase::BatchTick, run.n_live() as u64);
            run.step()
        })) {
            Ok(res) => res,
            Err(_p) => {
                // Supervision: the session is poisoned — quarantine it
                // (its Drop runs under its own catch_unwind so a second
                // panic can't escape) and retry every unreplied lane
                // solo via exact stateless recompute. One bad row costs
                // one retry pass, not the worker thread.
                health.contain_panic(metrics);
                let quarantined: Vec<LaneCtx> =
                    lanes.into_iter().filter(|l| !l.resp.is_replied()).collect();
                let _ = catch_unwind(AssertUnwindSafe(move || drop(run)));
                for lane in &quarantined {
                    retry_lane_solo(
                        backend,
                        vocab,
                        metrics,
                        cache,
                        mode,
                        lane,
                        degrade_level,
                        health,
                    );
                }
                return;
            }
        };
        let finished = match step_res {
            Ok(f) => f,
            Err(e) => {
                // Finished lanes already replied; fail the rest.
                for l in lanes.iter().filter(|l| !l.resp.is_replied()) {
                    let _ = l.resp.send(Err(format!("decode failed: {e}")));
                    metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
                }
                return;
            }
        };
        for li in finished {
            let (hyp, acc) = run.hyp_and_acceptance(li);
            let (src_q, src_c) = run.source_acceptance(li);
            metrics
                .tokens_generated
                .fetch_add(acc.total_tokens as u64, Ordering::Relaxed);
            metrics
                .draft_tokens_accepted
                .fetch_add(acc.accepted_draft_tokens as u64, Ordering::Relaxed);
            metrics
                .draft_accepted_query
                .fetch_add(src_q as u64, Ordering::Relaxed);
            metrics
                .draft_accepted_corpus
                .fetch_add(src_c as u64, Ordering::Relaxed);
            metrics.requests_total.fetch_add(1, Ordering::Relaxed);
            let reply = Reply {
                hyps: vec![(vocab.decode(&hyp.tokens), hyp.score)],
                decoder_calls: run.calls() - lanes[li].calls_at_admit,
                acceptance_rate: acc.rate(),
            };
            record_completion(
                cache,
                metrics,
                mode,
                &lanes[li].ids,
                &reply.hyps,
                &hyp.tokens,
                reply.acceptance_rate,
            );
            let _ = lanes[li].resp.send(Ok(reply));
            metrics.decode_latency.record(lanes[li].t0.elapsed());
            trace_completion(
                lanes[li].t_admit_ns,
                lanes[li].track,
                (run.calls() - lanes[li].calls_at_admit) as u64,
            );
        }

        // Continuous batching: admit compatible newcomers into the live
        // session while there is lane budget and the session is young
        // enough that its per-query caches stay bounded. Expired
        // newcomers are shed here too — mid-session admission must not
        // smuggle a dead request into a lane.
        let free = max_lanes
            .saturating_sub(run.n_live())
            .min(max_session_admissions.saturating_sub(lanes.len()));
        let newcomers =
            queue.try_pop_compatible_shedding(mode, free, &mut |r| shed_request(r, metrics));
        if !newcomers.is_empty() {
            let _adm_span = trace_span!(Phase::Admission, newcomers.len() as u64);
            // Newcomers become this worker's responsibility the moment
            // they leave the queue — register them before validation so
            // a wedge mid-admission still leaves them reclaimable.
            for r in &newcomers {
                health.register(r);
            }
            let now = Instant::now();
            let mut adm: Vec<(Request<Job>, Vec<i64>)> = Vec::new();
            for r in newcomers {
                metrics.queue_wait.record(now.duration_since(r.enqueued));
                let Some(ids) = validate(backend, vocab, &r, metrics) else {
                    continue;
                };
                if try_cache_reply(cache, metrics, mode, &ids, &r) {
                    continue;
                }
                metrics.batched_requests.fetch_add(1, Ordering::Relaxed);
                adm.push((r, ids));
            }
            if !adm.is_empty() {
                let refs: Vec<&[i64]> = adm.iter().map(|(_, ids)| ids.as_slice()).collect();
                // Mid-session growth hits the same panic surfaces as
                // setup (encoder kernels, arena rows), and a panic here
                // may leave the session half-grown — quarantine it and
                // retry residents and newcomers alike solo.
                let grow = catch_unwind(AssertUnwindSafe(|| -> Result<()> {
                    let extra = backend
                        .encode(&refs)
                        .map_err(|e| anyhow::anyhow!("encode failed: {e}"))?;
                    let base = run.append_memory(&extra);
                    for (k, (_, ids)) in adm.iter().enumerate() {
                        run.admit(base + k, ids);
                    }
                    Ok(())
                }));
                match grow {
                    Ok(Ok(())) => {
                        let calls = run.calls();
                        for (r, ids) in &adm {
                            lanes.push(fresh_lane(r, ids, calls));
                        }
                    }
                    Ok(Err(e)) => fail_all(&adm, e.to_string()),
                    Err(_p) => {
                        health.contain_panic(metrics);
                        let mut quarantined: Vec<LaneCtx> =
                            lanes.into_iter().filter(|l| !l.resp.is_replied()).collect();
                        for (r, ids) in &adm {
                            quarantined.push(fresh_lane(r, ids, 0));
                        }
                        let _ = catch_unwind(AssertUnwindSafe(move || drop(run)));
                        for lane in &quarantined {
                            retry_lane_solo(
                                backend,
                                vocab,
                                metrics,
                                cache,
                                mode,
                                lane,
                                degrade_level,
                                health,
                            );
                        }
                        return;
                    }
                }
            }
        }

        if run.finished() {
            metrics
                .decoder_calls
                .fetch_add(run.calls() as u64, Ordering::Relaxed);
            // Kernel-layer + arena accounting: every step() was one
            // fused extend over all live lanes. The field-by-field
            // mapping lives in `Metrics::absorb_session`, not here.
            metrics.absorb_session(&run.session_stats());
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::{self, FaultKind, FaultPlan, Trigger};
    use crate::testutil::CopyModel;
    use std::time::Duration;

    fn tiny_vocab() -> Vocab {
        Vocab::build(["CCONF", "c1ccccc1"]).unwrap()
    }

    fn send_job(queue: &RequestQueue<Job>, mode: DecodeMode, smiles: &str) -> mpsc::Receiver<JobResult> {
        let (tx, rx) = mpsc::channel();
        queue.push(mode, Job::new(smiles.to_string(), tx));
        rx
    }

    /// `ReplySlot` delivers exactly one reply, no matter how many clones
    /// race to send — the contract the pool's reclaim path leans on.
    #[test]
    fn reply_slot_dedups_across_clones() {
        let (tx, rx) = mpsc::channel();
        let a = ReplySlot::new(tx);
        let b = a.clone();
        assert!(!a.is_replied());
        assert!(a.send(Err("first".to_string())));
        assert!(a.is_replied() && b.is_replied());
        assert!(!b.send(Err("second".to_string())), "clone must lose the race");
        assert_eq!(rx.recv().unwrap().unwrap_err(), "first");
        assert!(rx.try_recv().is_err(), "exactly one reply");
    }

    #[test]
    fn worker_round_trips_greedy_jobs() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::default();

        let rx1 = send_job(&queue, DecodeMode::Greedy, "CCO");
        let rx2 = send_job(&queue, DecodeMode::SpecGreedy { dl: 2 }, "c1ccccc1");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &cache);

        // CopyModel regenerates the source tokens.
        let r1 = rx1.recv().unwrap().unwrap();
        assert_eq!(r1.hyps[0].0, "CCO");
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r2.hyps[0].0, "c1ccccc1");
        assert!(metrics.requests_total.load(Ordering::Relaxed) == 2);
        // Both completions were memoized and mined for draft windows.
        assert_eq!(metrics.cache_inserts.load(Ordering::Relaxed), 2);
        assert_eq!(cache.results().len(), 2);
    }

    #[test]
    fn worker_reports_bad_smiles() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let rx = send_job(&queue, DecodeMode::Greedy, "C C O");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &ServeCache::default());
        assert!(rx.recv().unwrap().is_err());
        assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn worker_handles_beam_and_sbs_modes() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let rx1 = send_job(&queue, DecodeMode::Beam { n: 3 }, "CCO");
        let rx2 = send_job(&queue, DecodeMode::Sbs { n: 3, dl: 4 }, "CCO");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &ServeCache::default());
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r1.hyps[0].0, "CCO");
        assert_eq!(r2.hyps[0].0, "CCO");
        assert!(!r2.hyps.is_empty());
    }

    /// The session-alive-across-ticks behaviour, deterministically: a
    /// request that arrives *after* the batch was popped is admitted
    /// into the running session by `process_batch` itself.
    #[test]
    fn late_request_joins_live_session() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::default();

        let rx1 = send_job(&queue, DecodeMode::Greedy, "c1ccccc1");
        let batch = queue.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        // Arrives between batching ticks — after pop, before decode ends.
        let rx2 = send_job(&queue, DecodeMode::Greedy, "CCO");
        process_batch(
            &backend,
            &vocab,
            batch,
            &queue,
            &metrics,
            &cache,
            0,
            &WorkerHealth::solo(),
        );

        assert_eq!(rx1.recv().unwrap().unwrap().hyps[0].0, "c1ccccc1");
        assert_eq!(
            rx2.recv().unwrap().unwrap().hyps[0].0,
            "CCO",
            "late request must be served by the same live session"
        );
        assert!(queue.is_empty(), "admission must drain the queue");
        assert_eq!(metrics.requests_total.load(Ordering::Relaxed), 2);
    }

    /// Incompatible work is never pulled into a live session.
    #[test]
    fn live_session_skips_incompatible_head() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());

        let rx1 = send_job(&queue, DecodeMode::Greedy, "CCO");
        let batch = queue.pop_batch().unwrap();
        let _rx2 = send_job(&queue, DecodeMode::Beam { n: 2 }, "CCO");
        process_batch(
            &backend,
            &vocab,
            batch,
            &queue,
            &metrics,
            &ServeCache::default(),
            0,
            &WorkerHealth::solo(),
        );

        assert!(rx1.recv().unwrap().is_ok());
        assert_eq!(queue.len(), 1, "beam request must stay queued");
    }

    /// A repeated request is served from the result cache: zero decoder
    /// calls, reply bit-identical to the decoded one.
    #[test]
    fn repeat_request_hits_cache_with_identical_reply() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::default();

        let rx1 = send_job(&queue, DecodeMode::SpecGreedy { dl: 2 }, "c1ccccc1");
        let b1 = queue.pop_batch().unwrap();
        process_batch(
            &backend,
            &vocab,
            b1,
            &queue,
            &metrics,
            &cache,
            0,
            &WorkerHealth::solo(),
        );
        let r1 = rx1.recv().unwrap().unwrap();
        assert!(r1.decoder_calls > 0);

        let rx2 = send_job(&queue, DecodeMode::SpecGreedy { dl: 2 }, "c1ccccc1");
        let b2 = queue.pop_batch().unwrap();
        process_batch(
            &backend,
            &vocab,
            b2,
            &queue,
            &metrics,
            &cache,
            0,
            &WorkerHealth::solo(),
        );
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r2.decoder_calls, 0, "hit must skip decoding");
        assert_eq!(r2.hyps, r1.hyps, "cached reply must be bit-identical");
        assert_eq!(r2.acceptance_rate, r1.acceptance_rate);
        assert_eq!(metrics.cache_hits.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.requests_total.load(Ordering::Relaxed), 2);

        // A different decoder kind over the same query is a miss.
        let rx3 = send_job(&queue, DecodeMode::Greedy, "c1ccccc1");
        let b3 = queue.pop_batch().unwrap();
        process_batch(
            &backend,
            &vocab,
            b3,
            &queue,
            &metrics,
            &cache,
            0,
            &WorkerHealth::solo(),
        );
        let r3 = rx3.recv().unwrap().unwrap();
        assert!(r3.decoder_calls > 0);
        assert_eq!(metrics.cache_hits.load(Ordering::Relaxed), 1);
    }

    /// Beam/SBS results are memoized too, and a disabled cache never
    /// hits, inserts, or records.
    #[test]
    fn solo_modes_memoize_and_disabled_cache_is_inert() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::default();

        // "c1ccccc1" decodes to 8 tokens — exactly one default-width
        // (8) draft-store window, so mining is observable.
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let rx1 = send_job(&queue, DecodeMode::Sbs { n: 2, dl: 4 }, "c1ccccc1");
        let rx2 = send_job(&queue, DecodeMode::Sbs { n: 2, dl: 4 }, "c1ccccc1");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &cache);
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r1.hyps, r2.hyps);
        assert_eq!(r2.decoder_calls, 0);
        assert!(!cache.drafts().is_empty(), "accepted target must be mined");

        let off = ServeCache::disabled();
        let metrics2 = Arc::new(Metrics::default());
        let queue2 = RequestQueue::new(8, Duration::from_millis(1));
        let rx3 = send_job(&queue2, DecodeMode::Greedy, "CCO");
        let rx4 = send_job(&queue2, DecodeMode::Greedy, "CCO");
        queue2.close();
        run_worker(&backend, &vocab, &queue2, &metrics2, &off);
        assert!(rx3.recv().unwrap().unwrap().decoder_calls > 0);
        assert!(rx4.recv().unwrap().unwrap().decoder_calls > 0);
        assert_eq!(metrics2.cache_hits.load(Ordering::Relaxed), 0);
        assert_eq!(metrics2.cache_inserts.load(Ordering::Relaxed), 0);
        assert!(off.results().is_empty());
        assert!(off.drafts().is_empty());
    }

    /// Supervision: a one-shot injected panic in the live session is
    /// contained, the lane is retried solo, and the reply is the same
    /// output a fault-free run produces.
    #[test]
    fn injected_session_panic_is_contained_and_retried() {
        let _guard = faults::testing::lock();
        let _disarm = faults::testing::Disarm;
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let metrics = Arc::new(Metrics::default());

        // Nth trigger: exactly the first decoder.extend fires, so the
        // solo retry (a fresh extend sequence) succeeds.
        faults::install(FaultPlan::new(7).with(
            "decoder.extend",
            FaultKind::Panic,
            Trigger::Nth(1),
        ));
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let rx = send_job(&queue, DecodeMode::Greedy, "CCO");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &ServeCache::disabled());
        let reply = rx.recv().unwrap().expect("retried lane must succeed");
        assert_eq!(reply.hyps[0].0, "CCO", "retry must be exact");
        assert_eq!(metrics.panics_contained.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.requests_retried.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 0);
        // Exactly one reply.
        assert!(rx.try_recv().is_err());
    }

    /// A persistent panic (fires every time) costs that client one ERR —
    /// and the worker keeps serving afterwards.
    #[test]
    fn persistent_panic_errs_once_and_worker_survives() {
        let _guard = faults::testing::lock();
        let _disarm = faults::testing::Disarm;
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let metrics = Arc::new(Metrics::default());

        faults::install(FaultPlan::new(7).with(
            "decoder.extend",
            FaultKind::Panic,
            Trigger::Prob(1.0),
        ));
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let rx = send_job(&queue, DecodeMode::SpecGreedy { dl: 2 }, "CCO");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &ServeCache::disabled());
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("panic"), "client must see the contained panic: {err}");
        assert!(rx.try_recv().is_err(), "exactly one reply");
        assert!(metrics.panics_contained.load(Ordering::Relaxed) >= 2);

        // Disarm and serve again on the same (surviving) code path.
        faults::disarm();
        let queue2 = RequestQueue::new(8, Duration::from_millis(1));
        let rx2 = send_job(&queue2, DecodeMode::SpecGreedy { dl: 2 }, "CCO");
        queue2.close();
        run_worker(&backend, &vocab, &queue2, &metrics, &ServeCache::disabled());
        assert_eq!(rx2.recv().unwrap().unwrap().hyps[0].0, "CCO");
    }

    /// Solo beam decodes are supervised too: one-shot panic → retried,
    /// exact reply.
    #[test]
    fn solo_beam_panic_is_retried() {
        let _guard = faults::testing::lock();
        let _disarm = faults::testing::Disarm;
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let metrics = Arc::new(Metrics::default());

        faults::install(FaultPlan::new(11).with(
            "decoder.extend",
            FaultKind::Panic,
            Trigger::Nth(1),
        ));
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let rx = send_job(&queue, DecodeMode::Beam { n: 2 }, "CCO");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &ServeCache::disabled());
        let reply = rx.recv().unwrap().expect("retried beam must succeed");
        assert_eq!(reply.hyps[0].0, "CCO");
        assert_eq!(metrics.panics_contained.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.requests_retried.load(Ordering::Relaxed), 1);
    }

    /// Expired requests never reach a decode lane: they are shed at pop
    /// time with ERR deadline_exceeded.
    #[test]
    fn expired_requests_shed_with_deadline_err() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let metrics = Arc::new(Metrics::default());
        let queue: RequestQueue<Job> =
            RequestQueue::with_capacity(8, Duration::from_millis(1), 8);

        let (tx_dead, rx_dead) = mpsc::channel();
        queue
            .try_push(
                DecodeMode::Greedy,
                Job::new("CCO".to_string(), tx_dead),
                Some(Instant::now() - Duration::from_millis(1)),
            )
            .unwrap();
        let rx_live = send_job(&queue, DecodeMode::Greedy, "CCO");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics, &ServeCache::disabled());

        let err = rx_dead.recv().unwrap().unwrap_err();
        assert_eq!(err, "deadline_exceeded");
        assert!(rx_dead.try_recv().is_err(), "exactly one reply for shed requests");
        assert!(rx_live.recv().unwrap().is_ok(), "live request still served");
        assert_eq!(metrics.requests_shed.load(Ordering::Relaxed), 1);
        assert_eq!(
            metrics.requests_total.load(Ordering::Relaxed),
            1,
            "shed request must never count as served"
        );
    }

    /// The degradation ladder escalates only under sustained pressure
    /// and de-escalates immediately.
    #[test]
    fn degrade_ladder_escalates_after_sustained_pressure() {
        let mut d = DegradeState::default();
        assert_eq!(d.observe(0.1), 0);
        assert_eq!(d.observe(0.6), 0);
        assert_eq!(d.observe(0.6), 0);
        assert_eq!(d.observe(0.6), 1, "third consecutive hot tick escalates");
        assert_eq!(d.observe(0.6), 1);
        // Level-2 pressure needs its own sustain run.
        assert_eq!(d.observe(0.9), 1);
        assert_eq!(d.observe(0.9), 1);
        assert_eq!(d.observe(0.9), 2);
        // De-escalation is immediate.
        assert_eq!(d.observe(0.6), 1);
        assert_eq!(d.observe(0.0), 0);
        // A blip never escalates.
        assert_eq!(d.observe(0.9), 0);
        assert_eq!(d.observe(0.0), 0);
    }

    /// Degraded decoding is output-neutral for greedy/spec-greedy: the
    /// same reply at level 0 and level 2 (speculation is lossless for
    /// any draft set, including none).
    #[test]
    fn degraded_decode_is_output_neutral() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let metrics = Arc::new(Metrics::default());
        let mut replies = Vec::new();
        for level in [0u8, 1, 2] {
            let queue = RequestQueue::new(8, Duration::from_millis(1));
            let rx = send_job(&queue, DecodeMode::SpecGreedy { dl: 3 }, "c1ccccc1");
            let batch = queue.pop_batch().unwrap();
            process_batch(
                &backend,
                &vocab,
                batch,
                &queue,
                &metrics,
                &ServeCache::disabled(),
                level,
                &WorkerHealth::solo(),
            );
            replies.push(rx.recv().unwrap().unwrap().hyps);
        }
        assert_eq!(replies[0], replies[1]);
        assert_eq!(replies[0], replies[2]);
    }
}
