//! The model worker: a single thread owning the backend, draining the
//! request queue batch by batch.
//!
//! One worker is the right shape for this testbed (one PJRT CPU device;
//! XLA already uses the cores a single executable can use). The queue +
//! worker split still gives the serving properties that matter: FIFO
//! fairness, dynamic batching, and backpressure (bounded queue wait shows
//! up in metrics rather than in stalled sockets).
//!
//! Greedy and speculative-greedy batches run as **live decoding
//! sessions** ([`GreedyRun`] / [`SpecGreedyRun`]): the session stays
//! alive across batching ticks, finished lanes reply immediately, and
//! compatible requests that arrive mid-decode are admitted into the
//! running session (`RequestQueue::try_pop_compatible`) instead of
//! waiting behind the whole batch — continuous batching. Beam and SBS
//! requests still run solo (their effective batch is already
//! beams × drafts).

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::batcher::{DecodeMode, Request, RequestQueue};
use crate::coordinator::metrics::Metrics;
use crate::decoding::{beam_search, sbs, Backend, GreedyRun, SbsConfig, SpecGreedyRun};
use crate::draft::{Acceptance, DraftConfig};
use crate::vocab::Vocab;

/// One unit of serving work: a query SMILES and a reply channel.
pub struct Job {
    pub smiles: String,
    pub resp: mpsc::Sender<JobResult>,
}

/// What the worker sends back.
pub type JobResult = Result<Reply, String>;

/// A successful decode: (SMILES, cumulative log-prob) pairs, best first.
#[derive(Debug, Clone)]
pub struct Reply {
    pub hyps: Vec<(String, f64)>,
    pub decoder_calls: usize,
    pub acceptance_rate: f64,
}

/// Drain the queue until it is closed. Runs on its own thread.
pub fn run_worker<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
) {
    while let Some(batch) = queue.pop_batch() {
        let now = Instant::now();
        for r in &batch {
            metrics
                .queue_wait
                .record(now.duration_since(r.enqueued));
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics
            .batched_requests
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        process_batch(backend, vocab, batch, queue, metrics);
    }
}

/// Encode one request's SMILES, failing the request over its channel on
/// bad input. Returns the wrapped token ids on success.
fn validate<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    r: &Request<Job>,
    metrics: &Arc<Metrics>,
) -> Option<Vec<i64>> {
    match vocab.encode_wrapped(&r.payload.smiles) {
        Ok(ids) if ids.len() <= backend.dims().s_len => Some(ids),
        Ok(_) => {
            let _ = r.payload.resp.send(Err("query too long".to_string()));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            None
        }
        Err(e) => {
            let _ = r.payload.resp.send(Err(format!("bad SMILES: {e}")));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

fn process_batch<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    batch: Vec<Request<Job>>,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
) {
    let mode = batch[0].mode;
    match mode {
        DecodeMode::Greedy | DecodeMode::SpecGreedy { .. } => {
            stream_batch(backend, vocab, batch, queue, metrics, mode)
        }
        DecodeMode::Beam { .. } | DecodeMode::Sbs { .. } => {
            solo_batch(backend, vocab, batch, metrics, mode)
        }
    }
}

/// Beam / SBS: the batcher hands us one request at a time.
fn solo_batch<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    batch: Vec<Request<Job>>,
    metrics: &Arc<Metrics>,
    mode: DecodeMode,
) {
    for r in &batch {
        let Some(src) = validate(backend, vocab, r, metrics) else {
            continue;
        };
        let t0 = Instant::now();
        let out = match mode {
            DecodeMode::Beam { n } => beam_search(backend, &src, n),
            DecodeMode::Sbs { n, dl } => sbs(backend, &src, &SbsConfig::new(n, dl)),
            _ => unreachable!("solo_batch only handles beam/sbs"),
        };
        match out {
            Ok(out) => {
                metrics
                    .tokens_generated
                    .fetch_add(out.stats.acceptance.total_tokens as u64, Ordering::Relaxed);
                metrics.draft_tokens_accepted.fetch_add(
                    out.stats.acceptance.accepted_draft_tokens as u64,
                    Ordering::Relaxed,
                );
                metrics
                    .decoder_calls
                    .fetch_add(out.stats.decoder_calls as u64, Ordering::Relaxed);
                metrics.requests_total.fetch_add(1, Ordering::Relaxed);
                let reply = Reply {
                    hyps: out
                        .hyps
                        .iter()
                        .map(|h| (vocab.decode(&h.tokens), h.score))
                        .collect(),
                    decoder_calls: out.stats.decoder_calls,
                    acceptance_rate: out.stats.acceptance.rate(),
                };
                let _ = r.payload.resp.send(Ok(reply));
            }
            Err(e) => {
                let _ = r
                    .payload
                    .resp
                    .send(Err(format!("decode failed: {e}")));
                metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        metrics.decode_latency.record(t0.elapsed());
    }
}

/// Either incremental run type behind one dispatch surface.
enum Run<'a> {
    Greedy(GreedyRun<'a>),
    Spec(SpecGreedyRun<'a>),
}

impl<'a> Run<'a> {
    fn admit(&mut self, mem_row: usize, src: &[i64]) -> usize {
        match self {
            Run::Greedy(r) => r.admit(mem_row),
            Run::Spec(r) => r.admit(mem_row, src),
        }
    }

    fn append_memory(&mut self, extra: &crate::decoding::Memory) -> usize {
        match self {
            Run::Greedy(r) => r.session_mut().append_memory(extra),
            Run::Spec(r) => r.session_mut().append_memory(extra),
        }
    }

    fn step(&mut self) -> Result<Vec<usize>> {
        match self {
            Run::Greedy(r) => r.step(),
            Run::Spec(r) => r.step(),
        }
    }

    fn finished(&self) -> bool {
        match self {
            Run::Greedy(r) => r.finished(),
            Run::Spec(r) => r.finished(),
        }
    }

    fn n_live(&self) -> usize {
        match self {
            Run::Greedy(r) => r.n_live(),
            Run::Spec(r) => r.n_live(),
        }
    }

    fn calls(&self) -> usize {
        match self {
            Run::Greedy(r) => r.calls(),
            Run::Spec(r) => r.calls(),
        }
    }

    fn hyp_and_acceptance(&self, lane: usize) -> (crate::decoding::Hypothesis, Acceptance) {
        match self {
            Run::Greedy(r) => {
                let h = r.hypothesis(lane);
                let acc = Acceptance {
                    accepted_draft_tokens: 0,
                    total_tokens: h.tokens.len(),
                };
                (h, acc)
            }
            Run::Spec(r) => (r.hypothesis(lane), r.lane_acceptance(lane)),
        }
    }
}

/// Greedy / speculative-greedy: run a live session, replying per lane as
/// it finishes and admitting compatible newcomers between steps.
fn stream_batch<B: Backend>(
    backend: &B,
    vocab: &Vocab,
    batch: Vec<Request<Job>>,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
    mode: DecodeMode,
) {
    let max_lanes = queue.max_batch.max(1);

    // Validate and encode the initial batch.
    let mut valid: Vec<(Request<Job>, Vec<i64>)> = Vec::new();
    for r in batch {
        if let Some(ids) = validate(backend, vocab, &r, metrics) {
            valid.push((r, ids));
        }
    }
    if valid.is_empty() {
        return;
    }
    let refs: Vec<&[i64]> = valid.iter().map(|(_, ids)| ids.as_slice()).collect();
    let fail_all = |valid: &[(Request<Job>, Vec<i64>)], e: String| {
        for (r, _) in valid {
            let _ = r.payload.resp.send(Err(e.clone()));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
        }
    };
    let memory = match backend.encode(&refs) {
        Ok(m) => m,
        Err(e) => return fail_all(&valid, format!("encode failed: {e}")),
    };
    let sess = match backend.begin(memory) {
        Ok(s) => s,
        Err(e) => return fail_all(&valid, format!("session failed: {e}")),
    };
    let mut run = match mode {
        DecodeMode::SpecGreedy { dl } => Run::Spec(SpecGreedyRun::new(sess, DraftConfig::new(dl))),
        _ => Run::Greedy(GreedyRun::new(sess)),
    };

    // Lane bookkeeping: reply channel, per-request decode timer, the
    // session call count at admission (so the per-request decoder_calls
    // stat covers only this request's lifetime), replied?
    struct LaneCtx {
        resp: mpsc::Sender<JobResult>,
        t0: Instant,
        calls_at_admit: usize,
        replied: bool,
    }
    let mut lanes: Vec<LaneCtx> = Vec::new();
    for (i, (r, ids)) in valid.iter().enumerate() {
        let lane = run.admit(i, ids);
        debug_assert_eq!(lane, lanes.len());
        lanes.push(LaneCtx {
            resp: r.payload.resp.clone(),
            t0: Instant::now(),
            calls_at_admit: run.calls(),
            replied: false,
        });
    }
    drop(valid);

    // A session's encoder memory and cross-attention caches grow with
    // every admitted query and are only reclaimed when the session
    // drops, so a live session must not serve unboundedly many
    // requests. After this many admissions the session drains and
    // returns; remaining queued work starts a fresh session via the
    // next `pop_batch` tick.
    let max_session_admissions = max_lanes.saturating_mul(8);

    loop {
        let finished = match run.step() {
            Ok(f) => f,
            Err(e) => {
                // Finished lanes already replied; fail the rest.
                for l in lanes.iter().filter(|l| !l.replied) {
                    let _ = l.resp.send(Err(format!("decode failed: {e}")));
                    metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
                }
                return;
            }
        };
        for li in finished {
            let (hyp, acc) = run.hyp_and_acceptance(li);
            metrics
                .tokens_generated
                .fetch_add(acc.total_tokens as u64, Ordering::Relaxed);
            metrics
                .draft_tokens_accepted
                .fetch_add(acc.accepted_draft_tokens as u64, Ordering::Relaxed);
            metrics.requests_total.fetch_add(1, Ordering::Relaxed);
            let reply = Reply {
                hyps: vec![(vocab.decode(&hyp.tokens), hyp.score)],
                decoder_calls: run.calls() - lanes[li].calls_at_admit,
                acceptance_rate: acc.rate(),
            };
            let _ = lanes[li].resp.send(Ok(reply));
            lanes[li].replied = true;
            metrics.decode_latency.record(lanes[li].t0.elapsed());
        }

        // Continuous batching: admit compatible newcomers into the live
        // session while there is lane budget and the session is young
        // enough that its per-query caches stay bounded.
        let free = max_lanes
            .saturating_sub(run.n_live())
            .min(max_session_admissions.saturating_sub(lanes.len()));
        let newcomers = queue.try_pop_compatible(mode, free);
        if !newcomers.is_empty() {
            let now = Instant::now();
            let mut adm: Vec<(Request<Job>, Vec<i64>)> = Vec::new();
            for r in newcomers {
                metrics.queue_wait.record(now.duration_since(r.enqueued));
                metrics.batched_requests.fetch_add(1, Ordering::Relaxed);
                if let Some(ids) = validate(backend, vocab, &r, metrics) {
                    adm.push((r, ids));
                }
            }
            if !adm.is_empty() {
                let refs: Vec<&[i64]> = adm.iter().map(|(_, ids)| ids.as_slice()).collect();
                match backend.encode(&refs) {
                    Ok(extra) => {
                        let base = run.append_memory(&extra);
                        for (k, (r, ids)) in adm.iter().enumerate() {
                            let lane = run.admit(base + k, ids);
                            debug_assert_eq!(lane, lanes.len());
                            lanes.push(LaneCtx {
                                resp: r.payload.resp.clone(),
                                t0: Instant::now(),
                                calls_at_admit: run.calls(),
                                replied: false,
                            });
                        }
                    }
                    Err(e) => fail_all(&adm, format!("encode failed: {e}")),
                }
            }
        }

        if run.finished() {
            metrics
                .decoder_calls
                .fetch_add(run.calls() as u64, Ordering::Relaxed);
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::CopyModel;
    use std::time::Duration;

    fn tiny_vocab() -> Vocab {
        Vocab::build(["CCONF", "c1ccccc1"]).unwrap()
    }

    fn send_job(queue: &RequestQueue<Job>, mode: DecodeMode, smiles: &str) -> mpsc::Receiver<JobResult> {
        let (tx, rx) = mpsc::channel();
        queue.push(
            mode,
            Job {
                smiles: smiles.to_string(),
                resp: tx,
            },
        );
        rx
    }

    #[test]
    fn worker_round_trips_greedy_jobs() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());

        let rx1 = send_job(&queue, DecodeMode::Greedy, "CCO");
        let rx2 = send_job(&queue, DecodeMode::SpecGreedy { dl: 2 }, "c1ccccc1");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics);

        // CopyModel regenerates the source tokens.
        let r1 = rx1.recv().unwrap().unwrap();
        assert_eq!(r1.hyps[0].0, "CCO");
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r2.hyps[0].0, "c1ccccc1");
        assert!(metrics.requests_total.load(Ordering::Relaxed) == 2);
    }

    #[test]
    fn worker_reports_bad_smiles() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let rx = send_job(&queue, DecodeMode::Greedy, "C C O");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics);
        assert!(rx.recv().unwrap().is_err());
        assert_eq!(metrics.requests_failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn worker_handles_beam_and_sbs_modes() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let rx1 = send_job(&queue, DecodeMode::Beam { n: 3 }, "CCO");
        let rx2 = send_job(&queue, DecodeMode::Sbs { n: 3, dl: 4 }, "CCO");
        queue.close();
        run_worker(&backend, &vocab, &queue, &metrics);
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        assert_eq!(r1.hyps[0].0, "CCO");
        assert_eq!(r2.hyps[0].0, "CCO");
        assert!(!r2.hyps.is_empty());
    }

    /// The session-alive-across-ticks behaviour, deterministically: a
    /// request that arrives *after* the batch was popped is admitted
    /// into the running session by `process_batch` itself.
    #[test]
    fn late_request_joins_live_session() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());

        let rx1 = send_job(&queue, DecodeMode::Greedy, "c1ccccc1");
        let batch = queue.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        // Arrives between batching ticks — after pop, before decode ends.
        let rx2 = send_job(&queue, DecodeMode::Greedy, "CCO");
        process_batch(&backend, &vocab, batch, &queue, &metrics);

        assert_eq!(rx1.recv().unwrap().unwrap().hyps[0].0, "c1ccccc1");
        assert_eq!(
            rx2.recv().unwrap().unwrap().hyps[0].0,
            "CCO",
            "late request must be served by the same live session"
        );
        assert!(queue.is_empty(), "admission must drain the queue");
        assert_eq!(metrics.requests_total.load(Ordering::Relaxed), 2);
    }

    /// Incompatible work is never pulled into a live session.
    #[test]
    fn live_session_skips_incompatible_head() {
        let vocab = tiny_vocab();
        let backend = CopyModel::new(96, 96, vocab.len());
        let queue = RequestQueue::new(8, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());

        let rx1 = send_job(&queue, DecodeMode::Greedy, "CCO");
        let batch = queue.pop_batch().unwrap();
        let _rx2 = send_job(&queue, DecodeMode::Beam { n: 2 }, "CCO");
        process_batch(&backend, &vocab, batch, &queue, &metrics);

        assert!(rx1.recv().unwrap().is_ok());
        assert_eq!(queue.len(), 1, "beam request must stay queued");
    }
}
