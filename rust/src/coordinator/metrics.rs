//! Serving metrics: counters and log-bucketed latency histograms.
//!
//! Dependency-free (no prometheus in the offline set); the server exposes
//! a `STATS` command that renders a snapshot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::cache::ArenaCounters;
use crate::coordinator::batcher::lock_ok;
use crate::decoding::SessionStats;

/// Log-bucketed latency histogram (microseconds).
///
/// Buckets are powers of √2 from 1µs up to ~17s: index = ⌊2·log2(µs)⌋,
/// giving ~±19% bucket resolution, lock-free recording.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const N_BUCKETS: usize = 49;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        if us <= 1 {
            return 0;
        }
        let log2 = 63 - us.leading_zeros() as u64;
        // Exact half-step test: bucket 2·log2+1 starts at √2·2^log2, so
        // membership is us ≥ √2·2^log2 ⇔ us² ≥ 2^(2·log2+1). The old
        // mantissa-bit shortcut tested us ≥ 1.5·2^log2 and misbucketed
        // everything in [√2·2^k, 1.5·2^k). u128 squares can't overflow
        // (us < 2^64 ⇒ us² < 2^128) and 2·log2+1 ≤ 127.
        let half = u64::from((us as u128) * (us as u128) >= 1u128 << (2 * log2 + 1));
        ((2 * log2 + half) as usize).min(N_BUCKETS - 1)
    }

    /// Upper edge (µs) of bucket `i`: values `v` land in bucket `i` iff
    /// `2^(i/2) ≤ v < 2^((i+1)/2)` — so the upper edge is `2^((i+1)/2)`,
    /// the exclusive bound (the old `2^(i/2)` was the *lower* edge, so
    /// reported quantiles under-stated their bucket).
    fn bucket_edge(i: usize) -> f64 {
        2f64.powf((i as f64 + 1.0) / 2.0)
    }

    pub fn record(&self, d: std::time::Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ms(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1000.0
        }
    }

    pub fn max_ms(&self) -> f64 {
        self.max_us.load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// Approximate quantile in microseconds (upper edge of the bucket
    /// holding the q-th sample), q in [0,1]. 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_edge(i).round() as u64;
            }
        }
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile (upper bucket edge) in milliseconds.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        self.quantile(q) as f64 / 1000.0
    }

    pub fn summary(&self, name: &str) -> String {
        format!(
            "{name}: n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.count(),
            self.mean_ms(),
            self.quantile_ms(0.50),
            self.quantile_ms(0.95),
            self.quantile_ms(0.99),
            self.max_ms(),
        )
    }
}

/// Serving-wide metrics registry.
#[derive(Default)]
pub struct Metrics {
    pub request_latency: Histogram,
    pub queue_wait: Histogram,
    pub decode_latency: Histogram,
    pub requests_total: AtomicU64,
    pub requests_failed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub draft_tokens_accepted: AtomicU64,
    pub decoder_calls: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Result-cache traffic (`cache::ResultCache` consulted at admission).
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub cache_inserts: AtomicU64,
    pub cache_evictions: AtomicU64,
    /// Draft-source attribution of accepted tokens: paper-style query
    /// copies vs corpus-learned `cache::DraftStore` windows.
    pub draft_accepted_query: AtomicU64,
    pub draft_accepted_corpus: AtomicU64,
    /// Kernel-layer session accounting: `extend` ticks and the rows
    /// packed into them (`packed_rows / extend_calls` = mean fused batch
    /// per tick), plus the high-water mark of per-row retained log-prob
    /// positions.
    pub extend_calls: AtomicU64,
    pub packed_rows: AtomicU64,
    pub lp_high_water: AtomicU64,
    /// Encoder-side packing: encode passes and the source rows packed
    /// into them (`packed_src_rows / encode_calls` = mean packed encoder
    /// batch per call).
    pub encode_calls: AtomicU64,
    pub packed_src_rows: AtomicU64,
    /// Paged KV arena residency: currently resident pages (gauge —
    /// latest session snapshot wins), the high-water page count, total
    /// budget evictions, and pages copied by divergent-write COW after
    /// forks. All zero when `RXNSPEC_ARENA=off` (dense path).
    pub kv_pages_resident: AtomicU64,
    pub kv_pages_high_water: AtomicU64,
    pub kv_page_bytes: AtomicU64,
    pub arena_evictions: AtomicU64,
    pub fork_pages_copied: AtomicU64,
    /// Resilience accounting (the fault-tolerance layer): deadline-shed
    /// and capacity-refused admissions, stateless retries after a
    /// contained worker panic, panics caught by the supervision wrapper,
    /// batching ticks spent degraded plus the current degradation level
    /// (gauge: 0 = full drafts, 1 = corpus drafts off, 2 = speculation
    /// off), graceful-drain wall time (gauge, ms), and result-cache hits
    /// served from a warm-boot dump.
    pub requests_shed: AtomicU64,
    pub requests_busy: AtomicU64,
    pub requests_retried: AtomicU64,
    pub panics_contained: AtomicU64,
    pub degraded_ticks: AtomicU64,
    pub degrade_level: AtomicU64,
    pub drain_ms: AtomicU64,
    pub cache_warm_hits: AtomicU64,
    /// Pool accounting (the multi-worker serving tier): configured
    /// worker count (gauge), replacement workers spawned after a loss,
    /// requests reclaimed from lost workers and re-enqueued, and the
    /// per-slot contained-panic mirror (current incarnation; the
    /// pool-wide aggregate stays in `panics_contained` so the `resil_*`
    /// surface keeps its single-worker meaning).
    pub workers: AtomicU64,
    pub worker_restarts: AtomicU64,
    pub requests_reclaimed: AtomicU64,
    pub worker_panics: Mutex<Vec<u64>>,
}

impl Metrics {
    /// Fold a finished session's cache accounting into the registry —
    /// the one place the `SessionStats` → serving-counter mapping lives
    /// (the worker used to spell out every field at its call site).
    pub fn absorb_session(&self, s: &SessionStats) {
        self.extend_calls.fetch_add(s.extend_calls as u64, Ordering::Relaxed);
        self.packed_rows.fetch_add(s.packed_rows as u64, Ordering::Relaxed);
        self.lp_high_water.fetch_max(s.lp_high_water as u64, Ordering::Relaxed);
        self.encode_calls.fetch_add(s.encode_calls as u64, Ordering::Relaxed);
        self.packed_src_rows.fetch_add(s.packed_src_rows as u64, Ordering::Relaxed);
        // Residency is a gauge (latest session snapshot wins); the page
        // size is a static property of the arena configuration.
        self.kv_pages_resident.store(s.kv_pages_resident as u64, Ordering::Relaxed);
        self.kv_pages_high_water.fetch_max(s.kv_pages_high_water as u64, Ordering::Relaxed);
        if s.kv_page_bytes > 0 {
            self.kv_page_bytes.store(s.kv_page_bytes as u64, Ordering::Relaxed);
        }
        self.arena_evictions.fetch_add(s.arena_evictions as u64, Ordering::Relaxed);
        self.fork_pages_copied.fetch_add(s.fork_pages_copied as u64, Ordering::Relaxed);
    }

    /// Mirror one worker slot's contained-panic count into the per-slot
    /// vector rendered by `STATS` (grown on demand — the pool sizes it).
    pub fn set_worker_panics(&self, slot: usize, panics: u64) {
        let mut v = lock_ok(&self.worker_panics);
        if v.len() <= slot {
            v.resize(slot + 1, 0);
        }
        v[slot] = panics;
    }

    /// The arena counters as the shared snapshot struct (rendered by
    /// both `STATS` and the bench JSON writer).
    pub fn arena_counters(&self) -> ArenaCounters {
        ArenaCounters {
            kv_pages_resident: self.kv_pages_resident.load(Ordering::Relaxed),
            kv_pages_high_water: self.kv_pages_high_water.load(Ordering::Relaxed),
            kv_page_bytes: self.kv_page_bytes.load(Ordering::Relaxed),
            arena_evictions: self.arena_evictions.load(Ordering::Relaxed),
            fork_pages_copied: self.fork_pages_copied.load(Ordering::Relaxed),
            rehydrated_pages: 0,
        }
    }

    pub fn snapshot(&self) -> String {
        let req = self.requests_total.load(Ordering::Relaxed);
        let fail = self.requests_failed.load(Ordering::Relaxed);
        let toks = self.tokens_generated.load(Ordering::Relaxed);
        let acc = self.draft_tokens_accepted.load(Ordering::Relaxed);
        let calls = self.decoder_calls.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed).max(1);
        let breq = self.batched_requests.load(Ordering::Relaxed);
        let mut s = String::new();
        s.push_str(&format!(
            "requests={req} failed={fail} tokens={toks} accepted_draft_tokens={acc} \
             acceptance_rate={:.3} decoder_calls={calls} tokens_per_call={:.2} \
             mean_batch={:.2}\n",
            if toks == 0 { 0.0 } else { acc as f64 / toks as f64 },
            if calls == 0 { 0.0 } else { toks as f64 / calls as f64 },
            breq as f64 / batches as f64,
        ));
        let ch = self.cache_hits.load(Ordering::Relaxed);
        let cm = self.cache_misses.load(Ordering::Relaxed);
        let lookups = ch + cm;
        s.push_str(&format!(
            "cache_hits={ch} cache_misses={cm} cache_hit_rate={:.3} cache_inserts={} \
             cache_evictions={} draft_accepted_query={} draft_accepted_corpus={}\n",
            if lookups == 0 { 0.0 } else { ch as f64 / lookups as f64 },
            self.cache_inserts.load(Ordering::Relaxed),
            self.cache_evictions.load(Ordering::Relaxed),
            self.draft_accepted_query.load(Ordering::Relaxed),
            self.draft_accepted_corpus.load(Ordering::Relaxed),
        ));
        let ec = self.extend_calls.load(Ordering::Relaxed);
        let pr = self.packed_rows.load(Ordering::Relaxed);
        let enc = self.encode_calls.load(Ordering::Relaxed);
        let psr = self.packed_src_rows.load(Ordering::Relaxed);
        s.push_str(&format!(
            "kernel: extend_calls={ec} packed_rows={pr} packed_rows_per_call={:.2} \
             encode_calls={enc} packed_src_rows={psr} packed_src_rows_per_call={:.2} \
             lp_high_water={}\n",
            if ec == 0 { 0.0 } else { pr as f64 / ec as f64 },
            if enc == 0 { 0.0 } else { psr as f64 / enc as f64 },
            self.lp_high_water.load(Ordering::Relaxed),
        ));
        s.push_str(&self.arena_counters().render_line());
        s.push('\n');
        s.push_str(&format!(
            "resilience: requests_shed={} requests_busy={} requests_retried={} \
             panics_contained={} degraded_ticks={} degrade_level={} drain_ms={} \
             cache_warm_hits={} faults_injected={}\n",
            self.requests_shed.load(Ordering::Relaxed),
            self.requests_busy.load(Ordering::Relaxed),
            self.requests_retried.load(Ordering::Relaxed),
            self.panics_contained.load(Ordering::Relaxed),
            self.degraded_ticks.load(Ordering::Relaxed),
            self.degrade_level.load(Ordering::Relaxed),
            self.drain_ms.load(Ordering::Relaxed),
            self.cache_warm_hits.load(Ordering::Relaxed),
            crate::faults::injected(),
        ));
        let per_slot: Vec<String> = lock_ok(&self.worker_panics)
            .iter()
            .map(|p| p.to_string())
            .collect();
        s.push_str(&format!(
            "pool: workers={} worker_restarts={} requests_reclaimed={} worker_panics=[{}]\n",
            self.workers.load(Ordering::Relaxed),
            self.worker_restarts.load(Ordering::Relaxed),
            self.requests_reclaimed.load(Ordering::Relaxed),
            per_slot.join(","),
        ));
        s.push_str(&self.request_latency.summary("request_latency"));
        s.push('\n');
        s.push_str(&self.queue_wait.summary("queue_wait"));
        s.push('\n');
        s.push_str(&self.decode_latency.summary("decode_latency"));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn histogram_records_and_reports() {
        let h = Histogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_ms() - 23.0).abs() < 0.5);
        assert!(h.max_ms() >= 100.0);
        let p50 = h.quantile_ms(0.5);
        assert!(p50 >= 2.0 && p50 <= 8.0, "p50 {p50}");
        assert!(h.quantile_ms(1.0) >= 64.0);
    }

    #[test]
    fn bucket_of_exact_powers_of_two() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        for k in 1..=24u32 {
            assert_eq!(
                Histogram::bucket_of(1u64 << k),
                (2 * k) as usize,
                "2^{k} must open bucket {}",
                2 * k
            );
            // One below the power stays in the previous half-step.
            assert!(Histogram::bucket_of((1u64 << k) - 1) < (2 * k) as usize);
        }
    }

    #[test]
    fn bucket_of_sqrt2_boundaries_are_exact() {
        // The old mantissa-bit shortcut put the half-step at 1.5·2^k;
        // the true boundary is √2·2^k ≈ 1.41421·2^k. Values in between
        // were misbucketed — pin the exact integer boundary per octave,
        // found by binary search on the defining inequality.
        fn exact_boundary(k: u32) -> u64 {
            let target = 1u128 << (2 * k + 1);
            let (mut lo, mut hi) = (1u64 << k, 1u64 << (k + 1));
            while lo + 1 < hi {
                let mid = lo + (hi - lo) / 2;
                if (mid as u128) * (mid as u128) >= target {
                    hi = mid;
                } else {
                    lo = mid;
                }
            }
            hi
        }
        for k in 1..=20u32 {
            let boundary = exact_boundary(k);
            assert_eq!(
                Histogram::bucket_of(boundary - 1),
                (2 * k) as usize,
                "just below √2·2^{k}"
            );
            assert_eq!(
                Histogram::bucket_of(boundary),
                (2 * k + 1) as usize,
                "at √2·2^{k}"
            );
        }
        // The concrete regression: 1449 ≥ √2·1024 but < 1.5·1024 — the
        // old code filed it one half-step low.
        assert_eq!(Histogram::bucket_of(1448), 20);
        assert_eq!(Histogram::bucket_of(1449), 21);
    }

    #[test]
    fn bucket_of_is_monotone_and_edges_bracket() {
        let mut prev = 0usize;
        for us in 1..=100_000u64 {
            let b = Histogram::bucket_of(us);
            assert!(b >= prev, "bucket_of must be monotone at us={us}");
            prev = b;
            if b + 1 < N_BUCKETS {
                // Value sits strictly below its bucket's upper edge and
                // at/above the previous bucket's upper edge.
                assert!((us as f64) < Histogram::bucket_edge(b) * (1.0 + 1e-9), "us={us} b={b}");
                if b > 0 {
                    assert!(
                        (us as f64) >= Histogram::bucket_edge(b - 1) * (1.0 - 1e-9),
                        "us={us} b={b}"
                    );
                }
            }
        }
    }

    #[test]
    fn bucket_edges_strictly_increase() {
        for i in 1..N_BUCKETS {
            assert!(Histogram::bucket_edge(i) > Histogram::bucket_edge(i - 1));
        }
    }

    #[test]
    fn quantile_reports_upper_bucket_edge_in_us() {
        let h = Histogram::new();
        for ms in [1u64, 2, 4, 8, 100] {
            h.record(Duration::from_millis(ms));
        }
        // The 3rd of 5 samples is 4ms = 4000µs → bucket 23
        // ([2^11.5, 2^12)), whose upper edge is 2^12 = 4096µs.
        assert_eq!(h.quantile(0.5), 4096);
        assert!(h.quantile(0.99) >= 100_000);
        assert_eq!(Histogram::new().quantile(0.99), 0);
        let (p50, p95) = (h.quantile(0.50), h.quantile(0.95));
        assert!(p50 <= p95);
    }

    #[test]
    fn absorb_session_folds_every_counter() {
        use crate::decoding::SessionStats;
        let m = Metrics::default();
        let s = SessionStats {
            extend_calls: 3,
            packed_rows: 12,
            lp_high_water: 9,
            encode_calls: 2,
            packed_src_rows: 5,
            kv_pages_resident: 4,
            kv_pages_high_water: 6,
            kv_page_bytes: 512,
            arena_evictions: 1,
            fork_pages_copied: 2,
            ..SessionStats::default()
        };
        m.absorb_session(&s);
        m.absorb_session(&s);
        assert_eq!(m.extend_calls.load(Ordering::Relaxed), 6);
        assert_eq!(m.lp_high_water.load(Ordering::Relaxed), 9);
        // Gauge semantics: residency is the latest snapshot, not a sum.
        assert_eq!(m.kv_pages_resident.load(Ordering::Relaxed), 4);
        assert_eq!(m.arena_evictions.load(Ordering::Relaxed), 2);
        let ac = m.arena_counters();
        assert_eq!(ac.kv_bytes_resident(), 4 * 512);
        assert!(m.snapshot().contains("kv_pages_resident=4"));
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 37));
        }
        let (p25, p50, p95) = (h.quantile_ms(0.25), h.quantile_ms(0.5), h.quantile_ms(0.95));
        assert!(p25 <= p50 && p50 <= p95, "{p25} {p50} {p95}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.quantile_ms(0.99), 0.0);
    }

    #[test]
    fn metrics_snapshot_contains_rates() {
        let m = Metrics::default();
        m.requests_total.store(10, Ordering::Relaxed);
        m.tokens_generated.store(100, Ordering::Relaxed);
        m.draft_tokens_accepted.store(79, Ordering::Relaxed);
        m.decoder_calls.store(25, Ordering::Relaxed);
        let snap = m.snapshot();
        assert!(snap.contains("acceptance_rate=0.790"));
        assert!(snap.contains("tokens_per_call=4.00"));
    }

    #[test]
    fn metrics_snapshot_exposes_arena_counters() {
        let m = Metrics::default();
        m.kv_pages_resident.store(12, Ordering::Relaxed);
        m.kv_pages_high_water.store(20, Ordering::Relaxed);
        m.kv_page_bytes.store(4096, Ordering::Relaxed);
        m.arena_evictions.store(3, Ordering::Relaxed);
        m.fork_pages_copied.store(7, Ordering::Relaxed);
        let snap = m.snapshot();
        assert!(snap.contains("kv_pages_resident=12"));
        assert!(snap.contains("kv_pages_high_water=20"));
        assert!(snap.contains("kv_bytes_resident=49152"));
        assert!(snap.contains("arena_evictions=3"));
        assert!(snap.contains("fork_pages_copied=7"));
    }

    #[test]
    fn metrics_snapshot_exposes_resilience_counters() {
        let m = Metrics::default();
        m.requests_shed.store(4, Ordering::Relaxed);
        m.requests_busy.store(2, Ordering::Relaxed);
        m.requests_retried.store(3, Ordering::Relaxed);
        m.panics_contained.store(3, Ordering::Relaxed);
        m.degraded_ticks.store(11, Ordering::Relaxed);
        m.degrade_level.store(1, Ordering::Relaxed);
        m.drain_ms.store(17, Ordering::Relaxed);
        m.cache_warm_hits.store(5, Ordering::Relaxed);
        let snap = m.snapshot();
        assert!(snap.contains("requests_shed=4"));
        assert!(snap.contains("requests_busy=2"));
        assert!(snap.contains("requests_retried=3"));
        assert!(snap.contains("panics_contained=3"));
        assert!(snap.contains("degraded_ticks=11"));
        assert!(snap.contains("degrade_level=1"));
        assert!(snap.contains("drain_ms=17"));
        assert!(snap.contains("cache_warm_hits=5"));
        assert!(snap.contains("faults_injected="));
        // The resilience line must come before the latency summaries so
        // `decode_latency` stays the client-side STATS terminator.
        let res = snap.find("resilience:").unwrap();
        let dec = snap.find("decode_latency:").unwrap();
        assert!(res < dec);
    }

    #[test]
    fn metrics_snapshot_exposes_pool_counters() {
        let m = Metrics::default();
        m.workers.store(4, Ordering::Relaxed);
        m.worker_restarts.store(2, Ordering::Relaxed);
        m.requests_reclaimed.store(3, Ordering::Relaxed);
        m.set_worker_panics(0, 1);
        m.set_worker_panics(3, 5);
        let snap = m.snapshot();
        assert!(snap.contains("pool: workers=4 worker_restarts=2 requests_reclaimed=3"));
        // Slots 1 and 2 were never reported — rendered as zeros.
        assert!(snap.contains("worker_panics=[1,0,0,5]"));
        // The pool line must also precede the latency summaries so the
        // client-side STATS terminator (`decode_latency`) stays last.
        let pool = snap.find("pool:").unwrap();
        let dec = snap.find("decode_latency:").unwrap();
        assert!(pool < dec);
    }

    #[test]
    fn metrics_snapshot_exposes_cache_counters() {
        let m = Metrics::default();
        m.cache_hits.store(3, Ordering::Relaxed);
        m.cache_misses.store(1, Ordering::Relaxed);
        m.cache_inserts.store(1, Ordering::Relaxed);
        m.cache_evictions.store(0, Ordering::Relaxed);
        m.draft_accepted_query.store(70, Ordering::Relaxed);
        m.draft_accepted_corpus.store(9, Ordering::Relaxed);
        m.encode_calls.store(4, Ordering::Relaxed);
        m.packed_src_rows.store(10, Ordering::Relaxed);
        let snap = m.snapshot();
        assert!(snap.contains("packed_src_rows_per_call=2.50"));
        assert!(snap.contains("cache_hits=3"));
        assert!(snap.contains("cache_hit_rate=0.750"));
        assert!(snap.contains("draft_accepted_query=70"));
        assert!(snap.contains("draft_accepted_corpus=9"));
        // Empty registry renders a zero rate, not NaN.
        let empty = Metrics::default();
        assert!(empty.snapshot().contains("cache_hit_rate=0.000"));
    }
}
