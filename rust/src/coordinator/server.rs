//! TCP line-protocol front end — the "AI assistant for chemists" serving
//! surface.
//!
//! Protocol (one request per line, UTF-8):
//!     PREDICT <decoder> <smiles>      decoder ∈ greedy | spec:<dl> |
//!                                     bs:<n> | sbs:<n>:<dl>
//!     STATS                           cache state + metrics snapshot
//!     TRACE [<path>]                  Chrome trace JSON of collected
//!                                     spans — inline (one line) or
//!                                     written server-side to <path>
//!     PING                            liveness
//!     QUIT                            close connection
//!
//! Responses:
//!     OK <latency_ms> <calls> <acc_rate> <hyp> <score> [<hyp> <score>…]
//!     ERR <message>
//!     PONG
//!
//! SMILES never contain spaces, so space-separated framing is safe.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::Result;

use crate::cache::ServeCache;
use crate::coordinator::batcher::{DecodeMode, RequestQueue};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::worker::{Job, JobResult};

/// Shared server state handed to every connection thread.
pub struct ServerState {
    pub queue: RequestQueue<Job>,
    pub metrics: Arc<Metrics>,
    /// The worker's cache pair; `STATS` renders its live state.
    pub cache: Arc<ServeCache>,
    pub shutdown: AtomicBool,
}

/// Accept loop: one thread per connection. Returns when `shutdown` is set
/// (checked between accepts; use a connect to self to wake it) or the
/// listener errors out.
pub fn serve(listener: TcpListener, state: Arc<ServerState>) -> Result<()> {
    listener.set_nonblocking(false)?;
    for stream in listener.incoming() {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                let st = Arc::clone(&state);
                std::thread::spawn(move || {
                    let _ = handle_conn(s, st);
                });
            }
            Err(e) => {
                eprintln!("accept error: {e}");
            }
        }
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, state: Arc<ServerState>) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // EOF
        }
        let t0 = Instant::now();
        let trimmed = line.trim_end();
        let reply = handle_line(trimmed, &state);
        state.metrics.request_latency.record(t0.elapsed());
        match reply {
            LineReply::Text(s) => {
                writer.write_all(s.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            LineReply::Quit => return Ok(()),
        }
    }
}

enum LineReply {
    Text(String),
    Quit,
}

fn handle_line(line: &str, state: &Arc<ServerState>) -> LineReply {
    let mut parts = line.splitn(3, ' ');
    match parts.next() {
        Some("PING") => LineReply::Text("PONG".to_string()),
        Some("STATS") => {
            // Cache line first, metrics after — the metrics snapshot ends
            // with the decode_latency line clients use as a terminator.
            let mut s = state.cache.describe();
            s.push('\n');
            s.push_str(&state.metrics.snapshot());
            LineReply::Text(s)
        }
        Some("QUIT") => LineReply::Quit,
        Some("TRACE") => {
            // `chrome_trace_json` renders single-line, so the inline
            // reply keeps the one-response-per-line framing intact.
            let json = crate::trace::export_chrome_json();
            match parts.next() {
                Some(path) if !path.trim().is_empty() => {
                    match std::fs::write(path.trim(), &json) {
                        Ok(()) => LineReply::Text(format!(
                            "OK wrote {} bytes to {}",
                            json.len(),
                            path.trim()
                        )),
                        Err(e) => LineReply::Text(format!("ERR trace write: {e}")),
                    }
                }
                _ => LineReply::Text(json),
            }
        }
        Some("PREDICT") => {
            let (Some(dec), Some(smiles)) = (parts.next(), parts.next()) else {
                return LineReply::Text("ERR usage: PREDICT <decoder> <smiles>".to_string());
            };
            let Some(mode) = DecodeMode::parse(dec) else {
                return LineReply::Text(format!("ERR unknown decoder {dec:?}"));
            };
            let t0 = Instant::now();
            let (tx, rx) = mpsc::channel::<JobResult>();
            state.queue.push(
                mode,
                Job {
                    smiles: smiles.trim().to_string(),
                    resp: tx,
                },
            );
            match rx.recv() {
                Ok(Ok(reply)) => {
                    let ms = t0.elapsed().as_secs_f64() * 1000.0;
                    let mut s = format!(
                        "OK {ms:.2} {} {:.3}",
                        reply.decoder_calls, reply.acceptance_rate
                    );
                    for (h, score) in &reply.hyps {
                        s.push_str(&format!(" {h} {score:.4}"));
                    }
                    LineReply::Text(s)
                }
                Ok(Err(e)) => LineReply::Text(format!("ERR {e}")),
                Err(_) => LineReply::Text("ERR worker gone".to_string()),
            }
        }
        _ => LineReply::Text("ERR unknown command".to_string()),
    }
}

/// Simple blocking client for the line protocol (used by examples, tests
/// and the load generator).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// One parsed PREDICT response.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub latency_ms: f64,
    pub decoder_calls: usize,
    pub acceptance_rate: f64,
    pub hyps: Vec<(String, f64)>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    fn roundtrip(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        Ok(resp.trim_end().to_string())
    }

    pub fn ping(&mut self) -> Result<bool> {
        Ok(self.roundtrip("PING")? == "PONG")
    }

    pub fn predict(&mut self, decoder: &str, smiles: &str) -> Result<Prediction> {
        let resp = self.roundtrip(&format!("PREDICT {decoder} {smiles}"))?;
        let mut f = resp.split(' ');
        match f.next() {
            Some("OK") => {
                let latency_ms: f64 = f.next().unwrap_or("0").parse()?;
                let decoder_calls: usize = f.next().unwrap_or("0").parse()?;
                let acceptance_rate: f64 = f.next().unwrap_or("0").parse()?;
                let rest: Vec<&str> = f.collect();
                let hyps = rest
                    .chunks(2)
                    .filter(|c| c.len() == 2)
                    .map(|c| (c[0].to_string(), c[1].parse().unwrap_or(0.0)))
                    .collect();
                Ok(Prediction {
                    latency_ms,
                    decoder_calls,
                    acceptance_rate,
                    hyps,
                })
            }
            Some("ERR") => anyhow::bail!("server: {}", resp),
            _ => anyhow::bail!("bad response: {resp}"),
        }
    }

    /// Fetch the collected span trace as one line of Chrome trace JSON.
    pub fn trace_json(&mut self) -> Result<String> {
        self.roundtrip("TRACE")
    }

    pub fn stats(&mut self) -> Result<String> {
        // STATS is multi-line; read until the decode_latency line.
        self.writer.write_all(b"STATS\n")?;
        let mut out = String::new();
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            out.push_str(&line);
            if line.starts_with("decode_latency") || line.is_empty() {
                break;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::run_worker;
    use crate::testutil::CopyModel;
    use crate::vocab::Vocab;
    use std::time::Duration;

    /// Full in-process serving round trip over a real TCP socket.
    #[test]
    fn tcp_round_trip_with_copy_model() {
        let vocab = Vocab::build(["CCONF", "c1ccccc1Br"]).unwrap();
        let state = Arc::new(ServerState {
            queue: RequestQueue::new(8, Duration::from_millis(1)),
            metrics: Arc::new(Metrics::default()),
            cache: Arc::new(ServeCache::default()),
            shutdown: AtomicBool::new(false),
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();

        let accept_state = Arc::clone(&state);
        std::thread::spawn(move || serve(listener, accept_state));
        let worker_state = Arc::clone(&state);
        let worker = std::thread::spawn(move || {
            let backend = CopyModel::new(96, 96, 20);
            let vocab = Vocab::build(["CCONF", "c1ccccc1Br"]).unwrap();
            run_worker(
                &backend,
                &vocab,
                &worker_state.queue,
                &worker_state.metrics,
                &worker_state.cache,
            );
        });

        let mut c = Client::connect(&addr).unwrap();
        assert!(c.ping().unwrap());
        let p = c.predict("greedy", "CCO").unwrap();
        assert_eq!(p.hyps[0].0, "CCO");
        let p = c.predict("spec:4", "c1ccccc1").unwrap();
        assert_eq!(p.hyps[0].0, "c1ccccc1");
        assert!(p.acceptance_rate > 0.0);
        let p = c.predict("sbs:2:4", "CCO").unwrap();
        assert!(!p.hyps.is_empty());
        // A repeated request is served from the result cache, verbatim.
        let hit = c.predict("spec:4", "c1ccccc1").unwrap();
        assert_eq!(hit.hyps[0].0, "c1ccccc1");
        assert_eq!(hit.decoder_calls, 0, "repeat must be a cache hit");
        // Errors are per-request, connection stays usable.
        assert!(c.predict("greedy", "!!bad!!").is_err());
        assert!(c.ping().unwrap());
        let stats = c.stats().unwrap();
        assert!(stats.contains("cache: enabled=true"));
        assert!(stats.contains("requests="));
        assert!(stats.contains("cache_hits=1"));
        // TRACE always answers one line of valid Chrome trace JSON,
        // even with RXNSPEC_TRACE off (empty event array).
        let tr = c.trace_json().unwrap();
        assert!(tr.starts_with("{\"traceEvents\":["), "bad trace reply: {tr}");

        let _ = vocab;
        state.queue.close();
        worker.join().unwrap();
    }

    #[test]
    fn unknown_decoder_is_rejected() {
        let state = Arc::new(ServerState {
            queue: RequestQueue::new(2, Duration::from_millis(1)),
            metrics: Arc::new(Metrics::default()),
            cache: Arc::new(ServeCache::default()),
            shutdown: AtomicBool::new(false),
        });
        match handle_line("PREDICT wat CCO", &state) {
            LineReply::Text(t) => assert!(t.starts_with("ERR")),
            _ => panic!("expected ERR"),
        }
        match handle_line("NONSENSE", &state) {
            LineReply::Text(t) => assert!(t.starts_with("ERR")),
            _ => panic!("expected ERR"),
        }
    }
}
