//! TCP line-protocol front end — the "AI assistant for chemists" serving
//! surface.
//!
//! Protocol (one request per line, UTF-8):
//!     [DEADLINE <ms>] PREDICT <decoder> <smiles>
//!                                     decoder ∈ greedy | spec:<dl> |
//!                                     bs:<n> | sbs:<n>:<dl>; the optional
//!                                     prefix bounds how long the request
//!                                     may wait + decode before the server
//!                                     sheds it (default: RXNSPEC_SLO_MS)
//!     STATS                           cache state + metrics snapshot
//!     TRACE [<path>]                  Chrome trace JSON of collected
//!                                     spans — inline (one line) or
//!                                     written server-side to <path>
//!     PING                            liveness
//!     SHUTDOWN                        begin graceful drain (admissions
//!                                     stop, in-flight work completes)
//!     QUIT                            close connection
//!
//! Responses:
//!     OK <latency_ms> <calls> <acc_rate> <hyp> <score> [<hyp> <score>…]
//!     ERR <message>
//!     BUSY <reason>                   over capacity — retry later; the
//!                                     request was NOT admitted
//!     PONG
//!
//! SMILES never contain spaces, so space-separated framing is safe.
//!
//! Backpressure is explicit: a full queue answers `BUSY queue_full` and a
//! connection over `RXNSPEC_MAX_CONNS` answers `BUSY max_connections` —
//! immediately, instead of letting latency absorb the overload. Expired
//! requests come back as `ERR deadline_exceeded` (shed server-side before
//! they ever occupy a decode lane).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use std::sync::Mutex;

use crate::cache::ServeCache;
use crate::coordinator::batcher::{lock_ok, DecodeMode, PushError, RequestQueue};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::worker::{Job, JobResult};

/// How long a connection thread blocks in one read before re-checking the
/// shutdown flag. Bounds how stale an idle connection's view of a drain
/// can be — and therefore how long [`serve`]'s join phase waits.
const READ_TICK: Duration = Duration::from_millis(250);

/// Accept-loop poll interval while no connection is pending.
const ACCEPT_TICK: Duration = Duration::from_millis(5);

/// Shared server state handed to every connection thread.
pub struct ServerState {
    pub queue: RequestQueue<Job>,
    pub metrics: Arc<Metrics>,
    /// The worker's cache pair; `STATS` renders its live state.
    pub cache: Arc<ServeCache>,
    pub shutdown: AtomicBool,
    /// Deadline attached to `PREDICT` lines that carry no explicit
    /// `DEADLINE` prefix (`RXNSPEC_SLO_MS`; `None` = wait forever).
    pub default_slo: Option<Duration>,
    /// Concurrent-connection cap; the accept loop answers
    /// `BUSY max_connections` beyond it (`RXNSPEC_MAX_CONNS`).
    pub max_conns: usize,
    /// When [`ServerState::begin_shutdown`] first ran — the `drain_ms`
    /// metric measures from here to full stop.
    drain_started: Mutex<Option<Instant>>,
}

impl ServerState {
    /// Build serving state with SLO and connection limits from the
    /// environment: `RXNSPEC_SLO_MS` (default: no deadline; `0` also
    /// means none) and `RXNSPEC_MAX_CONNS` (default 256).
    pub fn new(
        queue: RequestQueue<Job>,
        metrics: Arc<Metrics>,
        cache: Arc<ServeCache>,
    ) -> ServerState {
        let slo_ms = crate::knobs::SLO_MS.parsed::<u64>().filter(|ms| *ms > 0);
        let max_conns = crate::knobs::MAX_CONNS.parsed_or(256usize).max(1);
        ServerState::with_limits(queue, metrics, cache, slo_ms.map(Duration::from_millis), max_conns)
    }

    /// Build serving state with explicit limits (tests, benches).
    pub fn with_limits(
        queue: RequestQueue<Job>,
        metrics: Arc<Metrics>,
        cache: Arc<ServeCache>,
        default_slo: Option<Duration>,
        max_conns: usize,
    ) -> ServerState {
        ServerState {
            queue,
            metrics,
            cache,
            shutdown: AtomicBool::new(false),
            default_slo,
            max_conns,
            drain_started: Mutex::new(None),
        }
    }

    /// Stop admissions and close the queue — the worker drains what is
    /// already in flight, connection threads exit at their next read
    /// tick, and [`serve`] joins them and returns. Idempotent (the first
    /// call stamps the drain start).
    pub fn begin_shutdown(&self) {
        let mut started = lock_ok(&self.drain_started);
        if started.is_none() {
            *started = Some(Instant::now());
        }
        drop(started);
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
    }

    /// When the drain began, if one has.
    pub fn drain_started(&self) -> Option<Instant> {
        *lock_ok(&self.drain_started)
    }
}

/// Accept loop. Polls a nonblocking listener (no wake-up connection
/// tricks: the shutdown flag is observed within one [`ACCEPT_TICK`]),
/// tracks every connection thread, and on shutdown joins them all before
/// returning — by then every admitted request has been replied to.
pub fn serve(listener: TcpListener, state: Arc<ServerState>) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !state.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut stream, _)) => {
                conns.retain(|h| !h.is_finished());
                if conns.len() >= state.max_conns {
                    state.metrics.requests_busy.fetch_add(1, Ordering::Relaxed);
                    let _ = stream.write_all(b"BUSY max_connections\n");
                    continue; // drop closes the socket
                }
                let st = Arc::clone(&state);
                conns.push(std::thread::spawn(move || {
                    let _ = handle_conn(stream, st);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                conns.retain(|h| !h.is_finished());
                std::thread::sleep(ACCEPT_TICK);
            }
            Err(e) => {
                eprintln!("accept error: {e}");
                std::thread::sleep(ACCEPT_TICK);
            }
        }
    }
    for h in conns {
        let _ = h.join();
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, state: Arc<ServerState>) -> Result<()> {
    // The listener is nonblocking; the per-connection socket must block
    // with a bounded read so the thread can observe a drain.
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(READ_TICK))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            return Ok(()); // drain: drop the connection
        }
        // `read_line` appends; a timeout mid-line keeps the partial
        // prefix in `line` and the next pass completes it.
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        let t0 = Instant::now();
        let reply = handle_line(line.trim_end(), &state);
        line.clear();
        state.metrics.request_latency.record(t0.elapsed());
        match reply {
            LineReply::Text(s) => {
                writer.write_all(s.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            LineReply::Quit => return Ok(()),
        }
    }
}

enum LineReply {
    Text(String),
    Quit,
}

fn handle_line(line: &str, state: &Arc<ServerState>) -> LineReply {
    // Optional per-request deadline: "DEADLINE <ms> <command…>".
    let (line, deadline) = match line.strip_prefix("DEADLINE ") {
        Some(rest) => {
            let mut p = rest.splitn(2, ' ');
            match (p.next().and_then(|ms| ms.parse::<u64>().ok()), p.next()) {
                (Some(ms), Some(cmd)) => {
                    (cmd, Some(Instant::now() + Duration::from_millis(ms)))
                }
                _ => {
                    return LineReply::Text("ERR usage: DEADLINE <ms> <command>".to_string())
                }
            }
        }
        None => (line, state.default_slo.map(|slo| Instant::now() + slo)),
    };
    let mut parts = line.splitn(3, ' ');
    match parts.next() {
        Some("PING") => LineReply::Text("PONG".to_string()),
        Some("STATS") => {
            // Cache line first, metrics after — the metrics snapshot ends
            // with the decode_latency line clients use as a terminator.
            let mut s = state.cache.describe();
            s.push('\n');
            s.push_str(&state.metrics.snapshot());
            LineReply::Text(s)
        }
        Some("QUIT") => LineReply::Quit,
        Some("SHUTDOWN") => {
            state.begin_shutdown();
            LineReply::Text("OK draining".to_string())
        }
        Some("TRACE") => {
            // `chrome_trace_json` renders single-line, so the inline
            // reply keeps the one-response-per-line framing intact.
            let json = crate::trace::export_chrome_json();
            match parts.next() {
                Some(path) if !path.trim().is_empty() => {
                    match std::fs::write(path.trim(), &json) {
                        Ok(()) => LineReply::Text(format!(
                            "OK wrote {} bytes to {}",
                            json.len(),
                            path.trim()
                        )),
                        Err(e) => LineReply::Text(format!("ERR trace write: {e}")),
                    }
                }
                _ => LineReply::Text(json),
            }
        }
        Some("PREDICT") => {
            let (Some(dec), Some(smiles)) = (parts.next(), parts.next()) else {
                return LineReply::Text("ERR usage: PREDICT <decoder> <smiles>".to_string());
            };
            let Some(mode) = DecodeMode::parse(dec) else {
                return LineReply::Text(format!("ERR unknown decoder {dec:?}"));
            };
            let t0 = Instant::now();
            let (tx, rx) = mpsc::channel::<JobResult>();
            let job = Job::new(smiles.trim().to_string(), tx);
            match state.queue.try_push(mode, job, deadline) {
                Ok(()) => {}
                Err(PushError::Full(_)) => {
                    state.metrics.requests_busy.fetch_add(1, Ordering::Relaxed);
                    return LineReply::Text("BUSY queue_full".to_string());
                }
                Err(PushError::Closed(_)) => {
                    return LineReply::Text("ERR shutting_down".to_string());
                }
            }
            match rx.recv() {
                Ok(Ok(reply)) => {
                    let ms = t0.elapsed().as_secs_f64() * 1000.0;
                    let mut s = format!(
                        "OK {ms:.2} {} {:.3}",
                        reply.decoder_calls, reply.acceptance_rate
                    );
                    for (h, score) in &reply.hyps {
                        s.push_str(&format!(" {h} {score:.4}"));
                    }
                    LineReply::Text(s)
                }
                Ok(Err(e)) => LineReply::Text(format!("ERR {e}")),
                Err(_) => LineReply::Text("ERR worker gone".to_string()),
            }
        }
        _ => LineReply::Text("ERR unknown command".to_string()),
    }
}

/// Simple blocking client for the line protocol (used by examples, tests
/// and the load generator).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// One parsed PREDICT response.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub latency_ms: f64,
    pub decoder_calls: usize,
    pub acceptance_rate: f64,
    pub hyps: Vec<(String, f64)>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    fn roundtrip(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        Ok(resp.trim_end().to_string())
    }

    pub fn ping(&mut self) -> Result<bool> {
        Ok(self.roundtrip("PING")? == "PONG")
    }

    pub fn predict(&mut self, decoder: &str, smiles: &str) -> Result<Prediction> {
        let resp = self.roundtrip(&format!("PREDICT {decoder} {smiles}"))?;
        Self::parse_predict(&resp)
    }

    /// `PREDICT` with an explicit per-request deadline. `ERR
    /// deadline_exceeded` (shed) and `BUSY …` (not admitted) both
    /// surface as errors.
    pub fn predict_with_deadline(
        &mut self,
        deadline_ms: u64,
        decoder: &str,
        smiles: &str,
    ) -> Result<Prediction> {
        let resp =
            self.roundtrip(&format!("DEADLINE {deadline_ms} PREDICT {decoder} {smiles}"))?;
        Self::parse_predict(&resp)
    }

    fn parse_predict(resp: &str) -> Result<Prediction> {
        let mut f = resp.split(' ');
        match f.next() {
            Some("OK") => {
                let latency_ms: f64 = f.next().unwrap_or("0").parse()?;
                let decoder_calls: usize = f.next().unwrap_or("0").parse()?;
                let acceptance_rate: f64 = f.next().unwrap_or("0").parse()?;
                let rest: Vec<&str> = f.collect();
                let hyps = rest
                    .chunks(2)
                    .filter(|c| c.len() == 2)
                    .map(|c| (c[0].to_string(), c[1].parse().unwrap_or(0.0)))
                    .collect();
                Ok(Prediction {
                    latency_ms,
                    decoder_calls,
                    acceptance_rate,
                    hyps,
                })
            }
            Some("ERR") => anyhow::bail!("server: {}", resp),
            Some("BUSY") => anyhow::bail!("server busy: {}", resp),
            _ => anyhow::bail!("bad response: {resp}"),
        }
    }

    /// Ask the server to drain gracefully. Returns its acknowledgement.
    pub fn shutdown(&mut self) -> Result<String> {
        self.roundtrip("SHUTDOWN")
    }

    /// Fetch the collected span trace as one line of Chrome trace JSON.
    pub fn trace_json(&mut self) -> Result<String> {
        self.roundtrip("TRACE")
    }

    pub fn stats(&mut self) -> Result<String> {
        // STATS is multi-line; read until the decode_latency line.
        self.writer.write_all(b"STATS\n")?;
        let mut out = String::new();
        loop {
            let mut line = String::new();
            self.reader.read_line(&mut line)?;
            out.push_str(&line);
            if line.starts_with("decode_latency") || line.is_empty() {
                break;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::run_worker;
    use crate::testutil::CopyModel;
    use crate::vocab::Vocab;
    use std::io::Read;

    fn test_state(queue: RequestQueue<Job>) -> Arc<ServerState> {
        Arc::new(ServerState::with_limits(
            queue,
            Arc::new(Metrics::default()),
            Arc::new(ServeCache::default()),
            None,
            256,
        ))
    }

    /// Full in-process serving round trip over a real TCP socket,
    /// finishing with a graceful SHUTDOWN that joins every thread.
    #[test]
    fn tcp_round_trip_with_copy_model() {
        let state = test_state(RequestQueue::new(8, Duration::from_millis(1)));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();

        let accept_state = Arc::clone(&state);
        let acceptor = std::thread::spawn(move || serve(listener, accept_state));
        let worker_state = Arc::clone(&state);
        let worker = std::thread::spawn(move || {
            let backend = CopyModel::new(96, 96, 20);
            let vocab = Vocab::build(["CCONF", "c1ccccc1Br"]).unwrap();
            run_worker(
                &backend,
                &vocab,
                &worker_state.queue,
                &worker_state.metrics,
                &worker_state.cache,
            );
        });

        let mut c = Client::connect(&addr).unwrap();
        assert!(c.ping().unwrap());
        let p = c.predict("greedy", "CCO").unwrap();
        assert_eq!(p.hyps[0].0, "CCO");
        let p = c.predict("spec:4", "c1ccccc1").unwrap();
        assert_eq!(p.hyps[0].0, "c1ccccc1");
        assert!(p.acceptance_rate > 0.0);
        let p = c.predict("sbs:2:4", "CCO").unwrap();
        assert!(!p.hyps.is_empty());
        // A repeated request is served from the result cache, verbatim.
        let hit = c.predict("spec:4", "c1ccccc1").unwrap();
        assert_eq!(hit.hyps[0].0, "c1ccccc1");
        assert_eq!(hit.decoder_calls, 0, "repeat must be a cache hit");
        // A generous explicit deadline is honored (not shed).
        let p = c.predict_with_deadline(60_000, "greedy", "CCO").unwrap();
        assert_eq!(p.hyps[0].0, "CCO");
        // An already-expired deadline is shed server-side.
        let err = c
            .predict_with_deadline(0, "greedy", "c1ccccc1Br")
            .unwrap_err();
        assert!(err.to_string().contains("deadline_exceeded"), "{err}");
        // Errors are per-request, connection stays usable.
        assert!(c.predict("greedy", "!!bad!!").is_err());
        assert!(c.ping().unwrap());
        let stats = c.stats().unwrap();
        assert!(stats.contains("cache: enabled=true"));
        assert!(stats.contains("requests="));
        assert!(stats.contains("cache_hits=1"));
        assert!(stats.contains("requests_shed=1"));
        // TRACE always answers one line of valid Chrome trace JSON,
        // even with RXNSPEC_TRACE off (empty event array).
        let tr = c.trace_json().unwrap();
        assert!(tr.starts_with("{\"traceEvents\":["), "bad trace reply: {tr}");

        // Graceful drain: SHUTDOWN stops admissions, the worker drains,
        // and the accept loop joins every connection thread.
        assert_eq!(c.shutdown().unwrap(), "OK draining");
        worker.join().unwrap();
        acceptor.join().unwrap().unwrap();
    }

    #[test]
    fn unknown_decoder_is_rejected() {
        let state = test_state(RequestQueue::new(2, Duration::from_millis(1)));
        match handle_line("PREDICT wat CCO", &state) {
            LineReply::Text(t) => assert!(t.starts_with("ERR")),
            _ => panic!("expected ERR"),
        }
        match handle_line("NONSENSE", &state) {
            LineReply::Text(t) => assert!(t.starts_with("ERR")),
            _ => panic!("expected ERR"),
        }
        match handle_line("DEADLINE nope PREDICT greedy CCO", &state) {
            LineReply::Text(t) => assert!(t.starts_with("ERR usage: DEADLINE")),
            _ => panic!("expected ERR"),
        }
    }

    /// A full queue answers BUSY immediately — the reply is explicit,
    /// not a silent drop, and the request is never admitted.
    #[test]
    fn full_queue_answers_busy() {
        let state = test_state(RequestQueue::with_capacity(
            8,
            Duration::from_millis(1),
            1,
        ));
        // Fill the single admission slot directly.
        let (tx, _rx) = mpsc::channel();
        state
            .queue
            .try_push(
                DecodeMode::Greedy,
                Job::new("CCO".to_string(), tx),
                None,
            )
            .unwrap();
        match handle_line("PREDICT greedy CCO", &state) {
            LineReply::Text(t) => assert_eq!(t, "BUSY queue_full"),
            _ => panic!("expected BUSY"),
        }
        assert_eq!(state.metrics.requests_busy.load(Ordering::Relaxed), 1);
        assert_eq!(state.queue.len(), 1, "rejected request must not be admitted");
    }

    /// After SHUTDOWN, new PREDICTs are refused as shutting_down.
    #[test]
    fn shutdown_refuses_new_admissions() {
        let state = test_state(RequestQueue::new(8, Duration::from_millis(1)));
        match handle_line("SHUTDOWN", &state) {
            LineReply::Text(t) => assert_eq!(t, "OK draining"),
            _ => panic!("expected OK"),
        }
        assert!(state.shutdown.load(Ordering::SeqCst));
        assert!(state.queue.is_closed());
        match handle_line("PREDICT greedy CCO", &state) {
            LineReply::Text(t) => assert_eq!(t, "ERR shutting_down"),
            _ => panic!("expected ERR"),
        }
    }

    /// Connections beyond `max_conns` get an explicit BUSY line, not a
    /// hang or a silent reset.
    #[test]
    fn connection_cap_answers_busy() {
        let state = Arc::new(ServerState::with_limits(
            RequestQueue::new(2, Duration::from_millis(1)),
            Arc::new(Metrics::default()),
            Arc::new(ServeCache::default()),
            None,
            0, // floor: every connection is over the cap
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let accept_state = Arc::clone(&state);
        let acceptor = std::thread::spawn(move || serve(listener, accept_state));

        let mut s = TcpStream::connect(&addr).unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap(); // server closes after BUSY
        assert_eq!(resp, "BUSY max_connections\n");
        assert!(state.metrics.requests_busy.load(Ordering::Relaxed) >= 1);

        state.begin_shutdown();
        acceptor.join().unwrap().unwrap();
    }
}
