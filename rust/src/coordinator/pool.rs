//! The supervised worker pool: N worker threads draining one shared
//! [`RequestQueue`], plus a supervisor that watches their heartbeats and
//! fails work over between them.
//!
//! Each worker owns its **own backend instance** (sessions, arena rows,
//! scratch — nothing about a backend is shared) but all workers share one
//! [`ServeCache`]: results memoized by any worker are hits for all of
//! them, and corpus windows mined by worker A draft worker B's
//! speculative decodes. The queue stays the single admission point, so
//! FIFO fairness and backpressure semantics are unchanged from the
//! single-worker shape — `RXNSPEC_WORKERS=1` is exactly the old server.
//!
//! # Failure model
//!
//! The supervisor polls every [`PoolConfig::poll`] and declares a worker
//! **lost** when any of these hold:
//!
//! - *wedged*: the worker is inside a batch (`busy`) but its heartbeat —
//!   ticked on every pop and every session step — has been stale longer
//!   than [`PoolConfig::wedge_timeout`];
//! - *sick*: it has contained [`PoolConfig::max_worker_panics`] panics
//!   (each one is survivable, but the rate says the incarnation is bad);
//! - *dead*: its thread returned while the queue was still open or while
//!   it still owed replies (a panic that escaped the worker loop, or a
//!   backend that failed to load).
//!
//! A lost worker's unreplied in-flight requests are **reclaimed**: pushed
//! back at the *front* of the queue (they already waited their turn) with
//! their original admission ids, where a sibling pops them next tick.
//! Reclaim happens **exactly once per request id** — a request lost a
//! second time gets `ERR worker_lost` instead of another bounce, so a
//! poisoned query cannot loop through the pool forever. Exactly-one-reply
//! still holds end to end because replies travel through
//! [`ReplySlot`](crate::coordinator::worker::ReplySlot): if the original
//! owner limps to completion after its request was re-served, its late
//! send loses the CAS and is dropped.
//!
//! The lost worker itself is abandoned in place (never joined while the
//! pool runs — joining a wedged thread would wedge the supervisor) and a
//! replacement is spawned into the same slot, bounded by
//! [`PoolConfig::max_restarts`]. Abandoned "ghosts" stay under watch:
//! one that pops fresh work and wedges *again* is reclaimed by the same
//! rule, so no request can hide in a dying worker.
//!
//! Drain generalizes pool-wide: closing the queue stops admissions, every
//! worker exits when the queue is empty, the supervisor waits until no
//! ghost owes a reply, and only then releases parked threads and joins
//! the scope. Stats need no merge step — workers share one [`Metrics`],
//! so the `resil_*` aggregates keep their single-worker meaning; per-slot
//! panic counts are mirrored into `Metrics::worker_panics` each poll.

use std::collections::HashSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::Result;

use crate::cache::ServeCache;
use crate::coordinator::batcher::{Request, RequestQueue};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::worker::{run_worker_supervised, Job, WorkerHealth};
use crate::decoding::Backend;
use crate::faults;
use crate::vocab::Vocab;

/// Default pool width: one worker per core, capped — each worker owns a
/// full backend instance (weights are shared, sessions are not), so past
/// a few workers the queue, not compute, is the bottleneck for the
/// single-step reaction models this server fronts.
pub fn default_workers() -> usize {
    thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 4)
}

/// Pool sizing and supervision knobs. Env-driven in production
/// (`RXNSPEC_WORKERS`, `RXNSPEC_WEDGE_MS`); tests build them directly.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// Worker threads (min 1).
    pub workers: usize,
    /// A busy worker whose heartbeat is older than this is wedged.
    pub wedge_timeout: Duration,
    /// Supervisor poll interval (derived: `wedge_timeout / 8`, clamped).
    pub poll: Duration,
    /// Contained panics before an incarnation is declared sick.
    pub max_worker_panics: u64,
    /// Replacement-spawn budget for the pool's lifetime.
    pub max_restarts: u64,
}

impl PoolConfig {
    /// Config for `n` workers with default supervision timing.
    pub fn with_workers(n: usize) -> PoolConfig {
        PoolConfig::build(n, 2000)
    }

    /// Read `RXNSPEC_WORKERS` (default [`default_workers`]) and
    /// `RXNSPEC_WEDGE_MS` (default 2000).
    pub fn from_env() -> PoolConfig {
        let workers = crate::knobs::WORKERS
            .parsed::<usize>()
            .unwrap_or_else(default_workers);
        let wedge_ms = crate::knobs::WEDGE_MS.parsed_or(2000u64);
        PoolConfig::build(workers, wedge_ms)
    }

    fn build(workers: usize, wedge_ms: u64) -> PoolConfig {
        let wedge_ms = wedge_ms.max(1);
        PoolConfig {
            workers: workers.max(1),
            wedge_timeout: Duration::from_millis(wedge_ms),
            poll: Duration::from_millis((wedge_ms / 8).clamp(2, 250)),
            max_worker_panics: 64,
            max_restarts: 16,
        }
    }
}

/// Re-enqueue a lost worker's unreplied requests, exactly once each.
/// First loss of an id → front of the queue with the id preserved (the
/// dedup unit); second loss → `ERR worker_lost` so reclaim can't loop.
fn reclaim_unreplied(
    queue: &RequestQueue<Job>,
    metrics: &Metrics,
    health: &WorkerHealth,
    reclaimed_ids: &mut HashSet<u64>,
) {
    // The reclaim path is itself a fault site: a panic here must cost
    // the pool nothing but the containment count.
    if catch_unwind(AssertUnwindSafe(|| faults::fire_infallible("queue.reclaim"))).is_err() {
        metrics.panics_contained.fetch_add(1, Ordering::Relaxed);
    }
    for (id, inf) in health.take_unreplied() {
        if reclaimed_ids.insert(id) {
            metrics.requests_reclaimed.fetch_add(1, Ordering::Relaxed);
            queue.requeue_front(Request {
                id,
                mode: inf.mode,
                payload: Job {
                    smiles: inf.smiles,
                    resp: inf.resp,
                },
                enqueued: inf.enqueued,
                deadline: inf.deadline,
            });
        } else {
            let _ = inf.resp.send(Err("worker_lost".to_string()));
            metrics.requests_failed.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Run a supervised pool until the queue is closed and fully drained.
///
/// `factory` builds one backend per worker and is invoked **on the
/// worker's own thread** (backends need not be `Sync`, only the factory
/// is); a factory error retires that incarnation and the supervisor
/// respawns against the restart budget. Blocks the calling thread, which
/// becomes the supervisor.
pub fn run_pool<B, F>(
    factory: F,
    vocab: &Vocab,
    queue: &RequestQueue<Job>,
    metrics: &Arc<Metrics>,
    cache: &ServeCache,
    cfg: &PoolConfig,
) where
    B: Backend,
    F: Fn(usize) -> Result<B> + Sync,
{
    let workers = cfg.workers.max(1);
    metrics.workers.store(workers as u64, Ordering::Relaxed);
    let wedge_ms = cfg.wedge_timeout.as_millis() as u64;
    let released = Arc::new(AtomicBool::new(false));
    let factory = &factory;
    let released_ref = &released;

    thread::scope(|s| {
        let spawn = |slot: usize, generation: u64| {
            let health = Arc::new(WorkerHealth::new(slot, generation, Arc::clone(released_ref)));
            let h2 = Arc::clone(&health);
            let handle = s.spawn(move || {
                let backend = match factory(slot) {
                    Ok(b) => b,
                    Err(e) => {
                        eprintln!("pool: worker {slot}.{generation} backend load failed: {e}");
                        return;
                    }
                };
                // A panic that escapes the worker loop (its internal
                // containment notwithstanding) must not poison the scope
                // join — swallow it here; the supervisor sees a finished
                // thread with unreplied work and reclaims.
                if catch_unwind(AssertUnwindSafe(|| {
                    run_worker_supervised(&backend, vocab, queue, metrics, cache, &h2)
                }))
                .is_err()
                {
                    h2.panics.fetch_add(1, Ordering::Relaxed);
                }
            });
            (health, handle)
        };

        let mut gen_by_slot: Vec<u64> = vec![0; workers];
        let mut slots: Vec<_> = (0..workers).map(|i| spawn(i, 0)).collect();
        let mut ghosts: Vec<_> = Vec::new();
        let mut reclaimed_ids: HashSet<u64> = HashSet::new();
        let mut restarts: u64 = 0;

        loop {
            thread::sleep(cfg.poll);

            // Mirror per-slot (current incarnation) panic counts into
            // STATS; the pool-wide aggregate is already in
            // `panics_contained` via `WorkerHealth::contain_panic`.
            for (h, _) in &slots {
                metrics.set_worker_panics(h.slot, h.panics.load(Ordering::Relaxed));
            }

            // Sweep active workers for losses.
            let mut i = 0;
            while i < slots.len() {
                let finished = slots[i].1.is_finished();
                let h = &slots[i].0;
                let lost = if finished {
                    // Returning is only legitimate once the queue is
                    // closed and drained, and never with replies owed.
                    !(queue.is_closed() && queue.is_empty()) || h.has_unreplied()
                } else {
                    (h.is_busy() && h.stale_ms() > wedge_ms)
                        || h.panics.load(Ordering::Relaxed) >= cfg.max_worker_panics
                };
                if !lost {
                    i += 1;
                    continue;
                }
                eprintln!(
                    "pool: worker {}.{} lost ({}); reclaiming its in-flight requests",
                    h.slot,
                    h.generation,
                    if finished { "thread exited" } else { "wedged or sick" }
                );
                reclaim_unreplied(queue, metrics, h, &mut reclaimed_ids);
                let (h_old, handle_old) = slots.remove(i);
                let slot_idx = h_old.slot;
                // Never joined while the pool runs: joining a wedged
                // thread would wedge the supervisor. The scope join at
                // drain collects it once `released` frees parked loops.
                ghosts.push((h_old, handle_old));
                if restarts < cfg.max_restarts {
                    restarts += 1;
                    metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                    gen_by_slot[slot_idx] += 1;
                    slots.push(spawn(slot_idx, gen_by_slot[slot_idx]));
                }
            }

            // Ghosts stay under watch: an abandoned-but-alive worker that
            // popped fresh work and then wedged (or died) still owes
            // replies nobody else knows about.
            for (h, hd) in &ghosts {
                let ghost_lost = hd.is_finished() || (h.is_busy() && h.stale_ms() > wedge_ms);
                if ghost_lost && h.has_unreplied() {
                    reclaim_unreplied(queue, metrics, h, &mut reclaimed_ids);
                }
            }

            let drained = queue.is_closed() && queue.is_empty();

            // Safety net: reclaimed (or still-queued) work with no live
            // worker left to serve it — spawn one against the budget.
            let any_live = slots.iter().any(|(_, hd)| !hd.is_finished());
            if !any_live && !drained && restarts < cfg.max_restarts {
                restarts += 1;
                metrics.worker_restarts.fetch_add(1, Ordering::Relaxed);
                gen_by_slot[0] += 1;
                slots.push(spawn(0, gen_by_slot[0]));
                continue;
            }

            let all_exited = slots.iter().all(|(_, hd)| hd.is_finished());
            let ghosts_clear = ghosts.iter().all(|(h, _)| !h.has_unreplied());
            if drained && all_exited && ghosts_clear {
                break;
            }
        }

        // Free parked (wedged) threads so the scope join below — which
        // joins every spawned thread, ghosts included — can complete.
        released.store(true, Ordering::Release);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::DecodeMode;
    use crate::coordinator::worker::JobResult;
    use crate::testutil::CopyModel;
    use std::sync::mpsc;

    fn tiny_vocab() -> Vocab {
        Vocab::build(["CCONF", "c1ccccc1"]).unwrap()
    }

    #[test]
    fn config_derives_poll_from_wedge_timeout() {
        let cfg = PoolConfig::with_workers(4);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.wedge_timeout, Duration::from_millis(2000));
        assert_eq!(cfg.poll, Duration::from_millis(250));
        // Tiny wedge windows keep a sane floor; huge ones a ceiling.
        assert_eq!(PoolConfig::build(1, 4).poll, Duration::from_millis(2));
        assert_eq!(PoolConfig::build(1, 10_000).poll, Duration::from_millis(250));
        assert_eq!(PoolConfig::build(0, 0).workers, 1);
    }

    /// The basic pool shape: N workers, one queue, one cache — every
    /// request answered exactly once, correctly.
    #[test]
    fn pool_serves_a_mixed_workload_with_n_workers() {
        let vocab = tiny_vocab();
        let queue = RequestQueue::new(4, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::default();

        let mut rxs: Vec<(String, mpsc::Receiver<JobResult>)> = Vec::new();
        for i in 0..12 {
            let smiles = if i % 2 == 0 { "CCO" } else { "c1ccccc1" };
            let mode = match i % 3 {
                0 => DecodeMode::Greedy,
                1 => DecodeMode::SpecGreedy { dl: 2 },
                _ => DecodeMode::Beam { n: 2 },
            };
            let (tx, rx) = mpsc::channel();
            queue.push(mode, Job::new(smiles.to_string(), tx));
            rxs.push((smiles.to_string(), rx));
        }
        queue.close();

        let cfg = PoolConfig::with_workers(3);
        run_pool(
            |_slot| Ok(CopyModel::new(96, 96, vocab.len())),
            &vocab,
            &queue,
            &metrics,
            &cache,
            &cfg,
        );

        for (smiles, rx) in rxs {
            let reply = rx.recv().unwrap().unwrap();
            assert_eq!(reply.hyps[0].0, smiles);
            assert!(rx.try_recv().is_err(), "exactly one reply");
        }
        assert_eq!(metrics.workers.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.requests_total.load(Ordering::Relaxed), 12);
        assert_eq!(metrics.worker_restarts.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.requests_reclaimed.load(Ordering::Relaxed), 0);
    }

    /// A factory that fails on one slot retires that incarnation; the
    /// respawn budget brings up a replacement and the queue still drains.
    #[test]
    fn factory_failure_is_retried_within_budget() {
        let vocab = tiny_vocab();
        let queue = RequestQueue::new(4, Duration::from_millis(1));
        let metrics = Arc::new(Metrics::default());
        let cache = ServeCache::disabled();

        let (tx, rx) = mpsc::channel();
        queue.push(DecodeMode::Greedy, Job::new("CCO".to_string(), tx));
        queue.close();

        let mut cfg = PoolConfig::with_workers(1);
        cfg.wedge_timeout = Duration::from_millis(100);
        cfg.poll = Duration::from_millis(2);
        let attempts = std::sync::atomic::AtomicU64::new(0);
        run_pool(
            |_slot| {
                if attempts.fetch_add(1, Ordering::Relaxed) == 0 {
                    anyhow::bail!("injected load failure");
                }
                Ok(CopyModel::new(96, 96, vocab.len()))
            },
            &vocab,
            &queue,
            &metrics,
            &cache,
            &cfg,
        );

        assert_eq!(rx.recv().unwrap().unwrap().hyps[0].0, "CCO");
        assert!(metrics.worker_restarts.load(Ordering::Relaxed) >= 1);
    }
}
