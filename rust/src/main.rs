//! `rxnspec` — CLI entry point for the serving stack.
//!
//! Subcommands:
//!   serve    run the TCP serving front end (the request path: artifacts
//!            only, no Python)
//!   predict  one-shot decode of a query SMILES
//!   eval     top-N accuracy of a decoder on a test split (Tables 1 & 4)
//!   parity   cross-implementation agreement, PJRT artifact vs pure-Rust
//!            reference (the paper's Table 1 "original vs ours" check)
//!
//! Hand-rolled flag parsing: the offline crate set has no clap.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use rxnspec::cache::{dump_to_path, load_into, ServeCache};
use rxnspec::chem::read_split;
use rxnspec::coordinator::{
    run_pool, serve, DecodeMode, Metrics, PoolConfig, RequestQueue, ServerState,
};
use rxnspec::decoding::{beam_search, greedy, sbs, spec_greedy, Backend, DecodeOutput, SbsConfig};
use rxnspec::draft::DraftConfig;
use rxnspec::runtime::AnyBackend;
use rxnspec::vocab::Vocab;

fn usage() -> ! {
    eprintln!(
        "rxnspec — speculative decoding for SMILES-to-SMILES reaction transformers

USAGE:
  rxnspec serve   [--task fwd|retro] [--backend pjrt|rust] [--artifacts DIR]
                  [--data DIR] [--port N] [--batch-max N] [--batch-wait-ms N]
                  [--cache on|off] [--cache-dump FILE] [--trace FILE]
  rxnspec predict --smiles SMILES [--decoder D] [--task ...] [--backend ...]
  rxnspec eval    [--decoder D] [--limit N] [--task ...] [--backend ...]
  rxnspec parity  [--limit N] [--task ...]

  decoder D ∈ greedy | spec:<dl> | bs:<n> | sbs:<n>:<dl>   (default greedy)

  serve drains gracefully on SIGTERM/SIGINT or the SHUTDOWN command:
  admissions stop, in-flight requests complete, and the cache pair is
  persisted to --cache-dump (or RXNSPEC_CACHE_DUMP) for a warm boot.
  SLO knobs: RXNSPEC_SLO_MS (default deadline per PREDICT),
  RXNSPEC_QUEUE_CAP (admission bound, default 1024),
  RXNSPEC_MAX_CONNS (connection cap, default 256).
  Pool knobs: RXNSPEC_WORKERS (worker threads, default cores capped
  at 4; each owns a backend instance), RXNSPEC_WEDGE_MS (heartbeat
  staleness before a busy worker is declared wedged, default 2000)."
    );
    std::process::exit(2)
}

#[derive(Clone)]
struct Opts {
    task: String,
    backend: String,
    artifacts: PathBuf,
    data: PathBuf,
    decoder: String,
    smiles: Option<String>,
    limit: usize,
    port: u16,
    batch_max: usize,
    batch_wait_ms: u64,
    cache: bool,
    /// Persist the cache pair here on graceful drain and warm-boot from
    /// it on start (`RXNSPEC_CACHE_DUMP` is the env fallback).
    cache_dump: Option<PathBuf>,
    /// Write a Chrome trace JSON of the run here on shutdown (also
    /// force-enables span collection, overriding `RXNSPEC_TRACE`).
    trace: Option<PathBuf>,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            task: "fwd".into(),
            backend: "pjrt".into(),
            artifacts: "artifacts".into(),
            data: "data".into(),
            decoder: "greedy".into(),
            smiles: None,
            limit: 200,
            port: 7878,
            batch_max: 32,
            batch_wait_ms: 5,
            cache: true,
            cache_dump: rxnspec::knobs::CACHE_DUMP.raw_os().map(PathBuf::from),
            trace: None,
        }
    }
}

fn parse_opts(args: &[String]) -> Opts {
    let mut o = Opts::default();
    let mut i = 0;
    while i < args.len() {
        let need = |i: usize| -> String { args.get(i + 1).cloned().unwrap_or_else(|| usage()) };
        match args[i].as_str() {
            "--task" => o.task = need(i),
            "--backend" => o.backend = need(i),
            "--artifacts" => o.artifacts = PathBuf::from(need(i)),
            "--data" => o.data = PathBuf::from(need(i)),
            "--decoder" => o.decoder = need(i),
            "--smiles" => o.smiles = Some(need(i)),
            "--limit" => o.limit = need(i).parse().unwrap_or_else(|_| usage()),
            "--port" => o.port = need(i).parse().unwrap_or_else(|_| usage()),
            "--batch-max" => o.batch_max = need(i).parse().unwrap_or_else(|_| usage()),
            "--batch-wait-ms" => o.batch_wait_ms = need(i).parse().unwrap_or_else(|_| usage()),
            "--cache" => {
                o.cache = match need(i).as_str() {
                    "on" => true,
                    "off" => false,
                    _ => usage(),
                }
            }
            "--cache-dump" => o.cache_dump = Some(PathBuf::from(need(i))),
            "--trace" => o.trace = Some(PathBuf::from(need(i))),
            _ => usage(),
        }
        i += 2;
    }
    o
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let opts = parse_opts(&args[1..]);
    match cmd.as_str() {
        "serve" => cmd_serve(opts),
        "predict" => cmd_predict(opts),
        "eval" => cmd_eval(opts),
        "parity" => cmd_parity(opts),
        _ => usage(),
    }
}

fn load_vocab(opts: &Opts) -> Result<Vocab> {
    Vocab::load(&opts.data.join("vocab.txt")).context("load vocab (run gen-data)")
}

/// Set by the `SIGTERM`/`SIGINT` handler; a watcher thread folds it into
/// a graceful drain. The handler itself only stores an atomic (the only
/// async-signal-safe thing it could do).
static SIGNALLED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" {
        // libc's classic `signal(2)`; declared here because the offline
        // crate set has no libc binding. The returned previous handler
        // is opaque to us.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    extern "C" fn on_signal(_sig: i32) {
        SIGNALLED.store(true, Ordering::SeqCst);
    }
    // SAFETY: `signal(2)` is callable from any thread before workers
    // start; `on_signal` only performs an async-signal-safe atomic store.
    unsafe {
        signal(15, on_signal); // SIGTERM
        signal(2, on_signal); // SIGINT
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

fn cmd_serve(opts: Opts) -> Result<()> {
    let vocab = load_vocab(&opts)?;
    let backend = AnyBackend::load(&opts.backend, &opts.artifacts, &opts.task)?;
    eprintln!("precompiling artifacts...");
    backend.precompile()?;
    let cache = if opts.cache {
        ServeCache::default()
    } else {
        ServeCache::disabled()
    };
    // Cache entries are only valid per artifact version: bind the loaded
    // model's identity so a redeploy can never serve stale predictions.
    cache.bind_artifact_version(backend.artifact_version());
    // Warm boot: reload the previous drain's dump. A version-mismatched,
    // torn, or missing dump is a clean cold boot, never a crash.
    if let Some(path) = opts.cache_dump.as_ref().filter(|p| p.exists()) {
        match load_into(&cache, path, backend.artifact_version()) {
            Ok(report) => eprintln!(
                "warm boot: restored {} results, {} draft windows from {}",
                report.results,
                report.windows,
                path.display()
            ),
            Err(e) => eprintln!("cold boot ({e})"),
        }
    }
    let queue_cap = rxnspec::knobs::QUEUE_CAP.parsed_or(1024usize);
    let state = Arc::new(ServerState::new(
        RequestQueue::with_capacity(
            opts.batch_max,
            Duration::from_millis(opts.batch_wait_ms),
            queue_cap,
        ),
        Arc::new(Metrics::default()),
        Arc::new(cache),
    ));
    let pool_cfg = PoolConfig::from_env();
    let listener = TcpListener::bind(("0.0.0.0", opts.port))?;
    eprintln!(
        "rxnspec serving task={} backend={} on port {} (workers={}, batch_max={}, wait={}ms, \
         cache={}, queue_cap={queue_cap}, max_conns={}, slo={:?})",
        opts.task,
        opts.backend,
        opts.port,
        pool_cfg.workers,
        opts.batch_max,
        opts.batch_wait_ms,
        if opts.cache { "on" } else { "off" },
        state.max_conns,
        state.default_slo,
    );
    if opts.trace.is_some() {
        rxnspec::trace::set_enabled(true);
    }
    // Chaos opt-in: RXNSPEC_FAULTS arms the seeded fault-injection plan
    // for this serve process (inert otherwise).
    match rxnspec::faults::plan_from_env() {
        Some(Ok(plan)) => {
            eprintln!(
                "fault injection armed: seed={} rules={}",
                plan.seed,
                plan.rules.len()
            );
            rxnspec::faults::install(plan);
        }
        Some(Err(e)) => bail!("bad RXNSPEC_FAULTS: {e}"),
        None => {}
    }
    install_signal_handlers();
    let watch_state = Arc::clone(&state);
    std::thread::spawn(move || loop {
        if SIGNALLED.load(Ordering::SeqCst) {
            eprintln!("signal received; draining...");
            watch_state.begin_shutdown();
            return;
        }
        if watch_state.shutdown.load(Ordering::SeqCst) {
            return; // SHUTDOWN command won the race
        }
        std::thread::sleep(Duration::from_millis(100));
    });
    let accept_state = Arc::clone(&state);
    let accept = std::thread::spawn(move || serve(listener, accept_state));
    // Each pool worker loads its own backend instance (sessions, arena
    // rows, and scratch are per-worker; artifacts are shared on disk and
    // already precompiled above). The initial probe backend bound the
    // artifact version and fails fast on broken artifacts — the pool
    // doesn't need it beyond that.
    drop(backend);
    // This thread becomes the pool supervisor; run_pool returns once the
    // queue is closed AND every in-flight request has been replied to.
    run_pool(
        |_slot| {
            let b = AnyBackend::load(&opts.backend, &opts.artifacts, &opts.task)?;
            b.precompile()?;
            Ok(b)
        },
        &vocab,
        &state.queue,
        &state.metrics,
        &state.cache,
        &pool_cfg,
    );
    let _ = accept.join();
    // Post-drain: persist the cache pair so the next boot starts warm.
    if let Some(path) = &opts.cache_dump {
        match dump_to_path(&state.cache, path) {
            Ok(n) => eprintln!("cache dump: {n} records -> {}", path.display()),
            Err(e) => eprintln!("cache dump failed: {e}"),
        }
    }
    if let Some(t) = state.drain_started() {
        let ms = t.elapsed().as_millis() as u64;
        state.metrics.drain_ms.store(ms, Ordering::Relaxed);
        eprintln!("drained in {ms} ms");
    }
    if let Some(path) = &opts.trace {
        std::fs::write(path, rxnspec::trace::export_chrome_json())
            .with_context(|| format!("write trace to {}", path.display()))?;
        eprintln!("trace written to {}", path.display());
    }
    Ok(())
}

fn decode_one<B: Backend>(
    backend: &B,
    src: &[i64],
    mode: DecodeMode,
) -> Result<DecodeOutput> {
    match mode {
        DecodeMode::Greedy => greedy(backend, src),
        DecodeMode::SpecGreedy { dl } => spec_greedy(backend, src, &DraftConfig::new(dl)),
        DecodeMode::Beam { n } => beam_search(backend, src, n),
        DecodeMode::Sbs { n, dl } => sbs(backend, src, &SbsConfig::new(n, dl)),
    }
}

fn cmd_predict(opts: Opts) -> Result<()> {
    let Some(smiles) = opts.smiles.clone() else {
        bail!("predict needs --smiles")
    };
    let vocab = load_vocab(&opts)?;
    let mode = DecodeMode::parse(&opts.decoder).context("bad --decoder")?;
    let backend = AnyBackend::load(&opts.backend, &opts.artifacts, &opts.task)?;
    let src = vocab.encode_wrapped(&smiles)?;
    let t0 = Instant::now();
    let out = decode_one(&backend, &src, mode)?;
    let ms = t0.elapsed().as_secs_f64() * 1000.0;
    println!(
        "# {} in {ms:.1} ms, {} decoder calls, acceptance {:.1}%",
        mode,
        out.stats.decoder_calls,
        out.stats.acceptance.rate() * 100.0
    );
    for (i, h) in out.hyps.iter().enumerate() {
        println!("{}\t{:.4}\t{}", i + 1, h.score, vocab.decode(&h.tokens));
    }
    Ok(())
}

/// Top-N accuracy of a decoder over a test split — the measurements behind
/// Tables 1 and 4.
fn cmd_eval(opts: Opts) -> Result<()> {
    let vocab = load_vocab(&opts)?;
    let mode = DecodeMode::parse(&opts.decoder).context("bad --decoder")?;
    let backend = AnyBackend::load(&opts.backend, &opts.artifacts, &opts.task)?;
    let split = read_split(&opts.data.join(format!("{}_test.tsv", opts.task)))?;
    let n_eval = split.len().min(opts.limit);
    let top_n = match mode {
        DecodeMode::Beam { n } | DecodeMode::Sbs { n, .. } => n,
        _ => 1,
    };
    let mut hits = vec![0usize; top_n];
    let mut calls = 0usize;
    let t0 = Instant::now();
    for (i, ex) in split[..n_eval].iter().enumerate() {
        let src = vocab.encode_wrapped(&ex.src)?;
        let out = decode_one(&backend, &src, mode)?;
        calls += out.stats.decoder_calls;
        for (rank, h) in out.hyps.iter().enumerate() {
            if vocab.decode(&h.tokens) == ex.tgt {
                for slot in hits[rank..].iter_mut() {
                    *slot += 1;
                }
                break;
            }
        }
        if (i + 1) % 50 == 0 {
            eprintln!(
                "  {}/{} top-1 {:.1}% ({:.1}s)",
                i + 1,
                n_eval,
                hits[0] as f64 * 100.0 / (i + 1) as f64,
                t0.elapsed().as_secs_f64()
            );
        }
    }
    println!(
        "task={} decoder={} backend={} n={} wall={:.1}s decoder_calls={}",
        opts.task,
        mode,
        opts.backend,
        n_eval,
        t0.elapsed().as_secs_f64(),
        calls
    );
    for (rank, h) in hits.iter().enumerate() {
        if rank == 0 || rank == 2 || rank == 4 || rank + 1 == top_n || rank == 9 {
            println!("top-{}: {:.2}%", rank + 1, *h as f64 * 100.0 / n_eval as f64);
        }
    }
    Ok(())
}

/// Table 1 analogue: agreement between the two independent implementations
/// (PJRT artifact vs pure-Rust reference) on top-5 beam outputs.
fn cmd_parity(opts: Opts) -> Result<()> {
    let vocab = load_vocab(&opts)?;
    let pjrt = AnyBackend::load("pjrt", &opts.artifacts, &opts.task)?;
    let rust = AnyBackend::load("rust", &opts.artifacts, &opts.task)?;
    let split = read_split(&opts.data.join(format!("{}_test.tsv", opts.task)))?;
    let n_eval = split.len().min(opts.limit);
    let mut top1_agree = 0usize;
    let mut top5_overlap = 0usize;
    let mut logp_max_diff = 0f64;
    for ex in &split[..n_eval] {
        let src = vocab.encode_wrapped(&ex.src)?;
        let a = beam_search(&pjrt, &src, 5)?;
        let b = beam_search(&rust, &src, 5)?;
        if a.hyps[0].tokens == b.hyps[0].tokens {
            top1_agree += 1;
            logp_max_diff = logp_max_diff.max((a.hyps[0].score - b.hyps[0].score).abs());
        }
        let set_b: std::collections::HashSet<&Vec<i64>> =
            b.hyps.iter().map(|h| &h.tokens).collect();
        top5_overlap += a.hyps.iter().filter(|h| set_b.contains(&h.tokens)).count();
    }
    println!(
        "parity task={} n={}: top-1 agreement {:.2}%, top-5 overlap {:.2}%, max |Δlogp| {:.2e}",
        opts.task,
        n_eval,
        top1_agree as f64 * 100.0 / n_eval as f64,
        top5_overlap as f64 * 100.0 / (5 * n_eval) as f64,
        logp_max_diff
    );
    Ok(())
}
