//! Typed registry of every `RXNSPEC_*` environment knob.
//!
//! Each knob is declared exactly once — name, type, default, one doc
//! line — and every env read in the tree goes through the accessors on
//! [`Knob`]. That single declaration is what the static-analysis pass
//! (`rxnspec-lint`, [`crate::lint`]) cross-checks: an `RXNSPEC_*`
//! literal anywhere in the sources, CI workflow, or README that is not
//! in [`REGISTRY`] is a lint failure, and so is a raw
//! `std::env::var("RXNSPEC_…")` read outside this module. The README's
//! knob table is generated from the same declarations
//! ([`knob_table_markdown`]) and checked for drift.
//!
//! Parsing stays at the call sites on purpose: the accessors hand back
//! the raw value (or a trimmed `FromStr` parse), and each site keeps
//! its own fallback/clamp semantics — `RXNSPEC_THREADS=auto`,
//! `RXNSPEC_KV_BUDGET=512m`, "0 means no deadline", and so on — so
//! migrating onto the registry can never change behaviour.

use std::ffi::OsString;
use std::str::FromStr;

/// Broad value class of a knob — documentation and table rendering,
/// not an enforcement mechanism (call sites own their parse).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KnobKind {
    /// Presence / on-off style (`on`, `off`, `1`, or merely being set).
    Flag,
    /// Plain non-negative integer (counts, sizes in items).
    Count,
    /// Integer milliseconds.
    Millis,
    /// Byte size, optionally with a `k`/`m`/`g` suffix (powers of 1024).
    Bytes,
    /// Filesystem path.
    Path,
    /// Short symbolic name (backend kind, SIMD level).
    Name,
    /// Structured mini-grammar (see the knob's doc line).
    Spec,
}

impl KnobKind {
    /// Stable lowercase label used in the generated knob table.
    pub fn label(self) -> &'static str {
        match self {
            KnobKind::Flag => "flag",
            KnobKind::Count => "count",
            KnobKind::Millis => "millis",
            KnobKind::Bytes => "bytes",
            KnobKind::Path => "path",
            KnobKind::Name => "name",
            KnobKind::Spec => "spec",
        }
    }
}

/// One declared environment knob.
#[derive(Debug)]
pub struct Knob {
    /// Full variable name (`RXNSPEC_…`).
    pub name: &'static str,
    pub kind: KnobKind,
    /// Human-readable effective default (what happens when unset).
    pub default: &'static str,
    /// One-line effect description (rendered into the README table).
    pub doc: &'static str,
}

impl Knob {
    /// Raw value, if set and valid UTF-8.
    pub fn raw(&self) -> Option<String> {
        std::env::var(self.name).ok()
    }

    /// Raw OS value, if set (no UTF-8 requirement).
    pub fn raw_os(&self) -> Option<OsString> {
        std::env::var_os(self.name)
    }

    /// Is the variable set at all (to anything, including empty)?
    pub fn is_set(&self) -> bool {
        std::env::var_os(self.name).is_some()
    }

    /// Trimmed `FromStr` parse of the value; `None` when unset or
    /// unparsable (call sites pick their own fallback).
    pub fn parsed<T: FromStr>(&self) -> Option<T> {
        self.raw().and_then(|v| v.trim().parse().ok())
    }

    /// [`Knob::parsed`] with an inline default.
    pub fn parsed_or<T: FromStr>(&self, default: T) -> T {
        self.parsed().unwrap_or(default)
    }
}

macro_rules! declare_knobs {
    ($($const_name:ident = {
        name: $name:literal,
        kind: $kind:ident,
        default: $default:literal,
        doc: $doc:literal
    }),+ $(,)?) => {
        $(pub static $const_name: Knob = Knob {
            name: $name,
            kind: KnobKind::$kind,
            default: $default,
            doc: $doc,
        };)+

        /// Every declared knob, in table order.
        pub static REGISTRY: &[&Knob] = &[$(&$const_name),+];
    };
}

declare_knobs! {
    THREADS = {
        name: "RXNSPEC_THREADS",
        kind: Count,
        default: "1",
        doc: "Kernel-pool thread budget: unset/`1` = off, `auto` = available parallelism, N = explicit count (unparsable values warn once and disable threading)"
    },
    SIMD = {
        name: "RXNSPEC_SIMD",
        kind: Name,
        default: "auto",
        doc: "`off`/`scalar`/`0` forces the portable 8-lane fallback; anything else runs CPU feature detection (AVX2+FMA)"
    },
    ARENA = {
        name: "RXNSPEC_ARENA",
        kind: Flag,
        default: "on",
        doc: "`off`/`0`/`false`/`dense` disables the paged KV arena in favour of dense per-row K/V residency (the bit-parity oracle)"
    },
    KV_PAGE = {
        name: "RXNSPEC_KV_PAGE",
        kind: Count,
        default: "16",
        doc: "Arena page size in positions (min 1)"
    },
    KV_BUDGET = {
        name: "RXNSPEC_KV_BUDGET",
        kind: Bytes,
        default: "unbounded",
        doc: "Soft arena byte budget (plain bytes or `k`/`m`/`g` suffix); excess pages are LRU-evicted and healed by exact recompute"
    },
    LP_RETAIN = {
        name: "RXNSPEC_LP_RETAIN",
        kind: Count,
        default: "64",
        doc: "Per-row retained log-prob positions in cached sessions (min 1; deeper rewinds heal via one exact recompute)"
    },
    WORKERS = {
        name: "RXNSPEC_WORKERS",
        kind: Count,
        default: "min(cores, 4)",
        doc: "Serving-pool worker threads sharing the request queue (each owns a backend instance)"
    },
    WEDGE_MS = {
        name: "RXNSPEC_WEDGE_MS",
        kind: Millis,
        default: "2000",
        doc: "Heartbeat staleness after which a busy worker is declared wedged and its in-flight requests reclaimed"
    },
    SLO_MS = {
        name: "RXNSPEC_SLO_MS",
        kind: Millis,
        default: "0 (none)",
        doc: "Default per-PREDICT deadline; expired requests are shed at pop time (`0`/unset = no deadline)"
    },
    MAX_CONNS = {
        name: "RXNSPEC_MAX_CONNS",
        kind: Count,
        default: "256",
        doc: "Concurrent TCP connection cap; excess connections are answered `BUSY` (min 1)"
    },
    QUEUE_CAP = {
        name: "RXNSPEC_QUEUE_CAP",
        kind: Count,
        default: "1024",
        doc: "Admission queue bound; a full queue answers `BUSY` instead of queueing unboundedly"
    },
    TRACE = {
        name: "RXNSPEC_TRACE",
        kind: Flag,
        default: "off",
        doc: "`1`/`on`/`true`/`yes` enables span collection (near-zero cost when off; `serve --trace` overrides)"
    },
    TRACE_BUF = {
        name: "RXNSPEC_TRACE_BUF",
        kind: Count,
        default: "65536",
        doc: "Per-thread trace ring capacity in events (min 16; oldest events are overwritten and counted as dropped)"
    },
    TRACE_EXEMPLARS = {
        name: "RXNSPEC_TRACE_EXEMPLARS",
        kind: Count,
        default: "4",
        doc: "Worst-N slowest requests whose full span trees are retained past ring wrap-around"
    },
    FAULTS = {
        name: "RXNSPEC_FAULTS",
        kind: Spec,
        default: "unset",
        doc: "Seeded fault-injection plan, `<seed>:<site>=<kind>@<prob>,…` (`#<nth>` triggers on exactly one hit; see `faults::parse_spec`); inert unless armed"
    },
    NO_DECFAST = {
        name: "RXNSPEC_NO_DECFAST",
        kind: Flag,
        default: "unset",
        doc: "When set (to anything), disables the PJRT B=1 decfast fast path"
    },
    NO_DECCACHE = {
        name: "RXNSPEC_NO_DECCACHE",
        kind: Flag,
        default: "unset",
        doc: "When set (to anything), forces the stateless PJRT session even when deccache artifacts are present"
    },
    CACHE_DUMP = {
        name: "RXNSPEC_CACHE_DUMP",
        kind: Path,
        default: "unset",
        doc: "Cache persistence file: dumped on graceful drain, warm-booted from on start (`--cache-dump` overrides)"
    },
    DATA = {
        name: "RXNSPEC_DATA",
        kind: Path,
        default: "data",
        doc: "Dataset directory for benches and examples (vocab + test splits)"
    },
    ARTIFACTS = {
        name: "RXNSPEC_ARTIFACTS",
        kind: Path,
        default: "artifacts",
        doc: "Compiled-artifact directory for benches and the real-artifact parity tests"
    },
    BACKEND = {
        name: "RXNSPEC_BACKEND",
        kind: Name,
        default: "pjrt",
        doc: "Backend kind for benches and examples (`pjrt` or `rust`)"
    },
    LIMIT = {
        name: "RXNSPEC_LIMIT",
        kind: Count,
        default: "per-bench",
        doc: "Bench subset size override (the 1-core testbed default; the paper ran full splits)"
    },
    BENCH_JSON = {
        name: "RXNSPEC_BENCH_JSON",
        kind: Path,
        default: "<repo>/BENCH_kernels.json",
        doc: "Perf-trajectory file `--json` bench runs merge into (default anchored at the workspace root)"
    },
}

/// Look a knob up by its full `RXNSPEC_*` name.
pub fn lookup(name: &str) -> Option<&'static Knob> {
    REGISTRY.iter().copied().find(|k| k.name == name)
}

/// Render the registry as the README's markdown knob table. The
/// `readme-knobs` lint rule regenerates this and diffs it against the
/// committed README, so the two cannot drift.
pub fn knob_table_markdown() -> String {
    let mut out = String::from("| Knob | Type | Default | Effect |\n|---|---|---|---|\n");
    for k in REGISTRY {
        out.push_str(&format!(
            "| `{}` | {} | {} | {} |\n",
            k.name,
            k.kind.label(),
            k.default,
            k.doc
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_prefixed_and_resolvable() {
        let mut seen = std::collections::HashSet::new();
        for k in REGISTRY {
            assert!(k.name.starts_with("RXNSPEC_"), "{} lacks the prefix", k.name);
            assert!(seen.insert(k.name), "duplicate knob {}", k.name);
            assert!(std::ptr::eq(lookup(k.name).expect("lookup"), *k));
            assert!(!k.doc.is_empty() && !k.default.is_empty());
        }
        // lint:allow(knob-literal) — deliberately unregistered name.
        assert!(lookup("RXNSPEC_NOT_A_REAL_KNOB").is_none());
    }

    #[test]
    fn accessors_reflect_the_environment() {
        // Read-only against the live environment: whatever the CI leg
        // exports must round-trip through the accessors.
        for k in REGISTRY {
            assert_eq!(k.is_set(), k.raw_os().is_some());
            if let Some(v) = k.raw() {
                assert_eq!(std::env::var(k.name).ok().as_deref(), Some(v.as_str()));
            }
        }
    }

    #[test]
    fn knob_table_lists_every_knob_once() {
        let table = knob_table_markdown();
        for k in REGISTRY {
            let needle = format!("`{}`", k.name);
            assert_eq!(
                table.matches(&needle).count(),
                1,
                "{} must appear exactly once in the table",
                k.name
            );
        }
    }
}
