//! The compute-kernel layer: every dense-algebra operation of the
//! pure-Rust reference backend, packaged as reusable, deterministic,
//! optionally-threaded kernels.
//!
//! Before this layer existed, `model::reference` ran naive scalar triple
//! loops per call. The kernels here keep the *same arithmetic per output
//! element* while restructuring the work for throughput:
//!
//! * [`PackedLinear`] — weights are re-laid-out **once at load time**
//!   into transposed, tile-aligned column panels feeding a blocked,
//!   register-tiled GEMM with the bias fused into the accumulators
//!   (`gemm` module). Several projections over the same input can be
//!   packed into one fused matrix (`pack_fused`, used for QKV).
//! * [`KvPanels`] / [`attn_panels`] — attention K/V held as contiguous
//!   per-head panels so each head's score/context loops stream over
//!   dense memory (`attention` module).
//! * [`simd`] — the wide-lane layer under both of the above: a fixed
//!   [`simd::LANES`]-wide vector model with a portable `[f32; 8]`
//!   fallback ([`simd::F32Lanes`]) and an AVX2 intrinsic backend
//!   selected once at runtime (`RXNSPEC_SIMD` forces the fallback).
//!   Kernels vectorize across **output lanes only**, never across a
//!   reduction dimension, so both backends are bit-identical.
//! * [`threads`] — an opt-in deterministic partitioner (rows for GEMM,
//!   heads for attention) over a **persistent pool of parked workers**
//!   (std-only; no per-call thread spawns), sized from
//!   `std::thread::available_parallelism` via `RXNSPEC_THREADS`, with
//!   work-size gates adapted to the measured dispatch cost.
//!
//! # Determinism contract
//!
//! Every kernel computes each output element with a **fixed reduction
//! order** that does not depend on tiling, row blocking, thread count,
//! SIMD dispatch level, or which other rows share the batch:
//!
//! * GEMM: `bias[o]` then `k = 0..din` ascending, for every `(row, o)`.
//! * Attention: per `(head, query)`, each key score reduces its query
//!   dimensions `d = 0..d_head` ascending; the scale multiply, running
//!   max, exp-sum and value accumulation all run `j = 0..len` ascending.
//!
//! Consequently a batched call is bit-identical to the equivalent
//! sequence of single-row calls, a threaded call is bit-identical to
//! the single-threaded one, and the AVX2 path is bit-identical to the
//! portable fallback — the properties the session-parity and
//! kernel-parity test suites hold as hard invariants.

pub mod attention;
pub mod gemm;
pub mod simd;
pub mod threads;

pub use attention::{
    attn_panels, attn_panels_paged, attn_panels_paged_threaded, attn_panels_threaded, KvPanels,
    PagedKv,
};
pub use gemm::PackedLinear;
pub use simd::{simd_level, SimdLevel};
pub use threads::default_threads;
