//! Opt-in scoped-thread partitioning for the kernel layer.
//!
//! Threading is **off by default** (`RXNSPEC_THREADS` unset or `1`);
//! `RXNSPEC_THREADS=auto` sizes the partitioner from
//! `std::thread::available_parallelism`, any other value is an explicit
//! thread count. Kernels partition work into contiguous chunks with
//! disjoint outputs, so the reduction order of every output element is
//! unchanged and threaded results are bit-identical to single-threaded
//! ones (see the module docs of [`crate::kernels`]).
//!
//! There is no persistent pool: callers gate on a minimum work size so a
//! scoped spawn only happens when it pays for itself.

use std::sync::OnceLock;

/// Resolve the process-wide default kernel thread count once.
///
/// * unset / unparsable / `0` / `1` → `1` (threading off),
/// * `auto` → `std::thread::available_parallelism()`,
/// * `N` → `N`.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| match std::env::var("RXNSPEC_THREADS") {
        Ok(v) if v.trim() == "auto" => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        Ok(v) => v.trim().parse::<usize>().unwrap_or(1).max(1),
        Err(_) => 1,
    })
}

/// Run `f` over every item, the slice split into at most `threads`
/// contiguous chunks, each chunk on its own scoped thread. Items are
/// mutated in place; chunks are disjoint, so this is deterministic for
/// any per-item-independent `f`.
pub fn for_each_partitioned<T: Send, F: Fn(&mut T) + Sync>(items: &mut [T], threads: usize, f: F) {
    let n = items.len();
    if threads <= 1 || n <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let chunk = n.div_ceil(threads.min(n));
    std::thread::scope(|s| {
        for part in items.chunks_mut(chunk) {
            let fref = &f;
            s.spawn(move || {
                for it in part.iter_mut() {
                    fref(it);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitioned_map_touches_every_item_once() {
        let mut xs: Vec<u64> = (0..37).collect();
        for_each_partitioned(&mut xs, 4, |x| *x += 1000);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(x, 1000 + i as u64);
        }
        // Degenerate partitions.
        let mut ys: Vec<u64> = vec![7];
        for_each_partitioned(&mut ys, 8, |y| *y *= 2);
        assert_eq!(ys, vec![14]);
        let mut empty: Vec<u64> = Vec::new();
        for_each_partitioned(&mut empty, 3, |_| unreachable!());
    }

    #[test]
    fn default_threads_is_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
