//! Opt-in deterministic threading for the kernel layer, backed by a
//! **persistent pool of parked workers**.
//!
//! Threading is **off by default** (`RXNSPEC_THREADS` unset or `1`);
//! `RXNSPEC_THREADS=auto` sizes the partitioner from
//! `std::thread::available_parallelism`, any other positive integer is
//! an explicit thread count (an unparsable value logs a one-time stderr
//! warning and disables threading). Kernels partition work into
//! contiguous chunks with disjoint outputs, so the reduction order of
//! every output element is unchanged and threaded results are
//! bit-identical to single-threaded ones (see the module docs of
//! [`crate::kernels`]).
//!
//! Earlier revisions paid a fresh `std::thread::scope` spawn per
//! threaded call, which forced conservative work-size gates. The pool
//! (std-only: a mutex-guarded injector queue plus condvars, no new
//! dependencies) spawns workers **once**, on demand by dispatch width
//! up to `available_parallelism - 1`; workers park on a condvar
//! between jobs. [`for_each_partitioned`] keeps the exact same API and
//! determinism contract: the caller runs the first chunk inline,
//! self-drains its own still-queued chunks while waiting (never a
//! concurrent dispatch's — no hostage latency), and returns only after
//! every chunk completed (a panicking chunk resurfaces as a panic in
//! the caller). Jobs must not themselves dispatch to the pool (kernel
//! chunks are serial by construction).
//!
//! The dispatch round-trip is measured once at pool start
//! ([`pool_dispatch_ns`]) and feeds the **adaptive** work-size gates
//! ([`par_min_macs`], [`par_min_attn_work`]) that decide when a kernel
//! call is large enough to fork. [`for_each_partitioned_scoped`] keeps
//! the old scoped-spawn path alive for the pool-vs-spawn bench and the
//! parity property tests.

use std::any::Any;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::coordinator::lock_ok;
use crate::trace::Phase;
use crate::trace_span;

/// Resolve the process-wide default kernel thread count once.
///
/// * unset / `0` / `1` → `1` (threading off),
/// * `auto` → `std::thread::available_parallelism()`,
/// * positive integer `N` → `N`,
/// * anything else → `1`, with a one-time warning on stderr.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| match crate::knobs::THREADS.raw() {
        Some(v) if v.trim() == "auto" => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        Some(v) => match v.trim().parse::<usize>() {
            Ok(n) => n.max(1),
            Err(_) => {
                eprintln!(
                    "rxnspec: ignoring unparsable RXNSPEC_THREADS={v:?} \
                     (accepted: unset or 1 = off, `auto`, or a positive integer); \
                     kernel threading disabled"
                );
                1
            }
        },
        None => 1,
    })
}

// ---------------------------------------------------------------------------
// The persistent pool
// ---------------------------------------------------------------------------

/// One queued chunk: a monomorphized trampoline plus a pointer to its
/// stack-held [`ChunkCtx`]. The dispatcher keeps the context alive until
/// its latch opens, which happens only from inside `run`. `latch`
/// duplicates the context's latch pointer so the dispatcher can
/// self-drain **its own** queued chunks without popping (and being
/// blocked behind) a concurrent dispatch's work.
struct RawJob {
    // SAFETY: only ever called with `ctx` pointing at the live, unmoved
    // `ChunkCtx<T, F>` this trampoline was monomorphized for.
    run: unsafe fn(*const ()),
    ctx: *const (),
    latch: *const Latch,
}

// SAFETY: the pointers reference a `ChunkCtx` (plus the slice and
// closure it points at) that the dispatching thread keeps alive and
// unmoved until the job signals its latch; chunk slices are disjoint.
unsafe impl Send for RawJob {}

struct Shared {
    queue: Mutex<VecDeque<RawJob>>,
    work_ready: Condvar,
    /// Workers spawned so far — grown on demand by dispatch width (see
    /// [`Pool::ensure_workers`]), never torn down.
    spawned: Mutex<usize>,
}

type PanicPayload = Box<dyn Any + Send>;

/// Completion latch for one dispatch: remaining chunk count plus the
/// first panic payload caught in any chunk (re-raised by the caller,
/// preserving the diagnostics the old scoped-spawn path surfaced via
/// `std::thread::scope`'s join).
struct Latch {
    state: Mutex<(usize, Option<PanicPayload>)>,
    done: Condvar,
}

impl Latch {
    fn new(jobs: usize) -> Latch {
        Latch {
            state: Mutex::new((jobs, None)),
            done: Condvar::new(),
        }
    }

    fn signal(&self, panic: Option<PanicPayload>) {
        let mut st = lock_ok(&self.state);
        st.0 -= 1;
        if panic.is_some() && st.1.is_none() {
            st.1 = panic;
        }
        if st.0 == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every job signalled; returns the first panic payload.
    fn wait(&self) -> Option<PanicPayload> {
        let mut st = lock_ok(&self.state);
        while st.0 > 0 {
            st = self.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.1.take()
    }
}

/// Per-chunk context, stack-held by the dispatcher for the duration of
/// the dispatch.
struct ChunkCtx<T, F> {
    items: *mut T,
    len: usize,
    f: *const F,
    latch: *const Latch,
}

// SAFETY: to call, `p` must point at a live `ChunkCtx<T, F>` whose
// latch, items pointer, and closure all outlive the call; chunk slices
// are disjoint, so the `from_raw_parts_mut` below aliases nothing.
unsafe fn run_chunk<T: Send, F: Fn(&mut T) + Sync>(p: *const ()) {
    let ctx = &*(p.cast::<ChunkCtx<T, F>>());
    let latch = &*ctx.latch;
    let items = std::slice::from_raw_parts_mut(ctx.items, ctx.len);
    let f = &*ctx.f;
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        for it in items.iter_mut() {
            f(it);
        }
    }));
    latch.signal(result.err());
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = lock_ok(&sh.queue);
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                // Park until a dispatcher enqueues work.
                q = sh.work_ready.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };
        // SAFETY: see `RawJob`; panics are contained inside `run_chunk`.
        unsafe { (job.run)(job.ctx) };
    }
}

struct Pool {
    shared: Arc<Shared>,
    /// Worker ceiling: the dispatcher always works a chunk itself, so
    /// one fewer than the hardware threads (min 1 so explicit thread
    /// requests work even on single-core boxes).
    max_workers: usize,
    dispatch_ns: u64,
}

impl Pool {
    fn start() -> Pool {
        let hw = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_ready: Condvar::new(),
            spawned: Mutex::new(0),
        });
        let mut pool = Pool {
            shared,
            max_workers: hw.saturating_sub(1).max(1),
            dispatch_ns: 1,
        };
        // Measure the fork/join round trip (one trivial job per lane)
        // — the overhead the adaptive gates must amortize. A small
        // dispatch, so a big host serving a small `RXNSPEC_THREADS`
        // budget doesn't spawn a full worker complement up front; and
        // untimed warm-ups first, so the one-time worker spawns never
        // land inside the timed window (the gates must reflect
        // steady-state dispatch, not spawn cost).
        let mut sink = vec![0u64; hw.min(4)];
        for _ in 0..2 {
            pool.run_parts(&mut sink, 1, &|x: &mut u64| *x = x.wrapping_add(1));
        }
        let reps: u32 = 16;
        let t0 = Instant::now();
        for _ in 0..reps {
            pool.run_parts(&mut sink, 1, &|x: &mut u64| *x = x.wrapping_add(1));
        }
        pool.dispatch_ns = ((t0.elapsed().as_nanos() / reps as u128) as u64).max(1);
        pool
    }

    /// Grow the worker set to serve `jobs` queued chunks, up to the
    /// `max_workers` ceiling. Demand-driven: a process whose dispatches
    /// never exceed N chunks never holds more than N parked threads.
    fn ensure_workers(&self, jobs: usize) {
        let want = jobs.min(self.max_workers);
        let mut spawned = lock_ok(&self.shared.spawned);
        while *spawned < want {
            let sh = Arc::clone(&self.shared);
            std::thread::Builder::new()
                .name(format!("rxnspec-kernel-{}", *spawned))
                .spawn(move || worker_loop(sh))
                .expect("failed to spawn kernel pool worker");
            *spawned += 1;
        }
    }

    /// Split `items` into `chunk`-sized contiguous chunks; the caller
    /// runs the first inline (then self-drains its own still-queued
    /// chunks), pool workers take the rest. Returns after every chunk
    /// completed.
    fn run_parts<T: Send, F: Fn(&mut T) + Sync>(&self, items: &mut [T], chunk: usize, f: &F) {
        let mut it = items.chunks_mut(chunk);
        let Some(first) = it.next() else {
            return;
        };
        let rest: Vec<&mut [T]> = it.collect();
        if rest.is_empty() {
            for x in first.iter_mut() {
                f(x);
            }
            return;
        }
        self.ensure_workers(rest.len());
        let latch = Latch::new(rest.len());
        let me = &latch as *const Latch;
        let ctxs: Vec<ChunkCtx<T, F>> = rest
            .into_iter()
            .map(|c| ChunkCtx {
                items: c.as_mut_ptr(),
                len: c.len(),
                f: f as *const F,
                latch: me,
            })
            .collect();
        {
            let mut q = lock_ok(&self.shared.queue);
            for ctx in &ctxs {
                q.push_back(RawJob {
                    run: run_chunk::<T, F>,
                    ctx: (ctx as *const ChunkCtx<T, F>).cast(),
                    latch: me,
                });
            }
        }
        self.shared.work_ready.notify_all();
        // Run our own chunk, panic-deferred: the queued contexts must
        // stay alive until the latch opens, so we join before unwinding.
        let mine = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for x in first.iter_mut() {
                f(x);
            }
        }));
        // Self-drain: pick up any of *our* chunks still queued instead
        // of blocking while workers are busy. Only our own — popping a
        // concurrent dispatch's (possibly large) chunk would hold this
        // call hostage past its own completion.
        loop {
            let job = {
                let mut q = lock_ok(&self.shared.queue);
                q.iter()
                    .position(|j| std::ptr::eq(j.latch, me))
                    .and_then(|i| q.remove(i))
            };
            let Some(j) = job else { break };
            // SAFETY: see `RawJob`.
            unsafe { (j.run)(j.ctx) };
        }
        let job_panic = latch.wait();
        if let Err(p) = mine {
            std::panic::resume_unwind(p);
        }
        if let Some(p) = job_panic {
            // Re-raise the chunk's own payload so diagnostics (assert
            // messages, bounds-check locations) survive the pool hop.
            std::panic::resume_unwind(p);
        }
    }
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(Pool::start)
}

/// Measured fork/join round-trip latency of one pool dispatch, in
/// nanoseconds (sampled once at pool start). Starts the pool on first
/// call.
pub fn pool_dispatch_ns() -> u64 {
    pool().dispatch_ns
}

/// Number of pool workers spawned so far (grown on demand by dispatch
/// width; the caller thread adds one more working lane on top). Starts
/// the pool on first call.
pub fn pool_workers() -> usize {
    let p = pool();
    *lock_ok(&p.shared.spawned)
}

/// Minimum GEMM multiply-accumulate count (`n·din·dout`) before row
/// partitioning pays for a pool dispatch. Adaptive: derived from the
/// measured [`pool_dispatch_ns`] so the fork cost stays a small
/// fraction of the forked work (assuming a conservative ~1 MAC/ns
/// serial throughput), clamped so a pathological measurement can never
/// thread tiny calls or disable threading outright.
pub fn par_min_macs() -> usize {
    static GATE: OnceLock<usize> = OnceLock::new();
    *GATE.get_or_init(|| ((pool_dispatch_ns() as usize) * 8).clamp(1 << 13, 1 << 18))
}

/// Attention analogue of [`par_min_macs`] over the
/// `nq·nk·d_head·n_heads` work product — attention does several flops
/// per product unit, so the gate sits lower, with its own clamp.
pub fn par_min_attn_work() -> usize {
    static GATE: OnceLock<usize> = OnceLock::new();
    *GATE.get_or_init(|| ((pool_dispatch_ns() as usize) * 2).clamp(1 << 11, 1 << 16))
}

/// Run `f` over every item, the slice split into at most `threads`
/// contiguous chunks executed on the persistent pool (the caller works
/// the first chunk itself). Items are mutated in place; chunks are
/// disjoint, so this is deterministic for any per-item-independent `f`
/// — bit-identical to the serial loop and to
/// [`for_each_partitioned_scoped`] at every thread count.
pub fn for_each_partitioned<T: Send, F: Fn(&mut T) + Sync>(items: &mut [T], threads: usize, f: F) {
    let n = items.len();
    if threads <= 1 || n <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let chunk = n.div_ceil(threads.min(n));
    let _sp = trace_span!(Phase::PoolDispatch, n.div_ceil(chunk) as u64);
    pool().run_parts(items, chunk, &f);
}

/// The pre-pool implementation: one fresh scoped thread per chunk.
/// Kept for the pool-vs-spawn micro bench and the partitioner parity
/// property tests; identical chunking, identical results.
pub fn for_each_partitioned_scoped<T: Send, F: Fn(&mut T) + Sync>(
    items: &mut [T],
    threads: usize,
    f: F,
) {
    let n = items.len();
    if threads <= 1 || n <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let chunk = n.div_ceil(threads.min(n));
    std::thread::scope(|s| {
        for part in items.chunks_mut(chunk) {
            let fref = &f;
            s.spawn(move || {
                for it in part.iter_mut() {
                    fref(it);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitioned_map_touches_every_item_once() {
        let mut xs: Vec<u64> = (0..37).collect();
        for_each_partitioned(&mut xs, 4, |x| *x += 1000);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(x, 1000 + i as u64);
        }
        // Degenerate partitions.
        let mut ys: Vec<u64> = vec![7];
        for_each_partitioned(&mut ys, 8, |y| *y *= 2);
        assert_eq!(ys, vec![14]);
        let mut empty: Vec<u64> = Vec::new();
        for_each_partitioned(&mut empty, 3, |_| unreachable!());
    }

    #[test]
    fn pool_matches_scoped_and_serial() {
        let f = |x: &mut f32| {
            // A few non-associative float steps so any ordering bug
            // would change bits.
            *x = (*x * 1.7 + 0.3) * 0.9;
            *x += *x * 0.01;
        };
        let base: Vec<f32> = (0..101).map(|i| i as f32 * 0.37 - 5.0).collect();
        let mut serial = base.clone();
        for it in serial.iter_mut() {
            f(it);
        }
        for threads in [2usize, 3, 5, 16] {
            let mut pooled = base.clone();
            for_each_partitioned(&mut pooled, threads, f);
            assert_eq!(serial, pooled, "pool threads={threads}");
            let mut scoped = base.clone();
            for_each_partitioned_scoped(&mut scoped, threads, f);
            assert_eq!(serial, scoped, "scoped threads={threads}");
        }
    }

    #[test]
    fn jobs_may_outnumber_workers() {
        // Far more chunks than pool workers: every chunk must still run
        // exactly once.
        let mut xs: Vec<u64> = (0..257).collect();
        for_each_partitioned(&mut xs, 64, |x| *x = x.wrapping_mul(3) + 1);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(x, (i as u64).wrapping_mul(3) + 1);
        }
    }

    #[test]
    fn pool_survives_reuse_across_dispatches() {
        for round in 0..32u64 {
            let mut xs: Vec<u64> = (0..19).collect();
            for_each_partitioned(&mut xs, 4, |x| *x += round);
            for (i, &x) in xs.iter().enumerate() {
                assert_eq!(x, i as u64 + round);
            }
        }
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn pool_propagates_worker_job_panics_with_payload() {
        let mut xs: Vec<u64> = (0..64).collect();
        // Item 63 lands in the last chunk (a pool worker's), so the
        // panic crosses the latch back into the caller — with its
        // original payload intact.
        for_each_partitioned(&mut xs, 4, |x| {
            if *x == 63 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn dispatch_cost_and_gates_are_sane() {
        assert!(pool_dispatch_ns() >= 1);
        // A two-chunk dispatch guarantees at least one worker exists
        // regardless of test order or core count (demand-grown pool).
        let mut xs = [0u64, 1];
        for_each_partitioned(&mut xs, 2, |x| *x += 1);
        assert_eq!(xs, [1, 2]);
        assert!(pool_workers() >= 1);
        let g = par_min_macs();
        assert!((1 << 13..=1 << 18).contains(&g), "gate {g}");
        let a = par_min_attn_work();
        assert!((1 << 11..=1 << 16).contains(&a), "attn gate {a}");
    }

    #[test]
    fn default_threads_is_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
