//! Head-blocked scaled-dot-product attention over contiguous per-head
//! K/V panels.
//!
//! The reference backend's old `attn_core` strided through interleaved
//! `[len, d_model]` K/V buffers, touching `d_model`-spaced slivers per
//! head. [`KvPanels`] instead stores one contiguous `[len, d_head]`
//! panel per head, so the score loop and the context accumulation both
//! stream dense memory. Panels also make the KV cache's `append` /
//! `truncate` head-local and cheap.
//!
//! Determinism: per `(head, query)` the key scores, the running max, the
//! exp-sum and the value accumulation all run `j = 0..len` ascending —
//! identical for batched, single-row, and head-threaded calls.

/// Minimum `nq·nk·d_head·n_heads` product before head-partitioned
/// threading pays for scoped spawns.
const PAR_MIN_WORK: usize = 1 << 14;

/// Per-layer attention K/V of one row, stored as contiguous per-head
/// panels (`[len, d_head]` each).
#[derive(Debug, Clone)]
pub struct KvPanels {
    d_head: usize,
    len: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvPanels {
    pub fn new(n_heads: usize, d_head: usize) -> KvPanels {
        KvPanels {
            d_head,
            len: 0,
            k: vec![Vec::new(); n_heads],
            v: vec![Vec::new(); n_heads],
        }
    }

    pub fn n_heads(&self) -> usize {
        self.k.len()
    }

    pub fn d_head(&self) -> usize {
        self.d_head
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn k_panel(&self, h: usize) -> &[f32] {
        &self.k[h]
    }

    pub fn v_panel(&self, h: usize) -> &[f32] {
        &self.v[h]
    }

    /// Append `m` positions whose K and V rows live head-interleaved
    /// (`[m, n_heads·d_head]`) inside a wider row-major matrix: row `r`'s
    /// K starts at `data[r·stride + k_off]`, its V at
    /// `data[r·stride + v_off]`. This is how the fused-QKV GEMM output
    /// (`stride = 3·d_model`) lands in the cache without an intermediate
    /// copy.
    pub fn append_strided(
        &mut self,
        data: &[f32],
        m: usize,
        stride: usize,
        k_off: usize,
        v_off: usize,
    ) {
        let dh = self.d_head;
        for (h, (kp, vp)) in self.k.iter_mut().zip(self.v.iter_mut()).enumerate() {
            for r in 0..m {
                let base = r * stride + h * dh;
                kp.extend_from_slice(&data[base + k_off..base + k_off + dh]);
                vp.extend_from_slice(&data[base + v_off..base + v_off + dh]);
            }
        }
        self.len += m;
    }

    /// Append from separate head-interleaved `[m, d_model]` K and V
    /// matrices (the cross-attention memory projection).
    pub fn append(&mut self, k_rows: &[f32], v_rows: &[f32], m: usize) {
        let d_model = self.d_head * self.k.len();
        let dh = self.d_head;
        debug_assert!(k_rows.len() >= m * d_model && v_rows.len() >= m * d_model);
        for (h, (kp, vp)) in self.k.iter_mut().zip(self.v.iter_mut()).enumerate() {
            for r in 0..m {
                let base = r * d_model + h * dh;
                kp.extend_from_slice(&k_rows[base..base + dh]);
                vp.extend_from_slice(&v_rows[base..base + dh]);
            }
        }
        self.len += m;
    }

    /// Roll the cache back to its first `len` positions.
    pub fn truncate(&mut self, len: usize) {
        if len >= self.len {
            return;
        }
        let dh = self.d_head;
        for (kp, vp) in self.k.iter_mut().zip(self.v.iter_mut()) {
            kp.truncate(len * dh);
            vp.truncate(len * dh);
        }
        self.len = len;
    }
}

/// One head's attention: queries `i` live head-interleaved in `q` (row
/// `i`, head `h` at `q[q_base + i·q_stride + h·d_head]`); context rows
/// land at `out[i·out_stride + out_base]`. `causal_offset = Some(p)`
/// lets query `i` attend keys `j ≤ p + i` (global positions);
/// `None` attends every cached key (cross-attention).
#[allow(clippy::too_many_arguments)]
fn attn_one_head(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: &KvPanels,
    h: usize,
    causal_offset: Option<usize>,
    out: &mut [f32],
    out_stride: usize,
    out_base: usize,
) {
    let dh = kv.d_head;
    let nk = kv.len;
    let scale = 1.0 / (dh as f32).sqrt();
    let kp = kv.k_panel(h);
    let vp = kv.v_panel(h);
    let mut scores = vec![0f32; nk];
    for i in 0..nq {
        let qo = q_base + i * q_stride + h * dh;
        let qi = &q[qo..qo + dh];
        let lim = match causal_offset {
            Some(p) => (p + i + 1).min(nk),
            None => nk,
        };
        let mut mx = f32::NEG_INFINITY;
        for (j, s) in scores[..lim].iter_mut().enumerate() {
            let kj = &kp[j * dh..j * dh + dh];
            let mut acc = 0f32;
            for (a, b) in qi.iter().zip(kj) {
                acc += a * b;
            }
            let sv = acc * scale;
            *s = sv;
            if sv > mx {
                mx = sv;
            }
        }
        let mut z = 0f32;
        for s in scores[..lim].iter_mut() {
            *s = (*s - mx).exp();
            z += *s;
        }
        let inv = 1.0 / z;
        let co = i * out_stride + out_base;
        let ci = &mut out[co..co + dh];
        for c in ci.iter_mut() {
            *c = 0.0;
        }
        for (j, &w0) in scores[..lim].iter().enumerate() {
            let w = w0 * inv;
            if w == 0.0 {
                continue;
            }
            let vj = &vp[j * dh..j * dh + dh];
            for (c, &vv) in ci.iter_mut().zip(vj) {
                *c += w * vv;
            }
        }
    }
}

/// Head-blocked attention of `nq` interleaved queries against panel K/V;
/// context written head-interleaved into `ctx` (`[nq, n_heads·d_head]`).
/// See [`attn_one_head`] for the query layout and masking semantics.
pub fn attn_panels(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: &KvPanels,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
) {
    let d_model = kv.n_heads() * kv.d_head();
    for h in 0..kv.n_heads() {
        attn_one_head(
            q,
            q_stride,
            q_base,
            nq,
            kv,
            h,
            causal_offset,
            ctx,
            d_model,
            h * kv.d_head(),
        );
    }
}

/// [`attn_panels`] with the heads partitioned across up to `threads`
/// scoped threads (each head computed into its own scratch panel, merged
/// serially) — bit-identical to the serial call, since per-head
/// arithmetic is untouched.
#[allow(clippy::too_many_arguments)]
pub fn attn_panels_threaded(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: &KvPanels,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
    threads: usize,
) {
    let nh = kv.n_heads();
    let dh = kv.d_head();
    let work = nq * kv.len() * dh * nh;
    if threads <= 1 || nh <= 1 || work < PAR_MIN_WORK {
        attn_panels(q, q_stride, q_base, nq, kv, causal_offset, ctx);
        return;
    }
    let d_model = nh * dh;
    let per = nh.div_ceil(threads.min(nh));
    let mut scratch: Vec<Vec<f32>> = (0..nh).map(|_| vec![0f32; nq * dh]).collect();
    std::thread::scope(|s| {
        for (ci, bufs) in scratch.chunks_mut(per).enumerate() {
            let h0 = ci * per;
            s.spawn(move || {
                for (k, buf) in bufs.iter_mut().enumerate() {
                    attn_one_head(q, q_stride, q_base, nq, kv, h0 + k, causal_offset, buf, dh, 0);
                }
            });
        }
    });
    for (h, buf) in scratch.iter().enumerate() {
        for i in 0..nq {
            let co = i * d_model + h * dh;
            ctx[co..co + dh].copy_from_slice(&buf[i * dh..(i + 1) * dh]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect()
    }

    fn filled_panels(rng: &mut Rng, nh: usize, dh: usize, len: usize) -> KvPanels {
        let d = nh * dh;
        let mut kv = KvPanels::new(nh, dh);
        let k = rand_vec(rng, len * d);
        let v = rand_vec(rng, len * d);
        kv.append(&k, &v, len);
        kv
    }

    #[test]
    fn append_strided_matches_plain_append() {
        let mut rng = Rng::new(1);
        let (nh, dh, m) = (3usize, 4usize, 5usize);
        let d = nh * dh;
        // A fused-QKV-shaped matrix [m, 3d]: K at offset d, V at 2d.
        let fused = rand_vec(&mut rng, m * 3 * d);
        let mut a = KvPanels::new(nh, dh);
        a.append_strided(&fused, m, 3 * d, d, 2 * d);
        let mut k_rows = vec![0f32; m * d];
        let mut v_rows = vec![0f32; m * d];
        for r in 0..m {
            k_rows[r * d..(r + 1) * d].copy_from_slice(&fused[r * 3 * d + d..r * 3 * d + 2 * d]);
            v_rows[r * d..(r + 1) * d]
                .copy_from_slice(&fused[r * 3 * d + 2 * d..r * 3 * d + 3 * d]);
        }
        let mut b = KvPanels::new(nh, dh);
        b.append(&k_rows, &v_rows, m);
        assert_eq!(a.len(), b.len());
        for h in 0..nh {
            assert_eq!(a.k_panel(h), b.k_panel(h));
            assert_eq!(a.v_panel(h), b.v_panel(h));
        }
    }

    #[test]
    fn truncate_rolls_back_appends() {
        let mut rng = Rng::new(2);
        let (nh, dh) = (2usize, 3usize);
        let d = nh * dh;
        let k1 = rand_vec(&mut rng, 4 * d);
        let v1 = rand_vec(&mut rng, 4 * d);
        let mut kv = KvPanels::new(nh, dh);
        kv.append(&k1, &v1, 4);
        let snap_k: Vec<Vec<f32>> = (0..nh).map(|h| kv.k_panel(h)[..2 * dh].to_vec()).collect();
        kv.truncate(2);
        assert_eq!(kv.len(), 2);
        for h in 0..nh {
            assert_eq!(kv.k_panel(h), snap_k[h].as_slice());
        }
        // Truncate past the end is a no-op.
        kv.truncate(10);
        assert_eq!(kv.len(), 2);
    }

    #[test]
    fn causal_mask_ignores_future_keys() {
        // With causal_offset = Some(p), query i's context must be
        // independent of keys beyond p + i.
        let mut rng = Rng::new(3);
        let (nh, dh, nk) = (2usize, 4usize, 6usize);
        let d = nh * dh;
        let kv_full = filled_panels(&mut rng, nh, dh, nk);
        let mut kv_cut = kv_full.clone();
        kv_cut.truncate(3); // keys 0..3 = everything query 0 (p=2) may see
        let q = rand_vec(&mut rng, d);
        let mut ctx_full = vec![0f32; d];
        let mut ctx_cut = vec![0f32; d];
        attn_panels(&q, d, 0, 1, &kv_full, Some(2), &mut ctx_full);
        attn_panels(&q, d, 0, 1, &kv_cut, Some(2), &mut ctx_cut);
        assert_eq!(ctx_full, ctx_cut);
    }

    #[test]
    fn threaded_attention_is_bit_identical_to_serial() {
        let mut rng = Rng::new(4);
        // Crosses the PAR_MIN_WORK gate: 8·64·8·4 = 16384.
        let (nh, dh, nk, nq) = (4usize, 8usize, 64usize, 8usize);
        let d = nh * dh;
        let kv = filled_panels(&mut rng, nh, dh, nk);
        let q = rand_vec(&mut rng, nq * d);
        for mask in [None, Some(nk - nq)] {
            let mut serial = vec![0f32; nq * d];
            attn_panels(&q, d, 0, nq, &kv, mask, &mut serial);
            for threads in [2usize, 3, 4, 9] {
                let mut par = vec![0f32; nq * d];
                attn_panels_threaded(&q, d, 0, nq, &kv, mask, &mut par, threads);
                assert_eq!(serial, par, "threads={threads} mask={mask:?}");
            }
        }
    }

    #[test]
    fn strided_queries_match_contiguous_queries() {
        // Reading queries out of a wider matrix (the fused-QKV output)
        // must equal reading them from a dense [nq, d] copy.
        let mut rng = Rng::new(5);
        let (nh, dh, nk, nq) = (2usize, 4usize, 5usize, 3usize);
        let d = nh * dh;
        let kv = filled_panels(&mut rng, nh, dh, nk);
        let wide = rand_vec(&mut rng, nq * 3 * d);
        let mut dense = vec![0f32; nq * d];
        for r in 0..nq {
            dense[r * d..(r + 1) * d].copy_from_slice(&wide[r * 3 * d..r * 3 * d + d]);
        }
        let mut ctx_wide = vec![0f32; nq * d];
        let mut ctx_dense = vec![0f32; nq * d];
        attn_panels(&wide, 3 * d, 0, nq, &kv, Some(1), &mut ctx_wide);
        attn_panels(&dense, d, 0, nq, &kv, Some(1), &mut ctx_dense);
        assert_eq!(ctx_wide, ctx_dense);
    }
}
