//! Head-blocked scaled-dot-product attention over per-head K/V panels,
//! running on the wide-lane SIMD layer.
//!
//! [`KvPanels`] stores the two operands in the layout each consuming
//! loop wants to vectorize over:
//!
//! * **K is dimension-major**: lane `h·d_head + d` holds key component
//!   `d` of head `h` for every cached position, j-ascending. The score
//!   loop then runs one broadcast-`q[d]` × contiguous-key-lane op per
//!   query dimension — vectorized **across keys**, which are the score
//!   row's output elements, while each score keeps its d-ascending
//!   reduction order.
//! * **V is row-major** per head (`[len, d_head]` panels): the context
//!   accumulation runs one broadcast-weight × contiguous-value-row op
//!   per key — vectorized **across context dimensions** (the output
//!   elements), each keeping its j-ascending reduction order.
//!
//! Panels keep the KV cache's `append` / `truncate` head-local and
//! cheap. The micro-loops dispatch at runtime between AVX2 intrinsics
//! and the portable [`F32Lanes`] fallback (see [`crate::kernels::simd`]).
//!
//! The transposed K layout is a deliberate append-vs-read trade:
//! appending one position costs `d_model` strided element pushes (one
//! per lane) instead of `n_heads` contiguous copies, but each appended
//! key is then *read* at unit stride by every later score pass —
//! `O(len · d_head)` lane-vectorized reads per query against a
//! `O(d_head)` one-time append cost, which wins for any cache that is
//! attended more than once.
//!
//! Determinism: per `(head, query)` the key scores (d ascending each),
//! the scale multiply, the running max, the exp-sum and the value
//! accumulation (j ascending each) are identical for batched,
//! single-row, head-threaded, and either-SIMD-backend calls.

use crate::kernels::simd::{self, F32Lanes, SimdLevel, LANES};
use crate::kernels::threads;
use crate::trace::Phase;
use crate::trace_span;

/// Per-layer attention K/V of one row, stored as per-head panels (see
/// module docs for the K/V layouts).
#[derive(Debug, Clone)]
pub struct KvPanels {
    d_head: usize,
    len: usize,
    /// `n_heads · d_head` dimension-major key lanes, each `len` long.
    k: Vec<Vec<f32>>,
    /// `n_heads` row-major value panels, each `[len, d_head]`.
    v: Vec<Vec<f32>>,
}

impl KvPanels {
    pub fn new(n_heads: usize, d_head: usize) -> KvPanels {
        KvPanels {
            d_head,
            len: 0,
            k: vec![Vec::new(); n_heads * d_head],
            v: vec![Vec::new(); n_heads],
        }
    }

    pub fn n_heads(&self) -> usize {
        self.v.len()
    }

    pub fn d_head(&self) -> usize {
        self.d_head
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Key component `d` of head `h` across all cached positions
    /// (j-ascending).
    pub fn k_lane(&self, h: usize, d: usize) -> &[f32] {
        &self.k[h * self.d_head + d]
    }

    pub fn v_panel(&self, h: usize) -> &[f32] {
        &self.v[h]
    }

    /// Append `m` positions whose K and V rows live head-interleaved
    /// (`[m, n_heads·d_head]`) inside a wider row-major matrix: row `r`'s
    /// K starts at `data[r·stride + k_off]`, its V at
    /// `data[r·stride + v_off]`. This is how the fused-QKV GEMM output
    /// (`stride = 3·d_model`) lands in the cache without an intermediate
    /// copy.
    pub fn append_strided(
        &mut self,
        data: &[f32],
        m: usize,
        stride: usize,
        k_off: usize,
        v_off: usize,
    ) {
        let dh = self.d_head;
        for (hd, lane) in self.k.iter_mut().enumerate() {
            lane.reserve(m);
            for r in 0..m {
                lane.push(data[r * stride + k_off + hd]);
            }
        }
        for (h, vp) in self.v.iter_mut().enumerate() {
            vp.reserve(m * dh);
            for r in 0..m {
                let base = r * stride + v_off + h * dh;
                vp.extend_from_slice(&data[base..base + dh]);
            }
        }
        self.len += m;
    }

    /// Append from separate head-interleaved `[m, d_model]` K and V
    /// matrices (the cross-attention memory projection).
    pub fn append(&mut self, k_rows: &[f32], v_rows: &[f32], m: usize) {
        let dh = self.d_head;
        let d_model = dh * self.v.len();
        debug_assert!(k_rows.len() >= m * d_model && v_rows.len() >= m * d_model);
        for (hd, lane) in self.k.iter_mut().enumerate() {
            lane.reserve(m);
            for r in 0..m {
                lane.push(k_rows[r * d_model + hd]);
            }
        }
        for (h, vp) in self.v.iter_mut().enumerate() {
            vp.reserve(m * dh);
            for r in 0..m {
                let base = r * d_model + h * dh;
                vp.extend_from_slice(&v_rows[base..base + dh]);
            }
        }
        self.len += m;
    }

    /// Roll the cache back to its first `len` positions.
    pub fn truncate(&mut self, len: usize) {
        if len >= self.len {
            return;
        }
        let dh = self.d_head;
        for lane in self.k.iter_mut() {
            lane.truncate(len);
        }
        for vp in self.v.iter_mut() {
            vp.truncate(len * dh);
        }
        self.len = len;
    }

    /// Paged constructor: borrow `len` cached positions from page-pooled
    /// storage instead of owned panels. Each entry of `pages` is one
    /// page's `(K, V)` blobs for **one layer**, holding `page` positions
    /// in the panel layouts scaled down to a page:
    ///
    /// * K dimension-major `[n_heads·d_head, page]` — lane `(h, d)` at
    ///   `k[(h·d_head + d)·page ..][..page]`, slot-ascending;
    /// * V row-major per head `[n_heads, page, d_head]` — slot `s` of
    ///   head `h` at `v[(h·page + s)·d_head ..][..d_head]`.
    ///
    /// The score/AV micro-loops therefore stay on contiguous lanes
    /// *within* a page and chunk at page boundaries, which is
    /// bit-identical to the dense panels (see [`attn_panels_paged`]).
    pub fn paged<'a>(
        n_heads: usize,
        d_head: usize,
        len: usize,
        page: usize,
        pages: Vec<(&'a [f32], &'a [f32])>,
    ) -> PagedKv<'a> {
        debug_assert!(page >= 1);
        debug_assert!(pages.len() * page >= len, "page table too short for len");
        debug_assert!(pages
            .iter()
            .all(|(k, v)| k.len() >= n_heads * d_head * page && v.len() >= n_heads * d_head * page));
        PagedKv {
            n_heads,
            d_head,
            len,
            page,
            pages,
        }
    }
}

/// A borrowed page-strided view of one layer's K/V — what the paged KV
/// arena hands the attention kernels. Built via [`KvPanels::paged`].
#[derive(Debug, Clone)]
pub struct PagedKv<'a> {
    n_heads: usize,
    d_head: usize,
    len: usize,
    /// Positions per page.
    page: usize,
    /// Page `p` holds positions `[p·page, (p+1)·page)`.
    pages: Vec<(&'a [f32], &'a [f32])>,
}

impl<'a> PagedKv<'a> {
    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    pub fn d_head(&self) -> usize {
        self.d_head
    }

    /// Number of cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions per page.
    pub fn page(&self) -> usize {
        self.page
    }

    /// Key component `d` of head `h` across page `p`'s slots.
    #[inline]
    fn k_lane_page(&self, p: usize, h: usize, d: usize) -> &'a [f32] {
        let (k, _) = self.pages[p];
        let base = (h * self.d_head + d) * self.page;
        &k[base..base + self.page]
    }

    /// Value row of global position `p·page + slot`, head `h`.
    #[inline]
    fn v_row(&self, p: usize, h: usize, slot: usize) -> &'a [f32] {
        let (_, v) = self.pages[p];
        let base = (h * self.page + slot) * self.d_head;
        &v[base..base + self.d_head]
    }
}

// ---------------------------------------------------------------------------
// Micro-loops (portable + AVX2, bit-identical pairs)
// ---------------------------------------------------------------------------

/// `scores[j] += qd · lane[j]` over all `j` — the per-query-dimension
/// rank-1 update of the score row (keys are the lanes).
fn score_update_lanes(scores: &mut [f32], qd: f32, lane: &[f32]) {
    let n = scores.len();
    let ql = F32Lanes::splat(qd);
    let mut j = 0usize;
    while j + LANES <= n {
        let acc = F32Lanes::load(&scores[j..j + LANES])
            .mul_then_add(ql, F32Lanes::load(&lane[j..j + LANES]));
        acc.store(&mut scores[j..j + LANES]);
        j += LANES;
    }
    while j < n {
        scores[j] += qd * lane[j];
        j += 1;
    }
}

/// AVX2 twin of [`score_update_lanes`] — identical per-element
/// arithmetic and order.
///
/// # Safety
/// AVX2 must be available (dispatch sites check
/// [`simd::avx2_available`]); `lane.len() >= scores.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn score_update_avx2(scores: &mut [f32], qd: f32, lane: &[f32]) {
    use crate::kernels::simd::avx2 as v;
    let n = scores.len();
    let ql = v::splat(qd);
    let mut j = 0usize;
    while j + LANES <= n {
        let acc = v::mul_then_add(
            v::load(&scores[j..j + LANES]),
            ql,
            v::load(&lane[j..j + LANES]),
        );
        v::store(acc, &mut scores[j..j + LANES]);
        j += LANES;
    }
    while j < n {
        scores[j] += qd * lane[j];
        j += 1;
    }
}

/// `ci[d] += w · vj[d]` over all `d` — one key's weighted value row
/// added into the context (dimensions are the lanes).
fn av_update_lanes(ci: &mut [f32], w: f32, vj: &[f32]) {
    let dh = ci.len();
    let wl = F32Lanes::splat(w);
    let mut d = 0usize;
    while d + LANES <= dh {
        let acc = F32Lanes::load(&ci[d..d + LANES])
            .mul_then_add(wl, F32Lanes::load(&vj[d..d + LANES]));
        acc.store(&mut ci[d..d + LANES]);
        d += LANES;
    }
    while d < dh {
        ci[d] += w * vj[d];
        d += 1;
    }
}

/// AVX2 twin of [`av_update_lanes`].
///
/// # Safety
/// AVX2 must be available (dispatch sites check
/// [`simd::avx2_available`]); `vj.len() >= ci.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn av_update_avx2(ci: &mut [f32], w: f32, vj: &[f32]) {
    use crate::kernels::simd::avx2 as v;
    let dh = ci.len();
    let wl = v::splat(w);
    let mut d = 0usize;
    while d + LANES <= dh {
        let acc = v::mul_then_add(v::load(&ci[d..d + LANES]), wl, v::load(&vj[d..d + LANES]));
        v::store(acc, &mut ci[d..d + LANES]);
        d += LANES;
    }
    while d < dh {
        ci[d] += w * vj[d];
        d += 1;
    }
}

// Both dispatchers re-check the CPU before entering `#[target_feature]`
// code: `SimdLevel` is a plain public enum, so a caller-supplied `Avx2`
// is no proof of support — it falls back to the portable lanes instead.

#[inline]
fn score_update(scores: &mut [f32], qd: f32, lane: &[f32], level: SimdLevel) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: guarded by runtime detection.
        SimdLevel::Avx2 if simd::avx2_available() => unsafe {
            score_update_avx2(scores, qd, lane)
        },
        SimdLevel::Avx2 => score_update_lanes(scores, qd, lane),
        SimdLevel::Scalar => score_update_lanes(scores, qd, lane),
    }
}

#[inline]
fn av_update(ci: &mut [f32], w: f32, vj: &[f32], level: SimdLevel) {
    match level {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: guarded by runtime detection.
        SimdLevel::Avx2 if simd::avx2_available() => unsafe { av_update_avx2(ci, w, vj) },
        SimdLevel::Avx2 => av_update_lanes(ci, w, vj),
        SimdLevel::Scalar => av_update_lanes(ci, w, vj),
    }
}

/// Either K/V representation behind one attention call: owned dense
/// panels or a borrowed page-strided arena view. Per-element arithmetic
/// and reduction orders are identical through both arms, so the two are
/// bit-identical for the same cached values.
#[derive(Clone, Copy)]
enum KvRef<'a> {
    Dense(&'a KvPanels),
    Paged(&'a PagedKv<'a>),
}

impl KvRef<'_> {
    fn n_heads(self) -> usize {
        match self {
            KvRef::Dense(kv) => kv.n_heads(),
            KvRef::Paged(pv) => pv.n_heads(),
        }
    }

    fn d_head(self) -> usize {
        match self {
            KvRef::Dense(kv) => kv.d_head(),
            KvRef::Paged(pv) => pv.d_head(),
        }
    }

    fn len(self) -> usize {
        match self {
            KvRef::Dense(kv) => kv.len(),
            KvRef::Paged(pv) => pv.len(),
        }
    }
}

/// One head's attention: queries `i` live head-interleaved in `q` (row
/// `i`, head `h` at `q[q_base + i·q_stride + h·d_head]`); context rows
/// land at `out[i·out_stride + out_base]`. `causal_offset = Some(p)`
/// lets query `i` attend keys `j ≤ p + i` (global positions);
/// `None` attends every cached key (cross-attention).
#[allow(clippy::too_many_arguments)]
fn attn_one_head(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: KvRef<'_>,
    h: usize,
    causal_offset: Option<usize>,
    out: &mut [f32],
    out_stride: usize,
    out_base: usize,
    level: SimdLevel,
) {
    let dh = kv.d_head();
    let nk = kv.len();
    let scale = 1.0 / (dh as f32).sqrt();
    let mut scores = vec![0f32; nk];
    for i in 0..nq {
        let qo = q_base + i * q_stride + h * dh;
        let qi = &q[qo..qo + dh];
        let lim = match causal_offset {
            Some(p) => (p + i + 1).min(nk),
            None => nk,
        };
        // Scores: one rank-1 lane update per query dimension, so each
        // score_j reduces d-ascending exactly like a scalar dot. The
        // paged arm runs the same update chunked at page boundaries —
        // the update is elementwise and the SIMD/scalar split is itself
        // bit-identical per element, so chunking changes no score.
        for s in scores[..lim].iter_mut() {
            *s = 0.0;
        }
        match kv {
            KvRef::Dense(kv) => {
                for (d, &qd) in qi.iter().enumerate() {
                    score_update(&mut scores[..lim], qd, &kv.k_lane(h, d)[..lim], level);
                }
            }
            KvRef::Paged(pv) => {
                for (d, &qd) in qi.iter().enumerate() {
                    let mut j0 = 0usize;
                    let mut p = 0usize;
                    while j0 < lim {
                        let take = (lim - j0).min(pv.page);
                        score_update(
                            &mut scores[j0..j0 + take],
                            qd,
                            &pv.k_lane_page(p, h, d)[..take],
                            level,
                        );
                        j0 += take;
                        p += 1;
                    }
                }
            }
        }
        // Scale + running max, j ascending.
        let mut mx = f32::NEG_INFINITY;
        for s in scores[..lim].iter_mut() {
            *s *= scale;
            if *s > mx {
                mx = *s;
            }
        }
        let mut z = 0f32;
        for s in scores[..lim].iter_mut() {
            *s = (*s - mx).exp();
            z += *s;
        }
        let inv = 1.0 / z;
        let co = i * out_stride + out_base;
        let ci = &mut out[co..co + dh];
        for c in ci.iter_mut() {
            *c = 0.0;
        }
        // Context: one weighted value-row lane update per key, so each
        // ci[d] reduces j-ascending — the paged arm reads value rows
        // through the page table, same order, same arithmetic.
        match kv {
            KvRef::Dense(kv) => {
                let vp = kv.v_panel(h);
                for (j, &w0) in scores[..lim].iter().enumerate() {
                    av_update(ci, w0 * inv, &vp[j * dh..(j + 1) * dh], level);
                }
            }
            KvRef::Paged(pv) => {
                for (j, &w0) in scores[..lim].iter().enumerate() {
                    av_update(ci, w0 * inv, pv.v_row(j / pv.page, h, j % pv.page), level);
                }
            }
        }
    }
}

/// Head-blocked attention of `nq` interleaved queries against panel K/V;
/// context written head-interleaved into `ctx` (`[nq, n_heads·d_head]`),
/// at the process-wide SIMD dispatch level. See [`attn_one_head`] for
/// the query layout and masking semantics.
pub fn attn_panels(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: &KvPanels,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
) {
    attn_panels_with(q, q_stride, q_base, nq, kv, causal_offset, ctx, simd::simd_level());
}

/// [`attn_panels`] with an explicit SIMD dispatch level — the bench /
/// property-test hook; results are bit-identical at every level.
#[allow(clippy::too_many_arguments)]
pub fn attn_panels_with(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: &KvPanels,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
    level: SimdLevel,
) {
    attn_ref_with(q, q_stride, q_base, nq, KvRef::Dense(kv), causal_offset, ctx, level);
}

/// [`attn_panels`] over a page-strided arena view ([`KvPanels::paged`]),
/// at the process-wide SIMD dispatch level. Bit-identical to the dense
/// call over the same cached values.
pub fn attn_panels_paged(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: &PagedKv<'_>,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
) {
    attn_panels_paged_with(q, q_stride, q_base, nq, kv, causal_offset, ctx, simd::simd_level());
}

/// [`attn_panels_paged`] with an explicit SIMD dispatch level.
#[allow(clippy::too_many_arguments)]
pub fn attn_panels_paged_with(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: &PagedKv<'_>,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
    level: SimdLevel,
) {
    attn_ref_with(q, q_stride, q_base, nq, KvRef::Paged(kv), causal_offset, ctx, level);
}

#[allow(clippy::too_many_arguments)]
fn attn_ref_with(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: KvRef<'_>,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
    level: SimdLevel,
) {
    let _sp = trace_span!(
        Phase::Attention,
        (nq * kv.len() * kv.d_head() * kv.n_heads()) as u64
    );
    let d_model = kv.n_heads() * kv.d_head();
    for h in 0..kv.n_heads() {
        attn_one_head(
            q,
            q_stride,
            q_base,
            nq,
            kv,
            h,
            causal_offset,
            ctx,
            d_model,
            h * kv.d_head(),
            level,
        );
    }
}

/// [`attn_panels`] with the heads partitioned across up to `threads`
/// persistent-pool lanes (each head computed into its own scratch
/// panel, merged serially) once the call clears the adaptive
/// [`threads::par_min_attn_work`] gate — bit-identical to the serial
/// call, since per-head arithmetic is untouched.
#[allow(clippy::too_many_arguments)]
pub fn attn_panels_threaded(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: &KvPanels,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
    threads: usize,
) {
    attn_panels_threaded_with(
        q,
        q_stride,
        q_base,
        nq,
        kv,
        causal_offset,
        ctx,
        threads,
        simd::simd_level(),
    )
}

/// [`attn_panels_threaded`] with an explicit SIMD dispatch level.
#[allow(clippy::too_many_arguments)]
pub fn attn_panels_threaded_with(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: &KvPanels,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
    threads: usize,
    level: SimdLevel,
) {
    attn_ref_threaded_with(
        q,
        q_stride,
        q_base,
        nq,
        KvRef::Dense(kv),
        causal_offset,
        ctx,
        threads,
        level,
    );
}

/// [`attn_panels_threaded`] over a page-strided arena view — same
/// adaptive head partitioning and work gate, so the paged threaded call
/// is bit-identical to both its serial form and the dense path.
#[allow(clippy::too_many_arguments)]
pub fn attn_panels_paged_threaded(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: &PagedKv<'_>,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
    threads: usize,
) {
    attn_ref_threaded_with(
        q,
        q_stride,
        q_base,
        nq,
        KvRef::Paged(kv),
        causal_offset,
        ctx,
        threads,
        simd::simd_level(),
    );
}

#[allow(clippy::too_many_arguments)]
fn attn_ref_threaded_with(
    q: &[f32],
    q_stride: usize,
    q_base: usize,
    nq: usize,
    kv: KvRef<'_>,
    causal_offset: Option<usize>,
    ctx: &mut [f32],
    threads: usize,
    level: SimdLevel,
) {
    let nh = kv.n_heads();
    let dh = kv.d_head();
    let work = nq * kv.len() * dh * nh;
    if threads <= 1 || nh <= 1 || work < threads::par_min_attn_work() {
        attn_ref_with(q, q_stride, q_base, nq, kv, causal_offset, ctx, level);
        return;
    }
    // The serial fallback above routes through `attn_ref_with`, which
    // carries its own span — so this covers only the parallel branch.
    let _sp = trace_span!(Phase::Attention, work as u64);
    let d_model = nh * dh;
    let per = nh.div_ceil(threads.min(nh));
    let mut scratch: Vec<Vec<f32>> = (0..nh).map(|_| vec![0f32; nq * dh]).collect();
    let mut parts: Vec<(usize, &mut [Vec<f32>])> = scratch
        .chunks_mut(per)
        .enumerate()
        .map(|(ci, bufs)| (ci * per, bufs))
        .collect();
    let n_parts = parts.len();
    threads::for_each_partitioned(&mut parts, n_parts, |p| {
        let h0 = p.0;
        for (k, buf) in p.1.iter_mut().enumerate() {
            attn_one_head(
                q,
                q_stride,
                q_base,
                nq,
                kv,
                h0 + k,
                causal_offset,
                buf,
                dh,
                0,
                level,
            );
        }
    });
    for (h, buf) in scratch.iter().enumerate() {
        for i in 0..nq {
            let co = i * d_model + h * dh;
            ctx[co..co + dh].copy_from_slice(&buf[i * dh..(i + 1) * dh]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect()
    }

    fn filled_panels(rng: &mut Rng, nh: usize, dh: usize, len: usize) -> KvPanels {
        let d = nh * dh;
        let mut kv = KvPanels::new(nh, dh);
        let k = rand_vec(rng, len * d);
        let v = rand_vec(rng, len * d);
        kv.append(&k, &v, len);
        kv
    }

    fn assert_same_panels(a: &KvPanels, b: &KvPanels) {
        assert_eq!(a.len(), b.len());
        assert_eq!(a.n_heads(), b.n_heads());
        assert_eq!(a.d_head(), b.d_head());
        for h in 0..a.n_heads() {
            for d in 0..a.d_head() {
                assert_eq!(a.k_lane(h, d), b.k_lane(h, d), "k lane h={h} d={d}");
            }
            assert_eq!(a.v_panel(h), b.v_panel(h), "v panel h={h}");
        }
    }

    #[test]
    fn append_strided_matches_plain_append() {
        let mut rng = Rng::new(1);
        let (nh, dh, m) = (3usize, 4usize, 5usize);
        let d = nh * dh;
        // A fused-QKV-shaped matrix [m, 3d]: K at offset d, V at 2d.
        let fused = rand_vec(&mut rng, m * 3 * d);
        let mut a = KvPanels::new(nh, dh);
        a.append_strided(&fused, m, 3 * d, d, 2 * d);
        let mut k_rows = vec![0f32; m * d];
        let mut v_rows = vec![0f32; m * d];
        for r in 0..m {
            k_rows[r * d..(r + 1) * d].copy_from_slice(&fused[r * 3 * d + d..r * 3 * d + 2 * d]);
            v_rows[r * d..(r + 1) * d]
                .copy_from_slice(&fused[r * 3 * d + 2 * d..r * 3 * d + 3 * d]);
        }
        let mut b = KvPanels::new(nh, dh);
        b.append(&k_rows, &v_rows, m);
        assert_same_panels(&a, &b);
        // The lane layout itself: lane (h,d) at position j is row j's
        // K component h·dh + d.
        for h in 0..nh {
            for d0 in 0..dh {
                for j in 0..m {
                    assert_eq!(a.k_lane(h, d0)[j], k_rows[j * d + h * dh + d0]);
                }
            }
        }
    }

    #[test]
    fn truncate_rolls_back_appends() {
        let mut rng = Rng::new(2);
        let (nh, dh) = (2usize, 3usize);
        let d = nh * dh;
        let k1 = rand_vec(&mut rng, 4 * d);
        let v1 = rand_vec(&mut rng, 4 * d);
        let mut kv = KvPanels::new(nh, dh);
        kv.append(&k1, &v1, 4);
        let snap_k: Vec<Vec<f32>> = (0..nh)
            .flat_map(|h| (0..dh).map(move |d0| (h, d0)))
            .map(|(h, d0)| kv.k_lane(h, d0)[..2].to_vec())
            .collect();
        kv.truncate(2);
        assert_eq!(kv.len(), 2);
        for (i, (h, d0)) in (0..nh)
            .flat_map(|h| (0..dh).map(move |d0| (h, d0)))
            .enumerate()
        {
            assert_eq!(kv.k_lane(h, d0), snap_k[i].as_slice());
        }
        // Truncate past the end is a no-op.
        kv.truncate(10);
        assert_eq!(kv.len(), 2);
    }

    #[test]
    fn causal_mask_ignores_future_keys() {
        // With causal_offset = Some(p), query i's context must be
        // independent of keys beyond p + i.
        let mut rng = Rng::new(3);
        let (nh, dh, nk) = (2usize, 4usize, 6usize);
        let d = nh * dh;
        let kv_full = filled_panels(&mut rng, nh, dh, nk);
        let mut kv_cut = kv_full.clone();
        kv_cut.truncate(3); // keys 0..3 = everything query 0 (p=2) may see
        let q = rand_vec(&mut rng, d);
        let mut ctx_full = vec![0f32; d];
        let mut ctx_cut = vec![0f32; d];
        attn_panels(&q, d, 0, 1, &kv_full, Some(2), &mut ctx_full);
        attn_panels(&q, d, 0, 1, &kv_cut, Some(2), &mut ctx_cut);
        assert_eq!(ctx_full, ctx_cut);
    }

    #[test]
    fn simd_dispatch_is_bit_identical_to_scalar_fallback() {
        // Shapes that exercise lane tails in both loops: nk and dh not
        // multiples of LANES, plus causal masks trimming lim. The AVX2
        // level is requested explicitly whenever the CPU supports it
        // (dispatch re-checks support), so an `RXNSPEC_SIMD=off` run
        // can't silently reduce this to scalar-vs-scalar.
        let level = if simd::avx2_available() {
            SimdLevel::Avx2
        } else {
            simd::simd_level()
        };
        let mut rng = Rng::new(6);
        for &(nh, dh, nk, nq) in &[(2usize, 3usize, 11usize, 3usize), (1, 8, 16, 1), (3, 5, 7, 4)]
        {
            let d = nh * dh;
            let kv = filled_panels(&mut rng, nh, dh, nk);
            let q = rand_vec(&mut rng, nq * d);
            for mask in [None, Some(nk.saturating_sub(nq))] {
                let mut scalar = vec![0f32; nq * d];
                attn_panels_with(&q, d, 0, nq, &kv, mask, &mut scalar, SimdLevel::Scalar);
                let mut auto = vec![0f32; nq * d];
                attn_panels_with(&q, d, 0, nq, &kv, mask, &mut auto, level);
                assert_eq!(
                    scalar, auto,
                    "nh={nh} dh={dh} nk={nk} nq={nq} mask={mask:?}"
                );
            }
        }
    }

    #[test]
    fn threaded_attention_is_bit_identical_to_serial() {
        let mut rng = Rng::new(4);
        // Work product 16·64·16·4 = 2^16 meets the adaptive gate's
        // upper clamp, so head partitioning engages at any measurement.
        let (nh, dh, nk, nq) = (4usize, 16usize, 64usize, 16usize);
        let d = nh * dh;
        let kv = filled_panels(&mut rng, nh, dh, nk);
        let q = rand_vec(&mut rng, nq * d);
        for mask in [None, Some(nk - nq)] {
            let mut serial = vec![0f32; nq * d];
            attn_panels(&q, d, 0, nq, &kv, mask, &mut serial);
            for threads in [2usize, 3, 4, 9] {
                let mut par = vec![0f32; nq * d];
                attn_panels_threaded(&q, d, 0, nq, &kv, mask, &mut par, threads);
                assert_eq!(serial, par, "threads={threads} mask={mask:?}");
            }
        }
    }

    /// Chop a dense cache into page blobs in the [`KvPanels::paged`]
    /// per-page layouts (what the arena-backed sessions materialize).
    fn page_blobs(kv: &KvPanels, page: usize) -> Vec<(Vec<f32>, Vec<f32>)> {
        let (nh, dh) = (kv.n_heads(), kv.d_head());
        let n_pages = kv.len().div_ceil(page);
        let mut out = Vec::with_capacity(n_pages);
        for p in 0..n_pages {
            let mut k = vec![0f32; nh * dh * page];
            let mut v = vec![0f32; nh * dh * page];
            for s in 0..page {
                let j = p * page + s;
                if j >= kv.len() {
                    break;
                }
                for h in 0..nh {
                    for d in 0..dh {
                        k[(h * dh + d) * page + s] = kv.k_lane(h, d)[j];
                    }
                    let dst = (h * page + s) * dh;
                    v[dst..dst + dh].copy_from_slice(&kv.v_panel(h)[j * dh..(j + 1) * dh]);
                }
            }
            out.push((k, v));
        }
        out
    }

    #[test]
    fn paged_view_is_bit_identical_to_dense_panels() {
        // Page sizes deliberately off the LANES grid (1, 3, 5) force the
        // SIMD chunking to split where the dense loop would have run a
        // full vector — bit-identical anyway, because the vector and
        // scalar per-element arithmetic are themselves identical.
        let level = if simd::avx2_available() {
            SimdLevel::Avx2
        } else {
            simd::simd_level()
        };
        let mut rng = Rng::new(7);
        for &(nh, dh, nk, nq) in &[(2usize, 3usize, 11usize, 3usize), (1, 8, 16, 2), (3, 5, 7, 4)]
        {
            let d = nh * dh;
            let kv = filled_panels(&mut rng, nh, dh, nk);
            let q = rand_vec(&mut rng, nq * d);
            for page in [1usize, 3, 5, 8, 16, 32] {
                let blobs = page_blobs(&kv, page);
                let view = KvPanels::paged(
                    nh,
                    dh,
                    kv.len(),
                    page,
                    blobs.iter().map(|(k, v)| (k.as_slice(), v.as_slice())).collect(),
                );
                for mask in [None, Some(nk.saturating_sub(nq))] {
                    let mut dense = vec![0f32; nq * d];
                    attn_panels_with(&q, d, 0, nq, &kv, mask, &mut dense, level);
                    let mut paged = vec![0f32; nq * d];
                    attn_panels_paged_with(&q, d, 0, nq, &view, mask, &mut paged, level);
                    assert_eq!(
                        dense, paged,
                        "nh={nh} dh={dh} nk={nk} nq={nq} page={page} mask={mask:?}"
                    );
                    let mut scalar = vec![0f32; nq * d];
                    attn_panels_paged_with(
                        &q,
                        d,
                        0,
                        nq,
                        &view,
                        mask,
                        &mut scalar,
                        SimdLevel::Scalar,
                    );
                    assert_eq!(paged, scalar, "paged scalar/simd split page={page}");
                }
            }
        }
    }

    #[test]
    fn paged_threaded_attention_is_bit_identical_to_dense_serial() {
        let mut rng = Rng::new(8);
        let (nh, dh, nk, nq) = (4usize, 16usize, 64usize, 16usize);
        let d = nh * dh;
        let kv = filled_panels(&mut rng, nh, dh, nk);
        let q = rand_vec(&mut rng, nq * d);
        let page = 12; // off the LANES grid, partial tail page
        let blobs = page_blobs(&kv, page);
        let view = KvPanels::paged(
            nh,
            dh,
            kv.len(),
            page,
            blobs.iter().map(|(k, v)| (k.as_slice(), v.as_slice())).collect(),
        );
        for mask in [None, Some(nk - nq)] {
            let mut serial = vec![0f32; nq * d];
            attn_panels(&q, d, 0, nq, &kv, mask, &mut serial);
            for threads in [1usize, 2, 4, 9] {
                let mut par = vec![0f32; nq * d];
                attn_panels_paged_threaded(&q, d, 0, nq, &view, mask, &mut par, threads);
                assert_eq!(serial, par, "threads={threads} mask={mask:?}");
            }
        }
    }

    #[test]
    fn strided_queries_match_contiguous_queries() {
        // Reading queries out of a wider matrix (the fused-QKV output)
        // must equal reading them from a dense [nq, d] copy.
        let mut rng = Rng::new(5);
        let (nh, dh, nk, nq) = (2usize, 4usize, 5usize, 3usize);
        let d = nh * dh;
        let kv = filled_panels(&mut rng, nh, dh, nk);
        let wide = rand_vec(&mut rng, nq * 3 * d);
        let mut dense = vec![0f32; nq * d];
        for r in 0..nq {
            dense[r * d..(r + 1) * d].copy_from_slice(&wide[r * 3 * d..r * 3 * d + d]);
        }
        let mut ctx_wide = vec![0f32; nq * d];
        let mut ctx_dense = vec![0f32; nq * d];
        attn_panels(&wide, 3 * d, 0, nq, &kv, Some(1), &mut ctx_wide);
        attn_panels(&dense, d, 0, nq, &kv, Some(1), &mut ctx_dense);
        assert_eq!(ctx_wide, ctx_dense);
    }
}
