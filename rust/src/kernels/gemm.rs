//! Packed, blocked, register-tiled GEMM with a fused bias term, running
//! on the wide-lane SIMD layer.
//!
//! Weights are re-laid-out **once** (at model load) into column panels:
//! panel `p` covers output columns `[p·TILE_COLS, (p+1)·TILE_COLS)` and
//! stores them k-major, so the hot loop streams one contiguous
//! `TILE_COLS`-wide row of weights per `k` while broadcasting a handful
//! of activations. `TILE_COLS` equals the SIMD lane width
//! ([`crate::kernels::simd::LANES`]): each accumulator strip is exactly
//! one vector register, updated by a broadcast-activation ×
//! packed-panel-row lane op per `k`. The micro-kernel dispatches at
//! runtime between the AVX2 intrinsic backend and the portable
//! [`F32Lanes`] fallback (see [`crate::kernels::simd`]); the tail panel
//! and its bias strip are zero-padded so both paths stay branch-light
//! (padded lanes accumulate exact zeros and are never stored).
//!
//! Determinism: every output element is `bias[o] + Σ_k x[r,k]·w[k,o]`
//! with `k` ascending and two roundings per term, independent of row
//! blocking, column tiling, thread partitioning **and SIMD dispatch
//! level** — the lanes run across output columns only, never across the
//! `k` reduction. See [`crate::kernels`] module docs and
//! `rust/tests/kernel_parity.rs`.

use crate::kernels::simd::{self, F32Lanes, SimdLevel, LANES};
use crate::kernels::threads;
use crate::trace::Phase;
use crate::trace_span;

/// Output-column tile width (one register strip of accumulators). Must
/// equal the SIMD lane width.
pub const TILE_COLS: usize = LANES;
/// Rows processed per micro-kernel invocation (activation broadcast reuse).
const TILE_ROWS: usize = 4;

/// A pre-packed dense layer `y = x·W + b` (`W: [din, dout]`, row-major
/// input `x: [n, din]`).
#[derive(Debug, Clone)]
pub struct PackedLinear {
    din: usize,
    dout: usize,
    /// `ceil(dout / TILE_COLS)` column panels, each `[din, TILE_COLS]`
    /// k-major, the tail panel zero-padded.
    panels: Vec<f32>,
    /// Bias padded to the panel grid (`panels.len() / din` strips of
    /// `TILE_COLS`, tail zero-padded) so accumulator init is one lane
    /// load per panel.
    bias_pad: Vec<f32>,
}

impl PackedLinear {
    /// Pack a row-major `[din, dout]` weight matrix plus its bias.
    pub fn pack(w: &[f32], din: usize, dout: usize, bias: &[f32]) -> PackedLinear {
        assert_eq!(w.len(), din * dout, "weight shape mismatch");
        assert_eq!(bias.len(), dout, "bias shape mismatch");
        let np = dout.div_ceil(TILE_COLS);
        let mut panels = vec![0f32; np * din * TILE_COLS];
        for p in 0..np {
            for k in 0..din {
                for j in 0..TILE_COLS {
                    let o = p * TILE_COLS + j;
                    if o < dout {
                        panels[(p * din + k) * TILE_COLS + j] = w[k * dout + o];
                    }
                }
            }
        }
        let mut bias_pad = vec![0f32; np * TILE_COLS];
        bias_pad[..dout].copy_from_slice(bias);
        PackedLinear {
            din,
            dout,
            panels,
            bias_pad,
        }
    }

    /// Pack several projections over the same input as **one** fused
    /// matrix, concatenated along the output dimension (the QKV trick:
    /// one packed GEMM over `wq|wk|wv` instead of three small ones).
    /// `ws[i]` is row-major `[din, douts[i]]`.
    pub fn pack_fused(
        ws: &[&[f32]],
        biases: &[&[f32]],
        din: usize,
        douts: &[usize],
    ) -> PackedLinear {
        assert_eq!(ws.len(), douts.len());
        assert_eq!(biases.len(), douts.len());
        let dout: usize = douts.iter().sum();
        let mut w = vec![0f32; din * dout];
        let mut b = vec![0f32; dout];
        let mut off = 0usize;
        for ((wi, bi), &doi) in ws.iter().zip(biases).zip(douts) {
            assert_eq!(wi.len(), din * doi, "fused part shape mismatch");
            assert_eq!(bi.len(), doi, "fused bias shape mismatch");
            for k in 0..din {
                w[k * dout + off..k * dout + off + doi]
                    .copy_from_slice(&wi[k * doi..(k + 1) * doi]);
            }
            b[off..off + doi].copy_from_slice(bi);
            off += doi;
        }
        PackedLinear::pack(&w, din, dout, &b)
    }

    pub fn din(&self) -> usize {
        self.din
    }

    pub fn dout(&self) -> usize {
        self.dout
    }

    /// `y = x·W + b` over `n` rows, allocated fresh.
    pub fn apply(&self, x: &[f32], n: usize, threads: usize) -> Vec<f32> {
        let mut y = vec![0f32; n * self.dout];
        self.apply_into(x, n, &mut y, threads);
        y
    }

    /// `y = x·W + b` into a caller-provided buffer, at the process-wide
    /// SIMD dispatch level. Rows are partitioned across up to `threads`
    /// persistent-pool lanes once the call clears the adaptive
    /// [`threads::par_min_macs`] gate; results are bit-identical at any
    /// thread count and dispatch level.
    pub fn apply_into(&self, x: &[f32], n: usize, y: &mut [f32], threads: usize) {
        crate::faults::fire_infallible("kernel.gemm");
        self.apply_into_with(x, n, y, threads, simd::simd_level());
    }

    /// [`PackedLinear::apply_into`] with an explicit SIMD dispatch level
    /// — the bench / property-test hook for comparing backends.
    pub fn apply_into_with(
        &self,
        x: &[f32],
        n: usize,
        y: &mut [f32],
        threads: usize,
        level: SimdLevel,
    ) {
        assert_eq!(x.len(), n * self.din, "input shape mismatch");
        assert_eq!(y.len(), n * self.dout, "output shape mismatch");
        let _sp = trace_span!(Phase::Gemm, (n * self.din * self.dout) as u64);
        let par = threads > 1 && n > 1 && n * self.din * self.dout >= threads::par_min_macs();
        if !par {
            self.apply_serial(x, n, y, level);
            return;
        }
        let rows_per = n.div_ceil(threads.min(n));
        let mut parts: Vec<(&[f32], &mut [f32])> = Vec::new();
        for (ci, chunk) in y.chunks_mut(rows_per * self.dout).enumerate() {
            let rows = chunk.len() / self.dout;
            parts.push((&x[ci * rows_per * self.din..][..rows * self.din], chunk));
        }
        let n_parts = parts.len();
        threads::for_each_partitioned(&mut parts, n_parts, |p| {
            let rows = p.1.len() / self.dout;
            self.apply_serial(p.0, rows, p.1, level);
        });
    }

    /// Dispatch the serial micro-kernel by SIMD level. A requested
    /// `Avx2` is re-checked against the CPU (`SimdLevel` is a plain
    /// public enum, so the level alone is no proof of support) and
    /// falls back to the portable lanes when unavailable.
    fn apply_serial(&self, x: &[f32], n: usize, y: &mut [f32], level: SimdLevel) {
        match level {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: guarded by runtime detection.
            SimdLevel::Avx2 if simd::avx2_available() => unsafe {
                self.apply_serial_avx2(x, n, y)
            },
            SimdLevel::Avx2 => self.apply_serial_lanes(x, n, y),
            SimdLevel::Scalar => self.apply_serial_lanes(x, n, y),
        }
    }

    /// The blocked micro-kernel on portable lanes: `TILE_ROWS` vector
    /// accumulators (one `TILE_COLS`-wide strip each), bias fused into
    /// the accumulator init, `k` ascending.
    fn apply_serial_lanes(&self, x: &[f32], n: usize, y: &mut [f32]) {
        let (din, dout) = (self.din, self.dout);
        let mut r = 0usize;
        while r < n {
            let mr = TILE_ROWS.min(n - r);
            for (p, panel) in self.panels.chunks_exact(din * TILE_COLS).enumerate() {
                let o0 = p * TILE_COLS;
                let oc = TILE_COLS.min(dout - o0);
                let binit = F32Lanes::load(&self.bias_pad[o0..o0 + LANES]);
                let mut acc = [binit; TILE_ROWS];
                for (k, wrow) in panel.chunks_exact(TILE_COLS).enumerate() {
                    let wl = F32Lanes::load(wrow);
                    for (ri, a) in acc.iter_mut().take(mr).enumerate() {
                        *a = a.mul_then_add(F32Lanes::splat(x[(r + ri) * din + k]), wl);
                    }
                }
                for (ri, a) in acc.iter().take(mr).enumerate() {
                    let yo = (r + ri) * dout + o0;
                    y[yo..yo + oc].copy_from_slice(&a.0[..oc]);
                }
            }
            r += mr;
        }
    }

    /// The same micro-kernel on AVX2 intrinsics — identical arithmetic
    /// per element (broadcast × panel row, `mul` then `add`, `k`
    /// ascending), so bit-identical to [`Self::apply_serial_lanes`].
    ///
    /// # Safety
    /// AVX2 must be available (every dispatch site checks
    /// [`simd::avx2_available`]); `x`/`y` must hold `n` rows of
    /// `din`/`dout` floats.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn apply_serial_avx2(&self, x: &[f32], n: usize, y: &mut [f32]) {
        use crate::kernels::simd::avx2 as v;
        let (din, dout) = (self.din, self.dout);
        let mut r = 0usize;
        while r < n {
            let mr = TILE_ROWS.min(n - r);
            for (p, panel) in self.panels.chunks_exact(din * TILE_COLS).enumerate() {
                let o0 = p * TILE_COLS;
                let oc = TILE_COLS.min(dout - o0);
                let binit = v::load(&self.bias_pad[o0..o0 + LANES]);
                let mut acc = [binit; TILE_ROWS];
                for (k, wrow) in panel.chunks_exact(TILE_COLS).enumerate() {
                    let wl = v::load(wrow);
                    for (ri, a) in acc.iter_mut().take(mr).enumerate() {
                        *a = v::mul_then_add(*a, v::splat(x[(r + ri) * din + k]), wl);
                    }
                }
                if oc == TILE_COLS {
                    for (ri, a) in acc.iter().take(mr).enumerate() {
                        let yo = (r + ri) * dout + o0;
                        v::store(*a, &mut y[yo..yo + LANES]);
                    }
                } else {
                    let mut tmp = [0f32; LANES];
                    for (ri, a) in acc.iter().take(mr).enumerate() {
                        v::store(*a, &mut tmp);
                        let yo = (r + ri) * dout + o0;
                        y[yo..yo + oc].copy_from_slice(&tmp[..oc]);
                    }
                }
            }
            r += mr;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Reference matmul with the exact reduction order the kernel
    /// promises: bias, then k ascending.
    fn naive(x: &[f32], n: usize, w: &[f32], din: usize, dout: usize, b: &[f32]) -> Vec<f32> {
        let mut y = vec![0f32; n * dout];
        for r in 0..n {
            for o in 0..dout {
                let mut acc = b[o];
                for k in 0..din {
                    acc += x[r * din + k] * w[k * dout + o];
                }
                y[r * dout + o] = acc;
            }
        }
        y
    }

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.f64() as f32 - 0.5) * 2.0).collect()
    }

    #[test]
    fn packed_gemm_is_bit_identical_to_naive_order() {
        let mut rng = Rng::new(0xF00D);
        // Sizes straddling the tile boundaries, including n < TILE_ROWS
        // and dout not a multiple of TILE_COLS.
        for &(n, din, dout) in &[(1usize, 5usize, 3usize), (3, 16, 8), (7, 33, 19), (12, 8, 64)] {
            let w = rand_vec(&mut rng, din * dout);
            let b = rand_vec(&mut rng, dout);
            let x = rand_vec(&mut rng, n * din);
            let packed = PackedLinear::pack(&w, din, dout, &b);
            assert_eq!(packed.din(), din);
            assert_eq!(packed.dout(), dout);
            let y_ref = naive(&x, n, &w, din, dout, &b);
            // Both dispatch levels against the scalar oracle.
            let y = packed.apply(&x, n, 1);
            assert_eq!(y, y_ref, "auto level: n={n} din={din} dout={dout}");
            let mut y_s = vec![0f32; n * dout];
            packed.apply_into_with(&x, n, &mut y_s, 1, SimdLevel::Scalar);
            assert_eq!(y_s, y_ref, "scalar level: n={n} din={din} dout={dout}");
        }
    }

    #[test]
    fn threaded_gemm_is_bit_identical_to_single_thread() {
        let mut rng = Rng::new(0xBEEF);
        // Big enough to clear the adaptive gate's upper clamp
        // (65·64·64 = 266240 > 2^18), with a row count that doesn't
        // divide evenly by the threads.
        let (n, din, dout) = (65usize, 64usize, 64usize);
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let x = rand_vec(&mut rng, n * din);
        let packed = PackedLinear::pack(&w, din, dout, &b);
        let y1 = packed.apply(&x, n, 1);
        for threads in [2usize, 3, 4, 16] {
            let yt = packed.apply(&x, n, threads);
            assert_eq!(y1, yt, "threads={threads} diverged");
        }
        assert_eq!(y1, naive(&x, n, &w, din, dout, &b));
    }

    #[test]
    fn fused_pack_matches_separate_packs() {
        let mut rng = Rng::new(0xABCD);
        let din = 10usize;
        let (d1, d2, d3) = (6usize, 6usize, 4usize);
        let (w1, w2, w3) = (
            rand_vec(&mut rng, din * d1),
            rand_vec(&mut rng, din * d2),
            rand_vec(&mut rng, din * d3),
        );
        let (b1, b2, b3) = (
            rand_vec(&mut rng, d1),
            rand_vec(&mut rng, d2),
            rand_vec(&mut rng, d3),
        );
        let fused = PackedLinear::pack_fused(
            &[&w1, &w2, &w3],
            &[&b1, &b2, &b3],
            din,
            &[d1, d2, d3],
        );
        let n = 5usize;
        let x = rand_vec(&mut rng, n * din);
        let yf = fused.apply(&x, n, 1);
        let y1 = PackedLinear::pack(&w1, din, d1, &b1).apply(&x, n, 1);
        let y2 = PackedLinear::pack(&w2, din, d2, &b2).apply(&x, n, 1);
        let y3 = PackedLinear::pack(&w3, din, d3, &b3).apply(&x, n, 1);
        for r in 0..n {
            assert_eq!(&yf[r * (d1 + d2 + d3)..r * (d1 + d2 + d3) + d1], &y1[r * d1..(r + 1) * d1]);
            assert_eq!(
                &yf[r * (d1 + d2 + d3) + d1..r * (d1 + d2 + d3) + d1 + d2],
                &y2[r * d2..(r + 1) * d2]
            );
            assert_eq!(
                &yf[r * (d1 + d2 + d3) + d1 + d2..(r + 1) * (d1 + d2 + d3)],
                &y3[r * d3..(r + 1) * d3]
            );
        }
    }

    #[test]
    fn batched_rows_match_single_row_calls() {
        // Row independence: the value of row r must not depend on which
        // other rows share the call — the property cross-row batched
        // `extend` (and now batched `encode`) rests on.
        let mut rng = Rng::new(0x5151);
        let (din, dout) = (13usize, 21usize);
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let packed = PackedLinear::pack(&w, din, dout, &b);
        let x = rand_vec(&mut rng, 6 * din);
        let batched = packed.apply(&x, 6, 1);
        for r in 0..6 {
            let solo = packed.apply(&x[r * din..(r + 1) * din], 1, 1);
            assert_eq!(&batched[r * dout..(r + 1) * dout], solo.as_slice(), "row {r}");
        }
    }
}
