//! Wide-lane SIMD abstraction for the compute kernels.
//!
//! Every kernel vectorizes **across output lanes only** — the 8 output
//! columns of a GEMM panel, 8 keys of an attention score row, 8 context
//! dimensions of an AV accumulation — never across a reduction
//! dimension. Each output element therefore keeps the exact scalar
//! reduction order (`k`/`d`/`j` ascending, one rounding per multiply and
//! one per add), so the SIMD and portable paths are **bit-identical by
//! construction**; `rust/tests/kernel_parity.rs` property-tests this.
//!
//! Two backends share the [`LANES`]-wide model:
//!
//! * [`F32Lanes`] — a portable `[f32; LANES]` value type whose ops are
//!   plain per-lane arithmetic. This is the always-available fallback
//!   (and compiles to decent autovectorized code on its own).
//! * [`avx2`] (x86_64 only) — thin `#[target_feature]` wrappers over the
//!   AVX2 `__m256` intrinsics, selected at **runtime** when the CPU
//!   reports `avx2`+`fma` support (see [`simd_level`]).
//!
//! Note the deliberate absence of fused multiply-add anywhere: an FMA
//! rounds once where the scalar contract rounds twice, so
//! [`avx2::mul_then_add`] is an explicit `mul` + `add` pair even though
//! the dispatch requires the `fma` CPU flag (the flag gates the whole
//! modern-x86 feature generation we target, and keeps the door open for
//! kernels that opt out of bit-exactness later).
//!
//! `RXNSPEC_SIMD` overrides detection: `auto` (default) detects, while
//! `off` / `scalar` / `0` force the portable fallback — the knob CI uses
//! to record both dispatch paths in `BENCH_kernels.json`.

use std::sync::OnceLock;

/// Fixed vector width (f32 lanes). Equals one AVX2 `__m256` register and
/// one GEMM output-column tile ([`crate::kernels::gemm::TILE_COLS`]).
pub const LANES: usize = 8;

/// Which micro-kernel backend calls dispatch to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable [`F32Lanes`] fallback (per-lane scalar arithmetic).
    Scalar,
    /// AVX2 intrinsics (x86_64, runtime-detected).
    Avx2,
}

impl SimdLevel {
    /// Short name for logs / bench metric labels.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
        }
    }
}

fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return SimdLevel::Avx2;
        }
    }
    SimdLevel::Scalar
}

/// Process-wide dispatch level, resolved once: `RXNSPEC_SIMD` set to
/// `off` / `scalar` / `0` forces [`SimdLevel::Scalar`]; anything else
/// (including unset / `auto`) runs CPU feature detection.
pub fn simd_level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| match crate::knobs::SIMD.raw() {
        Some(v) if matches!(v.trim(), "off" | "scalar" | "0") => SimdLevel::Scalar,
        _ => detect(),
    })
}

/// True when the AVX2 backend is actually executable on this CPU
/// (independent of any `RXNSPEC_SIMD` override). Every dispatch site
/// re-checks this before entering `#[target_feature]` code, so a
/// caller-supplied [`SimdLevel::Avx2`] — the level is a plain public
/// enum — can never reach the intrinsics on unsupported hardware; it
/// silently falls back to the portable lanes instead.
#[inline]
pub fn avx2_available() -> bool {
    static OK: OnceLock<bool> = OnceLock::new();
    *OK.get_or_init(|| detect() == SimdLevel::Avx2)
}

/// A [`LANES`]-wide f32 vector with portable per-lane ops — the scalar
/// fallback backend, and the reference semantics the AVX2 backend must
/// reproduce bit for bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct F32Lanes(pub [f32; LANES]);

impl F32Lanes {
    #[inline(always)]
    pub fn zero() -> F32Lanes {
        F32Lanes([0.0; LANES])
    }

    #[inline(always)]
    pub fn splat(v: f32) -> F32Lanes {
        F32Lanes([v; LANES])
    }

    /// Load the first [`LANES`] values of `s` (`s.len() >= LANES`).
    #[inline(always)]
    pub fn load(s: &[f32]) -> F32Lanes {
        let mut a = [0f32; LANES];
        a.copy_from_slice(&s[..LANES]);
        F32Lanes(a)
    }

    /// Store into the first [`LANES`] values of `d`.
    #[inline(always)]
    pub fn store(self, d: &mut [f32]) {
        d[..LANES].copy_from_slice(&self.0);
    }

    /// `self + a·b` per lane, rounding the product and the sum
    /// **separately** (two roundings) — the scalar semantics every
    /// kernel's bit-exactness contract is written against. Deliberately
    /// not a fused multiply-add.
    #[inline(always)]
    pub fn mul_then_add(self, a: F32Lanes, b: F32Lanes) -> F32Lanes {
        let mut o = self.0;
        for ((c, &x), &y) in o.iter_mut().zip(&a.0).zip(&b.0) {
            *c += x * y;
        }
        F32Lanes(o)
    }
}

/// AVX2 backend: thin wrappers over `core::arch::x86_64` intrinsics.
/// Callers hold the dispatch proof — [`simd_level`] returned
/// [`SimdLevel::Avx2`] — and are themselves `#[target_feature]`
/// functions, so these inline into them.
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use core::arch::x86_64::{
        __m256, _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };

    use super::LANES;

    /// # Safety
    /// AVX2 must be available (dispatch via [`super::simd_level`]);
    /// `s.len() >= LANES`.
    #[inline]
    #[target_feature(enable = "avx2")]
    pub unsafe fn load(s: &[f32]) -> __m256 {
        debug_assert!(s.len() >= LANES);
        _mm256_loadu_ps(s.as_ptr())
    }

    /// # Safety
    /// AVX2 must be available.
    #[inline]
    #[target_feature(enable = "avx2")]
    pub unsafe fn splat(v: f32) -> __m256 {
        _mm256_set1_ps(v)
    }

    /// `acc + a·b` per lane with **two roundings** (`mul` then `add`,
    /// never `fmadd` — fusing would single-round and break bit parity
    /// with the portable fallback).
    ///
    /// # Safety
    /// AVX2 must be available.
    #[inline]
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_then_add(acc: __m256, a: __m256, b: __m256) -> __m256 {
        _mm256_add_ps(acc, _mm256_mul_ps(a, b))
    }

    /// # Safety
    /// AVX2 must be available; `d.len() >= LANES`.
    #[inline]
    #[target_feature(enable = "avx2")]
    pub unsafe fn store(v: __m256, d: &mut [f32]) {
        debug_assert!(d.len() >= LANES);
        _mm256_storeu_ps(d.as_mut_ptr(), v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn portable_lanes_match_scalar_arithmetic() {
        let a = F32Lanes::load(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let b = F32Lanes::splat(0.5);
        let acc = F32Lanes::zero().mul_then_add(a, b);
        let mut out = [0f32; LANES];
        acc.store(&mut out);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, (i as f32 + 1.0) * 0.5);
        }
    }

    #[test]
    fn level_resolves_and_names() {
        let l = simd_level();
        assert!(matches!(l, SimdLevel::Scalar | SimdLevel::Avx2));
        assert!(!l.name().is_empty());
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_backend_is_bit_identical_to_portable() {
        if simd_level() != SimdLevel::Avx2 {
            return; // CPU (or RXNSPEC_SIMD) rules the backend out
        }
        // acc + a*b over values with inexact products, both backends.
        let acc0 = [0.137f32, -2.5, 3.1, 0.0, -0.625, 9.7, 1e-3, 4.2];
        let av = [1.1f32, -0.3, 2.7, 5.5, -6.1, 0.9, 3.3, -1.7];
        let bv = [0.77f32, 0.13, -4.9, 2.2, 1.01, -8.8, 0.505, 6.6];
        let portable = F32Lanes::load(&acc0)
            .mul_then_add(F32Lanes::load(&av), F32Lanes::load(&bv));
        let mut got = [0f32; LANES];
        // SAFETY: the `simd_level()` guard above proves AVX2+FMA are
        // present, and both arrays are exactly LANES long.
        unsafe {
            let r = avx2::mul_then_add(avx2::load(&acc0), avx2::load(&av), avx2::load(&bv));
            avx2::store(r, &mut got);
        }
        assert_eq!(portable.0, got);
    }
}
