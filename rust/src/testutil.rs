//! Deterministic mock backends and property-test helpers.
//!
//! The offline environment has no `proptest`, so invariants are checked by
//! seeded random-case sweeps over these mocks. Both mocks honour the
//! [`Backend`](crate::decoding::Backend) conditional-consistency contract:
//! a row's successor distribution depends only on its own tokens and its
//! memory row — the property speculative decoding's losslessness rests on.
//!
//! * [`CopyModel`] — the target is a deterministic function of the source
//!   that *contains source substrings verbatim*, modelling the chemistry
//!   regime (products copy reactant fragments) where draft acceptance is
//!   high.
//! * [`HashModel`] — fully content-dependent pseudo-random distributions
//!   (a keyed hash of the entire prefix), modelling the adversarial regime
//!   where drafts are almost never accepted; used to prove equivalences
//!   hold for *any* conditional model, not just friendly ones.

use anyhow::Result;

use crate::decoding::{Backend, DecoderRow, DecoderSession, LogProbs, Memory, ModelDims};
use crate::model::{Config, RustBackend, Tensor, Weights};
use crate::rng::Rng;
use crate::runtime::{CachedPjrtSession, DeccacheCall, DeccacheExec, DeccacheOut};
use crate::vocab::{BOS_ID, EOS_ID, PAD_ID, UNK_ID};

/// Number of reserved special ids; mock vocab tokens start here.
pub const FIRST_REAL_TOKEN: i64 = 4;

fn mem_from_srcs(srcs: &[&[i64]], s_len: usize) -> Memory {
    // Mocks stash raw source tokens in the activation buffer (d_model = 1)
    // so `decode` can recover them per row.
    let batch = srcs.len();
    let mut data = vec![0f32; batch * s_len];
    let mut pad = vec![0f32; batch * s_len];
    for (b, src) in srcs.iter().enumerate() {
        assert!(src.len() <= s_len, "src longer than s_len");
        for (i, &t) in src.iter().enumerate() {
            data[b * s_len + i] = t as f32;
            pad[b * s_len + i] = 1.0;
        }
    }
    Memory {
        data,
        pad,
        batch,
        s_len,
        d_model: 1,
    }
}

fn src_tokens_of_row(memory: &Memory, b: usize) -> Vec<i64> {
    memory
        .row(b)
        .iter()
        .zip(memory.pad_row(b))
        .take_while(|(_, &p)| p > 0.0)
        .map(|(&v, _)| v as i64)
        .collect()
}

/// Fill one position's distribution: `chosen` gets log(p), the rest share
/// the remainder uniformly (a proper log-probability vector).
fn peaked_dist(out: &mut [f32], chosen: i64, p: f64) {
    let v = out.len();
    let rest = ((1.0 - p) / (v as f64 - 3.0)).ln() as f32; // excl. specials
    let neg = -1e9f32;
    for (i, o) in out.iter_mut().enumerate() {
        *o = if i as i64 == PAD_ID || i as i64 == BOS_ID || i as i64 == UNK_ID {
            neg
        } else {
            rest
        };
    }
    out[chosen as usize] = p.ln() as f32;
}

/// A backend whose target sequence is a deterministic function of the
/// source: the source's inner tokens verbatim, followed by EOS. Products
/// copying reactant substrings is exactly the regime the paper exploits.
pub struct CopyModel {
    dims: ModelDims,
    emit_eos: bool,
}

impl CopyModel {
    pub fn new(s_len: usize, t_len: usize, vocab: usize) -> CopyModel {
        CopyModel {
            dims: ModelDims {
                s_len,
                t_len,
                d_model: 1,
                vocab,
            },
            emit_eos: true,
        }
    }

    /// Variant that never emits EOS (cycles over the target) — for testing
    /// window-limit termination.
    pub fn never_eos(s_len: usize, t_len: usize, vocab: usize) -> CopyModel {
        CopyModel {
            dims: ModelDims {
                s_len,
                t_len,
                d_model: 1,
                vocab,
            },
            emit_eos: false,
        }
    }

    /// The target the model deterministically generates for `src`
    /// (BOS/EOS-wrapped), excluding EOS.
    pub fn target_for(&self, src: &[i64]) -> Vec<i64> {
        src.iter()
            .copied()
            .filter(|&t| t != BOS_ID && t != EOS_ID && t != PAD_ID)
            .collect()
    }
}

impl Backend for CopyModel {
    fn dims(&self) -> ModelDims {
        self.dims
    }

    fn encode(&self, srcs: &[&[i64]]) -> Result<Memory> {
        Ok(mem_from_srcs(srcs, self.dims.s_len))
    }

    fn decode(&self, rows: &[DecoderRow], memory: &Memory) -> Result<LogProbs> {
        let (t_len, vocab) = (self.dims.t_len, self.dims.vocab);
        let mut data = vec![0f32; rows.len() * t_len * vocab];
        let mut lens = Vec::with_capacity(rows.len());
        for (r, row) in rows.iter().enumerate() {
            let target = self.target_for(&src_tokens_of_row(memory, row.mem_row));
            let len = row.tokens.len();
            lens.push(len);
            let pad_cols = t_len - len;
            for j in 0..len {
                // Successor of position j is target[j] (position 0 is BOS).
                let chosen = if j < target.len() {
                    target[j]
                } else if self.emit_eos {
                    EOS_ID
                } else {
                    target[j % target.len().max(1)]
                };
                let off = (r * t_len + pad_cols + j) * vocab;
                peaked_dist(&mut data[off..off + vocab], chosen, 0.9);
            }
        }
        Ok(LogProbs::new(data, lens, t_len, vocab))
    }
}

/// A backend with keyed-hash pseudo-random (but deterministic and
/// conditionally consistent) successor distributions.
pub struct HashModel {
    dims: ModelDims,
    key: u64,
    /// Additive EOS bonus per generated position — guarantees termination.
    eos_ramp: f32,
    /// Logit sharpness. ~6 gives high-entropy (adversarial) distributions;
    /// ~40 gives near-one-hot ones — the low-entropy regime the paper says
    /// retrosynthesis models actually operate in (§3.3).
    sharpness: f32,
}

impl HashModel {
    pub fn new(s_len: usize, t_len: usize, vocab: usize, key: u64) -> HashModel {
        HashModel {
            dims: ModelDims {
                s_len,
                t_len,
                d_model: 1,
                vocab,
            },
            key,
            eos_ramp: 0.35,
            sharpness: 6.0,
        }
    }

    /// Low-entropy variant: probability mass concentrates on one token.
    pub fn peaked(s_len: usize, t_len: usize, vocab: usize, key: u64) -> HashModel {
        HashModel {
            sharpness: 40.0,
            eos_ramp: 2.0,
            ..HashModel::new(s_len, t_len, vocab, key)
        }
    }
}

fn fnv(mut h: u64, x: u64) -> u64 {
    h ^= x;
    h = h.wrapping_mul(0x100000001b3);
    h ^ (h >> 29)
}

impl Backend for HashModel {
    fn dims(&self) -> ModelDims {
        self.dims
    }

    fn encode(&self, srcs: &[&[i64]]) -> Result<Memory> {
        Ok(mem_from_srcs(srcs, self.dims.s_len))
    }

    fn decode(&self, rows: &[DecoderRow], memory: &Memory) -> Result<LogProbs> {
        let (t_len, vocab) = (self.dims.t_len, self.dims.vocab);
        let mut data = vec![0f32; rows.len() * t_len * vocab];
        let mut lens = Vec::with_capacity(rows.len());
        for (r, row) in rows.iter().enumerate() {
            let src = src_tokens_of_row(memory, row.mem_row);
            let mut h = fnv(0xcbf29ce484222325 ^ self.key, src.len() as u64);
            for &t in &src {
                h = fnv(h, t as u64);
            }
            let len = row.tokens.len();
            lens.push(len);
            let pad_cols = t_len - len;
            // Prefix hash evolves token by token: the distribution at j
            // depends on tokens 0..=j only.
            let mut ph = h;
            for j in 0..len {
                ph = fnv(ph, row.tokens[j] as u64 + 7);
                let off = (r * t_len + pad_cols + j) * vocab;
                let out = &mut data[off..off + vocab];
                // Raw peaked logits from the hash, then log-softmax.
                let mut mx = f32::NEG_INFINITY;
                for (v, o) in out.iter_mut().enumerate() {
                    let v64 = v as i64;
                    if v64 == PAD_ID || v64 == BOS_ID || v64 == UNK_ID {
                        *o = -1e9;
                        continue;
                    }
                    let u = (fnv(ph, v as u64 + 13) >> 24) as f64 as f32 / (1u64 << 40) as f32;
                    let mut logit = self.sharpness * u;
                    if v64 == EOS_ID {
                        logit += self.eos_ramp * j as f32 - 2.0;
                    }
                    *o = logit;
                    mx = mx.max(logit);
                }
                let mut z = 0f64;
                for &o in out.iter() {
                    if o > -1e8 {
                        z += ((o - mx) as f64).exp();
                    }
                }
                let lz = mx as f64 + z.ln();
                for o in out.iter_mut() {
                    if *o > -1e8 {
                        *o = (*o as f64 - lz) as f32;
                    }
                }
            }
        }
        Ok(LogProbs::new(data, lens, t_len, vocab))
    }
}

/// Recompute a hypothesis's true cumulative log-probability (incl. the
/// final EOS) with fresh single-row decoder calls — the oracle for the
/// "returned scores are real model scores" invariant.
pub fn rescore<B: Backend>(
    backend: &B,
    src: &[i64],
    tokens: &[i64],
    ends_with_eos: bool,
) -> f64 {
    let mem = backend.encode(&[src]).unwrap();
    let mut full = vec![BOS_ID];
    full.extend_from_slice(tokens);
    if ends_with_eos {
        full.push(EOS_ID);
    }
    let row = DecoderRow {
        tokens: full.clone(),
        mem_row: 0,
    };
    let lp = backend.decode(&[row], &mem).unwrap();
    (0..full.len() - 1)
        .map(|j| lp.logp(0, j, full[j + 1]) as f64)
        .sum()
}

/// Random BOS/EOS-wrapped source of inner length in `[min_len, max_len]`,
/// token ids in `[FIRST_REAL_TOKEN, vocab)`.
pub fn random_wrapped_src(rng: &mut Rng, min_len: usize, max_len: usize, vocab: usize) -> Vec<i64> {
    let len = rng.range(min_len, max_len);
    let mut src = vec![BOS_ID];
    for _ in 0..len {
        src.push(rng.range(FIRST_REAL_TOKEN as usize, vocab - 1) as i64);
    }
    src.push(EOS_ID);
    src
}

/// Delegating wrapper that **suppresses** a backend's cache-aware session
/// override: it forwards `dims`/`encode`/`decode` but inherits the
/// default [`Backend::begin`], so every decode goes through the
/// stateless-recompute [`StatelessSession`](crate::decoding::StatelessSession).
/// The oracle side of the cached-vs-stateless parity property tests.
pub struct ForceStateless<'a, B: Backend>(pub &'a B);

impl<B: Backend> Backend for ForceStateless<'_, B> {
    fn dims(&self) -> ModelDims {
        self.0.dims()
    }

    fn encode(&self, srcs: &[&[i64]]) -> Result<Memory> {
        self.0.encode(srcs)
    }

    fn decode(&self, rows: &[DecoderRow], memory: &Memory) -> Result<LogProbs> {
        self.0.decode(rows, memory)
    }
    // No `begin` override: the default StatelessSession applies.
}

/// Reference-kernel [`DeccacheExec`]: mirrors the `deccache` artifact
/// semantics with [`RustBackend::deccache_apply`], including the
/// device-resident output retention the real PJRT executor performs —
/// `kv_host: None` calls are served from the previous call's retained
/// caches, so parity tests exercise the session's buffer-reuse path too.
///
/// Because `deccache_apply` runs the exact kernels the reference
/// `CachedSession` runs, a [`CachedPjrtSession`] driven by this executor
/// is **bit-identical** to the stateless oracle — the invariant
/// `rust/tests/session_parity.rs` holds for the PJRT session machinery.
pub struct RefDeccacheExec<'a> {
    backend: &'a RustBackend,
    grid: Vec<(usize, usize)>,
    retained: std::cell::RefCell<Option<(Vec<f32>, Vec<f32>, usize)>>,
}

impl<'a> RefDeccacheExec<'a> {
    pub fn new(backend: &'a RustBackend, grid: Vec<(usize, usize)>) -> RefDeccacheExec<'a> {
        RefDeccacheExec {
            backend,
            grid,
            retained: std::cell::RefCell::new(None),
        }
    }
}

impl DeccacheExec for RefDeccacheExec<'_> {
    fn dims(&self) -> ModelDims {
        Backend::dims(self.backend)
    }

    fn n_layers(&self) -> usize {
        self.backend.config().n_dec
    }

    fn grid(&self) -> Vec<(usize, usize)> {
        self.grid.clone()
    }

    fn run(&self, call: DeccacheCall<'_>) -> Result<DeccacheOut> {
        let (mut k, mut v) = match call.kv_host {
            Some((k, v)) => (k, v),
            None => {
                let retained = self.retained.borrow_mut().take();
                let (k, v, eb) = retained.expect("kv reuse without retained caches");
                assert_eq!(eb, call.eb, "kv reuse across EB buckets");
                (k, v)
            }
        };
        let logp = self.backend.deccache_apply(
            call.w,
            call.eb,
            &call.tgt,
            &call.pos,
            &call.tgt_pad,
            &call.cache_len,
            &mut k,
            &mut v,
            call.mem,
            call.mem_rows,
        )?;
        let out = DeccacheOut {
            logp,
            k_cache: k.clone(),
            v_cache: v.clone(),
            device_resident: true,
        };
        *self.retained.borrow_mut() = Some((k, v, call.eb));
        Ok(out)
    }
}

/// Backend wrapper that decodes through the **PJRT cached-session
/// machinery** (`runtime::deccache::CachedPjrtSession`) with the
/// reference executor standing in for real artifacts — the stand-in the
/// parity tests and the `kernel_micro` bench use to measure/verify the
/// deccache path offline. `dims`/`encode`/`decode` delegate to the
/// wrapped reference backend.
pub struct DeccacheHarness<'a> {
    backend: &'a RustBackend,
    grid: Vec<(usize, usize)>,
}

impl<'a> DeccacheHarness<'a> {
    /// Default grid mirrors aot.py's: windows {1, 4, 8, 16} (clamped to
    /// t_len) × effective batches {1, 2, 4, 8, 16}.
    pub fn new(backend: &'a RustBackend) -> DeccacheHarness<'a> {
        let t_len = backend.config().t_len;
        let mut grid = Vec::new();
        for w in [1usize, 4, 8, 16] {
            if w > t_len {
                continue;
            }
            for eb in [1usize, 2, 4, 8, 16] {
                grid.push((w, eb));
            }
        }
        DeccacheHarness { backend, grid }
    }

    pub fn with_grid(backend: &'a RustBackend, grid: Vec<(usize, usize)>) -> DeccacheHarness<'a> {
        DeccacheHarness { backend, grid }
    }

    /// The concrete cached session (tests reach `kv_uploads_skipped`).
    pub fn begin_cached(&self, memory: Memory) -> CachedPjrtSession<RefDeccacheExec<'a>> {
        CachedPjrtSession::new(RefDeccacheExec::new(self.backend, self.grid.clone()), memory)
    }

    /// The concrete cached session with an explicit arena mode (`None`
    /// forces the dense mirror path), bypassing `RXNSPEC_ARENA` — tests
    /// drive paged and dense sessions side by side without racing on
    /// process-global env vars.
    pub fn begin_cached_with(
        &self,
        memory: Memory,
        arena: Option<crate::decoding::ArenaConfig>,
    ) -> CachedPjrtSession<RefDeccacheExec<'a>> {
        CachedPjrtSession::with_arena(
            RefDeccacheExec::new(self.backend, self.grid.clone()),
            memory,
            arena,
        )
    }
}

impl Backend for DeccacheHarness<'_> {
    fn dims(&self) -> ModelDims {
        Backend::dims(self.backend)
    }

    fn encode(&self, srcs: &[&[i64]]) -> Result<Memory> {
        self.backend.encode(srcs)
    }

    fn decode(&self, rows: &[DecoderRow], memory: &Memory) -> Result<LogProbs> {
        self.backend.decode(rows, memory)
    }

    fn begin(&self, memory: Memory) -> Result<Box<dyn DecoderSession + '_>> {
        Ok(Box::new(self.begin_cached(memory)))
    }
}

/// A tiny reference transformer with seeded-random weights, built fully
/// in memory. Small dims keep the scalar reference code fast enough for
/// property sweeps; the *shape* of computation (multi-head attention,
/// pre-LN blocks, cross-attention, log-softmax head) is the real one, so
/// parity between its cached and stateless sessions exercises every
/// layer of the incremental path.
pub fn random_rust_backend(seed: u64, vocab: usize, s_len: usize, t_len: usize) -> RustBackend {
    random_rust_backend_cfg(
        seed,
        Config {
            vocab,
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            n_enc: 1,
            n_dec: 2,
            s_len,
            t_len,
        },
    )
}

/// [`random_rust_backend`] with explicit dimensions — the kernel-layer
/// benches and threading-parity tests use larger configs so the GEMM /
/// attention partitioners actually engage.
pub fn random_rust_backend_cfg(seed: u64, cfg: Config) -> RustBackend {
    fn rand_t(name: &str, dims: Vec<usize>, scale: f32, rng: &mut Rng) -> (String, Tensor) {
        let n: usize = dims.iter().product();
        let data: Vec<f32> = (0..n)
            .map(|_| (rng.f64() as f32 - 0.5) * 2.0 * scale)
            .collect();
        (name.to_string(), Tensor { dims, data })
    }
    fn ln_t(name: &str, d: usize, one: bool) -> (String, Tensor) {
        (
            name.to_string(),
            Tensor {
                dims: vec![d],
                data: vec![if one { 1.0 } else { 0.0 }; d],
            },
        )
    }
    fn attn(prefix: &str, d: usize, tensors: &mut Vec<(String, Tensor)>, rng: &mut Rng) {
        for w in ["wq", "wk", "wv", "wo"] {
            tensors.push(rand_t(&format!("{prefix}.{w}"), vec![d, d], 0.3, rng));
        }
        for b in ["bq", "bk", "bv", "bo"] {
            tensors.push(rand_t(&format!("{prefix}.{b}"), vec![d], 0.05, rng));
        }
    }
    let mut rng = Rng::new(seed ^ 0x5EED_CAFE);
    let mut tensors: Vec<(String, Tensor)> = Vec::new();
    let d = cfg.d_model;
    tensors.push(rand_t("tok_emb", vec![cfg.vocab, d], 0.5, &mut rng));
    tensors.push(rand_t("out_w", vec![d, cfg.vocab], 0.5, &mut rng));
    tensors.push(rand_t("out_b", vec![cfg.vocab], 0.1, &mut rng));
    tensors.push(ln_t("enc_ln_f.g", d, true));
    tensors.push(ln_t("enc_ln_f.b", d, false));
    tensors.push(ln_t("dec_ln_f.g", d, true));
    tensors.push(ln_t("dec_ln_f.b", d, false));
    for i in 0..cfg.n_enc {
        for ln in ["ln1", "ln2"] {
            tensors.push(ln_t(&format!("enc{i}.{ln}.g"), d, true));
            tensors.push(ln_t(&format!("enc{i}.{ln}.b"), d, false));
        }
        attn(&format!("enc{i}.attn"), d, &mut tensors, &mut rng);
        tensors.push(rand_t(&format!("enc{i}.ffn.w1"), vec![d, cfg.d_ff], 0.3, &mut rng));
        tensors.push(rand_t(&format!("enc{i}.ffn.b1"), vec![cfg.d_ff], 0.1, &mut rng));
        tensors.push(rand_t(&format!("enc{i}.ffn.w2"), vec![cfg.d_ff, d], 0.3, &mut rng));
        tensors.push(rand_t(&format!("enc{i}.ffn.b2"), vec![d], 0.1, &mut rng));
    }
    for i in 0..cfg.n_dec {
        for ln in ["ln1", "ln2", "ln3"] {
            tensors.push(ln_t(&format!("dec{i}.{ln}.g"), d, true));
            tensors.push(ln_t(&format!("dec{i}.{ln}.b"), d, false));
        }
        attn(&format!("dec{i}.self_attn"), d, &mut tensors, &mut rng);
        attn(&format!("dec{i}.cross_attn"), d, &mut tensors, &mut rng);
        tensors.push(rand_t(&format!("dec{i}.ffn.w1"), vec![d, cfg.d_ff], 0.3, &mut rng));
        tensors.push(rand_t(&format!("dec{i}.ffn.b1"), vec![cfg.d_ff], 0.1, &mut rng));
        tensors.push(rand_t(&format!("dec{i}.ffn.w2"), vec![cfg.d_ff, d], 0.3, &mut rng));
        tensors.push(rand_t(&format!("dec{i}.ffn.b2"), vec![d], 0.1, &mut rng));
    }
    let weights = Weights::from_tensors(tensors);
    RustBackend::from_weights(&weights, cfg).expect("random backend assembly")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_model_is_conditionally_consistent() {
        // The distribution at position j must be identical whether the row
        // is decoded alone or alongside other rows / with a longer tail.
        let m = CopyModel::new(32, 32, 20);
        let src: Vec<i64> = vec![BOS_ID, 10, 11, 12, EOS_ID];
        let mem = m.encode(&[&src]).unwrap();
        let short = DecoderRow {
            tokens: vec![BOS_ID, 10],
            mem_row: 0,
        };
        let long = DecoderRow {
            tokens: vec![BOS_ID, 10, 11, 12],
            mem_row: 0,
        };
        let lp1 = m.decode(&[short.clone()], &mem).unwrap();
        let lp2 = m.decode(&[long, short], &mem).unwrap();
        for v in 0..20 {
            assert_eq!(lp1.logp(0, 1, v), lp2.logp(1, 1, v));
        }
    }

    #[test]
    fn hash_model_is_conditionally_consistent() {
        let m = HashModel::new(32, 32, 24, 42);
        let src: Vec<i64> = vec![BOS_ID, 9, 8, 7, 6, EOS_ID];
        let mem = m.encode(&[&src]).unwrap();
        let a = DecoderRow {
            tokens: vec![BOS_ID, 5, 6],
            mem_row: 0,
        };
        let b = DecoderRow {
            tokens: vec![BOS_ID, 5, 6, 9, 9, 9],
            mem_row: 0,
        };
        let lp_a = m.decode(&[a], &mem).unwrap();
        let lp_b = m.decode(&[b], &mem).unwrap();
        for j in 0..3 {
            for v in 0..24 {
                assert!(
                    (lp_a.logp(0, j, v) - lp_b.logp(0, j, v)).abs() < 1e-6,
                    "mismatch at j={j} v={v}"
                );
            }
        }
    }

    #[test]
    fn hash_model_distributions_are_normalized() {
        let m = HashModel::new(32, 32, 24, 7);
        let src: Vec<i64> = vec![BOS_ID, 4, 5, EOS_ID];
        let mem = m.encode(&[&src]).unwrap();
        let row = DecoderRow {
            tokens: vec![BOS_ID, 4],
            mem_row: 0,
        };
        let lp = m.decode(&[row], &mem).unwrap();
        for j in 0..2 {
            let s: f64 = (0..24)
                .map(|v| (lp.logp(0, j, v) as f64).exp())
                .sum();
            assert!((s - 1.0).abs() < 1e-4, "sum {s} at j={j}");
        }
    }

    #[test]
    fn hash_model_never_prefers_specials() {
        let m = HashModel::new(32, 32, 24, 3);
        let mut rng = Rng::new(2);
        let src = random_wrapped_src(&mut rng, 4, 10, 24);
        let mem = m.encode(&[&src]).unwrap();
        let row = DecoderRow {
            tokens: vec![BOS_ID, 6, 7, 8],
            mem_row: 0,
        };
        let lp = m.decode(&[row], &mem).unwrap();
        for j in 0..4 {
            let am = lp.argmax(0, j);
            assert!(am != PAD_ID && am != BOS_ID && am != UNK_ID);
        }
    }

    #[test]
    fn different_memory_rows_give_different_distributions() {
        let m = HashModel::new(32, 32, 24, 5);
        let s1: Vec<i64> = vec![BOS_ID, 10, 11, EOS_ID];
        let s2: Vec<i64> = vec![BOS_ID, 12, 13, EOS_ID];
        let mem = m.encode(&[&s1, &s2]).unwrap();
        let rows = vec![
            DecoderRow {
                tokens: vec![BOS_ID, 4],
                mem_row: 0,
            },
            DecoderRow {
                tokens: vec![BOS_ID, 4],
                mem_row: 1,
            },
        ];
        let lp = m.decode(&rows, &mem).unwrap();
        let d0: Vec<f32> = (0..24).map(|v| lp.logp(0, 1, v)).collect();
        let d1: Vec<f32> = (0..24).map(|v| lp.logp(1, 1, v)).collect();
        assert_ne!(d0, d1);
    }
}
