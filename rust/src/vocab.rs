//! Token vocabulary shared across the stack.
//!
//! Built once by `gen-data` from the training corpus, written to
//! `data/vocab.txt` (one token per line, line number = id), and consumed by
//! the Python trainer / AOT pipeline and the Rust runtime. The encoder and
//! decoder share one dictionary, as in the paper (Appendix A).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::chem::tokenizer::tokenize;

/// Reserved special-token ids. These are fixed by convention so both the
/// Python and Rust sides can hard-code them.
pub const PAD_ID: i64 = 0;
pub const BOS_ID: i64 = 1;
pub const EOS_ID: i64 = 2;
pub const UNK_ID: i64 = 3;

pub const PAD_TOK: &str = "<pad>";
pub const BOS_TOK: &str = "<bos>";
pub const EOS_TOK: &str = "<eos>";
pub const UNK_TOK: &str = "<unk>";

/// Bidirectional token ↔ id mapping.
#[derive(Debug, Clone)]
pub struct Vocab {
    id_to_tok: Vec<String>,
    tok_to_id: HashMap<String, i64>,
}

impl Vocab {
    /// Build from an iterator of corpus strings (SMILES). Tokens are sorted
    /// lexicographically for determinism; specials occupy ids 0..4.
    pub fn build<'a, I: IntoIterator<Item = &'a str>>(corpus: I) -> Result<Vocab> {
        let mut set = std::collections::BTreeSet::new();
        for s in corpus {
            for t in tokenize(s).with_context(|| format!("building vocab from {s:?}"))? {
                set.insert(t);
            }
        }
        let mut id_to_tok: Vec<String> = vec![
            PAD_TOK.to_string(),
            BOS_TOK.to_string(),
            EOS_TOK.to_string(),
            UNK_TOK.to_string(),
        ];
        id_to_tok.extend(set);
        Ok(Self::from_tokens(id_to_tok))
    }

    fn from_tokens(id_to_tok: Vec<String>) -> Vocab {
        let tok_to_id = id_to_tok
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as i64))
            .collect();
        Vocab { id_to_tok, tok_to_id }
    }

    /// Number of entries including specials.
    pub fn len(&self) -> usize {
        self.id_to_tok.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id_to_tok.is_empty()
    }

    /// Id for a token; `UNK_ID` for unknown tokens.
    pub fn id(&self, tok: &str) -> i64 {
        *self.tok_to_id.get(tok).unwrap_or(&UNK_ID)
    }

    /// Token for an id (panics on out-of-range: that is a programming error,
    /// model logits are always sized to the vocab).
    pub fn tok(&self, id: i64) -> &str {
        &self.id_to_tok[id as usize]
    }

    /// Encode a SMILES string to ids (no BOS/EOS added).
    pub fn encode(&self, smiles: &str) -> Result<Vec<i64>> {
        Ok(tokenize(smiles)?.iter().map(|t| self.id(t)).collect())
    }

    /// Encode with BOS/EOS wrapping.
    pub fn encode_wrapped(&self, smiles: &str) -> Result<Vec<i64>> {
        let mut ids = vec![BOS_ID];
        ids.extend(self.encode(smiles)?);
        ids.push(EOS_ID);
        Ok(ids)
    }

    /// Decode ids to a SMILES string, stopping at EOS and skipping
    /// PAD/BOS/EOS.
    pub fn decode(&self, ids: &[i64]) -> String {
        let mut s = String::new();
        for &id in ids {
            if id == EOS_ID {
                break;
            }
            if id == PAD_ID || id == BOS_ID {
                continue;
            }
            s.push_str(self.tok(id));
        }
        s
    }

    /// Write `vocab.txt`: one token per line, line number == id.
    pub fn save(&self, path: &Path) -> Result<()> {
        let body = self.id_to_tok.join("\n") + "\n";
        std::fs::write(path, body).with_context(|| format!("write {}", path.display()))
    }

    /// Load `vocab.txt`.
    pub fn load(path: &Path) -> Result<Vocab> {
        let body =
            std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        let toks: Vec<String> = body.lines().map(|l| l.to_string()).collect();
        if toks.len() < 4
            || toks[0] != PAD_TOK
            || toks[1] != BOS_TOK
            || toks[2] != EOS_TOK
            || toks[3] != UNK_TOK
        {
            bail!(
                "{} is not a rxnspec vocab file (bad specials header)",
                path.display()
            );
        }
        Ok(Self::from_tokens(toks))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v() -> Vocab {
        Vocab::build(["CCO", "c1ccccc1Br", "[nH]"]).unwrap()
    }

    #[test]
    fn specials_have_fixed_ids() {
        let v = v();
        assert_eq!(v.id(PAD_TOK), PAD_ID);
        assert_eq!(v.id(BOS_TOK), BOS_ID);
        assert_eq!(v.id(EOS_TOK), EOS_ID);
        assert_eq!(v.id(UNK_TOK), UNK_ID);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let v = v();
        let ids = v.encode_wrapped("c1ccccc1Br").unwrap();
        assert_eq!(ids[0], BOS_ID);
        assert_eq!(*ids.last().unwrap(), EOS_ID);
        assert_eq!(v.decode(&ids), "c1ccccc1Br");
    }

    #[test]
    fn unknown_tokens_map_to_unk() {
        let v = v();
        // 'S' never appeared in the build corpus.
        let ids = v.encode("S").unwrap();
        assert_eq!(ids, vec![UNK_ID]);
    }

    #[test]
    fn decode_stops_at_eos() {
        let v = v();
        let c = v.id("C");
        let ids = vec![BOS_ID, c, EOS_ID, c, c];
        assert_eq!(v.decode(&ids), "C");
    }

    #[test]
    fn save_load_roundtrip() {
        let v = v();
        let dir = std::env::temp_dir().join("rxnspec_vocab_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("vocab.txt");
        v.save(&p).unwrap();
        let v2 = Vocab::load(&p).unwrap();
        assert_eq!(v.len(), v2.len());
        for i in 0..v.len() {
            assert_eq!(v.tok(i as i64), v2.tok(i as i64));
        }
    }

    #[test]
    fn build_is_deterministic() {
        let a = Vocab::build(["CCO", "NCC"]).unwrap();
        let b = Vocab::build(["NCC", "CCO"]).unwrap();
        assert_eq!(a.id_to_tok, b.id_to_tok);
    }
}
