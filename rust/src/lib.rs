//! # rxnspec
//!
//! A serving stack for SMILES-to-SMILES chemical reaction transformers with
//! speculative decoding, reproducing *“Accelerating the inference of string
//! generation-based chemical reaction models for industrial applications”*
//! (Andronov et al., 2024).
//!
//! The stack has three layers (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the Rust coordinator: SMILES tokenization, draft
//!   construction, greedy / speculative-greedy / beam / speculative-beam
//!   decoding, a dynamic batcher and TCP serving front end, and the PJRT
//!   runtime that executes AOT-compiled model artifacts. Python is never on
//!   the request path.
//! * **L2** — a JAX Molecular Transformer (`python/compile/model.py`),
//!   trained at build time and lowered to HLO text artifacts.
//! * **L1** — a Pallas fused-attention kernel (`python/compile/kernels/`)
//!   called from L2, validated against a pure-jnp oracle.

pub mod bench;
pub mod cache;
pub mod chem;
pub mod coordinator;
pub mod decoding;
pub mod draft;
pub mod faults;
pub mod kernels;
pub mod knobs;
pub mod lint;
pub mod model;
pub mod planner;
pub mod rng;
pub mod runtime;
pub mod testutil;
pub mod trace;
pub mod vocab;
