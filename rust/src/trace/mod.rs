//! `rxntrace`: span-structured, std-only request tracing.
//!
//! Every hot layer (coordinator, decoders, kernels, arena, PJRT
//! session) opens phase-tagged spans through [`span`] / the
//! [`trace_span!`](crate::trace_span) macro. Each span is a fixed-size
//! [`Event`] (id, parent, phase, start/end ns, u64 payload) pushed into
//! a per-thread ring buffer on drop; a global collector snapshots the
//! rings into Chrome trace-event JSON that Perfetto loads directly.
//!
//! Cost model: when tracing is disabled (the default — gated on
//! `RXNSPEC_TRACE`), a call site is one relaxed atomic load and a
//! branch; no thread-local is touched, no clock is read, and the
//! payload expression inside `trace_span!` is not even evaluated. When
//! enabled, a span costs two monotonic clock reads plus one push under
//! an uncontended per-thread mutex (the mutex is shared only with the
//! snapshot collector, which runs on demand).
//!
//! Threading contract: span *stacks* are thread-local, so parentage is
//! only inferred between spans on one thread — exactly the nesting
//! Perfetto renders per track. Cross-thread work (pool lanes running
//! GEMM panels) appears as root spans on the worker threads, under the
//! wall-clock window of the dispatching span. Overlapping per-request
//! intervals in the continuous-batching loop (many live requests on one
//! worker thread) are recorded via [`record_manual`] onto synthetic
//! per-request tracks instead of the thread stack.
//!
//! The worst-N exemplar store ([`note_request`]) retains the full span
//! window of the slowest requests so a p99 outlier is explainable after
//! the ring has wrapped past it.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::coordinator::lock_ok;

/// Phase tag carried by every span. `name()` strings are the Chrome
/// trace-event `name` field and the README phase glossary — keep the
/// three in sync.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u8)]
pub enum Phase {
    /// Whole request: admission to reply (synthetic per-request track).
    Request = 0,
    /// Queue residency before admission (synthetic per-request track).
    QueueWait,
    /// Coordinator pulling compatible newcomers into a live batch.
    Admission,
    /// One iteration of the continuous-batching decode loop.
    BatchTick,
    /// Source-side encoder forward (cross-row packed).
    Encode,
    /// `Backend::begin` — session construction from encoder memory.
    SessionBegin,
    /// One KV-cached `extend` over the packed delta rows.
    Extend,
    /// Copy-on-write session forks for speculative drafts.
    Fork,
    /// Rolling losing drafts back to the accepted prefix.
    Truncate,
    /// Draft verification: scoring proposals against model argmax.
    Verify,
    /// Packed tile GEMM (payload = MACs).
    Gemm,
    /// Head-blocked attention over panel K/V (payload = work units).
    Attention,
    /// Persistent-pool fork/join dispatch (payload = partitions).
    PoolDispatch,
    /// Arena copy-on-write page unshare (payload = pages copied).
    ArenaCow,
    /// Arena LRU page eviction under `RXNSPEC_KV_BUDGET`.
    ArenaEvict,
    /// Exact-recompute heal of evicted pages (payload = positions).
    ArenaHeal,
    /// `CachedPjrtSession` (W, EB) bucket selection (payload = W).
    BucketRoute,
    /// Host→device KV gather + upload (payload = bytes).
    KvUpload,
    /// Device-buffer KV reuse — the upload that didn't happen.
    KvReuse,
}

/// Number of phases; sizes the per-thread phase-time accumulators.
pub const N_PHASES: usize = 19;

/// Every phase, in discriminant order.
pub const ALL_PHASES: [Phase; N_PHASES] = [
    Phase::Request,
    Phase::QueueWait,
    Phase::Admission,
    Phase::BatchTick,
    Phase::Encode,
    Phase::SessionBegin,
    Phase::Extend,
    Phase::Fork,
    Phase::Truncate,
    Phase::Verify,
    Phase::Gemm,
    Phase::Attention,
    Phase::PoolDispatch,
    Phase::ArenaCow,
    Phase::ArenaEvict,
    Phase::ArenaHeal,
    Phase::BucketRoute,
    Phase::KvUpload,
    Phase::KvReuse,
];

impl Phase {
    /// Stable lowercase name used in trace JSON and docs.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Request => "request",
            Phase::QueueWait => "queue_wait",
            Phase::Admission => "admission",
            Phase::BatchTick => "batch_tick",
            Phase::Encode => "encode",
            Phase::SessionBegin => "session_begin",
            Phase::Extend => "extend",
            Phase::Fork => "fork",
            Phase::Truncate => "truncate",
            Phase::Verify => "verify",
            Phase::Gemm => "gemm",
            Phase::Attention => "attention",
            Phase::PoolDispatch => "pool_dispatch",
            Phase::ArenaCow => "arena_cow",
            Phase::ArenaEvict => "arena_evict",
            Phase::ArenaHeal => "arena_heal",
            Phase::BucketRoute => "bucket_route",
            Phase::KvUpload => "kv_upload",
            Phase::KvReuse => "kv_reuse",
        }
    }
}

/// One completed span. Fixed-size (`Copy`) so ring pushes never
/// allocate.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Process-unique span id (never 0).
    pub id: u64,
    /// Enclosing span's id on the same thread, or 0 for a root span.
    pub parent: u64,
    pub phase: Phase,
    /// Nanoseconds since the process trace epoch.
    pub t_start_ns: u64,
    pub t_end_ns: u64,
    /// Phase-specific magnitude (MACs, bytes, rows, pages…).
    pub payload: u64,
    /// Track id: real thread counter, or a synthetic per-request track
    /// (`TRACK_BASE + n`) for overlapping request/queue-wait intervals.
    pub tid: u64,
}

/// Synthetic-track offset for [`record_manual`] request tracks; keeps
/// them visually separate from real thread tracks in Perfetto.
pub const TRACK_BASE: u64 = 1_000_000;

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process trace epoch (first trace touch).
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

// 0 = uninitialised, 1 = off, 2 = on. Lazily folded from RXNSPEC_TRACE
// so the env read happens once, off the hot path.
static GATE: AtomicU8 = AtomicU8::new(0);

/// Is tracing live? One relaxed load on the hot path.
#[inline]
pub fn enabled() -> bool {
    match GATE.load(Ordering::Relaxed) {
        0 => init_gate(),
        g => g == 2,
    }
}

#[cold]
fn init_gate() -> bool {
    let on = crate::knobs::TRACE
        .raw()
        .map(|v| {
            let v = v.trim().to_ascii_lowercase();
            v == "1" || v == "on" || v == "true" || v == "yes"
        })
        .unwrap_or(false);
    let _ = epoch(); // anchor the clock before any span reads it
    GATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
    on
}

/// Programmatic override of the `RXNSPEC_TRACE` gate (used by
/// `serve --trace`, benches, and tests).
pub fn set_enabled(on: bool) {
    let _ = epoch();
    GATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

fn ring_capacity() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        crate::knobs::TRACE_BUF
            .parsed::<usize>()
            .filter(|&n| n >= 16)
            .unwrap_or(65_536)
    })
}

/// Fixed-capacity overwrite-oldest event buffer; one per thread.
struct Ring {
    buf: Vec<Event>,
    cap: usize,
    head: usize,
    len: usize,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Ring { buf: Vec::new(), cap, head: 0, len: 0, dropped: 0 }
    }

    fn push(&mut self, ev: Event) {
        if self.len < self.cap {
            self.buf.push(ev);
            self.len += 1;
        } else {
            // Full: overwrite the oldest slot and advance.
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events oldest-first.
    fn chrono(&self) -> Vec<Event> {
        let mut out = Vec::with_capacity(self.len);
        out.extend_from_slice(&self.buf[self.head..self.len]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.len = 0;
        self.dropped = 0;
    }
}

fn registry() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static REG: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

struct ThreadTrace {
    ring: Arc<Mutex<Ring>>,
    stack: Vec<u64>,
    phase_ns: [u64; N_PHASES],
    tid: u64,
}

impl ThreadTrace {
    fn register() -> Self {
        let ring = Arc::new(Mutex::new(Ring::new(ring_capacity())));
        lock_ok(registry()).push(Arc::clone(&ring));
        ThreadTrace {
            ring,
            stack: Vec::with_capacity(16),
            phase_ns: [0; N_PHASES],
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        }
    }
}

thread_local! {
    static TT: RefCell<ThreadTrace> = RefCell::new(ThreadTrace::register());
}

/// RAII span guard: records the enclosing span as parent on
/// construction, pushes the completed [`Event`] on drop. Obtained from
/// [`span`] or [`trace_span!`](crate::trace_span).
pub struct TraceScope {
    active: bool,
    id: u64,
    parent: u64,
    phase: Phase,
    t_start_ns: u64,
    payload: u64,
}

impl TraceScope {
    /// Update the payload after the measured work (e.g. accepted draft
    /// tokens, gathered bytes) is known.
    pub fn set_payload(&mut self, payload: u64) {
        self.payload = payload;
    }
}

/// Open a span for `phase`. No-op (no TLS, no clock) when tracing is
/// disabled; prefer [`trace_span!`](crate::trace_span), which also
/// skips payload evaluation.
pub fn span(phase: Phase, payload: u64) -> TraceScope {
    if !enabled() {
        return TraceScope { active: false, id: 0, parent: 0, phase, t_start_ns: 0, payload: 0 };
    }
    let id = next_id();
    let parent = TT
        .try_with(|t| {
            let mut t = t.borrow_mut();
            let p = t.stack.last().copied().unwrap_or(0);
            t.stack.push(id);
            p
        })
        .unwrap_or(0);
    TraceScope { active: true, id, parent, phase, t_start_ns: now_ns(), payload }
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let t_end_ns = now_ns();
        let ev = Event {
            id: self.id,
            parent: self.parent,
            phase: self.phase,
            t_start_ns: self.t_start_ns,
            t_end_ns,
            payload: self.payload,
            tid: 0, // filled from TLS below
        };
        // try_with: the TLS slot may already be torn down during thread
        // exit; losing that tail span is preferable to a panic in drop.
        let _ = TT.try_with(|t| {
            let mut t = t.borrow_mut();
            if t.stack.last() == Some(&self.id) {
                t.stack.pop();
            } else {
                t.stack.retain(|&x| x != self.id);
            }
            t.phase_ns[self.phase as usize] += t_end_ns.saturating_sub(self.t_start_ns);
            let tid = t.tid;
            lock_ok(&t.ring).push(Event { tid, ..ev });
        });
    }
}

/// Open a phase span, skipping even payload evaluation when tracing is
/// off. Bind the result: `let _g = trace_span!(Phase::Gemm, macs);` —
/// the span closes when `_g` drops.
#[macro_export]
macro_rules! trace_span {
    ($phase:expr) => {
        if $crate::trace::enabled() {
            Some($crate::trace::span($phase, 0))
        } else {
            None
        }
    };
    ($phase:expr, $payload:expr) => {
        if $crate::trace::enabled() {
            Some($crate::trace::span($phase, $payload))
        } else {
            None
        }
    };
}

/// Record a completed interval directly, bypassing the thread span
/// stack — for intervals that overlap on one thread (per-request wall
/// time and queue wait in the continuous-batching loop). `track`
/// selects a synthetic tid (`TRACK_BASE + track`) so each request gets
/// its own Perfetto row.
pub fn record_manual(phase: Phase, t_start_ns: u64, t_end_ns: u64, payload: u64, track: u64) {
    if !enabled() {
        return;
    }
    let ev = Event {
        id: next_id(),
        parent: 0,
        phase,
        t_start_ns,
        t_end_ns: t_end_ns.max(t_start_ns),
        payload,
        tid: TRACK_BASE + track,
    };
    let _ = TT.try_with(|t| {
        let t = t.borrow();
        lock_ok(&t.ring).push(ev);
    });
}

/// Cumulative nanoseconds spent per phase *on this thread*; diff two
/// snapshots around a decode call to attribute its wall time. Zeros
/// while tracing is disabled.
pub fn thread_phase_ns() -> [u64; N_PHASES] {
    if !enabled() {
        return [0; N_PHASES];
    }
    TT.try_with(|t| t.borrow().phase_ns).unwrap_or([0; N_PHASES])
}

/// This thread's trace track id (test hook for filtering snapshots).
pub fn current_tid() -> u64 {
    TT.try_with(|t| t.borrow().tid).unwrap_or(0)
}

/// Copy every ring's events, oldest-first per thread, sorted by start
/// time. Non-destructive: the rings keep their contents.
pub fn snapshot_events() -> Vec<Event> {
    let rings: Vec<Arc<Mutex<Ring>>> = lock_ok(registry()).iter().cloned().collect();
    let mut out = Vec::new();
    for r in &rings {
        out.extend(lock_ok(r).chrono());
    }
    out.sort_by_key(|e| (e.t_start_ns, e.id));
    out
}

/// Total events overwritten after their ring filled (coverage caveat
/// for long traces; raise `RXNSPEC_TRACE_BUF`).
pub fn dropped_events() -> u64 {
    let rings: Vec<Arc<Mutex<Ring>>> = lock_ok(registry()).iter().cloned().collect();
    rings.iter().map(|r| lock_ok(r).dropped).sum()
}

/// Empty every ring and the exemplar store (test / re-arm hook).
pub fn clear() {
    let rings: Vec<Arc<Mutex<Ring>>> = lock_ok(registry()).iter().cloned().collect();
    for r in &rings {
        lock_ok(r).clear();
    }
    lock_ok(exemplar_store()).clear();
}

/// A retained worst-case request: its span window plus a snapshot of
/// every event overlapping it, immune to later ring wrap-around.
pub struct Exemplar {
    pub label: String,
    pub t_start_ns: u64,
    pub t_end_ns: u64,
    pub events: Vec<Event>,
}

impl Exemplar {
    pub fn dur_ns(&self) -> u64 {
        self.t_end_ns.saturating_sub(self.t_start_ns)
    }
}

fn exemplar_store() -> &'static Mutex<Vec<Exemplar>> {
    static STORE: OnceLock<Mutex<Vec<Exemplar>>> = OnceLock::new();
    STORE.get_or_init(|| Mutex::new(Vec::new()))
}

fn exemplar_cap() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        crate::knobs::TRACE_EXEMPLARS.parsed_or(4usize)
    })
}

/// Offer a completed request to the worst-N store. If it beats the
/// current floor, the events overlapping `[t_start_ns, t_end_ns]` are
/// snapshotted and retained with it. Cheap rejection first: the ring
/// copy only happens for qualifying requests.
pub fn note_request(label: &str, t_start_ns: u64, t_end_ns: u64) {
    if !enabled() {
        return;
    }
    note_request_with_cap(label, t_start_ns, t_end_ns, exemplar_cap());
}

fn note_request_with_cap(label: &str, t_start_ns: u64, t_end_ns: u64, cap: usize) {
    if cap == 0 {
        return;
    }
    let dur = t_end_ns.saturating_sub(t_start_ns);
    {
        let store = lock_ok(exemplar_store());
        if store.len() >= cap && store.iter().all(|e| e.dur_ns() >= dur) {
            return; // slower than every retained exemplar
        }
    }
    // Snapshot outside the store lock (snapshot takes the registry and
    // ring locks), then insert.
    let events: Vec<Event> = snapshot_events()
        .into_iter()
        .filter(|e| e.t_end_ns >= t_start_ns && e.t_start_ns <= t_end_ns)
        .collect();
    let mut store = lock_ok(exemplar_store());
    store.push(Exemplar { label: label.to_string(), t_start_ns, t_end_ns, events });
    store.sort_by_key(|e| std::cmp::Reverse(e.dur_ns()));
    store.truncate(cap);
}

/// Worst-N exemplars as `(label, start_ns, end_ns, retained events)`,
/// slowest first.
pub fn exemplar_summaries() -> Vec<(String, u64, u64, usize)> {
    lock_ok(exemplar_store())
        .iter()
        .map(|e| (e.label.clone(), e.t_start_ns, e.t_end_ns, e.events.len()))
        .collect()
}

fn push_event_json(out: &mut String, ev: &Event, tid: u64) {
    use std::fmt::Write as _;
    let ts_us = ev.t_start_ns as f64 / 1000.0;
    let dur_us = ev.t_end_ns.saturating_sub(ev.t_start_ns) as f64 / 1000.0;
    let _ = write!(
        out,
        "{{\"name\":\"{}\",\"cat\":\"rxnspec\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\
         \"pid\":1,\"tid\":{},\"args\":{{\"id\":{},\"parent\":{},\"payload\":{}}}}}",
        ev.phase.name(),
        ts_us,
        dur_us,
        tid,
        ev.id,
        ev.parent,
        ev.payload
    );
}

/// Render events (plus retained exemplars on their own tracks) as
/// Chrome trace-event JSON — one line, Perfetto-loadable. Timestamps
/// are microseconds since the trace epoch.
pub fn chrome_trace_json(events: &[Event], exemplars: &[Exemplar]) -> String {
    let mut out = String::with_capacity(128 + events.len() * 160);
    out.push_str("{\"traceEvents\":[");
    let mut first = true;
    for ev in events {
        if !first {
            out.push(',');
        }
        first = false;
        push_event_json(&mut out, ev, ev.tid);
    }
    // Exemplar span trees replay on dedicated tracks so the worst
    // requests stay inspectable after the live rings have wrapped.
    for (i, ex) in exemplars.iter().enumerate() {
        let track = TRACK_BASE * 2 + i as u64;
        if !first {
            out.push(',');
        }
        first = false;
        use std::fmt::Write as _;
        let _ = write!(
            out,
            "{{\"name\":\"exemplar:{}\",\"cat\":\"rxnspec\",\"ph\":\"X\",\"ts\":{:.3},\
             \"dur\":{:.3},\"pid\":1,\"tid\":{},\"args\":{{\"payload\":0}}}}",
            ex.label.replace(['"', '\\'], "_"),
            ex.t_start_ns as f64 / 1000.0,
            ex.dur_ns() as f64 / 1000.0,
            track
        );
        for ev in &ex.events {
            out.push(',');
            push_event_json(&mut out, ev, track);
        }
    }
    out.push_str("]}");
    out
}

/// Snapshot everything recorded so far and render it as Chrome
/// trace-event JSON.
pub fn export_chrome_json() -> String {
    let events = snapshot_events();
    let store = lock_ok(exemplar_store());
    chrome_trace_json(&events, &store)
}

#[cfg(test)]
mod tests {
    use std::sync::MutexGuard;

    use super::*;

    /// Tests that flip the process-global gate serialise here; other
    /// suites run concurrently in the same binary, so assertions below
    /// filter to this thread's own tid.
    pub(crate) fn test_gate() -> MutexGuard<'static, ()> {
        static M: OnceLock<Mutex<()>> = OnceLock::new();
        lock_ok(M.get_or_init(|| Mutex::new(())))
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = test_gate();
        set_enabled(false);
        let my = current_tid();
        let before = snapshot_events().iter().filter(|e| e.tid == my).count();
        {
            let _s = span(Phase::Gemm, 42);
        }
        let after = snapshot_events().iter().filter(|e| e.tid == my).count();
        assert_eq!(before, after);
    }

    #[test]
    fn spans_nest_and_record() {
        let _g = test_gate();
        set_enabled(true);
        let (outer_id, inner_id);
        {
            let s = span(Phase::Extend, 7);
            outer_id = s.id;
            {
                let i = span(Phase::Gemm, 99);
                inner_id = i.id;
            }
        }
        set_enabled(false);
        let evs = snapshot_events();
        let outer = evs.iter().find(|e| e.id == outer_id).expect("outer recorded");
        let inner = evs.iter().find(|e| e.id == inner_id).expect("inner recorded");
        assert_eq!(inner.parent, outer_id);
        assert_eq!(outer.phase, Phase::Extend);
        assert_eq!(inner.phase, Phase::Gemm);
        assert_eq!(inner.payload, 99);
        assert!(inner.t_start_ns >= outer.t_start_ns);
        assert!(inner.t_end_ns <= outer.t_end_ns);
        assert!(outer.parent != outer_id);
    }

    #[test]
    fn phase_accumulator_advances() {
        let _g = test_gate();
        set_enabled(true);
        let before = thread_phase_ns();
        {
            let _s = span(Phase::Verify, 0);
            std::hint::black_box(0u64);
        }
        let after = thread_phase_ns();
        set_enabled(false);
        assert!(after[Phase::Verify as usize] >= before[Phase::Verify as usize]);
        // Drop is not instantaneous-free, but must have added something.
        assert!(after[Phase::Verify as usize] > before[Phase::Verify as usize]);
    }

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let mut r = Ring::new(4);
        for i in 0..6u64 {
            r.push(Event {
                id: i + 1,
                parent: 0,
                phase: Phase::Gemm,
                t_start_ns: i,
                t_end_ns: i + 1,
                payload: 0,
                tid: 1,
            });
        }
        assert_eq!(r.dropped, 2);
        let ids: Vec<u64> = r.chrono().iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![3, 4, 5, 6]);
    }

    #[test]
    fn manual_records_land_on_synthetic_track() {
        let _g = test_gate();
        set_enabled(true);
        record_manual(Phase::Request, 10, 50, 3, 7);
        set_enabled(false);
        let evs = snapshot_events();
        let ev = evs
            .iter()
            .find(|e| e.tid == TRACK_BASE + 7 && e.phase == Phase::Request && e.payload == 3)
            .expect("manual event recorded");
        assert_eq!(ev.t_start_ns, 10);
        assert_eq!(ev.t_end_ns, 50);
    }

    #[test]
    fn exemplar_store_keeps_worst_n() {
        let _g = test_gate();
        set_enabled(true);
        clear();
        for (i, dur) in [50u64, 10, 90, 30, 70].iter().enumerate() {
            note_request_with_cap(&format!("req{i}"), 1000, 1000 + dur, 3);
        }
        set_enabled(false);
        let got = exemplar_summaries();
        let durs: Vec<u64> = got.iter().map(|(_, s, e, _)| e - s).collect();
        assert_eq!(durs, vec![90, 70, 50]);
        clear();
    }

    #[test]
    fn chrome_json_is_valid_and_shaped() {
        let evs = [
            Event {
                id: 1,
                parent: 0,
                phase: Phase::Encode,
                t_start_ns: 1_000,
                t_end_ns: 5_500,
                payload: 2,
                tid: 1,
            },
            Event {
                id: 2,
                parent: 1,
                phase: Phase::Gemm,
                t_start_ns: 2_000,
                t_end_ns: 3_000,
                payload: 64,
                tid: 1,
            },
        ];
        let s = chrome_trace_json(&evs, &[]);
        assert!(!s.contains('\n'), "TRACE replies must stay single-line");
        let v = crate::bench::json::parse(&s).expect("chrome trace JSON parses");
        let arr = match v.get("traceEvents") {
            Some(crate::bench::json::Val::Arr(a)) => a,
            other => panic!("traceEvents missing or not an array: {other:?}"),
        };
        assert_eq!(arr.len(), 2);
        for (ev, want) in arr.iter().zip(["encode", "gemm"]) {
            match ev.get("name") {
                Some(crate::bench::json::Val::Str(n)) => assert_eq!(n, want),
                other => panic!("name missing: {other:?}"),
            }
            match ev.get("ph") {
                Some(crate::bench::json::Val::Str(p)) => assert_eq!(p, "X"),
                other => panic!("ph missing: {other:?}"),
            }
            assert!(matches!(ev.get("ts"), Some(crate::bench::json::Val::Num(_))));
            assert!(matches!(ev.get("dur"), Some(crate::bench::json::Val::Num(_))));
        }
        // ts/dur are µs: event 1 spans [1.0, 5.5]µs.
        match arr[0].get("dur") {
            Some(crate::bench::json::Val::Num(d)) => assert!((d - 4.5).abs() < 1e-9),
            other => panic!("dur missing: {other:?}"),
        }
    }

    #[test]
    fn trace_span_macro_skips_payload_when_off() {
        let _g = test_gate();
        set_enabled(false);
        let mut evaluated = false;
        let g = trace_span!(Phase::Fork, {
            evaluated = true;
            1u64
        });
        assert!(g.is_none());
        assert!(!evaluated, "payload must not be evaluated when tracing is off");
    }
}
