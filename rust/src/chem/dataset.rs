//! Dataset assembly and on-disk format.
//!
//! `gen-data` renders the synthetic corpus into plain TSV files (no serde
//! in the offline dependency set, and the format is two columns of SMILES):
//!
//! ```text
//! <src-smiles> \t <tgt-smiles> \t <template>
//! ```
//!
//! * forward task (product prediction, USPTO-MIT-mixed analogue):
//!   src = reactants+reagents (shuffled, dot-joined), tgt = product.
//! * retro task (single-step retrosynthesis, USPTO-50K analogue):
//!   src = product, tgt = reactants (dot-joined, no reagents).
//!
//! The retro training split is augmented `aug`× with different reactant
//! orderings — the analogue of the paper's 20× root-aligned augmentation
//! (our pairs are root-aligned by construction, see DESIGN.md §3).

use std::collections::HashSet;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::chem::gen::{gen_reaction, Reaction};
use crate::rng::Rng;

/// One source→target translation example.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Example {
    pub src: String,
    pub tgt: String,
    pub template: String,
}

/// A full task dataset: train/val/test splits.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub train: Vec<Example>,
    pub val: Vec<Example>,
    pub test: Vec<Example>,
}

/// Corpus-generation configuration.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub seed: u64,
    /// Distinct underlying reactions per split.
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
    /// Training-split augmentation factor for the retro task.
    pub retro_aug: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            seed: 20240607,
            n_train: 20_000,
            n_val: 1_000,
            n_test: 2_000,
            retro_aug: 3,
        }
    }
}

/// Generate `n` distinct reactions.
///
/// Dedup key is the *product*: distinct reactions may share a product
/// (e.g. two routes to one ester), and allowing that across splits would
/// leak retro-task test queries into training.
fn gen_distinct(rng: &mut Rng, n: usize, seen: &mut HashSet<String>) -> Vec<Reaction> {
    let mut out = Vec::with_capacity(n);
    let mut guard = 0usize;
    while out.len() < n {
        guard += 1;
        if guard > n * 200 {
            panic!("reaction generator failed to produce {n} distinct reactions");
        }
        let rx = gen_reaction(rng);
        if seen.insert(rx.product.clone()) {
            out.push(rx);
        }
    }
    out
}

fn identity_order(n: usize) -> Vec<usize> {
    (0..n).collect()
}

/// Render the forward-task example for a reaction with a shuffled
/// source-molecule order (mixed reactants/reagents, as in USPTO-MIT mixed).
fn forward_example(rng: &mut Rng, rx: &Reaction) -> Example {
    let mut order = identity_order(rx.n_src_molecules());
    rng.shuffle(&mut order);
    Example {
        src: rx.forward_src(&order),
        tgt: rx.product.clone(),
        template: rx.template.to_string(),
    }
}

/// Render a retro-task example with a given reactant ordering.
fn retro_example(rx: &Reaction, order: &[usize]) -> Example {
    Example {
        src: rx.product.clone(),
        tgt: rx.retro_tgt(order),
        template: rx.template.to_string(),
    }
}

/// Generated corpus for both tasks.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub forward: Dataset,
    pub retro: Dataset,
}

/// Generate the full two-task corpus.
///
/// Reactions are distinct across splits (no leakage: dedup set is shared),
/// and the retro train split is augmented with reactant-order permutations.
pub fn generate_corpus(cfg: &CorpusConfig) -> Corpus {
    let mut rng = Rng::new(cfg.seed);
    let mut seen = HashSet::new();
    let train_rx = gen_distinct(&mut rng, cfg.n_train, &mut seen);
    let val_rx = gen_distinct(&mut rng, cfg.n_val, &mut seen);
    let test_rx = gen_distinct(&mut rng, cfg.n_test, &mut seen);

    let mut fwd = Dataset::default();
    let mut retro = Dataset::default();

    for (rxs, fwd_split, retro_split, is_train) in [
        (&train_rx, &mut fwd.train, &mut retro.train, true),
        (&val_rx, &mut fwd.val, &mut retro.val, false),
        (&test_rx, &mut fwd.test, &mut retro.test, false),
    ] {
        for rx in rxs.iter() {
            fwd_split.push(forward_example(&mut rng, rx));
            let n_r = rx.reactants.len();
            if is_train && cfg.retro_aug > 1 && n_r > 1 {
                // Augment with distinct reactant orderings (at most n_r! of
                // them exist; with n_r == 2 that caps the factor at 2).
                let mut orders: Vec<Vec<usize>> = vec![identity_order(n_r)];
                let mut guard = 0;
                while orders.len() < cfg.retro_aug && guard < 20 {
                    guard += 1;
                    let mut o = identity_order(n_r);
                    rng.shuffle(&mut o);
                    if !orders.contains(&o) {
                        orders.push(o);
                    }
                }
                for o in &orders {
                    retro_split.push(retro_example(rx, o));
                }
            } else {
                retro_split.push(retro_example(rx, &identity_order(n_r)));
            }
        }
    }
    // Shuffle training splits so augmented copies are not adjacent.
    rng.shuffle(&mut fwd.train);
    rng.shuffle(&mut retro.train);
    Corpus { forward: fwd, retro }
}

/// Write one split to a TSV file.
pub fn write_split(path: &Path, examples: &[Example]) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    for ex in examples {
        writeln!(w, "{}\t{}\t{}", ex.src, ex.tgt, ex.template)?;
    }
    Ok(())
}

/// Read one split from a TSV file.
pub fn read_split(path: &Path) -> Result<Vec<Example>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let r = std::io::BufReader::new(f);
    let mut out = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split('\t');
        let (src, tgt) = match (parts.next(), parts.next()) {
            (Some(s), Some(t)) => (s.to_string(), t.to_string()),
            _ => bail!("{}:{}: expected at least 2 tab-separated columns", path.display(), i + 1),
        };
        let template = parts.next().unwrap_or("unknown").to_string();
        out.push(Example { src, tgt, template });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chem::tokenizer::is_valid_smiles;

    fn tiny_cfg() -> CorpusConfig {
        CorpusConfig {
            seed: 1,
            n_train: 50,
            n_val: 10,
            n_test: 10,
            retro_aug: 3,
        }
    }

    #[test]
    fn corpus_split_sizes() {
        let c = generate_corpus(&tiny_cfg());
        assert_eq!(c.forward.train.len(), 50);
        assert_eq!(c.forward.val.len(), 10);
        assert_eq!(c.forward.test.len(), 10);
        // retro train is augmented, so it is at least as large
        assert!(c.retro.train.len() >= 50);
        assert_eq!(c.retro.val.len(), 10);
        assert_eq!(c.retro.test.len(), 10);
    }

    #[test]
    fn corpus_examples_are_valid_smiles() {
        let c = generate_corpus(&tiny_cfg());
        for ex in c
            .forward
            .train
            .iter()
            .chain(&c.forward.test)
            .chain(&c.retro.train)
            .chain(&c.retro.test)
        {
            assert!(is_valid_smiles(&ex.src), "invalid src {}", ex.src);
            assert!(is_valid_smiles(&ex.tgt), "invalid tgt {}", ex.tgt);
        }
    }

    #[test]
    fn no_leakage_between_splits() {
        let c = generate_corpus(&tiny_cfg());
        let train_tgt: HashSet<&str> =
            c.forward.train.iter().map(|e| e.tgt.as_str()).collect();
        for ex in &c.forward.test {
            assert!(
                !train_tgt.contains(ex.tgt.as_str()),
                "test product leaked into train: {}",
                ex.tgt
            );
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let a = generate_corpus(&tiny_cfg());
        let b = generate_corpus(&tiny_cfg());
        assert_eq!(a.forward.train, b.forward.train);
        assert_eq!(a.retro.train, b.retro.train);
    }

    #[test]
    fn tsv_roundtrip() {
        let c = generate_corpus(&tiny_cfg());
        let dir = std::env::temp_dir().join("rxnspec_test_tsv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fwd_train.tsv");
        write_split(&path, &c.forward.train).unwrap();
        let back = read_split(&path).unwrap();
        assert_eq!(back, c.forward.train);
    }

    #[test]
    fn retro_augmentation_creates_order_variants() {
        let c = generate_corpus(&tiny_cfg());
        // Find at least one pair of augmented examples: same src, diff tgt.
        let mut by_src: std::collections::HashMap<&str, HashSet<&str>> =
            std::collections::HashMap::new();
        for ex in &c.retro.train {
            by_src.entry(&ex.src).or_default().insert(&ex.tgt);
        }
        assert!(
            by_src.values().any(|t| t.len() > 1),
            "no augmented reactant-order variants found"
        );
    }
}
