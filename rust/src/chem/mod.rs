//! Chemistry substrate: SMILES tokenization, synthetic reaction corpus
//! generation, and dataset IO.
//!
//! The paper's models are trained on USPTO data we cannot redistribute; see
//! DESIGN.md §3 for the substitution rationale. Everything downstream
//! (training, decoding, serving, benchmarks) is agnostic to where the
//! corpus came from.

pub mod dataset;
pub mod gen;
pub mod tokenizer;

pub use dataset::{generate_corpus, read_split, write_split, Corpus, CorpusConfig, Dataset, Example};
pub use gen::{gen_reaction, gen_reaction_with_template, Reaction, TEMPLATE_NAMES};
pub use tokenizer::{detokenize, is_valid_smiles, tokenize, TokenizeError};
