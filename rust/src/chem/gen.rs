//! Synthetic reaction corpus generator.
//!
//! The paper trains on USPTO-MIT / USPTO-50K, which we cannot ship. The
//! property its speculative-decoding method exploits is *not* chemistry per
//! se — it is that reactant and product SMILES share long common substrings
//! (large molecule fragments are untouched by a reaction, and root-aligned
//! SMILES keep them textually aligned). This module generates a corpus with
//! exactly that structure, from a fragment grammar plus a set of classic
//! reaction templates implemented as string splices:
//!
//!   * N-Boc protection of azoles (the paper's own Figure 2 example class)
//!   * amide coupling (acid + amine)
//!   * Fischer esterification (acid + alcohol) and ester hydrolysis
//!   * N-alkylation of azoles with alkyl halides
//!   * Williamson ether synthesis
//!   * Suzuki-like biaryl coupling (aryl halide + boronic acid)
//!   * ketone reduction
//!
//! Because products are built by splicing reactant substrings, every pair is
//! "root-aligned by construction" — the analogue of the paper's 20× root-
//! aligned augmentation (see DESIGN.md §3).

use crate::chem::tokenizer::{is_valid_smiles, tokenize};
use crate::rng::Rng;

/// One generated reaction sample.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Reaction {
    /// Molecules that contribute atoms to the product.
    pub reactants: Vec<String>,
    /// Spectator molecules (bases, catalysts, solvents). Present on the
    /// source side of the *forward* task (USPTO-MIT "mixed" has no
    /// reactant/reagent separation) and absent from the retro target
    /// (USPTO-50K lists reactants only).
    pub reagents: Vec<String>,
    /// Product molecule.
    pub product: String,
    /// Which template produced this sample (for stratified stats).
    pub template: &'static str,
}

impl Reaction {
    /// Source string for the forward (product-prediction) task:
    /// reactants and reagents mixed, dot-separated, order given.
    pub fn forward_src(&self, order: &[usize]) -> String {
        let all: Vec<&str> = self
            .reactants
            .iter()
            .chain(self.reagents.iter())
            .map(|s| s.as_str())
            .collect();
        order.iter().map(|&i| all[i]).collect::<Vec<_>>().join(".")
    }

    /// Number of source-side molecules in the forward task.
    pub fn n_src_molecules(&self) -> usize {
        self.reactants.len() + self.reagents.len()
    }

    /// Target string for the retro task: reactants only, dot-separated.
    pub fn retro_tgt(&self, order: &[usize]) -> String {
        order
            .iter()
            .map(|&i| self.reactants[i].as_str())
            .collect::<Vec<_>>()
            .join(".")
    }
}

/// Molecule generator: tracks ring-closure digits so that every fragment
/// instantiated within one molecule gets fresh labels.
struct MolGen<'a> {
    rng: &'a mut Rng,
    next_ring: u8,
}

impl<'a> MolGen<'a> {
    fn new(rng: &'a mut Rng) -> Self {
        MolGen { rng, next_ring: 1 }
    }

    fn ring_label(&mut self) -> String {
        let r = self.next_ring;
        self.next_ring += 1;
        if r < 10 {
            format!("{r}")
        } else {
            format!("%{r:02}")
        }
    }

    /// A short aliphatic chain, e.g. `CC`, `CC(C)C` (always starts and ends
    /// on carbon so it can be spliced anywhere an R-group fits).
    fn chain(&mut self, max_len: usize) -> String {
        let len = self.rng.range(1, max_len.max(1));
        let mut s = String::new();
        for i in 0..len {
            if i > 0 && i + 1 < len {
                // internal heteroatom or branch
                let roll = self.rng.below(10);
                if roll == 0 {
                    s.push('O');
                } else if roll == 1 {
                    s.push_str("C(C)");
                    continue;
                } else if roll == 2 {
                    s.push_str("C(F)");
                    continue;
                } else if roll == 3 {
                    s.push_str("C(CC)");
                    continue;
                }
            }
            s.push('C');
        }
        s
    }

    /// A small terminal substituent.
    fn substituent(&mut self, allow_ring: bool) -> String {
        let roll = self.rng.below(if allow_ring { 16 } else { 14 });
        match roll {
            0 => "F".to_string(),
            1 => "Cl".to_string(),
            2 => "OC".to_string(),            // methoxy
            3 => "C(F)(F)F".to_string(),      // trifluoromethyl
            4 => "C#N".to_string(),           // nitrile
            5 => "C(C)C".to_string(),         // isopropyl
            6 => "C".to_string(),             // methyl
            7 => "OCC".to_string(),           // ethoxy
            8 => "N(C)C".to_string(),         // dimethylamino
            9 => "C(C)(C)C".to_string(),      // tert-butyl
            10 => "CC".to_string(),           // ethyl
            11 => "S(=O)(=O)C".to_string(),   // methanesulfonyl
            12 | 13 => self.chain(4),
            _ => self.aryl(false),
        }
    }

    /// A six-membered aromatic ring with 0-2 substituents at random
    /// positions, optionally a pyridine; `sub` allows substitution.
    fn benzene_like(&mut self, sub: bool) -> String {
        let r = self.ring_label();
        let n_pos = if self.rng.chance(0.25) {
            self.rng.range(1, 5)
        } else {
            0 // plain carbocycle
        };
        let (mut sub_a, mut sub_b) = (0usize, 0usize);
        if sub {
            sub_a = self.rng.range(1, 5);
            if self.rng.chance(0.35) {
                sub_b = self.rng.range(1, 5);
                if sub_b == sub_a {
                    sub_b = 0;
                }
            }
        }
        let mut s = format!("c{r}");
        for pos in 1..=5 {
            if pos == n_pos {
                s.push('n');
            } else {
                s.push('c');
            }
            if (pos == sub_a || pos == sub_b) && pos != n_pos {
                let x = self.substituent(false);
                s.push('(');
                s.push_str(&x);
                s.push(')');
            }
        }
        s.push_str(&r);
        s
    }

    /// A five-membered aromatic ring (furan/thiophene-like).
    fn five_ring(&mut self) -> String {
        let r = self.ring_label();
        let het = *self.rng.choose(&["o", "s"]);
        format!("c{r}cc{het}c{r}")
    }

    /// Some aromatic system: benzene-like, five-ring, or (rarely) fused.
    fn aryl(&mut self, allow_sub: bool) -> String {
        match self.rng.below(6) {
            0 | 1 | 2 => {
                let sub = allow_sub && self.rng.chance(0.7);
                self.benzene_like(sub)
            }
            3 => self.five_ring(),
            4 => {
                // naphthalene-like fused bicycle: c1ccc2ccccc2c1
                let r = self.ring_label();
                let s = self.ring_label();
                format!("c{r}ccc{s}ccccc{s}c{r}")
            }
            _ => self.benzene_like(false),
        }
    }

    /// An azole with a free NH that templates can functionalize.
    ///
    /// Returns the free-NH SMILES plus the two halves around the
    /// substitution point, so the N-substituted product renders as
    /// `sub_pre + R + sub_post`. Two shapes exist: mid-string NH (the
    /// paper's indole example, substituent rendered as a branch
    /// `n(R)`) and ring-closing NH (substituent appended after the ring
    /// digit, `...n1R`), because SMILES ring-bond digits must directly
    /// follow the atom.
    /// A run of `n` aromatic carbons, each independently substituted with
    /// probability `p_sub` — diversity fuel for azole scaffolds.
    fn aryl_run(&mut self, n: usize, p_sub: f64) -> String {
        let mut s = String::new();
        for _ in 0..n {
            s.push('c');
            if self.rng.chance(p_sub) {
                let x = self.substituent(false);
                s.push('(');
                s.push_str(&x);
                s.push(')');
            }
        }
        s
    }

    fn azole_site(&mut self) -> AzoleSite {
        match self.rng.below(3) {
            0 => {
                // indole-like fused bicycle: c1c(X?)[nH]c2c(X?)c(X?)c(X?)c(X?)c12
                let r = self.ring_label();
                let s = self.ring_label();
                let x3 = if self.rng.chance(0.3) {
                    format!("({})", self.substituent(false))
                } else {
                    String::new()
                };
                let benzo = format!("c{s}{}c{r}{s}", self.aryl_run(4, 0.25));
                AzoleSite {
                    free: format!("c{r}c{x3}[nH]{benzo}"),
                    sub_pre: format!("c{r}c{x3}n("),
                    sub_post: format!("){benzo}"),
                }
            }
            1 => {
                // pyrrole-like: c1c(X?)c(X?)c[nH]1 — NH closes the ring, so
                // the substituent trails the ring digit: ...cn1R.
                let r = self.ring_label();
                let body = self.aryl_run(3, 0.3);
                AzoleSite {
                    free: format!("c{r}{body}[nH]{r}"),
                    sub_pre: format!("c{r}{body}n{r}"),
                    sub_post: String::new(),
                }
            }
            _ => {
                // imidazole-like: c1c(X?)nc(X?)[nH]1 → ...n1R
                let r = self.ring_label();
                let a = self.aryl_run(1, 0.4);
                let b = self.aryl_run(1, 0.4);
                AzoleSite {
                    free: format!("c{r}{a}n{b}[nH]{r}"),
                    sub_pre: format!("c{r}{a}n{b}n{r}"),
                    sub_post: String::new(),
                }
            }
        }
    }

    /// An R-group: chain, aryl, or chain-aryl.
    fn rgroup(&mut self) -> String {
        match self.rng.below(4) {
            0 => self.chain(4),
            1 => self.aryl(true),
            2 => format!("{}{}", self.chain(2), self.aryl(true)),
            _ => format!("{}{}", self.chain(3), self.aryl(false)),
        }
    }
}

/// Common spectator molecules for the forward (mixed) task. Chosen to put
/// bracket atoms and unusual tokens in the training distribution, as real
/// USPTO-MIT does.
const REAGENTS: &[&str] = &[
    "CCN(CC)CC",          // triethylamine
    "C(=O)([O-])[O-].[K+].[K+]", // potassium carbonate
    "[OH-].[Na+]",        // sodium hydroxide
    "O",                  // water
    "CCO",                // ethanol
    "CC(=O)OCC",          // ethyl acetate (solvent)
    "[Pd]",               // palladium catalyst
    "CS(C)=O",            // DMSO
    "CN(C)C=O",           // DMF
    "Cl",                 // HCl
];

/// Boc anhydride, exactly as written in the paper's Figure 2.
pub const BOC_ANHYDRIDE: &str = "C(=O)(OC(=O)OC(C)(C)C)OC(C)(C)C";
/// The Boc group spliced onto azole nitrogens, as in Figure 2's product.
pub const BOC_GROUP: &str = "C(=O)OC(C)(C)C";

/// Maximum tokens in a rendered forward source string. The model's source
/// bucket is S=96; two slots are reserved for BOS/EOS.
pub const MAX_SRC_TOKENS: usize = 90;

/// All reaction template names, in generation-probability order.
pub const TEMPLATE_NAMES: &[&str] = &[
    "boc_protection",
    "amide_coupling",
    "esterification",
    "ester_hydrolysis",
    "n_alkylation",
    "williamson_ether",
    "suzuki_coupling",
    "ketone_reduction",
];

/// Generate one reaction from a uniformly chosen template.
pub fn gen_reaction(rng: &mut Rng) -> Reaction {
    let t = rng.below(TEMPLATE_NAMES.len());
    gen_reaction_with_template(rng, TEMPLATE_NAMES[t])
}

/// Generate one reaction from a named template (panics on unknown name).
pub fn gen_reaction_with_template(rng: &mut Rng, template: &'static str) -> Reaction {
    let mut rx = match template {
        "boc_protection" => boc_protection(rng),
        "amide_coupling" => amide_coupling(rng),
        "esterification" => esterification(rng),
        "ester_hydrolysis" => ester_hydrolysis(rng),
        "n_alkylation" => n_alkylation(rng),
        "williamson_ether" => williamson_ether(rng),
        "suzuki_coupling" => suzuki_coupling(rng),
        "ketone_reduction" => ketone_reduction(rng),
        other => panic!("unknown template {other}"),
    };
    // Attach 0-2 spectator reagents for the forward (mixed) task, keeping
    // the full source under the model's source bucket (S=96 incl. BOS/EOS).
    let n_extra = rng.below(3);
    for _ in 0..n_extra {
        let r = (*rng.choose(REAGENTS)).to_string();
        if rx.reagents.contains(&r) {
            continue;
        }
        let src_now = rx.forward_src(&(0..rx.n_src_molecules()).collect::<Vec<_>>());
        let extra = tokenize(&r).map(|t| t.len()).unwrap_or(usize::MAX);
        let have = tokenize(&src_now).map(|t| t.len()).unwrap_or(usize::MAX);
        if have + 1 + extra <= MAX_SRC_TOKENS {
            rx.reagents.push(r);
        }
    }
    debug_assert!(rx.reactants.iter().all(|s| is_valid_smiles(s)), "{rx:?}");
    debug_assert!(is_valid_smiles(&rx.product), "{rx:?}");
    rx
}

/// Halves of an azole around its NH substitution point.
struct AzoleSite {
    free: String,
    sub_pre: String,
    sub_post: String,
}

impl AzoleSite {
    fn substituted(&self, r: &str) -> String {
        format!("{}{}{}", self.sub_pre, r, self.sub_post)
    }
}

/// Azole NH + Boc2O → N-Boc azole (paper Figure 2).
fn boc_protection(rng: &mut Rng) -> Reaction {
    let mut m = MolGen::new(rng);
    let site = m.azole_site();
    Reaction {
        reactants: vec![site.free.clone(), BOC_ANHYDRIDE.to_string()],
        reagents: vec![],
        product: site.substituted(BOC_GROUP),
        template: "boc_protection",
    }
}

/// R-C(=O)O + N-R' → R-C(=O)N-R'.
fn amide_coupling(rng: &mut Rng) -> Reaction {
    let mut m = MolGen::new(rng);
    let acid_sc = m.rgroup();
    let amine_tail = format!("C{}", m.rgroup());
    let acid = format!("{acid_sc}C(=O)O");
    let amine = format!("N{amine_tail}");
    let product = format!("{acid_sc}C(=O)N{amine_tail}");
    Reaction {
        reactants: vec![acid, amine],
        reagents: vec![],
        product,
        template: "amide_coupling",
    }
}

/// R-C(=O)O + HO-R' → R-C(=O)O-R'.
fn esterification(rng: &mut Rng) -> Reaction {
    let mut m = MolGen::new(rng);
    let acid_sc = m.rgroup();
    let alc_tail = if m.rng.chance(0.5) {
        format!("C{}", m.chain(4))
    } else {
        format!("C{}{}", m.chain(2), m.aryl(false))
    };
    let acid = format!("{acid_sc}C(=O)O");
    let alcohol = format!("O{alc_tail}");
    let product = format!("{acid_sc}C(=O)O{alc_tail}");
    Reaction {
        reactants: vec![acid, alcohol],
        reagents: vec![],
        product,
        template: "esterification",
    }
}

/// R-C(=O)O-R' + H2O → R-C(=O)O + HO-R' (product side of the forward task
/// is the acid; the alcohol is treated as a co-product and dropped, as
/// USPTO single-product entries do).
fn ester_hydrolysis(rng: &mut Rng) -> Reaction {
    let mut m = MolGen::new(rng);
    let acid_sc = m.rgroup();
    let alc_tail = format!("C{}", m.chain(4));
    let ester = format!("{acid_sc}C(=O)O{alc_tail}");
    let product = format!("{acid_sc}C(=O)O");
    Reaction {
        reactants: vec![ester],
        reagents: vec!["[OH-].[Na+]".to_string(), "O".to_string()],
        product,
        template: "ester_hydrolysis",
    }
}

/// Azole NH + Br-R → N-alkyl azole.
fn n_alkylation(rng: &mut Rng) -> Reaction {
    let mut m = MolGen::new(rng);
    let site = m.azole_site();
    let alkyl = format!("C{}", m.chain(3));
    let halide = format!("Br{alkyl}");
    Reaction {
        reactants: vec![site.free.clone(), halide],
        reagents: vec![],
        product: site.substituted(&alkyl),
        template: "n_alkylation",
    }
}

/// Br-R + HO-R' → R-O-R'.
fn williamson_ether(rng: &mut Rng) -> Reaction {
    let mut m = MolGen::new(rng);
    let alkyl = format!("C{}", m.chain(3));
    let alc_tail = format!("C{}", m.rgroup());
    let halide = format!("Br{alkyl}");
    let alcohol = format!("O{alc_tail}");
    let product = format!("{alc_tail}O{alkyl}");
    // product written alcohol-first keeps the longer fragment contiguous
    Reaction {
        reactants: vec![halide, alcohol],
        reagents: vec![],
        product,
        template: "williamson_ether",
    }
}

/// Ar-Br + Ar'-B(O)O → Ar-Ar'.
fn suzuki_coupling(rng: &mut Rng) -> Reaction {
    let mut m = MolGen::new(rng);
    let ar1 = m.aryl(true);
    let ar2 = m.aryl(false);
    let halide = format!("Br{ar1}");
    let boronic = format!("OB(O){ar2}");
    let product = format!("{ar2}{ar1}");
    Reaction {
        reactants: vec![halide, boronic],
        reagents: vec!["[Pd]".to_string()],
        product,
        template: "suzuki_coupling",
    }
}

/// R-C(R')=O → R-C(R')O.
fn ketone_reduction(rng: &mut Rng) -> Reaction {
    let mut m = MolGen::new(rng);
    let sc = m.rgroup();
    let alkyl = m.chain(3);
    let ketone = format!("{sc}C({alkyl})=O");
    let product = format!("{sc}C({alkyl})O");
    Reaction {
        reactants: vec![ketone],
        reagents: vec![],
        product,
        template: "ketone_reduction",
    }
}

/// Longest common substring length, in *tokens*, between two SMILES. Used
/// to verify the corpus has the substring-overlap property speculative
/// decoding needs (and reported per template by `gen-data --stats`).
pub fn longest_common_token_substring(a: &str, b: &str) -> usize {
    let (ta, tb) = match (tokenize(a), tokenize(b)) {
        (Ok(x), Ok(y)) => (x, y),
        _ => return 0,
    };
    let (n, m) = (ta.len(), tb.len());
    let mut prev = vec![0usize; m + 1];
    let mut best = 0usize;
    for i in 1..=n {
        let mut cur = vec![0usize; m + 1];
        for j in 1..=m {
            if ta[i - 1] == tb[j - 1] {
                cur[j] = prev[j - 1] + 1;
                best = best.max(cur[j]);
            }
        }
        prev = cur;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chem::tokenizer::is_valid_smiles;

    fn all_templates_many(seed: u64, n: usize) -> Vec<Reaction> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| gen_reaction(&mut rng)).collect()
    }

    #[test]
    fn generated_reactants_and_products_are_valid() {
        for rx in all_templates_many(1, 500) {
            for r in &rx.reactants {
                assert!(is_valid_smiles(r), "invalid reactant {r} in {rx:?}");
            }
            for r in &rx.reagents {
                assert!(is_valid_smiles(r), "invalid reagent {r} in {rx:?}");
            }
            assert!(is_valid_smiles(&rx.product), "invalid product in {rx:?}");
        }
    }

    #[test]
    fn every_template_is_reachable() {
        let seen: std::collections::HashSet<&str> =
            all_templates_many(2, 400).iter().map(|r| r.template).collect();
        for t in TEMPLATE_NAMES {
            assert!(seen.contains(t), "template {t} never generated");
        }
    }

    #[test]
    fn each_named_template_generates() {
        let mut rng = Rng::new(3);
        for t in TEMPLATE_NAMES {
            let rx = gen_reaction_with_template(&mut rng, t);
            assert_eq!(rx.template, *t);
            assert!(!rx.reactants.is_empty());
        }
    }

    #[test]
    fn products_share_long_substrings_with_reactants() {
        // The core corpus property: the product must share a long token
        // substring with the reactant side — that is what gives query-copy
        // drafts their high acceptance rate.
        let mut total = 0usize;
        let mut long_enough = 0usize;
        for rx in all_templates_many(4, 300) {
            let src = rx
                .reactants
                .iter()
                .cloned()
                .collect::<Vec<_>>()
                .join(".");
            let lcs = longest_common_token_substring(&src, &rx.product);
            total += 1;
            if lcs >= 4 {
                long_enough += 1;
            }
        }
        // At least 95% of reactions must share a ≥4-token substring.
        assert!(
            long_enough * 100 >= total * 95,
            "only {long_enough}/{total} reactions share a >=4-token substring"
        );
    }

    #[test]
    fn boc_protection_matches_paper_shape() {
        let mut rng = Rng::new(5);
        let rx = gen_reaction_with_template(&mut rng, "boc_protection");
        assert!(rx.reactants.iter().any(|r| r == BOC_ANHYDRIDE));
        assert!(rx.product.contains(BOC_GROUP));
        assert!(rx.reactants.iter().any(|r| r.contains("[nH]")));
        assert!(!rx.product.contains("[nH]"));
    }

    #[test]
    fn forward_src_and_retro_tgt_respect_order() {
        let mut rng = Rng::new(6);
        let rx = gen_reaction_with_template(&mut rng, "amide_coupling");
        assert_eq!(rx.reactants.len(), 2);
        let fwd = rx.forward_src(&[1, 0]);
        let parts: Vec<&str> = fwd.split('.').collect();
        assert_eq!(parts[0], rx.reactants[1]);
        assert_eq!(parts[1], rx.reactants[0]);
        let retro = rx.retro_tgt(&[1, 0]);
        assert!(retro.starts_with(&rx.reactants[1]));
    }

    #[test]
    fn lcs_token_metric_sane() {
        assert_eq!(longest_common_token_substring("CCO", "CCO"), 3);
        assert_eq!(longest_common_token_substring("CCO", "OCC"), 2);
        // Token-level, not char-level: Br is one token.
        assert_eq!(longest_common_token_substring("BrC", "BC", ), 1);
        assert_eq!(longest_common_token_substring("CC", "OO"), 0);
    }

    #[test]
    fn reaction_smiles_reasonably_sized() {
        // Model buckets: src fits S=96 (incl. BOS/EOS), tgt fits T=64.
        for rx in all_templates_many(7, 500) {
            let src = rx.forward_src(&(0..rx.n_src_molecules()).collect::<Vec<_>>());
            let n_src = tokenize(&src).unwrap().len();
            let n_tgt = tokenize(&rx.product).unwrap().len();
            assert!(n_src <= MAX_SRC_TOKENS, "src too long ({n_src}): {src}");
            assert!(n_tgt <= 62, "tgt too long ({n_tgt}): {}", rx.product);
            // Retro target (reactants incl. Boc anhydride) must also fit.
            let retro = rx.retro_tgt(&(0..rx.reactants.len()).collect::<Vec<_>>());
            let n_retro = tokenize(&retro).unwrap().len();
            assert!(n_retro <= MAX_SRC_TOKENS, "retro tgt too long ({n_retro}): {retro}");
        }
    }
}
