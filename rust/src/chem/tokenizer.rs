//! Atomwise SMILES tokenization.
//!
//! This is the standard tokenization procedure of Schwaller et al. (2019),
//! used verbatim by the paper: bracket atoms `[...]` are single tokens,
//! two-character organic-subset atoms (`Cl`, `Br`) are single tokens, ring
//! closures `%NN` are single tokens, and every other character (atoms,
//! bonds, branches, digits, the `.` separator and the `>` reaction marker)
//! is its own token.
//!
//! The Python build path (`python/compile/data.py`) implements the same
//! regex; `data/golden_tokens.tsv` written by `gen-data` pins the two
//! implementations together (checked by a pytest on the Python side).

/// Schwaller et al. (2019) atomwise tokenization pattern. The scanner
/// below implements exactly this alternation by hand (the offline crate
/// set has no `regex`); the constant stays as the canonical spec and for
/// parity with the Python implementation in `python/compile/data.py`.
pub const SMILES_TOKEN_PATTERN: &str = r"(\[[^\]]+\]|Br|Cl|N|O|S|P|F|I|B|b|c|n|o|s|p|\(|\)|\.|=|#|-|\+|\\|/|:|~|@|\?|>|\*|\$|%[0-9]{2}|[0-9]|[A-Za-z])";

/// Length (in bytes) of the token starting at the head of `rest`, or
/// `None` if no alternative of [`SMILES_TOKEN_PATTERN`] matches there.
/// Alternatives are tried longest-first per position, matching the regex
/// alternation order (bracket atom, `Br`/`Cl`, `%NN`, then single chars).
fn token_len(rest: &str) -> Option<usize> {
    let c = rest.chars().next()?;
    match c {
        '[' => {
            // `\[[^\]]+\]`: at least one non-`]` char, then the closing `]`.
            let mut len = 1usize;
            let mut inner = 0usize;
            for c2 in rest[1..].chars() {
                if c2 == ']' {
                    return if inner > 0 { Some(len + 1) } else { None };
                }
                inner += 1;
                len += c2.len_utf8();
            }
            None // unterminated bracket atom
        }
        'B' if rest[1..].starts_with('r') => Some(2),
        'C' if rest[1..].starts_with('l') => Some(2),
        '%' => {
            let b = rest.as_bytes();
            if b.len() >= 3 && b[1].is_ascii_digit() && b[2].is_ascii_digit() {
                Some(3)
            } else {
                None
            }
        }
        c if c.is_ascii_alphanumeric() => Some(1),
        '(' | ')' | '.' | '=' | '#' | '-' | '+' | '\\' | '/' | ':' | '~' | '@' | '?' | '>'
        | '*' | '$' => Some(1),
        _ => None,
    }
}

/// Split a SMILES string into atomwise tokens.
///
/// Every byte of the input must be consumed by the token pattern; any
/// leftover (e.g. whitespace or an unterminated bracket atom) is an error.
pub fn tokenize(smiles: &str) -> Result<Vec<String>, TokenizeError> {
    let mut tokens = Vec::with_capacity(smiles.len());
    let mut consumed = 0usize;
    while consumed < smiles.len() {
        match token_len(&smiles[consumed..]) {
            Some(n) => {
                tokens.push(smiles[consumed..consumed + n].to_string());
                consumed += n;
            }
            None => {
                return Err(TokenizeError {
                    smiles: smiles.to_string(),
                    at: consumed,
                })
            }
        }
    }
    Ok(tokens)
}

/// Inverse of [`tokenize`]: concatenation restores the exact input string.
pub fn detokenize<S: AsRef<str>>(tokens: &[S]) -> String {
    tokens.iter().map(|t| t.as_ref()).collect()
}

/// Tokenization failure: some byte range was not covered by the pattern.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TokenizeError {
    pub smiles: String,
    pub at: usize,
}

impl std::fmt::Display for TokenizeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cannot tokenize SMILES {:?} at byte {} ({:?}...)",
            self.smiles,
            self.at,
            &self.smiles[self.at..self.smiles.len().min(self.at + 8)]
        )
    }
}

impl std::error::Error for TokenizeError {}

/// Structural validity of a SMILES string at the token level.
///
/// We do not do full valence chemistry (the corpus generator only emits
/// grammar-constructed molecules); this check guards the *string* invariants
/// the decoder must learn and that the detokenizer relies on:
///   * balanced parentheses, no empty `()` branch, no branch at position 0
///   * every ring-closure digit / `%NN` label is opened and closed exactly
///     twice per molecule
///   * bracket atoms well-formed (non-empty, `[` closed by `]`)
///   * bond symbols are followed by an atom or ring closure
///   * `.` separates non-empty molecule fragments
pub fn is_valid_smiles(smiles: &str) -> bool {
    let tokens = match tokenize(smiles) {
        Ok(t) => t,
        Err(_) => return false,
    };
    if tokens.is_empty() {
        return false;
    }
    // Validate each `.`-separated fragment independently (ring labels and
    // parentheses cannot span fragments).
    let mut start = 0usize;
    for (i, t) in tokens.iter().enumerate() {
        if t == "." {
            if !fragment_is_valid(&tokens[start..i]) {
                return false;
            }
            start = i + 1;
        }
    }
    fragment_is_valid(&tokens[start..])
}

fn is_atom_token(t: &str) -> bool {
    matches!(
        t,
        "B" | "C" | "N" | "O" | "S" | "P" | "F" | "I" | "Br" | "Cl" | "b" | "c" | "n" | "o" | "s"
            | "p"
    ) || (t.starts_with('[') && t.ends_with(']') && t.len() > 2)
}

fn is_bond_token(t: &str) -> bool {
    matches!(t, "=" | "#" | "-" | "/" | "\\" | ":" | "~")
}

fn is_ring_token(t: &str) -> bool {
    t.len() == 1 && t.chars().next().unwrap().is_ascii_digit() || t.starts_with('%')
}

fn fragment_is_valid(tokens: &[String]) -> bool {
    if tokens.is_empty() {
        return false;
    }
    let mut depth: i32 = 0;
    let mut ring_open: std::collections::HashMap<&str, usize> = std::collections::HashMap::new();
    let mut prev_atom_seen = false;
    let mut prev: Option<&str> = None;

    for (i, t) in tokens.iter().enumerate() {
        let t = t.as_str();
        if t == "(" {
            // A branch must follow an atom or a ring closure.
            if !prev_atom_seen {
                return false;
            }
            if let Some(p) = prev {
                if p == "(" || is_bond_token(p) {
                    return false;
                }
            }
            depth += 1;
        } else if t == ")" {
            depth -= 1;
            if depth < 0 {
                return false;
            }
            if prev == Some("(") {
                return false; // empty branch
            }
            if let Some(p) = prev {
                if is_bond_token(p) {
                    return false; // dangling bond before ')'
                }
            }
        } else if is_bond_token(t) {
            // A bond may open a branch (`C(=O)`) but not start a fragment
            // or follow another bond.
            if i == 0 || prev.is_some_and(is_bond_token) {
                return false;
            }
        } else if is_ring_token(t) {
            // Ring digit must follow an atom, a bond, or another ring digit.
            if !prev_atom_seen {
                return false;
            }
            *ring_open.entry(ring_label(t)).or_insert(0) += 1;
        } else if is_atom_token(t) {
            prev_atom_seen = true;
        } else {
            // '>' '*' '$' '?' '@' '+' and raw letters are not valid in our
            // molecule corpus outside bracket atoms.
            return false;
        }
        prev = Some(t);
    }
    if depth != 0 {
        return false;
    }
    if let Some(p) = prev {
        if is_bond_token(p) || p == "(" {
            return false;
        }
    }
    // Every ring label must occur an even number of times (opened+closed).
    ring_open.values().all(|&c| c % 2 == 0)
}

fn ring_label(t: &str) -> &str {
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizes_paper_figure2_reactant() {
        // The Boc-protection example from Figure 2 of the paper.
        let smiles = "c1c[nH]c2ccc(C(C)=O)cc12";
        let toks = tokenize(smiles).unwrap();
        assert_eq!(
            toks,
            vec![
                "c", "1", "c", "[nH]", "c", "2", "c", "c", "c", "(", "C", "(", "C", ")", "=",
                "O", ")", "c", "c", "1", "2"
            ]
        );
        assert_eq!(detokenize(&toks), smiles);
    }

    #[test]
    fn tokenizes_two_char_atoms() {
        let toks = tokenize("BrCCCl").unwrap();
        assert_eq!(toks, vec!["Br", "C", "C", "Cl"]);
    }

    #[test]
    fn tokenizes_bracket_atoms_as_units() {
        let toks = tokenize("[nH]c[C@@H][NH3+]").unwrap();
        assert_eq!(toks, vec!["[nH]", "c", "[C@@H]", "[NH3+]"]);
    }

    #[test]
    fn tokenizes_reaction_smiles() {
        let toks = tokenize("CC=O.OCC>>CC(O)OCC").unwrap();
        assert!(toks.contains(&">".to_string()));
        assert!(toks.contains(&".".to_string()));
        assert_eq!(detokenize(&toks), "CC=O.OCC>>CC(O)OCC");
    }

    #[test]
    fn tokenizes_percent_ring_closures() {
        let toks = tokenize("C%12CC%12").unwrap();
        assert_eq!(toks, vec!["C", "%12", "C", "C", "%12"]);
    }

    #[test]
    fn rejects_unterminated_bracket() {
        assert!(tokenize("C[nH").is_err());
    }

    #[test]
    fn rejects_whitespace() {
        assert!(tokenize("C C").is_err());
    }

    #[test]
    fn valid_accepts_paper_reaction_parts() {
        for s in [
            "c1c[nH]c2ccc(C(C)=O)cc12",
            "C(=O)(OC(=O)OC(C)(C)C)OC(C)(C)C",
            "c1cn(C(=O)OC(C)(C)C)c2ccc(C(C)=O)cc12",
            "CC(=O)Nc1ccc(O)cc1",
            "CC(C)(C)OC(=O)N1CCC(N)CC1",
        ] {
            assert!(is_valid_smiles(s), "should be valid: {s}");
        }
    }

    #[test]
    fn valid_accepts_dot_separated() {
        assert!(is_valid_smiles("CCO.CC(=O)O"));
    }

    #[test]
    fn invalid_unbalanced_parens() {
        assert!(!is_valid_smiles("CC(C"));
        assert!(!is_valid_smiles("CC)C"));
    }

    #[test]
    fn invalid_empty_branch_or_leading_branch() {
        assert!(!is_valid_smiles("C()C"));
        assert!(!is_valid_smiles("(CC)"));
    }

    #[test]
    fn invalid_odd_ring_closures() {
        assert!(!is_valid_smiles("C1CC"));
        assert!(!is_valid_smiles("c1ccccc12"));
    }

    #[test]
    fn invalid_dangling_bond() {
        assert!(!is_valid_smiles("CC="));
        assert!(!is_valid_smiles("=CC"));
        assert!(!is_valid_smiles("C(=)C"));
    }

    #[test]
    fn invalid_empty_fragments() {
        assert!(!is_valid_smiles(""));
        assert!(!is_valid_smiles("CC..CC"));
        assert!(!is_valid_smiles(".CC"));
        assert!(!is_valid_smiles("CC."));
    }

    #[test]
    fn detokenize_roundtrip_misc() {
        for s in [
            "COc1ccc2[nH]c(C)cc2c1",
            "O=C(O)c1ccccc1Br",
            "FC(F)(F)c1ccc(N)cc1",
        ] {
            assert_eq!(detokenize(&tokenize(s).unwrap()), s);
        }
    }
}
