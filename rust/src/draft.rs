//! Draft construction for speculative decoding.
//!
//! The paper's drafting strategy (§2.1, Figure 2): before generating the
//! target, slice the *tokenized query SMILES* with a sliding window of the
//! chosen draft length and stride 1, and use those subsequences as draft
//! continuations. Reactions leave large molecule fragments untouched, so
//! these copies have a high acceptance rate (~79% reported).
//!
//! A second draft source supplements the query copies:
//! **corpus-learned windows** mined from previously accepted targets by a
//! [`cache::DraftStore`](crate::cache::DraftStore). Both sources merge in
//! [`extract_drafts_merged`] behind *one* shared dedup set and *one*
//! shared `max_drafts` cap (the paper's `N_d ≈ 25`, Appendix B, which
//! bounds the effective-batch inflation described in §3.3) — a window is
//! never verified twice just because two sources proposed it. Query
//! copies keep strict priority: they fill the cap first, so enabling the
//! corpus source can only *add* drafts, never displace a query window —
//! the exactness arguments in `cache/mod.rs` lean on this ordering.

use crate::vocab::BOS_ID;

/// Where a draft window came from — decoders attribute per-source
/// acceptance in `DecodeStats` with this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftSource {
    /// Sliding window of the current query (the paper's §2.1 copies).
    QueryCopy,
    /// Corpus-learned window from a [`cache::DraftStore`](crate::cache::DraftStore).
    Corpus,
    /// The never-accepted BOS sentinel (DL=0, or no usable windows).
    Sentinel,
}

/// One draft window plus its provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct Draft {
    pub tokens: Vec<i64>,
    pub source: DraftSource,
}

/// Configuration for query-copy draft extraction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DraftConfig {
    /// Sliding-window length (the paper's DL). `0` means "no usable
    /// drafts": a single never-accepted BOS draft, which reduces
    /// speculative decoding to the standard procedure (§3.2, "SBS, DL=0").
    pub draft_len: usize,
    /// Cap on the number of drafts kept (`N_d`).
    pub max_drafts: usize,
    /// Also include windows dilated by one token (the §3.1 suggestion for
    /// pushing the acceptance rate higher). Off by default.
    pub dilated: bool,
    /// Drop duplicate windows. The paper's listing keeps duplicates; we
    /// dedup by default since identical drafts waste effective batch.
    pub dedup: bool,
}

impl DraftConfig {
    pub fn new(draft_len: usize) -> Self {
        DraftConfig {
            draft_len,
            max_drafts: 25,
            dilated: false,
            dedup: true,
        }
    }
}

/// FNV-1a over a token window — the dedup prefilter key.
fn window_hash(w: &[i64]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &t in w {
        h ^= t as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Extract draft sequences from a tokenized query (query-copy source
/// only). See [`extract_drafts_merged`] for the full contract.
pub fn extract_drafts(query: &[i64], cfg: &DraftConfig) -> Vec<Vec<i64>> {
    extract_drafts_merged(query, cfg, &[])
        .into_iter()
        .map(|d| d.tokens)
        .collect()
}

/// Extract drafts from a tokenized query *and* a corpus-learned window
/// list, merged behind one dedup set and one `max_drafts` cap.
///
/// Ordering contract: query-copy windows first (plain, then dilated),
/// corpus windows after — so the corpus source can never displace a
/// query window, only fill leftover cap slots. Corpus windows may have
/// any length (the decoders clip and verify token-by-token).
///
/// Returns at least one draft: when `draft_len == 0`, or no source yields
/// a usable window, the fallback is a single `[BOS]` sentinel that the
/// model can never accept (BOS never follows another token in training),
/// reducing the speculative algorithms to their standard counterparts. A
/// query shorter than `draft_len` contributes no windows of its own but
/// corpus windows still apply.
///
/// Dedup is a `HashSet` of window hashes with an exact confirm on hash
/// hit — O(N_w) over all proposed windows instead of the old
/// O(N_w²) `drafts.contains` scan (which hurt exactly when callers lift
/// `max_drafts`, e.g. the long-query sweeps). The set is shared across
/// sources, so duplicates never consume `max_drafts` slots — whether they
/// repeat within the query or between query and corpus — and dedup lets
/// *later distinct* windows into the kept set (pinned by regression
/// tests below).
pub fn extract_drafts_merged(
    query: &[i64],
    cfg: &DraftConfig,
    corpus: &[Vec<i64>],
) -> Vec<Draft> {
    let dl = cfg.draft_len;
    if dl == 0 {
        return vec![Draft {
            tokens: vec![BOS_ID],
            source: DraftSource::Sentinel,
        }];
    }
    let mut drafts: Vec<Draft> = Vec::new();
    let mut seen: std::collections::HashSet<u64> = std::collections::HashSet::new();
    let push = |w: Vec<i64>,
                source: DraftSource,
                drafts: &mut Vec<Draft>,
                seen: &mut std::collections::HashSet<u64>| {
        if drafts.len() >= cfg.max_drafts {
            return;
        }
        if cfg.dedup {
            // Hash prefilter; on a hit, confirm against the kept windows
            // so a (cosmically unlikely) collision can't drop a draft.
            if !seen.insert(window_hash(&w)) && drafts.iter().any(|d| d.tokens == w) {
                return;
            }
        }
        drafts.push(Draft { tokens: w, source });
    };
    if query.len() >= dl {
        for start in 0..=(query.len() - dl) {
            push(
                query[start..start + dl].to_vec(),
                DraftSource::QueryCopy,
                &mut drafts,
                &mut seen,
            );
        }
        if cfg.dilated {
            // Windows that skip one token: cover deletions of a single
            // token between reactant and product strings.
            for start in 0..query.len().saturating_sub(dl) {
                let w: Vec<i64> = query[start..=start + dl]
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| *i != dl / 2)
                    .map(|(_, &t)| t)
                    .collect();
                push(w, DraftSource::QueryCopy, &mut drafts, &mut seen);
            }
        }
    }
    for w in corpus {
        if w.is_empty() {
            continue;
        }
        push(w.clone(), DraftSource::Corpus, &mut drafts, &mut seen);
    }
    if drafts.is_empty() {
        return vec![Draft {
            tokens: vec![BOS_ID],
            source: DraftSource::Sentinel,
        }];
    }
    drafts
}

/// Running acceptance statistics for one or more decodes (the paper's
/// "acceptance rate": accepted draft tokens / total generated tokens).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Acceptance {
    pub accepted_draft_tokens: usize,
    pub total_tokens: usize,
}

impl Acceptance {
    pub fn rate(&self) -> f64 {
        if self.total_tokens == 0 {
            0.0
        } else {
            self.accepted_draft_tokens as f64 / self.total_tokens as f64
        }
    }

    pub fn merge(&mut self, other: &Acceptance) {
        self.accepted_draft_tokens += other.accepted_draft_tokens;
        self.total_tokens += other.total_tokens;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(n: usize) -> Vec<i64> {
        (10..10 + n as i64).collect()
    }

    #[test]
    fn sliding_window_stride_one() {
        let cfg = DraftConfig {
            max_drafts: 100,
            ..DraftConfig::new(4)
        };
        let drafts = extract_drafts(&q(6), &cfg);
        assert_eq!(
            drafts,
            vec![
                vec![10, 11, 12, 13],
                vec![11, 12, 13, 14],
                vec![12, 13, 14, 15],
            ]
        );
    }

    #[test]
    fn figure2_draft_count() {
        // A 57-token query with DL=4 yields 54 stride-1 windows.
        let cfg = DraftConfig {
            max_drafts: usize::MAX,
            dedup: false,
            ..DraftConfig::new(4)
        };
        let drafts = extract_drafts(&q(57), &cfg);
        assert_eq!(drafts.len(), 54);
    }

    #[test]
    fn max_drafts_cap_applies() {
        let cfg = DraftConfig::new(4); // cap 25
        let drafts = extract_drafts(&q(100), &cfg);
        assert_eq!(drafts.len(), 25);
    }

    #[test]
    fn draft_len_zero_gives_bos_sentinel() {
        let drafts = extract_drafts(&q(20), &DraftConfig::new(0));
        assert_eq!(drafts, vec![vec![BOS_ID]]);
    }

    #[test]
    fn short_query_gives_bos_sentinel() {
        let drafts = extract_drafts(&q(3), &DraftConfig::new(10));
        assert_eq!(drafts, vec![vec![BOS_ID]]);
    }

    #[test]
    fn dedup_frees_cap_slots_for_later_distinct_windows() {
        // Periodic head: [5,6,5,6,5,6,5,6] yields only two distinct
        // 2-windows ([5,6] and [6,5]); the 10 distinct windows of the
        // ramp tail must still fit under a cap of 8 because duplicates
        // never consume `max_drafts` slots.
        let mut query = vec![5i64, 6, 5, 6, 5, 6, 5, 6];
        query.extend(10..20); // windows [6,10], [10,11], ..., [18,19]
        let cfg = DraftConfig {
            max_drafts: 8,
            ..DraftConfig::new(2)
        };
        let drafts = extract_drafts(&query, &cfg);
        assert_eq!(drafts.len(), 8);
        // First-occurrence order: the two periodic windows, then the tail.
        assert_eq!(drafts[0], vec![5, 6]);
        assert_eq!(drafts[1], vec![6, 5]);
        assert_eq!(drafts[2], vec![6, 10]);
        assert_eq!(drafts[3], vec![10, 11]);
        assert_eq!(drafts[7], vec![14, 15]);
        // Without dedup the duplicates eat the cap before the tail.
        let nodedup = extract_drafts(
            &query,
            &DraftConfig {
                dedup: false,
                max_drafts: 8,
                ..DraftConfig::new(2)
            },
        );
        assert_eq!(nodedup.len(), 8);
        assert!(!nodedup.contains(&vec![10, 11]));
    }

    #[test]
    fn dedup_removes_repeated_windows() {
        let query = vec![5, 5, 5, 5, 5, 5];
        let with = extract_drafts(&query, &DraftConfig::new(3));
        assert_eq!(with.len(), 1);
        let without = extract_drafts(
            &query,
            &DraftConfig {
                dedup: false,
                ..DraftConfig::new(3)
            },
        );
        assert_eq!(without.len(), 4);
    }

    #[test]
    fn dilated_adds_skip_windows() {
        let cfg = DraftConfig {
            dilated: true,
            max_drafts: 100,
            ..DraftConfig::new(2)
        };
        let drafts = extract_drafts(&q(4), &cfg);
        // plain windows: [10,11],[11,12],[12,13]; dilated (skip middle of
        // each 3-window): [10,12],[11,13]
        assert!(drafts.contains(&vec![10, 12]));
        assert!(drafts.contains(&vec![11, 13]));
        assert_eq!(drafts.len(), 5);
    }

    #[test]
    fn merged_sources_share_one_dedup_set_and_cap() {
        // Query windows: [10,11], [11,12], [12,13]. Corpus proposes a
        // duplicate of a query window plus two fresh windows; the
        // duplicate must not consume a cap slot.
        let corpus = vec![vec![11, 12], vec![50, 51], vec![60, 61]];
        let cfg = DraftConfig {
            max_drafts: 5,
            ..DraftConfig::new(2)
        };
        let drafts = extract_drafts_merged(&q(4), &cfg, &corpus);
        assert_eq!(drafts.len(), 5);
        let tokens: Vec<&Vec<i64>> = drafts.iter().map(|d| &d.tokens).collect();
        assert_eq!(tokens, vec![
            &vec![10, 11],
            &vec![11, 12],
            &vec![12, 13],
            &vec![50, 51],
            &vec![60, 61],
        ]);
        assert_eq!(drafts[2].source, DraftSource::QueryCopy);
        assert_eq!(drafts[3].source, DraftSource::Corpus);
        // Cross-source duplicate appears once, attributed to the query
        // (first occurrence wins).
        assert_eq!(
            drafts.iter().filter(|d| d.tokens == vec![11, 12]).count(),
            1
        );
        assert_eq!(drafts[1].source, DraftSource::QueryCopy);
    }

    #[test]
    fn query_windows_keep_priority_under_the_cap() {
        // Cap of 3 is filled by the query alone; corpus windows can only
        // fill leftover slots, never displace query copies.
        let corpus = vec![vec![90, 91], vec![92, 93]];
        let cfg = DraftConfig {
            max_drafts: 3,
            ..DraftConfig::new(2)
        };
        let drafts = extract_drafts_merged(&q(4), &cfg, &corpus);
        assert!(drafts.iter().all(|d| d.source == DraftSource::QueryCopy));
        let plain = extract_drafts(&q(4), &cfg);
        let tokens: Vec<Vec<i64>> = drafts.into_iter().map(|d| d.tokens).collect();
        assert_eq!(tokens, plain);
    }

    #[test]
    fn short_query_still_uses_corpus_windows() {
        // Query too short for its own windows: corpus drafts (of any
        // length) apply instead of the BOS sentinel.
        let corpus = vec![vec![40, 41, 42], vec![43, 44]];
        let drafts = extract_drafts_merged(&q(3), &DraftConfig::new(10), &corpus);
        assert_eq!(drafts.len(), 2);
        assert!(drafts.iter().all(|d| d.source == DraftSource::Corpus));
        // Empty corpus windows are skipped; nothing usable ⇒ sentinel.
        let empty = extract_drafts_merged(&q(3), &DraftConfig::new(10), &[vec![]]);
        assert_eq!(empty.len(), 1);
        assert_eq!(empty[0].source, DraftSource::Sentinel);
        assert_eq!(empty[0].tokens, vec![BOS_ID]);
    }

    #[test]
    fn dl_zero_ignores_corpus() {
        // DL=0 means "speculation off": the sentinel applies even with a
        // warm corpus, preserving SBS(DL=0) ≡ standard beam search.
        let corpus = vec![vec![40, 41]];
        let drafts = extract_drafts_merged(&q(10), &DraftConfig::new(0), &corpus);
        assert_eq!(drafts.len(), 1);
        assert_eq!(drafts[0].source, DraftSource::Sentinel);
    }

    #[test]
    fn acceptance_rate_math() {
        let mut a = Acceptance::default();
        a.merge(&Acceptance {
            accepted_draft_tokens: 39,
            total_tokens: 50,
        });
        assert!((a.rate() - 0.78).abs() < 1e-12);
        a.merge(&Acceptance {
            accepted_draft_tokens: 0,
            total_tokens: 0,
        });
        assert!((a.rate() - 0.78).abs() < 1e-12);
    }
}
