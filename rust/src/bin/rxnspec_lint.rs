//! `rxnspec-lint` — run the repo-invariant static-analysis pass.
//!
//! ```text
//! rxnspec-lint [--root <dir>] [--json <out>] [--knob-table]
//! ```
//!
//! Walks the repository (default: the workspace root containing this
//! crate) and prints one `file:line: rule: message` per finding. Exit
//! status: `0` clean, `1` findings, `2` operational error. `--json`
//! writes the findings as a machine-readable artifact (written even
//! when clean, so CI always has something to upload). `--knob-table`
//! prints the registry-generated README knob table and exits — the fix
//! for a `readme-knobs` finding.
//!
//! The binary links the `rxnspec` library, so every registry the rules
//! cross-check (`knobs::REGISTRY`, `faults::SITES`, `trace::N_PHASES`)
//! is the one the production code actually runs against.

use std::path::PathBuf;
use std::process::ExitCode;

use rxnspec::lint;

struct Opts {
    root: PathBuf,
    json: Option<PathBuf>,
    knob_table: bool,
}

fn parse_args() -> Result<Opts, String> {
    let mut opts = Opts {
        root: PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(".."),
        json: None,
        knob_table: false,
    };
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => {
                opts.root = args.next().map(PathBuf::from).ok_or("--root needs a path")?;
            }
            "--json" => {
                opts.json = Some(args.next().map(PathBuf::from).ok_or("--json needs a path")?);
            }
            "--knob-table" => opts.knob_table = true,
            "--help" | "-h" => {
                return Err("usage: rxnspec-lint [--root <dir>] [--json <out>] [--knob-table]"
                    .to_string())
            }
            other => return Err(format!("unknown argument {other:?}")),
        }
    }
    Ok(opts)
}

fn main() -> ExitCode {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(msg) => {
            eprintln!("{msg}");
            return ExitCode::from(2);
        }
    };
    if opts.knob_table {
        print!("{}", rxnspec::knobs::knob_table_markdown());
        return ExitCode::SUCCESS;
    }
    let findings = match lint::run_repo(&opts.root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("rxnspec-lint: {e:#}");
            return ExitCode::from(2);
        }
    };
    if let Some(path) = &opts.json {
        let doc = lint::findings_json(&findings);
        if let Err(e) = std::fs::write(path, doc.render() + "\n") {
            eprintln!("rxnspec-lint: write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        eprintln!("rxnspec-lint: clean ({} rules)", lint::RULES.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("rxnspec-lint: {} finding(s)", findings.len());
        ExitCode::FAILURE
    }
}
