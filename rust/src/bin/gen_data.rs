//! `gen-data` — render the synthetic reaction corpus to `data/`.
//!
//! Outputs:
//!   data/fwd_{train,val,test}.tsv    product-prediction task
//!   data/retro_{train,val,test}.tsv  single-step retrosynthesis task
//!   data/vocab.txt                   shared token vocabulary
//!   data/golden_tokens.tsv           tokenizer parity pins for pytest
//!
//! Usage: gen-data [--out DIR] [--seed N] [--train N] [--val N] [--test N]
//!                 [--retro-aug K] [--stats]

use std::path::PathBuf;

use anyhow::Result;

use rxnspec::chem::gen::longest_common_token_substring;
use rxnspec::chem::{generate_corpus, tokenize, write_split, CorpusConfig, Dataset};
use rxnspec::vocab::Vocab;

fn usage() -> ! {
    eprintln!(
        "usage: gen-data [--out DIR] [--seed N] [--train N] [--val N] [--test N] \
         [--retro-aug K] [--stats]"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let mut cfg = CorpusConfig::default();
    let mut out = PathBuf::from("data");
    let mut stats = false;

    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < args.len() {
        let need = |i: usize| args.get(i + 1).cloned().unwrap_or_else(|| usage());
        match args[i].as_str() {
            "--out" => {
                out = PathBuf::from(need(i));
                i += 2;
            }
            "--seed" => {
                cfg.seed = need(i).parse().unwrap_or_else(|_| usage());
                i += 2;
            }
            "--train" => {
                cfg.n_train = need(i).parse().unwrap_or_else(|_| usage());
                i += 2;
            }
            "--val" => {
                cfg.n_val = need(i).parse().unwrap_or_else(|_| usage());
                i += 2;
            }
            "--test" => {
                cfg.n_test = need(i).parse().unwrap_or_else(|_| usage());
                i += 2;
            }
            "--retro-aug" => {
                cfg.retro_aug = need(i).parse().unwrap_or_else(|_| usage());
                i += 2;
            }
            "--stats" => {
                stats = true;
                i += 1;
            }
            _ => usage(),
        }
    }

    eprintln!(
        "generating corpus: seed={} train={} val={} test={} retro_aug={}",
        cfg.seed, cfg.n_train, cfg.n_val, cfg.n_test, cfg.retro_aug
    );
    let corpus = generate_corpus(&cfg);
    std::fs::create_dir_all(&out)?;

    let write_task = |name: &str, ds: &Dataset| -> Result<()> {
        write_split(&out.join(format!("{name}_train.tsv")), &ds.train)?;
        write_split(&out.join(format!("{name}_val.tsv")), &ds.val)?;
        write_split(&out.join(format!("{name}_test.tsv")), &ds.test)?;
        eprintln!(
            "  {name}: train={} val={} test={}",
            ds.train.len(),
            ds.val.len(),
            ds.test.len()
        );
        Ok(())
    };
    write_task("fwd", &corpus.forward)?;
    write_task("retro", &corpus.retro)?;

    // Vocabulary over every string in the corpus (both tasks, all splits).
    let mut all: Vec<&str> = Vec::new();
    for ds in [&corpus.forward, &corpus.retro] {
        for split in [&ds.train, &ds.val, &ds.test] {
            for ex in split {
                all.push(&ex.src);
                all.push(&ex.tgt);
            }
        }
    }
    let vocab = Vocab::build(all.iter().copied())?;
    vocab.save(&out.join("vocab.txt"))?;
    eprintln!("  vocab: {} tokens", vocab.len());

    // Stock set for the CASP planner (examples/casp_planner.rs): every
    // molecule that appears as a *reactant* anywhere in the corpus counts
    // as purchasable — the AiZynthFinder convention (a purchasability
    // catalog spans the whole chemical space, not just training data).
    let mut stock: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();
    for split in [&corpus.retro.train, &corpus.retro.val, &corpus.retro.test] {
        for ex in split {
            for mol in ex.tgt.split('.') {
                stock.insert(mol);
            }
        }
    }
    let stock_body: String = stock.iter().map(|m| format!("{m}\n")).collect();
    std::fs::write(out.join("stock.txt"), stock_body)?;
    eprintln!("  stock: {} purchasable molecules", stock.len());

    // Golden tokenization pins: the Python tokenizer must produce the exact
    // same splits (checked by python/tests/test_tokenizer_parity.py).
    let mut golden = String::new();
    let mut pin_examples: Vec<&str> = vec![
        "c1c[nH]c2ccc(C(C)=O)cc12",
        "C(=O)(OC(=O)OC(C)(C)C)OC(C)(C)C",
        "BrCCCl.[Na+].[OH-]",
        "C%12CC%12",
    ];
    pin_examples.extend(corpus.forward.test.iter().take(50).map(|e| e.src.as_str()));
    for s in pin_examples {
        let toks = tokenize(s)?;
        golden.push_str(s);
        golden.push('\t');
        golden.push_str(&toks.join(" "));
        golden.push('\n');
    }
    std::fs::write(out.join("golden_tokens.tsv"), golden)?;

    if stats {
        print_stats(&corpus);
    }
    eprintln!("done: corpus written to {}", out.display());
    Ok(())
}

/// Per-template counts and source↔target longest-common-substring stats —
/// the corpus property that drives draft acceptance (DESIGN.md §3).
fn print_stats(corpus: &rxnspec::chem::Corpus) {
    use std::collections::HashMap;
    let mut by_template: HashMap<String, (usize, usize, usize)> = HashMap::new();
    for ex in &corpus.forward.test {
        let lcs = longest_common_token_substring(&ex.src, &ex.tgt);
        let n_tgt = tokenize(&ex.tgt).map(|t| t.len()).unwrap_or(0);
        let e = by_template.entry(ex.template.clone()).or_default();
        e.0 += 1;
        e.1 += lcs;
        e.2 += n_tgt;
    }
    println!("template\tcount\tavg_lcs_tokens\tavg_tgt_tokens");
    let mut keys: Vec<_> = by_template.keys().cloned().collect();
    keys.sort();
    for k in keys {
        let (n, lcs, tgt) = by_template[&k];
        println!(
            "{k}\t{n}\t{:.1}\t{:.1}",
            lcs as f64 / n as f64,
            tgt as f64 / n as f64
        );
    }
}
