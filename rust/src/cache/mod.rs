//! Cross-request reuse: a reaction result cache plus a corpus-learned
//! draft store.
//!
//! The paper accelerates one decode at a time; this subsystem accelerates
//! the *traffic*. Industrial workloads — multi-step retrosynthetic
//! planning above all — hit the single-step model with highly repetitive
//! queries, so two reuse mechanisms stack on top of speculative decoding:
//!
//! * [`ResultCache`] — a sharded, capacity-bounded LRU keyed by
//!   `(decoder kind, tokenized query)` that memoizes **completed**
//!   predictions. A hit skips decoding entirely and is served verbatim,
//!   bit-identical to the run that produced it.
//! * [`DraftStore`] — an n-gram index over previously accepted target
//!   windows. Its `top_k` windows are merged *behind* the paper's
//!   query-copy drafts (one shared dedup set, one shared `N_d` cap — see
//!   `draft::extract_drafts_merged`), giving the speculative decoders a
//!   corpus-learned draft source on top of the current query.
//!
//! # Exactness
//!
//! Neither component can change served content:
//!
//! * a `ResultCache` hit replays a stored completed output;
//! * a `DraftStore` window is only a *proposal* — the accept/reject rule
//!   compares every draft token against the model's own argmax, so for
//!   greedy-speculative decoding the emitted sequence is provably
//!   identical with the store warm, cold, or adversarially poisoned, and
//!   for SBS never-accepted corpus windows are provably output-neutral
//!   while accepted ones only deepen the verified greedy prefix (the same
//!   lever as raising `DL`, which Table 4 shows is accuracy-neutral —
//!   but which can reorder the candidate frontier, so the *serving*
//!   default keeps SBS corpus-free; see
//!   [`CacheConfig::corpus_drafts_for_sbs`]).
//!
//! Property tests in `rust/tests/cache_exactness.rs` pin all of this.

mod draft_store;
mod persist;
mod result_cache;
mod stats;

pub use draft_store::DraftStore;
pub use persist::{dump_to_path, load_into, LoadReport};
pub use result_cache::ResultCache;
pub use stats::{ArenaCounters, DraftStoreStats, ResultCacheStats};

/// Knobs for the serving-side cache pair.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheConfig {
    /// Master switch; `false` makes every component a no-op.
    pub enabled: bool,
    /// Total `ResultCache` entries across shards.
    pub result_capacity: usize,
    /// Independently locked LRU shards.
    pub result_shards: usize,
    /// Distinct target windows the `DraftStore` keeps.
    pub draft_capacity: usize,
    /// n-gram length recorded from completed targets.
    pub draft_window: usize,
    /// Corpus drafts fetched per request (they still share the
    /// `max_drafts` cap with query-copy windows).
    pub corpus_draft_budget: usize,
    /// Also feed corpus drafts to SBS requests. Off by default: accepted
    /// corpus windows deepen SBS's speculative lookahead, which — unlike
    /// greedy-spec — can reorder the candidate frontier, so served SBS
    /// outputs would depend on what the store happened to contain.
    /// Leaving this off keeps every served prediction bit-identical to
    /// the cold/disabled path (greedy-spec corpus drafts are provably
    /// output-neutral and stay on regardless).
    pub corpus_drafts_for_sbs: bool,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            enabled: true,
            result_capacity: 4096,
            result_shards: 8,
            draft_capacity: 4096,
            draft_window: 8,
            corpus_draft_budget: 8,
            corpus_drafts_for_sbs: false,
        }
    }
}

/// A memoized completed prediction, exactly as the worker replied it
/// (minus per-run cost counters, which are zero on a hit).
#[derive(Debug, Clone, PartialEq)]
pub struct CachedPrediction {
    /// (SMILES, cumulative log-prob) pairs, best first.
    pub hyps: Vec<(String, f64)>,
    /// Acceptance rate of the run that produced the entry.
    pub acceptance_rate: f64,
}

/// The serving coordinator's cache pair behind one handle.
pub struct ServeCache {
    cfg: CacheConfig,
    results: ResultCache<CachedPrediction>,
    drafts: DraftStore,
    artifact_version: std::sync::atomic::AtomicU64,
}

impl ServeCache {
    pub fn new(cfg: CacheConfig) -> ServeCache {
        ServeCache {
            results: ResultCache::new(cfg.result_capacity, cfg.result_shards),
            drafts: DraftStore::new(cfg.draft_window, cfg.draft_capacity),
            cfg,
            artifact_version: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Bind the cache pair to a model/artifact identity
    /// (`AnyBackend::artifact_version()`): cached predictions and mined
    /// draft windows are only valid per artifact version, so a redeploy
    /// (different weights or artifacts) flushes both stores and folds the
    /// new version into every future result-cache key. Rebinding the
    /// same version is a no-op — serving setup calls this once per
    /// backend load.
    pub fn bind_artifact_version(&self, version: u64) {
        use std::sync::atomic::Ordering;
        let old = self.artifact_version.swap(version, Ordering::Relaxed);
        self.results.set_version(version);
        if old != version {
            self.drafts.clear();
        }
    }

    /// A cache that never hits, never records, and fetches no drafts.
    pub fn disabled() -> ServeCache {
        ServeCache::new(CacheConfig {
            enabled: false,
            ..CacheConfig::default()
        })
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The artifact version the pair is currently bound to (0 until
    /// [`ServeCache::bind_artifact_version`] runs) — stamped into cache
    /// dumps so a warm boot can reject a dump from a different model.
    pub fn artifact_version(&self) -> u64 {
        self.artifact_version.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    pub fn results(&self) -> &ResultCache<CachedPrediction> {
        &self.results
    }

    pub fn drafts(&self) -> &DraftStore {
        &self.drafts
    }

    /// Corpus drafts for the next greedy-spec request (empty when
    /// disabled). Output-neutral there for any store content.
    pub fn corpus_drafts(&self) -> Vec<Vec<i64>> {
        if !self.cfg.enabled {
            return Vec::new();
        }
        self.drafts.top_k(self.cfg.corpus_draft_budget)
    }

    /// Corpus drafts for an SBS request — empty unless the operator
    /// opted in via [`CacheConfig::corpus_drafts_for_sbs`] (see that
    /// knob for why the default trades acceptance for strict
    /// replay-exactness).
    pub fn corpus_drafts_for_sbs(&self) -> Vec<Vec<i64>> {
        if !self.cfg.corpus_drafts_for_sbs {
            return Vec::new();
        }
        self.corpus_drafts()
    }

    /// One-line *occupancy* summary for the `STATS` serving surface.
    /// Traffic counters (hits/misses/inserts/evictions) live in the
    /// coordinator's `Metrics` snapshot — one copy per STATS reply, not
    /// two that must be kept in lockstep.
    pub fn describe(&self) -> String {
        let r = self.results.stats();
        let d = self.drafts.stats();
        format!(
            "cache: enabled={} results={}/{} draft_windows={}/{} windows_recorded={} \
             window_evictions={}",
            self.cfg.enabled, r.len, r.capacity, d.windows, d.capacity, d.recorded, d.evicted,
        )
    }
}

impl Default for ServeCache {
    fn default() -> Self {
        ServeCache::new(CacheConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_cache_roundtrip_is_verbatim() {
        let c = ServeCache::default();
        assert!(c.enabled());
        let pred = CachedPrediction {
            hyps: vec![("CCO".to_string(), -0.25)],
            acceptance_rate: 0.79,
        };
        c.results().insert(1, vec![4, 5, 6], pred.clone());
        assert_eq!(c.results().get(1, &[4, 5, 6]), Some(pred));
        assert!(c.results().get(2, &[4, 5, 6]).is_none());
    }

    #[test]
    fn disabled_cache_fetches_no_drafts() {
        let c = ServeCache::disabled();
        assert!(!c.enabled());
        c.drafts().record(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert!(c.corpus_drafts().is_empty());
        assert!(c.describe().contains("enabled=false"));
    }

    #[test]
    fn sbs_corpus_drafts_require_opt_in() {
        let c = ServeCache::default();
        c.drafts().record(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert!(!c.corpus_drafts().is_empty());
        assert!(
            c.corpus_drafts_for_sbs().is_empty(),
            "SBS must not see corpus drafts unless opted in"
        );
        let c2 = ServeCache::new(CacheConfig {
            corpus_drafts_for_sbs: true,
            ..CacheConfig::default()
        });
        c2.drafts().record(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert!(!c2.corpus_drafts_for_sbs().is_empty());
    }

    #[test]
    fn artifact_rebind_flushes_results_and_drafts() {
        let c = ServeCache::default();
        let pred = CachedPrediction {
            hyps: vec![("CCO".to_string(), -0.5)],
            acceptance_rate: 0.5,
        };
        c.results().insert(1, vec![4, 5], pred);
        c.drafts().record(&[1, 2, 3, 4, 5, 6, 7, 8]);
        c.bind_artifact_version(0xA11FA);
        assert!(
            c.results().get(1, &[4, 5]).is_none(),
            "prediction from the old model must not survive a redeploy"
        );
        assert!(c.results().is_empty());
        assert!(c.drafts().is_empty(), "mined windows are per-model too");
        // Same version again: entries written after the rebind survive.
        let pred2 = CachedPrediction {
            hyps: vec![("CC".to_string(), -0.1)],
            acceptance_rate: 0.0,
        };
        c.results().insert(1, vec![4, 5], pred2.clone());
        c.bind_artifact_version(0xA11FA);
        assert_eq!(c.results().get(1, &[4, 5]), Some(pred2));
    }

    #[test]
    fn describe_reports_occupancy() {
        let c = ServeCache::default();
        let pred = CachedPrediction {
            hyps: vec![],
            acceptance_rate: 0.0,
        };
        c.results().insert(0, vec![1], pred);
        c.drafts().record(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let s = c.describe();
        assert!(s.contains("results=1/4096"));
        assert!(s.contains("draft_windows=1/4096"));
        assert!(s.contains("windows_recorded=1"));
        // Traffic counters are the Metrics snapshot's job, not ours.
        assert!(!s.contains("hit_rate"));
    }
}
