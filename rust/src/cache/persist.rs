//! Cache persistence: dump the [`ServeCache`] pair to a file on graceful
//! drain, reload it on boot (warm start).
//!
//! Closes the ROADMAP "cache persistence" item: industrial traffic is
//! repetitive across *process lifetimes* too — a redeploy that cold-boots
//! the result cache and draft store throws away exactly the reuse the
//! serving layer exists to capture. The dump is a plain tab-separated
//! text file:
//!
//! ```text
//! rxnspec-cache-dump\tv1
//! version\t<artifact version, hex>
//! R\t<tag hex>\t<query csv>\t<acceptance f64 bits hex>\t<n hyps>\t<smiles>\t<score bits hex>...
//! D\t<window csv>\t<count>
//! end\t<record count>
//! ```
//!
//! Tab separation is safe because SMILES strings never contain
//! whitespace; scores round-trip through `f64::to_bits` hex so reloaded
//! predictions are **bit-identical** to what was served. `R` records are
//! written least-recently-used first (the [`ResultCache::export`] order)
//! so a capacity-bounded reload evicts the same entries the live cache
//! would have; `D` records keep first-seen order so `top_k` tie-breaks
//! survive the round trip.
//!
//! Versioning: the dump is stamped with the artifact version the cache
//! was bound to. [`load_into`] refuses a dump whose stamp differs from
//! the running backend's version — a model redeploy invalidates both
//! stores (same rule as [`ResultCache::set_version`]'s
//! flush-on-mismatch), and the server then simply boots cold.
//!
//! Crash safety: [`dump_to_path`] writes `<path>.tmp` and renames it into
//! place, so a crash mid-dump leaves the previous dump (or no dump)
//! intact, never a torn file.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{CachedPrediction, ServeCache};

const MAGIC: &str = "rxnspec-cache-dump\tv1";

/// What a successful [`load_into`] restored.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoadReport {
    /// Result-cache entries restored (marked warm).
    pub results: usize,
    /// Draft-store windows restored.
    pub windows: usize,
}

fn csv_i64(v: &[i64]) -> String {
    let mut s = String::new();
    for (i, t) in v.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(s, "{t}");
    }
    s
}

fn parse_csv_i64(s: &str) -> Result<Vec<i64>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|t| t.parse::<i64>().with_context(|| format!("bad token id {t:?}")))
        .collect()
}

/// Serialize the cache pair to `path` (write-tmp-then-rename). The dump
/// is stamped with the cache's bound artifact version. Returns the
/// number of records written.
pub fn dump_to_path(cache: &ServeCache, path: &Path) -> Result<usize> {
    let mut out = String::new();
    out.push_str(MAGIC);
    out.push('\n');
    let _ = writeln!(out, "version\t{:x}", cache.artifact_version());
    let mut n = 0usize;
    for (tag, query, pred) in cache.results().export() {
        let _ = write!(
            out,
            "R\t{tag:x}\t{}\t{:x}\t{}",
            csv_i64(&query),
            pred.acceptance_rate.to_bits(),
            pred.hyps.len()
        );
        for (smiles, score) in &pred.hyps {
            debug_assert!(
                !smiles.chars().any(|c| c.is_whitespace()),
                "SMILES must be whitespace-free"
            );
            let _ = write!(out, "\t{smiles}\t{:x}", score.to_bits());
        }
        out.push('\n');
        n += 1;
    }
    for (window, count) in cache.drafts().export() {
        let _ = writeln!(out, "D\t{}\t{count}", csv_i64(&window));
        n += 1;
    }
    let _ = writeln!(out, "end\t{n}");
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, out).with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming into {}", path.display()))?;
    Ok(n)
}

/// Restore a dump into `cache`, refusing it unless its stamped artifact
/// version equals `expect_version` (the running backend's). On a refusal
/// or parse error the cache is left untouched by result entries parsed
/// so far only if the error occurs before any record — records stream in
/// as parsed, so callers treat any `Err` as "boot cold": version and
/// magic are validated *before* the first record, and a torn tail (a
/// missing/`end` mismatch) aborts with the restored prefix still valid
/// (every restored entry is individually well-formed and version-bound).
pub fn load_into(cache: &ServeCache, path: &Path, expect_version: u64) -> Result<LoadReport> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    let mut lines = text.lines();
    match lines.next() {
        Some(l) if l == MAGIC => {}
        other => bail!("not a rxnspec cache dump (header {other:?})"),
    }
    let vline = lines.next().context("dump truncated before version line")?;
    let version = vline
        .strip_prefix("version\t")
        .with_context(|| format!("bad version line {vline:?}"))
        .and_then(|h| u64::from_str_radix(h, 16).context("bad version hex"))?;
    if version != expect_version {
        bail!(
            "cache dump artifact version mismatch: dump {version:#x}, running model \
             {expect_version:#x} — booting cold"
        );
    }
    let mut report = LoadReport::default();
    let mut seen = 0usize;
    let mut ended = false;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let mut f = line.split('\t');
        match f.next() {
            Some("R") => {
                let tag = f
                    .next()
                    .context("R: missing tag")
                    .and_then(|h| u64::from_str_radix(h, 16).context("R: bad tag hex"))?;
                let query = parse_csv_i64(f.next().context("R: missing query")?)?;
                let acc = f
                    .next()
                    .context("R: missing acceptance")
                    .and_then(|h| u64::from_str_radix(h, 16).context("R: bad acceptance hex"))
                    .map(f64::from_bits)?;
                let n_hyps: usize = f.next().context("R: missing hyp count")?.parse()?;
                let mut hyps = Vec::with_capacity(n_hyps);
                for i in 0..n_hyps {
                    let smiles = f.next().with_context(|| format!("R: missing hyp {i}"))?;
                    let score = f
                        .next()
                        .with_context(|| format!("R: missing score {i}"))
                        .and_then(|h| u64::from_str_radix(h, 16).context("R: bad score hex"))
                        .map(f64::from_bits)?;
                    hyps.push((smiles.to_string(), score));
                }
                cache.results().insert_warm(
                    tag,
                    query,
                    CachedPrediction {
                        hyps,
                        acceptance_rate: acc,
                    },
                );
                report.results += 1;
                seen += 1;
            }
            Some("D") => {
                let window = parse_csv_i64(f.next().context("D: missing window")?)?;
                let count: u64 = f.next().context("D: missing count")?.parse()?;
                cache.drafts().import_counted(&window, count);
                report.windows += 1;
                seen += 1;
            }
            Some("end") => {
                let n: usize = f.next().context("end: missing count")?.parse()?;
                if n != seen {
                    bail!("cache dump truncated: trailer says {n} records, found {seen}");
                }
                ended = true;
                break;
            }
            other => bail!("unknown dump record {other:?}"),
        }
    }
    if !ended {
        bail!("cache dump truncated: no end trailer");
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CacheConfig;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("rxnspec-persist-{}-{name}.dump", std::process::id()));
        p
    }

    fn seeded_cache(version: u64) -> ServeCache {
        let c = ServeCache::new(CacheConfig::default());
        c.bind_artifact_version(version);
        c.results().insert(
            1,
            vec![4, 5, 6],
            CachedPrediction {
                hyps: vec![("CCO".to_string(), -0.25), ("CC=O".to_string(), -1.5)],
                acceptance_rate: 0.79,
            },
        );
        c.results().insert(
            3 | (5 << 8),
            vec![9],
            CachedPrediction {
                hyps: vec![],
                acceptance_rate: 0.0,
            },
        );
        c.drafts().record(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        c.drafts().record_window(&[7, 7]);
        c
    }

    #[test]
    fn dump_reload_roundtrip_is_bit_identical_and_warm() {
        let path = tmp_path("roundtrip");
        let src = seeded_cache(0xFEED);
        let n = dump_to_path(&src, &path).unwrap();
        assert_eq!(n, 2 + src.drafts().len());

        let dst = ServeCache::new(CacheConfig::default());
        dst.bind_artifact_version(0xFEED);
        let report = load_into(&dst, &path, 0xFEED).unwrap();
        assert_eq!(report.results, 2);
        assert_eq!(report.windows, src.drafts().len());
        let hit = dst.results().get(1, &[4, 5, 6]).unwrap();
        assert_eq!(hit.hyps, vec![("CCO".to_string(), -0.25), ("CC=O".to_string(), -1.5)]);
        assert_eq!(hit.acceptance_rate.to_bits(), 0.79f64.to_bits());
        assert!(dst.results().get(3 | (5 << 8), &[9]).is_some());
        assert_eq!(dst.results().stats().warm_hits, 2, "reloaded hits count warm");
        assert_eq!(dst.drafts().top_k(16), src.drafts().top_k(16));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn version_mismatch_is_rejected_cleanly() {
        let path = tmp_path("mismatch");
        let src = seeded_cache(0xAAA);
        dump_to_path(&src, &path).unwrap();
        let dst = ServeCache::new(CacheConfig::default());
        dst.bind_artifact_version(0xBBB);
        let err = load_into(&dst, &path, 0xBBB).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
        assert!(dst.results().is_empty(), "rejected dump must not seed the cache");
        assert!(dst.drafts().is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_and_truncation_are_rejected() {
        let path = tmp_path("garbage");
        std::fs::write(&path, "not a dump\n").unwrap();
        let dst = ServeCache::new(CacheConfig::default());
        assert!(load_into(&dst, &path, 0).is_err());
        // A dump missing its end trailer is refused too.
        std::fs::write(&path, format!("{MAGIC}\nversion\t0\nD\t1,2\t3\n")).unwrap();
        let err = load_into(&dst, &path, 0).unwrap_err();
        assert!(err.to_string().contains("no end trailer"), "{err}");
        // Trailer count mismatch.
        std::fs::write(&path, format!("{MAGIC}\nversion\t0\nD\t1,2\t3\nend\t5\n")).unwrap();
        assert!(load_into(&dst, &path, 0).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_an_error_not_a_panic() {
        let dst = ServeCache::new(CacheConfig::default());
        assert!(load_into(&dst, Path::new("/nonexistent/rxnspec.dump"), 0).is_err());
    }

    #[test]
    fn empty_cache_dump_roundtrips() {
        let path = tmp_path("empty");
        let src = ServeCache::new(CacheConfig::default());
        src.bind_artifact_version(1);
        assert_eq!(dump_to_path(&src, &path).unwrap(), 0);
        let dst = ServeCache::new(CacheConfig::default());
        dst.bind_artifact_version(1);
        let report = load_into(&dst, &path, 1).unwrap();
        assert_eq!(report, LoadReport::default());
        std::fs::remove_file(&path).ok();
    }
}
