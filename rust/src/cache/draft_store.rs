//! Corpus-learned draft windows mined from previously accepted targets.
//!
//! The paper's drafting copies subsequences of the *current query* only
//! (§2.1). Industrial traffic is repetitive — multi-step planning hammers
//! the single-step model with recurring intermediates — so targets the
//! server already produced are a second, corpus-level draft source. The
//! store indexes fixed-length n-grams of completed target sequences with
//! occurrence counts; `top_k` returns the most frequently seen windows to
//! merge behind the query-copy drafts (one shared dedup set and the
//! shared `max_drafts` cap live in `draft::extract_drafts_merged`).
//!
//! Exactness: a corpus draft is a *proposal*, never an emission — the
//! accept/reject rule still compares every draft token against the
//! model's own argmax, so stale, foreign, or adversarially poisoned
//! windows cost at most wasted verify rows (see `tests/cache_exactness.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::lock_ok;

use super::stats::DraftStoreStats;

struct Entry {
    count: u64,
    /// First-observation order; breaks count ties deterministically.
    seq: u64,
}

struct Inner {
    counts: HashMap<Vec<i64>, Entry>,
    seq: u64,
}

/// Bounded n-gram index over accepted target windows.
pub struct DraftStore {
    window: usize,
    capacity: usize,
    inner: Mutex<Inner>,
    recorded: AtomicU64,
    evicted: AtomicU64,
}

impl DraftStore {
    /// `window`: n-gram length recorded from targets. `capacity`: max
    /// distinct windows kept (floored at 1).
    pub fn new(window: usize, capacity: usize) -> DraftStore {
        DraftStore {
            window: window.max(1),
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                counts: HashMap::new(),
                seq: 0,
            }),
            recorded: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
        }
    }

    /// Record every stride-1 window of a completed target sequence.
    pub fn record(&self, target: &[i64]) {
        if target.len() < self.window {
            return;
        }
        let mut guard = lock_ok(&self.inner);
        let inner = &mut *guard;
        let mut recorded = 0u64;
        for start in 0..=(target.len() - self.window) {
            let win = &target[start..start + self.window];
            inner.seq += 1;
            let seq = inner.seq;
            inner
                .counts
                .entry(win.to_vec())
                .and_modify(|e| e.count += 1)
                .or_insert(Entry { count: 1, seq });
            recorded += 1;
        }
        let evicted = evict_over_capacity(inner, self.capacity);
        drop(guard);
        self.recorded.fetch_add(recorded, Ordering::Relaxed);
        self.evicted.fetch_add(evicted, Ordering::Relaxed);
    }

    /// Record one window verbatim (any length) — used by tests to plant
    /// adversarial entries and by callers with pre-sliced windows.
    pub fn record_window(&self, window: &[i64]) {
        if window.is_empty() {
            return;
        }
        let mut guard = lock_ok(&self.inner);
        let inner = &mut *guard;
        inner.seq += 1;
        let seq = inner.seq;
        inner
            .counts
            .entry(window.to_vec())
            .and_modify(|e| e.count += 1)
            .or_insert(Entry { count: 1, seq });
        let evicted = evict_over_capacity(inner, self.capacity);
        drop(guard);
        self.recorded.fetch_add(1, Ordering::Relaxed);
        self.evicted.fetch_add(evicted, Ordering::Relaxed);
    }

    /// The `k` most established windows: highest count first, ties broken
    /// by earliest first observation (deterministic).
    pub fn top_k(&self, k: usize) -> Vec<Vec<i64>> {
        if k == 0 {
            return Vec::new();
        }
        let guard = lock_ok(&self.inner);
        let mut order: Vec<(u64, u64, &Vec<i64>)> = guard
            .counts
            .iter()
            .map(|(w, e)| (e.count, e.seq, w))
            .collect();
        order.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        order.into_iter().take(k).map(|(_, _, w)| w.clone()).collect()
    }

    /// Snapshot every indexed window as `(window, count)`, first-seen
    /// order (ascending `seq`). Replaying through
    /// [`DraftStore::import_counted`] in this order reproduces both the
    /// counts and the deterministic tie-break order of `top_k`.
    pub fn export(&self) -> Vec<(Vec<i64>, u64)> {
        let guard = lock_ok(&self.inner);
        let mut out: Vec<(u64, Vec<i64>, u64)> = guard
            .counts
            .iter()
            .map(|(w, e)| (e.seq, w.clone(), e.count))
            .collect();
        out.sort_by_key(|(seq, _, _)| *seq);
        out.into_iter().map(|(_, w, c)| (w, c)).collect()
    }

    /// Restore one window with an explicit occurrence count (warm boot
    /// from a persisted dump). Gets a fresh `seq`, so dump order defines
    /// the restored tie-break order; counts add if the window already
    /// exists.
    pub fn import_counted(&self, window: &[i64], count: u64) {
        if window.is_empty() || count == 0 {
            return;
        }
        let mut guard = lock_ok(&self.inner);
        let inner = &mut *guard;
        inner.seq += 1;
        let seq = inner.seq;
        inner
            .counts
            .entry(window.to_vec())
            .and_modify(|e| e.count += count)
            .or_insert(Entry { count, seq });
        let evicted = evict_over_capacity(inner, self.capacity);
        drop(guard);
        self.recorded.fetch_add(count, Ordering::Relaxed);
        self.evicted.fetch_add(evicted, Ordering::Relaxed);
    }

    /// Drop every indexed window (model redeploy: mined windows are only
    /// valid per artifact version — a new model's targets are a new
    /// corpus). The observation sequence keeps counting so tie-break
    /// order stays monotonic across flushes.
    pub fn clear(&self) {
        lock_ok(&self.inner).counts.clear();
    }

    /// Distinct windows currently indexed.
    pub fn len(&self) -> usize {
        lock_ok(&self.inner).counts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> DraftStoreStats {
        DraftStoreStats {
            windows: self.len(),
            capacity: self.capacity,
            recorded: self.recorded.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
        }
    }
}

/// Drop the weakest entries — lowest count, ties → oldest (smallest
/// `seq`), so among equally rare windows the store rotates toward recent
/// traffic and a full store of one-offs cannot fossilize.
///
/// Evicts in a batch down to ⅞ of `capacity` (not just to `capacity`),
/// so the O(n) threshold-select + retain pass runs once per
/// `capacity / 8` inserts instead of on every `record` at steady state —
/// this sits on the worker's completion path under the store mutex. No
/// per-entry clones: `(count, seq)` pairs are unique (seq is unique), so
/// a rank threshold identifies exactly the entries to retain.
fn evict_over_capacity(inner: &mut Inner, capacity: usize) -> u64 {
    if inner.counts.len() <= capacity {
        return 0;
    }
    let target = capacity - capacity / 8;
    let n_evict = inner.counts.len() - target;
    let mut ranks: Vec<(u64, u64)> = inner.counts.values().map(|e| (e.count, e.seq)).collect();
    ranks.select_nth_unstable(n_evict - 1);
    let threshold = ranks[n_evict - 1];
    inner.counts.retain(|_, e| (e.count, e.seq) > threshold);
    n_evict as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_slides_stride_one_windows() {
        let s = DraftStore::new(3, 64);
        s.record(&[1, 2, 3, 4]); // windows [1,2,3], [2,3,4]
        assert_eq!(s.len(), 2);
        assert_eq!(s.stats().recorded, 2);
        let top = s.top_k(10);
        assert!(top.contains(&vec![1, 2, 3]));
        assert!(top.contains(&vec![2, 3, 4]));
        // Too-short targets record nothing.
        s.record(&[7, 8]);
        assert_eq!(s.stats().recorded, 2);
    }

    #[test]
    fn top_k_orders_by_count_then_first_seen() {
        let s = DraftStore::new(2, 64);
        s.record(&[1, 2]); // [1,2] x1 (seq 1)
        s.record(&[3, 4]); // [3,4] x1 (seq 2)
        s.record(&[3, 4]); // [3,4] x2
        s.record(&[5, 6]); // [5,6] x1 (seq 4)
        let top = s.top_k(3);
        assert_eq!(top[0], vec![3, 4]); // highest count
        assert_eq!(top[1], vec![1, 2]); // tie → earliest seen
        assert_eq!(top[2], vec![5, 6]);
        assert_eq!(s.top_k(1).len(), 1);
        assert!(s.top_k(0).is_empty());
    }

    #[test]
    fn capacity_evicts_weakest_entries() {
        let s = DraftStore::new(2, 2);
        s.record_window(&[1, 1]);
        s.record_window(&[1, 1]); // established, count 2
        s.record_window(&[2, 2]);
        s.record_window(&[3, 3]); // over capacity: weakest-oldest goes
        assert_eq!(s.len(), 2);
        assert!(s.stats().evicted >= 1);
        let top = s.top_k(4);
        assert!(top.contains(&vec![1, 1]), "established window must survive");
        assert!(top.contains(&vec![3, 3]), "fresh window rotates in");
    }

    #[test]
    fn export_import_roundtrip_preserves_top_k_order() {
        let s = DraftStore::new(2, 64);
        s.record(&[1, 2]); // seq 1
        s.record(&[3, 4]); // seq 2
        s.record(&[3, 4]);
        s.record(&[5, 6]); // seq 4
        let dump = s.export();
        assert_eq!(dump.len(), 3);
        // First-seen order with counts intact.
        assert_eq!(dump[0], (vec![1, 2], 1));
        assert_eq!(dump[1], (vec![3, 4], 2));
        assert_eq!(dump[2], (vec![5, 6], 1));
        let s2 = DraftStore::new(2, 64);
        for (w, c) in &dump {
            s2.import_counted(w, *c);
        }
        assert_eq!(s2.top_k(3), s.top_k(3), "restored tie-break order must match");
        // Zero-count and empty imports are ignored.
        s2.import_counted(&[], 5);
        s2.import_counted(&[9, 9], 0);
        assert_eq!(s2.len(), 3);
    }

    #[test]
    fn mixed_window_lengths_coexist_via_record_window() {
        let s = DraftStore::new(4, 16);
        s.record_window(&[9, 9]);
        s.record(&[1, 2, 3, 4, 5]);
        assert_eq!(s.len(), 3);
        assert!(s.top_k(8).contains(&vec![9, 9]));
    }
}
