//! Sharded, capacity-bounded LRU memo of completed predictions.
//!
//! Keys are `(decoder-kind tag, tokenized query)`: two requests share an
//! entry only when both the query *and* the decoding procedure match, so a
//! hit can be served verbatim — bit-identical to what the decode produced
//! (the cache stores exactly the completed output, never a recompute).
//!
//! Sharding bounds lock contention on the serving path: the key hashes to
//! one of `n_shards` independently locked LRUs, each holding
//! `capacity / n_shards` entries. Recency is a per-shard logical clock —
//! a `BTreeMap<tick, key>` ordered index beside the `HashMap` — so both
//! touch and evict are O(log n), no intrusive list needed.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::lock_ok;

use super::stats::ResultCacheStats;

/// Cache key: a caller-chosen decoder-kind tag plus the tokenized query.
type Key = (u64, Vec<i64>);

struct Slot<V> {
    value: V,
    tick: u64,
    /// Loaded from a warm-boot dump (vs produced by a live decode) —
    /// lets the serving layer report how much of the hit traffic the
    /// persisted cache actually bought.
    warm: bool,
}

struct Shard<V> {
    map: HashMap<Key, Slot<V>>,
    /// tick → key, ascending = least recently used first.
    lru: BTreeMap<u64, Key>,
    clock: u64,
}

impl<V> Shard<V> {
    fn new() -> Shard<V> {
        Shard {
            map: HashMap::new(),
            lru: BTreeMap::new(),
            clock: 0,
        }
    }
}

/// The memo. Generic over the cached value so the serving coordinator
/// (completed replies) and the planner (disconnection lists) share one
/// implementation.
///
/// Entries are only valid for the model that produced them: the caller's
/// tag is combined with an **artifact version** ([`ResultCache::set_version`])
/// before keying, so entries written under one model identity can never
/// hit under another — and a version change additionally flushes every
/// shard (belt and suspenders: the fold guards even persisted/raced
/// entries, the flush reclaims the memory immediately).
pub struct ResultCache<V> {
    shards: Vec<Mutex<Shard<V>>>,
    shard_capacity: usize,
    version: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
    warm_hits: AtomicU64,
}

fn key_hash(tag: u64, query: &[i64]) -> u64 {
    let mut h = 0xcbf29ce484222325u64 ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for &t in query {
        h ^= t as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl<V: Clone> ResultCache<V> {
    /// `capacity` entries total, spread over `n_shards` locks (both
    /// floored at 1).
    pub fn new(capacity: usize, n_shards: usize) -> ResultCache<V> {
        let n = n_shards.max(1);
        ResultCache {
            shards: (0..n).map(|_| Mutex::new(Shard::new())).collect(),
            shard_capacity: capacity.div_ceil(n).max(1),
            version: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            warm_hits: AtomicU64::new(0),
        }
    }

    /// Fold the artifact version into a caller tag. Keys store the
    /// *effective* tag, so even an entry that somehow survived a flush
    /// (or arrived from a future persisted store) cannot hit across a
    /// model redeploy. XOR with a fixed multiple keeps the fold
    /// invertible: [`ResultCache::export`] applies the same fold again
    /// to recover the caller tag for persistence.
    fn effective_tag(&self, tag: u64) -> u64 {
        tag ^ self.version.load(Ordering::Relaxed).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Bind the cache to a model/artifact identity. A changed version
    /// flushes every shard (flush-on-mismatch) and re-tags all future
    /// keys; rebinding the same version is a no-op.
    pub fn set_version(&self, version: u64) {
        let old = self.version.swap(version, Ordering::Relaxed);
        if old != version {
            self.clear();
        }
    }

    /// Drop every entry (all shards).
    pub fn clear(&self) {
        for s in &self.shards {
            let mut g = lock_ok(s);
            g.map.clear();
            g.lru.clear();
        }
    }

    fn shard_of(&self, tag: u64, query: &[i64]) -> usize {
        (key_hash(tag, query) % self.shards.len() as u64) as usize
    }

    /// Look up a memoized value, refreshing its recency on a hit.
    pub fn get(&self, tag: u64, query: &[i64]) -> Option<V> {
        let tag = self.effective_tag(tag);
        let idx = self.shard_of(tag, query);
        let mut guard = lock_ok(&self.shards[idx]);
        let sh = &mut *guard;
        let key = (tag, query.to_vec());
        sh.clock += 1;
        let tick = sh.clock;
        if let Some(slot) = sh.map.get_mut(&key) {
            let old = slot.tick;
            slot.tick = tick;
            let value = slot.value.clone();
            let warm = slot.warm;
            sh.lru.remove(&old);
            sh.lru.insert(tick, key);
            self.hits.fetch_add(1, Ordering::Relaxed);
            if warm {
                self.warm_hits.fetch_add(1, Ordering::Relaxed);
            }
            Some(value)
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            None
        }
    }

    /// Insert (or refresh) an entry. Returns how many entries were
    /// evicted to make room (0 or 1).
    pub fn insert(&self, tag: u64, query: Vec<i64>, value: V) -> u64 {
        self.insert_inner(tag, query, value, false)
    }

    /// Insert an entry restored from a persisted dump: hits against it
    /// are counted as warm. A later live [`ResultCache::insert`] of the
    /// same key clears the flag (the entry is re-earned, not restored).
    pub fn insert_warm(&self, tag: u64, query: Vec<i64>, value: V) -> u64 {
        self.insert_inner(tag, query, value, true)
    }

    fn insert_inner(&self, tag: u64, query: Vec<i64>, value: V, warm: bool) -> u64 {
        let tag = self.effective_tag(tag);
        let idx = self.shard_of(tag, &query);
        let mut guard = lock_ok(&self.shards[idx]);
        let sh = &mut *guard;
        let key = (tag, query);
        sh.clock += 1;
        let tick = sh.clock;
        let mut evicted = 0u64;
        if let Some(slot) = sh.map.get_mut(&key) {
            let old = slot.tick;
            slot.tick = tick;
            slot.value = value;
            slot.warm = warm;
            sh.lru.remove(&old);
            sh.lru.insert(tick, key);
        } else {
            sh.map.insert(key.clone(), Slot { value, tick, warm });
            sh.lru.insert(tick, key);
            if sh.map.len() > self.shard_capacity {
                if let Some((_, lru_key)) = sh.lru.pop_first() {
                    sh.map.remove(&lru_key);
                    evicted = 1;
                }
            }
        }
        drop(guard);
        self.inserts.fetch_add(1, Ordering::Relaxed);
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        evicted
    }

    /// Snapshot every resident entry as `(caller tag, query, value)`,
    /// least recently used first (per shard, shards concatenated) — so a
    /// capacity-bounded reload replays inserts in an order that evicts
    /// the same entries the live cache would have. The version fold is
    /// undone (XOR is an involution), so the tags are the caller's
    /// original tags, portable across a dump/reload under the same
    /// artifact version.
    pub fn export(&self) -> Vec<(u64, Vec<i64>, V)> {
        let mut out = Vec::new();
        for s in &self.shards {
            let g = lock_ok(s);
            for (_, key) in g.lru.iter() {
                if let Some(slot) = g.map.get(key) {
                    out.push((self.effective_tag(key.0), key.1.clone(), slot.value.clone()));
                }
            }
        }
        out
    }

    /// Entries currently resident across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_ok(s).map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter + occupancy snapshot.
    pub fn stats(&self) -> ResultCacheStats {
        ResultCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            len: self.len(),
            capacity: self.shard_capacity * self.shards.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_bit_identical_and_counted() {
        let c: ResultCache<Vec<i64>> = ResultCache::new(16, 2);
        assert!(c.get(1, &[5, 6]).is_none());
        c.insert(1, vec![5, 6], vec![9, 8, 7]);
        assert_eq!(c.get(1, &[5, 6]), Some(vec![9, 8, 7]));
        // Same query, different decoder tag: a distinct entry.
        assert!(c.get(2, &[5, 6]).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.inserts, s.evictions), (1, 2, 1, 0));
        assert_eq!(s.len, 1);
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn insert_refreshes_existing_entry() {
        let c: ResultCache<i64> = ResultCache::new(4, 1);
        c.insert(0, vec![1], 10);
        c.insert(0, vec![1], 20);
        assert_eq!(c.get(0, &[1]), Some(20));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        // Single shard for a deterministic recency order.
        let c: ResultCache<i64> = ResultCache::new(3, 1);
        c.insert(0, vec![1], 1);
        c.insert(0, vec![2], 2);
        c.insert(0, vec![3], 3);
        // Touch [1] so [2] becomes the LRU entry.
        assert_eq!(c.get(0, &[1]), Some(1));
        let ev = c.insert(0, vec![4], 4);
        assert_eq!(ev, 1);
        assert!(c.get(0, &[2]).is_none(), "LRU entry must be evicted");
        assert_eq!(c.get(0, &[1]), Some(1));
        assert_eq!(c.get(0, &[3]), Some(3));
        assert_eq!(c.get(0, &[4]), Some(4));
        assert_eq!(c.len(), 3);
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn version_change_misses_and_flushes() {
        let c: ResultCache<i64> = ResultCache::new(8, 2);
        c.insert(1, vec![5, 6], 42);
        assert_eq!(c.get(1, &[5, 6]), Some(42));
        // Redeploy: a different artifact version must miss AND flush.
        c.set_version(0x0DD5EED);
        assert!(
            c.get(1, &[5, 6]).is_none(),
            "entry from the old model must not survive a redeploy"
        );
        assert_eq!(c.len(), 0, "flush-on-mismatch must drop all entries");
        c.insert(1, vec![5, 6], 43);
        assert_eq!(c.get(1, &[5, 6]), Some(43));
        // Rebinding the same version is a no-op.
        c.set_version(0x0DD5EED);
        assert_eq!(c.get(1, &[5, 6]), Some(43));
        // Another redeploy re-tags again.
        c.set_version(7);
        assert!(c.get(1, &[5, 6]).is_none());
    }

    #[test]
    fn capacity_is_bounded_across_shards() {
        let c: ResultCache<usize> = ResultCache::new(32, 4);
        for i in 0..1000usize {
            c.insert(7, vec![i as i64, (i * 31) as i64], i);
        }
        let s = c.stats();
        assert!(s.len <= s.capacity);
        assert!(s.evictions as usize >= 1000 - s.capacity);
    }

    #[test]
    fn export_recovers_caller_tags_under_any_version() {
        let c: ResultCache<i64> = ResultCache::new(8, 2);
        c.set_version(0xDEADBEEFu64);
        c.insert(1, vec![5, 6], 42);
        c.insert(9, vec![7], 43);
        let mut dump = c.export();
        dump.sort_by_key(|(tag, _, _)| *tag);
        assert_eq!(dump.len(), 2);
        assert_eq!(dump[0], (1, vec![5, 6], 42));
        assert_eq!(dump[1], (9, vec![7], 43));
        // Replaying the export into a fresh cache at the same version
        // reproduces the hits.
        let c2: ResultCache<i64> = ResultCache::new(8, 2);
        c2.set_version(0xDEADBEEFu64);
        for (tag, q, v) in dump {
            c2.insert_warm(tag, q, v);
        }
        assert_eq!(c2.get(1, &[5, 6]), Some(42));
        assert_eq!(c2.get(9, &[7]), Some(43));
    }

    #[test]
    fn warm_hits_counted_until_live_reinsert() {
        let c: ResultCache<i64> = ResultCache::new(8, 1);
        c.insert_warm(0, vec![1], 10);
        c.insert(0, vec![2], 20);
        assert_eq!(c.get(0, &[1]), Some(10));
        assert_eq!(c.get(0, &[2]), Some(20));
        assert_eq!(c.get(0, &[1]), Some(10));
        let s = c.stats();
        assert_eq!(s.hits, 3);
        assert_eq!(s.warm_hits, 2, "only dump-loaded entries count as warm");
        // A live insert over the warm key re-earns the entry.
        c.insert(0, vec![1], 11);
        assert_eq!(c.get(0, &[1]), Some(11));
        assert_eq!(c.stats().warm_hits, 2);
    }

    #[test]
    fn export_orders_lru_first_within_shard() {
        let c: ResultCache<i64> = ResultCache::new(4, 1);
        c.insert(0, vec![1], 1);
        c.insert(0, vec![2], 2);
        c.insert(0, vec![3], 3);
        // Touch [1]: it becomes most recent, so export must list it last.
        assert_eq!(c.get(0, &[1]), Some(1));
        let order: Vec<i64> = c.export().into_iter().map(|(_, q, _)| q[0]).collect();
        assert_eq!(order, vec![2, 3, 1]);
    }
}
