//! Point-in-time snapshots of cache state — the serving `STATS` surface
//! and the bench columns read these instead of poking at atomics.

/// Snapshot of a [`ResultCache`](super::ResultCache)'s counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResultCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Entries currently resident.
    pub len: usize,
    /// Total capacity across shards.
    pub capacity: usize,
}

impl ResultCacheStats {
    /// Hits over lookups; 0.0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.hits + self.misses;
        if lookups == 0 {
            0.0
        } else {
            self.hits as f64 / lookups as f64
        }
    }
}

/// Snapshot of a [`DraftStore`](super::DraftStore)'s counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DraftStoreStats {
    /// Distinct windows currently indexed.
    pub windows: usize,
    /// Maximum distinct windows kept.
    pub capacity: usize,
    /// Window observations recorded (including repeats).
    pub recorded: u64,
    /// Windows dropped by capacity eviction.
    pub evicted: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_handles_empty_and_mixed() {
        let mut s = ResultCacheStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        s.hits = 3;
        s.misses = 1;
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }
}
