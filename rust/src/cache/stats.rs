//! Point-in-time snapshots of cache state — the serving `STATS` surface
//! and the bench columns read these instead of poking at atomics.

use crate::decoding::{ArenaStats, SessionStats};

/// Snapshot of a [`ResultCache`](super::ResultCache)'s counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResultCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Hits served by entries restored from a warm-boot dump.
    pub warm_hits: u64,
    /// Entries currently resident.
    pub len: usize,
    /// Total capacity across shards.
    pub capacity: usize,
}

impl ResultCacheStats {
    /// Hits over lookups; 0.0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.hits + self.misses;
        if lookups == 0 {
            0.0
        } else {
            self.hits as f64 / lookups as f64
        }
    }
}

/// Snapshot of a [`DraftStore`](super::DraftStore)'s counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DraftStoreStats {
    /// Distinct windows currently indexed.
    pub windows: usize,
    /// Maximum distinct windows kept.
    pub capacity: usize,
    /// Window observations recorded (including repeats).
    pub recorded: u64,
    /// Windows dropped by capacity eviction.
    pub evicted: u64,
}

/// One snapshot of the paged-KV-arena counters, shared by every surface
/// that renders them: the `STATS` arena line, the kernel-bench JSON
/// entries, and the serving metrics absorption. Before this struct,
/// `worker.rs` and the benches each re-listed the fields by hand.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaCounters {
    /// Pages resident at snapshot time (gauge).
    pub kv_pages_resident: u64,
    /// High-water mark of resident pages.
    pub kv_pages_high_water: u64,
    /// Bytes of one page (K + V blobs).
    pub kv_page_bytes: u64,
    /// Pages evicted under `RXNSPEC_KV_BUDGET`.
    pub arena_evictions: u64,
    /// Pages deep-copied by copy-on-write divergence after forks.
    pub fork_pages_copied: u64,
    /// Pages rebuilt by the exact-recompute heal path.
    pub rehydrated_pages: u64,
}

impl ArenaCounters {
    /// Fold from a finished session's accounting (sessions do not track
    /// heal rehydration; that arrives via [`ArenaCounters::from_arena`]).
    pub fn from_session(s: &SessionStats) -> ArenaCounters {
        ArenaCounters {
            kv_pages_resident: s.kv_pages_resident as u64,
            kv_pages_high_water: s.kv_pages_high_water as u64,
            kv_page_bytes: s.kv_page_bytes as u64,
            arena_evictions: s.arena_evictions as u64,
            fork_pages_copied: s.fork_pages_copied as u64,
            rehydrated_pages: 0,
        }
    }

    /// Fold directly from a live arena's stats.
    pub fn from_arena(a: &ArenaStats) -> ArenaCounters {
        ArenaCounters {
            kv_pages_resident: a.pages_resident as u64,
            kv_pages_high_water: a.pages_high_water as u64,
            kv_page_bytes: a.page_bytes as u64,
            arena_evictions: a.evictions as u64,
            fork_pages_copied: a.fork_pages_copied as u64,
            rehydrated_pages: a.rehydrated_pages as u64,
        }
    }

    /// Bytes currently resident.
    pub fn kv_bytes_resident(&self) -> u64 {
        self.kv_pages_resident * self.kv_page_bytes
    }

    /// High-water residency in bytes.
    pub fn peak_kv_bytes(&self) -> u64 {
        self.kv_pages_high_water * self.kv_page_bytes
    }

    /// The `STATS` arena line (no trailing newline).
    pub fn render_line(&self) -> String {
        format!(
            "arena: kv_pages_resident={} kv_pages_high_water={} kv_page_bytes={} \
             kv_bytes_resident={} arena_evictions={} fork_pages_copied={}",
            self.kv_pages_resident,
            self.kv_pages_high_water,
            self.kv_page_bytes,
            self.kv_bytes_resident(),
            self.arena_evictions,
            self.fork_pages_copied,
        )
    }

    /// The kernel-bench JSON metrics (key names are the
    /// `BENCH_kernels.json` schema contract).
    pub fn bench_entries(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("fork_pages_copied", self.fork_pages_copied as f64),
            ("kv_pages_resident", self.kv_pages_resident as f64),
            ("peak_kv_bytes", self.peak_kv_bytes() as f64),
            ("arena_evictions", self.arena_evictions as f64),
            ("heal_rehydrated_pages", self.rehydrated_pages as f64),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_handles_empty_and_mixed() {
        let mut s = ResultCacheStats::default();
        assert_eq!(s.hit_rate(), 0.0);
        s.hits = 3;
        s.misses = 1;
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn arena_counters_render_one_format_everywhere() {
        let c = ArenaCounters {
            kv_pages_resident: 12,
            kv_pages_high_water: 20,
            kv_page_bytes: 4096,
            arena_evictions: 3,
            fork_pages_copied: 7,
            rehydrated_pages: 2,
        };
        let line = c.render_line();
        assert!(line.contains("kv_pages_resident=12"));
        assert!(line.contains("kv_bytes_resident=49152"));
        assert!(line.contains("arena_evictions=3"));
        assert_eq!(c.peak_kv_bytes(), 20 * 4096);
        let entries = c.bench_entries();
        assert_eq!(entries.iter().find(|(k, _)| *k == "peak_kv_bytes").unwrap().1, 81920.0);
        assert_eq!(
            entries.iter().find(|(k, _)| *k == "heal_rehydrated_pages").unwrap().1,
            2.0
        );
    }

    #[test]
    fn arena_counters_fold_from_session_and_arena() {
        let s = SessionStats {
            kv_pages_resident: 5,
            kv_pages_high_water: 9,
            kv_page_bytes: 128,
            arena_evictions: 1,
            fork_pages_copied: 4,
            ..SessionStats::default()
        };
        let c = ArenaCounters::from_session(&s);
        assert_eq!(c.kv_pages_resident, 5);
        assert_eq!(c.kv_bytes_resident(), 5 * 128);
        assert_eq!(c.rehydrated_pages, 0);
    }
}
