//! Deterministic, dependency-free pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so the whole stack
//! (corpus generation, augmentation, property tests, workload generators)
//! shares this SplitMix64 implementation. SplitMix64 passes BigCrush for
//! the 64-bit output stream and is trivially seedable, which keeps every
//! dataset and test case reproducible from a single `u64`.

/// SplitMix64 PRNG (Steele, Lea & Flood, 2014).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free reduction is fine here: n is tiny
        // relative to 2^64 so modulo bias is negligible, but we use the
        // widening-multiply trick anyway because it is branch-free.
        let x = self.next_u64();
        ((x as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Fork a child generator with an independent stream.
    ///
    /// Used to give each dataset split / worker / test case its own stream
    /// while keeping the parent reproducible.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(3);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            let v = r.range(2, 5);
            assert!((2..=5).contains(&v));
            lo_seen |= v == 2;
            hi_seen |= v == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut a = Rng::new(1);
        let mut c = a.fork();
        // The parent and child streams should not be identical.
        let pa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let pc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(pa, pc);
    }
}
