//! `rxnspec-lint`: the repo-invariant static-analysis pass.
//!
//! The paper's "3X faster with no loss in accuracy" claim rests on
//! conventions this crate enforces only by construction: the kernels'
//! no-FMA two-rounding contract, the `lock_ok` poison-recovery
//! discipline, audited `unsafe`, and a registry for every name that is
//! stringly shared between layers (env knobs, fault sites, trace
//! phases, bench metric keys). This module turns those conventions
//! into machine-checked rules over a lightweight line/token scan —
//! std-only, no syn, no regex — wired into CI and tier-1 via the
//! `rxnspec-lint` binary and `rust/tests/lint_clean.rs`.
//!
//! Rules (each [`Finding`] carries the rule name):
//!
//! * `float-contract` — `mul_add`/`fmadd`/`*_fast` float intrinsics are
//!   forbidden under `src/kernels/`, `src/decoding/`, `src/model/`:
//!   fusing single-rounds the accumulate and breaks bit parity across
//!   dispatch levels.
//! * `lock-discipline` — raw `.lock()` outside `coordinator/batcher.rs`
//!   (which defines [`lock_ok`](crate::coordinator::lock_ok)) must go
//!   through `lock_ok`, so a contained worker panic can never poison a
//!   shared mutex into a full-server outage.
//! * `unsafe-audit` — every `unsafe` token needs an adjacent
//!   `// SAFETY:` comment (or a `# Safety` doc section) within the
//!   contiguous comment/attribute block above it.
//! * `env-read` — direct `env::var` reads of `RXNSPEC_*` variables
//!   outside `src/knobs.rs`; all knob reads go through the typed
//!   registry accessors.
//! * `knob-literal` — every `RXNSPEC_*` literal in sources, workflows,
//!   and the README must be declared in [`crate::knobs::REGISTRY`].
//! * `fault-site` — every site literal passed to `faults::fire*` (and
//!   every site named in a CI `RXNSPEC_FAULTS` schedule) must be in
//!   [`crate::faults::SITES`].
//! * `trace-registry` — the `Phase` enum, `N_PHASES`, and the README
//!   phase glossary must agree.
//! * `bench-schema` — every metric key a bench merged into
//!   `BENCH_kernels.json` must match a `meta.schema_keys` /
//!   `meta.schema_row_keys` pattern.
//! * `readme-knobs` — the README knob table must equal
//!   [`crate::knobs::knob_table_markdown`] output.
//!
//! Comments and string/char literals are blanked before token rules
//! run, so documentation (and this module's own pattern strings) can
//! never trip a rule. A deliberate exception can be waived with a
//! `lint:allow(<rule>)` comment on the same or the preceding line.

use std::fmt;
use std::path::Path;

use anyhow::{Context, Result};

use crate::bench::json::{self, Val};

/// Every rule the pass can emit, in documentation order.
pub const RULES: &[&str] = &[
    "float-contract",
    "lock-discipline",
    "unsafe-audit",
    "env-read",
    "knob-literal",
    "fault-site",
    "trace-registry",
    "bench-schema",
    "readme-knobs",
];

/// One rule violation at a file location (line 0 = whole-file).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.msg)
    }
}

// ---------------------------------------------------------------------------
// Source stripping
// ---------------------------------------------------------------------------

/// Blank comments and string/char literals out of Rust source (replaced
/// by spaces, newlines preserved), so token rules see only code.
/// Handles `//`, nested `/* */`, `"…"` with escapes, `r"…"`/`r#"…"#`
/// raw strings (and their `b` byte variants), and char literals
/// (distinguished from lifetimes by their closing quote).
pub fn strip_rust(text: &str) -> Vec<String> {
    let b: Vec<char> = text.chars().collect();
    let mut out = String::with_capacity(text.len());
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == '/' && b.get(i + 1) == Some(&'/') {
            while i < b.len() && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
        } else if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(if b[i] == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
        } else if let Some(adv) = raw_string_len(&b, i) {
            for k in 0..adv {
                out.push(if b[i + k] == '\n' { '\n' } else { ' ' });
            }
            i += adv;
        } else if c == '"' {
            out.push(' ');
            i += 1;
            while i < b.len() {
                if b[i] == '\\' && i + 1 < b.len() {
                    // Preserve the newline of a `\`-continuation so
                    // line numbers stay aligned.
                    out.push(' ');
                    out.push(if b[i + 1] == '\n' { '\n' } else { ' ' });
                    i += 2;
                } else if b[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                } else {
                    out.push(if b[i] == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
        } else if c == '\'' {
            // Char literal vs lifetime: a literal closes with a quote.
            if b.get(i + 1) == Some(&'\\') {
                // Escaped char literal: blank through the closing quote.
                out.push(' ');
                i += 1;
                let mut escaped = false;
                while i < b.len() {
                    let d = b[i];
                    out.push(' ');
                    i += 1;
                    if escaped {
                        escaped = false;
                    } else if d == '\\' {
                        escaped = true;
                    } else if d == '\'' {
                        break;
                    }
                }
            } else if b.get(i + 2) == Some(&'\'') {
                out.push_str("   ");
                i += 3;
            } else {
                out.push(' ');
                i += 1;
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    out.lines().map(|l| l.to_string()).collect()
}

/// If a raw (or byte) string literal starts at `i`, return its total
/// char length; `None` otherwise. A preceding identifier char rules it
/// out (`var` vs `r"…"`).
fn raw_string_len(b: &[char], i: usize) -> Option<usize> {
    if i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_') {
        return None;
    }
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) != Some(&'"') {
        return None;
    }
    j += 1;
    while j < b.len() {
        if b[j] == '"' {
            let mut k = 0usize;
            while k < hashes && b.get(j + 1 + k) == Some(&'#') {
                k += 1;
            }
            if k == hashes {
                return Some(j + 1 + hashes - i);
            }
        }
        j += 1;
    }
    Some(b.len() - i)
}

/// Does line `idx` (or the line above it) carry a `lint:allow(<rule>)`
/// waiver for `rule`?
fn waived(raw: &[&str], idx: usize, rule: &str) -> bool {
    let carries = |l: &str| {
        l.find("lint:allow(").is_some_and(|p| {
            let rest = &l[p + "lint:allow(".len()..];
            rest.split(')').next().unwrap_or("").split(',').any(|r| r.trim() == rule)
        })
    };
    carries(raw[idx]) || (idx > 0 && carries(raw[idx - 1]))
}

fn word_at(line: &str, word: &str) -> Option<usize> {
    let bytes = line.as_bytes();
    let mut start = 0usize;
    while let Some(p) = line[start..].find(word) {
        let at = start + p;
        let before_ok = at == 0 || {
            let c = bytes[at - 1] as char;
            !c.is_alphanumeric() && c != '_'
        };
        let end = at + word.len();
        let after_ok = end >= bytes.len() || {
            let c = bytes[end] as char;
            !c.is_alphanumeric() && c != '_'
        };
        if before_ok && after_ok {
            return Some(at);
        }
        start = at + 1;
    }
    None
}

// ---------------------------------------------------------------------------
// Per-file Rust rules
// ---------------------------------------------------------------------------

const FORBIDDEN_FLOAT: &[&str] =
    &["mul_add", "fmadd", "fadd_fast", "fmul_fast", "fsub_fast", "fdiv_fast"];

/// Files where the bit-identity float contract applies.
fn float_zone(rel: &str) -> bool {
    rel.contains("src/kernels/") || rel.contains("src/decoding/") || rel.contains("src/model/")
}

/// Is the contiguous comment/attribute block ending just above line
/// `idx` (or the line itself) carrying a safety comment?
fn has_safety_comment(raw: &[&str], idx: usize) -> bool {
    let marks = |l: &str| l.contains("SAFETY:") || l.contains("# Safety");
    if marks(raw[idx]) {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = raw[j].trim_start();
        if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#!") {
            if marks(t) {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

/// Run every per-line rule over one Rust source. `rel` is the
/// forward-slash path from the repo root (it selects which zone rules
/// apply); fixture tests pass synthetic paths.
pub fn scan_rust_source(rel: &str, text: &str) -> Vec<Finding> {
    let raw: Vec<&str> = text.lines().collect();
    let stripped = strip_rust(text);
    let mut out = Vec::new();
    let in_float_zone = float_zone(rel);
    let lock_exempt = rel.ends_with("coordinator/batcher.rs");
    let env_exempt = rel.ends_with("src/knobs.rs");
    let fault_zone = rel.starts_with("rust/src/") && !rel.ends_with("faults/mod.rs");

    for (i, line) in stripped.iter().enumerate() {
        let lineno = i + 1;
        if in_float_zone {
            for pat in FORBIDDEN_FLOAT {
                if line.contains(pat) && !waived(&raw, i, "float-contract") {
                    out.push(Finding {
                        rule: "float-contract",
                        file: rel.to_string(),
                        line: lineno,
                        msg: format!(
                            "`{pat}` breaks the two-rounding bit-identity contract; \
                             use mul-then-add (see kernels::simd)"
                        ),
                    });
                }
            }
        }
        if !lock_exempt && line.contains(".lock()") && !waived(&raw, i, "lock-discipline") {
            out.push(Finding {
                rule: "lock-discipline",
                file: rel.to_string(),
                line: lineno,
                msg: "raw Mutex::lock; route through coordinator::lock_ok so a contained \
                      panic cannot poison shared state into an outage"
                    .to_string(),
            });
        }
        if word_at(line, "unsafe").is_some()
            && !has_safety_comment(&raw, i)
            && !waived(&raw, i, "unsafe-audit")
        {
            out.push(Finding {
                rule: "unsafe-audit",
                file: rel.to_string(),
                line: lineno,
                msg: "`unsafe` without an adjacent `// SAFETY:` comment".to_string(),
            });
        }
        if !env_exempt
            && (raw[i].contains("var(\"RXNSPEC") || raw[i].contains("var_os(\"RXNSPEC"))
            && !waived(&raw, i, "env-read")
        {
            out.push(Finding {
                rule: "env-read",
                file: rel.to_string(),
                line: lineno,
                msg: "direct RXNSPEC_* env read; go through the typed knobs registry \
                      (rust/src/knobs.rs)"
                    .to_string(),
            });
        }
        if fault_zone {
            for site in fire_site_literals(raw[i]) {
                if !crate::faults::SITES.contains(&site.as_str())
                    && !waived(&raw, i, "fault-site")
                {
                    out.push(Finding {
                        rule: "fault-site",
                        file: rel.to_string(),
                        line: lineno,
                        msg: format!("fault site {site:?} is not declared in faults::SITES"),
                    });
                }
            }
        }
    }
    out
}

/// Site literals passed to `faults::fire` / `fire_infallible` / `fires`
/// on one raw line.
fn fire_site_literals(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut start = 0usize;
    while let Some(p) = line[start..].find("faults::fire") {
        let at = start + p + "faults::fire".len();
        let rest = &line[at..];
        let call = rest
            .strip_prefix("_infallible(")
            .or_else(|| rest.strip_prefix("s("))
            .or_else(|| rest.strip_prefix("("));
        if let Some(args) = call {
            if let Some(lit) = args.strip_prefix('"') {
                if let Some(end) = lit.find('"') {
                    out.push(lit[..end].to_string());
                }
            }
        }
        start = at;
    }
    out
}

// ---------------------------------------------------------------------------
// Knob-literal rule (any file kind)
// ---------------------------------------------------------------------------

/// Every `RXNSPEC_<CAPS>` token in `text`, with its 1-based line.
pub fn knob_tokens(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let bytes = line.as_bytes();
        let mut start = 0usize;
        while let Some(p) = line[start..].find("RXNSPEC_") {
            let at = start + p;
            let before_ok = at == 0 || {
                let c = bytes[at - 1] as char;
                !c.is_alphanumeric() && c != '_'
            };
            let mut end = at + "RXNSPEC_".len();
            while end < line.len() {
                let c = bytes[end] as char;
                if c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_' {
                    end += 1;
                } else {
                    break;
                }
            }
            // A bare `RXNSPEC_` (docs writing `RXNSPEC_*`) is a
            // wildcard mention, not a knob name.
            if before_ok && end > at + "RXNSPEC_".len() {
                out.push((i + 1, line[at..end].trim_end_matches('_').to_string()));
            }
            start = at + 1;
        }
    }
    out
}

/// `knob-literal`: every token must resolve in the typed registry.
pub fn check_knob_literals(rel: &str, text: &str) -> Vec<Finding> {
    let raw: Vec<&str> = text.lines().collect();
    knob_tokens(text)
        .into_iter()
        .filter(|(line, name)| {
            crate::knobs::lookup(name).is_none() && !waived(&raw, line - 1, "knob-literal")
        })
        .map(|(line, name)| Finding {
            rule: "knob-literal",
            file: rel.to_string(),
            line,
            msg: format!("{name} is not declared in knobs::REGISTRY"),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Repo-level registries
// ---------------------------------------------------------------------------

/// `trace-registry`: phase names unique, the `Phase` enum's variant
/// count equal to `N_PHASES`, and every name present in the README
/// phase glossary.
fn check_trace_registry(trace_src: &str, readme: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for p in crate::trace::ALL_PHASES {
        if !seen.insert(p.name()) {
            out.push(Finding {
                rule: "trace-registry",
                file: "rust/src/trace/mod.rs".into(),
                line: 0,
                msg: format!("duplicate phase name {:?}", p.name()),
            });
        }
        if !readme.contains(&format!("`{}`", p.name())) {
            out.push(Finding {
                rule: "trace-registry",
                file: "README.md".into(),
                line: 0,
                msg: format!("phase `{}` missing from the README phase glossary", p.name()),
            });
        }
    }
    let stripped = strip_rust(trace_src);
    let mut variants = 0usize;
    let mut in_enum = false;
    for line in &stripped {
        let t = line.trim();
        if t.starts_with("pub enum Phase") {
            in_enum = true;
            continue;
        }
        if in_enum {
            if t.starts_with('}') {
                break;
            }
            if t.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
                variants += 1;
            }
        }
    }
    if variants != crate::trace::N_PHASES {
        out.push(Finding {
            rule: "trace-registry",
            file: "rust/src/trace/mod.rs".into(),
            line: 0,
            msg: format!(
                "Phase enum declares {variants} variants but N_PHASES = {} — keep the enum, \
                 N_PHASES, ALL_PHASES, and name() in sync",
                crate::trace::N_PHASES
            ),
        });
    }
    out
}

/// Glob match with `*` as the only metacharacter.
pub fn glob_match(pattern: &str, s: &str) -> bool {
    fn inner(p: &[u8], s: &[u8]) -> bool {
        match p.first() {
            None => s.is_empty(),
            Some(b'*') => {
                (0..=s.len()).any(|k| inner(&p[1..], &s[k..]))
            }
            Some(&c) => s.first() == Some(&c) && inner(&p[1..], &s[1..]),
        }
    }
    inner(pattern.as_bytes(), s.as_bytes())
}

fn schema_patterns(meta: &Val, key: &str) -> Option<Vec<String>> {
    match meta.get(key) {
        Some(Val::Arr(items)) => Some(
            items
                .iter()
                .filter_map(|v| match v {
                    Val::Str(s) => Some(s.clone()),
                    _ => None,
                })
                .collect(),
        ),
        _ => None,
    }
}

/// `bench-schema`: every key in every non-meta section of the perf
/// trajectory must match a declared `meta.schema_keys` pattern (or, for
/// per-configuration row objects, `meta.schema_row_keys`).
pub fn check_bench_schema(doc: &Val, file: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let fail = |msg: String| Finding { rule: "bench-schema", file: file.to_string(), line: 0, msg };
    let Some(meta) = doc.get("meta") else {
        return vec![fail("missing meta section".into())];
    };
    let Some(keys) = schema_patterns(meta, "schema_keys") else {
        return vec![fail("meta.schema_keys (array of key patterns) is missing".into())];
    };
    let Some(row_keys) = schema_patterns(meta, "schema_row_keys") else {
        return vec![fail("meta.schema_row_keys (array of key patterns) is missing".into())];
    };
    let Val::Obj(sections) = doc else {
        return vec![fail("root is not an object".into())];
    };
    for (section, val) in sections {
        if section == "meta" {
            continue;
        }
        let Val::Obj(entries) = val else {
            out.push(fail(format!("section {section:?} is not an object")));
            continue;
        };
        for (k, v) in entries {
            match v {
                Val::Num(_) | Val::Str(_) => {
                    if !keys.iter().any(|p| glob_match(p, k)) {
                        out.push(fail(format!(
                            "{section}.{k} matches no meta.schema_keys pattern"
                        )));
                    }
                }
                Val::Obj(inner) => {
                    for (ik, iv) in inner {
                        if !matches!(iv, Val::Num(_)) {
                            out.push(fail(format!(
                                "{section}.{k}.{ik}: row metrics must be numbers"
                            )));
                        }
                        if !row_keys.iter().any(|p| glob_match(p, ik)) {
                            out.push(fail(format!(
                                "{section}.{k}.{ik} matches no meta.schema_row_keys pattern"
                            )));
                        }
                    }
                }
                other => {
                    out.push(fail(format!(
                        "{section}.{k}: unexpected value shape {other:?}"
                    )));
                }
            }
        }
    }
    out
}

/// `readme-knobs`: the table between the knob-table markers must equal
/// the registry-generated one.
fn check_readme_knobs(readme: &str) -> Vec<Finding> {
    const BEGIN: &str = "<!-- knob-table:begin -->";
    const END: &str = "<!-- knob-table:end -->";
    let fail = |msg: String| {
        vec![Finding { rule: "readme-knobs", file: "README.md".into(), line: 0, msg }]
    };
    let Some(b) = readme.find(BEGIN) else {
        return fail(format!("marker {BEGIN:?} missing"));
    };
    let Some(e) = readme.find(END) else {
        return fail(format!("marker {END:?} missing"));
    };
    if e < b {
        return fail("knob-table markers are out of order".into());
    }
    let committed = readme[b + BEGIN.len()..e].trim();
    let generated = crate::knobs::knob_table_markdown();
    if committed != generated.trim() {
        return fail(
            "knob table is stale; regenerate with `cargo run --bin rxnspec-lint -- --knob-table`"
                .into(),
        );
    }
    Vec::new()
}

/// CI fault schedules: every `faults:` value in a workflow must parse
/// under the `RXNSPEC_FAULTS` grammar and name only registered sites.
fn check_workflow_faults(rel: &str, text: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let Some(p) = line.find("faults:") else { continue };
        let val = line[p + "faults:".len()..].trim();
        let Some(stripped) = val.strip_prefix('"') else { continue };
        let Some(end) = stripped.find('"') else { continue };
        let spec = &stripped[..end];
        if spec.is_empty() {
            continue;
        }
        match crate::faults::parse_spec(spec) {
            Err(e) => out.push(Finding {
                rule: "fault-site",
                file: rel.to_string(),
                line: i + 1,
                msg: format!("RXNSPEC_FAULTS schedule does not parse: {e}"),
            }),
            Ok(plan) => {
                for r in &plan.rules {
                    if !crate::faults::SITES.contains(&r.site.as_str()) {
                        out.push(Finding {
                            rule: "fault-site",
                            file: rel.to_string(),
                            line: i + 1,
                            msg: format!(
                                "CI fault schedule names unregistered site {:?}",
                                r.site
                            ),
                        });
                    }
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Repo walk
// ---------------------------------------------------------------------------

fn walk_ext(dir: &Path, ext: &str, out: &mut Vec<std::path::PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    let mut entries: Vec<_> = rd.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_ext(&p, ext, out);
        } else if p.extension().and_then(|e| e.to_str()) == Some(ext) {
            out.push(p);
        }
    }
}

fn rel_str(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Run every rule over the repository at `root` (the workspace root —
/// the directory holding `rust/`, `examples/`, `README.md`).
pub fn run_repo(root: &Path) -> Result<Vec<Finding>> {
    let mut findings = Vec::new();

    let mut rust_files = Vec::new();
    for dir in ["rust/src", "rust/tests", "rust/benches", "examples"] {
        walk_ext(&root.join(dir), "rs", &mut rust_files);
    }
    for path in &rust_files {
        let rel = rel_str(root, path);
        let text = std::fs::read_to_string(path).with_context(|| format!("read {rel}"))?;
        findings.extend(scan_rust_source(&rel, &text));
        findings.extend(check_knob_literals(&rel, &text));
    }

    let mut workflows = Vec::new();
    walk_ext(&root.join(".github/workflows"), "yml", &mut workflows);
    walk_ext(&root.join(".github/workflows"), "yaml", &mut workflows);
    for path in &workflows {
        let rel = rel_str(root, path);
        let text = std::fs::read_to_string(path).with_context(|| format!("read {rel}"))?;
        findings.extend(check_knob_literals(&rel, &text));
        findings.extend(check_workflow_faults(&rel, &text));
    }

    let readme = std::fs::read_to_string(root.join("README.md")).context("read README.md")?;
    findings.extend(check_knob_literals("README.md", &readme));
    findings.extend(check_readme_knobs(&readme));

    let trace_src = std::fs::read_to_string(root.join("rust/src/trace/mod.rs"))
        .context("read rust/src/trace/mod.rs")?;
    findings.extend(check_trace_registry(&trace_src, &readme));

    let bench_path = root.join("BENCH_kernels.json");
    let bench_rel = "BENCH_kernels.json";
    match std::fs::read_to_string(&bench_path) {
        Err(e) => findings.push(Finding {
            rule: "bench-schema",
            file: bench_rel.into(),
            line: 0,
            msg: format!("unreadable: {e}"),
        }),
        Ok(body) => match json::parse(&body) {
            Err(e) => findings.push(Finding {
                rule: "bench-schema",
                file: bench_rel.into(),
                line: 0,
                msg: format!("unparsable: {e}"),
            }),
            Ok(doc) => findings.extend(check_bench_schema(&doc, bench_rel)),
        },
    }

    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(findings)
}

/// Findings as the machine-readable artifact CI uploads.
pub fn findings_json(findings: &[Finding]) -> Val {
    Val::Obj(vec![
        ("count".into(), Val::num(findings.len() as f64)),
        (
            "findings".into(),
            Val::Arr(
                findings
                    .iter()
                    .map(|f| {
                        Val::Obj(vec![
                            ("rule".into(), Val::str(f.rule)),
                            ("file".into(), Val::str(&f.file)),
                            ("line".into(), Val::num(f.line as f64)),
                            ("msg".into(), Val::str(&f.msg)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}
