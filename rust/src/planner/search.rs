//! Best-first route search over single-step disconnections.
//!
//! A simplified Retro*/AiZynthFinder-style planner: nodes are partial
//! routes (a set of still-unsolved molecules plus the steps taken), the
//! frontier is a max-heap on cumulative model confidence, and a node
//! budget bounds total single-step calls. Optionally each disconnection
//! is round-trip checked with the forward (product-prediction) model —
//! the standard CASP consistency filter, and a nice use of both of this
//! repo's trained artifacts in one system.
//!
//! **Expansion memoization**: retrosynthetic search trees revisit the
//! same intermediate on different branches constantly — and separate
//! targets share intermediates too. A shared [`PlannerCache`] (the cache
//! subsystem's [`ResultCache`] over disconnection lists) threads through
//! expansions so each distinct molecule costs one single-step model call
//! per cache lifetime; `PlanStats::cache_hits` counts the saved calls.

use std::collections::BinaryHeap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cache::ResultCache;

use super::{Disconnection, SingleStepModel, Stock};

/// Shared memo of single-step proposals, keyed by (beam width, molecule).
/// Share one cache per underlying model only — entries are raw model
/// output, so two different models must not exchange them.
pub type PlannerCache = ResultCache<Vec<Disconnection>>;

/// Cache key for a molecule SMILES (the cache subsystem keys on token
/// sequences; byte values serve for strings).
fn mol_key(mol: &str) -> Vec<i64> {
    mol.bytes().map(|b| b as i64).collect()
}

/// Forward-model interface for round-trip checking.
pub trait ForwardCheck {
    /// Predict the major product of `reactants`.
    fn predict(&self, reactants: &[String]) -> Result<String>;
}

/// No-op checker (round-trip filtering disabled).
impl ForwardCheck for () {
    fn predict(&self, _: &[String]) -> Result<String> {
        anyhow::bail!("no forward model")
    }
}

/// Planner configuration.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Disconnections requested per expansion (the single-step beam n).
    pub n_suggestions: usize,
    /// Maximum route depth (reaction steps along one branch).
    pub max_depth: usize,
    /// Maximum number of node expansions (≈ single-step model calls).
    pub expansion_budget: usize,
    /// Reject disconnections whose forward prediction does not regenerate
    /// the product (requires a forward model).
    pub roundtrip_filter: bool,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            n_suggestions: 5,
            max_depth: 4,
            expansion_budget: 50,
            roundtrip_filter: false,
        }
    }
}

/// One retro step of a solved route.
#[derive(Debug, Clone, PartialEq)]
pub struct RouteStep {
    pub product: String,
    pub reactants: Vec<String>,
    pub score: f64,
}

/// A solved synthesis route (steps in retrosynthetic order: target first).
#[derive(Debug, Clone, PartialEq)]
pub struct Route {
    pub target: String,
    pub steps: Vec<RouteStep>,
    pub score: f64,
}

impl Route {
    /// Starting materials (leaves) of the route.
    pub fn leaves(&self) -> Vec<&str> {
        let products: std::collections::HashSet<&str> =
            self.steps.iter().map(|s| s.product.as_str()).collect();
        let mut out = Vec::new();
        for s in &self.steps {
            for r in &s.reactants {
                if !products.contains(r.as_str()) {
                    out.push(r.as_str());
                }
            }
        }
        if self.steps.is_empty() {
            out.push(self.target.as_str());
        }
        out
    }

    /// Human-readable multi-line rendering.
    pub fn render(&self) -> String {
        let mut s = format!("route for {} (score {:.3}):\n", self.target, self.score);
        for (i, step) in self.steps.iter().enumerate() {
            s.push_str(&format!(
                "  {}. {}  <=  {}   ({:.3})\n",
                i + 1,
                step.product,
                step.reactants.join(" + "),
                step.score
            ));
        }
        s
    }
}

/// Search instrumentation.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanStats {
    pub expansions: usize,
    pub nodes_generated: usize,
    /// Expansions whose proposals came from the shared [`PlannerCache`]
    /// instead of a single-step model call.
    pub cache_hits: usize,
    pub solved: bool,
    pub wall: std::time::Duration,
}

#[derive(Debug, Clone)]
struct Node {
    /// Molecules still to be made (none ⇒ solved).
    open: Vec<String>,
    steps: Vec<RouteStep>,
    score: f64,
    depth: usize,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.score == other.score
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap on score; fewer open molecules break ties.
        self.score
            .partial_cmp(&other.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| other.open.len().cmp(&self.open.len()))
    }
}

/// The planner. Generic over the single-step model (decoding stack or
/// test stub) and the optional forward checker.
pub struct Planner<'a, M: SingleStepModel, F: ForwardCheck = ()> {
    pub model: &'a M,
    pub stock: &'a Stock,
    pub forward: Option<&'a F>,
    pub cfg: PlannerConfig,
    /// Shared expansion memo; `None` disables memoization. Shareable
    /// across `plan` calls and planner instances over the same model.
    pub cache: Option<Arc<PlannerCache>>,
}

impl<'a, M: SingleStepModel> Planner<'a, M, ()> {
    pub fn new(model: &'a M, stock: &'a Stock, cfg: PlannerConfig) -> Self {
        Planner {
            model,
            stock,
            forward: None,
            cfg,
            cache: None,
        }
    }
}

impl<'a, M: SingleStepModel, F: ForwardCheck> Planner<'a, M, F> {
    pub fn with_forward(
        model: &'a M,
        stock: &'a Stock,
        forward: &'a F,
        cfg: PlannerConfig,
    ) -> Self {
        Planner {
            model,
            stock,
            forward: Some(forward),
            cfg,
            cache: None,
        }
    }

    /// Attach a shared expansion memo (builder style).
    pub fn with_cache(mut self, cache: Arc<PlannerCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// One molecule's proposals, via the shared cache when attached. The
    /// cache stores raw model output (pre-filter: `accept` is
    /// node-dependent and re-runs per expansion).
    fn propose_cached(&self, mol: &str, stats: &mut PlanStats) -> Result<Vec<Disconnection>> {
        let Some(cache) = &self.cache else {
            return self.model.propose(mol, self.cfg.n_suggestions);
        };
        let tag = self.cfg.n_suggestions as u64;
        let key = mol_key(mol);
        if let Some(hit) = cache.get(tag, &key) {
            stats.cache_hits += 1;
            return Ok(hit);
        }
        let proposals = self.model.propose(mol, self.cfg.n_suggestions)?;
        cache.insert(tag, key, proposals.clone());
        Ok(proposals)
    }

    /// Search for a route that turns `target` into stock molecules.
    pub fn plan(&self, target: &str) -> Result<(Option<Route>, PlanStats)> {
        let t0 = Instant::now();
        let mut stats = PlanStats::default();

        if self.stock.contains(target) {
            stats.solved = true;
            stats.wall = t0.elapsed();
            return Ok((
                Some(Route {
                    target: target.to_string(),
                    steps: Vec::new(),
                    score: 0.0,
                }),
                stats,
            ));
        }

        let mut heap = BinaryHeap::new();
        heap.push(Node {
            open: vec![target.to_string()],
            steps: Vec::new(),
            score: 0.0,
            depth: 0,
        });

        while let Some(node) = heap.pop() {
            if node.open.is_empty() {
                stats.solved = true;
                stats.wall = t0.elapsed();
                return Ok((
                    Some(Route {
                        target: target.to_string(),
                        steps: node.steps,
                        score: node.score,
                    }),
                    stats,
                ));
            }
            if stats.expansions >= self.cfg.expansion_budget {
                break;
            }
            if node.depth >= self.cfg.max_depth {
                continue; // dead branch: too deep, unsolved molecules left
            }

            // Expand the first open molecule.
            let mol = node.open[0].clone();
            stats.expansions += 1;
            let proposals = self.propose_cached(&mol, &mut stats)?;
            for d in proposals {
                if !self.accept(&mol, &d, &node) {
                    continue;
                }
                let mut open: Vec<String> = node.open[1..].to_vec();
                for r in &d.reactants {
                    if !self.stock.contains(r) {
                        open.push(r.clone());
                    }
                }
                let mut steps = node.steps.clone();
                steps.push(RouteStep {
                    product: mol.clone(),
                    reactants: d.reactants.clone(),
                    score: d.score,
                });
                stats.nodes_generated += 1;
                heap.push(Node {
                    open,
                    steps,
                    score: node.score + d.score,
                    depth: node.depth + 1,
                });
            }
        }
        stats.wall = t0.elapsed();
        Ok((None, stats))
    }

    /// Sanity + optional round-trip filters for one disconnection.
    fn accept(&self, product: &str, d: &Disconnection, node: &Node) -> bool {
        // Degenerate or cyclic proposals.
        if d.reactants.is_empty() || d.reactants.iter().any(|r| r.is_empty()) {
            return false;
        }
        if d.reactants.iter().any(|r| r == product) {
            return false;
        }
        // A molecule we are already trying to make upstream ⇒ cycle.
        if node.steps.iter().any(|s| d.reactants.contains(&s.product)) {
            return false;
        }
        if self.cfg.roundtrip_filter {
            if let Some(f) = self.forward {
                match f.predict(&d.reactants) {
                    Ok(p) => {
                        if p != product {
                            return false;
                        }
                    }
                    Err(_) => return false,
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    /// Scripted single-step model for unit tests; counts `propose` calls
    /// so memoization is observable.
    struct Stub {
        table: HashMap<String, Vec<Disconnection>>,
        calls: std::cell::Cell<usize>,
    }

    impl Stub {
        fn new(entries: &[(&str, &[(&[&str], f64)])]) -> Stub {
            let mut table = HashMap::new();
            for (product, ds) in entries {
                table.insert(
                    product.to_string(),
                    ds.iter()
                        .map(|(rs, score)| Disconnection {
                            reactants: rs.iter().map(|r| r.to_string()).collect(),
                            score: *score,
                        })
                        .collect(),
                );
            }
            Stub {
                table,
                calls: std::cell::Cell::new(0),
            }
        }
    }

    impl SingleStepModel for Stub {
        fn propose(&self, product: &str, n: usize) -> Result<Vec<Disconnection>> {
            self.calls.set(self.calls.get() + 1);
            let mut v = self.table.get(product).cloned().unwrap_or_default();
            v.truncate(n);
            Ok(v)
        }
    }

    fn stock(mols: &[&str]) -> Stock {
        Stock::from_iter(mols.iter().map(|m| m.to_string()))
    }

    #[test]
    fn target_already_in_stock() {
        let model = Stub::new(&[]);
        let st = stock(&["CCO"]);
        let p = Planner::new(&model, &st, PlannerConfig::default());
        let (route, stats) = p.plan("CCO").unwrap();
        let route = route.unwrap();
        assert!(route.steps.is_empty());
        assert!(stats.solved);
        assert_eq!(route.leaves(), vec!["CCO"]);
    }

    #[test]
    fn single_step_route() {
        let model = Stub::new(&[("P", &[(&["A", "B"], -0.1)])]);
        let st = stock(&["A", "B"]);
        let p = Planner::new(&model, &st, PlannerConfig::default());
        let (route, stats) = p.plan("P").unwrap();
        let route = route.unwrap();
        assert_eq!(route.steps.len(), 1);
        assert_eq!(route.steps[0].reactants, vec!["A", "B"]);
        assert!(stats.solved);
        assert_eq!(stats.expansions, 1);
    }

    #[test]
    fn multi_step_route_prefers_better_score() {
        // P -> (X, B) with X needing one more step, or P -> (DEAD,) which
        // scores better at step one but cannot be completed.
        let model = Stub::new(&[
            ("P", &[(&["DEAD"], -0.05), (&["X", "B"], -0.2)]),
            ("X", &[(&["A"], -0.1)]),
            // DEAD has no disconnections
        ]);
        let st = stock(&["A", "B"]);
        let p = Planner::new(&model, &st, PlannerConfig::default());
        let (route, stats) = p.plan("P").unwrap();
        let route = route.unwrap();
        assert_eq!(route.steps.len(), 2);
        assert!(stats.solved);
        let mut leaves = route.leaves();
        leaves.sort();
        assert_eq!(leaves, vec!["A", "B"]);
    }

    #[test]
    fn unsolvable_returns_none_within_budget() {
        let model = Stub::new(&[("P", &[(&["Q"], -0.1)]), ("Q", &[(&["P2"], -0.1)])]);
        let st = stock(&["A"]);
        let cfg = PlannerConfig {
            expansion_budget: 10,
            ..Default::default()
        };
        let p = Planner::new(&model, &st, cfg);
        let (route, stats) = p.plan("P").unwrap();
        assert!(route.is_none());
        assert!(!stats.solved);
        assert!(stats.expansions <= 10);
    }

    #[test]
    fn cycles_are_rejected() {
        // P -> Q -> P would loop forever without the ancestor check.
        let model = Stub::new(&[("P", &[(&["Q"], -0.1)]), ("Q", &[(&["P"], -0.1)])]);
        let st = stock(&[]);
        let cfg = PlannerConfig {
            expansion_budget: 20,
            max_depth: 10,
            ..Default::default()
        };
        let p = Planner::new(&model, &st, cfg);
        let (route, stats) = p.plan("P").unwrap();
        assert!(route.is_none());
        assert!(stats.expansions < 20, "cycle not pruned: {stats:?}");
    }

    #[test]
    fn depth_limit_prunes() {
        let model = Stub::new(&[
            ("P", &[(&["Q1"], -0.1)]),
            ("Q1", &[(&["Q2"], -0.1)]),
            ("Q2", &[(&["Q3"], -0.1)]),
            ("Q3", &[(&["A"], -0.1)]),
        ]);
        let st = stock(&["A"]);
        let shallow = PlannerConfig {
            max_depth: 2,
            ..Default::default()
        };
        let p = Planner::new(&model, &st, shallow);
        assert!(p.plan("P").unwrap().0.is_none());
        let deep = PlannerConfig {
            max_depth: 5,
            ..Default::default()
        };
        let p = Planner::new(&model, &st, deep);
        assert!(p.plan("P").unwrap().0.is_some());
    }

    struct StubForward {
        ok_product: String,
    }

    impl ForwardCheck for StubForward {
        fn predict(&self, _reactants: &[String]) -> Result<String> {
            Ok(self.ok_product.clone())
        }
    }

    #[test]
    fn roundtrip_filter_rejects_inconsistent_disconnections() {
        let model = Stub::new(&[("P", &[(&["A"], -0.1)])]);
        let st = stock(&["A"]);
        // Forward model predicts something ≠ P ⇒ suggestion filtered.
        let fwd = StubForward {
            ok_product: "NOT_P".to_string(),
        };
        let cfg = PlannerConfig {
            roundtrip_filter: true,
            ..Default::default()
        };
        let p = Planner::with_forward(&model, &st, &fwd, cfg);
        assert!(p.plan("P").unwrap().0.is_none());

        let fwd_ok = StubForward {
            ok_product: "P".to_string(),
        };
        let cfg = PlannerConfig {
            roundtrip_filter: true,
            ..Default::default()
        };
        let p = Planner::with_forward(&model, &st, &fwd_ok, cfg);
        assert!(p.plan("P").unwrap().0.is_some());
    }

    /// A branching target whose intermediate `M` is needed on two
    /// branches. The cache must spend one model call on `M`, hitting on
    /// its second expansion.
    fn branching_model() -> Stub {
        Stub::new(&[
            ("P", &[(&["X", "Y"], -0.1)]),
            ("X", &[(&["M"], -0.1)]),
            ("Y", &[(&["M"], -0.1)]),
            ("M", &[(&["A"], -0.1)]),
        ])
    }

    fn deep_cfg() -> PlannerConfig {
        PlannerConfig {
            max_depth: 10,
            expansion_budget: 50,
            ..Default::default()
        }
    }

    #[test]
    fn cache_memoizes_repeated_intermediates_within_a_plan() {
        let st = stock(&["A"]);

        // Cold baseline: M is proposed twice (once per branch).
        let cold_model = branching_model();
        let p = Planner::new(&cold_model, &st, deep_cfg());
        let (route, stats) = p.plan("P").unwrap();
        assert!(route.is_some());
        assert_eq!(stats.cache_hits, 0);
        let cold_calls = cold_model.calls.get();
        assert_eq!(cold_calls, 5, "P, X, Y, M, M");

        // Warm: the second M expansion is a cache hit — strictly fewer
        // model calls for the identical route.
        let model = branching_model();
        let cache = Arc::new(PlannerCache::new(256, 2));
        let p = Planner::new(&model, &st, deep_cfg()).with_cache(Arc::clone(&cache));
        let (warm_route, warm_stats) = p.plan("P").unwrap();
        assert_eq!(warm_route, route, "memoization must not change the route");
        assert_eq!(warm_stats.cache_hits, 1);
        assert!(
            model.calls.get() < cold_calls,
            "expected strictly fewer model calls: {} vs {cold_calls}",
            model.calls.get()
        );
        assert_eq!(model.calls.get(), 4);
        // Expansion accounting is unchanged — hits still expand nodes.
        assert_eq!(warm_stats.expansions, stats.expansions);
    }

    #[test]
    fn cache_shared_across_plans_skips_all_repeat_calls() {
        let st = stock(&["A"]);
        let model = branching_model();
        let cache = Arc::new(PlannerCache::new(256, 2));
        let p = Planner::new(&model, &st, deep_cfg()).with_cache(Arc::clone(&cache));
        let (r1, _) = p.plan("P").unwrap();
        let calls_after_first = model.calls.get();
        let (r2, s2) = p.plan("P").unwrap();
        assert_eq!(r1, r2);
        assert_eq!(
            model.calls.get(),
            calls_after_first,
            "a warm cache must serve every expansion"
        );
        assert_eq!(s2.cache_hits, s2.expansions);
    }

    #[test]
    fn route_render_contains_steps() {
        let model = Stub::new(&[("P", &[(&["A", "B"], -0.1)])]);
        let st = stock(&["A", "B"]);
        let p = Planner::new(&model, &st, PlannerConfig::default());
        let (route, _) = p.plan("P").unwrap();
        let r = route.unwrap().render();
        assert!(r.contains("P  <=  A + B"));
    }
}
