//! Multi-step synthesis planning — the CASP system the paper's
//! acceleration work exists to serve.
//!
//! The paper's motivation (§1, after Segler et al. 2018): a CASP system is
//! a **single-step retrosynthesis model** plus a **planning algorithm**
//! that expands a search tree over disconnections until every leaf is a
//! purchasable ("in stock") molecule. Single-step calls dominate planning
//! wall time, which is why the paper's SBS speedup matters: §3.2 "such a
//! speed-up could make the transformer a more attractive single-step
//! model for multi-step synthesis planning".
//!
//! This module provides:
//! * [`SingleStepModel`] — the planner-facing abstraction over "propose
//!   reactant sets for a product", implemented by the decoding stack
//!   ([`RetroModel`], with standard BS or speculative SBS) and by scripted
//!   test stubs.
//! * [`Stock`] — the purchasable-molecule set.
//! * [`Planner`] — best-first AND-OR search with a node budget, optional
//!   forward-model round-trip filtering, and synthesis-route extraction.

mod search;
mod stock;

pub use search::{
    ForwardCheck, PlanStats, Planner, PlannerCache, PlannerConfig, Route, RouteStep,
};
pub use stock::Stock;

use anyhow::Result;

use crate::decoding::{beam_search, sbs, Backend, SbsConfig};
use crate::vocab::Vocab;

/// One proposed disconnection: precursor molecules and the model's
/// confidence (normalized log-prob).
#[derive(Debug, Clone, PartialEq)]
pub struct Disconnection {
    pub reactants: Vec<String>,
    pub score: f64,
}

/// The single-step retrosynthesis interface the planner consumes.
pub trait SingleStepModel {
    /// Propose up to `n` reactant sets for `product` (best first).
    fn propose(&self, product: &str, n: usize) -> Result<Vec<Disconnection>>;
}

/// Which decoding procedure the retro model uses — the planner-level knob
/// the paper's Tables 3/4 are about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetroDecoder {
    BeamSearch,
    /// Speculative beam search with the given draft length.
    Sbs { draft_len: usize },
}

/// A trained retro backend + vocabulary as a [`SingleStepModel`].
pub struct RetroModel<'a, B: Backend> {
    pub backend: &'a B,
    pub vocab: &'a Vocab,
    pub decoder: RetroDecoder,
    /// Cumulative decoder calls across all `propose` invocations (the
    /// planning-level cost metric).
    pub decoder_calls: std::cell::Cell<usize>,
}

impl<'a, B: Backend> RetroModel<'a, B> {
    pub fn new(backend: &'a B, vocab: &'a Vocab, decoder: RetroDecoder) -> Self {
        RetroModel {
            backend,
            vocab,
            decoder,
            decoder_calls: std::cell::Cell::new(0),
        }
    }
}

impl<'a, B: Backend> SingleStepModel for RetroModel<'a, B> {
    fn propose(&self, product: &str, n: usize) -> Result<Vec<Disconnection>> {
        let src = self.vocab.encode_wrapped(product)?;
        let out = match self.decoder {
            RetroDecoder::BeamSearch => beam_search(self.backend, &src, n)?,
            RetroDecoder::Sbs { draft_len } => {
                sbs(self.backend, &src, &SbsConfig::new(n, draft_len))?
            }
        };
        self.decoder_calls
            .set(self.decoder_calls.get() + out.stats.decoder_calls);
        Ok(out
            .hyps
            .iter()
            .map(|h| {
                let smiles = self.vocab.decode(&h.tokens);
                Disconnection {
                    reactants: smiles.split('.').map(|s| s.to_string()).collect(),
                    score: h.score / (h.tokens.len().max(1)) as f64,
                }
            })
            .collect())
    }
}
