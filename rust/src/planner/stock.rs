//! Purchasable-molecule stock set.
//!
//! The AiZynthFinder convention at corpus scale: a synthesis route is
//! solved when every leaf is in stock. `gen-data` writes `data/stock.txt`
//! (every reactant molecule of the training corpus).

use std::collections::HashSet;
use std::path::Path;

use anyhow::{Context, Result};

/// A set of purchasable molecules (exact-SMILES membership; our corpus is
/// canonical-by-construction so string identity suffices).
#[derive(Debug, Clone, Default)]
pub struct Stock {
    mols: HashSet<String>,
}

impl Stock {
    pub fn from_iter<I: IntoIterator<Item = String>>(mols: I) -> Stock {
        Stock {
            mols: mols.into_iter().collect(),
        }
    }

    /// Load `stock.txt` (one SMILES per line).
    pub fn load(path: &Path) -> Result<Stock> {
        let body = std::fs::read_to_string(path)
            .with_context(|| format!("read {} (run gen-data)", path.display()))?;
        Ok(Stock {
            mols: body.lines().filter(|l| !l.is_empty()).map(String::from).collect(),
        })
    }

    pub fn contains(&self, smiles: &str) -> bool {
        self.mols.contains(smiles)
    }

    pub fn len(&self) -> usize {
        self.mols.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mols.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn membership_and_load() {
        let dir = std::env::temp_dir().join("rxnspec_stock_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("stock.txt");
        std::fs::write(&p, "CCO\nc1ccccc1\n\n").unwrap();
        let s = Stock::load(&p).unwrap();
        assert_eq!(s.len(), 2);
        assert!(s.contains("CCO"));
        assert!(!s.contains("CCN"));
    }
}
