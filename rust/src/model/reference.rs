//! Pure-Rust reference implementation of the Molecular Transformer.
//!
//! Mirrors `python/compile/model.py` operation for operation (pre-LN
//! encoder-decoder, sinusoidal encodings from explicit position ids,
//! log-softmax outputs) over the same RXW1 weights file. It plays the role
//! the OpenNMT "original MT" plays in the paper's Table 1: an independent
//! implementation whose outputs the production path (the AOT artifact run
//! by PJRT) is validated against. It also lets the entire decoding stack
//! run and be tested without compiled artifacts.
//!
//! Numerical parity with the artifact is approximate (different reduction
//! orders), ~1e-3 absolute on log-probs — enough for argmax/top-k
//! agreement on all but pathological ties; `rust/tests/backend_parity.rs`
//! quantifies it.
//!
//! All dense math runs on the compute-kernel layer (`crate::kernels`):
//! weights are pre-packed at load time into tile-aligned GEMM panels
//! (self-attention QKV fused into one packed matrix), attention K/V live
//! as contiguous per-head panels, the micro-kernels dispatch onto
//! explicit SIMD lanes (`kernels::simd`), and **both** directions of the
//! model cross-row pack: `CachedSession::extend` packs every row's
//! appended window into one activation matrix per decoder layer, and
//! `encode` packs every source row into one activation matrix per
//! encoder layer — one fused-QKV GEMM per layer per call instead of one
//! per row (`SessionStats::packed_src_rows` counts the encoder side).
//! The kernels' fixed-reduction-order contract makes stateless decode,
//! single-row extend, batched extend, batched encode, threaded and
//! SIMD execution all bit-identical (`rust/tests/session_parity.rs`,
//! `rust/tests/kernel_parity.rs`).

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::decoding::session::{
    assemble_window_row, lp_retention_from_env, needed_window, rollback_for_extend_kv,
    trim_lp_suffix,
};
use crate::decoding::{
    ArenaConfig, ArenaStats, Backend, DecoderRow, DecoderSession, KvArena, LogProbs, Memory,
    ModelDims, SessionStats, TableId,
};
use crate::kernels::{
    attn_panels_paged_threaded, attn_panels_threaded, default_threads, KvPanels, PackedLinear,
    PagedKv,
};
use crate::model::weights::{load_config, Tensor, Weights};

/// Model hyper-parameters (matches `ModelConfig` in model.py).
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_enc: usize,
    pub n_dec: usize,
    pub s_len: usize,
    pub t_len: usize,
}

impl Config {
    pub fn from_file(path: &Path) -> Result<Config> {
        let kv = load_config(path)?;
        let g = |k: &str| -> Result<usize> {
            kv.get(k)
                .copied()
                .ok_or_else(|| anyhow::anyhow!("config missing {k}"))
        };
        Ok(Config {
            vocab: g("vocab")?,
            d_model: g("d_model")?,
            n_heads: g("n_heads")?,
            d_ff: g("d_ff")?,
            n_enc: g("n_enc")?,
            n_dec: g("n_dec")?,
            s_len: g("s_len")?,
            t_len: g("t_len")?,
        })
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

// ---------------------------------------------------------------------------
// Small per-row helpers (row-major [rows, cols] in flat Vec<f32>)
// ---------------------------------------------------------------------------

fn layer_norm(x: &mut [f32], n: usize, d: usize, g: &Tensor, b: &Tensor) {
    for r in 0..n {
        let row = &mut x[r * d..(r + 1) * d];
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mu) * inv * g.data[i] + b.data[i];
        }
    }
}

fn layer_normed(x: &[f32], n: usize, d: usize, g: &Tensor, b: &Tensor) -> Vec<f32> {
    let mut y = x.to_vec();
    layer_norm(&mut y, n, d, g, b);
    y
}

/// Sinusoidal positional encoding row for one position id (the fallback
/// for positions beyond the precomputed table; also builds the table).
fn add_pe(row: &mut [f32], pos: i64, d: usize) {
    let half = d / 2;
    for i in 0..half {
        let freq = (-(10000f32).ln() * (2.0 * i as f32 / d as f32)).exp();
        let ang = pos as f32 * freq;
        row[i] += ang.sin();
        row[half + i] += ang.cos();
    }
}

fn add_assign(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// log-softmax of one logits row into `out` (same length).
fn log_softmax_row_into(lrow: &[f32], out: &mut [f32]) {
    let mx = lrow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = lrow.iter().map(|&l| (l - mx).exp()).sum();
    let lz = mx + z.ln();
    for (o, &l) in out.iter_mut().zip(lrow) {
        *o = l - lz;
    }
}

// ---------------------------------------------------------------------------
// Parameter bundles (packed at load time)
// ---------------------------------------------------------------------------

/// Self-attention: one fused packed GEMM over `wq|wk|wv` plus the output
/// projection.
struct SelfAttnParams {
    qkv: PackedLinear,
    wo: PackedLinear,
}

/// Cross-attention keeps separate projections: K/V run once per memory
/// row per session, queries once per appended window.
struct CrossAttnParams {
    wq: PackedLinear,
    wk: PackedLinear,
    wv: PackedLinear,
    wo: PackedLinear,
}

struct FfnParams {
    w1: PackedLinear,
    w2: PackedLinear,
}

struct LnParams {
    g: Tensor,
    b: Tensor,
}

struct EncLayer {
    ln1: LnParams,
    attn: SelfAttnParams,
    ln2: LnParams,
    ffn: FfnParams,
}

struct DecLayer {
    ln1: LnParams,
    self_attn: SelfAttnParams,
    ln2: LnParams,
    cross_attn: CrossAttnParams,
    ln3: LnParams,
    ffn: FfnParams,
}

fn packed(w: &Weights, wname: &str, bname: &str) -> Result<PackedLinear> {
    let wt = w.get(wname)?;
    let bt = w.get(bname)?;
    anyhow::ensure!(wt.dims.len() == 2, "{wname}: expected 2-D weight");
    Ok(PackedLinear::pack(
        &wt.data,
        wt.dims[0],
        wt.dims[1],
        &bt.data,
    ))
}

fn self_attn_params(w: &Weights, prefix: &str) -> Result<SelfAttnParams> {
    let wq = w.get(&format!("{prefix}.wq"))?;
    let wk = w.get(&format!("{prefix}.wk"))?;
    let wv = w.get(&format!("{prefix}.wv"))?;
    let bq = w.get(&format!("{prefix}.bq"))?;
    let bk = w.get(&format!("{prefix}.bk"))?;
    let bv = w.get(&format!("{prefix}.bv"))?;
    anyhow::ensure!(
        wq.dims.len() == 2 && wk.dims == wq.dims && wv.dims == wq.dims,
        "{prefix}: inconsistent QKV shapes"
    );
    let qkv = PackedLinear::pack_fused(
        &[&wq.data, &wk.data, &wv.data],
        &[&bq.data, &bk.data, &bv.data],
        wq.dims[0],
        &[wq.dims[1], wk.dims[1], wv.dims[1]],
    );
    Ok(SelfAttnParams {
        qkv,
        wo: packed(w, &format!("{prefix}.wo"), &format!("{prefix}.bo"))?,
    })
}

fn cross_attn_params(w: &Weights, prefix: &str) -> Result<CrossAttnParams> {
    Ok(CrossAttnParams {
        wq: packed(w, &format!("{prefix}.wq"), &format!("{prefix}.bq"))?,
        wk: packed(w, &format!("{prefix}.wk"), &format!("{prefix}.bk"))?,
        wv: packed(w, &format!("{prefix}.wv"), &format!("{prefix}.bv"))?,
        wo: packed(w, &format!("{prefix}.wo"), &format!("{prefix}.bo"))?,
    })
}

fn ffn_params(w: &Weights, prefix: &str) -> Result<FfnParams> {
    Ok(FfnParams {
        w1: packed(w, &format!("{prefix}.w1"), &format!("{prefix}.b1"))?,
        w2: packed(w, &format!("{prefix}.w2"), &format!("{prefix}.b2"))?,
    })
}

fn ln_params(w: &Weights, prefix: &str) -> Result<LnParams> {
    Ok(LnParams {
        g: w.get(&format!("{prefix}.g"))?.clone(),
        b: w.get(&format!("{prefix}.b"))?.clone(),
    })
}

/// The reference backend: pre-packed weights + config, implements
/// [`Backend`].
pub struct RustBackend {
    cfg: Config,
    tok_emb: Tensor,
    out: PackedLinear,
    enc_ln_f: LnParams,
    dec_ln_f: LnParams,
    enc: Vec<EncLayer>,
    dec: Vec<DecLayer>,
    /// Sinusoidal positional-encoding table `[pe_len, d_model]`,
    /// precomputed once at load for every position either bucket can
    /// reach (no per-embed `exp`/`ln`).
    pe: Vec<f32>,
    pe_len: usize,
    /// Kernel thread budget (1 = off; `RXNSPEC_THREADS` sets the
    /// default, [`RustBackend::set_threads`] overrides it).
    threads: usize,
    /// Checkpoint content hash — the artifact identity folded into
    /// cross-request cache keys (`cache::ServeCache`).
    version: u64,
}

impl RustBackend {
    /// Load from `artifacts/weights_{task}.bin` + `config_{task}.txt`.
    pub fn load(weights_path: &Path, config_path: &Path) -> Result<RustBackend> {
        let cfg = Config::from_file(config_path)?;
        let w = Weights::load(weights_path)?;
        Self::from_weights(&w, cfg)
    }

    pub fn from_weights(w: &Weights, cfg: Config) -> Result<RustBackend> {
        let mut enc = Vec::new();
        for i in 0..cfg.n_enc {
            enc.push(EncLayer {
                ln1: ln_params(w, &format!("enc{i}.ln1"))?,
                attn: self_attn_params(w, &format!("enc{i}.attn"))?,
                ln2: ln_params(w, &format!("enc{i}.ln2"))?,
                ffn: ffn_params(w, &format!("enc{i}.ffn"))?,
            });
        }
        let mut dec = Vec::new();
        for i in 0..cfg.n_dec {
            dec.push(DecLayer {
                ln1: ln_params(w, &format!("dec{i}.ln1"))?,
                self_attn: self_attn_params(w, &format!("dec{i}.self_attn"))?,
                ln2: ln_params(w, &format!("dec{i}.ln2"))?,
                cross_attn: cross_attn_params(w, &format!("dec{i}.cross_attn"))?,
                ln3: ln_params(w, &format!("dec{i}.ln3"))?,
                ffn: ffn_params(w, &format!("dec{i}.ffn"))?,
            });
        }
        let d = cfg.d_model;
        let pe_len = cfg.s_len.max(cfg.t_len);
        let mut pe = vec![0f32; pe_len * d];
        for pos in 0..pe_len {
            add_pe(&mut pe[pos * d..(pos + 1) * d], pos as i64, d);
        }
        Ok(RustBackend {
            cfg,
            tok_emb: w.get("tok_emb")?.clone(),
            out: packed(w, "out_w", "out_b")?,
            enc_ln_f: ln_params(w, "enc_ln_f")?,
            dec_ln_f: ln_params(w, "dec_ln_f")?,
            enc,
            dec,
            pe,
            pe_len,
            threads: default_threads(),
            version: w.content_hash(),
        })
    }

    pub fn config(&self) -> Config {
        self.cfg
    }

    /// Checkpoint identity for cross-request cache keying.
    pub fn artifact_version(&self) -> u64 {
        self.version
    }

    /// Override the kernel thread budget (1 disables threading). The
    /// partitioner is deterministic: outputs are bit-identical at any
    /// setting.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    fn embed_into(&self, tokens: &[i64], positions: &[i64], out: &mut [f32]) {
        let d = self.cfg.d_model;
        let scale = (d as f32).sqrt();
        for (i, &t) in tokens.iter().enumerate() {
            let row = &mut out[i * d..(i + 1) * d];
            let emb = &self.tok_emb.data[t as usize * d..(t as usize + 1) * d];
            for (o, &e) in row.iter_mut().zip(emb) {
                *o = e * scale;
            }
            let pos = positions[i];
            if pos >= 0 && (pos as usize) < self.pe_len {
                let pr = &self.pe[pos as usize * d..(pos as usize + 1) * d];
                for (o, &p) in row.iter_mut().zip(pr) {
                    *o += p;
                }
            } else {
                add_pe(row, pos, d);
            }
        }
    }

    fn embed(&self, tokens: &[i64], positions: &[i64]) -> Vec<f32> {
        let mut x = vec![0f32; tokens.len() * self.cfg.d_model];
        self.embed_into(tokens, positions, &mut x);
        x
    }

    /// Fused self-attention block over already-normed `h`: one packed
    /// QKV GEMM, K/V appended to `kv`, head-blocked attention (causal
    /// from global offset `p`, or unmasked), output projection.
    fn fused_self_attn(
        &self,
        h: &[f32],
        n: usize,
        params: &SelfAttnParams,
        kv: &mut KvPanels,
        causal_offset: Option<usize>,
    ) -> Vec<f32> {
        let d = self.cfg.d_model;
        let qkv = params.qkv.apply(h, n, self.threads);
        kv.append_strided(&qkv, n, 3 * d, d, 2 * d);
        let mut ctx = vec![0f32; n * d];
        attn_panels_threaded(&qkv, 3 * d, 0, n, kv, causal_offset, &mut ctx, self.threads);
        params.wo.apply(&ctx, n, self.threads)
    }

    /// Cross-attention block with K/V projected fresh from `mem` (the
    /// stateless path; sessions hoist the projection via [`KvPanels`]).
    fn cross_attn_full(
        &self,
        h: &[f32],
        n: usize,
        params: &CrossAttnParams,
        mem: &[f32],
        mem_n: usize,
    ) -> Vec<f32> {
        let kv = self.project_cross_kv(params, mem, mem_n);
        self.cross_attn_cached(h, n, params, &kv)
    }

    /// Project one memory row's cross-attention K/V panels.
    fn project_cross_kv(&self, params: &CrossAttnParams, mem: &[f32], mem_n: usize) -> KvPanels {
        let k = params.wk.apply(mem, mem_n, self.threads);
        let v = params.wv.apply(mem, mem_n, self.threads);
        let mut kv = KvPanels::new(self.cfg.n_heads, self.cfg.d_head());
        kv.append(&k, &v, mem_n);
        kv
    }

    /// Cross-attention block against already-projected K/V panels.
    fn cross_attn_cached(
        &self,
        h: &[f32],
        n: usize,
        params: &CrossAttnParams,
        kv: &KvPanels,
    ) -> Vec<f32> {
        let d = self.cfg.d_model;
        let q = params.wq.apply(h, n, self.threads);
        let mut ctx = vec![0f32; n * d];
        attn_panels_threaded(&q, d, 0, n, kv, None, &mut ctx, self.threads);
        params.wo.apply(&ctx, n, self.threads)
    }

    fn ffn(&self, h: &[f32], n: usize, p: &FfnParams) -> Vec<f32> {
        let mut f = p.w1.apply(h, n, self.threads);
        relu(&mut f);
        p.w2.apply(&f, n, self.threads)
    }
}

impl Backend for RustBackend {
    fn dims(&self) -> ModelDims {
        ModelDims {
            s_len: self.cfg.s_len,
            t_len: self.cfg.t_len,
            d_model: self.cfg.d_model,
            vocab: self.cfg.vocab,
        }
    }

    fn encode(&self, srcs: &[&[i64]]) -> Result<Memory> {
        let (s_len, d) = (self.cfg.s_len, self.cfg.d_model);
        // Cross-row packing, mirroring `extend_rows_batched`: every
        // source row's tokens are packed into one `[Σnᵢ, d_model]`
        // activation matrix, so each encoder layer issues **one** fused
        // QKV GEMM, one output projection and one FFN pass for the whole
        // batch instead of one per row. Attention stays per-row against
        // each row's own keys (compact rows: no pad keys exist, so no
        // mask); the kernels' row-independence contract makes this
        // bit-identical to encoding each row alone
        // (`rust/tests/kernel_parity.rs`).
        let mut offs = Vec::with_capacity(srcs.len());
        let mut total = 0usize;
        for src in srcs {
            let n = src.len();
            anyhow::ensure!(n <= s_len, "src length {n} exceeds bucket {s_len}");
            offs.push(total);
            total += n;
        }
        let mut x = vec![0f32; total * d];
        for (src, &off) in srcs.iter().zip(&offs) {
            let positions: Vec<i64> = (0..src.len() as i64).collect();
            self.embed_into(src, &positions, &mut x[off * d..(off + src.len()) * d]);
        }
        // One reusable K/V panel set: truncate(0) keeps every lane's
        // capacity, so rows and layers after the first append without
        // reallocating.
        let mut kv = KvPanels::new(self.cfg.n_heads, self.cfg.d_head());
        for layer in &self.enc {
            let h = layer_normed(&x, total, d, &layer.ln1.g, &layer.ln1.b);
            let qkv = layer.attn.qkv.apply(&h, total, self.threads);
            let mut ctx = vec![0f32; total * d];
            for (src, &off) in srcs.iter().zip(&offs) {
                let n = src.len();
                if n == 0 {
                    continue;
                }
                kv.truncate(0);
                kv.append_strided(&qkv[off * 3 * d..], n, 3 * d, d, 2 * d);
                attn_panels_threaded(
                    &qkv,
                    3 * d,
                    off * 3 * d,
                    n,
                    &kv,
                    None,
                    &mut ctx[off * d..(off + n) * d],
                    self.threads,
                );
            }
            let a = layer.attn.wo.apply(&ctx, total, self.threads);
            add_assign(&mut x, &a);
            let h = layer_normed(&x, total, d, &layer.ln2.g, &layer.ln2.b);
            let f = self.ffn(&h, total, &layer.ffn);
            add_assign(&mut x, &f);
        }
        layer_norm(&mut x, total, d, &self.enc_ln_f.g, &self.enc_ln_f.b);
        let mut data = vec![0f32; srcs.len() * s_len * d];
        let mut pad = vec![0f32; srcs.len() * s_len];
        for (bi, (src, &off)) in srcs.iter().zip(&offs).enumerate() {
            let n = src.len();
            data[bi * s_len * d..bi * s_len * d + n * d]
                .copy_from_slice(&x[off * d..(off + n) * d]);
            for p in pad[bi * s_len..bi * s_len + n].iter_mut() {
                *p = 1.0;
            }
        }
        Ok(Memory {
            data,
            pad,
            batch: srcs.len(),
            s_len,
            d_model: d,
        })
    }

    fn decode(&self, rows: &[DecoderRow], memory: &Memory) -> Result<LogProbs> {
        let (t_len, d, v) = (self.cfg.t_len, self.cfg.d_model, self.cfg.vocab);
        let mut out = vec![0f32; rows.len() * t_len * v];
        let mut lens = Vec::with_capacity(rows.len());
        for (ri, row) in rows.iter().enumerate() {
            let n = row.tokens.len();
            anyhow::ensure!(n <= t_len, "row length {n} exceeds bucket {t_len}");
            lens.push(n);
            // Compact computation: pad columns contribute nothing (their
            // keys are masked, their queries unread), so we evaluate only
            // the n real positions with positions 0..n — numerically equal
            // to the padded layouts (see test_model.py's left-pad test).
            let positions: Vec<i64> = (0..n as i64).collect();
            let mut x = self.embed(&row.tokens, &positions);

            // Memory row: compact to its real length.
            let mem_pad = memory.pad_row(row.mem_row);
            let mem_n = mem_pad.iter().take_while(|&&p| p > 0.0).count();
            let mem = &memory.row(row.mem_row)[..mem_n * d];

            for layer in &self.dec {
                let h = layer_normed(&x, n, d, &layer.ln1.g, &layer.ln1.b);
                let mut kv = KvPanels::new(self.cfg.n_heads, self.cfg.d_head());
                let a = self.fused_self_attn(&h, n, &layer.self_attn, &mut kv, Some(0));
                add_assign(&mut x, &a);
                let h = layer_normed(&x, n, d, &layer.ln2.g, &layer.ln2.b);
                let a = self.cross_attn_full(&h, n, &layer.cross_attn, mem, mem_n);
                add_assign(&mut x, &a);
                let h = layer_normed(&x, n, d, &layer.ln3.g, &layer.ln3.b);
                let f = self.ffn(&h, n, &layer.ffn);
                add_assign(&mut x, &f);
            }
            layer_norm(&mut x, n, d, &self.dec_ln_f.g, &self.dec_ln_f.b);
            let logits = self.out.apply(&x, n, self.threads);
            // log_softmax per position, written right-aligned into [T, V].
            let base = ri * t_len * v + (t_len - n) * v;
            for i in 0..n {
                let lrow = &logits[i * v..(i + 1) * v];
                log_softmax_row_into(lrow, &mut out[base + i * v..base + (i + 1) * v]);
            }
        }
        Ok(LogProbs::new(out, lens, t_len, v))
    }

    fn begin(&self, memory: Memory) -> Result<Box<dyn DecoderSession + '_>> {
        Ok(Box::new(self.begin_cached(memory)))
    }
}

// ---------------------------------------------------------------------------
// KV-cached incremental decoding session
// ---------------------------------------------------------------------------

/// Committed state of one session row. Forks share it through an `Arc`
/// (copy-on-write: the first `extend` after a fork clones exactly once).
/// In paged-arena mode the K/V lives in the session's [`KvArena`]
/// instead (`SessRow::table`), so the Arc-COW clone covers only the
/// scalar state here — tokens and the bounded log-prob suffix.
#[derive(Clone)]
struct RowCache {
    tokens: Vec<i64>,
    /// One per-head-panel K/V cache per decoder layer — dense
    /// (`RXNSPEC_ARENA=off`) mode only; empty when the row's K/V lives
    /// in the arena.
    kv: Vec<KvPanels>,
    /// Retained **suffix** of per-position successor log-probs,
    /// `[retained, vocab]` starting at absolute position `lp_start` —
    /// kept so `extend` can serve the window position `len_before - 1`
    /// without recomputing it. Bounded to the session's retention cap
    /// after every extend; a truncate that rewinds past the suffix is
    /// healed by bit-identically recomputing one position (see
    /// `CachedSession::extend`).
    lp: Vec<f32>,
    lp_start: usize,
}

struct SessRow {
    mem_row: usize,
    cache: Arc<RowCache>,
    /// Logical committed length. `truncate` only moves this (O(1)); the
    /// shared buffers are trimmed lazily by the next `extend` once the
    /// row holds a unique copy.
    len: usize,
    /// Paged mode: this row's page table in the session arena. `fork`
    /// clones only the table (O(pages) refcount bumps); the shared
    /// partial tail page is copied lazily on first divergent write.
    table: Option<TableId>,
}

/// The reference backend's [`DecoderSession`]: incremental self-attention
/// K/V panels, session-cached cross-attention K/V, and a bounded cache of
/// per-position log-probs. `extend` packs every row's appended window
/// into one `[Σmᵢ, d_model]` activation matrix per layer — N per-row
/// layer passes become one packed pass per layer. Produces
/// **bit-identical** log-probabilities to [`RustBackend::decode`] — the
/// kernels' fixed reduction order makes this a hard invariant,
/// property-tested in `rust/tests/session_parity.rs` and
/// `rust/tests/kernel_parity.rs`.
pub struct CachedSession<'a> {
    backend: &'a RustBackend,
    memory: Memory,
    cross: Vec<Option<Arc<Vec<KvPanels>>>>,
    rows: Vec<Option<SessRow>>,
    stats: SessionStats,
    lp_retain: usize,
    /// Page-pooled K/V residency (`RXNSPEC_ARENA`; `None` = dense
    /// per-row panels, the fallback and parity oracle).
    arena: Option<KvArena>,
}

impl<'a> CachedSession<'a> {
    pub fn new(backend: &'a RustBackend, memory: Memory) -> CachedSession<'a> {
        CachedSession::with_arena(backend, memory, ArenaConfig::from_env())
    }

    /// Open a session with an explicit arena mode, bypassing the
    /// `RXNSPEC_ARENA` environment knobs (tests drive paged and dense
    /// sessions side by side this way without touching process env).
    pub fn with_arena(
        backend: &'a RustBackend,
        memory: Memory,
        arena: Option<ArenaConfig>,
    ) -> CachedSession<'a> {
        let batch = memory.batch;
        let lp_retain = lp_retention_from_env();
        let arena = arena.map(|cfg| KvArena::new(&cfg, backend.cfg.n_dec * backend.cfg.d_model));
        CachedSession {
            backend,
            memory,
            cross: (0..batch).map(|_| None).collect(),
            rows: Vec::new(),
            // The session's memory came from one (cross-row packed)
            // encoder call over `batch` source rows.
            stats: SessionStats {
                encode_calls: 1,
                packed_src_rows: batch,
                ..SessionStats::default()
            },
            lp_retain,
            arena,
        }
    }

    /// Arena residency counters, `None` on the dense path.
    pub fn arena_stats(&self) -> Option<ArenaStats> {
        self.arena.as_ref().map(|a| a.stats())
    }

    /// Cap the per-row log-prob retention (positions; min 1). Lower caps
    /// save `positions × vocab` floats per row; rewinds past the cap are
    /// healed by recomputing one position bit-identically.
    pub fn set_lp_retention(&mut self, positions: usize) {
        self.lp_retain = positions.max(1);
    }

    fn row(&self, row: usize) -> &SessRow {
        self.rows[row].as_ref().expect("released session row")
    }

    /// Lazily project this memory row's cross-attention K/V panels per
    /// layer — the same GEMMs the stateless path issues per decode call,
    /// hoisted to once per session.
    fn cross_for(&mut self, mem_row: usize) -> Arc<Vec<KvPanels>> {
        if self.cross[mem_row].is_none() {
            let d = self.backend.cfg.d_model;
            let mem_pad = self.memory.pad_row(mem_row);
            let mem_n = mem_pad.iter().take_while(|&&p| p > 0.0).count();
            let mem = &self.memory.row(mem_row)[..mem_n * d];
            let per_layer = self
                .backend
                .dec
                .iter()
                .map(|layer| self.backend.project_cross_kv(&layer.cross_attn, mem, mem_n))
                .collect();
            self.cross[mem_row] = Some(Arc::new(per_layer));
        }
        Arc::clone(self.cross[mem_row].as_ref().unwrap())
    }
}

/// Where one extend job's self-attention K/V lives: the row's own dense
/// panels, or a page table in the session arena (pages already prepared
/// — rolled back, unshared, allocated — by the caller).
enum JobKv<'a> {
    Dense(&'a mut Vec<KvPanels>),
    Paged(TableId),
}

/// One row's slice of a batched extend pass: its (already rolled-back)
/// scalar cache parts, its K/V designator, its per-layer cross-attention
/// panels, and the token window to append.
struct ExtendJob<'a> {
    tokens: &'a mut Vec<i64>,
    lp: &'a mut Vec<f32>,
    kv: JobKv<'a>,
    cross: &'a [KvPanels],
    toks: &'a [i64],
}

impl RustBackend {
    /// Run the decoder stack **once** over every job's appended window,
    /// packed into one `[Σmᵢ, d_model]` activation matrix per layer.
    /// GEMMs, layer norms, the FFN and the output head are cross-row
    /// packed; attention stays per-row against each row's own K/V
    /// history — dense panels or a page-strided arena view, which the
    /// kernels guarantee bit-identical. Per-row arithmetic is identical
    /// to a sequence of single-row passes (the kernels' row-independence
    /// contract), so batching never changes results.
    fn extend_rows_batched(&self, jobs: &mut [ExtendJob<'_>], mut arena: Option<&mut KvArena>) {
        let d = self.cfg.d_model;
        let v = self.cfg.vocab;
        let total: usize = jobs.iter().map(|j| j.toks.len()).sum();
        if total == 0 {
            return;
        }
        let mut offs = Vec::with_capacity(jobs.len());
        let mut starts = Vec::with_capacity(jobs.len());
        let mut x = vec![0f32; total * d];
        {
            let mut off = 0usize;
            for job in jobs.iter_mut() {
                let m = job.toks.len();
                offs.push(off);
                let p = job.tokens.len();
                starts.push(p);
                if m > 0 {
                    let positions: Vec<i64> = (p as i64..(p + m) as i64).collect();
                    self.embed_into(job.toks, &positions, &mut x[off * d..(off + m) * d]);
                    job.tokens.extend_from_slice(job.toks);
                }
                off += m;
            }
        }
        let n = total;
        for (li, layer) in self.dec.iter().enumerate() {
            // Causal self-attention: one fused QKV GEMM over the packed
            // windows, then per-row append + attention against that
            // row's own cache.
            let h = layer_normed(&x, n, d, &layer.ln1.g, &layer.ln1.b);
            let qkv = layer.self_attn.qkv.apply(&h, n, self.threads);
            let mut ctx = vec![0f32; n * d];
            for (ji, job) in jobs.iter_mut().enumerate() {
                let m = job.toks.len();
                if m == 0 {
                    continue;
                }
                let off = offs[ji];
                match &mut job.kv {
                    JobKv::Dense(kvs) => {
                        let kv = &mut kvs[li];
                        kv.append_strided(&qkv[off * 3 * d..], m, 3 * d, d, 2 * d);
                        attn_panels_threaded(
                            &qkv,
                            3 * d,
                            off * 3 * d,
                            m,
                            kv,
                            Some(starts[ji]),
                            &mut ctx[off * d..(off + m) * d],
                            self.threads,
                        );
                    }
                    JobKv::Paged(table) => {
                        let ar = arena.as_deref_mut().expect("paged job without an arena");
                        self.append_kv_paged(
                            ar,
                            *table,
                            li,
                            &qkv[off * 3 * d..],
                            m,
                            3 * d,
                            d,
                            2 * d,
                            starts[ji],
                        );
                        let view = self.paged_layer_view(ar, *table, li, starts[ji] + m);
                        attn_panels_paged_threaded(
                            &qkv,
                            3 * d,
                            off * 3 * d,
                            m,
                            &view,
                            Some(starts[ji]),
                            &mut ctx[off * d..(off + m) * d],
                            self.threads,
                        );
                    }
                }
            }
            let a = layer.self_attn.wo.apply(&ctx, n, self.threads);
            add_assign(&mut x, &a);

            // Cross-attention against the session-cached memory panels.
            let h = layer_normed(&x, n, d, &layer.ln2.g, &layer.ln2.b);
            let q = layer.cross_attn.wq.apply(&h, n, self.threads);
            let mut ctx = vec![0f32; n * d];
            for (ji, job) in jobs.iter().enumerate() {
                let m = job.toks.len();
                if m == 0 {
                    continue;
                }
                let off = offs[ji];
                attn_panels_threaded(
                    &q,
                    d,
                    off * d,
                    m,
                    &job.cross[li],
                    None,
                    &mut ctx[off * d..(off + m) * d],
                    self.threads,
                );
            }
            let a = layer.cross_attn.wo.apply(&ctx, n, self.threads);
            add_assign(&mut x, &a);

            let h = layer_normed(&x, n, d, &layer.ln3.g, &layer.ln3.b);
            let f = self.ffn(&h, n, &layer.ffn);
            add_assign(&mut x, &f);
        }
        layer_norm(&mut x, n, d, &self.dec_ln_f.g, &self.dec_ln_f.b);
        let logits = self.out.apply(&x, n, self.threads);
        for (ji, job) in jobs.iter_mut().enumerate() {
            let m = job.toks.len();
            let off = offs[ji];
            for i in 0..m {
                let lrow = &logits[(off + i) * v..(off + i + 1) * v];
                let base = job.lp.len();
                job.lp.resize(base + v, 0.0);
                log_softmax_row_into(lrow, &mut job.lp[base..]);
            }
        }
    }

    /// Write `m` appended positions' K/V (rows of a fused-QKV matrix:
    /// row `r`'s K at `data[r·stride + k_off]`, V at `data[r·stride +
    /// v_off]`) into the row's arena pages at layer `li`, starting at
    /// global position `start`. Page blobs are `[n_dec, d_model·P]`
    /// per buffer; within layer `li`'s slice the layouts are exactly
    /// [`KvPanels::paged`]'s: K lanes `[d_model, P]`, V panels
    /// `[n_heads, P, d_head]`.
    #[allow(clippy::too_many_arguments)]
    fn append_kv_paged(
        &self,
        arena: &mut KvArena,
        table: TableId,
        li: usize,
        data: &[f32],
        m: usize,
        stride: usize,
        k_off: usize,
        v_off: usize,
        start: usize,
    ) {
        let d = self.cfg.d_model;
        let dh = self.cfg.d_head();
        let pp = arena.page_positions();
        let lbase = li * d * pp;
        for r in 0..m {
            let pos = start + r;
            let pid = arena.table_pages(table)[pos / pp];
            let slot = pos % pp;
            let (pk, pv) = arena.page_kv_mut(pid);
            for hd in 0..d {
                pk[lbase + hd * pp + slot] = data[r * stride + k_off + hd];
            }
            for h in 0..self.cfg.n_heads {
                let dst = lbase + (h * pp + slot) * dh;
                let src = r * stride + v_off + h * dh;
                pv[dst..dst + dh].copy_from_slice(&data[src..src + dh]);
            }
        }
    }

    /// Borrow layer `li` of a row's pages as a page-strided attention
    /// view over positions `0..len`.
    fn paged_layer_view<'v>(
        &self,
        arena: &'v KvArena,
        table: TableId,
        li: usize,
        len: usize,
    ) -> PagedKv<'v> {
        let d = self.cfg.d_model;
        let pp = arena.page_positions();
        let lbase = li * d * pp;
        let n_pages = len.div_ceil(pp);
        let pages = arena.table_pages(table)[..n_pages]
            .iter()
            .map(|&pid| {
                (
                    &arena.page_k(pid)[lbase..lbase + d * pp],
                    &arena.page_v(pid)[lbase..lbase + d * pp],
                )
            })
            .collect();
        KvPanels::paged(self.cfg.n_heads, self.cfg.d_head(), len, pp, pages)
    }

    /// Pure-Rust mirror of the `deccache` AOT artifact semantics
    /// (`python/compile/model.py::decode_logprobs_cached`): one decoder
    /// pass over each lane's appended window against flat `[L, EB, T, D]`
    /// K/V caches, windows right-padded, `cache_len[lane]` committed
    /// positions per lane, the window's K/V written back at slots
    /// `cache_len..cache_len+m` (everything else untouched).
    ///
    /// This is the executor the PJRT cached-session machinery is
    /// property-tested against (`testutil::RefDeccacheExec`): per lane it
    /// runs the exact kernels the reference `CachedSession` runs —
    /// fused-QKV GEMM, panel attention with causal offset `cache_len`,
    /// session-equivalent cross-attention — so its outputs are
    /// **bit-identical** to the stateless oracle by the kernels'
    /// fixed-reduction-order contract. (The real artifact computes the
    /// same function with XLA kernels; artifact↔reference closeness is
    /// backend_parity's job.)
    ///
    /// Returns `[EB, W, V]` log-probs (pad slots zero-filled).
    #[allow(clippy::too_many_arguments)]
    pub fn deccache_apply(
        &self,
        w: usize,
        eb: usize,
        tgt: &[i64],
        pos: &[i64],
        tgt_pad: &[f32],
        cache_len: &[i64],
        k_cache: &mut [f32],
        v_cache: &mut [f32],
        mem: &Memory,
        mem_rows: &[usize],
    ) -> Result<Vec<f32>> {
        let (d, v, t_cap) = (self.cfg.d_model, self.cfg.vocab, self.cfg.t_len);
        let n_l = self.cfg.n_dec;
        anyhow::ensure!(
            k_cache.len() == n_l * eb * t_cap * d && v_cache.len() == k_cache.len(),
            "deccache_apply: cache shape mismatch"
        );
        anyhow::ensure!(
            tgt.len() == eb * w && pos.len() == eb * w && tgt_pad.len() == eb * w,
            "deccache_apply: window shape mismatch"
        );
        let dh = self.cfg.d_head();
        let mut logp = vec![0f32; eb * w * v];
        for lane in 0..eb {
            let m = (0..w).take_while(|&j| tgt_pad[lane * w + j] > 0.0).count();
            if m == 0 {
                continue;
            }
            let start = cache_len[lane] as usize;
            anyhow::ensure!(
                start + m <= t_cap,
                "deccache_apply: lane {lane} overflows cache capacity {t_cap}"
            );
            let toks = &tgt[lane * w..lane * w + m];
            let positions = &pos[lane * w..lane * w + m];
            let mut x = vec![0f32; m * d];
            self.embed_into(toks, positions, &mut x);

            // Load the committed prefix into per-head panels, per layer.
            let mut kvs: Vec<KvPanels> = (0..n_l)
                .map(|l| {
                    let base = (l * eb + lane) * t_cap * d;
                    let mut kv = KvPanels::new(self.cfg.n_heads, dh);
                    kv.append(
                        &k_cache[base..base + start * d],
                        &v_cache[base..base + start * d],
                        start,
                    );
                    kv
                })
                .collect();

            let mem_pad = mem.pad_row(mem_rows[lane]);
            let mem_n = mem_pad.iter().take_while(|&&p| p > 0.0).count();
            let mrow = &mem.row(mem_rows[lane])[..mem_n * d];

            for (li, layer) in self.dec.iter().enumerate() {
                let h = layer_normed(&x, m, d, &layer.ln1.g, &layer.ln1.b);
                // The exact block the cached session runs (bit-identity
                // by construction, not by parallel maintenance).
                let a = self.fused_self_attn(&h, m, &layer.self_attn, &mut kvs[li], Some(start));
                add_assign(&mut x, &a);
                let h = layer_normed(&x, m, d, &layer.ln2.g, &layer.ln2.b);
                let a = self.cross_attn_full(&h, m, &layer.cross_attn, mrow, mem_n);
                add_assign(&mut x, &a);
                let h = layer_normed(&x, m, d, &layer.ln3.g, &layer.ln3.b);
                let f = self.ffn(&h, m, &layer.ffn);
                add_assign(&mut x, &f);
            }
            layer_norm(&mut x, m, d, &self.dec_ln_f.g, &self.dec_ln_f.b);
            let logits = self.out.apply(&x, m, self.threads);
            for i in 0..m {
                log_softmax_row_into(
                    &logits[i * v..(i + 1) * v],
                    &mut logp[(lane * w + i) * v..(lane * w + i + 1) * v],
                );
            }

            // Write the window's K/V back into the flat caches.
            for (l, kv) in kvs.iter().enumerate() {
                let base = (l * eb + lane) * t_cap * d;
                for s in start..start + m {
                    for h in 0..self.cfg.n_heads {
                        for dd in 0..dh {
                            k_cache[base + s * d + h * dh + dd] = kv.k_lane(h, dd)[s];
                        }
                        v_cache[base + s * d + h * dh..base + s * d + (h + 1) * dh]
                            .copy_from_slice(&kv.v_panel(h)[s * dh..(s + 1) * dh]);
                    }
                }
            }
        }
        Ok(logp)
    }
}

impl DecoderSession for CachedSession<'_> {
    fn dims(&self) -> ModelDims {
        Backend::dims(self.backend)
    }

    fn memory(&self) -> &Memory {
        &self.memory
    }

    fn append_memory(&mut self, extra: &Memory) -> usize {
        assert_eq!(extra.s_len, self.memory.s_len, "memory s_len mismatch");
        assert_eq!(extra.d_model, self.memory.d_model, "memory width mismatch");
        let base = self.memory.batch;
        self.memory.data.extend_from_slice(&extra.data);
        self.memory.pad.extend_from_slice(&extra.pad);
        self.memory.batch += extra.batch;
        self.cross.extend((0..extra.batch).map(|_| None));
        self.stats.encode_calls += 1;
        self.stats.packed_src_rows += extra.batch;
        base
    }

    fn new_row(&mut self, mem_row: usize) -> usize {
        assert!(mem_row < self.memory.batch, "memory row out of range");
        let cfg = &self.backend.cfg;
        let table = self.arena.as_mut().map(|a| a.new_table());
        let kv = if table.is_some() {
            Vec::new()
        } else {
            (0..cfg.n_dec)
                .map(|_| KvPanels::new(cfg.n_heads, cfg.d_head()))
                .collect()
        };
        self.rows.push(Some(SessRow {
            mem_row,
            cache: Arc::new(RowCache {
                tokens: Vec::new(),
                kv,
                lp: Vec::new(),
                lp_start: 0,
            }),
            len: 0,
            table,
        }));
        self.rows.len() - 1
    }

    fn fork(&mut self, row: usize) -> usize {
        let src = self.row(row);
        let mut copy = SessRow {
            mem_row: src.mem_row,
            cache: Arc::clone(&src.cache),
            len: src.len,
            table: src.table,
        };
        // Paged: O(pages) table clone + refcount bumps; no K/V floats
        // move until a divergent write COWs the shared tail page.
        if let Some(t) = copy.table {
            copy.table = Some(self.arena.as_mut().expect("table without an arena").fork(t));
        }
        self.rows.push(Some(copy));
        self.rows.len() - 1
    }

    fn truncate(&mut self, row: usize, len: usize) {
        let sr = self.rows[row].as_mut().expect("released session row");
        assert!(len <= sr.len, "truncate beyond row length");
        sr.len = len;
        // Paged: return whole pages past the cut to the free list now
        // (the partial page holding the new tail stays resident for the
        // next extend's heal).
        if let (Some(arena), Some(t)) = (self.arena.as_mut(), sr.table) {
            arena.truncate(t, len);
        }
    }

    fn release(&mut self, row: usize) {
        if let Some(sr) = self.rows[row].take() {
            if let (Some(arena), Some(t)) = (self.arena.as_mut(), sr.table) {
                arena.release(t);
            }
        }
    }

    fn row_len(&self, row: usize) -> usize {
        self.row(row).len
    }

    fn extend(&mut self, deltas: &[(usize, &[i64])]) -> Result<LogProbs> {
        let (t_len, v) = (self.backend.cfg.t_len, self.backend.cfg.vocab);
        self.stats.extend_calls += 1;
        self.stats.packed_rows += deltas.len();

        // Validate everything before mutating anything.
        for &(row, toks) in deltas {
            let sr = self.rows[row].as_ref().expect("released session row");
            anyhow::ensure!(
                sr.len + toks.len() <= t_len,
                "row length {} exceeds bucket {t_len}",
                sr.len + toks.len()
            );
        }

        // Pin every batch row's page table for the whole extend: one
        // row's page allocation must never evict a sibling that is about
        // to be (or already was) prepared in this same pass.
        if let Some(arena) = self.arena.as_mut() {
            for &(row, _) in deltas {
                let sr = self.rows[row].as_ref().expect("released session row");
                if let Some(t) = sr.table {
                    arena.set_pinned(t, true);
                }
            }
        }

        struct Prep<'t> {
            row: usize,
            sr: SessRow,
            cross: Arc<Vec<KvPanels>>,
            /// Borrows the caller's window on the common path; owns a
            /// prepended copy only for the rare deep-rewind heal.
            toks: std::borrow::Cow<'t, [i64]>,
            len_before: usize,
            delta_len: usize,
        }
        let mut prep: Vec<Prep<'_>> = Vec::with_capacity(deltas.len());
        for &(row, toks) in deltas {
            let mem_row = self.rows[row].as_ref().expect("released session row").mem_row;
            let cross = self.cross_for(mem_row);
            let mut sr = self.rows[row].take().expect("released session row");
            let len_before = sr.len;
            // K/V still resident for this row: everything (dense), or
            // whatever survived eviction (paged) — the rollback helper
            // deepens the resume point to cover the gap, and the heal
            // recompute is exact.
            let kv_valid = match (self.arena.as_ref(), sr.table) {
                (Some(a), Some(t)) => a.positions(t),
                _ => len_before,
            };
            // Unshare the scalar cache (one clone if forked) and roll
            // the buffers back to the resume point — the shared
            // session-contract helper handles both the deep-rewind heal
            // and eviction rehydration (bit-identical recomputes).
            let cache = Arc::make_mut(&mut sr.cache);
            let (start, job_toks) = rollback_for_extend_kv(
                &mut cache.tokens,
                &mut cache.lp,
                &mut cache.lp_start,
                len_before,
                kv_valid,
                toks,
                v,
            );
            match (self.arena.as_mut(), sr.table) {
                (Some(arena), Some(t)) => {
                    if kv_valid < len_before {
                        arena.note_rehydrated(len_before - start);
                    }
                    // Roll the page table back and make the append range
                    // writable (COW-unshare the tail page, allocate).
                    arena.truncate(t, start);
                    arena.prepare_append(t, start, job_toks.len());
                }
                _ => {
                    for kv in cache.kv.iter_mut() {
                        kv.truncate(start);
                    }
                }
            }
            self.stats.tokens_computed += job_toks.len();
            self.stats.tokens_reused += start;
            prep.push(Prep {
                row,
                sr,
                cross,
                toks: job_toks,
                len_before,
                delta_len: toks.len(),
            });
        }

        // One packed decoder pass per layer across every row's window.
        {
            let mut jobs: Vec<ExtendJob<'_>> = prep
                .iter_mut()
                .map(|p| {
                    let table = p.sr.table;
                    let cache = Arc::make_mut(&mut p.sr.cache);
                    let kv = match table {
                        Some(t) => JobKv::Paged(t),
                        None => JobKv::Dense(&mut cache.kv),
                    };
                    ExtendJob {
                        tokens: &mut cache.tokens,
                        lp: &mut cache.lp,
                        kv,
                        cross: &p.cross[..],
                        toks: &p.toks[..],
                    }
                })
                .collect();
            self.backend.extend_rows_batched(&mut jobs, self.arena.as_mut());
        }

        // Window sizing over logical lengths (same contract as before).
        let mut lens = Vec::with_capacity(prep.len());
        let mut window = 1usize;
        for p in prep.iter_mut() {
            p.sr.len = p.len_before + p.delta_len;
            lens.push(p.sr.len);
            window = window.max(needed_window(p.len_before, p.delta_len));
        }

        // Assemble the shared-window view from the per-row log-prob
        // caches, then trim each cache to the retention bound (shared
        // session-contract helpers).
        let mut data = vec![0f32; prep.len() * window * v];
        for (ri, p) in prep.iter().enumerate() {
            let cache = &p.sr.cache;
            assemble_window_row(&mut data, ri, window, v, p.sr.len, &cache.lp, cache.lp_start);
        }
        for mut p in prep {
            {
                let cache = Arc::get_mut(&mut p.sr.cache).expect("cache just unshared");
                let retained =
                    trim_lp_suffix(&mut cache.lp, &mut cache.lp_start, v, self.lp_retain);
                self.stats.lp_high_water = self.stats.lp_high_water.max(retained);
            }
            if let (Some(arena), Some(t)) = (self.arena.as_mut(), p.sr.table) {
                arena.set_pinned(t, false);
            }
            self.rows[p.row] = Some(p.sr);
        }
        Ok(LogProbs::new_windowed(data, lens, t_len, v, window))
    }

    fn stats(&self) -> SessionStats {
        let mut stats = self.stats;
        if let Some(arena) = self.arena.as_ref() {
            let a = arena.stats();
            stats.kv_pages_resident = a.pages_resident;
            stats.kv_pages_high_water = a.pages_high_water;
            stats.kv_page_bytes = a.page_bytes;
            stats.arena_evictions = a.evictions;
            stats.fork_pages_copied = a.fork_pages_copied;
        }
        stats
    }
}

impl RustBackend {
    /// Open a [`CachedSession`] as a concrete type (tests and tools use
    /// this to reach knobs like [`CachedSession::set_lp_retention`]).
    pub fn begin_cached(&self, memory: Memory) -> CachedSession<'_> {
        CachedSession::new(self, memory)
    }

    /// Open a [`CachedSession`] with an explicit arena configuration
    /// (`None` forces the dense per-row K/V path), bypassing the
    /// `RXNSPEC_ARENA` environment knobs. Tests use this to exercise
    /// both residency models without racing on process-global env vars.
    pub fn begin_cached_with(&self, memory: Memory, arena: Option<ArenaConfig>) -> CachedSession<'_> {
        CachedSession::with_arena(self, memory, arena)
    }
}
