//! Pure-Rust reference implementation of the Molecular Transformer.
//!
//! Mirrors `python/compile/model.py` operation for operation (pre-LN
//! encoder-decoder, sinusoidal encodings from explicit position ids,
//! log-softmax outputs) over the same RXW1 weights file. It plays the role
//! the OpenNMT "original MT" plays in the paper's Table 1: an independent
//! implementation whose outputs the production path (the AOT artifact run
//! by PJRT) is validated against. It also lets the entire decoding stack
//! run and be tested without compiled artifacts.
//!
//! Numerical parity with the artifact is approximate (different reduction
//! orders), ~1e-3 absolute on log-probs — enough for argmax/top-k
//! agreement on all but pathological ties; `rust/tests/backend_parity.rs`
//! quantifies it.
//!
//! The compute here is straightforward scalar code: the PJRT path is the
//! performance story, this one is the oracle.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::decoding::{
    Backend, DecoderRow, DecoderSession, LogProbs, Memory, ModelDims, SessionStats,
};
use crate::model::weights::{load_config, Tensor, Weights};

/// Model hyper-parameters (matches `ModelConfig` in model.py).
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_enc: usize,
    pub n_dec: usize,
    pub s_len: usize,
    pub t_len: usize,
}

impl Config {
    pub fn from_file(path: &Path) -> Result<Config> {
        let kv = load_config(path)?;
        let g = |k: &str| -> Result<usize> {
            kv.get(k)
                .copied()
                .ok_or_else(|| anyhow::anyhow!("config missing {k}"))
        };
        Ok(Config {
            vocab: g("vocab")?,
            d_model: g("d_model")?,
            n_heads: g("n_heads")?,
            d_ff: g("d_ff")?,
            n_enc: g("n_enc")?,
            n_dec: g("n_dec")?,
            s_len: g("s_len")?,
            t_len: g("t_len")?,
        })
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

const NEG_INF: f32 = -1e9;

// ---------------------------------------------------------------------------
// Small dense-algebra helpers (row-major [rows, cols] in flat Vec<f32>)
// ---------------------------------------------------------------------------

/// y[r,:] += x[r,:] @ w + b for all rows; x is [n, din], w [din, dout].
fn linear(x: &[f32], n: usize, w: &Tensor, b: &Tensor) -> Vec<f32> {
    let (din, dout) = (w.dims[0], w.dims[1]);
    debug_assert_eq!(x.len(), n * din);
    let mut y = vec![0f32; n * dout];
    for r in 0..n {
        let xr = &x[r * din..(r + 1) * din];
        let yr = &mut y[r * dout..(r + 1) * dout];
        yr.copy_from_slice(&b.data);
        for (i, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w.data[i * dout..(i + 1) * dout];
            for (o, &wv) in yr.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    y
}

fn layer_norm(x: &mut [f32], n: usize, d: usize, g: &Tensor, b: &Tensor) {
    for r in 0..n {
        let row = &mut x[r * d..(r + 1) * d];
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - mu) * inv * g.data[i] + b.data[i];
        }
    }
}

fn layer_normed(x: &[f32], n: usize, d: usize, g: &Tensor, b: &Tensor) -> Vec<f32> {
    let mut y = x.to_vec();
    layer_norm(&mut y, n, d, g, b);
    y
}

/// Sinusoidal positional encoding row for one position id.
fn add_pe(row: &mut [f32], pos: i64, d: usize) {
    let half = d / 2;
    for i in 0..half {
        let freq = (-(10000f32).ln() * (2.0 * i as f32 / d as f32)).exp();
        let ang = pos as f32 * freq;
        row[i] += ang.sin();
        row[half + i] += ang.cos();
    }
}

/// Scaled-dot-product attention over already-projected q/k/v rows.
/// `allow(i, j)` gates whether query i may attend key j (the
/// additive-mask analogue). Factored out of [`mha`] so the KV-cached
/// session path runs the *same arithmetic in the same order* against
/// cached key/value buffers — bit-identical results are a tested
/// invariant, not an accident.
fn attn_core<F: Fn(usize, usize) -> bool>(
    q: &[f32],
    nq: usize,
    k: &[f32],
    v: &[f32],
    nk: usize,
    n_heads: usize,
    d_model: usize,
    allow: F,
) -> Vec<f32> {
    let dh = d_model / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut ctx = vec![0f32; nq * d_model];
    let mut scores = vec![0f32; nk];
    for h in 0..n_heads {
        let off = h * dh;
        for i in 0..nq {
            let qi = &q[i * d_model + off..i * d_model + off + dh];
            let mut mx = f32::NEG_INFINITY;
            for j in 0..nk {
                let s = if allow(i, j) {
                    let kj = &k[j * d_model + off..j * d_model + off + dh];
                    qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale
                } else {
                    NEG_INF
                };
                scores[j] = s;
                mx = mx.max(s);
            }
            let mut z = 0f32;
            for s in scores[..nk].iter_mut() {
                *s = (*s - mx).exp();
                z += *s;
            }
            let inv = 1.0 / z;
            let ci = &mut ctx[i * d_model + off..i * d_model + off + dh];
            for j in 0..nk {
                let w = scores[j] * inv;
                if w == 0.0 {
                    continue;
                }
                let vj = &v[j * d_model + off..j * d_model + off + dh];
                for (c, &vv) in ci.iter_mut().zip(vj) {
                    *c += w * vv;
                }
            }
        }
    }
    ctx
}

/// Multi-head attention: q rows attend to kv rows. `allow(i, j)` gates
/// whether query i may attend key j (the additive-mask analogue).
fn mha<F: Fn(usize, usize) -> bool>(
    xq: &[f32],
    nq: usize,
    xkv: &[f32],
    nk: usize,
    p: &AttnParams,
    n_heads: usize,
    d_model: usize,
    allow: F,
) -> Vec<f32> {
    let q = linear(xq, nq, &p.wq, &p.bq);
    let k = linear(xkv, nk, &p.wk, &p.bk);
    let v = linear(xkv, nk, &p.wv, &p.bv);
    let ctx = attn_core(&q, nq, &k, &v, nk, n_heads, d_model, allow);
    linear(&ctx, nq, &p.wo, &p.bo)
}

fn add_assign(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

// ---------------------------------------------------------------------------
// Parameter bundles
// ---------------------------------------------------------------------------

struct AttnParams {
    wq: Tensor,
    wk: Tensor,
    wv: Tensor,
    wo: Tensor,
    bq: Tensor,
    bk: Tensor,
    bv: Tensor,
    bo: Tensor,
}

struct FfnParams {
    w1: Tensor,
    b1: Tensor,
    w2: Tensor,
    b2: Tensor,
}

struct LnParams {
    g: Tensor,
    b: Tensor,
}

struct EncLayer {
    ln1: LnParams,
    attn: AttnParams,
    ln2: LnParams,
    ffn: FfnParams,
}

struct DecLayer {
    ln1: LnParams,
    self_attn: AttnParams,
    ln2: LnParams,
    cross_attn: AttnParams,
    ln3: LnParams,
    ffn: FfnParams,
}

fn attn_params(w: &Weights, prefix: &str) -> Result<AttnParams> {
    Ok(AttnParams {
        wq: w.get(&format!("{prefix}.wq"))?.clone(),
        wk: w.get(&format!("{prefix}.wk"))?.clone(),
        wv: w.get(&format!("{prefix}.wv"))?.clone(),
        wo: w.get(&format!("{prefix}.wo"))?.clone(),
        bq: w.get(&format!("{prefix}.bq"))?.clone(),
        bk: w.get(&format!("{prefix}.bk"))?.clone(),
        bv: w.get(&format!("{prefix}.bv"))?.clone(),
        bo: w.get(&format!("{prefix}.bo"))?.clone(),
    })
}

fn ffn_params(w: &Weights, prefix: &str) -> Result<FfnParams> {
    Ok(FfnParams {
        w1: w.get(&format!("{prefix}.w1"))?.clone(),
        b1: w.get(&format!("{prefix}.b1"))?.clone(),
        w2: w.get(&format!("{prefix}.w2"))?.clone(),
        b2: w.get(&format!("{prefix}.b2"))?.clone(),
    })
}

fn ln_params(w: &Weights, prefix: &str) -> Result<LnParams> {
    Ok(LnParams {
        g: w.get(&format!("{prefix}.g"))?.clone(),
        b: w.get(&format!("{prefix}.b"))?.clone(),
    })
}

/// The reference backend: weights + config, implements [`Backend`].
pub struct RustBackend {
    cfg: Config,
    tok_emb: Tensor,
    out_w: Tensor,
    out_b: Tensor,
    enc_ln_f: LnParams,
    dec_ln_f: LnParams,
    enc: Vec<EncLayer>,
    dec: Vec<DecLayer>,
}

impl RustBackend {
    /// Load from `artifacts/weights_{task}.bin` + `config_{task}.txt`.
    pub fn load(weights_path: &Path, config_path: &Path) -> Result<RustBackend> {
        let cfg = Config::from_file(config_path)?;
        let w = Weights::load(weights_path)?;
        Self::from_weights(&w, cfg)
    }

    pub fn from_weights(w: &Weights, cfg: Config) -> Result<RustBackend> {
        let mut enc = Vec::new();
        for i in 0..cfg.n_enc {
            enc.push(EncLayer {
                ln1: ln_params(w, &format!("enc{i}.ln1"))?,
                attn: attn_params(w, &format!("enc{i}.attn"))?,
                ln2: ln_params(w, &format!("enc{i}.ln2"))?,
                ffn: ffn_params(w, &format!("enc{i}.ffn"))?,
            });
        }
        let mut dec = Vec::new();
        for i in 0..cfg.n_dec {
            dec.push(DecLayer {
                ln1: ln_params(w, &format!("dec{i}.ln1"))?,
                self_attn: attn_params(w, &format!("dec{i}.self_attn"))?,
                ln2: ln_params(w, &format!("dec{i}.ln2"))?,
                cross_attn: attn_params(w, &format!("dec{i}.cross_attn"))?,
                ln3: ln_params(w, &format!("dec{i}.ln3"))?,
                ffn: ffn_params(w, &format!("dec{i}.ffn"))?,
            });
        }
        Ok(RustBackend {
            cfg,
            tok_emb: w.get("tok_emb")?.clone(),
            out_w: w.get("out_w")?.clone(),
            out_b: w.get("out_b")?.clone(),
            enc_ln_f: ln_params(w, "enc_ln_f")?,
            dec_ln_f: ln_params(w, "dec_ln_f")?,
            enc,
            dec,
        })
    }

    pub fn config(&self) -> Config {
        self.cfg
    }

    fn embed(&self, tokens: &[i64], positions: &[i64]) -> Vec<f32> {
        let d = self.cfg.d_model;
        let scale = (d as f32).sqrt();
        let mut x = vec![0f32; tokens.len() * d];
        for (i, &t) in tokens.iter().enumerate() {
            let row = &mut x[i * d..(i + 1) * d];
            let emb = &self.tok_emb.data[t as usize * d..(t as usize + 1) * d];
            for (o, &e) in row.iter_mut().zip(emb) {
                *o = e * scale;
            }
            add_pe(row, positions[i], d);
        }
        x
    }
}

impl Backend for RustBackend {
    fn dims(&self) -> ModelDims {
        ModelDims {
            s_len: self.cfg.s_len,
            t_len: self.cfg.t_len,
            d_model: self.cfg.d_model,
            vocab: self.cfg.vocab,
        }
    }

    fn encode(&self, srcs: &[&[i64]]) -> Result<Memory> {
        let (s_len, d) = (self.cfg.s_len, self.cfg.d_model);
        let mut data = vec![0f32; srcs.len() * s_len * d];
        let mut pad = vec![0f32; srcs.len() * s_len];
        for (bi, src) in srcs.iter().enumerate() {
            let n = src.len();
            anyhow::ensure!(n <= s_len, "src length {n} exceeds bucket {s_len}");
            let positions: Vec<i64> = (0..n as i64).collect();
            let mut x = self.embed(src, &positions);
            for layer in &self.enc {
                let h = layer_normed(&x, n, d, &layer.ln1.g, &layer.ln1.b);
                let a = mha(
                    &h,
                    n,
                    &h,
                    n,
                    &layer.attn,
                    self.cfg.n_heads,
                    d,
                    |_, _| true, // compact rows: no pad keys exist
                );
                add_assign(&mut x, &a);
                let h = layer_normed(&x, n, d, &layer.ln2.g, &layer.ln2.b);
                let mut f = linear(&h, n, &layer.ffn.w1, &layer.ffn.b1);
                relu(&mut f);
                let f = linear(&f, n, &layer.ffn.w2, &layer.ffn.b2);
                add_assign(&mut x, &f);
            }
            layer_norm(&mut x, n, d, &self.enc_ln_f.g, &self.enc_ln_f.b);
            data[bi * s_len * d..bi * s_len * d + n * d].copy_from_slice(&x);
            for p in pad[bi * s_len..bi * s_len + n].iter_mut() {
                *p = 1.0;
            }
        }
        Ok(Memory {
            data,
            pad,
            batch: srcs.len(),
            s_len,
            d_model: d,
        })
    }

    fn decode(&self, rows: &[DecoderRow], memory: &Memory) -> Result<LogProbs> {
        let (t_len, d, v) = (self.cfg.t_len, self.cfg.d_model, self.cfg.vocab);
        let mut out = vec![0f32; rows.len() * t_len * v];
        let mut lens = Vec::with_capacity(rows.len());
        for (ri, row) in rows.iter().enumerate() {
            let n = row.tokens.len();
            anyhow::ensure!(n <= t_len, "row length {n} exceeds bucket {t_len}");
            lens.push(n);
            // Compact computation: pad columns contribute nothing (their
            // keys are masked, their queries unread), so we evaluate only
            // the n real positions with positions 0..n — numerically equal
            // to the padded layouts (see test_model.py's left-pad test).
            let positions: Vec<i64> = (0..n as i64).collect();
            let mut x = self.embed(&row.tokens, &positions);

            // Memory row: compact to its real length.
            let mem_pad = memory.pad_row(row.mem_row);
            let mem_n = mem_pad.iter().take_while(|&&p| p > 0.0).count();
            let mem = &memory.row(row.mem_row)[..mem_n * d];

            for layer in &self.dec {
                let h = layer_normed(&x, n, d, &layer.ln1.g, &layer.ln1.b);
                let a = mha(
                    &h,
                    n,
                    &h,
                    n,
                    &layer.self_attn,
                    self.cfg.n_heads,
                    d,
                    |i, j| j <= i, // causal
                );
                add_assign(&mut x, &a);
                let h = layer_normed(&x, n, d, &layer.ln2.g, &layer.ln2.b);
                let a = mha(
                    &h,
                    n,
                    mem,
                    mem_n,
                    &layer.cross_attn,
                    self.cfg.n_heads,
                    d,
                    |_, _| true,
                );
                add_assign(&mut x, &a);
                let h = layer_normed(&x, n, d, &layer.ln3.g, &layer.ln3.b);
                let mut f = linear(&h, n, &layer.ffn.w1, &layer.ffn.b1);
                relu(&mut f);
                let f = linear(&f, n, &layer.ffn.w2, &layer.ffn.b2);
                add_assign(&mut x, &f);
            }
            layer_norm(&mut x, n, d, &self.dec_ln_f.g, &self.dec_ln_f.b);
            let logits = linear(&x, n, &self.out_w, &self.out_b);
            // log_softmax per position, written right-aligned into [T, V].
            let base = ri * t_len * v + (t_len - n) * v;
            for i in 0..n {
                let lrow = &logits[i * v..(i + 1) * v];
                let mx = lrow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let z: f32 = lrow.iter().map(|&l| (l - mx).exp()).sum();
                let lz = mx + z.ln();
                let orow = &mut out[base + i * v..base + (i + 1) * v];
                for (o, &l) in orow.iter_mut().zip(lrow) {
                    *o = l - lz;
                }
            }
        }
        Ok(LogProbs::new(out, lens, t_len, v))
    }

    fn begin(&self, memory: Memory) -> Result<Box<dyn DecoderSession + '_>> {
        Ok(Box::new(CachedSession::new(self, memory)))
    }
}

// ---------------------------------------------------------------------------
// KV-cached incremental decoding session
// ---------------------------------------------------------------------------

/// Per-layer self-attention K/V of one row, row-major `[len, d_model]`.
#[derive(Clone)]
struct LayerKv {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Committed state of one session row. Forks share it through an `Arc`
/// (copy-on-write: the first `extend` after a fork clones exactly once).
#[derive(Clone)]
struct RowCache {
    tokens: Vec<i64>,
    /// One entry per decoder layer.
    kv: Vec<LayerKv>,
    /// Per-position successor log-probs, `[len, vocab]` — kept so that
    /// `extend` can serve the window position `len_before - 1` (the
    /// successor of the last committed token) without recomputing it,
    /// and so truncated rows can re-expose earlier distributions.
    lp: Vec<f32>,
}

struct SessRow {
    mem_row: usize,
    cache: Arc<RowCache>,
    /// Logical committed length. `truncate` only moves this (O(1)); the
    /// shared buffers are trimmed lazily by the next `extend` once the
    /// row holds a unique copy.
    len: usize,
}

/// Cross-attention K/V of one memory row (one entry per decoder layer,
/// `[mem_n, d_model]` each) — computed once per memory row per session
/// instead of once per decoder call.
struct CrossKv {
    k: Vec<f32>,
    v: Vec<f32>,
    mem_n: usize,
}

/// The reference backend's [`DecoderSession`]: incremental self-attention
/// K/V, session-cached cross-attention K/V, and cached per-position
/// log-probs. Produces **bit-identical** log-probabilities to
/// [`RustBackend::decode`] — the conditional-consistency contract makes
/// this a hard invariant, property-tested in
/// `rust/tests/session_parity.rs`.
pub struct CachedSession<'a> {
    backend: &'a RustBackend,
    memory: Memory,
    cross: Vec<Option<Arc<Vec<CrossKv>>>>,
    rows: Vec<Option<SessRow>>,
    stats: SessionStats,
}

impl<'a> CachedSession<'a> {
    pub fn new(backend: &'a RustBackend, memory: Memory) -> CachedSession<'a> {
        let batch = memory.batch;
        CachedSession {
            backend,
            memory,
            cross: (0..batch).map(|_| None).collect(),
            rows: Vec::new(),
            stats: SessionStats::default(),
        }
    }

    fn row(&self, row: usize) -> &SessRow {
        self.rows[row].as_ref().expect("released session row")
    }

    /// Lazily project this memory row's cross-attention K/V per layer —
    /// the same `linear` calls `mha` issued per decode call, hoisted to
    /// once per session.
    fn cross_for(&mut self, mem_row: usize) -> Arc<Vec<CrossKv>> {
        if self.cross[mem_row].is_none() {
            let d = self.backend.cfg.d_model;
            let mem_pad = self.memory.pad_row(mem_row);
            let mem_n = mem_pad.iter().take_while(|&&p| p > 0.0).count();
            let mem = &self.memory.row(mem_row)[..mem_n * d];
            let per_layer = self
                .backend
                .dec
                .iter()
                .map(|layer| CrossKv {
                    k: linear(mem, mem_n, &layer.cross_attn.wk, &layer.cross_attn.bk),
                    v: linear(mem, mem_n, &layer.cross_attn.wv, &layer.cross_attn.bv),
                    mem_n,
                })
                .collect();
            self.cross[mem_row] = Some(Arc::new(per_layer));
        }
        Arc::clone(self.cross[mem_row].as_ref().unwrap())
    }
}

impl RustBackend {
    /// Compute the decoder stack for `new_toks` appended to the committed
    /// row state in `cache`, reusing the cached per-layer K/V of the
    /// prefix. Mirrors the per-row body of [`RustBackend::decode`]
    /// operation for operation.
    fn extend_row(&self, cache: &mut RowCache, cross: &[CrossKv], new_toks: &[i64]) {
        let d = self.cfg.d_model;
        let v = self.cfg.vocab;
        let p = cache.tokens.len();
        let m = new_toks.len();
        if m == 0 {
            return;
        }
        let positions: Vec<i64> = (p as i64..(p + m) as i64).collect();
        let mut x = self.embed(new_toks, &positions);
        cache.tokens.extend_from_slice(new_toks);

        for (li, layer) in self.dec.iter().enumerate() {
            // Causal self-attention over cached + fresh K/V.
            let h = layer_normed(&x, m, d, &layer.ln1.g, &layer.ln1.b);
            let q = linear(&h, m, &layer.self_attn.wq, &layer.self_attn.bq);
            let k_new = linear(&h, m, &layer.self_attn.wk, &layer.self_attn.bk);
            let v_new = linear(&h, m, &layer.self_attn.wv, &layer.self_attn.bv);
            let kv = &mut cache.kv[li];
            kv.k.extend_from_slice(&k_new);
            kv.v.extend_from_slice(&v_new);
            let nk = p + m;
            let ctx = attn_core(&q, m, &kv.k, &kv.v, nk, self.cfg.n_heads, d, |i, j| {
                j <= p + i // causal in global positions
            });
            let a = linear(&ctx, m, &layer.self_attn.wo, &layer.self_attn.bo);
            add_assign(&mut x, &a);

            // Cross-attention against the session-cached memory K/V.
            let h = layer_normed(&x, m, d, &layer.ln2.g, &layer.ln2.b);
            let q = linear(&h, m, &layer.cross_attn.wq, &layer.cross_attn.bq);
            let ck = &cross[li];
            let ctx = attn_core(
                &q,
                m,
                &ck.k,
                &ck.v,
                ck.mem_n,
                self.cfg.n_heads,
                d,
                |_, _| true,
            );
            let a = linear(&ctx, m, &layer.cross_attn.wo, &layer.cross_attn.bo);
            add_assign(&mut x, &a);

            let h = layer_normed(&x, m, d, &layer.ln3.g, &layer.ln3.b);
            let mut f = linear(&h, m, &layer.ffn.w1, &layer.ffn.b1);
            relu(&mut f);
            let f = linear(&f, m, &layer.ffn.w2, &layer.ffn.b2);
            add_assign(&mut x, &f);
        }
        layer_norm(&mut x, m, d, &self.dec_ln_f.g, &self.dec_ln_f.b);
        let logits = linear(&x, m, &self.out_w, &self.out_b);
        for i in 0..m {
            let lrow = &logits[i * v..(i + 1) * v];
            let mx = lrow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = lrow.iter().map(|&l| (l - mx).exp()).sum();
            let lz = mx + z.ln();
            for &l in lrow {
                cache.lp.push(l - lz);
            }
        }
    }
}

impl DecoderSession for CachedSession<'_> {
    fn dims(&self) -> ModelDims {
        Backend::dims(self.backend)
    }

    fn memory(&self) -> &Memory {
        &self.memory
    }

    fn append_memory(&mut self, extra: &Memory) -> usize {
        assert_eq!(extra.s_len, self.memory.s_len, "memory s_len mismatch");
        assert_eq!(extra.d_model, self.memory.d_model, "memory width mismatch");
        let base = self.memory.batch;
        self.memory.data.extend_from_slice(&extra.data);
        self.memory.pad.extend_from_slice(&extra.pad);
        self.memory.batch += extra.batch;
        self.cross.extend((0..extra.batch).map(|_| None));
        base
    }

    fn new_row(&mut self, mem_row: usize) -> usize {
        assert!(mem_row < self.memory.batch, "memory row out of range");
        let n_dec = self.backend.cfg.n_dec;
        self.rows.push(Some(SessRow {
            mem_row,
            cache: Arc::new(RowCache {
                tokens: Vec::new(),
                kv: (0..n_dec)
                    .map(|_| LayerKv {
                        k: Vec::new(),
                        v: Vec::new(),
                    })
                    .collect(),
                lp: Vec::new(),
            }),
            len: 0,
        }));
        self.rows.len() - 1
    }

    fn fork(&mut self, row: usize) -> usize {
        let src = self.row(row);
        let copy = SessRow {
            mem_row: src.mem_row,
            cache: Arc::clone(&src.cache),
            len: src.len,
        };
        self.rows.push(Some(copy));
        self.rows.len() - 1
    }

    fn truncate(&mut self, row: usize, len: usize) {
        let sr = self.rows[row].as_mut().expect("released session row");
        assert!(len <= sr.len, "truncate beyond row length");
        sr.len = len;
    }

    fn release(&mut self, row: usize) {
        self.rows[row] = None;
    }

    fn row_len(&self, row: usize) -> usize {
        self.row(row).len
    }

    fn extend(&mut self, deltas: &[(usize, &[i64])]) -> Result<LogProbs> {
        let (t_len, v) = (self.backend.cfg.t_len, self.backend.cfg.vocab);
        let d = self.backend.cfg.d_model;
        self.stats.extend_calls += 1;

        let mut lens = Vec::with_capacity(deltas.len());
        let mut window = 1usize;
        for &(row, toks) in deltas {
            let mem_row = self.row(row).mem_row;
            let cross = self.cross_for(mem_row);
            let sr = self.rows[row].as_mut().expect("released session row");
            let len_before = sr.len;
            anyhow::ensure!(
                len_before + toks.len() <= t_len,
                "row length {} exceeds bucket {t_len}",
                len_before + toks.len()
            );
            // Unshare (one clone if forked) and roll the buffers back to
            // the logical length before appending.
            let cache = Arc::make_mut(&mut sr.cache);
            cache.tokens.truncate(len_before);
            cache.lp.truncate(len_before * v);
            for kv in cache.kv.iter_mut() {
                kv.k.truncate(len_before * d);
                kv.v.truncate(len_before * d);
            }
            self.backend.extend_row(cache, &cross, toks);
            sr.len = len_before + toks.len();
            self.stats.tokens_computed += toks.len();
            self.stats.tokens_reused += len_before;
            lens.push(sr.len);
            let needed = (toks.len() + usize::from(len_before > 0)).min(sr.len);
            window = window.max(needed);
        }

        // Assemble the shared-window view from the per-row log-prob
        // caches (unfilled leading columns are unreadable by contract).
        let mut data = vec![0f32; deltas.len() * window * v];
        for (ri, &(row, _)) in deltas.iter().enumerate() {
            let sr = self.row(row);
            let len = sr.len;
            for j in len.saturating_sub(window)..len {
                let wcol = window - len + j;
                let dst = (ri * window + wcol) * v;
                data[dst..dst + v].copy_from_slice(&sr.cache.lp[j * v..(j + 1) * v]);
            }
        }
        Ok(LogProbs::new_windowed(data, lens, t_len, v, window))
    }

    fn stats(&self) -> SessionStats {
        self.stats
    }
}
