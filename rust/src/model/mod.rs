//! Model substrate: weights IO and the pure-Rust reference transformer.

pub mod reference;
pub mod weights;

pub use reference::{CachedSession, Config, RustBackend};
pub use weights::{load_config, Tensor, Weights};
