//! RXW1 weights file reader (writer lives in `python/compile/weights_io.py`).
//!
//! Layout (little-endian): magic `RXW1`, u32 tensor count, then per tensor
//! `u32 name_len, name, u32 ndim, u32 dims…, u8 dtype (0 = f32), raw f32`.
//! Keys are dotted paths (`dec0.ffn.w1`), sorted, deterministic.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One named tensor: row-major f32 data plus its shape.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// 2-D accessor (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j]
    }
}

/// All tensors of one checkpoint, by dotted name.
#[derive(Debug, Clone)]
pub struct Weights {
    tensors: HashMap<String, Tensor>,
}

impl Weights {
    /// Assemble a checkpoint in memory (tests build tiny random models;
    /// the session-parity property tests run the reference transformer
    /// without any file on disk).
    pub fn from_tensors<I: IntoIterator<Item = (String, Tensor)>>(tensors: I) -> Weights {
        Weights {
            tensors: tensors.into_iter().collect(),
        }
    }

    pub fn load(path: &Path) -> Result<Weights> {
        let data = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        if data.len() < 8 || &data[0..4] != b"RXW1" {
            bail!("{}: not an RXW1 weights file", path.display());
        }
        let mut off = 4usize;
        let rd_u32 = |data: &[u8], off: &mut usize| -> Result<u32> {
            if *off + 4 > data.len() {
                bail!("truncated weights file");
            }
            let v = u32::from_le_bytes(data[*off..*off + 4].try_into().unwrap());
            *off += 4;
            Ok(v)
        };
        let count = rd_u32(&data, &mut off)?;
        let mut tensors = HashMap::with_capacity(count as usize);
        for _ in 0..count {
            let nlen = rd_u32(&data, &mut off)? as usize;
            let name = String::from_utf8(data[off..off + nlen].to_vec())?;
            off += nlen;
            let ndim = rd_u32(&data, &mut off)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(rd_u32(&data, &mut off)? as usize);
            }
            if off >= data.len() {
                bail!("truncated weights file at {name}");
            }
            let dtype = data[off];
            off += 1;
            if dtype != 0 {
                bail!("{name}: unsupported dtype {dtype}");
            }
            let n: usize = dims.iter().product();
            if off + 4 * n > data.len() {
                bail!("truncated tensor data for {name}");
            }
            let mut values = Vec::with_capacity(n);
            for i in 0..n {
                let b = &data[off + 4 * i..off + 4 * i + 4];
                values.push(f32::from_le_bytes(b.try_into().unwrap()));
            }
            off += 4 * n;
            tensors.insert(name, Tensor { dims, data: values });
        }
        Ok(Weights { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing tensor {name}"))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.tensors.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// FNV-1a over sorted names, shapes and raw f32 bits: the checkpoint
    /// identity folded into cross-request cache keys (`cache::ServeCache`
    /// flushes on mismatch so entries never survive a model redeploy).
    pub fn content_hash(&self) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for name in self.names() {
            h = fnv1a(h, name.as_bytes());
            let t = &self.tensors[name];
            for &d in &t.dims {
                h = fnv1a(h, &(d as u64).to_le_bytes());
            }
            for &x in &t.data {
                h = fnv1a(h, &x.to_bits().to_le_bytes());
            }
        }
        h
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

/// One FNV-1a fold step over a byte run — the single hash primitive
/// behind every artifact/weights identity (`Weights::content_hash`,
/// `runtime::pjrt`'s manifest fold), so the constants live in one place.
pub fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// `config_{task}.txt` reader: `key=value` lines (see weights_io.py).
pub fn load_config(path: &Path) -> Result<HashMap<String, usize>> {
    let body =
        std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
    let mut out = HashMap::new();
    for line in body.lines() {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .with_context(|| format!("bad config line {line:?}"))?;
        out.insert(k.to_string(), v.parse()?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_rxw1(path: &Path, tensors: &[(&str, Vec<usize>, Vec<f32>)]) {
        let mut buf: Vec<u8> = b"RXW1".to_vec();
        buf.extend((tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            buf.extend((name.len() as u32).to_le_bytes());
            buf.extend(name.as_bytes());
            buf.extend((dims.len() as u32).to_le_bytes());
            for d in dims {
                buf.extend((*d as u32).to_le_bytes());
            }
            buf.push(0u8);
            for v in data {
                buf.extend(v.to_le_bytes());
            }
        }
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(&buf).unwrap();
    }

    #[test]
    fn roundtrip_read() {
        let dir = std::env::temp_dir().join("rxnspec_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_rxw1(
            &p,
            &[
                ("a.b", vec![2, 3], (0..6).map(|x| x as f32).collect()),
                ("c", vec![2], vec![1.5, -2.5]),
            ],
        );
        let w = Weights::load(&p).unwrap();
        assert_eq!(w.len(), 2);
        let t = w.get("a.b").unwrap();
        assert_eq!(t.dims, vec![2, 3]);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(w.get("c").unwrap().data, vec![1.5, -2.5]);
        assert!(w.get("nope").is_err());
    }

    #[test]
    fn content_hash_tracks_content() {
        let t = |data: Vec<f32>| Tensor {
            dims: vec![data.len()],
            data,
        };
        let a = Weights::from_tensors(vec![("x".to_string(), t(vec![1.0, 2.0]))]);
        let b = Weights::from_tensors(vec![("x".to_string(), t(vec![1.0, 2.0]))]);
        let c = Weights::from_tensors(vec![("x".to_string(), t(vec![1.0, 2.5]))]);
        let d = Weights::from_tensors(vec![("y".to_string(), t(vec![1.0, 2.0]))]);
        assert_eq!(a.content_hash(), b.content_hash());
        assert_ne!(a.content_hash(), c.content_hash());
        assert_ne!(a.content_hash(), d.content_hash());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("rxnspec_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(Weights::load(&p).is_err());
    }

    #[test]
    fn config_parse() {
        let dir = std::env::temp_dir().join("rxnspec_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.txt");
        std::fs::write(&p, "d_model=128\nvocab=31\n").unwrap();
        let c = load_config(&p).unwrap();
        assert_eq!(c["d_model"], 128);
        assert_eq!(c["vocab"], 31);
    }
}
